# lint-fixture-path: src/repro/core/fixture_rl005.py
"""RL005 fail: sys.path mutation, host clock/RNG in a jitted module."""
import random                            # RL005: host RNG module
import sys
import time                              # RL005: host clock

import numpy as np

sys.path.insert(0, "/tmp/somewhere")     # RL005: sys.path mutation


def sample(m):
    np.random.seed(0)                    # RL005: legacy global state
    t0 = time.time()
    return np.random.rand(m), random.random(), t0
