# lint-fixture-path: src/repro/core/sharded_batched.py
"""RL002 fail: a collective with no wire-counter binding in the same
function, and a schema wire field whose accumulation was deleted."""
from typing import NamedTuple

import jax
import jax.numpy as jnp


class _RoundCarry(NamedTuple):
    wire_core: jax.Array


STATE_DTYPES = dict(wire_bytes="int32")


def _round_body(c, cx):
    cx_all = jax.lax.all_gather(cx, "players")   # RL002: unaccounted
    return _RoundCarry(c.wire_core)              # no accumulation either


def _one_step(s, out):
    return {"rounds": s["rounds"] + 1}           # wire_bytes update gone
