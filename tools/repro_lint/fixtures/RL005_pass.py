# lint-fixture-path: src/repro/core/fixture_rl005.py
"""RL005 pass: seeded generator API only, no host clock, no sys.path."""
import numpy as np


def sample(seed, m):
    rng = np.random.default_rng(seed)   # seeded Generator API: allowed
    return rng.standard_normal(m)
