"""RL004 pass fixture: pallas body stub."""


def demo_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]
