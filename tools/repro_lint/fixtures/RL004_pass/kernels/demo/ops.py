"""RL004 pass fixture: public entry routing the interpret flag."""


def demo(x, *, interpret=None):
    return x
