"""RL004 pass fixture: pure-jnp ground truth."""


def demo_ref(x):
    return x
