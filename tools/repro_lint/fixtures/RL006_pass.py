# lint-fixture-path: src/repro/core/fixture_rl006.py
"""RL006 pass: spans wrap the dispatch on the host; named_scope inside."""
import functools

import jax
import jax.numpy as jnp

from repro.obs import trace as obs_trace


def _round(carry):
    s, i = carry
    with jax.named_scope("round"):          # device-visible label: allowed
        return s + jnp.float32(1.0), i + 1


@functools.partial(jax.jit, static_argnames=())
def _run(s):
    out, _ = jax.lax.while_loop(lambda c: c[1] < 4, _round,
                                (s, jnp.int32(0)))
    return out


def run(s):
    """Host wrapper: span + annotation OUTSIDE the traced closure."""
    with obs_trace.span("run_rounds", "engine", engine="fixture"), \
            obs_trace.annotate("run"):
        return _run(jnp.asarray(s, jnp.float32))
