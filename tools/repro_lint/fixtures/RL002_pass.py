# lint-fixture-path: src/repro/core/sharded_batched.py
"""RL002 pass: collectives paired with wire counters; every schema wire
field has a maintaining accumulation."""
from typing import NamedTuple

import jax
import jax.numpy as jnp


class _RoundCarry(NamedTuple):
    wire_core: jax.Array


STATE_DTYPES = dict(wire_bytes="int32")


def _round_body(c, cx):
    cx_all = jax.lax.all_gather(cx, "players")
    n_examples = cx_all.shape[0] * cx_all.shape[1]
    return _RoundCarry(wire_core=c.wire_core + n_examples)


def _one_step(s, out):
    return {"wire_bytes": s["wire_bytes"] + out.wire_core * 8}
