# lint-fixture-path: src/repro/core/fixture_rl001.py
"""RL001 pass: pinned helpers, numpy-host extrema, stable argsort."""
import jax.numpy as jnp
import numpy as np

from repro.core.pinned import pinned_argmax, pinned_argmin


def erm(errs, gains):
    j = pinned_argmin(errs)           # pinned: ties break to lowest index
    g = pinned_argmax(gains)
    order = jnp.argsort(errs, stable=True)
    return j, g, order


def host_side(a):
    return np.argmin(a), np.argmax(a)  # numpy pins first occurrence
