# lint-fixture-path: src/repro/core/fixture_rl003.py
"""RL003 fail: dtype-less jnp constructors, bare astype, jnp f64."""
import jax.numpy as jnp


def build(m, x):
    idx = jnp.arange(m)                 # RL003: dtype-less (f64 under x64)
    buf = jnp.zeros((m,))               # RL003: dtype-less
    bad = x.astype(float)               # RL003: host-dependent width
    wide = jnp.asarray(x, jnp.float64)  # RL003: f64 literal
    return idx, buf, bad, wide
