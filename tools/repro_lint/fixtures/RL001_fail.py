# lint-fixture-path: src/repro/core/fixture_rl001.py
"""RL001 fail: bare jnp extrema + top_k + unstable argsort."""
import jax
import jax.numpy as jnp


def erm(errs, gains, ranks):
    j = jnp.argmin(errs)                       # RL001: bare argmin
    g = jnp.argmax(gains)                      # RL001: bare argmax
    _, top = jax.lax.top_k(ranks, 2)           # RL001: bare top_k
    order = jnp.argsort(errs, stable=False)    # RL001: unstable argsort
    return j, g, top, order
