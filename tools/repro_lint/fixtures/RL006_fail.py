# lint-fixture-path: src/repro/core/fixture_rl006.py
"""RL006 fail: span/metric emission inside the traced closure."""
import functools

import jax
import jax.numpy as jnp

from repro.obs import trace as obs_trace
from repro.obs.metrics import default_registry


def _round(carry):
    s, i = carry
    with obs_trace.span("round", "engine"):      # RL006: while_loop body
        s = s + jnp.float32(1.0)
    default_registry().counter("rounds").inc()   # RL006: metrics in trace
    return s, i + 1


@functools.partial(jax.jit, static_argnames=())
def _run(s):
    obs_trace.instant("step", "engine")          # RL006: jitted function
    out, _ = jax.lax.while_loop(lambda c: c[1] < 4, _round,
                                (s, jnp.int32(0)))
    return out
