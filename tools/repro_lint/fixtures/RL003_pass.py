# lint-fixture-path: src/repro/core/fixture_rl003.py
"""RL003 pass: every jnp constructor names its dtype; astype is
explicit; host numpy keeps its own (allowed) defaults."""
import jax.numpy as jnp
import numpy as np


def build(m):
    idx = jnp.arange(m, dtype=jnp.int32)
    buf = jnp.zeros((m,), jnp.float32)
    pad = jnp.full((m,), -1, dtype=jnp.int32)
    out = buf.astype(jnp.float32)
    host = np.arange(m)                 # host-side numpy: out of scope
    return idx, buf, pad, out, host
