"""RL004 fail fixture: entry point without an interpret flag."""


def demo(x):
    return x
