"""RL004 fail fixture: kernel with no ref.py and no interpret routing."""


def demo_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]
