"""Layer 2: trace both boosting engines and audit the jaxprs.

Three checks over ``init_state`` / ``run_rounds`` traces of the batched
and sharded engines (stumps, a 1-D protocol class, and histogram trees
in each ``comm_mode``, at one canonical small config):

* **primitive denylist** — no nondeterministic or host-callback
  primitives (``argmin``/``argmax`` tie order is backend-defined;
  callbacks smuggle host state into traced programs);
* **dtype census** — no float64/complex anywhere in any trace (the
  STATE_DTYPES contract is f32/int32/int8/bool/uint32);
* **collective census** — the sharded step trace contains EXACTLY the
  ``all_gather``/``psum`` eqn counts that
  :func:`repro.core.ledger.collective_sites_per_round` declares (and
  nothing else from the collective family); the batched trace contains
  none.  A new collective cannot ship without ledger accounting.

Tracing is abstract (``jax.eval_shape`` state + ``jax.make_jaxpr``):
no kernels execute, so the audit runs in seconds on CPU CI.
"""

from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched, ledger, sharded_batched
from repro.core.types import BoostConfig
from repro.core.weak import AxisStumps, Thresholds
from repro.weak_tree.trees import HistogramTrees

# Nondeterministic / host-coupled primitives that must never appear in
# an engine trace.  NOTE ``top_k`` is absent on purpose: the voting
# election uses it on all-distinct ranks (RL001 allowlist) — the AST
# layer polices call sites, the jaxpr layer polices what cannot be
# argued safe at any site.
DENY_PRIMITIVES = frozenset({
    "argmin", "argmax",
    "rng_bit_generator",
    "pure_callback", "io_callback", "outside_call", "debug_callback",
    "infeed", "outfeed",
})

BAD_DTYPES = frozenset({"float64", "complex64", "complex128"})

COLLECTIVE_FAMILY = frozenset({
    "all_gather", "psum", "pmean", "pmax", "pmin", "ppermute",
    "all_to_all", "psum_scatter", "reduce_scatter",
})

CANON = dict(B=1, k=2, mloc=8, F=3)


def canonical_config() -> BoostConfig:
    return BoostConfig(k=CANON["k"], coreset_size=4, domain_size=64,
                       opt_budget=2)


def engine_cases():
    """(name, cls, no_center) — the class/mode grid the audit traces."""
    F = CANON["F"]
    return [
        ("thresholds", Thresholds(n=64), False),
        ("stumps", AxisStumps(num_features=F), False),
        ("stumps-nocenter", AxisStumps(num_features=F), True),
        ("tree-coreset",
         HistogramTrees(num_features=F, depth=2, bins=8,
                        comm_mode="coreset"), False),
        ("tree-histogram",
         HistogramTrees(num_features=F, depth=2, bins=8,
                        comm_mode="histogram"), False),
        ("tree-voting",
         HistogramTrees(num_features=F, depth=2, bins=8,
                        comm_mode="voting"), False),
    ]


def _inputs(cls, cfg: BoostConfig):
    """Canonical [B, k, mloc(, F)] inputs — values never execute (the
    traces are abstract), only shapes/dtypes matter."""
    B, k, mloc, F = (CANON["B"], CANON["k"], CANON["mloc"], CANON["F"])
    if getattr(cls, "needs_features", False):
        x = np.zeros((B, k, mloc, F), np.float32)
    else:
        x = np.zeros((B, k, mloc), np.int32)
    y = np.ones((B, k, mloc), np.int8)
    keys = jax.random.split(jax.random.key(0), B)
    return x, y, keys


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _sub_jaxprs(params: dict):
    """Yield every (Closed)Jaxpr reachable from an eqn's params —
    pjit/while/cond/scan/shard_map all stash sub-jaxprs differently, so
    duck-type instead of enumerating param names."""
    stack = list(params.values())
    while stack:
        v = stack.pop()
        if isinstance(v, (tuple, list)):
            stack.extend(v)
        elif hasattr(v, "jaxpr") and hasattr(v, "consts"):  # ClosedJaxpr
            yield v.jaxpr
        elif hasattr(v, "eqns") and hasattr(v, "invars"):   # open Jaxpr
            yield v


def iter_eqns(jaxpr):
    """Depth-first over every eqn, including nested sub-jaxprs."""
    if hasattr(jaxpr, "jaxpr"):          # ClosedJaxpr → Jaxpr
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def primitive_census(jaxpr) -> collections.Counter:
    return collections.Counter(e.primitive.name for e in iter_eqns(jaxpr))


def dtype_census(jaxpr) -> collections.Counter:
    out = collections.Counter()
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            dt = getattr(var.aval, "dtype", None)
            if dt is not None:
                out[str(dt)] += 1
    return out


# ---------------------------------------------------------------------------
# engine tracing
# ---------------------------------------------------------------------------

def trace_engine(cls, cfg: BoostConfig, engine: str,
                 no_center: bool = False):
    """(init_jaxpr, step_jaxpr) for one engine/class/mode."""
    x, y, keys = _inputs(cls, cfg)
    # cfg.num_rounds does host-side int() math — resolve it before
    # tracing (init_state would otherwise hit a ConcretizationTypeError
    # under the abstract trace)
    t_buf = cfg.num_rounds(CANON["k"] * CANON["mloc"])
    if engine == "batched":
        def init_fn(xx, yy, kk):
            return batched.init_state(xx, yy, kk, cfg, t_buf=t_buf,
                                      cls=cls)

        def step_fn(st, xx, yy):
            return batched.run_rounds(st, xx, yy, cfg, cls, n=1)
    elif engine == "sharded":
        mesh = sharded_batched.make_players_mesh(cfg.k)

        def init_fn(xx, yy, kk):
            return sharded_batched.init_state_sharded(
                xx, yy, kk, cfg, t_buf=t_buf, cls=cls)

        def step_fn(st, xx, yy):
            return sharded_batched.run_rounds_sharded(
                st, xx, yy, cfg, cls, mesh=mesh, n=1,
                no_center=no_center)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    state = jax.eval_shape(init_fn, x, y, keys)
    init_jaxpr = jax.make_jaxpr(init_fn)(x, y, keys)
    step_jaxpr = jax.make_jaxpr(step_fn)(state, x, y)
    return init_jaxpr, step_jaxpr


@dataclasses.dataclass
class EngineReport:
    name: str                     # e.g. "sharded/tree-voting"
    primitives: collections.Counter
    dtypes: collections.Counter
    collectives: dict             # observed counts, collective family only
    expected: dict | None         # ledger census (None for batched)
    failures: list


def audit_case(name: str, cls, no_center: bool, engine: str,
               cfg: BoostConfig | None = None) -> EngineReport:
    cfg = cfg or canonical_config()
    init_jaxpr, step_jaxpr = trace_engine(cls, cfg, engine,
                                          no_center=no_center)
    prims = primitive_census(init_jaxpr) + primitive_census(step_jaxpr)
    dts = dtype_census(init_jaxpr) + dtype_census(step_jaxpr)
    label = f"{engine}/{name}"
    failures: list[str] = []

    for p in sorted(DENY_PRIMITIVES & set(prims)):
        failures.append(f"{label}: denied primitive `{p}` "
                        f"×{prims[p]} in trace")
    for dt in sorted(BAD_DTYPES & set(dts)):
        failures.append(f"{label}: {dt} appears ×{dts[dt]} in trace "
                        f"(STATE_DTYPES contract is 32-bit)")

    observed = {p: n for p, n in prims.items() if p in COLLECTIVE_FAMILY}
    if engine == "batched":
        expected = None
        if observed:
            failures.append(f"{label}: batched engine trace contains "
                            f"collectives {observed} — it must be "
                            f"mesh-free")
    else:
        expected = ledger.collective_sites_per_round(
            cls, no_center=no_center)
        init_coll = {p: n
                     for p, n in primitive_census(init_jaxpr).items()
                     if p in COLLECTIVE_FAMILY}
        if init_coll:
            failures.append(f"{label}: init_state trace contains "
                            f"collectives {init_coll} — init must not "
                            f"touch the wire")
        step_coll = {p: n
                     for p, n in primitive_census(step_jaxpr).items()
                     if p in COLLECTIVE_FAMILY}
        extra = set(step_coll) - set(expected)
        if extra:
            failures.append(
                f"{label}: unaccounted collective family members "
                f"{sorted(extra)} (ledger census only declares "
                f"{sorted(expected)})")
        for p, want in expected.items():
            got = step_coll.get(p, 0)
            if got != want:
                failures.append(
                    f"{label}: `{p}` eqn count {got} != {want} "
                    f"declared by ledger.collective_sites_per_round "
                    f"— a collective site changed without matching "
                    f"ledger accounting")
    return EngineReport(label, prims, dts, observed, expected, failures)


def run_audit(cases=None, engines=("batched", "sharded"),
              cfg: BoostConfig | None = None) -> list[str]:
    """Full audit; returns failure strings (empty == pass)."""
    failures: list[str] = []
    for name, cls, no_center in (cases or engine_cases()):
        for engine in engines:
            if engine == "batched" and no_center:
                continue          # no_center only exists sharded
            failures.extend(
                audit_case(name, cls, no_center, engine, cfg).failures)
    return failures


def finalize_smoke(cfg: BoostConfig | None = None) -> None:
    """Concrete init → finalize round-trip for both engines (stumps):
    finalize is host-side materialisation, so it has no jaxpr to audit
    — this asserts it stays that way (consumes stepped state without
    launching device programs that could hide primitives)."""
    cfg = cfg or canonical_config()
    cls = AxisStumps(num_features=CANON["F"])
    x, y, keys = _inputs(cls, cfg)
    st = batched.init_state(x, y, keys, cfg, cls=cls)
    res = batched.finalize(st, x, y, jnp.ones(y.shape, bool), cfg, cls)
    assert isinstance(res.rounds, np.ndarray)
    st2 = sharded_batched.init_state_sharded(x, y, keys, cfg, cls=cls)
    res2 = sharded_batched.finalize_sharded(
        st2, x, y, jnp.ones(y.shape, bool), cfg, cls)
    assert isinstance(res2.wire_bytes, np.ndarray)
