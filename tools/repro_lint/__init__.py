"""Static analysis for the repro tree: AST rules + jaxpr verification.

Two layers (see docs/static_analysis.md):

* ``tools.repro_lint.rules`` — RL001–RL005 AST rules over ``src/repro``;
* ``tools.repro_lint.jaxpr_audit`` — traces both boosting engines and
  checks the primitive denylist, dtype census, and collective census
  against :func:`repro.core.ledger.collective_sites_per_round`.

CLI: ``python -m tools.repro_lint src/ [--jaxpr]``.
"""

from tools.repro_lint.engine import (Violation, lint_paths, lint_source,
                                     load_baseline)
from tools.repro_lint.rules import ALL_RULES, RULE_IDS

__all__ = ["Violation", "lint_paths", "lint_source", "load_baseline",
           "ALL_RULES", "RULE_IDS"]
