"""CLI: ``python -m tools.repro_lint [paths…] [--jaxpr]``.

Exit codes: 0 clean, 1 violations/audit failures, 2 usage error.
Writes a summary table to ``$GITHUB_STEP_SUMMARY`` when set (the CI
lint job surfaces per-rule counts without scrolling logs).
"""

from __future__ import annotations

import argparse
import collections
import os
import sys


def _ensure_src_importable() -> None:
    """The jaxpr audit imports ``repro``; running from the repo root
    without PYTHONPATH=src is the common case, so fall back to the
    in-tree layout (append, never mutate precedence of existing entries).
    """
    try:
        import repro  # noqa: F401
        return
    except ImportError:
        pass
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    src = os.path.join(here, "src")
    if os.path.isdir(src) and src not in sys.path:
        sys.path.append(src)


def _step_summary(lines: list[str]) -> None:
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    with open(path, "a", encoding="utf-8") as f:
        f.write("\n".join(lines) + "\n")


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description="repro-lint: AST rules + jaxpr verification")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/dirs to lint (default: src/)")
    parser.add_argument("--jaxpr", action="store_true",
                        help="also run the jaxpr audit (traces both "
                             "engines; needs repro importable)")
    parser.add_argument("--baseline", default=None,
                        help="suppressions file (default: "
                             "tools/repro_lint/baseline_suppressions.txt)")
    args = parser.parse_args(argv)

    repo_root = os.getcwd()
    paths = args.paths or ["src"]
    for p in paths:
        if not os.path.exists(p):
            print(f"repro-lint: no such path: {p}", file=sys.stderr)
            return 2

    from tools.repro_lint.engine import lint_paths, load_baseline
    from tools.repro_lint.rules import ALL_RULES

    baseline_path = args.baseline or os.path.join(
        os.path.dirname(os.path.abspath(__file__)),
        "baseline_suppressions.txt")
    baseline = load_baseline(baseline_path)

    violations, suppressed = lint_paths(paths, ALL_RULES,
                                        repo_root=repo_root,
                                        baseline=baseline)
    per_rule = collections.Counter(v.rule for v in violations)
    for v in violations:
        print(v)

    audit_failures: list[str] = []
    if args.jaxpr:
        _ensure_src_importable()
        from tools.repro_lint.jaxpr_audit import run_audit
        audit_failures = run_audit()
        for msg in audit_failures:
            print(f"jaxpr-audit: {msg}")

    summary = ["### repro-lint", "",
               "| check | findings |", "| --- | ---: |"]
    from tools.repro_lint.rules import RULE_IDS
    for rid in RULE_IDS:
        summary.append(f"| {rid} | {per_rule.get(rid, 0)} |")
    if args.jaxpr:
        summary.append(f"| jaxpr audit | {len(audit_failures)} |")
    if suppressed:
        summary.append(f"| baseline-suppressed | {len(suppressed)} |")
    _step_summary(summary)

    n = len(violations) + len(audit_failures)
    tail = f", {len(suppressed)} baseline-suppressed" if suppressed else ""
    print(f"repro-lint: {len(violations)} violation(s)"
          + (f", {len(audit_failures)} jaxpr audit failure(s)"
                 if args.jaxpr else "")
          + tail)
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
