"""RL001–RL006: the repo's determinism / dtype / accounting invariants.

Each rule's ``rationale`` is the short form of the catalog entry in
``docs/static_analysis.md``; each has a pass/fail fixture pair under
``tools/repro_lint/fixtures/`` exercised by ``tests/test_lint.py``.
"""

from __future__ import annotations

import ast
import os

from tools.repro_lint.engine import SourceRule, TreeRule, Violation

# Modules whose code lands inside jaxprs (jit/shard_map bodies live
# here).  launch/ (host-side serving loops, wall-clock timers), ckpt/
# (host I/O) and configs/ are deliberately out of scope for the
# dtype/host-purity rules.
JITTED_DIRS = ("core", "kernels", "weak_tree", "models", "optim", "data")


def in_jitted_module(relpath: str) -> bool:
    p = relpath.replace(os.sep, "/")
    return any(f"repro/{d}/" in p for d in JITTED_DIRS)


def _dotted(node: ast.AST) -> str | None:
    """'jnp', 'jax.lax', 'np.random' … for Name/Attribute chains."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _QualnameVisitor(ast.NodeVisitor):
    """Tracks the enclosing function/class qualname while walking."""

    def __init__(self):
        self.stack: list[str] = []

    @property
    def qualname(self) -> str:
        return ".".join(self.stack)

    def visit_FunctionDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()


# ---------------------------------------------------------------------------
# RL001 — no bare extrema / top_k tie-breaking
# ---------------------------------------------------------------------------

# (path suffix, qualname substring, callee attr, reason)
ALLOWLIST: list[tuple[str, str, str, str]] = [
    ("weak_tree/trees.py", "erm_players", "top_k",
     "operates on ranks votes*F + (F-1-f): all values distinct by "
     "construction, so top_k tie order cannot matter"),
]

_EXTREMA = {"argmin", "argmax", "top_k"}


class NoBareExtrema(SourceRule):
    rule_id = "RL001"
    title = "no bare argmin/argmax/top_k outside pinned sites"
    rationale = (
        "XLA makes no cross-backend promise about which index argmin/"
        "argmax/top_k return on ties; the repo's bit-parity law requires "
        "the lowest index.  Use repro.core.pinned (min/where/iota) or an "
        "ALLOWLIST entry arguing the operands are tie-free."
    )

    def check(self, tree, src, relpath):
        out: list[Violation] = []
        rule = self

        class V(_QualnameVisitor):
            def visit_Call(self, node):
                name = None
                recv = None
                if isinstance(node.func, ast.Attribute):
                    name = node.func.attr
                    recv = _dotted(node.func.value)
                elif isinstance(node.func, ast.Name):
                    name = node.func.id
                if name in _EXTREMA and recv not in ("np", "numpy", "math"):
                    if not self._allowed(name):
                        out.append(rule.violation(
                            relpath, node,
                            f"bare `{name}` (tie order is backend-defined); "
                            f"use repro.core.pinned or add an ALLOWLIST "
                            f"entry [in {self.qualname or '<module>'}]"))
                if (name == "argsort"
                        and any(kw.arg == "stable"
                                and isinstance(kw.value, ast.Constant)
                                and kw.value.value is False
                                for kw in node.keywords)):
                    out.append(rule.violation(
                        relpath, node, "argsort(stable=False) is "
                        "nondeterministic on ties"))
                self.generic_visit(node)

            def _allowed(self, name):
                q = self.qualname
                return any(relpath.endswith(sfx) and part in q and name == cn
                           for sfx, part, cn, _ in ALLOWLIST)

        V().visit(tree)
        return out


# ---------------------------------------------------------------------------
# RL002 — collectives paired with wire accounting (sharded engine)
# ---------------------------------------------------------------------------

_COLLECTIVES = {"all_gather", "psum", "pmean", "pmax", "pmin",
                "ppermute", "all_to_all", "psum_scatter"}
_WIRE_NAME = __import__("re").compile(
    r"^(n_(examples|scalars|bytes|hist|votes)"
    r"|a?wire_[a-z0-9_]+|hist_wire_[a-z0-9_]+)$")


def _wire_bindings(node: ast.AST) -> set[str]:
    """Names bound in wire-counter positions anywhere under ``node``:
    assignment targets, call keywords, dict-literal string keys."""
    found: set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = n.targets if isinstance(n, ast.Assign) else [n.target]
            for t in targets:
                if isinstance(t, ast.Name) and _WIRE_NAME.match(t.id):
                    found.add(t.id)
        elif isinstance(n, ast.keyword) and n.arg and _WIRE_NAME.match(n.arg):
            found.add(n.arg)
        elif isinstance(n, ast.Dict):
            for k in n.keys:
                if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                        and _WIRE_NAME.match(k.value)):
                    found.add(k.value)
    return found


def _references_name(node: ast.AST, name: str) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id == name:
            return True
        if isinstance(n, ast.Attribute) and n.attr == name:
            return True
        if (isinstance(n, ast.Constant) and n.value == name):
            return True
    return False


def _accumulates(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Add):
            return True
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
                and n.func.attr in ("set", "add")):
            return True
    return False


class LedgerPairing(SourceRule):
    rule_id = "RL002"
    title = "every collective in the sharded engine pairs with wire counters"
    rationale = (
        "core/sharded_batched.py is the engine whose traffic "
        "validate_ledger audits; a collective without a measured "
        "wire-counter update in the same function ships unaccounted "
        "bits.  Additionally every wire field the module's own schema "
        "declares (_RoundCarry wire_* fields, STATE_DTYPES wire keys) "
        "must have a maintaining accumulation somewhere in the module — "
        "deleting a counter update is a lint failure, not silent drift."
    )

    def applies_to(self, relpath):
        return relpath.replace(os.sep, "/").endswith(
            "core/sharded_batched.py")

    def check(self, tree, src, relpath):
        out: list[Violation] = []

        # -- pass 1: per-function collective/counter pairing ---------------
        for node in tree.body:
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            colls = [
                n for n in ast.walk(node)
                if isinstance(n, ast.Call)
                and isinstance(n.func, ast.Attribute)
                and n.func.attr in _COLLECTIVES
            ]
            if colls and not _wire_bindings(node):
                out.append(self.violation(
                    relpath, colls[0],
                    f"`{node.name}` calls "
                    f"{sorted({c.func.attr for c in colls})} but binds no "
                    f"wire counter (n_*/wire_*/awire_*/hist_wire_*)"))

        # -- pass 2: schema census vs maintaining accumulations ------------
        schema: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                for stmt in node.body:
                    if (isinstance(stmt, ast.AnnAssign)
                            and isinstance(stmt.target, ast.Name)
                            and _WIRE_NAME.match(stmt.target.id)):
                        schema.add(stmt.target.id)
            elif isinstance(node, ast.Assign):
                if any(isinstance(t, ast.Name) and t.id == "STATE_DTYPES"
                       for t in node.targets):
                    v = node.value
                    if isinstance(v, ast.Call):
                        for kw in v.keywords:
                            if kw.arg and _WIRE_NAME.match(kw.arg):
                                schema.add(kw.arg)
                    elif isinstance(v, ast.Dict):
                        for k in v.keys:
                            if (isinstance(k, ast.Constant)
                                    and isinstance(k.value, str)
                                    and _WIRE_NAME.match(k.value)):
                                schema.add(k.value)
        if not schema:
            out.append(Violation(
                self.rule_id, relpath, 1,
                "wire-schema introspection found no wire_* fields in "
                "_RoundCarry / STATE_DTYPES — the rule cannot audit this "
                "module (did the schema move?)"))
            return out

        maintained: set[str] = set()
        for n in ast.walk(tree):
            pairs: list[tuple[str, ast.AST]] = []
            if isinstance(n, (ast.Assign, ast.AugAssign)):
                targets = (n.targets if isinstance(n, ast.Assign)
                           else [n.target])
                for t in targets:
                    if isinstance(t, ast.Name):
                        pairs.append((t.id, n.value))
            elif isinstance(n, ast.keyword) and n.arg:
                pairs.append((n.arg, n.value))
            elif isinstance(n, ast.Dict):
                for k, v in zip(n.keys, n.values):
                    if (isinstance(k, ast.Constant)
                            and isinstance(k.value, str)):
                        pairs.append((k.value, v))
            for name, value in pairs:
                if (name in schema and _references_name(value, name)
                        and _accumulates(value)):
                    maintained.add(name)

        for name in sorted(schema - maintained):
            out.append(Violation(
                self.rule_id, relpath, 1,
                f"wire field `{name}` is declared in the module schema "
                f"but has no maintaining accumulation (an assignment/"
                f"keyword/dict entry that reads `{name}` and adds to it) "
                f"— its counter update was deleted or never written"))
        return out


# ---------------------------------------------------------------------------
# RL003 — dtype discipline in jitted modules
# ---------------------------------------------------------------------------

_NEEDS_DTYPE = {
    "zeros": 2, "ones": 2, "empty": 2,   # ok with >=2 positional args
    "full": 3,
    "arange": None, "linspace": None, "eye": None,  # kwarg only
}
_BAD_DTYPE_NAMES = {"float64", "complex64", "complex128", "double"}


class DtypeDiscipline(SourceRule):
    rule_id = "RL003"
    title = "no f64 literals, bare astype, or dtype-less jnp constructors"
    rationale = (
        "STATE_DTYPES is the checkpoint/parity contract; a dtype-less "
        "jnp constructor silently flips to float64 under x64, and "
        ".astype(float) means different widths on different hosts.  "
        "Every jnp array in a jitted module is constructed with an "
        "explicit dtype."
    )

    def applies_to(self, relpath):
        return in_jitted_module(relpath)

    def check(self, tree, src, relpath):
        out: list[Violation] = []
        for node in ast.walk(tree):
            # host-side numpy is allowed f64 (canonicalized at the jnp
            # boundary); only jnp-space f64 reaches traces
            if (isinstance(node, ast.Attribute)
                    and node.attr in _BAD_DTYPE_NAMES
                    and _dotted(node.value) in ("jnp", "jax.numpy")):
                out.append(self.violation(
                    relpath, node, f"float64/complex dtype "
                    f"`jnp.{node.attr}` in a jitted module"))
            elif (isinstance(node, ast.Constant)
                    and isinstance(node.value, str)
                    and node.value in _BAD_DTYPE_NAMES):
                out.append(self.violation(
                    relpath, node,
                    f"float64/complex dtype string '{node.value}'"))
            elif isinstance(node, ast.Call):
                out.extend(self._check_call(node, relpath))
        return out

    def _check_call(self, node: ast.Call, relpath):
        out = []
        if isinstance(node.func, ast.Attribute):
            name, recv = node.func.attr, _dotted(node.func.value)
            if (name == "astype" and node.args
                    and isinstance(node.args[0], ast.Name)
                    and node.args[0].id in ("float", "int", "complex")):
                out.append(self.violation(
                    relpath, node,
                    f"bare .astype({node.args[0].id}) — width is "
                    f"host-dependent; name the jnp dtype"))
            if recv in ("jnp", "jax.numpy") and name in _NEEDS_DTYPE:
                has_kw = any(kw.arg == "dtype" for kw in node.keywords)
                min_pos = _NEEDS_DTYPE[name]
                has_pos = (min_pos is not None
                           and len(node.args) >= min_pos)
                if not (has_kw or has_pos):
                    out.append(self.violation(
                        relpath, node,
                        f"jnp.{name}(...) without explicit dtype "
                        f"(flips to f64 under x64)"))
        return out


# ---------------------------------------------------------------------------
# RL004 — kernel directories are complete kernel/ops/ref triples
# ---------------------------------------------------------------------------

class KernelTriple(TreeRule):
    rule_id = "RL004"
    title = "every kernels/<name>/ is a kernel/ops/ref triple with interpret routing"
    rationale = (
        "The kernel contract (docs/static_analysis.md): ref.py is the pure-jnp "
        "ground truth, kernel.py the pallas body, ops.py the public "
        "entry routing an `interpret=` flag so CPU CI exercises the "
        "kernel path.  A missing leg means an untestable kernel."
    )

    REQUIRED = ("kernel.py", "ops.py", "ref.py")

    def check_tree(self, root):
        out: list[Violation] = []
        for dirpath, dirnames, filenames in os.walk(root):
            if os.path.basename(dirpath) != "kernels":
                continue
            for sub in sorted(dirnames):
                if sub == "__pycache__":
                    continue
                kdir = os.path.join(dirpath, sub)
                rel = os.path.relpath(kdir).replace(os.sep, "/")
                missing = [f for f in self.REQUIRED
                           if not os.path.exists(os.path.join(kdir, f))]
                if missing:
                    out.append(Violation(
                        self.rule_id, rel, 0,
                        f"kernel dir missing {missing} — must be a "
                        f"complete kernel/ops/ref triple"))
                    continue
                ops = os.path.join(kdir, "ops.py")
                if not self._routes_interpret(ops):
                    out.append(Violation(
                        self.rule_id, rel + "/ops.py", 0,
                        "no public function takes an `interpret=` "
                        "flag — CPU CI cannot exercise the kernel path"))
        return out

    @staticmethod
    def _routes_interpret(ops_path: str) -> bool:
        with open(ops_path, encoding="utf-8") as f:
            try:
                tree = ast.parse(f.read(), filename=ops_path)
            except SyntaxError:
                return False
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                names = [a.arg for a in
                         args.args + args.kwonlyargs + args.posonlyargs]
                if "interpret" in names:
                    return True
        return False


# ---------------------------------------------------------------------------
# RL005 — host purity in jitted modules
# ---------------------------------------------------------------------------

_LEGACY_NP_RANDOM = {
    "seed", "rand", "randn", "randint", "random", "choice", "shuffle",
    "permutation", "uniform", "normal", "binomial", "poisson", "get_state",
    "set_state", "random_sample", "standard_normal",
}


class HostPurity(SourceRule):
    rule_id = "RL005"
    title = "no sys.path mutation; no time/random in jitted modules"
    rationale = (
        "sys.path mutation makes import resolution order-dependent "
        "(banned repo-wide); `time`/`random` and legacy global-state "
        "`np.random.*` calls in modules that define jitted code bake "
        "host state into traced constants.  Seeded np.random.default_rng "
        "/ Generator / SeedSequence remain allowed."
    )

    def check(self, tree, src, relpath):
        out: list[Violation] = []
        jitted = in_jitted_module(relpath)
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute) and node.attr == "path" \
                    and _dotted(node.value) == "sys":
                out.append(self.violation(
                    relpath, node, "sys.path mutation/access — import "
                    "resolution must not depend on call order"))
            elif jitted and isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.split(".")[0] in ("time", "random"):
                        out.append(self.violation(
                            relpath, node,
                            f"import {alias.name} in a jitted module — "
                            f"host clock/RNG state must not reach traces"))
            elif jitted and isinstance(node, ast.ImportFrom):
                if node.module and node.module.split(".")[0] in (
                        "time", "random"):
                    out.append(self.violation(
                        relpath, node,
                        f"from {node.module} import … in a jitted module"))
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                recv = _dotted(node.func.value)
                if (recv in ("np.random", "numpy.random")
                        and node.func.attr in _LEGACY_NP_RANDOM):
                    out.append(self.violation(
                        relpath, node,
                        f"legacy global-state np.random.{node.func.attr} "
                        f"— use np.random.default_rng(seed)"))
        return out


# ---------------------------------------------------------------------------
# RL006 — observability is host-side only
# ---------------------------------------------------------------------------

# Last dotted component of callables that put a function argument inside
# a trace: passing `f` by name to any of these makes `f`'s body traced.
_TRANSFORMS = {
    "jit", "vmap", "pmap", "shard_map", "_shard_map",
    "while_loop", "scan", "fori_loop", "cond", "switch",
    "checkpoint", "remat",
}

# Decorators that jit the function they sit on (directly or via
# functools.partial(jax.jit, ...)).
_JIT_DECORATORS = {"jit", "pmap", "checkpoint", "remat"}


def _last(dotted: str | None) -> str:
    return (dotted or "").rsplit(".", 1)[-1]


def _is_jit_decorator(d: ast.AST) -> bool:
    if _last(_dotted(d)) in _JIT_DECORATORS:
        return True
    if isinstance(d, ast.Call):
        fl = _last(_dotted(d.func))
        if fl in _JIT_DECORATORS:
            return True
        if fl == "partial" and d.args:
            return _last(_dotted(d.args[0])) in _JIT_DECORATORS
    return False


def _obs_imports(tree: ast.AST) -> tuple[set[str], set[str]]:
    """(module aliases bound to repro.obs[.x], names imported FROM it)."""
    aliases: set[str] = set()
    direct: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro.obs" or a.name.startswith("repro.obs."):
                    if a.asname:
                        aliases.add(a.asname)
                    # plain `import repro.obs.trace` binds `repro`; call
                    # sites then spell the full repro.obs.* chain, which
                    # _obs_call matches by prefix.
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "repro":
                for a in node.names:
                    if a.name == "obs":
                        aliases.add(a.asname or "obs")
            elif mod == "repro.obs":
                for a in node.names:
                    aliases.add(a.asname or a.name)
            elif mod.startswith("repro.obs."):
                for a in node.names:
                    direct.add(a.asname or a.name)
    return aliases, direct


def _obs_call(node: ast.Call, aliases: set[str],
              direct: set[str]) -> str | None:
    d = _dotted(node.func)
    if d:
        if d.startswith("repro.obs."):
            return d
        if "." in d and d.split(".", 1)[0] in aliases:
            return d
    if isinstance(node.func, ast.Name) and node.func.id in direct:
        return node.func.id
    return None


class HostSideObservability(SourceRule):
    rule_id = "RL006"
    title = "no span/metric emission inside jitted code"
    rationale = (
        "obs spans/metrics are host-side Python side effects; inside a "
        "traced function they fire once at trace time (then never "
        "again from the compiled program) and their timestamps bound "
        "tracing, not execution — silently wrong numbers.  The rule "
        "takes the traced closure (jit-decorated functions, functions "
        "passed by name to jit/vmap/shard_map/while_loop/scan/…, plus "
        "everything they reference module-locally) and bans repro.obs "
        "calls inside it.  `jax.named_scope` is the device-visible "
        "label that IS allowed in traced code; spans wrap the dispatch "
        "from the host side (see run_rounds)."
    )

    def applies_to(self, relpath):
        return in_jitted_module(relpath)

    def check(self, tree, src, relpath):
        aliases, direct = _obs_imports(tree)
        if not aliases and not direct:
            return []

        funcs: dict[str, list[ast.AST]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                funcs.setdefault(node.name, []).append(node)

        roots: set[str] = set()
        for name, defs in funcs.items():
            if any(_is_jit_decorator(d) for fn in defs
                   for d in fn.decorator_list):
                roots.add(name)
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and _last(_dotted(node.func)) in _TRANSFORMS):
                for a in node.args:
                    if isinstance(a, ast.Name) and a.id in funcs:
                        roots.add(a.id)

        # conservative transitive closure: any module-local function
        # NAME referenced inside a traced function joins the closure
        # (covers functools.partial(_round_body, …) handed to while_loop)
        closure: set[str] = set()
        todo = sorted(roots)
        while todo:
            name = todo.pop()
            if name in closure:
                continue
            closure.add(name)
            for fn in funcs[name]:
                for n in ast.walk(fn):
                    if (isinstance(n, ast.Name) and n.id in funcs
                            and n.id not in closure):
                        todo.append(n.id)

        out: list[Violation] = []
        seen: set[int] = set()
        for name in sorted(closure):
            for fn in funcs[name]:
                for n in ast.walk(fn):
                    if not isinstance(n, ast.Call):
                        continue
                    label = _obs_call(n, aliases, direct)
                    if label and n.lineno not in seen:
                        seen.add(n.lineno)
                        out.append(self.violation(
                            relpath, n,
                            f"obs call `{label}` inside the traced "
                            f"closure (via `{name}`) — spans/metrics "
                            f"are host-side only; use jax.named_scope "
                            f"for device-visible labels"))
        return out


ALL_RULES = [NoBareExtrema(), LedgerPairing(), DtypeDiscipline(),
             KernelTriple(), HostPurity(), HostSideObservability()]

RULE_IDS = sorted(r.rule_id for r in ALL_RULES)
