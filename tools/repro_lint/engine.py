"""repro-lint rule engine: AST rules over ``src/repro``.

The engine is deliberately small: a rule is a class with an ``rule_id``,
a one-line ``title``, a ``rationale`` docstring, an ``applies_to(relpath)``
path scope, and a ``check(tree, src, relpath)`` returning
:class:`Violation` rows.  Directory-shape rules (RL004) implement
``check_tree(root)`` instead.  The CLI (``python -m tools.repro_lint``)
and the tests both go through :func:`lint_paths` so fixtures exercise the
exact production path.

Suppression channels, in increasing order of friction:

* inline pragma ``# repro-lint: allow=RL00X <reason>`` on the flagged
  line — for pinned sites whose determinism is argued locally;
* ``ALLOWLIST`` entries in :mod:`tools.repro_lint.rules` — path +
  enclosing qualname + reason, reviewed like code;
* ``baseline_suppressions.txt`` — ``path:RULE`` rows for pre-existing
  debt.  The repo's policy (docs/static_analysis.md) is that this file
  stays EMPTY: new rules land together with the fixes they require.

Fixtures declare a virtual path via a first-lines pragma
``# lint-fixture-path: src/repro/...`` so path-scoped rules fire on
files that physically live under ``tools/repro_lint/fixtures/``.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re

PRAGMA_ALLOW_RE = re.compile(r"#\s*repro-lint:\s*allow=([A-Z]{2}\d{3})\b")
FIXTURE_PATH_RE = re.compile(r"#\s*lint-fixture-path:\s*(\S+)")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    message: str

    def key(self) -> str:
        """Baseline-suppression key — line-insensitive so the baseline
        does not churn on unrelated edits."""
        return f"{self.path}:{self.rule}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class SourceRule:
    """Base class for per-file AST rules."""

    rule_id: str = "RL000"
    title: str = ""
    rationale: str = ""

    def applies_to(self, relpath: str) -> bool:
        return True

    def check(self, tree: ast.AST, src: str, relpath: str) -> list[Violation]:
        raise NotImplementedError

    def violation(self, relpath: str, node: ast.AST, message: str) -> Violation:
        return Violation(self.rule_id, relpath,
                         getattr(node, "lineno", 0), message)


class TreeRule:
    """Base class for directory-shape rules (run once per scanned root)."""

    rule_id: str = "RL000"
    title: str = ""
    rationale: str = ""

    def check_tree(self, root: str) -> list[Violation]:
        raise NotImplementedError


def virtual_path(src: str, default: str) -> str:
    """Honour the ``# lint-fixture-path:`` pragma (first 5 lines)."""
    for line in src.splitlines()[:5]:
        m = FIXTURE_PATH_RE.search(line)
        if m:
            return m.group(1)
    return default


def _pragma_allowed(src_lines: list[str], v: Violation) -> bool:
    if 1 <= v.line <= len(src_lines):
        m = PRAGMA_ALLOW_RE.search(src_lines[v.line - 1])
        if m and m.group(1) == v.rule:
            return True
    return False


def lint_source(src: str, relpath: str,
                rules: list[SourceRule]) -> list[Violation]:
    """Lint one file's source text under its (possibly virtual) path."""
    relpath = virtual_path(src, relpath)
    try:
        tree = ast.parse(src, filename=relpath)
    except SyntaxError as e:  # a file the linter cannot read is a finding
        return [Violation("RL000", relpath, e.lineno or 0,
                          f"syntax error: {e.msg}")]
    out: list[Violation] = []
    lines = src.splitlines()
    for rule in rules:
        if not isinstance(rule, SourceRule):
            continue              # TreeRules need a directory, not a file
        if not rule.applies_to(relpath):
            continue
        for v in rule.check(tree, src, relpath):
            if not _pragma_allowed(lines, v):
                out.append(v)
    return out


def iter_py_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if d not in {"__pycache__", ".git"})
        for name in sorted(filenames):
            if name.endswith(".py"):
                yield os.path.join(dirpath, name)


def load_baseline(path: str) -> set[str]:
    """Read ``path:RULE`` suppression keys; blank lines/comments skipped."""
    keys: set[str] = set()
    if not os.path.exists(path):
        return keys
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                keys.add(line)
    return keys


def lint_paths(paths: list[str], rules: list | None = None,
               repo_root: str | None = None,
               baseline: set[str] | None = None):
    """Lint files/directories.  Returns (violations, suppressed)."""
    if rules is None:
        from tools.repro_lint.rules import ALL_RULES
        rules = ALL_RULES
    source_rules = [r for r in rules if isinstance(r, SourceRule)]
    tree_rules = [r for r in rules if isinstance(r, TreeRule)]
    repo_root = repo_root or os.getcwd()
    baseline = baseline if baseline is not None else set()

    violations: list[Violation] = []
    for path in paths:
        if os.path.isdir(path):
            for fp in iter_py_files(path):
                violations.extend(_lint_file(fp, repo_root, source_rules))
            for rule in tree_rules:
                violations.extend(rule.check_tree(path))
        else:
            violations.extend(_lint_file(path, repo_root, source_rules))

    kept = [v for v in violations if v.key() not in baseline]
    suppressed = [v for v in violations if v.key() in baseline]
    return kept, suppressed


def _lint_file(path: str, repo_root: str,
               rules: list[SourceRule]) -> list[Violation]:
    relpath = os.path.relpath(path, repo_root).replace(os.sep, "/")
    with open(path, encoding="utf-8") as f:
        src = f.read()
    return lint_source(src, relpath, rules)
