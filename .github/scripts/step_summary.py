"""Render CI step summaries (GITHUB_STEP_SUMMARY markdown).

Two modes, both reading artifacts the jobs already produce — the point
is that a regression is visible on the run page without downloading
anything:

    step_summary.py durations <pytest-output-file>
        The "slowest durations" block pytest prints under --durations=N,
        as a markdown table.

    step_summary.py bench <bench-csv-file>
        The name,us_per_call,derived CSV that benchmarks/run.py prints,
        as a markdown table (derived split into its ;-separated fields).

Both modes are best-effort: missing/empty input produces a note, not a
failure (the summary step must never mask the real job status).
"""

from __future__ import annotations

import re
import sys


def durations(path: str) -> str:
    try:
        with open(path, encoding="utf-8", errors="replace") as f:
            text = f.read()
    except OSError as e:
        return f"_no pytest output ({e})_\n"
    rows = re.findall(
        r"^\s*(\d+\.\d+)s\s+(call|setup|teardown)\s+(\S+)\s*$",
        text, re.MULTILINE)
    if not rows:
        return "_no --durations block in pytest output_\n"
    out = ["## Slowest tests", "",
           "| seconds | phase | test |", "|---:|---|---|"]
    for secs, phase, test in rows:
        out.append(f"| {secs} | {phase} | `{test}` |")
    return "\n".join(out) + "\n"


def bench(path: str) -> str:
    try:
        with open(path, encoding="utf-8", errors="replace") as f:
            lines = [ln.strip() for ln in f if ln.strip()]
    except OSError as e:
        return f"_no bench output ({e})_\n"
    rows = []
    for ln in lines:
        m = re.match(r'^([\w-]+),(-?[\d.]+),"?(.*?)"?$', ln)
        if m and m.group(1) != "name":
            rows.append(m.groups())
    if not rows:
        return "_no bench CSV rows_\n"
    out = ["## Benchmark smoke", "",
           "| bench | µs/call | derived |", "|---|---:|---|"]
    for name, us, derived in rows:
        derived = "<br>".join(p for p in derived.split(";") if p)
        flag = " ⚠️" if us == "-1" else ""
        out.append(f"| {name}{flag} | {us} | {derived} |")
    return "\n".join(out) + "\n"


def main(argv) -> int:
    if len(argv) != 3 or argv[1] not in ("durations", "bench"):
        print(__doc__, file=sys.stderr)
        return 2
    fn = durations if argv[1] == "durations" else bench
    sys.stdout.write(fn(argv[2]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
