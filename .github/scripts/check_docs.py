"""Doc-consistency check: docs must not rot against the code.

Two checks, both build-failing (run from CI and from tier-1 via
tests/test_docs.py):

1. **Code references resolve.**  Every backtick span in ``docs/*.md``
   (and README.md) that names a dotted ``repro.*`` / ``benchmarks.*``
   path must resolve: the longest importable module prefix is
   imported, the remainder is walked with getattr.  A renamed module,
   class, function or attribute breaks the doc that references it.
2. **Tier-1 command agreement.**  ROADMAP.md declares the tier-1
   verify command (the line ``**Tier-1 verify:** `...` ``); TESTING.md
   must quote exactly that command — the two files drifting is how a
   "gate every PR must keep green" stops being the gate anyone runs.

Usage: ``python .github/scripts/check_docs.py [repo_root]`` — exits
non-zero listing every failure (never stops at the first).
"""

from __future__ import annotations

import glob
import importlib
import os
import re
import sys

# dotted repro./benchmarks. paths inside backticks; a trailing
# ``(...)`` or markdown punctuation stays outside the capture
REF_RE = re.compile(r"`((?:repro|benchmarks)(?:\.[A-Za-z_][A-Za-z0-9_]*)+)`")
_MISSING = object()


def iter_refs(md_path: str):
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    for m in REF_RE.finditer(text):
        yield m.group(1)


def resolve(ref: str) -> str | None:
    """None if ``ref`` resolves, else a reason string."""
    parts = ref.split(".")
    mod = None
    mod_len = 0
    for i in range(len(parts), 0, -1):
        try:
            mod = importlib.import_module(".".join(parts[:i]))
            mod_len = i
            break
        except ImportError:
            continue
        except Exception as e:  # noqa: BLE001 — import-time crash is a failure too
            return f"importing {'.'.join(parts[:i])} raised {type(e).__name__}: {e}"
    if mod is None:
        return "no importable module prefix"
    obj = mod
    for attr in parts[mod_len:]:
        obj = getattr(obj, attr, _MISSING)
        if obj is _MISSING:
            return (f"{'.'.join(parts[:mod_len])} has no attribute "
                    f"chain {'.'.join(parts[mod_len:])!r}")
    return None


def check_refs(root: str) -> list[str]:
    failures = []
    pages = sorted(glob.glob(os.path.join(root, "docs", "*.md")))
    readme = os.path.join(root, "README.md")
    if os.path.exists(readme):
        pages.append(readme)
    if not pages:
        return ["no docs/*.md found — the docs subsystem is missing"]
    for page in pages:
        for ref in iter_refs(page):
            reason = resolve(ref)
            if reason is not None:
                failures.append(
                    f"{os.path.relpath(page, root)}: `{ref}` does not "
                    f"resolve ({reason})")
    return failures


def check_tier1_command(root: str) -> list[str]:
    roadmap = os.path.join(root, "ROADMAP.md")
    testing = os.path.join(root, "TESTING.md")
    try:
        with open(roadmap, encoding="utf-8") as f:
            m = re.search(r"\*\*Tier-1 verify:\*\*\s*`([^`]+)`", f.read())
    except OSError as e:
        return [f"cannot read ROADMAP.md: {e}"]
    if not m:
        return ["ROADMAP.md no longer declares '**Tier-1 verify:** `...`'"]
    cmd = m.group(1).strip()
    try:
        with open(testing, encoding="utf-8") as f:
            testing_text = f.read()
    except OSError as e:
        return [f"cannot read TESTING.md: {e}"]
    if cmd not in testing_text:
        return [f"TESTING.md does not contain ROADMAP's tier-1 command "
                f"verbatim: {cmd!r}"]
    return []


def main(root: str | None = None) -> int:
    root = root or os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    sys.path.insert(0, os.path.join(root, "src"))
    sys.path.insert(0, root)                      # benchmarks package
    failures = check_refs(root) + check_tier1_command(root)
    for f in failures:
        print(f"DOC DRIFT: {f}", file=sys.stderr)
    if not failures:
        print("docs consistent: all code references resolve, tier-1 "
              "command agrees")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else None))
