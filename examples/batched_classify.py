"""Multi-tenant resilient boosting: B AccuratelyClassify tasks in ONE
device dispatch via the batched engine.

Each "tenant" is an independent noisy learning task; the engine runs
the full protocol (BoostAttempt rounds, stuck checks, full-point
quarantine, dispute accounting) for all of them inside a single jitted
program and proves E_S(f) ≤ OPT per tenant at the end.

    PYTHONPATH=src python examples/batched_classify.py
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import batched, tasks, weak
from repro.core.types import BoostConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--noise", type=int, default=3)
    a = ap.parse_args()

    N = 1 << 12
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=a.k, coreset_size=100, domain_size=N,
                      opt_budget=16)
    x, y, ts = tasks.make_batch(cls, a.batch, a.m, a.k, a.noise)
    keys = jax.random.split(jax.random.key(0), a.batch)

    res = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    print(f"batch={a.batch} ok={int(res.ok.sum())} "
          f"attempts={res.attempts.tolist()}")
    for b in range(a.batch):
        f = res.classifier(b)
        errs = int(weak.empirical_errors(
            f(jnp.asarray(ts[b].flat_x)), jnp.asarray(ts[b].flat_y)))
        opt = tasks.true_opt(ts[b])
        status = "OK " if errs <= opt else "BAD"
        print(f"  tenant {b:2d}: E_S(f)={errs:3d}  OPT={opt:3d}  "
              f"attempts={int(res.attempts[b])}  "
              f"bits={res.ledger(b).total_bits}  [{status}]")


if __name__ == "__main__":
    main()
