"""Batched serving example: prefill + decode across architectures.

Runs reduced variants of a dense, an MoE, and an SSM architecture
through the same prefill/decode code path the production dry-run
lowers, with batched requests.

    PYTHONPATH=src python examples/serve_batch.py
"""

import argparse

from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="+",
                    default=["qwen3-32b", "phi3.5-moe-42b-a6.6b",
                             "xlstm-1.3b"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=12)
    a = ap.parse_args()
    for arch in a.archs:
        args = argparse.Namespace(arch=arch, smoke=True, batch=a.batch,
                                  prompt_len=64, gen=a.gen, seed=0)
        run(args)


if __name__ == "__main__":
    main()
