"""Quickstart: the paper's protocol in 30 lines.

Learn a noisy threshold task distributed across 4 players with
communication counted in bits, and verify the Theorem 4.1 guarantee
E_S(f) ≤ OPT.

    PYTHONPATH=src python examples/quickstart.py

(QUICKSTART_M / QUICKSTART_NOISE env vars shrink the sample — how the
examples smoke test runs this file in seconds; defaults unchanged.)
"""

import os

import jax
import jax.numpy as jnp

from repro.core import classify, ledger, tasks, weak
from repro.core.types import BoostConfig

# A domain of 2^16 points, hypothesis class = thresholds (VC dim 1).
n = 1 << 16
cls = weak.Thresholds(n=n)

# 8192 examples labelled by a hidden threshold, 10 labels flipped
# (OPT ≤ 10), adversarially split among k=4 players by domain region.
m = int(os.environ.get("QUICKSTART_M", "8192"))
noise = int(os.environ.get("QUICKSTART_NOISE", "10"))
task = tasks.make_task(cls, m=m, k=4, noise=noise, seed=0)
opt = tasks.true_opt(task)

cfg = BoostConfig(k=4, coreset_size=400, domain_size=n, opt_budget=32)
f, result = classify.learn(jnp.asarray(task.x), jnp.asarray(task.y),
                           jax.random.key(0), cfg, cls)

errors = int(weak.empirical_errors(f(jnp.asarray(task.flat_x)),
                                   jnp.asarray(task.flat_y)))
naive = ledger.naive_baseline_bits(m, n)

print(f"OPT                  = {opt}")
print(f"E_S(f)               = {errors}   (guarantee: ≤ OPT)")
print(f"BoostAttempt calls   = {result.attempts}")
print(f"communication        = {result.ledger.total_bits:,} bits")
print(f"send-raw-data        = {naive:,} bits")
print(f"quarantined points   = {result.dispute_count}")
assert errors <= opt

# Where to go from here: the same protocol scales along three axes.
#   batch:  python -m repro.launch.serve --workload classify --batch 32
#   class:  add --cls tree --tree-depth 2, and pick how tree growth
#           crosses the wire with --comm-mode {coreset,histogram,voting}
#           (+ --vote-topk N for voting) — see docs/ledger.md for what
#           each mode pays per round
#   data:   BoostConfig(chunk_size=...) streams m >= 10^6 points
#           (docs/streaming.md)
print("next: python -m repro.launch.serve --workload classify "
      "--cls tree --comm-mode voting --vote-topk 1")
