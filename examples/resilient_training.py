"""Resilient neural training: the paper's mechanism as a framework
feature, on a real (reduced) transformer.

Trains a deepseek-family model on a synthetic corpus with planted label
noise, twice — vanilla vs resilient (multiplicative weights + hard-core
quarantine) — and compares clean-split eval loss and noise detection.

Default: ~26M params, 150 steps (CPU-feasible).  --full: ~110M params,
300 steps (the assignment's "~100M for a few hundred steps" scale; run
on real hardware or be patient).

    PYTHONPATH=src python examples/resilient_training.py [--full]
"""

import argparse

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="minimal model + steps (the examples smoke "
                         "test runs this)")
    a = ap.parse_args()
    if a.full:
        d_model, steps, batch, seq = 768, 300, 32, 128   # ≈110M params
    elif a.smoke:
        d_model, steps, batch, seq = 64, 6, 8, 16
    else:
        d_model, steps, batch, seq = 384, 150, 32, 48    # ≈26M params
    steps = a.steps or steps
    results = {}
    for resilient in (False, True):
        print(f"\n=== {'RESILIENT' if resilient else 'VANILLA'} ===")
        args = argparse.Namespace(
            arch="deepseek-7b", smoke=True, steps=steps, batch=batch,
            seq_len=seq, d_model=d_model, vocab=2048,
            num_examples=4096, noise=0.10, resilient=resilient,
            check_every=25, coreset=64, min_gap=3, lr=1e-3, seed=0,
            log_every=max(steps // 6, 1), ckpt_dir=None,
            ckpt_every=10 ** 9)
        results[resilient] = run(args)
    dv = results[False]["clean_eval_loss"]
    dr = results[True]["clean_eval_loss"]
    print(f"\nclean-eval loss: vanilla={dv:.4f}  resilient={dr:.4f}  "
          f"(improvement {dv - dr:+.4f})")
    print(f"noise recall={results[True].get('noise_recall', 0):.2f} "
          f"precision={results[True].get('noise_precision', 0):.2f}")


if __name__ == "__main__":
    main()
