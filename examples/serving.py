"""Continuous-batching boosting service in ~40 lines.

A mixed stream of AccuratelyClassify requests — different sample
sizes, noise levels and adversarial scenarios — arrives as a Poisson
process and is served through the shape-bucketed scheduler: requests
pad up to a small (B, mloc) bucket lattice, every bucket's program is
compiled exactly once, and steady-state traffic runs with zero
recompiles while each request's result stays bit-identical to a
one-shot engine run.

    PYTHONPATH=src python examples/serving.py
"""

import argparse

from repro.launch import scheduler as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=300.0)
    ap.add_argument("--policy", default="pack",
                    choices=["pack", "fill"])
    a = ap.parse_args()

    shapes = [
        {"m": 96, "k": 2, "noise": 1},
        {"m": 128, "k": 2, "noise": 0},
        {"m": 192, "k": 2, "noise": 2, "scenario": "byzantine"},
    ]
    reqs = S.make_request_stream(
        a.requests, S.poisson_trace(a.requests, a.rate), shapes,
        coreset_size=64, opt_budget=8)

    sched = S.BoostScheduler(
        lattice=S.BucketLattice(b_sizes=(4, 8), mloc_sizes=(64, 128)),
        policy=a.policy)
    compiled = sched.warm(reqs)
    print(f"warm: {compiled} bucket programs compiled")

    done = sched.run_stream(reqs)
    st, cs = sched.stats, sched.cache.stats
    print(f"served {len(done)} requests in {st.dispatches} dispatches "
          f"({st.filler_lanes} filler lanes, "
          f"{st.padded_requests} padded requests)")
    print(f"compile cache: {cs.hits} hits, "
          f"{cs.compiles - compiled} steady-state compiles")
    summary = S.latency_summary(done)
    print(f"throughput {summary['tasks_per_s']} tasks/s, "
          f"p50 {summary['p50_latency_s']}s, "
          f"p99 {summary['p99_latency_s']}s")
    for name, row in summary["buckets"].items():
        print(f"  {name:24s} served={row['served']:3d} "
              f"p50={row['p50_latency_s']}s p99={row['p99_latency_s']}s")
    bad = [c for c in done if not c.ok]
    print(f"budget-exhausted lanes (byzantine OPT > opt_budget): "
          f"{len(bad)}")


if __name__ == "__main__":
    main()
