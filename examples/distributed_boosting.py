"""End-to-end driver: the full resilient distributed boosting protocol
at scale — the paper's own 'workload'.

* 65,536 examples over a 2^20-point domain, k = 16 players,
  adversarial split, adversarial label noise;
* all three 1-D hypothesis classes + the feature-stump class;
* the DISJ-derived hard instances of Theorem 2.3 (communication is
  forced to grow with OPT);
* the semi-agnostic reduction baseline on the same inputs;
* full communication ledger vs the Theorem 4.1 bound and the naive
  baseline.

    PYTHONPATH=src python examples/distributed_boosting.py [--fast]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (classify, ledger, lower_bound, semi_agnostic,
                        tasks, weak)
from repro.core.types import BoostConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sizes for CI")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: seconds, not minutes (the "
                         "examples smoke test runs this)")
    args = ap.parse_args()
    if args.smoke:
        m, n, k = 512, 1 << 10, 4
    elif args.fast:
        m, n, k = 8192, 1 << 16, 8
    else:
        m, n, k = 65536, 1 << 20, 16

    print("=== AccuratelyClassify across hypothesis classes ===")
    for clsname in ("thresholds", "intervals", "singletons"):
        cls = weak.make_class(clsname, n=n)
        cfg = BoostConfig(k=k, coreset_size=400, domain_size=n,
                          opt_budget=64)
        task = tasks.make_task(cls, m=m, k=k, noise=12, seed=1)
        opt = tasks.true_opt(task)
        t0 = time.time()
        f, res = classify.learn(jnp.asarray(task.x),
                                jnp.asarray(task.y),
                                jax.random.key(1), cfg, cls)
        errs = int(weak.empirical_errors(f(jnp.asarray(task.flat_x)),
                                         jnp.asarray(task.flat_y)))
        bound = ledger.theorem_41_bound(cfg, cls, m, opt, constant=4.0)
        print(f"{clsname:12s} m={m} k={k} OPT={opt:3d} E_S(f)={errs:3d} "
              f"attempts={res.attempts} "
              f"bits={res.ledger.total_bits / 1e6:7.2f}M "
              f"(Thm4.1 bound {bound / 1e6:7.1f}M, "
              f"naive {ledger.naive_baseline_bits(m, n) / 1e6:6.2f}M) "
              f"[{time.time() - t0:.1f}s]")
        assert errs <= opt

    print("\n=== Theorem 2.3 hard instances (set disjointness) ===")
    rng = np.random.default_rng(0)
    for r in ((4,) if args.smoke else (4, 16)):
        cfg = BoostConfig(k=2, coreset_size=400, domain_size=n,
                          opt_budget=3 * r + 8)
        for disjoint in (True, False):
            x, y = lower_bound.random_disj_instance(
                rng, r=r, weight=r // 2, disjoint=disjoint)
            out = lower_bound.solve_disjointness(x, y, n, cfg, seed=r)
            print(f"r={r:3d} disjoint={str(disjoint):5s} "
                  f"decided={str(out.disjoint_decided):5s} "
                  f"OPT={out.opt:3d} bits={out.total_bits / 1e6:6.2f}M")
            assert out.disjoint_decided == disjoint

    print("\n=== Semi-agnostic reduction baseline ===")
    cls = weak.Thresholds(n=n)
    cfg = BoostConfig(k=k, coreset_size=400, domain_size=n,
                      opt_budget=64)
    task = tasks.make_task(cls, m=m, k=k, noise=12, seed=2)
    sa = semi_agnostic.run_semi_agnostic(
        jnp.asarray(task.x), jnp.asarray(task.y), jax.random.key(2),
        cfg, cls)
    print(f"smooth-boost+patch: E_S(f)={sa.final_errors} "
          f"(pre-patch {sa.boost_errors}), patched {sa.patched} examples, "
          f"bits={sa.ledger.total_bits / 1e6:.2f}M")
    print("\nall guarantees held ✓")


if __name__ == "__main__":
    main()
