"""Fault-tolerant protocol execution, end to end.

Demonstrates the three layers ISSUE 4 added:

1. **Infrastructure adversaries** — dropout / flaky / rejoin player
   schedules run through the batched engine: the protocol proceeds with
   k′ < k players, the guarantee E_S(f) ≤ OPT holds over the surviving
   shards, and the masked communication ledger charges strictly fewer
   bits than the all-alive run.
2. **Round-granular stepping** — the same protocol executed in 3-round
   slices via ``init_state / run_rounds / finalize``, bit-identical to
   the monolithic dispatch.
3. **Checkpoint / resume** — a run preempted mid-protocol, its state
   serialized to a msgpack file, restored and completed — the output is
   bit-identical to the uninterrupted run.

    PYTHONPATH=src python examples/fault_tolerance.py
"""

import argparse
import os
import tempfile

import jax
import numpy as np

from repro.core import batched, scenarios, tasks, weak
from repro.ckpt import msgpack_ckpt
from repro.core.types import BoostConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--noise", type=int, default=3)
    a = ap.parse_args()

    N = 1 << 12
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=a.k, coreset_size=100, domain_size=N,
                      opt_budget=16)
    x, y, ts = tasks.make_batch(cls, a.batch, a.m, a.k, a.noise,
                                seed0=11)
    keys = jax.random.split(jax.random.key(5), a.batch)
    baseline = batched.run_accurately_classify_batched(x, y, keys, cfg,
                                                       cls)

    # -- 1: infrastructure adversaries ----------------------------------
    specs = [
        scenarios.InfraSpec(name="dropout", player=1, drop_round=5),
        scenarios.InfraSpec(name="flaky", player=2, miss_rate=0.3),
        scenarios.InfraSpec(name="rejoin", player=0, drop_round=4,
                            rejoin_round=12),
    ]
    for spec in specs:
        sched = spec.schedule(a.k, seed=0)
        res = batched.run_accurately_classify_batched(
            x, y, keys, cfg, cls, player_sched=sched)
        print(f"adversary {spec.name}: "
              f"survivors={int(spec.survivors(a.k).sum())}/{a.k}")
        for b in range(a.batch):
            rep = scenarios.infra_report(ts[b], res, b, spec)
            saved = 1 - res.ledger(b).total_bits \
                / baseline.ledger(b).total_bits
            ok = "OK " if rep["guarantee_ok"] else "BAD"
            print(f"  task {b}: E_surv={rep['errors']:2d} "
                  f"OPT_surv={rep['opt']:2d} [{ok}] "
                  f"attempts={rep['attempts']} "
                  f"bits={rep['bits']} (saved {saved:.1%} vs all-alive)")

    # -- 2: round-granular stepping --------------------------------------
    state = batched.init_state(x, y, keys, cfg)
    slices = 0
    a_max = cfg.opt_budget + 1
    while bool(np.any(~np.asarray(state.done)
                      & (np.asarray(state.attempt) < a_max))):
        state = batched.run_rounds(state, x, y, cfg, cls, n=3)
        slices += 1
    sliced = batched.finalize(state, x, y, baseline.alive0, cfg, cls)
    same = np.array_equal(baseline.hypotheses, sliced.hypotheses)
    print(f"stepping: {slices} slices of 3 rounds — "
          f"bit-identical to monolithic run: {same}")

    # -- 3: checkpoint / resume ------------------------------------------
    state = batched.run_rounds(batched.init_state(x, y, keys, cfg),
                               x, y, cfg, cls, n=4)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "engine_state.msgpack")
        msgpack_ckpt.save_pytree(path, jax.device_get(state),
                                 meta={"rounds_done": 4})
        size = os.path.getsize(path)
        del state                             # the preemption
        template = batched.init_state(x, y, keys, cfg)
        restored, meta = msgpack_ckpt.load_pytree(path, like=template)
        done = batched.run_rounds(restored, x, y, cfg, cls)
    resumed = batched.finalize(done, x, y, baseline.alive0, cfg, cls)
    same = (np.array_equal(baseline.hypotheses, resumed.hypotheses)
            and np.array_equal(baseline.disputed, resumed.disputed)
            and all(baseline.ledger(b).total_bits
                    == resumed.ledger(b).total_bits
                    for b in range(a.batch)))
    print(f"checkpoint/resume: preempted after "
          f"{meta['rounds_done']} rounds, state file {size / 1024:.1f} "
          f"KiB — resumed run bit-identical: {same}")


if __name__ == "__main__":
    main()
