"""Adversarial scenario suite over the mesh-sharded protocol engine.

Runs every noise scenario (core/scenarios.py) through
core/sharded_batched.py — the k players live on a real ``players``
device mesh and exchange coresets/weight sums with actual collectives —
then proves, per tenant, the paper's guarantee E_S(f) ≤ OPT and the
ledger-vs-payload identity (Theorem 4.1 accounting == bytes the
collectives moved).

    PYTHONPATH=src python examples/sharded_scenarios.py
    # real 4-device CPU mesh (one player per device):
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/sharded_scenarios.py
"""

import argparse

import jax

from repro.core import scenarios, sharded_batched, weak
from repro.core.types import BoostConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--noise", type=int, default=4)
    ap.add_argument("--coreset", type=int, default=24)
    a = ap.parse_args()

    N = 1 << 12
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=a.k, coreset_size=a.coreset, domain_size=N,
                      opt_budget=32)
    mesh = sharded_batched.make_players_mesh(a.k)
    print(f"mesh: {mesh.shape[sharded_batched.AXIS]} device(s) host "
          f"{a.k} players")
    for name in scenarios.SCENARIOS:
        spec = scenarios.ScenarioSpec(name=name, noise=a.noise)
        x, y, ts = scenarios.make_scenario_batch(
            cls, a.batch, a.m, a.k, spec, seed0=7)
        keys = jax.random.split(jax.random.key(1), a.batch)
        res = sharded_batched.run_accurately_classify_sharded(
            x, y, keys, cfg, cls, mesh=mesh)
        print(f"scenario {name}:")
        for b in range(a.batch):
            if not res.ok[b]:
                print(f"  tenant {b}: exhausted opt_budget="
                      f"{cfg.opt_budget} (OPT above this run's promise)")
                continue
            rep = scenarios.scenario_report(ts[b], res, b)
            wire = res.wire_summary(b)
            res.validate_ledger(b)
            ok = "OK " if rep["guarantee_ok"] else "BAD"
            print(f"  tenant {b}: E_S(f)={rep['errors']:3d} "
                  f"OPT={rep['opt']:3d} attempts={rep['attempts']} "
                  f"disputed={rep['disputed']:3d} "
                  f"recall={rep['recall_contradicted']:.2f} "
                  f"bits={rep['bits']} "
                  f"wire_bytes={wire['collective_bytes']} "
                  f"[{ok} ledger==payload]")


if __name__ == "__main__":
    main()
