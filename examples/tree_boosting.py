"""Histogram-tree weak learners on concepts stumps cannot fit.

Plants an off-centre XOR (and alternating bands) over [0,1)^F, shows
the best axis stump is pinned near chance while the depth-2 histogram
tree class drives the full resilient protocol to E_S(f) ≈ OPT, and
prints the wire cost: tree hypotheses are
``nodes·(⌈log2 F⌉+bin_bits)+leaves`` bits per round — the Theorem 4.1
communication scales with that encoding, never with m.

    PYTHONPATH=src python examples/tree_boosting.py
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched, scenarios, weak
from repro.core.types import BoostConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--noise", type=int, default=4)
    ap.add_argument("--features", type=int, default=4)
    ap.add_argument("--bins", type=int, default=32)
    a = ap.parse_args()

    stumps = weak.AxisStumps(num_features=a.features)
    for name, depth, kw in (("xor", 2, {}),
                            ("bands", 3, {"n_bands": 4})):
        cls = weak.make_class("tree", num_features=a.features,
                              tree_depth=depth, tree_bins=a.bins)
        cfg = BoostConfig(k=a.k, coreset_size=64,
                          domain_size=1 << cls.value_bits,
                          opt_budget=16, deterministic_coreset=False)
        spec = scenarios.ScenarioSpec(name=name, noise=a.noise, **kw)
        ts = [scenarios.make_feature_task(cls, m=a.m, k=a.k, spec=spec,
                                          seed=s)
              for s in range(a.batch)]
        x = np.stack([t.x for t in ts])
        y = np.stack([t.y for t in ts])
        keys = jax.random.split(jax.random.key(0), a.batch)
        res = batched.run_accurately_classify_batched(x, y, keys, cfg,
                                                      cls)
        print(f"=== {name} (depth-{depth} trees, "
              f"{cls.hypothesis_bits()}-bit hypotheses) ===")
        for b in range(a.batch):
            f = res.classifier(b)
            errs = int(weak.empirical_errors(
                f(jnp.asarray(ts[b].flat_x)),
                jnp.asarray(ts[b].flat_y)))
            planted = scenarios.planted_errors(ts[b])
            floor = scenarios.class_floor(ts[b], stumps)
            status = "OK " if errs <= planted + 0.05 * a.m else "BAD"
            print(f"  task {b}: E_S(f)={errs:3d}  OPT≤{planted:3d}  "
                  f"best-stump={floor:3d}  [{status}]  "
                  f"attempts={int(res.attempts[b])}  "
                  f"bits={res.ledger(b).total_bits:,}")


if __name__ == "__main__":
    main()
