"""Tree communication modes: coreset vs histogram-merge vs voting.

The coreset protocol ships ``c`` weighted EXAMPLES per player per
round — Theorem 4.1's m-independent but c·example_bits-heavy payload.
For histogram-tree classes two classical distributed-GBDT layouts move
strictly less (``repro.weak_tree.trees.HistogramTrees.erm_players``):

* ``histogram`` — feature-parallel merge: each player ships its full
  per-node weighted histograms (2·nodes·F·Q fixed-point cells) and the
  merged sums drive the same greedy grower;
* ``voting``    — LightGBM-style parallel voting: top-k split
  proposals per node (feat_bits+bin_bits+gain each), a deterministic
  election, then merged histograms on the 2k elected columns only.

Three registered gates (run.py fails the run if one stops executing):

* **tree_comm_parity** — per mode, the host loop, the batched engine
  and the mesh-sharded engine produce bit-identical hypothesis
  streams, attempts and ledgers on every lane (modes may differ from
  each other — each mode is its own deterministic float program — but
  the three engines must agree bit-for-bit WITHIN a mode).
* **tree_comm_ledger** — ``validate_ledger`` on every sharded lane:
  the Theorem-4.1-style accounting (bits_histograms / bits_votes /
  stuck-round-only coresets) equals the payloads measured at the
  collective sites.
* **tree_comm_savings** — on each planted family (xor, checkerboard,
  bands) the measured total wire bits order
  ``voting < histogram < coreset``: the election's 2·topk elected
  columns beat the full F-column exchange, which beats shipping
  c examples — the sizing (c=512, F=8, Q=8, depth 2, topk=1) mirrors
  the regime the LightGBM voting paper targets (payload ∝ features,
  not examples).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import batched, classify, scenarios, sharded_batched, weak
from repro.core.types import BoostConfig

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
B = 2 if SMOKE else 4
M = 256 if SMOKE else 512
K = 4
F = 8
BINS = 8
DEPTH = 2
TOPK = 1
CORESET = 512                    # the payload the merges must beat
MODES = ("coreset", "histogram", "voting")
# depth-2-representable members of every family (min_tree_depth ≤ 2)
FAMILIES = (("xor", dict()),
            ("checkerboard", dict(cells=2)),
            ("bands", dict(n_bands=2)))


def _cls(mode):
    return weak.make_class("tree", num_features=F, tree_depth=DEPTH,
                           tree_bins=BINS, tree_comm_mode=mode,
                           tree_vote_topk=TOPK)


def _cfg(cls):
    return BoostConfig(k=K, coreset_size=CORESET,
                       domain_size=1 << min(cls.value_bits, 30),
                       opt_budget=16, deterministic_coreset=False)


def _host_loop(x, y, keys, cfg, cls):
    out = []
    for b in range(x.shape[0]):
        try:
            out.append(classify.run_accurately_classify(
                jnp.asarray(x[b]), jnp.asarray(y[b]), keys[b], cfg, cls))
        except RuntimeError:             # opt_budget exhausted — the
            out.append(None)             # engines flag it as ok=False
    return out


def bench_family(name, knobs, seed0):
    spec = scenarios.ScenarioSpec(name=name, noise=2, **knobs)
    # tasks are raw split arrays — identical for every mode (the mode
    # classes differ only in how the protocol merges, not in the
    # concept grid), so all modes run the SAME samples and keys
    x, y, ts = scenarios.make_scenario_batch(_cls("coreset"), B, M, K,
                                             spec, seed0=seed0)
    keys = jax.random.split(jax.random.key(seed0), B)
    mesh = sharded_batched.make_players_mesh(K)
    rows, wire = [], {}
    for mode in MODES:
        cls = _cls(mode)
        cfg = _cfg(cls)
        host_out = _host_loop(x, y, keys, cfg, cls)
        bat_out = batched.run_accurately_classify_batched(x, y, keys,
                                                          cfg, cls)
        t0 = time.time()
        sh_out = sharded_batched.run_accurately_classify_sharded(
            x, y, keys, cfg, cls, mesh=mesh)
        wall = time.time() - t0
        ok = [bool(bat_out.ok[b]) and bool(sh_out.ok[b])
              and host_out[b] is not None for b in range(B)]
        assert all(ok), f"{name}/{mode}: lanes exhausted opt_budget"
        agree = all(
            host_out[b].attempts == int(bat_out.attempts[b])
            == int(sh_out.attempts[b])
            and host_out[b].ledger.total_bits
            == bat_out.ledger(b).total_bits
            == sh_out.ledger(b).total_bits
            and np.array_equal(
                np.asarray(host_out[b].hypotheses)[:host_out[b].rounds],
                np.asarray(bat_out.hypotheses[b])[
                    :int(bat_out.rounds[b])])
            and np.array_equal(
                np.asarray(host_out[b].hypotheses)[:host_out[b].rounds],
                sh_out.hypotheses[b][:int(sh_out.rounds[b])])
            for b in range(B))
        common.gate("tree_comm_parity", agree,
                    f"{name}/{mode}: host/batched/sharded diverge")
        for b in range(B):
            sh_out.validate_ledger(b)    # ledger ≡ measured payload
        common.gate("tree_comm_ledger", True, "")
        bits = [sh_out.ledger(b).total_bits for b in range(B)]
        led = sh_out.ledger(0)
        wire[mode] = int(np.mean(bits))
        errs = [int(weak.empirical_errors(
            sh_out.classifier(b)(jnp.asarray(ts[b].flat_x)),
            jnp.asarray(ts[b].flat_y))) for b in range(B)]
        rows.append({
            "bench": f"tree_comms_{name}_{mode}",
            "us_per_call": round(1e6 * wall / B, 1),
            "derived": (f"bits_mean={wire[mode]};"
                        f"hist_bits={led.bits_histograms};"
                        f"vote_bits={led.bits_votes};"
                        f"coreset_bits={led.bits_coresets};"
                        f"E_S_max={max(errs)};"
                        f"rounds_max={int(sh_out.rounds.max())}"),
            "family": name, "mode": mode, "B": B, "m": M, "k": K,
            "wire_bits_mean": wire[mode],
            "bits_histograms": led.bits_histograms,
            "bits_votes": led.bits_votes,
            "bits_coresets": led.bits_coresets,
            "errors": errs,
            "tasks_per_s": round(B / max(wall, 1e-9), 2),
        })
    common.gate(
        "tree_comm_savings",
        wire["voting"] < wire["histogram"] < wire["coreset"],
        f"{name}: wire bits {wire} violate voting<histogram<coreset")
    return rows


def run_all():
    rows = []
    for i, (name, knobs) in enumerate(FAMILIES):
        rows += bench_family(name, knobs, seed0=10 * (i + 1) + 3)
    return rows


if __name__ == "__main__":
    import json

    for row in run_all():
        print(row["bench"], json.dumps(row))
