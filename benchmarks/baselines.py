"""Benchmarks 7–9: baselines and the neural-resilience experiment.

7. resilient_vs_vanilla — classical (non-resilient) boosting collapses
   under label noise (Dietterich 2000 / Long–Servedio 2010 motivation);
   AccuratelyClassify keeps E_S(f) ≤ OPT at the same communication
   order.
8. semi_agnostic — the reduction route the paper credits (smooth
   boosting + broadcast-and-patch): final error and bits vs the direct
   protocol on identical inputs.
9. neural_resilient — the framework integration: resilient training of
   a reduced transformer on a noisy corpus vs vanilla training (clean
   eval loss + noise recall/precision).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import learn_once
from repro.core import semi_agnostic, tasks, weak
from repro.core.types import BoostConfig


def resilient_vs_vanilla():
    """Vanilla = classical realizable-case distributed boosting
    (BoostAttempt alone).  On samples with contradicting examples it
    provably cannot output a classifier — it gets STUCK (Observation
    4.3); that fragility is the paper's motivation.  AccuratelyClassify
    runs on the identical inputs and meets E_S(f) ≤ OPT."""
    import numpy as np
    from repro.core import boost_attempt, classify
    rows = []
    n = 1 << 10                       # small domain ⇒ duplicated points
    cls = weak.Thresholds(n=n)
    for noise in (0, 8, 24):
        rng = np.random.default_rng(40 + noise)
        x = rng.integers(0, n, size=2048).astype(np.int32)
        y = np.where(x >= n // 2, 1, -1).astype(np.int8)
        if noise:
            flip = rng.choice(2048, size=noise, replace=False)
            y[flip] = -y[flip]        # duplicates ⇒ contradictions
        order = np.argsort(x, kind="stable")
        xk = jnp.asarray(x[order].reshape(4, -1))
        yk = jnp.asarray(y[order].reshape(4, -1))
        w = jnp.ones((2048,), jnp.float32) / 2048
        _, opt_loss = cls.erm(jnp.asarray(x), jnp.asarray(y), w)
        opt = int(round(float(opt_loss) * 2048))
        cfg = BoostConfig(k=4, coreset_size=400, domain_size=n,
                          opt_budget=96)
        van = boost_attempt.run_boost_attempt(
            xk, yk, jnp.ones_like(xk, bool), jax.random.key(0), cfg, cls)
        if van.stuck:
            van_err = None            # no classifier at all
        else:
            g = weak.ensemble_predict(cls, van.hypotheses, van.rounds,
                                      jnp.asarray(x))
            van_err = int(weak.empirical_errors(g, jnp.asarray(y)))
        f, res = classify.learn(xk, yk, jax.random.key(0), cfg, cls)
        res_err = int(weak.empirical_errors(f(jnp.asarray(x)),
                                            jnp.asarray(y)))
        rows.append({
            "bench": "resilient_vs_vanilla", "noise": noise, "opt": opt,
            "vanilla_stuck": bool(van.stuck),
            "vanilla_errors": van_err,
            "resilient_errors": res_err,
            "resilient_bits": res.ledger.total_bits,
            "derived": (f"vanilla={'STUCK(no output)' if van.stuck else van_err};"
                        f"resilient={res_err}<=opt={opt}"),
        })
        assert res_err <= opt
    # classical boosting must fail (stuck) once contradictions exist
    assert any(r["vanilla_stuck"] for r in rows if r["noise"] > 0)
    assert not rows[0]["vanilla_stuck"]          # realizable case fine
    return rows


def semi_agnostic_bench():
    rows = []
    n = 1 << 12
    cls = weak.Thresholds(n=n)
    for noise, seed in ((4, 0), (12, 1)):
        task = tasks.make_task(cls, m=2048, k=4, noise=noise, seed=seed)
        opt = tasks.true_opt(task)
        cfg = BoostConfig(k=4, coreset_size=400, domain_size=n,
                          opt_budget=96)
        sa = semi_agnostic.run_semi_agnostic(
            jnp.asarray(task.x), jnp.asarray(task.y),
            jax.random.key(seed), cfg, cls)
        direct = learn_once("thresholds", m=2048, k=4, noise=noise,
                            seed=seed)
        rows.append({
            "bench": "semi_agnostic", "noise": noise, "opt": opt,
            "reduction_errors": sa.final_errors,
            "reduction_bits": sa.ledger.total_bits,
            "direct_errors": direct["errors"],
            "direct_bits": direct["bits"],
            "derived": (f"patched={sa.patched};"
                        f"bits_ratio="
                        f"{sa.ledger.total_bits / direct['bits']:.2f}"),
        })
    return rows


def neural_resilient(steps: int = 220):
    """Reduced transformer on a 12%-noise corpus: resilient vs vanilla."""
    from repro.launch.train import run
    rows = []
    outs = {}
    for resilient_on in (False, True):
        args = argparse.Namespace(
            arch="deepseek-7b", smoke=True, steps=steps, batch=48,
            seq_len=24, d_model=128, vocab=128, num_examples=768,
            noise=0.12, resilient=resilient_on, check_every=20,
            coreset=32, min_gap=3, lr=1.5e-3, seed=0, log_every=steps,
            ckpt_dir=None, ckpt_every=10 ** 9)
        outs[resilient_on] = run(args)
    for flag, out in outs.items():
        rows.append({
            "bench": "neural_resilient", "resilient": flag,
            "clean_eval_loss": round(out["clean_eval_loss"], 4),
            "train_loss": round(out["final_train_loss"], 4),
            "quarantined": out.get("quarantined", 0),
            "noise_recall": out.get("noise_recall", 0.0),
            "noise_precision": out.get("noise_precision", 0.0),
            "derived": (f"delta_clean="
                        f"{outs[False]['clean_eval_loss'] - outs[True]['clean_eval_loss']:.4f}"),
        })
    return rows
