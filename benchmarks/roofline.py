"""Benchmark 11: roofline table assembly.

Reads the dry-run JSONs under experiments/roofline_1pod (the unrolled,
single-pod compiles) and emits the per-(arch × shape) roofline rows
used by EXPERIMENTS.md §Roofline.  If the unrolled runs are absent it
falls back to the scan-form gate results (marked approx).
"""

from __future__ import annotations

import glob
import json
import os

DIRS = ("experiments/roofline_1pod", "experiments/gate_1pod")


def load_rows(root: str = "."):
    rows = {}
    for d in DIRS:
        for path in sorted(glob.glob(os.path.join(root, d, "*.json"))):
            with open(path) as f:
                r = json.load(f)
            key = (r["arch"], r["shape"])
            if key in rows:
                continue                      # prefer roofline dir
            exact = r.get("unrolled") == "full"
            terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                     "collective": r["collective_s"]}
            dom = max(terms, key=terms.get)
            bound = max(terms.values())
            frac = (r["compute_s"] / bound) if bound else 0.0
            rows[key] = {
                "bench": "roofline", "arch": r["arch"],
                "shape": r["shape"], "exact_counts": exact,
                "compute_s": f"{r['compute_s']:.4g}",
                "memory_s": f"{r['memory_s']:.4g}",
                "collective_s": f"{r['collective_s']:.4g}",
                "dominant": dom,
                "roofline_frac": f"{frac:.3f}",
                "useful_ratio": f"{r.get('useful_ratio', 0):.3f}",
                "derived": (f"dom={dom};frac={frac:.3f};"
                            f"exact={exact}"),
            }
    return list(rows.values())


def run_all(root: str = "."):
    rows = load_rows(root)
    if not rows:
        return [{"bench": "roofline", "derived": "no dry-run data yet"}]
    return rows
