"""Benchmark harness entry: one benchmark per paper claim.

Prints ``name,us_per_call,derived`` CSV (plus bench-specific fields in
the derived column).  ``python -m benchmarks.run [--only NAME[,NAME…]]``.

Besides ``--out`` (the merged machine-readable results), every run
appends one dated ``BENCH_<n>.json`` snapshot at the repo root — the
perf-trajectory record: n increments monotonically, each file carries
the date, the suites run and their rows, so regressions are diffable
across PRs (the CI bench-smoke job uploads the snapshot as an
artifact).  ``--no-trajectory`` suppresses it.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
import time


def _ensure_src_importable() -> None:
    """Make ``repro`` importable without clobbering the caller's path.

    An existing ``PYTHONPATH=src`` (how CI invokes tier-1 and this
    harness) wins; only when ``repro`` cannot be resolved at all is the
    repo's own ``src/`` appended — resolved once, relative to the repo
    root, never blindly prepended at import time.
    """
    try:
        import repro  # noqa: F401
    except ModuleNotFoundError:
        repo_root = os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)))
        sys.path.append(os.path.join(repo_root, "src"))


# Correctness gates each suite must EXECUTE (benchmarks/common.gate
# records them).  A gate that stops running — renamed, skipped, its
# suite no longer reaching it — fails the run even though nothing
# asserted: silently-not-run is indistinguishable from passing
# otherwise.  The executed list is printed (and written to
# GITHUB_STEP_SUMMARY in CI) for the record.
EXPECTED_GATES = {
    "batched_classify": ("batched_host_parity",),
    "serving": ("serving_zero_steady_compiles", "serving_one_shot_parity",
                "serving_sharded_ledger_payload"),
    "fault_injection": ("fault_engine_parity", "fault_masked_ledger",
                        "fault_preempt_resume_parity"),
    "checkpointing": ("ckpt_resume_parity", "ckpt_incremental_bytes",
                      "ckpt_template_free_parity"),
    "trees": ("tree_hist_kernel_parity", "tree_xor_guarantee",
              "tree_stump_separation", "tree_matched_accuracy",
              "tree_matched_wire"),
    "tree_comms": ("tree_comm_parity", "tree_comm_ledger",
                   "tree_comm_savings"),
    "streaming": ("streaming_small_m_parity", "streaming_hist_parity",
                  "streaming_peak_memory", "streaming_sketch_epsilon"),
    "observability": ("obs_trace_ledger_exact", "obs_trace_masked",
                      "obs_trace_preempt_resume",
                      "obs_disabled_overhead"),
}


def _suite():
    from benchmarks import (baselines, batched_classify, checkpointing,
                            fault_injection, finite_class, kernel_micro,
                            observability, paper_claims, roofline,
                            serving, sharded_scenarios, streaming,
                            tree_comms, trees)
    return {
        "batched_classify": batched_classify.run_all,
        "serving": serving.run_all,
        "observability": observability.run_all,
        "fault_injection": fault_injection.run_all,
        "checkpointing": checkpointing.run_all,
        "trees": trees.run_all,
        "tree_comms": tree_comms.run_all,
        "sharded_scenarios": sharded_scenarios.run_all,
        "comm_vs_opt": paper_claims.comm_vs_opt,
        "comm_vs_k": paper_claims.comm_vs_k,
        "comm_vs_m": paper_claims.comm_vs_m,
        "comm_vs_d": paper_claims.comm_vs_d,
        "error_guarantee": paper_claims.error_guarantee,
        "lower_bound": paper_claims.lower_bound_bench,
        "resilient_vs_vanilla": baselines.resilient_vs_vanilla,
        "semi_agnostic": baselines.semi_agnostic_bench,
        "neural_resilient": baselines.neural_resilient,
        "finite_class": finite_class.run_all,
        "kernel_micro": kernel_micro.run_all,
        "roofline": roofline.run_all,
        "streaming": streaming.run_all,
    }


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def write_trajectory_snapshot(all_rows: dict, failures: int,
                              only: str | None,
                              root: str | None = None) -> str:
    """Append the next dated BENCH_<n>.json at the repo root.

    The index is claimed atomically: ``os.open(O_CREAT | O_EXCL)``
    either owns the path or raises, and a collision (two runs in one
    session racing the same glob-derived n, or a leftover file the glob
    missed) retries on the next index — never truncating an existing
    snapshot.
    """
    root = _repo_root() if root is None else root
    taken = []
    for f in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(f))
        if m:
            taken.append(int(m.group(1)))
    n = max(taken, default=0) + 1
    while True:
        path = os.path.join(root, f"BENCH_{n}.json")
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY,
                         0o644)
            break
        except FileExistsError:
            n += 1
    snapshot = {
        "n": n,
        "date": time.strftime("%Y-%m-%d %H:%M:%S"),
        "only": only,
        "suites_run": sorted(all_rows),
        "failures": failures,
        "results": all_rows,
    }
    with os.fdopen(fd, "w") as f:
        json.dump(snapshot, f, indent=1, default=str)
    return path


def _collect_trend(root: str | None = None) -> dict:
    """bench name → [(snapshot n, date, us_per_call), …] across every
    BENCH_<n>.json at the repo root, in snapshot order."""
    root = _repo_root() if root is None else root
    snaps = []
    for f in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(f))
        if not m:
            continue
        try:
            with open(f) as fh:
                snap = json.load(fh)
        except (OSError, ValueError):
            continue                     # unreadable snapshot: skip
        snaps.append((int(m.group(1)), snap))
    snaps.sort()
    series: dict = {}
    for n, snap in snaps:
        for suite_name, rows in (snap.get("results") or {}).items():
            if not isinstance(rows, list):
                continue
            for row in rows:
                if not isinstance(row, dict):
                    continue
                try:
                    us = float(row.get("us_per_call"))
                except (TypeError, ValueError):
                    continue
                if us <= 0:              # failed or untimed rows
                    continue
                series.setdefault(row.get("bench", suite_name),
                                  []).append((n, snap.get("date", ""),
                                              us))
    return series


def write_report(tolerance_pct: float = 25.0,
                 root: str | None = None) -> int:
    """Merge the BENCH_<n>.json trajectory into a per-bench trend
    table: latest vs previous snapshot, % delta, regressions beyond
    the tolerance flagged.  Printed to stdout and appended to
    GITHUB_STEP_SUMMARY when CI provides one; returns the number of
    flagged benches (reported, not an exit failure — snapshot-to-
    snapshot wall time is machine-noisy; the correctness gates are the
    hard bar)."""
    series = _collect_trend(root)
    lines = ["| bench | latest µs | prev µs | Δ% | snapshots | flag |",
             "|---|---|---|---|---|---|"]
    flagged = 0
    for bench in sorted(series):
        pts = series[bench]
        _, _, us1 = pts[-1]
        if len(pts) > 1:
            _, _, us0 = pts[-2]
            delta = (us1 - us0) / us0 * 100.0
            flag = "REGRESSED" if delta > tolerance_pct else ""
            flagged += bool(flag)
            lines.append(f"| {bench} | {us1:.0f} | {us0:.0f} "
                         f"| {delta:+.1f}% | {len(pts)} | {flag} |")
        else:
            lines.append(f"| {bench} | {us1:.0f} | — | — | 1 | |")
    table = "\n".join(lines)
    print(table)
    if flagged:
        print(f"# {flagged} bench(es) regressed beyond "
              f"{tolerance_pct:.0f}%", file=sys.stderr)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(f"## Benchmark trend (tolerance "
                    f"{tolerance_pct:.0f}%)\n\n" + table + "\n")
    return flagged


def _write_gate_summary(suite: dict, gates_executed: dict) -> None:
    """Print the executed-gate table; append it to GITHUB_STEP_SUMMARY
    when CI provides one, so every run records WHICH correctness gates
    actually ran (not just that nothing asserted)."""
    lines = ["| suite | gate | executed | passed |",
             "|---|---|---|---|"]
    for name in suite:
        ran = gates_executed.get(name, {})
        for g in EXPECTED_GATES.get(name, ()):
            lines.append(
                f"| {name} | {g} | {'yes' if g in ran else 'NO'} "
                f"| {'yes' if ran.get(g) else 'NO'} |")
        for g in sorted(set(ran) - set(EXPECTED_GATES.get(name, ()))):
            lines.append(f"| {name} | {g} (unregistered) | yes "
                         f"| {'yes' if ran[g] else 'NO'} |")
    table = "\n".join(lines)
    print(f"# executed gates:\n{table}", file=sys.stderr)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("## Benchmark correctness gates\n\n" + table + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--out", default="experiments/bench_results.json")
    ap.add_argument("--no-trajectory", action="store_true",
                    help="skip the dated BENCH_<n>.json repo-root "
                         "snapshot")
    ap.add_argument("--list", action="store_true",
                    help="print registered suites and their expected "
                         "gates, then exit 0 (no benchmark runs)")
    ap.add_argument("--report", action="store_true",
                    help="merge the BENCH_<n>.json snapshots into a "
                         "per-bench trend table (latest vs previous, "
                         "%% delta, regressions flagged) and exit — "
                         "no benchmark runs")
    ap.add_argument("--report-tolerance", type=float, default=25.0,
                    metavar="PCT",
                    help="--report: flag benches whose latest "
                         "us_per_call regressed more than PCT%% over "
                         "the previous snapshot (default 25)")
    args = ap.parse_args()
    if args.report:
        write_report(args.report_tolerance)
        return
    _ensure_src_importable()
    suite = _suite()
    if args.list:
        for name in sorted(suite):
            gates = EXPECTED_GATES.get(name, ())
            print(name if not gates else f"{name}: {' '.join(gates)}")
        return
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in suite]
        if unknown:
            raise SystemExit(
                f"unknown benchmark(s) {unknown}; pick from "
                f"{sorted(suite)}")
        suite = {n: suite[n] for n in names}
    print("name,us_per_call,derived")
    all_rows = {}
    failures = 0
    gates_executed = {}
    from benchmarks import common as _common
    for name, fn in suite.items():
        t0 = time.time()
        _common.reset_gates()
        try:
            rows = fn()
            us = (time.time() - t0) * 1e6
            all_rows[name] = rows
            gates_executed[name] = dict(_common.GATES_RUN)
            # a gate is a regression when it didn't run OR recorded a
            # failure without raising (gate()'s assert is stripped
            # under python -O; the registry must not depend on it)
            missing = [g for g in EXPECTED_GATES.get(name, ())
                       if not _common.GATES_RUN.get(g)]
            if missing:
                failures += 1
                print(f"{name},-1,\"GATES NOT PASSED: {missing}\"")
            for row in rows:
                derived = row.get("derived", "")
                extra = ";".join(f"{k}={v}" for k, v in row.items()
                                 if k not in ("bench", "derived", "cfg",
                                              "cls", "us_per_call"))
                # per-row bench id, not the suite key — a multi-row
                # suite's rows must be tellable apart in the CSV/summary
                print(f"{row.get('bench', name)},"
                      f"{row.get('us_per_call', round(us, 0))},"
                      f"\"{derived};{extra}\"")
        except Exception as e:  # noqa: BLE001
            failures += 1
            gates_executed[name] = dict(_common.GATES_RUN)
            print(f"{name},-1,\"FAILED: {type(e).__name__}: {e}\"")
    _write_gate_summary(suite, gates_executed)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    if args.only and os.path.exists(args.out):
        # --only refreshes just its suite's rows; keep the others, but
        # never keep stale rows for a suite that just FAILED (it has no
        # entry in all_rows, so drop any previous one)
        try:
            with open(args.out) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
        for name in suite:
            merged.pop(name, None)
        merged.update(all_rows)
        all_rows = merged
    with open(args.out, "w") as f:
        json.dump(all_rows, f, indent=1, default=str)
    if not args.no_trajectory:
        # only suites that actually produced rows; failures are counted
        # in the snapshot's own field, not smuggled in as null results
        path = write_trajectory_snapshot(
            {n: all_rows[n] for n in suite if n in all_rows},
            failures, args.only)
        print(f"# trajectory snapshot: {path}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
