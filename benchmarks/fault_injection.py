"""Fault-injection benchmark: infrastructure adversaries + preemption.

Three gates make this a regression test, not just a report (run.py
exits non-zero if any trips):

* **engine parity under the mask** — for every adversary (dropout,
  flaky, rejoin) the sharded engine's outputs are bit-identical to the
  local batched engine given the same player schedule;
* **ledger ≡ payload under the mask** — every ok sharded lane passes
  ``validate_ledger`` (Theorem 4.1 bits vs measured collective
  payloads, with only alive players' messages charged), and the masked
  run charges strictly fewer bits than the all-alive baseline;
* **preempt/resume parity** — a scheduler stream with an injected
  preemption (checkpoint → requeue → resume) completes every request
  bit-identical to its ``one_shot`` run.

Reported: tasks/sec per adversary and the communication saved by the
mask, plus the preempted stream's end-to-end rate.  ``warm()`` now
pre-compiles the stepping programs whenever a checkpoint dir is set,
so the preempted stream's rate no longer swallows their one-time
compiles (benchmarks/checkpointing.py tracks the resume path's
latency in detail; this suite gates parity).

``REPRO_BENCH_SMOKE=1`` (the CI bench-smoke job) shrinks the batch;
the gates are identical at both scales.
"""

from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import batched, scenarios, sharded_batched, tasks, weak
from repro.core.types import BoostConfig
from repro.launch import scheduler as S

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
B = 2 if SMOKE else 8
M = 256 if SMOKE else 512
N = 1 << 12
N_REQUESTS = 12 if SMOKE else 48

SPECS = {
    "dropout": scenarios.InfraSpec(name="dropout", player=1,
                                   drop_round=5),
    "flaky": scenarios.InfraSpec(name="flaky", player=2, miss_rate=0.3,
                                 horizon=64),
    "rejoin": scenarios.InfraSpec(name="rejoin", player=0, drop_round=4,
                                  rejoin_round=12),
}


def _assert_engine_parity(ref, got):
    np.testing.assert_array_equal(ref.hypotheses, got.hypotheses)
    np.testing.assert_array_equal(ref.attempts, got.attempts)
    np.testing.assert_array_equal(ref.disputed, got.disputed)
    np.testing.assert_array_equal(ref.hist_players, got.hist_players)


def bench_adversary(name: str) -> dict:
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=4, coreset_size=100, domain_size=N,
                      opt_budget=16)
    spec = SPECS[name]
    sched = spec.schedule(4, seed=0)
    x, y, ts = tasks.make_batch(cls, B, M, 4, 3, seed0=11)
    keys = jax.random.split(jax.random.key(5), B)
    run = batched.run_accurately_classify_batched
    baseline = run(x, y, keys, cfg, cls)
    run(x, y, keys, cfg, cls, player_sched=sched)      # warm
    t0 = time.perf_counter()
    res = run(x, y, keys, cfg, cls, player_sched=sched)
    wall = time.perf_counter() - t0
    assert bool(res.ok.all())
    mesh = sharded_batched.make_players_mesh(4)
    got = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, cfg, cls, mesh=mesh, player_sched=sched)
    _assert_engine_parity(res, got)                    # gate 1
    common.gate("fault_engine_parity", True)
    bits_masked = bits_full = 0
    for b in range(B):
        got.validate_ledger(b)                         # gate 2
        bits_masked += got.ledger(b).total_bits
        bits_full += baseline.ledger(b).total_bits
        rep = scenarios.infra_report(ts[b], res, b, spec)
        assert rep["guarantee_ok"], (name, b, rep)
    common.gate("fault_masked_ledger", bits_masked < bits_full,
                f"{name}: masked {bits_masked} ≥ all-alive {bits_full}")
    return {
        "bench": f"fault_{name}",
        "us_per_call": round(1e6 * wall / B, 1),
        "derived": (f"tps={round(B / max(wall, 1e-9), 1)};"
                    f"bits_saved_pct="
                    f"{round(100 * (1 - bits_masked / bits_full), 1)};"
                    f"survivor_guarantees={B}/{B}"),
        "tasks_per_s": round(B / max(wall, 1e-9), 2),
        "bits_masked": bits_masked,
        "bits_all_alive": bits_full,
    }


def bench_preempt_resume() -> dict:
    shapes = [{"m": 64, "k": 2, "noise": 1},
              {"m": 128, "k": 2, "noise": 2}]
    lattice = S.BucketLattice(b_sizes=(2, 4), mloc_sizes=(32, 64))
    req_common = dict(coreset_size=48, opt_budget=6)
    arrivals = S.poisson_trace(N_REQUESTS, rate_per_s=500.0, seed=5)
    reqs = S.make_request_stream(N_REQUESTS, arrivals, shapes,
                                 seed0=11, **req_common)
    with tempfile.TemporaryDirectory() as ck:
        sched = S.BoostScheduler(lattice=lattice, ckpt_dir=ck,
                                 preempt={0: 3, 1: 4})
        sched.warm(reqs, b_sizes=lattice.b_sizes + (1,))
        t0 = time.perf_counter()
        done = sched.run_stream(reqs)
        wall = time.perf_counter() - t0
        assert len(done) == N_REQUESTS
        assert sched.stats.preemptions == 2
        assert sched.stats.resumes == 2
        idx = np.linspace(0, len(done) - 1,
                          min(8, len(done)), dtype=int)
        ledgers_compared = 0
        for i in idx:                                  # gate 3
            c = done[int(i)]
            one = sched.one_shot(c.request)
            np.testing.assert_array_equal(
                c.result.hypotheses[c.lane], one.hypotheses[0])
            np.testing.assert_array_equal(
                c.result.disputed[c.lane], one.disputed[0])
            if c.ok:
                assert (c.per_task().ledger.total_bits
                        == one.per_task(0).ledger.total_bits)
                ledgers_compared += 1
        # the ledger leg must have compared SOMETHING — all-failed
        # lanes would otherwise record a vacuous pass
        common.gate("fault_preempt_resume_parity", ledgers_compared > 0,
                    "no ok completion reached the ledger comparison")
        resumed = [c for c in done if c.resumed]
    return {
        "bench": "fault_preempt_resume",
        "us_per_call": round(1e6 * wall / N_REQUESTS, 1),
        "derived": (f"tps={round(N_REQUESTS / max(wall, 1e-9), 1)};"
                    f"preemptions={sched.stats.preemptions};"
                    f"resumed_requests={len(resumed)};"
                    f"parity_checked={len(idx)}"),
        "tasks_per_s": round(N_REQUESTS / max(wall, 1e-9), 2),
        "preemptions": sched.stats.preemptions,
        "resumes": sched.stats.resumes,
    }


def run_all():
    rows = [bench_adversary(name) for name in sorted(SPECS)]
    rows.append(bench_preempt_resume())
    return rows


if __name__ == "__main__":
    import json

    for row in run_all():
        print(row["bench"], json.dumps(row))
