"""Tree weak-learner benchmark: histogram kernel vs ref, and
trees-vs-stumps throughput + wire bits at matched accuracy.

Three parts, four registered gates (run.py checks each was executed):

* **Histogram kernel parity + micro-roofline.**  The Pallas tree-
  histogram kernel (interpret mode off-TPU) must match ``ref.py``
  bit-exactly — the parity inputs use dyadic-rational weights, whose
  partial sums are all exactly representable, so equality is
  order-independent and bitwise assertable on padded/ragged shapes.
  Wall-times on CPU time the jnp ref (the CPU production path); the
  TPU roofline analysis lives in EXPERIMENTS.md.

* **Separation (xor).**  The planted-XOR scenario: the depth-2 tree
  protocol must reach ``E_S(f) ≤ planted + 0.05·m`` per task while the
  best axis stump on the same sample is pinned ≥ 0.25·m errors — the
  workload class single-feature hypotheses provably cannot fit.

* **Matched accuracy (half-plane).**  ``bands`` with n_bands = 2 is a
  single half-plane — fittable by BOTH stumps and depth-2 trees.  All
  classes run the full protocol on identical samples to the same
  accuracy; the rows report tasks/sec and total wire bits each, with
  TWO stump baselines so the comparison measures what it says:
  ``stumps_grid`` charges the same 20-bit grid-row example encoding
  the trees use (``value_bits = F·bin_bits``) — at matched accuracy
  its wire cost is IDENTICAL to the tree's (25-bit hypotheses both) —
  while ``stumps_raw32`` is the repo-default 32-bit-threshold
  encoding, whose extra cost is encoding overhead, not expressiveness.
  The Thm 4.1 point: bits scale with the hypothesis/example encoding,
  never with m — the class that ALSO fits XOR (see the separation
  gate) costs nothing extra on the wire once encodings are matched.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import batched, scenarios, weak
from repro.core.types import BoostConfig
from repro.kernels.histogram import ops as hist_ops

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
B = 4 if SMOKE else 8
M = 256
K = 4
F = 4
BINS = 32


def _cfg(cls):
    return BoostConfig(k=K, coreset_size=64,
                       domain_size=1 << min(cls.value_bits, 30),
                       opt_budget=16, deterministic_coreset=False)


def bench_hist_kernel() -> list:
    rows = []
    rng = np.random.default_rng(0)
    # bitwise parity on padded/ragged shapes: dyadic weights (j/256)
    for c, f, n in ((130, 9, 3), (128, 8, 4), (1, 1, 1), (257, 5, 2)):
        x = ((rng.integers(0, BINS, (c, f)) + 0.5) / BINS) \
            .astype(np.float32)
        w = (rng.integers(0, 256, (n, c)) / 256.0).astype(np.float32)
        wy = w * rng.choice([-1.0, 1.0], (n, c)).astype(np.float32)
        ref = hist_ops.node_histograms_ref(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(wy), BINS)
        got = hist_ops.node_histograms(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(wy), BINS,
            interpret=jax.default_backend() != "tpu")
        common.gate(
            "tree_hist_kernel_parity",
            all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(got, ref)),
            f"kernel != ref at c={c} F={f} N={n}")
    # micro timing of the production path (ref on CPU, kernel on TPU)
    c, f, n = 512, 8, 4
    x = jnp.asarray(rng.random((c, f)), jnp.float32)
    w = jnp.asarray(rng.random((n, c)), jnp.float32)
    wy = w * jnp.asarray(rng.choice([-1.0, 1.0], (n, c)), jnp.float32)
    hist = jax.jit(lambda *a: hist_ops.node_histograms(*a, BINS))
    us = common.timeit(hist, x, w, wy)
    flops = 2 * c * f * BINS * n * 2          # two weighted contractions
    rows.append({
        "bench": "tree_hist_kernel",
        "us_per_call": round(us, 1),
        "derived": (f"cFNQ={c}x{f}x{n}x{BINS};"
                    f"gflops={round(flops / us / 1e3, 2)};"
                    f"backend={jax.default_backend()};parity=bitwise"),
    })
    return rows


def _run_protocol(cls, ts, seed=0):
    """Batched protocol over stacked tasks → (tps, bits, errors/task)."""
    x = np.stack([t.x for t in ts])
    y = np.stack([t.y for t in ts])
    keys = jax.random.split(jax.random.key(seed), len(ts))
    cfg = _cfg(cls)
    run = batched.run_accurately_classify_batched
    run(x, y, keys, cfg, cls)                  # warm
    t0 = time.perf_counter()
    res = run(x, y, keys, cfg, cls)
    wall = time.perf_counter() - t0
    errs, bits = [], []
    for b in range(len(ts)):
        f = res.classifier(b)
        errs.append(int(weak.empirical_errors(
            f(jnp.asarray(ts[b].flat_x)), jnp.asarray(ts[b].flat_y))))
        bits.append(res.ledger(b).total_bits)
    return res, wall, errs, bits


def bench_trees_vs_stumps() -> list:
    rows = []
    stumps = weak.AxisStumps(num_features=F)
    tree2 = weak.make_class("tree", num_features=F, tree_depth=2,
                            tree_bins=BINS)
    # --- separation: planted XOR, trees solve, stumps pinned ≥ 0.25m --
    spec = scenarios.ScenarioSpec(name="xor", noise=4)
    ts = [scenarios.make_feature_task(tree2, m=M, k=K, spec=spec,
                                      seed=s) for s in range(B)]
    res, wall, errs, bits = _run_protocol(tree2, ts)
    planted = [scenarios.planted_errors(t) for t in ts]
    floors = [scenarios.class_floor(t, stumps) for t in ts]
    common.gate(
        "tree_xor_guarantee",
        bool(res.ok.all()) and all(e <= p + 0.05 * M
                                   for e, p in zip(errs, planted)),
        f"errs={errs} planted={planted}")
    common.gate(
        "tree_stump_separation",
        all(fl >= 0.25 * M for fl in floors),
        f"stump floors {floors} < 0.25·m={0.25 * M}")
    rows.append({
        "bench": "tree_xor_separation",
        "us_per_call": round(1e6 * wall / B, 1),
        "derived": (f"tps={round(B / max(wall, 1e-9), 1)};"
                    f"E_S_max={max(errs)};planted_max={max(planted)};"
                    f"stump_floor_min={min(floors)};"
                    f"bits_mean={int(np.mean(bits))}"),
        "tasks_per_s": round(B / max(wall, 1e-9), 2),
        "errors": errs, "stump_floors": floors,
    })
    # --- matched accuracy: half-plane task every class fits ----------
    spec = scenarios.ScenarioSpec(name="bands", noise=3, n_bands=2)
    ts = [scenarios.make_feature_task(tree2, m=M, k=K, spec=spec,
                                      seed=100 + s) for s in range(B)]
    grid_stumps = weak.AxisStumps(num_features=F,
                                  value_bits=F * tree2.bin_bits)
    wire = {}
    for label, cls in (("tree_d2", tree2),
                       ("stumps_grid", grid_stumps),
                       ("stumps_raw32", stumps)):
        res, wall, errs, bits = _run_protocol(cls, ts)
        planted = [scenarios.planted_errors(t) for t in ts]
        common.gate(
            "tree_matched_accuracy",
            bool(res.ok.all()) and all(e <= p + 0.05 * M
                                       for e, p in zip(errs, planted)),
            f"{label}: errs={errs} planted={planted}")
        wire[label] = int(np.mean(bits))
        rows.append({
            "bench": f"tree_halfplane_{label}",
            "us_per_call": round(1e6 * wall / B, 1),
            "derived": (f"tps={round(B / max(wall, 1e-9), 1)};"
                        f"E_S_max={max(errs)};"
                        f"hyp_bits={cls.hypothesis_bits()};"
                        f"wire_bits_mean={int(np.mean(bits))}"),
            "tasks_per_s": round(B / max(wall, 1e-9), 2),
            "wire_bits_mean": int(np.mean(bits)),
            "hypothesis_bits": cls.hypothesis_bits(),
        })
    # the expressive class costs no extra wire once encodings match
    common.gate("tree_matched_wire",
                wire["tree_d2"] <= wire["stumps_grid"]
                <= wire["stumps_raw32"],
                f"wire bits {wire}")
    return rows


def run_all():
    return bench_hist_kernel() + bench_trees_vs_stumps()


if __name__ == "__main__":
    import json

    for row in run_all():
        print(row["bench"], json.dumps(row))
