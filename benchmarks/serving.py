"""Continuous-batching serving benchmark: throughput/latency per bucket
policy, gated on zero steady-state recompiles and bit-parity.

A mixed-shape request stream (three m shapes across ≥ 3 (B, mloc)
buckets, mixed noise and adversarial scenarios) is replayed from a
Poisson and a bursty arrival trace through the scheduler
(repro/launch/scheduler.py), once per admission policy:

* ``pack``  — dispatch as soon as anything is queued (latency-first);
* ``fill``  — hold for a full batch or the head deadline
  (throughput-first).

The cache is warmed first (``BoostScheduler.warm``), so the timed
replay is pure steady state.  Three gates make this a regression test,
not just a report (run.py exits non-zero if any trips):

* **zero recompiles** — the steady replay must not compile anything;
* **parity** — a sample of completions must be bit-identical to the
  one-shot engine run of the same request (hypotheses, attempts, total
  ledger bits);
* **ledger ≡ payload** — every ok sharded completion passes
  ``validate_ledger`` (Theorem 4.1 bits vs measured collective
  payloads).

``REPRO_BENCH_SMOKE=1`` (the CI bench-smoke job) shrinks the stream;
the gates are identical at both scales.
"""

from __future__ import annotations

import os

import numpy as np

from benchmarks import common
from repro.launch import scheduler as S

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
N_REQUESTS = 60 if SMOKE else 200
PARITY_SAMPLE = 8 if SMOKE else 24

SHAPES = [
    {"m": 96, "k": 2, "noise": 1},
    {"m": 128, "k": 2, "noise": 0},
    {"m": 192, "k": 2, "noise": 2, "scenario": "drift"},
]
LATTICE = S.BucketLattice(b_sizes=(4, 8), mloc_sizes=(64, 128))
COMMON = dict(coreset_size=64, opt_budget=8)


def _stream(trace: str, engine: str, n: int = N_REQUESTS):
    if trace == "bursty":
        arr = S.bursty_trace(n, rate_per_s=400.0, burst=8, seed=5)
    else:
        arr = S.poisson_trace(n, rate_per_s=400.0, seed=5)
    return S.make_request_stream(n, arr, SHAPES, seed0=11,
                                 engine=engine, **COMMON)


def _assert_parity(sched: S.BoostScheduler, completions):
    """Scheduler lanes ≡ one-shot engine runs, bit for bit."""
    idx = np.linspace(0, len(completions) - 1,
                      min(PARITY_SAMPLE, len(completions)),
                      dtype=int)
    for i in idx:
        c = completions[int(i)]
        one = sched.one_shot(c.request)
        np.testing.assert_array_equal(
            c.result.hypotheses[c.lane], one.hypotheses[0])
        assert int(c.result.attempts[c.lane]) == int(one.attempts[0])
        assert bool(c.result.ok[c.lane]) == bool(one.ok[0])
        if c.ok:
            assert (c.per_task().ledger.total_bits
                    == one.per_task(0).ledger.total_bits)


def bench_stream(policy: str, trace: str, engine: str = "batched",
                 cache: S.CompileCache | None = None) -> dict:
    reqs = _stream(trace, engine)
    sched = S.BoostScheduler(lattice=LATTICE, policy=policy,
                             fill_wait_s=0.02, cache=cache)
    sched.warm(reqs, b_sizes=LATTICE.b_sizes + (1,))  # +1 for one_shot
    compiles_warm = sched.cache.stats.compiles
    done = sched.run_stream(reqs)
    steady_compiles = sched.cache.stats.compiles - compiles_warm
    common.gate("serving_zero_steady_compiles", steady_compiles == 0,
                f"steady state recompiled {steady_compiles}×")
    assert len(done) == len(reqs)
    _assert_parity(sched, done)
    common.gate("serving_one_shot_parity", True)
    validated = 0
    if engine == "sharded":
        for c in done:
            if c.ok:
                c.validate_ledger()
                validated += 1
        common.gate("serving_sharded_ledger_payload", validated > 0,
                    "no sharded completion was ledger-validated")
    summary = S.latency_summary(done)
    return {
        "policy": policy, "trace": trace, "engine": engine,
        "requests": len(done), "dispatches": sched.stats.dispatches,
        "buckets_hit": len(summary["buckets"]),
        "filler_lanes": sched.stats.filler_lanes,
        "steady_compiles": steady_compiles,
        "cache_hits": sched.cache.stats.hits,
        "ledger_validated": validated,
        "tasks_per_s": summary["tasks_per_s"],
        "p50_latency_s": summary["p50_latency_s"],
        "p99_latency_s": summary["p99_latency_s"],
    }


def run_all():
    rows = []
    cache = S.CompileCache()        # shared: policies reuse programs
    grid = [("pack", "poisson", "batched"),
            ("pack", "bursty", "batched"),
            ("fill", "bursty", "batched"),
            ("pack", "poisson", "sharded")]
    for policy, trace, engine in grid:
        r = bench_stream(policy, trace, engine, cache=cache)
        rows.append({
            "bench": f"serving_{engine}_{policy}_{trace}",
            "us_per_call": round(1e6 / max(r["tasks_per_s"], 1e-9), 1),
            "derived": (f"tps={r['tasks_per_s']};"
                        f"p50={r['p50_latency_s']};"
                        f"p99={r['p99_latency_s']};"
                        f"steady_compiles={r['steady_compiles']};"
                        f"buckets={r['buckets_hit']}"),
            **r,
        })
    return rows


if __name__ == "__main__":
    import json

    for row in run_all():
        print(row["bench"], json.dumps(row))
