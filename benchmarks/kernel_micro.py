"""Benchmark 10: kernel microbenches (interpret-mode correctness +
structure; wall-times on CPU are NOT TPU predictions — the roofline
table in EXPERIMENTS.md carries the TPU-side analysis)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mw_update import ops as mw_ops
from repro.kernels.stump import ops as stump_ops
from repro.kernels.stump.ref import stump_errors_ref


def run_all():
    rows = []
    rng = np.random.default_rng(0)
    # mw_update
    m = 1 << 14
    hits = jnp.asarray(rng.integers(0, 40, m), jnp.int32)
    corr = jnp.asarray(rng.random(m) < 0.5)
    alive = jnp.asarray(rng.random(m) < 0.9)
    us = timeit(lambda: mw_ops.mw_update(hits, corr, alive))
    nh, ws = mw_ops.mw_update(hits, corr, alive)
    ref = jnp.sum(jnp.where(alive, jnp.exp2(-(hits + jnp.where(
        corr & alive, 1, 0)).astype(jnp.float32)), 0.0))
    rows.append({"bench": "kernel_mw_update", "us_per_call": round(us, 1),
                 "derived": f"m={m};allclose="
                 f"{bool(jnp.allclose(ws, ref, rtol=1e-5))}"})
    # stump
    c, F, Q = 512, 8, 128
    x = jnp.asarray(rng.standard_normal((c, F)), jnp.float32)
    w = rng.random(c).astype(np.float32)
    w = jnp.asarray(w / w.sum())
    y = jnp.asarray(rng.choice([-1.0, 1.0], c), jnp.float32)
    th = jnp.asarray(np.sort(rng.standard_normal((F, Q)), 1), jnp.float32)
    us = timeit(lambda: stump_ops.stump_errors(x, w, y, th))
    ok = bool(jnp.allclose(stump_ops.stump_errors(x, w, y, th),
                           stump_errors_ref(x, w, y, th), rtol=3e-5,
                           atol=3e-6))
    rows.append({"bench": "kernel_stump", "us_per_call": round(us, 1),
                 "derived": f"cFQ={c}x{F}x{Q};allclose={ok}"})
    # flash attention
    B, S, H, KV, hd = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    us = timeit(lambda: flash_ops.flash_attention(q, k, v), iters=1)
    got = flash_ops.flash_attention(q, k, v)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    ok = bool(jnp.allclose(got, ref, rtol=2e-5, atol=2e-5))
    rows.append({"bench": "kernel_flash", "us_per_call": round(us, 1),
                 "derived": f"BSHKVhd={B},{S},{H},{KV},{hd};allclose={ok}"})
    return rows
