"""Observability benchmark: trace↔ledger cross-validation + overhead.

The tracing subsystem (repro/obs) claims that the per-round spans a
traced run emits carry, per task and per ledger category, exactly the
wire bits the Theorem 4.1 accounting charges — derived purely from
host-visible state-counter deltas, never from instrumentation inside
jitted code.  This suite makes that claim a regression gate:

* **obs_trace_ledger_exact** — a round-granular traced run
  (``repro.obs.roundtrace.trace_rounds``) validates bit-exact against
  ``result.ledger(b)`` on the host, batched, and sharded engines, for
  every tree communication mode (coreset / histogram / voting) and for
  the thresholds class.
* **obs_trace_masked** — the same bit-exactness under a player-dropout
  schedule, plus the trace must record dead players explicitly as
  zero-bit ``dead_players`` instant events (absent players move
  nothing, and the trace says so rather than staying silent).
* **obs_trace_preempt_resume** — a run cut off mid-protocol,
  checkpointed (ckpt/msgpack_ckpt), restored template-free and traced
  to completion with a second recorder still validates after merging
  both segments' events: bits are counter deltas, so the resumed
  segment continues exactly where the preempted one stopped — no
  double count, no gap.
* **obs_disabled_overhead** — with tracing disabled (the default), the
  instrumented dispatch path must stay within 2% of calling the jitted
  program directly (the no-op span fast path is one ``is None`` test).

The traced thresholds run is also written to
``experiments/obs_trace.json`` — a Chrome trace-event file loadable at
https://ui.perfetto.dev (the CI bench-smoke job uploads it as an
artifact).

``REPRO_BENCH_SMOKE=1`` shrinks task sizes; every gate is identical at
both scales.
"""

from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.ckpt import msgpack_ckpt
from repro.core import batched, scenarios, sharded_batched, tasks, weak
from repro.core import classify
from repro.core.types import BoostConfig
from repro.obs import roundtrace, trace as obs_trace

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
B, K = 2, 4
M_TREE = 128 if SMOKE else 256
M_THRESH = 256 if SMOKE else 512
OVERHEAD_ITERS = 5 if SMOKE else 9

# a dropout schedule: player 0 absent for wire round 1 (then the
# schedule's last row extends — see core/batched.canon_player_sched)
MASK_SCHED = np.ones((5, K), bool)
MASK_SCHED[1, 0] = False


def _tree_cls(mode: str):
    return weak.make_class("tree", num_features=8, tree_depth=2,
                           tree_bins=8, tree_comm_mode=mode,
                           tree_vote_topk=1)


def _tree_cfg(cls) -> BoostConfig:
    return BoostConfig(k=K, coreset_size=512,
                       domain_size=1 << min(cls.value_bits, 30),
                       opt_budget=16, deterministic_coreset=False)


def _step_fn(engine: str, x, y, cfg, cls, mesh, player_sched):
    if engine == "sharded":
        return lambda s: sharded_batched.run_rounds_sharded(
            s, x, y, cfg, cls, mesh=mesh, n=1,
            player_sched=player_sched)
    return lambda s: batched.run_rounds(s, x, y, cfg, cls, n=1,
                                        player_sched=player_sched)


def _traced_run(engine: str, x, y, keys, cfg, cls, mesh,
                player_sched=None):
    """One round-granular traced dispatch → (recorder, result)."""
    alive0 = np.ones(y.shape, bool)
    with obs_trace.recording() as rec:
        if engine == "sharded":
            st = sharded_batched.init_state_sharded(x, y, keys, cfg,
                                                    cls=cls)
        else:
            st = batched.init_state(x, y, keys, cfg, cls=cls)
        st = roundtrace.trace_rounds(
            _step_fn(engine, x, y, cfg, cls, mesh, player_sched),
            st, cfg, cls, engine=engine)
        if engine == "sharded":
            res = sharded_batched.finalize_sharded(st, x, y, alive0,
                                                   cfg, cls, mesh=mesh)
        else:
            res = batched.finalize(st, x, y, alive0, cfg, cls)
    return rec, res


def _check_dead_events(rec) -> None:
    dead = [e for e in rec.events if e["name"] == "dead_players"]
    common.gate("obs_trace_masked",
                bool(dead) and all(e["args"]["bits"] == 0 for e in dead),
                "masked rounds must emit zero-bit dead_players events")


def bench_ledger_exact() -> list:
    """Traced bits ≡ ledger on every engine × comm mode (± mask)."""
    rows = []
    mesh = sharded_batched.make_players_mesh(K)

    # thresholds class: batched + sharded + the host reference engine
    n = 1 << 12
    cls = weak.make_class("thresholds", n=n)
    cfg = BoostConfig(k=K, coreset_size=100, domain_size=n,
                      opt_budget=16)
    x, y, _ = tasks.make_batch(cls, B, M_THRESH, K, 3, seed0=11)
    keys = jax.random.split(jax.random.key(5), B)
    for engine in ("batched", "sharded"):
        for ps in (None, MASK_SCHED):
            t0 = time.time()
            rec, res = _traced_run(engine, x, y, keys, cfg, cls, mesh,
                                   player_sched=ps)
            rep = roundtrace.validate_trace(
                rec, {b: res.ledger(b) for b in range(B)})
            common.gate("obs_trace_ledger_exact", True)
            if ps is not None:
                _check_dead_events(rec)
            if engine == "batched" and ps is None:
                # the Perfetto artifact CI uploads
                os.makedirs("experiments", exist_ok=True)
                rec.save("experiments/obs_trace.json")
            bits0 = sum(rep[0]["traced"][c]
                        for c in roundtrace.CATEGORY_FIELDS)
            rows.append({
                "bench": f"obs_thresholds_{engine}"
                         + ("_masked" if ps is not None else ""),
                "us_per_call": round((time.time() - t0) * 1e6, 0),
                "derived": f"events={len(rec.events)};bits0={bits0}",
            })

    # host engine: attempt-granular spans, same validator
    with obs_trace.recording() as rec:
        ref = classify.run_accurately_classify(
            jnp.asarray(x[0]), jnp.asarray(y[0]), keys[0], cfg, cls)
    roundtrace.validate_trace(rec, {0: ref.ledger})
    common.gate("obs_trace_ledger_exact", True)
    rows.append({"bench": "obs_thresholds_host",
                 "us_per_call": 0,
                 "derived": f"events={len(rec.events)}"})

    # tree class: every communication mode, both stepping engines,
    # full and masked
    spec = scenarios.ScenarioSpec(name="xor", noise=2)
    for mode in ("coreset", "histogram", "voting"):
        cls = _tree_cls(mode)
        cfg = _tree_cfg(cls)
        x, y, _ = scenarios.make_scenario_batch(cls, B, M_TREE, K,
                                                spec, seed0=7)
        keys = jax.random.split(jax.random.key(7), B)
        for engine in ("batched", "sharded"):
            for ps in (None, MASK_SCHED):
                rec, res = _traced_run(engine, x, y, keys, cfg, cls,
                                       mesh, player_sched=ps)
                roundtrace.validate_trace(
                    rec, {b: res.ledger(b) for b in range(B)})
                common.gate("obs_trace_ledger_exact", True)
                if ps is not None:
                    _check_dead_events(rec)
        with obs_trace.recording() as rec:
            ref = classify.run_accurately_classify(
                jnp.asarray(x[0]), jnp.asarray(y[0]), keys[0], cfg,
                cls)
        roundtrace.validate_trace(rec, {0: ref.ledger})
        common.gate("obs_trace_ledger_exact", True)
        rows.append({"bench": f"obs_tree_{mode}",
                     "us_per_call": 0,
                     "derived": "engines=batched,sharded,host;"
                                "masks=full,dropout"})
    return rows


def bench_preempt_resume() -> list:
    """Spans survive checkpoint/resume with no double-counted bits."""
    mesh = sharded_batched.make_players_mesh(K)
    n = 1 << 12
    cls = weak.make_class("thresholds", n=n)
    cfg = BoostConfig(k=K, coreset_size=100, domain_size=n,
                      opt_budget=16)
    x, y, _ = tasks.make_batch(cls, B, M_THRESH, K, 3, seed0=21)
    keys = jax.random.split(jax.random.key(9), B)
    alive0 = np.ones(y.shape, bool)
    rows = []
    grid = [("batched", None), ("sharded", MASK_SCHED)]
    for engine, ps in grid:
        step = _step_fn(engine, x, y, cfg, cls, mesh, ps)
        treedef = (sharded_batched.STATE_TREEDEF
                   if engine == "sharded" else batched.STATE_TREEDEF)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "preempt.msgpack")
            rec_a = obs_trace.TraceRecorder()
            if engine == "sharded":
                st = sharded_batched.init_state_sharded(x, y, keys,
                                                        cfg, cls=cls)
            else:
                st = batched.init_state(x, y, keys, cfg, cls=cls)
            st = roundtrace.trace_rounds(step, st, cfg, cls,
                                         recorder=rec_a, max_rounds=3,
                                         engine=engine)
            msgpack_ckpt.save_pytree(path, jax.device_get(st),
                                     treedef=treedef)
            del st                         # the preemption: state dies
            restored, _meta = msgpack_ckpt.restore_pytree(path)
            rec_b = obs_trace.TraceRecorder()
            restored = roundtrace.trace_rounds(step, restored, cfg,
                                               cls, recorder=rec_b,
                                               engine=engine)
            if engine == "sharded":
                res = sharded_batched.finalize_sharded(
                    restored, x, y, alive0, cfg, cls, mesh=mesh)
            else:
                res = batched.finalize(restored, x, y, alive0, cfg,
                                       cls)
        merged = obs_trace.TraceRecorder()
        merged.extend(rec_a.events)
        merged.extend(rec_b.events)
        roundtrace.validate_trace(merged,
                                  {b: res.ledger(b) for b in range(B)})
        common.gate("obs_trace_preempt_resume", True)
        rows.append({
            "bench": f"obs_preempt_resume_{engine}",
            "us_per_call": 0,
            "derived": (f"pre_events={len(rec_a.events)};"
                        f"post_events={len(rec_b.events)};"
                        f"masked={int(ps is not None)}"),
        })
    return rows


def bench_disabled_overhead() -> list:
    """Disabled-tracing instrumentation cost ≤ 2% of a real dispatch.

    Timing the full dispatch twice and subtracting cannot resolve a
    microsecond no-op against millisecond host jitter, so the gate is
    measured in two stable parts: (a) the wrapper delta — instrumented
    ``run_rounds`` vs its exact pre-instrumentation body — on a
    **completed** state, where the jitted while-loop exits immediately
    and the per-call time is pure host dispatch (median over many
    reps); (b) the real dispatch wall time, median over a few full
    runs.  Gate: delta / dispatch < 2%.
    """
    assert not obs_trace.enabled()
    n = 1 << 12
    cls = weak.make_class("thresholds", n=n)
    cfg = BoostConfig(k=K, coreset_size=100, domain_size=n,
                      opt_budget=16)
    x, y, _ = tasks.make_batch(cls, 4, M_THRESH, K, 3, seed0=31)
    keys = jax.random.split(jax.random.key(13), 4)
    state0 = batched.init_state(x, y, keys, cfg, cls=cls)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    done = jax.block_until_ready(
        batched.run_rounds(state0, xj, yj, cfg, cls, n=None))

    def bare(st):
        # run_rounds minus the obs hooks: exactly the
        # pre-instrumentation wrapper body (asarray + schedule canon +
        # the jitted call), so the delta isolates the no-op span cost
        x2, y2 = jnp.asarray(xj), jnp.asarray(yj)
        sched = batched.canon_player_sched(None, x2.shape[0],
                                           x2.shape[1])
        return batched._run_rounds_jit(x2, y2, sched, st,
                                       batched._RUN_FOREVER, cfg, cls)

    def instrumented(st):
        return batched.run_rounds(st, xj, yj, cfg, cls, n=None)

    def median_of(fn, st, iters):
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(st))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    reps = 40 if SMOKE else 120
    # interleave so host-load drift hits both variants alike
    t_bare_done = median_of(bare, done, reps)
    t_inst_done = median_of(instrumented, done, reps)
    t_bare_done = min(t_bare_done, median_of(bare, done, reps))
    t_inst_done = min(t_inst_done, median_of(instrumented, done, reps))
    delta = t_inst_done - t_bare_done
    t_dispatch = median_of(instrumented, state0, OVERHEAD_ITERS)
    rel = delta / t_dispatch
    ok = rel < 0.02
    common.gate("obs_disabled_overhead", ok,
                f"disabled-tracing overhead {rel * 100:.3f}% "
                f"(wrapper delta {delta * 1e6:.1f}µs on a "
                f"{t_dispatch * 1e3:.2f}ms dispatch)")
    return [{
        "bench": "obs_disabled_overhead",
        "us_per_call": round(t_dispatch * 1e6, 1),
        "derived": (f"wrapper_delta_us={delta * 1e6:.1f};"
                    f"overhead_pct={rel * 100:.3f}"),
    }]


def run_all():
    return (bench_ledger_exact() + bench_preempt_resume()
            + bench_disabled_overhead())


if __name__ == "__main__":
    import json

    for row in run_all():
        print(row["bench"], json.dumps(row))
