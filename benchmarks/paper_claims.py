"""Benchmarks 1–6: the paper's communication/error claims.

1. comm_vs_opt   — Theorem 4.1: bits grow LINEARLY in OPT.
2. comm_vs_k     — bits grow ~linearly in k at fixed OPT.
3. comm_vs_m     — bits grow polylog in |S| (naive baseline is linear).
4. comm_vs_d     — bits across classes of different VC dimension.
5. error_guarantee — E_S(f) ≤ OPT on every run (the Thm 2.2 guarantee).
6. lower_bound   — Thm 2.3: on the DISJ-derived hard instances the
   protocol's communication grows Ω(OPT) — matching the upper bound and
   exhibiting the unavoidable linear-in-OPT term.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import learn_once
from repro.core import ledger, lower_bound
from repro.core.types import BoostConfig


def comm_vs_opt():
    rows = []
    for noise in (0, 2, 4, 8, 16):
        r = learn_once("thresholds", m=4096, k=4, noise=noise, seed=noise)
        rows.append({"bench": "comm_vs_opt", "x": r["opt"],
                     "bits": r["bits"], "attempts": r["attempts"],
                     "ok": r["ok"]})
    # derived: linear fit quality of bits vs (opt+1)
    xs = np.array([row["x"] + 1 for row in rows], float)
    ys = np.array([row["bits"] for row in rows], float)
    slope = float(np.polyfit(xs, ys, 1)[0])
    r2 = float(np.corrcoef(xs, ys)[0, 1] ** 2)
    for row in rows:
        row["derived"] = f"slope={slope:.3g};r2={r2:.3f}"
    return rows


def comm_vs_k():
    rows = []
    for k in (2, 4, 8, 16):
        r = learn_once("thresholds", m=4096, k=k, noise=4, seed=1)
        rows.append({"bench": "comm_vs_k", "x": k, "bits": r["bits"],
                     "ok": r["ok"],
                     "derived": f"bits_per_k={r['bits'] / k:.3g}"})
    return rows


def comm_vs_m():
    rows = []
    for m in (1024, 4096, 16384, 65536):
        r = learn_once("thresholds", m=m, k=4, noise=4, seed=2)
        naive = ledger.naive_baseline_bits(m, 1 << 12)
        rows.append({"bench": "comm_vs_m", "x": m, "bits": r["bits"],
                     "naive_bits": naive, "ok": r["ok"],
                     "derived": f"ratio_vs_naive={r['bits'] / naive:.3g}"})
    # the protocol's bits/naive ratio must SHRINK as m grows (polylog vs
    # linear)
    ratios = [row["bits"] / row["naive_bits"] for row in rows]
    assert ratios[-1] < ratios[0], ratios
    return rows


def comm_vs_d():
    rows = []
    for clsname, d in (("thresholds", 1), ("intervals", 2),
                       ("stumps", 4)):
        r = learn_once(clsname, m=2048, k=4, noise=4, seed=3)
        rows.append({"bench": "comm_vs_d", "x": d, "cls": clsname,
                     "bits": r["bits"], "ok": r["ok"],
                     "derived": f"errors={r['errors']};opt={r['opt']}"})
    return rows


def error_guarantee():
    rows = []
    fails = 0
    total = 0
    for clsname in ("thresholds", "intervals", "singletons"):
        for noise in (0, 4, 12):
            for seed in (0, 1):
                r = learn_once(clsname, m=2048, k=4, noise=noise,
                               seed=seed)
                total += 1
                fails += 0 if r["ok"] else 1
                rows.append({"bench": "error_guarantee", "cls": clsname,
                             "noise": noise, "seed": seed,
                             "opt": r["opt"], "errors": r["errors"],
                             "ok": r["ok"]})
    for row in rows:
        row["derived"] = f"guarantee_rate={(total - fails) / total:.3f}"
    assert fails == 0, f"{fails}/{total} guarantee violations"
    return rows


def lower_bound_bench():
    """Communication on DISJ-hard instances grows with r ≈ OPT/2 —
    the Ω(T(n)) direction, and the protocol decides DISJ correctly."""
    rows = []
    rng = np.random.default_rng(0)
    n = 1 << 12
    for r in (8, 64, 512):
        cfg = BoostConfig(k=2, coreset_size=400, domain_size=n,
                          opt_budget=3 * r + 8)
        bits, correct = [], 0
        for disjoint in (True, False):
            x, y = lower_bound.random_disj_instance(
                rng, r=max(r, 2), weight=max(r // 2, 1),
                disjoint=disjoint)
            out = lower_bound.solve_disjointness(x, y, n, cfg, seed=r)
            bits.append(out.total_bits)
            correct += int(out.disjoint_decided == disjoint)
        rows.append({"bench": "lower_bound", "x": r,
                     "bits": int(np.mean(bits)),
                     "decisions_correct": correct,
                     "derived": f"correct={correct}/2"})
    assert all(row["decisions_correct"] == 2 for row in rows)
    # growth: bits at r=16 must exceed bits at r=2 (Ω(T(n)) term)
    assert rows[-1]["bits"] > rows[0]["bits"]
    return rows
