"""Scenario suite throughput: host loop vs batched vs mesh-sharded.

For each adversarial noise scenario (core/scenarios.py) the same batch
of tasks runs through the three execution forms of AccuratelyClassify:

* host loop   — ``classify.run_accurately_classify`` per task,
* batched     — ``core/batched.py`` (one jitted dispatch),
* sharded     — ``core/sharded_batched.py`` over the host's ``players``
  mesh (real collectives; 1 device ⇒ the same program with trivial
  transport — run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for a real
  mesh).

All three produce bit-identical protocol outputs (asserted), so the
rows compare pure serving throughput plus the communication the ledger
charges and the machine bytes the sharded engine's collectives moved;
``validate_ledger`` runs on every sharded lane so a row only emits if
the Theorem 4.1 accounting matches the measured payloads.

Methodology matches benchmarks/batched_classify.py: all paths fully
warmed, then timed in steady state.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched, classify, scenarios, sharded_batched, weak
from repro.core.types import BoostConfig

N = 1 << 12
SCENARIOS = ("uniform", "targeted_heavy", "byzantine", "boundary",
             "drift")


def _host_loop(x, y, keys, cfg, cls):
    out = []
    for b in range(x.shape[0]):
        try:
            out.append(classify.run_accurately_classify(
                jnp.asarray(x[b]), jnp.asarray(y[b]), keys[b], cfg, cls))
        except RuntimeError:              # opt_budget exhausted: the
            out.append(None)              # engines flag it as ok=False
    return out


def bench_scenario(name, B=8, m=256, k=4, noise=4, coreset=24, seed0=7):
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=k, coreset_size=coreset, domain_size=N,
                      opt_budget=32)
    spec = scenarios.ScenarioSpec(name=name, noise=noise)
    x, y, ts = scenarios.make_scenario_batch(cls, B, m, k, spec,
                                             seed0=seed0)
    keys = jax.random.split(jax.random.key(0), B)
    mesh = sharded_batched.make_players_mesh(k)

    # fully warm all three paths, then time steady state
    _host_loop(x, y, keys, cfg, cls)
    batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    sharded_batched.run_accurately_classify_sharded(x, y, keys, cfg,
                                                    cls, mesh=mesh)

    t0 = time.time()
    host_out = _host_loop(x, y, keys, cfg, cls)
    t_host = time.time() - t0
    t0 = time.time()
    bat_out = batched.run_accurately_classify_batched(x, y, keys, cfg,
                                                      cls)
    t_bat = time.time() - t0
    t0 = time.time()
    sh_out = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, cfg, cls, mesh=mesh)
    t_sh = time.time() - t0

    ok = [bool(bat_out.ok[b]) and bool(sh_out.ok[b])
          and host_out[b] is not None for b in range(B)]
    agree = all(
        host_out[b].attempts == int(bat_out.attempts[b])
        == int(sh_out.attempts[b])
        and host_out[b].ledger.total_bits
        == bat_out.ledger(b).total_bits == sh_out.ledger(b).total_bits
        and np.array_equal(
            np.asarray(host_out[b].hypotheses)[:host_out[b].rounds],
            sh_out.hypotheses[b][:int(sh_out.rounds[b])])
        for b in range(B) if ok[b])
    assert agree and np.array_equal(bat_out.disputed, sh_out.disputed), \
        f"engines disagree on scenario {name}"   # no row without parity
    for b in range(B):
        if ok[b]:
            sh_out.validate_ledger(b)        # ledger ≡ measured payload
    reports = [scenarios.scenario_report(ts[b], sh_out, b)
               for b in range(B) if ok[b]]
    assert reports, f"every lane exhausted opt_budget on {name}"
    return {
        "scenario": name, "B": B, "m": m, "k": k,
        # what the adversary actually planted (byzantine flips a whole
        # shard of m/k labels whatever the --noise knob says)
        "noise": max(int(t.noise_count) for t in ts),
        "host_tasks_per_s": round(B / max(t_host, 1e-9), 2),
        "batched_tasks_per_s": round(B / max(t_bat, 1e-9), 2),
        "sharded_tasks_per_s": round(B / max(t_sh, 1e-9), 2),
        "agree": agree,
        "ok": sum(ok),
        "mesh_devices": int(sh_out.mesh_devices),
        "bits_mean": int(sum(r["bits"] for r in reports) / len(reports)),
        "collective_bytes_mean": int(sh_out.wire_bytes.mean()),
        "guarantee_ok": all(r["guarantee_ok"] for r in reports),
        "ledger_vs_payload": "validated",
    }


def run_all():
    rows = []
    for name in SCENARIOS:
        r = bench_scenario(name)
        rows.append({
            "bench": f"sharded_scenarios_{name}",
            "us_per_call": round(1e6 / max(r["sharded_tasks_per_s"],
                                           1e-9), 1),
            "derived": (f"host_tps={r['host_tasks_per_s']};"
                        f"batched_tps={r['batched_tasks_per_s']};"
                        f"sharded_tps={r['sharded_tasks_per_s']};"
                        f"bits={r['bits_mean']};"
                        f"agree={r['agree']};"
                        f"guarantee_ok={r['guarantee_ok']}"),
            **r,
        })
    return rows


if __name__ == "__main__":
    import json

    for row in run_all():
        print(row["bench"], json.dumps(row))
