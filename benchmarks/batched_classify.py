"""Batched AccuratelyClassify throughput: device-resident engine vs the
host-driven reference loop.

The reference path dispatches one BoostAttempt per attempt per task and
round-trips to numpy for every quarantine — O(B · attempts) dispatches
(and a recompile for every new ⌈6·log2 m_alive⌉ the quarantine
produces).  The batched engine (core/batched.py) runs the same protocol
for all B tasks in ONE jitted program with a dynamic round bound.

Methodology: both paths are FULLY warmed first (the host loop runs the
whole batch once so every num_rounds variant it needs is compiled — the
strictest possible baseline), then timed in steady state.  Outputs are
bit-identical between the paths (tests/test_batched.py), so the ratio
is pure serving throughput.

Acceptance target (ISSUE 1): ≥ 5× tasks/sec at B = 32 on CPU — met by
the primary m=256 row (the multi-tenant serving shape; larger m rows
are reported for scaling context and are dominated by XLA:CPU's
row-serial cumsum, which both paths pay per element).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import batched, classify, tasks, weak
from repro.core.types import BoostConfig

N = 1 << 12
# CI's bench-smoke job (REPRO_BENCH_SMOKE=1) keeps the parity gate but
# shrinks the timed grid — the host-loop baseline dominates wall-clock
SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _host_loop(x, y, keys, cfg, cls):
    return [classify.run_accurately_classify(
        jnp.asarray(x[b]), jnp.asarray(y[b]), keys[b], cfg, cls)
        for b in range(x.shape[0])]


def bench_once(B=32, m=256, k=4, noise=2, coreset=100, seed0=7):
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=k, coreset_size=coreset, domain_size=N,
                      opt_budget=16)
    x, y, _ = tasks.make_batch(cls, B, m, k, noise, seed0=seed0)
    keys = jax.random.split(jax.random.key(0), B)

    # fully warm BOTH paths (every jit variant compiled), then time
    _host_loop(x, y, keys, cfg, cls)
    batched.run_accurately_classify_batched(x, y, keys, cfg, cls)

    t0 = time.time()
    host_out = _host_loop(x, y, keys, cfg, cls)
    t_host = time.time() - t0

    t0 = time.time()
    bat_out = batched.run_accurately_classify_batched(x, y, keys, cfg,
                                                      cls)
    t_bat = time.time() - t0

    # parity gate: the two paths must agree on the protocol outcome
    # (run.py turns the raised AssertionError into a FAILED row + exit 1
    # AND checks the registry recorded this gate as executed)
    agree = all(
        host_out[b].attempts == int(bat_out.attempts[b])
        and host_out[b].rounds == int(bat_out.rounds[b])
        for b in range(B))
    common.gate("batched_host_parity", agree,
                "batched engine diverged from the host loop")
    return {
        "B": B, "m": m, "k": k, "noise": noise, "coreset": coreset,
        "host_tasks_per_s": round(B / max(t_host, 1e-9), 2),
        "batched_tasks_per_s": round(B / max(t_bat, 1e-9), 2),
        "speedup": round(t_host / max(t_bat, 1e-9), 2),
        "agree": agree,
    }


def run_all():
    rows = []
    grid = ((8, 256),) if SMOKE else ((32, 256), (32, 512), (8, 256))
    for B, m in grid:
        r = bench_once(B=B, m=m)
        rows.append({
            "bench": f"batched_classify_B{B}_m{m}",
            "us_per_call": round(1e6 / max(r["batched_tasks_per_s"],
                                           1e-9), 1),
            "derived": (f"speedup={r['speedup']};agree={r['agree']};"
                        f"host_tps={r['host_tasks_per_s']};"
                        f"batched_tps={r['batched_tasks_per_s']}"),
            **r,
        })
    return rows


if __name__ == "__main__":
    import json

    for row in run_all():
        print(row["bench"], json.dumps(row))
