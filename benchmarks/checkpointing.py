"""Checkpointing benchmark: save/restore latency, incremental bytes,
preempt/resume throughput.

BENCH_1.json recorded the preempt/resume path at 5.9 tasks/sec while
the dropout/flaky fault paths ran at ~200 — a ~30x stall concentrated
in synchronous full-state serialization and a resume that re-ran
engine init just to build a restore template.  This suite pins the
rebuilt path (ckpt/msgpack_ckpt + launch/scheduler):

* **save latency** — synchronous full save vs the async writer's
  caller-visible cost (device→host copy + flatten + enqueue), per
  state size;
* **restore latency** — template-free restore (checkpoint manifest
  only) vs the legacy template path (engine init + ``like=`` load),
  gated bit-identical (``ckpt_template_free_parity``);
* **incremental bytes** — a round-sliced checkpoint chain vs full
  resaves of the same states, gated strictly smaller
  (``ckpt_incremental_bytes``) and chain-restore ≡ full-restore;
* **preempt/resume throughput** — the fault_injection preempt config
  replayed per engine on a warmed scheduler, with EVERY completion
  gated bit-identical to its uninterrupted ``one_shot`` run
  (``ckpt_resume_parity``) — the correctness bar the speedup must not
  move.

``REPRO_BENCH_SMOKE=1`` (the CI bench-smoke job) shrinks the scales;
the gates are identical at both scales.
"""

from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from benchmarks import common
from repro.ckpt import msgpack_ckpt
from repro.core import batched, tasks, weak
from repro.core.types import BoostConfig
from repro.launch import scheduler as S

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
N_REQUESTS = 12 if SMOKE else 48
MLOCS = (64,) if SMOKE else (64, 256)
CHAIN_SLICES = 4 if SMOKE else 8


def _engine_state(mloc: int, B: int = 4, k: int = 4, rounds: int = 3):
    """A mid-protocol batched engine state of the given shard size."""
    cls = weak.Thresholds(n=1 << 12)
    cfg = BoostConfig(k=k, coreset_size=64, domain_size=1 << 12,
                      opt_budget=8)
    x, y, _ = tasks.make_batch(cls, B, k * mloc, k, 2, seed0=3)
    keys = jax.random.split(jax.random.key(1), B)
    state = batched.init_state(x, y, keys, cfg)
    state = batched.run_rounds(state, x, y, cfg, cls, n=rounds)
    return jax.block_until_ready(state), (x, y, keys, cfg, cls)


def _tree_bytes(tree) -> int:
    return sum(np.asarray(leaf).nbytes
               for leaf in jax.tree_util.tree_leaves(tree))


def bench_save_latency() -> list:
    rows = []
    for mloc in MLOCS:
        state, _ = _engine_state(mloc)
        nbytes = _tree_bytes(state)
        with tempfile.TemporaryDirectory() as d:
            sync_path = os.path.join(d, "sync.msgpack")
            t0 = time.perf_counter()
            iters = 5
            for _ in range(iters):
                msgpack_ckpt.save_pytree(sync_path, jax.device_get(state))
            sync_s = (time.perf_counter() - t0) / iters
            writer = msgpack_ckpt.AsyncCheckpointer(max_pending=2)
            writer.save(os.path.join(d, "w.msgpack"), state)  # warm thread
            writer.wait()
            t0 = time.perf_counter()
            for i in range(iters):
                writer.save(os.path.join(d, f"a{i}.msgpack"), state)
            async_caller_s = (time.perf_counter() - t0) / iters
            writer.wait()
            writer.close()
        rows.append({
            "bench": f"ckpt_save_mloc{mloc}",
            "us_per_call": round(1e6 * async_caller_s, 1),
            "derived": (f"sync_us={round(1e6 * sync_s, 1)};"
                        f"async_caller_us={round(1e6 * async_caller_s, 1)};"
                        f"state_kib={round(nbytes / 1024, 1)}"),
            "sync_us": round(1e6 * sync_s, 1),
            "state_bytes": nbytes,
        })
    return rows


def bench_restore_latency() -> list:
    rows = []
    for mloc in MLOCS:
        state, (x, y, keys, cfg, cls) = _engine_state(mloc)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "state.msgpack")
            msgpack_ckpt.save_pytree(path, jax.device_get(state),
                                     treedef=batched.STATE_TREEDEF)
            iters = 5
            t0 = time.perf_counter()
            for _ in range(iters):
                free, _meta = msgpack_ckpt.restore_pytree(path)
            free_s = (time.perf_counter() - t0) / iters
            t0 = time.perf_counter()
            for _ in range(iters):
                template = batched.init_state(x, y, keys, cfg)
                legacy, _meta = msgpack_ckpt.load_pytree(path,
                                                         like=template)
            legacy_s = (time.perf_counter() - t0) / iters
        assert isinstance(free, batched.StepState)
        same = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(free),
                            jax.tree_util.tree_leaves(legacy)))
        common.gate("ckpt_template_free_parity", same,
                    f"mloc={mloc}: template-free restore diverged from "
                    f"the template path")
        rows.append({
            "bench": f"ckpt_restore_mloc{mloc}",
            "us_per_call": round(1e6 * free_s, 1),
            "derived": (f"template_free_us={round(1e6 * free_s, 1)};"
                        f"template_us={round(1e6 * legacy_s, 1)};"
                        f"speedup={round(legacy_s / max(free_s, 1e-9), 1)}x"),
            "template_us": round(1e6 * legacy_s, 1),
        })
    return rows


def bench_incremental() -> dict:
    """A round-sliced checkpoint chain: every slice saves only the
    leaves that changed (MW weights, counters, coreset buffers churn;
    quarantine masks and ensemble buffers mostly don't) — total bytes
    must be strictly below full resaves of the same states."""
    state, (x, y, keys, cfg, cls) = _engine_state(MLOCS[-1], rounds=1)
    with tempfile.TemporaryDirectory() as d:
        full_path = os.path.join(d, "chain_000.msgpack")
        hashes = msgpack_ckpt.save_pytree(
            full_path, jax.device_get(state),
            treedef=batched.STATE_TREEDEF)
        inc_bytes = os.path.getsize(full_path)
        full_bytes = inc_bytes
        prev = full_path
        tip = full_path
        for i in range(1, CHAIN_SLICES):
            state = batched.run_rounds(state, x, y, cfg, cls, n=2)
            host = jax.device_get(state)
            tip = os.path.join(d, f"chain_{i:03d}.msgpack")
            hashes = msgpack_ckpt.save_pytree(
                tip, host, treedef=batched.STATE_TREEDEF,
                base=prev, base_hashes=hashes)
            inc_bytes += os.path.getsize(tip)
            ref = os.path.join(d, "full.msgpack")
            msgpack_ckpt.save_pytree(ref, host,
                                     treedef=batched.STATE_TREEDEF)
            full_bytes += os.path.getsize(ref)
            prev = tip
        restored, _ = msgpack_ckpt.restore_pytree(tip)
        same = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(restored),
                            jax.tree_util.tree_leaves(state)))
    common.gate(
        "ckpt_incremental_bytes", same and inc_bytes < full_bytes,
        f"chain {inc_bytes}B vs full {full_bytes}B, restore_ok={same}")
    return {
        "bench": "ckpt_incremental",
        "us_per_call": 0.0,
        "derived": (f"chain_kib={round(inc_bytes / 1024, 1)};"
                    f"full_kib={round(full_bytes / 1024, 1)};"
                    f"saved_pct="
                    f"{round(100 * (1 - inc_bytes / full_bytes), 1)};"
                    f"slices={CHAIN_SLICES}"),
        "chain_bytes": inc_bytes,
        "full_bytes": full_bytes,
    }


def bench_preempt_resume(engine: str) -> dict:
    """The fault_injection preempt config on a warmed scheduler.

    ``preempt={0: 3, 1: 4}``: dispatch 0 is cut off after 3 rounds and
    its RESUME (dispatch 1) after 4 more — exercising a full snapshot,
    an incremental chained snapshot, and two template-free restores.
    Every completion is compared bit-identically to its ``one_shot``
    run (the resume-parity gate).
    """
    shapes = [{"m": 64, "k": 2, "noise": 1},
              {"m": 128, "k": 2, "noise": 2}]
    lattice = S.BucketLattice(b_sizes=(2, 4), mloc_sizes=(32, 64))
    n = N_REQUESTS if engine == "batched" else max(N_REQUESTS // 2, 6)
    arrivals = S.poisson_trace(n, rate_per_s=500.0, seed=5)
    reqs = S.make_request_stream(n, arrivals, shapes, seed0=11,
                                 engine=engine, coreset_size=48,
                                 opt_budget=6)
    with tempfile.TemporaryDirectory() as ck:
        sched = S.BoostScheduler(lattice=lattice, ckpt_dir=ck,
                                 preempt={0: 3, 1: 4})
        sched.warm(reqs, b_sizes=lattice.b_sizes + (1,))
        t0 = time.perf_counter()
        done = sched.run_stream(reqs)
        wall = time.perf_counter() - t0
        assert len(done) == n
        assert sched.stats.preemptions == 2
        assert sched.stats.resumes == 2
        ok = True
        for c in done:
            one = sched.one_shot(c.request)
            ok = ok and np.array_equal(c.result.hypotheses[c.lane],
                                       one.hypotheses[0])
            ok = ok and np.array_equal(c.result.disputed[c.lane],
                                       one.disputed[0])
            if c.ok:
                ok = ok and (c.per_task().ledger.total_bits
                             == one.per_task(0).ledger.total_bits)
        common.gate("ckpt_resume_parity", ok,
                    f"{engine}: a resumed completion diverged from "
                    f"one_shot")
        resumed = [c for c in done if c.resumed]
    return {
        "bench": f"ckpt_preempt_resume_{engine}",
        "us_per_call": round(1e6 * wall / n, 1),
        "derived": (f"tps={round(n / max(wall, 1e-9), 1)};"
                    f"preemptions={sched.stats.preemptions};"
                    f"resumed_requests={len(resumed)};"
                    f"parity_checked={len(done)}"),
        "tasks_per_s": round(n / max(wall, 1e-9), 2),
        "preemptions": sched.stats.preemptions,
        "resumes": sched.stats.resumes,
    }


def run_all():
    rows = []
    rows += bench_save_latency()
    rows += bench_restore_latency()
    rows.append(bench_incremental())
    rows.append(bench_preempt_resume("batched"))
    rows.append(bench_preempt_resume("sharded"))
    return rows


if __name__ == "__main__":
    import json

    for row in run_all():
        print(row["bench"], json.dumps(row))
