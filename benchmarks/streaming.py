"""Streaming tier: memory roofline + throughput for million-point tasks.

Four registered gates (run.py checks each executed — docs/streaming.md
documents the tier they pin):

* **streaming_small_m_parity** — at small m, `chunk_size` on vs off is
  BITWISE invisible across all three engines (host loop, batched,
  sharded): every hypothesis, round count, quarantine mask and ledger
  bit is equal.  This is the tier's core contract: the chunked sort
  order is the stable argsort, exactly (`core/streaming.sort_order`).
* **streaming_hist_parity** — chunked histogram accumulation (ref and
  interpreted-Pallas routing, batched and unbatched, non-dividing tile
  sizes) is bitwise equal to the monolithic kernels on dyadic weights.
* **streaming_peak_memory** — XLA's static buffer assignment
  (`compiled.memory_analysis()`) for the m-point histogram build: the
  chunked program's temp bytes must undercut the monolithic program's
  at the largest m.  Static analysis, not a high-water probe: the gate
  holds even where actually executing the monolithic program (a ≥ 1 GB
  one-hot at m = 10^6) would be irresponsible.
* **streaming_sketch_epsilon** — the bounded-memory quantile sketch's
  SELF-ACCOUNTED bound is honest (measured sup-loss approximation
  error ≤ the bound the sketch claims) and lands ≤ the paper's
  ε = 1/100 at the bench's cap — the pinned ε-approximation guarantee.

Rows: per m ∈ {10^4, 10^5, 10^6}, peak temp bytes (monolithic vs
chunked, static) and points/sec for the chunked histogram build and
the sketch build; plus chunked-vs-monolithic end-to-end tasks/sec on
the batched engine at the parity m.  ``REPRO_BENCH_SMOKE=1`` shrinks
the grid (CI).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import batched, classify, sharded_batched, streaming, tasks
from repro.core import approximation, weak
from repro.core.types import EPS_APPROX, BoostConfig
from repro.data import chunks as data_chunks
from repro.kernels.histogram import ops as hist_ops

SMOKE = os.environ.get("REPRO_BENCH_SMOKE") == "1"
M_GRID = (2_000, 10_000) if SMOKE else (10_000, 100_000, 1_000_000)
CHUNK = 1_024 if SMOKE else 16_384       # point tile (sort + histogram)
CAP = 8_192 if SMOKE else 32_768         # sketch capacity
CORESET = 1_024                          # sketch-coreset slots (ε gate)
N = 1 << 16                              # integer-track domain
F, Q, NODES = 8, 32, 4                   # histogram build shape
PARITY_M = 2_048                         # small-m three-engine parity


# ---------------------------------------------------------------------------
# gates
# ---------------------------------------------------------------------------

def gate_small_m_parity() -> None:
    """chunk_size on/off is bitwise invisible to all three engines."""
    cls = weak.Thresholds(n=N)
    B, k = 2, 4
    x, y, _ = tasks.make_batch(cls, B, PARITY_M, k, 5, seed0=3)
    keys = jax.random.split(jax.random.key(9), B)
    key_host = jax.random.key(9)

    def run(chunk):
        cfg = BoostConfig(k=k, coreset_size=64, domain_size=N,
                          opt_budget=32, chunk_size=chunk)
        host = classify.run_accurately_classify(
            jnp.asarray(x[0]), jnp.asarray(y[0]), key_host, cfg, cls)
        bat = batched.run_accurately_classify_batched(x, y, keys, cfg,
                                                      cls)
        shd = sharded_batched.run_accurately_classify_sharded(
            x, y, keys, cfg, cls)
        return host, bat, shd

    mono, chk = run(None), run(CHUNK)
    for name, a, b in (("host", mono[0], chk[0]),
                       ("batched", mono[1], chk[1]),
                       ("sharded", mono[2], chk[2])):
        for field in ("hypotheses", "rounds") if name == "host" else (
                "hypotheses", "rounds", "ok", "attempts", "disputed"):
            va = np.asarray(getattr(a, field))
            vb = np.asarray(getattr(b, field))
            common.gate("streaming_small_m_parity",
                        np.array_equal(va, vb),
                        f"{name}.{field} differs chunked vs monolithic")
    for b_i in range(B):
        common.gate("streaming_small_m_parity",
                    mono[1].ledger(b_i).total_bits
                    == chk[1].ledger(b_i).total_bits,
                    f"batched ledger differs at task {b_i}")


def gate_hist_parity() -> None:
    """Chunked accumulation ≡ monolithic kernels, bitwise, on dyadic
    weights — ref and interpreted-Pallas routing, (un)batched, ragged
    tiles."""
    rng = np.random.default_rng(0)
    interp = jax.default_backend() != "tpu"
    for c, tile in ((257, 64), (512, 128), (130, 200)):
        x = jnp.asarray((rng.integers(0, Q, (c, F)) + 0.5) / Q,
                        jnp.float32)
        w = jnp.asarray(rng.integers(0, 256, (NODES, c)) / 256.0,
                        jnp.float32)
        wy = w * jnp.asarray(rng.choice([-1.0, 1.0], (NODES, c)),
                             jnp.float32)
        ref = hist_ops.node_histograms_ref(x, w, wy, Q)
        for kw in ({"interpret": None}, {"interpret": interp}):
            got = hist_ops.node_histograms(x, w, wy, Q,
                                           chunk_size=tile, **kw)
            common.gate(
                "streaming_hist_parity",
                all(np.array_equal(np.asarray(a), np.asarray(b))
                    for a, b in zip(got, ref)),
                f"chunked != monolithic at c={c} tile={tile} {kw}")
        # batched (leading task axis) form
        xb, wb, wyb = x[None], w[None], wy[None]
        refb = hist_ops.node_histograms_ref(xb, wb, wyb, Q)
        gotb = hist_ops.node_histograms_chunked_ref(xb, wb, wyb, Q, tile)
        common.gate(
            "streaming_hist_parity",
            all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(gotb, refb)),
            f"batched chunked ref != monolithic at c={c} tile={tile}")


def _hist_args(m: int, rng):
    x = jnp.asarray((rng.integers(0, Q, (m, F)) + 0.5) / Q, jnp.float32)
    w = jnp.asarray(rng.integers(0, 256, (NODES, m)) / 256.0, jnp.float32)
    wy = w * jnp.asarray(rng.choice([-1.0, 1.0], (NODES, m)), jnp.float32)
    return x, w, wy


def _static_peak(fn, *args) -> int:
    """Temp-buffer bytes of the compiled program — XLA's static buffer
    assignment, no execution needed (how the roofline gate can price
    the 1 GB monolithic one-hot without allocating it)."""
    mem = jax.jit(fn).lower(*args).compile().memory_analysis()
    return int(mem.temp_size_in_bytes)


def bench_roofline() -> list:
    rows = []
    rng = np.random.default_rng(1)
    mono_peak = chunk_peak = 0
    for m in M_GRID:
        x, w, wy = _hist_args(m, rng)
        mono_peak = _static_peak(
            lambda a, b, c: hist_ops.node_histograms_ref(a, b, c, Q),
            x, w, wy)
        chunk_peak = _static_peak(
            lambda a, b, c: hist_ops.node_histograms(a, b, c, Q,
                                                     chunk_size=CHUNK),
            x, w, wy)
        hist = jax.jit(lambda a, b, c: hist_ops.node_histograms(
            a, b, c, Q, chunk_size=CHUNK))
        us = common.timeit(hist, x, w, wy)
        rows.append({
            "bench": "streaming_hist", "m": m,
            "us_per_call": round(us, 1),
            "mono_temp_bytes": mono_peak,
            "chunk_temp_bytes": chunk_peak,
            "derived": (f"m={m};pts_per_s={round(m / us * 1e6):,};"
                        f"mono_temp={mono_peak:,};"
                        f"chunk_temp={chunk_peak:,};chunk={CHUNK}"),
        })
    # gate at the largest m: the chunked program must undercut the
    # monolithic static peak (the whole point of the tier)
    common.gate("streaming_peak_memory", chunk_peak < mono_peak,
                f"chunked temp {chunk_peak:,} ≥ monolithic "
                f"{mono_peak:,} at m={M_GRID[-1]}")
    return rows


def bench_sketch() -> list:
    rows = []
    for m in M_GRID:
        rng = np.random.default_rng(m)
        x = rng.integers(0, N, size=m).astype(np.int32)
        y = rng.choice(np.array([-1, 1], np.int8), size=m)
        hits = rng.integers(0, 13, size=m).astype(np.int32)
        alive = np.ones(m, bool)
        w = np.asarray(streaming.sketch_weights(jnp.asarray(hits),
                                                jnp.asarray(alive)))

        def build():
            feed = data_chunks.iter_shard_chunks(x, y, w, CHUNK)
            return streaming.build_sketch(feed, CAP, n=N)

        sk = build()                     # warm/compile
        t0 = time.perf_counter()
        sk = build()
        jax.block_until_ready(sk.x)
        wall = time.perf_counter() - t0
        idx = streaming.sketch_coreset(sk, CORESET)
        bound = float(streaming.coreset_bound(sk, CORESET))
        theta = np.arange(0, N + 1, 256, dtype=np.int32)
        grid = jnp.asarray(np.stack(
            [np.concatenate([theta, theta]),
             np.concatenate([np.ones_like(theta),
                             -np.ones_like(theta)])], axis=1))

        def predict(params, pts):
            return (jnp.where(pts[None, :] <= params[:, 0:1], 1, -1)
                    * params[:, 1:2])

        measured = float(approximation.approximation_error(
            idx, jnp.asarray(x), jnp.asarray(y), jnp.asarray(hits),
            jnp.asarray(alive), predict, grid))
        common.gate("streaming_sketch_epsilon",
                    measured <= bound <= EPS_APPROX,
                    f"m={m}: measured {measured:.5f} ≤ bound "
                    f"{bound:.5f} ≤ ε={EPS_APPROX} violated")
        rows.append({
            "bench": "streaming_sketch", "m": m,
            "us_per_call": round(wall * 1e6, 1),
            "derived": (f"m={m};pts_per_s={round(m / wall):,};"
                        f"cap={CAP};measured={measured:.5f};"
                        f"bound={bound:.5f};eps={EPS_APPROX}"),
        })
    return rows


def bench_engine_throughput() -> list:
    """End-to-end chunked vs monolithic batched protocol at parity m."""
    cls = weak.Thresholds(n=N)
    B, k = 2, 4
    x, y, _ = tasks.make_batch(cls, B, PARITY_M, k, 5, seed0=3)
    keys = jax.random.split(jax.random.key(9), B)
    rows = []
    for label, chunk in (("monolithic", None), ("chunked", CHUNK)):
        cfg = BoostConfig(k=k, coreset_size=64, domain_size=N,
                          opt_budget=32, chunk_size=chunk)
        run = batched.run_accurately_classify_batched
        run(x, y, keys, cfg, cls)        # warm
        t0 = time.perf_counter()
        run(x, y, keys, cfg, cls)
        wall = time.perf_counter() - t0
        rows.append({
            "bench": f"streaming_engine_{label}", "m": PARITY_M,
            "us_per_call": round(wall * 1e6, 1),
            "derived": (f"tasks_per_s={round(B / wall, 1)};"
                        f"chunk={chunk};m={PARITY_M}"),
        })
    return rows


def run_all() -> list:
    gate_small_m_parity()
    gate_hist_parity()
    rows = bench_roofline()
    rows += bench_sketch()
    rows += bench_engine_throughput()
    return rows
