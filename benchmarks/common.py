"""Shared helpers for the benchmark harness.

The paper has no empirical tables; its "tables" are theorem statements.
Each benchmark module validates one claim and returns rows of
(name, value, derived) that run.py emits as CSV and EXPERIMENTS.md
ingests.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classify, tasks, weak
from repro.core.types import BoostConfig

N_DEFAULT = 1 << 12


def learn_once(clsname: str, m: int, k: int, noise: int, seed: int,
               n: int = N_DEFAULT, coreset: int = 400,
               num_features: int = 8):
    cls = weak.make_class(clsname, n=n, num_features=num_features)
    cfg = BoostConfig(
        k=k, coreset_size=coreset, domain_size=n, opt_budget=96,
        deterministic_coreset=clsname != "stumps")
    task = tasks.make_task(cls, m=m, k=k, noise=noise, seed=seed)
    opt = tasks.true_opt(task)
    t0 = time.time()
    f, res = classify.learn(jnp.asarray(task.x), jnp.asarray(task.y),
                            jax.random.key(seed), cfg, cls)
    wall = time.time() - t0
    errs = int(weak.empirical_errors(f(jnp.asarray(task.flat_x)),
                                     jnp.asarray(task.flat_y)))
    return {
        "class": clsname, "m": m, "k": k, "noise": noise, "opt": opt,
        "errors": errs, "ok": errs <= opt, "attempts": res.attempts,
        "bits": res.ledger.total_bits, "wall_s": round(wall, 2),
        "cfg": cfg, "cls": cls,
    }


def timeit(fn, *args, iters: int = 3, **kw):
    fn(*args, **kw)                      # compile/warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6   # µs
