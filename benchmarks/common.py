"""Shared helpers for the benchmark harness.

The paper has no empirical tables; its "tables" are theorem statements.
Each benchmark module validates one claim and returns rows of
(name, value, derived) that run.py emits as CSV and EXPERIMENTS.md
ingests.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classify, tasks, weak
from repro.core.types import BoostConfig

N_DEFAULT = 1 << 12


def learn_once(clsname: str, m: int, k: int, noise: int, seed: int,
               n: int = N_DEFAULT, coreset: int = 400,
               num_features: int = 8, tree_depth: int = 2,
               tree_bins: int = 32):
    cls = weak.make_class(clsname, n=n, num_features=num_features,
                          tree_depth=tree_depth, tree_bins=tree_bins)
    cfg = BoostConfig(
        k=k, coreset_size=coreset, domain_size=n, opt_budget=96,
        deterministic_coreset=not weak.needs_features(cls))
    task = tasks.make_task(cls, m=m, k=k, noise=noise, seed=seed)
    opt = tasks.true_opt(task)
    t0 = time.time()
    f, res = classify.learn(jnp.asarray(task.x), jnp.asarray(task.y),
                            jax.random.key(seed), cfg, cls)
    wall = time.time() - t0
    errs = int(weak.empirical_errors(f(jnp.asarray(task.flat_x)),
                                     jnp.asarray(task.flat_y)))
    return {
        "class": clsname, "m": m, "k": k, "noise": noise, "opt": opt,
        "errors": errs, "ok": errs <= opt, "attempts": res.attempts,
        "bits": res.ledger.total_bits, "wall_s": round(wall, 2),
        "cfg": cfg, "cls": cls,
    }


def timeit(fn, *args, iters: int = 3, **kw):
    fn(*args, **kw)                      # compile/warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6   # µs


# ---------------------------------------------------------------------------
# Gate registry.  A "gate" is a hard correctness assertion inside a
# benchmark (parity, guarantee, ledger≡payload).  Asserting inline is
# necessary but not sufficient: a gate that silently stops RUNNING
# (suite renamed, registration dropped) passes by absence.  Benches
# therefore record every gate here, and benchmarks/run.py checks the
# executed set against its per-suite EXPECTED_GATES declaration — a
# registered-but-not-executed gate fails the run, and the executed
# list lands in GITHUB_STEP_SUMMARY for the CI record.
# ---------------------------------------------------------------------------

GATES_RUN: dict = {}


def reset_gates() -> None:
    GATES_RUN.clear()


def gate(name: str, ok, detail: str = ""):
    """Record + enforce a named correctness gate.

    Recording accumulates with AND: gates re-checked in loops (one
    call per shape/adversary/class) stay failed once any iteration
    fails — run.py's registry check must hold even under ``python -O``
    where the assert below is stripped.
    """
    GATES_RUN[name] = GATES_RUN.get(name, True) and bool(ok)
    assert ok, f"gate {name} failed: {detail}"
