"""Benchmark 12 (paper §6 extension): finite classes need no OPT promise.

The direct finite-class protocol pays k·|H|·log m bits REGARDLESS of
OPT, while AccuratelyClassify pays per quarantined point — quantifying
the paper's closing observation about which classes escape the
linear-in-OPT dependence.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import learn_once
from repro.core import finite, weak


def run_all():
    n = 1 << 12
    cls = weak.Thresholds(n=n)
    grid = jnp.asarray([[2.0, t, t, s] for t in range(0, n, 16)
                        for s in (1.0, -1.0)], jnp.float32)
    rng = np.random.default_rng(7)
    rows = []
    for noise in (0, 16, 256):
        x = rng.integers(0, n, 4096).astype(np.int32)
        y = np.where(x >= n // 3, 1, -1).astype(np.int8)
        flip = rng.choice(4096, size=noise, replace=False)
        y[flip] = -y[flip]
        xk = jnp.asarray(x.reshape(4, -1))
        yk = jnp.asarray(y.reshape(4, -1))
        res = finite.learn_finite(xk, yk, grid, cls)
        rows.append({
            "bench": "finite_class", "noise": noise,
            "finite_bits": res.total_bits,
            "finite_errors": res.errors,
            "derived": f"|H|={grid.shape[0]};bits_opt_independent=True",
        })
    # the boosting route for comparison at small noise
    b = learn_once("thresholds", m=4096, k=4, noise=8, seed=7, n=n)
    rows.append({"bench": "finite_class", "noise": 8,
                 "boosting_bits": b["bits"], "boosting_errors": b["errors"],
                 "derived": "boosting reference (promise OPT small)"})
    assert rows[0]["finite_bits"] == rows[2]["finite_bits"]
    return rows
