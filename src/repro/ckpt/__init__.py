"""Checkpointing (msgpack-based; orbax is not available offline)."""

from repro.ckpt.msgpack_ckpt import (AsyncCheckpointer, CheckpointManager,
                                     load_pytree, register_treedef,
                                     restore_pytree, save_pytree,
                                     save_pytree_async)

__all__ = ["AsyncCheckpointer", "CheckpointManager", "load_pytree",
           "register_treedef", "restore_pytree", "save_pytree",
           "save_pytree_async"]
