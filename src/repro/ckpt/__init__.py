"""Checkpointing (msgpack-based; orbax is not available offline)."""

from repro.ckpt.msgpack_ckpt import save_pytree, load_pytree, CheckpointManager

__all__ = ["save_pytree", "load_pytree", "CheckpointManager"]
