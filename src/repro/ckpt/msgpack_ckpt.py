"""Pytree checkpointing on msgpack (atomic write, step management).

Layout: a single ``.msgpack`` file per step holding
{path: {dtype, shape, data-bytes}} plus a JSON-ish meta dict.
Host-gathered (fully addressable) arrays only — adequate for the
CPU-runnable training drivers in this repo; a real multi-host deployment
would swap in tensorstore/orbax behind the same interface.
"""

from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(path: str, tree, meta: dict | None = None) -> None:
    flat = _flatten_with_paths(tree)
    payload = {
        "__meta__": meta or {},
        "arrays": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in flat.items()
        },
    }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload))
        os.replace(tmp, path)                      # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str, like=None):
    """Returns (tree_or_flat_dict, meta).  With ``like``, restores the
    exact pytree structure of ``like``.

    Restoring into a template of mismatched shapes (e.g. resuming a
    round-granular engine state against a different batch or opt_budget)
    fails loudly per leaf instead of surfacing as a reshape error deep
    inside a jit trace — checkpoint/resume parity depends on the state
    landing in exactly the slots it left.
    """
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    arrays = {
        k: np.frombuffer(v["data"], dtype=np.dtype(v["dtype"])).reshape(
            v["shape"])
        for k, v in payload["arrays"].items()
    }
    meta = payload.get("__meta__", {})
    if like is None:
        return arrays, meta
    ref = _flatten_with_paths(like)
    missing = set(ref) - set(arrays)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for tree_path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in tree_path)
        arr = arrays[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(arr.shape)} "
                f"but the template expects {tuple(np.shape(leaf))} — "
                f"restore against the inputs the state was saved for "
                f"(file: {path})")
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves), meta


class CheckpointManager:
    """Step-numbered checkpoints with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.msgpack")

    def steps(self):
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".msgpack"):
                out.append(int(f[5:-8]))
        return sorted(out)

    def save(self, step: int, tree, meta=None):
        save_pytree(self._path(step), tree,
                    dict(meta or {}, step=step))
        for old in self.steps()[:-self.keep]:
            os.unlink(self._path(old))

    def restore_latest(self, like=None):
        steps = self.steps()
        if not steps:
            return None, None
        return load_pytree(self._path(steps[-1]), like=like)
