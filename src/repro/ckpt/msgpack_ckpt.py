"""Pytree checkpointing on msgpack: durable atomic writes, incremental
content-hashed snapshots, off-thread serialization, template-free
restore.

Layout: a single ``.msgpack`` file per snapshot holding
``{path: {dtype, shape, data-bytes}}`` plus a meta dict and a
**manifest** — per-leaf blake2b content hashes, the treedef-registry
name of the saved pytree, and (for incremental snapshots) the base
file the chain restores through.  Host-gathered (fully addressable)
arrays only — adequate for the CPU-runnable training drivers in this
repo; a real multi-host deployment would swap in tensorstore/orbax
behind the same interface.

Three mechanisms keep the preempt/resume path off the dispatch loop's
critical path (the maxtext standalone-checkpointer recipe):

* **Incremental saves.**  ``save_pytree(path, tree, base=,
  base_hashes=)`` serializes only leaves whose content hash changed
  since the base snapshot; the manifest chains back to the base, and
  loading overlays the chain tip-to-base.  Round-granular engine
  checkpoints churn MW weights and round counters but not the large
  coreset/history buffers, so chained snapshots are a fraction of a
  full resave (benchmarks/checkpointing.py pins this).
* **Off-thread serialization.**  :class:`AsyncCheckpointer` hands
  flattened host arrays to a single writer thread over a bounded
  queue; the caller pays only ``jax.device_get`` + flatten, while
  packb + fsync + rename happen off-thread.  ``wait()`` is the
  barrier: it blocks until every enqueued save is durably on disk and
  re-raises the first writer error.
* **Template-free restore.**  The manifest records each leaf's dtype
  and shape plus the pytree's :func:`register_treedef` name, so
  :func:`restore_pytree` rebuilds the exact saved pytree (e.g. a
  ``batched.StepState``) without re-running any engine init to obtain
  a template.

Durability: writes go to a same-directory temp file which is flushed
and fsync'd before the atomic ``os.replace``, and the directory entry
is fsync'd after — a crash mid-write can never publish a truncated
checkpoint under the final name (the prior snapshot survives intact).
"""

from __future__ import annotations

import contextlib
import hashlib
import os
import queue
import tempfile
import threading
import time
import warnings
from typing import Callable

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

FORMAT = 2


# ---------------------------------------------------------------------------
# Leaf paths + the treedef registry
# ---------------------------------------------------------------------------

def _entry_key(p) -> str:
    """Stable name of one pytree path entry: attr name for NamedTuple
    fields (GetAttrKey), dict key (DictKey), index (SequenceKey)."""
    for attr in ("name", "key", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten_with_paths(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_entry_key(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


_TREEDEF_REGISTRY: dict = {}


def register_treedef(name: str, unflatten: Callable) -> None:
    """Register a pytree reconstructor for template-free restore.

    ``unflatten`` maps ``{leaf_name: array}`` (the checkpoint's flat
    manifest keys, top-level only — no nesting) back to the live
    pytree.  Engines register their state types at import time
    (``batched.STATE_TREEDEF``, ``sharded_batched.STATE_TREEDEF``) so
    a checkpoint names its own structure and a resume never has to run
    engine init just to obtain a template.
    """
    _TREEDEF_REGISTRY[name] = unflatten


def _nest(flat: dict) -> dict:
    """Default reconstructor: nested dicts split on '/'."""
    out: dict = {}
    for k, arr in flat.items():
        parts = k.split("/")
        d = out
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = arr
    return out


register_treedef("nested_dict", _nest)


# ---------------------------------------------------------------------------
# Durable atomic write + hashing
# ---------------------------------------------------------------------------

def _fsync_dir(d: str) -> None:
    """fsync the directory entry so the rename itself is durable."""
    try:
        dfd = os.open(d, os.O_RDONLY)
    except OSError:                      # platform without dir-open
        return
    try:
        os.fsync(dfd)
    except OSError:
        pass
    finally:
        os.close(dfd)


def _write_atomic(path: str, blob: bytes) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())         # data durable BEFORE the rename
        os.replace(tmp, path)            # atomic publish
        _fsync_dir(d)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def leaf_hash(arr: np.ndarray) -> str:
    """Content hash of one leaf (dtype + shape + raw bytes)."""
    h = hashlib.blake2b(digest_size=16)
    h.update(str(arr.dtype).encode())
    h.update(repr(tuple(arr.shape)).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Save
# ---------------------------------------------------------------------------

def _save_flat(path: str, flat: dict, meta: dict, treedef: str | None,
               base: str | None, base_hashes: dict | None) -> dict:
    """Serialize a flattened {name: array} dict; returns its hashes.

    Every write path funnels through here (sync :func:`save_pytree`,
    the :class:`AsyncCheckpointer` worker, :class:`CheckpointManager`),
    so this is also where the observability hooks live: a
    ``ckpt_write`` trace span (worker-thread saves show up under their
    own tid in Perfetto) and the ``ckpt.save_s`` histogram / counters
    of `repro.obs.metrics.default_registry`.
    """
    t0 = time.perf_counter()
    with obs_trace.span("ckpt_write", "checkpoint", path=path,
                        full=base is None) as sp:
        hashes = {k: leaf_hash(v) for k, v in flat.items()}
        if base is not None and base_hashes is not None:
            write = {k: v for k, v in flat.items()
                     if hashes[k] != base_hashes.get(k)}
            base_name = os.path.basename(base)
        else:
            write, base_name = flat, None
        sp.update(leaves_written=len(write), leaves_total=len(flat))
        payload = {
            "__meta__": dict(meta or {}),
            "__format__": FORMAT,
            "__treedef__": treedef,
            "__base__": base_name,
            "__hashes__": hashes,
            "arrays": {
                k: {"dtype": str(v.dtype), "shape": list(v.shape),
                    "data": v.tobytes()}
                for k, v in write.items()
            },
        }
        _write_atomic(path, msgpack.packb(payload))
    reg = obs_metrics.default_registry()
    reg.counter("ckpt.saves").inc()
    reg.histogram("ckpt.save_s").observe(time.perf_counter() - t0)
    return hashes


def save_pytree(path: str, tree, meta: dict | None = None,
                treedef: str | None = None, base: str | None = None,
                base_hashes: dict | None = None) -> dict:
    """Write one snapshot; returns its per-leaf content hashes.

    Full snapshot by default.  With ``base`` (a prior snapshot in the
    same directory) and ``base_hashes`` (that snapshot's returned hash
    dict), only leaves whose content changed are serialized and the
    manifest chains back to the base — loading resolves the chain.
    ``treedef`` names a :func:`register_treedef` reconstructor so the
    file restores template-free via :func:`restore_pytree`.
    """
    return _save_flat(path, _flatten_with_paths(tree), meta or {},
                      treedef, base, base_hashes)


# ---------------------------------------------------------------------------
# Load
# ---------------------------------------------------------------------------

def _read_payload(path: str) -> dict:
    with open(path, "rb") as f:
        blob = f.read()
    try:
        payload = msgpack.unpackb(blob)
        if not isinstance(payload, dict) or "arrays" not in payload:
            raise ValueError("missing arrays section")
    except Exception as e:
        raise ValueError(f"corrupt checkpoint {path!r}: {e}") from e
    return payload


_MAX_CHAIN = 4096


def _load_arrays(path: str, _depth: int = 0):
    """Resolve a snapshot (following its incremental chain) to a flat
    {name: array} dict + the tip's payload.  Arrays are **owned
    copies** — ``np.frombuffer`` views of the msgpack buffer are
    read-only aliases, and restored state must survive in-place
    host-side mutation."""
    if _depth > _MAX_CHAIN:
        raise ValueError(f"checkpoint chain too deep at {path!r} "
                         f"(> {_MAX_CHAIN}) — cycle?")
    payload = _read_payload(path)
    arrays = {
        k: np.frombuffer(v["data"], dtype=np.dtype(v["dtype"]))
        .reshape(v["shape"]).copy()
        for k, v in payload["arrays"].items()
    }
    base = payload.get("__base__")
    if base is not None:
        base_path = os.path.join(os.path.dirname(os.path.abspath(path)),
                                 base)
        merged, _ = _load_arrays(base_path, _depth + 1)
        merged.update(arrays)            # tip wins
        arrays = merged
    return arrays, payload


@contextlib.contextmanager
def _restore_scope(path: str):
    """One restore's observability: a ``ckpt_restore`` trace span plus
    the ``ckpt.restore_s`` histogram / ``ckpt.restores`` counter of
    `repro.obs.metrics.default_registry` (metrics only on success)."""
    t0 = time.perf_counter()
    with obs_trace.span("ckpt_restore", "checkpoint", path=path):
        yield
    reg = obs_metrics.default_registry()
    reg.counter("ckpt.restores").inc()
    reg.histogram("ckpt.restore_s").observe(time.perf_counter() - t0)


def load_pytree(path: str, like=None):
    """Returns (tree_or_flat_dict, meta).  With ``like``, restores the
    exact pytree structure of ``like``.

    Restoring into a template of mismatched shapes **or dtypes** (e.g.
    resuming a round-granular engine state against a different batch,
    opt_budget, or a template whose leaves drifted to another dtype)
    fails loudly per leaf instead of surfacing as a reshape error —
    or, worse, a silent ``astype`` — deep inside a jit trace:
    checkpoint/resume bit-parity depends on the state landing in
    exactly the slots (and representations) it left.
    """
    with _restore_scope(path):
        arrays, payload = _load_arrays(path)
        meta = payload.get("__meta__", {})
        if like is None:
            return arrays, meta
        ref = _flatten_with_paths(like)
        missing = set(ref) - set(arrays)
        if missing:
            raise KeyError(
                f"checkpoint missing keys: {sorted(missing)[:5]}...")
        flat, _ = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for tree_path, leaf in flat:
            key = "/".join(_entry_key(p) for p in tree_path)
            arr = arrays[key]
            if tuple(arr.shape) != tuple(np.shape(leaf)):
                raise ValueError(
                    f"checkpoint leaf {key!r} has shape "
                    f"{tuple(arr.shape)} but the template expects "
                    f"{tuple(np.shape(leaf))} — restore against the "
                    f"inputs the state was saved for (file: {path})")
            want = np.dtype(getattr(leaf, "dtype",
                                    np.asarray(leaf).dtype))
            if arr.dtype != want:
                raise ValueError(
                    f"checkpoint leaf {key!r} has dtype {arr.dtype} but "
                    f"the template expects {want} — a silent astype "
                    f"here would break bit-parity invisibly "
                    f"(file: {path})")
            leaves.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves), meta


def restore_pytree(path: str):
    """Template-free restore: (tree, meta) rebuilt entirely from the
    checkpoint's own manifest — leaf names, dtypes, shapes, and the
    :func:`register_treedef` name recorded at save time.  No engine
    init, no template, no discarded device compute."""
    with _restore_scope(path):
        arrays, payload = _load_arrays(path)
        name = payload.get("__treedef__") or "nested_dict"
        if name not in _TREEDEF_REGISTRY:
            raise KeyError(
                f"checkpoint treedef {name!r} is not registered — "
                f"import the module that defines it (known: "
                f"{sorted(_TREEDEF_REGISTRY)})")
        # hand the reconstructor the raw host arrays: a jnp.asarray
        # here would silently truncate dtypes (e.g. int64→int32
        # without x64) BEFORE the engine's dtype check could refuse
        # the drift
        return _TREEDEF_REGISTRY[name](arrays), payload.get(
            "__meta__", {})


def snapshot_base(path: str) -> str | None:
    """The base filename an incremental snapshot chains to (None for a
    full snapshot) — read from the manifest."""
    return _read_payload(path).get("__base__")


# ---------------------------------------------------------------------------
# Off-thread serialization
# ---------------------------------------------------------------------------

class AsyncCheckpointer:
    """Single writer thread behind a bounded queue.

    ``save()`` flattens on the caller thread (paying only the
    device→host ``jax.device_get`` copy) and enqueues; the worker does
    hashing + packb + fsync + rename.  A full queue blocks the caller
    (bounded memory: at most ``max_pending`` host snapshots in
    flight).  ``wait()`` drains the queue and re-raises the first
    writer error; a failed save never silently vanishes.

    ``chain=`` threads incremental state through the worker: the first
    save of a chain id is a full snapshot, every later one serializes
    only leaves whose content hash changed, chained to the previous
    file.  ``forget(chain)`` drops the chain state once its files are
    consumed.
    """

    def __init__(self, max_pending: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max_pending)
        self._err: BaseException | None = None
        self._chains: dict = {}          # chain id -> (path, hashes)
        self._thread = threading.Thread(
            target=self._loop, name="ckpt-writer", daemon=True)
        self._thread.start()

    # -- caller side -------------------------------------------------------

    def save(self, path: str, tree, meta: dict | None = None,
             treedef: str | None = None, chain: str | None = None) -> None:
        self._raise_pending()
        flat = _flatten_with_paths(jax.device_get(tree))
        self._q.put(("save", path, flat, dict(meta or {}), treedef,
                     chain))

    def wait(self) -> None:
        """Barrier: every enqueued save is durably on disk (or its
        error raised here)."""
        self._q.join()
        self._raise_pending()

    def forget(self, chain: str) -> None:
        self._chains.pop(chain, None)

    def close(self) -> None:
        self.wait()
        self._q.put(("stop",))
        self._thread.join()

    def _raise_pending(self) -> None:
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("async checkpoint save failed") from err

    # -- worker side -------------------------------------------------------

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item[0] == "stop":
                    return
                _, path, flat, meta, treedef, chain = item
                base = base_hashes = None
                if chain is not None and chain in self._chains:
                    base, base_hashes = self._chains[chain]
                hashes = _save_flat(path, flat, meta, treedef, base,
                                    base_hashes)
                if chain is not None:
                    self._chains[chain] = (path, hashes)
            except BaseException as e:  # noqa: BLE001 — surfaced in wait()
                if self._err is None:
                    self._err = e
            finally:
                self._q.task_done()


_DEFAULT_WRITER: AsyncCheckpointer | None = None
_DEFAULT_WRITER_LOCK = threading.Lock()


def save_pytree_async(path: str, tree, meta: dict | None = None,
                      treedef: str | None = None,
                      chain: str | None = None) -> AsyncCheckpointer:
    """Module-level async save through a shared default writer; returns
    the writer so the caller can ``wait()`` on the barrier."""
    global _DEFAULT_WRITER
    with _DEFAULT_WRITER_LOCK:
        if _DEFAULT_WRITER is None:
            _DEFAULT_WRITER = AsyncCheckpointer()
    _DEFAULT_WRITER.save(path, tree, meta=meta, treedef=treedef,
                         chain=chain)
    return _DEFAULT_WRITER


# ---------------------------------------------------------------------------
# Step-numbered checkpoints with retention
# ---------------------------------------------------------------------------

class CheckpointManager:
    """Step-numbered checkpoints with retention (+ optional incremental
    chains).

    ``incremental=True`` chains each save to the previous step's
    snapshot (only changed leaves serialized), writing a fresh full
    snapshot every ``full_every`` saves so chains stay shallow and old
    chains become collectable.  Retention keeps the newest ``keep``
    steps **plus** any older snapshot a kept file's chain restores
    through — deleting a live base would corrupt every checkpoint
    downstream of it.
    """

    def __init__(self, directory: str, keep: int = 3,
                 incremental: bool = False, full_every: int = 8,
                 treedef: str | None = None):
        if keep < 1:
            raise ValueError(
                f"keep={keep} must be >= 1 — keep=0 would silently "
                f"disable retention (steps()[:-0] is the empty slice), "
                f"not keep nothing")
        if full_every < 1:
            raise ValueError(f"full_every={full_every} must be >= 1")
        self.dir = directory
        self.keep = keep
        self.incremental = incremental
        self.full_every = full_every
        self.treedef = treedef
        self._prev: tuple | None = None      # (path, hashes)
        self._since_full = 0
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.msgpack")

    def steps(self):
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".msgpack"):
                try:
                    out.append(int(f[5:-8]))
                except ValueError:
                    warnings.warn(
                        f"skipping unparsable checkpoint filename "
                        f"{f!r} in {self.dir!r}", stacklevel=2)
        return sorted(out)

    def _protected(self, kept_steps) -> set:
        """Filenames any kept snapshot's chain restores through."""
        protect: set = set()
        for step in kept_steps:
            path = self._path(step)
            while True:
                try:
                    base = snapshot_base(path)
                except (OSError, ValueError):
                    break
                if base is None or base in protect:
                    break
                protect.add(base)
                path = os.path.join(self.dir, base)
        return protect

    def save(self, step: int, tree, meta=None) -> str:
        path = self._path(step)
        base = base_hashes = None
        if self.incremental and self._prev is not None \
                and self._since_full < self.full_every:
            base, base_hashes = self._prev
        hashes = save_pytree(path, tree, dict(meta or {}, step=step),
                             treedef=self.treedef, base=base,
                             base_hashes=base_hashes)
        self._since_full = 0 if base is None else self._since_full + 1
        self._prev = (path, hashes)
        steps = self.steps()
        kept = steps[-self.keep:]
        protected = self._protected(kept)
        for old in steps[:-self.keep]:
            if os.path.basename(self._path(old)) not in protected:
                os.unlink(self._path(old))
        return path

    def restore_latest(self, like=None):
        steps = self.steps()
        if not steps:
            return None, None
        if like is None:
            return restore_pytree(self._path(steps[-1]))
        return load_pytree(self._path(steps[-1]), like=like)
