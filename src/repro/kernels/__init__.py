"""Pallas TPU kernels for the compute hot-spots.

Each kernel package ships kernel.py (pl.pallas_call + explicit BlockSpec
VMEM tiling, sized for TPU v5e: 128-aligned MXU dims, ≤ ~2 MiB VMEM
working set), ops.py (the jit'd public wrapper; interpret=True on CPU
so the kernel body executes on this container), and ref.py (the pure-jnp
oracle every test sweeps against).
"""
