"""Public wrapper: pad to block multiples, dispatch, compute stump errors.

Both entry points accept an optional leading batch (task) axis:
``x [c, F]`` uses the 3-D grid; ``x [B, c, F]`` lowers to the batched
kernel whose grid leads with B — one launch for the center ERM of all
B tasks (per-task weights AND per-task thresholds).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.stump import kernel as K
from repro.kernels.stump.ref import stump_errors_ref  # re-export oracle


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def stump_scores(x, wy, thetas, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    batched = x.ndim == 3
    c, F = x.shape[-2], x.shape[-1]
    Q = thetas.shape[-1]
    pc, pf, pq = (-c) % K.BC, (-F) % K.BF, (-Q) % K.BQ
    lead = ((0, 0),) if batched else ()
    xp = jnp.pad(x, lead + ((0, pc), (0, pf)))
    wyp = jnp.pad(wy, lead + ((0, pc),))            # zero weight ⇒ no-op
    # padded thresholds must not be ±inf (NaN-free): use +big so padded
    # rows compare to 0-features as 0 ≥ big = False
    tp = jnp.pad(thetas, lead + ((0, pf), (0, pq)),
                 constant_values=3.4e38)
    if batched:
        S = K.stump_scores_batched_pallas(xp, wyp, tp,
                                          interpret=interpret)
        return S[:, :F, :Q]
    S = K.stump_scores_pallas(xp, wyp, tp, interpret=interpret)
    return S[:F, :Q]


def stump_errors(x, w, y, thetas, interpret: bool | None = None):
    """[(B,) F, Q, 2] weighted stump errors via the Pallas contraction."""
    wy = w * y.astype(w.dtype)
    S = stump_scores(x, wy, thetas, interpret=interpret)
    W = jnp.sum(w, axis=-1)
    swy = jnp.sum(wy, axis=-1)
    if x.ndim == 3:
        W, swy = W[:, None, None], swy[:, None, None]
    corr_plus = 2.0 * S - swy
    return jnp.stack([0.5 * (W - corr_plus), 0.5 * (W + corr_plus)],
                     axis=-1)
