"""Public wrapper: pad to block multiples, dispatch, compute stump errors."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.stump import kernel as K
from repro.kernels.stump.ref import stump_errors_ref  # re-export oracle


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def stump_scores(x, wy, thetas, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    c, F = x.shape
    Q = thetas.shape[1]
    pc, pf, pq = (-c) % K.BC, (-F) % K.BF, (-Q) % K.BQ
    xp = jnp.pad(x, ((0, pc), (0, pf)))
    wyp = jnp.pad(wy, (0, pc))                      # zero weight ⇒ no-op
    # padded thresholds must not be ±inf (NaN-free): use +big so padded
    # rows compare to 0-features as 0 ≥ big = False
    tp = jnp.pad(thetas, ((0, pf), (0, pq)), constant_values=3.4e38)
    S = K.stump_scores_pallas(xp, wyp, tp, interpret=interpret)
    return S[:F, :Q]


def stump_errors(x, w, y, thetas, interpret: bool | None = None):
    """[F, Q, 2] weighted stump errors via the Pallas contraction."""
    wy = w * y.astype(w.dtype)
    S = stump_scores(x, wy, thetas, interpret=interpret)
    W = jnp.sum(w)
    swy = jnp.sum(wy)
    corr_plus = 2.0 * S - swy
    return jnp.stack([0.5 * (W - corr_plus), 0.5 * (W + corr_plus)],
                     axis=-1)
