"""Pure-jnp oracle for the stump score contraction."""

import jax.numpy as jnp


def stump_scores_ref(x, wy, thetas):
    """S[f,q] = Σ_i wy_i · 1[x[i,f] ≥ θ[f,q]] (optional leading batch)."""
    if x.ndim == 3:
        pred = (x[:, :, :, None] >= thetas[:, None, :, :])
        return jnp.einsum("bc,bcfq->bfq", wy, pred.astype(jnp.float32))
    pred = (x[:, :, None] >= thetas[None, :, :]).astype(jnp.float32)
    return jnp.einsum("c,cfq->fq", wy, pred)


def stump_errors_ref(x, w, y, thetas):
    """Weighted error of every (f, q, sign) stump.  Returns [F, Q, 2]
    with sign index 0 ⇒ +1 (predict +1 when x ≥ θ), 1 ⇒ −1."""
    wy = w * y.astype(w.dtype)
    S = stump_scores_ref(x, wy, thetas)
    W = jnp.sum(w, axis=-1)
    swy = jnp.sum(wy, axis=-1)
    if x.ndim == 3:
        W, swy = W[:, None, None], swy[:, None, None]
    corr_plus = 2.0 * S - swy          # Σ wy_i · pred_i for sign +1
    err_plus = 0.5 * (W - corr_plus)
    err_minus = 0.5 * (W + corr_plus)
    return jnp.stack([err_plus, err_minus], axis=-1)
