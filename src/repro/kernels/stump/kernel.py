"""Weighted decision-stump error contraction (the center's weak learner).

For coreset features X [c, F], signed weights wy = w·y [c], and
candidate thresholds Θ [F, Q], computes

    S[f, q] = Σ_i wy_i · 1[X[i, f] ≥ Θ[f, q]]

from which the weighted error of every (feature, threshold, sign) stump
follows in closed form:  err±(f,q) = ½(W ∓ (2·S[f,q] − Σwy)).

The comparison-generated ±1 matrix never hits HBM: each grid step
materializes a (BC × BF × BQ) compare tile in VMEM/VREGs and reduces it
immediately — the TPU translation of the paper's "evaluate every
hypothesis on the coreset" (an MXU-shaped contraction, not a gather).

Grid: (F/BF, Q/BQ, c/BC) with the c axis innermost, accumulating into
the output block (revisited across the c steps — standard Pallas
reduction pattern).  VMEM per step: BC·BF·4 + BF·BQ·4 + BC·BF·BQ·4
≈ 0.6 MiB at (128, 8, 128).

Batched form (:func:`stump_scores_batched_pallas`): a leading task axis
B is the OUTERMOST grid dimension — the center ERM of B independent
boosting tasks is one kernel launch, grid (B, F/BF, Q/BQ, c/BC), with
per-task thresholds.  Block shapes pick up a leading 1 (one task per
step); VMEM per step is unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BC, BF, BQ = 128, 8, 128


def _stump_kernel(x_ref, wy_ref, theta_ref, s_ref):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[...]                      # [BC, BF]
    wy = wy_ref[...]                    # [BC]
    th = theta_ref[...]                 # [BF, BQ]
    pred = (x[:, :, None] >= th[None, :, :]).astype(jnp.float32)
    s_ref[...] += jnp.einsum("c,cfq->fq", wy, pred)


@functools.partial(jax.jit, static_argnames=("interpret", "blocks"))
def stump_scores_pallas(x, wy, thetas, *, interpret: bool = False,
                        blocks=(BC, BF, BQ)):
    """x [c, F] f32; wy [c] f32; thetas [F, Q] f32 → S [F, Q] f32.
    c % BC == F % BF == Q % BQ == 0 (caller pads)."""
    bc, bf, bq = blocks
    c, F = x.shape
    Q = thetas.shape[1]
    assert c % bc == 0 and F % bf == 0 and Q % bq == 0
    return pl.pallas_call(
        _stump_kernel,
        grid=(F // bf, Q // bq, c // bc),
        in_specs=[
            pl.BlockSpec((bc, bf), lambda f, q, ci: (ci, f)),
            pl.BlockSpec((bc,), lambda f, q, ci: (ci,)),
            pl.BlockSpec((bf, bq), lambda f, q, ci: (f, q)),
        ],
        out_specs=pl.BlockSpec((bf, bq), lambda f, q, ci: (f, q)),
        out_shape=jax.ShapeDtypeStruct((F, Q), jnp.float32),
        interpret=interpret,
    )(x, wy, thetas)


def _stump_kernel_batched(x_ref, wy_ref, theta_ref, s_ref):
    ci = pl.program_id(3)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0]                        # [BC, BF]
    wy = wy_ref[0]                      # [BC]
    th = theta_ref[0]                   # [BF, BQ]
    pred = (x[:, :, None] >= th[None, :, :]).astype(jnp.float32)
    s_ref[0] += jnp.einsum("c,cfq->fq", wy, pred)


@functools.partial(jax.jit, static_argnames=("interpret", "blocks"))
def stump_scores_batched_pallas(x, wy, thetas, *, interpret: bool = False,
                                blocks=(BC, BF, BQ)):
    """x [B, c, F]; wy [B, c]; thetas [B, F, Q] → S [B, F, Q] f32.
    One launch for all B tasks; c % BC == F % BF == Q % BQ == 0."""
    bc, bf, bq = blocks
    B, c, F = x.shape
    Q = thetas.shape[2]
    assert c % bc == 0 and F % bf == 0 and Q % bq == 0
    return pl.pallas_call(
        _stump_kernel_batched,
        grid=(B, F // bf, Q // bq, c // bc),
        in_specs=[
            pl.BlockSpec((1, bc, bf), lambda b, f, q, ci: (b, ci, f)),
            pl.BlockSpec((1, bc), lambda b, f, q, ci: (b, ci)),
            pl.BlockSpec((1, bf, bq), lambda b, f, q, ci: (b, f, q)),
        ],
        out_specs=pl.BlockSpec((1, bf, bq), lambda b, f, q, ci: (b, f, q)),
        out_shape=jax.ShapeDtypeStruct((B, F, Q), jnp.float32),
        interpret=interpret,
    )(x, wy, thetas)
