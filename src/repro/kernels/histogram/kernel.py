"""Weighted per-node feature histograms (tree split finding).

For features X [c, F], per-node routed weights W [N, c] and signed
weights WY = W·y [N, c], computes

    hist_w [n, f, q] = Σ_i W[n, i] · 1[bin(X[i, f]) == q]
    hist_wy[n, f, q] = Σ_i WY[n, i] · 1[bin(X[i, f]) == q]

with ``bin(x) = clip(floor(x·Q), 0, Q−1)`` over the fixed [0, 1) grid
(the convention defined in ref.py) — the LightGBM-style histogram a
greedy tree grower reduces to best (feature, bin) splits per node.

Like the stump kernel, the one-hot bin-membership tile never hits HBM:
each grid step materialises a (BC × BF × BQ) compare tile in
VMEM/VREGs and contracts it immediately against the weight chunk (an
MXU-shaped reduction, not a scatter — scatters are row-serial on both
TPU and XLA:CPU).

Grid: (N, F/BF, Q/BQ, c/BC), c innermost, both outputs accumulated
across the c steps (revisited blocks — the standard Pallas reduction
pattern).  VMEM per step ≈ BC·BF·4 + 2·BC·4 + BC·BF·BQ·4 +
2·BF·BQ·4 ≈ 0.27 MiB at (128, 8, 64).

Batched form (:func:`hist_batched_pallas`): the (task, node) pair is
folded into the single OUTERMOST grid axis g = b·N + n — one launch
serves one tree level of the center ERM of all B tasks (X is indexed
by g // N, the weights by (g // N, g % N)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.histogram.ref import bin_index

BC, BF, BQ = 128, 8, 64


def _hist_kernel(bins, bq, x_ref, w_ref, wy_ref, hw_ref, hwy_ref):
    qi, ci = pl.program_id(2), pl.program_id(3)

    @pl.when(ci == 0)
    def _init():
        hw_ref[...] = jnp.zeros_like(hw_ref)
        hwy_ref[...] = jnp.zeros_like(hwy_ref)

    b = bin_index(x_ref[...], bins)               # [BC, BF]
    qs = qi * bq + jnp.arange(bq, dtype=jnp.int32)
    onehot = (b[:, :, None] == qs[None, None, :]).astype(jnp.float32)
    hw_ref[0] += jnp.einsum("c,cfq->fq", w_ref[0], onehot)
    hwy_ref[0] += jnp.einsum("c,cfq->fq", wy_ref[0], onehot)


@functools.partial(jax.jit,
                   static_argnames=("bins", "interpret", "blocks"))
def hist_pallas(x, w, wy, *, bins: int, interpret: bool = False,
                blocks=(BC, BF, BQ)):
    """x [c, F] f32; w, wy [N, c] f32 → (hist_w, hist_wy) [N, F, Q] f32
    with Q padded to the block grid.  c % BC == F % BF == Q % BQ == 0
    (caller pads); ``bins`` is the true Q the bin map clips to."""
    bc, bf, bq = blocks
    c, F = x.shape
    N = w.shape[0]
    Q = ((bins + bq - 1) // bq) * bq
    assert c % bc == 0 and F % bf == 0
    out = jax.ShapeDtypeStruct((N, F, Q), jnp.float32)
    return pl.pallas_call(
        functools.partial(_hist_kernel, bins, bq),
        grid=(N, F // bf, Q // bq, c // bc),
        in_specs=[
            pl.BlockSpec((bc, bf), lambda n, f, q, ci: (ci, f)),
            pl.BlockSpec((1, bc), lambda n, f, q, ci: (n, ci)),
            pl.BlockSpec((1, bc), lambda n, f, q, ci: (n, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, bf, bq), lambda n, f, q, ci: (n, f, q)),
            pl.BlockSpec((1, bf, bq), lambda n, f, q, ci: (n, f, q)),
        ],
        out_shape=[out, out],
        interpret=interpret,
    )(x, w, wy)


def _hist_kernel_batched(bins, bq, x_ref, w_ref, wy_ref, hw_ref,
                         hwy_ref):
    qi, ci = pl.program_id(2), pl.program_id(3)

    @pl.when(ci == 0)
    def _init():
        hw_ref[...] = jnp.zeros_like(hw_ref)
        hwy_ref[...] = jnp.zeros_like(hwy_ref)

    b = bin_index(x_ref[0], bins)                 # [BC, BF]
    qs = qi * bq + jnp.arange(bq, dtype=jnp.int32)
    onehot = (b[:, :, None] == qs[None, None, :]).astype(jnp.float32)
    hw_ref[0, 0] += jnp.einsum("c,cfq->fq", w_ref[0, 0], onehot)
    hwy_ref[0, 0] += jnp.einsum("c,cfq->fq", wy_ref[0, 0], onehot)


@functools.partial(jax.jit,
                   static_argnames=("bins", "interpret", "blocks"))
def hist_batched_pallas(x, w, wy, *, bins: int, interpret: bool = False,
                        blocks=(BC, BF, BQ)):
    """x [B, c, F]; w, wy [B, N, c] → (hist_w, hist_wy) [B, N, F, Q].
    One launch for one tree level of all B tasks: the outermost grid
    axis is g = b·N + n (N static, so the index maps divide it out)."""
    bc, bf, bq = blocks
    B, c, F = x.shape
    N = w.shape[1]
    Q = ((bins + bq - 1) // bq) * bq
    assert c % bc == 0 and F % bf == 0
    out = jax.ShapeDtypeStruct((B, N, F, Q), jnp.float32)
    return pl.pallas_call(
        functools.partial(_hist_kernel_batched, bins, bq),
        grid=(B * N, F // bf, Q // bq, c // bc),
        in_specs=[
            pl.BlockSpec((1, bc, bf), lambda g, f, q, ci: (g // N, ci, f)),
            pl.BlockSpec((1, 1, bc),
                         lambda g, f, q, ci: (g // N, g % N, ci)),
            pl.BlockSpec((1, 1, bc),
                         lambda g, f, q, ci: (g // N, g % N, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bf, bq),
                         lambda g, f, q, ci: (g // N, g % N, f, q)),
            pl.BlockSpec((1, 1, bf, bq),
                         lambda g, f, q, ci: (g // N, g % N, f, q)),
        ],
        out_shape=[out, out],
        interpret=interpret,
    )(x, w, wy)
