"""Public wrapper: pad to block multiples, dispatch, reduce to splits.

Both entry points accept an optional leading batch (task) axis:
``x [c, F]`` uses the 4-D grid; ``x [B, c, F]`` lowers to the batched
kernel whose outermost grid axis folds (task, node) — one launch for
one tree level of the center ERM of all B tasks.

Routing policy (mirrors how the stump kernel is deployed): the Pallas
program is the TPU fast path; on CPU the pure-jnp ref IS the production
implementation (XLA:CPU lowers the one-hot einsum well, while
interpret-mode Pallas is a debugging tool, not a fast path — see
TESTING.md for forcing it).  :func:`node_histograms` therefore
dispatches ref-vs-Pallas on the backend unless ``interpret=True``
explicitly requests the interpreted kernel (the parity tests do).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.histogram import kernel as K
from repro.kernels.histogram.ref import (  # noqa: F401  (re-export oracle)
    best_splits_per_feature, best_splits_ref, bin_index,
    node_histograms_ref, split_err_surface)


def _pallas_histograms(x, w, wy, bins: int, interpret: bool):
    batched = x.ndim == 3
    c, F = x.shape[-2], x.shape[-1]
    pc, pf = (-c) % K.BC, (-F) % K.BF
    lead = ((0, 0),) if batched else ()
    xp = jnp.pad(x, lead + ((0, pc), (0, pf)))      # pad rows: zero weight
    wp = jnp.pad(w, lead + ((0, 0), (0, pc)))       # ⇒ no-op in every bin
    wyp = jnp.pad(wy, lead + ((0, 0), (0, pc)))
    if batched:
        hw, hwy = K.hist_batched_pallas(xp, wp, wyp, bins=bins,
                                        interpret=interpret)
        return hw[:, :, :F, :bins], hwy[:, :, :F, :bins]
    hw, hwy = K.hist_pallas(xp, wp, wyp, bins=bins, interpret=interpret)
    return hw[:, :F, :bins], hwy[:, :F, :bins]


def node_histograms(x, w, wy, bins: int, interpret: bool | None = None):
    """(hist_w, hist_wy) [(B,) N, F, Q] — see ref.node_histograms_ref.

    ``interpret=None`` (default): Pallas on TPU, jnp ref elsewhere.
    ``interpret=True``: force the interpreted Pallas kernel (parity
    testing).  ``interpret=False``: force the compiled kernel.
    """
    if interpret is None:
        if jax.default_backend() != "tpu":
            return node_histograms_ref(x, w, wy, bins)
        interpret = False
    return _pallas_histograms(x, w, wy, bins, interpret)


def best_node_splits(x, w, wy, bins: int, interpret: bool | None = None):
    """Histogram + reduce: the best (feature, bin) split per node.

    Returns (feat, q, err) each [(B,) N] — the full split-finding step
    of one tree level in one call (kernel contraction + jnp reduction).
    """
    hw, hwy = node_histograms(x, w, wy, bins, interpret=interpret)
    return best_splits_ref(hw, hwy)
