"""Public wrapper: pad to block multiples, dispatch, reduce to splits.

Both entry points accept an optional leading batch (task) axis:
``x [c, F]`` uses the 4-D grid; ``x [B, c, F]`` lowers to the batched
kernel whose outermost grid axis folds (task, node) — one launch for
one tree level of the center ERM of all B tasks.

Routing policy (mirrors how the stump kernel is deployed): the Pallas
program is the TPU fast path; on CPU the pure-jnp ref IS the production
implementation (XLA:CPU lowers the one-hot einsum well, while
interpret-mode Pallas is a debugging tool, not a fast path — see
TESTING.md for forcing it).  :func:`node_histograms` therefore
dispatches ref-vs-Pallas on the backend unless ``interpret=True``
explicitly requests the interpreted kernel (the parity tests do).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.histogram import kernel as K
from repro.kernels.histogram.ref import (  # noqa: F401  (re-export oracle)
    best_splits_per_feature, best_splits_ref, bin_index,
    node_histograms_chunked_ref, node_histograms_ref, split_err_surface)


def _pallas_histograms(x, w, wy, bins: int, interpret: bool):
    batched = x.ndim == 3
    c, F = x.shape[-2], x.shape[-1]
    pc, pf = (-c) % K.BC, (-F) % K.BF
    lead = ((0, 0),) if batched else ()
    xp = jnp.pad(x, lead + ((0, pc), (0, pf)))      # pad rows: zero weight
    wp = jnp.pad(w, lead + ((0, 0), (0, pc)))       # ⇒ no-op in every bin
    wyp = jnp.pad(wy, lead + ((0, 0), (0, pc)))
    if batched:
        hw, hwy = K.hist_batched_pallas(xp, wp, wyp, bins=bins,
                                        interpret=interpret)
        return hw[:, :, :F, :bins], hwy[:, :, :F, :bins]
    hw, hwy = K.hist_pallas(xp, wp, wyp, bins=bins, interpret=interpret)
    return hw[:, :F, :bins], hwy[:, :F, :bins]


def _chunked_histograms(x, w, wy, bins: int, interpret: bool | None,
                        chunk_size: int):
    """Scan the dispatched kernel over point tiles (streaming tier).

    Whatever :func:`node_histograms` would run monolithically — jnp ref
    or (interpreted) Pallas — runs per ``chunk_size`` tile inside a
    ``lax.scan`` that folds into the [(B,) N, F, Q] accumulator, so the
    O(c·F·Q) intermediate never exceeds one tile.  Bitwise equal to the
    monolithic path on dyadic weights (exact f32 partial sums)."""
    c, F = x.shape[-2], x.shape[-1]
    pc = (-c) % chunk_size
    lead = ((0, 0),) if x.ndim == 3 else ()
    xp = jnp.pad(x, lead + ((0, pc), (0, 0)))   # pad rows: zero weight
    wp = jnp.pad(w, lead + ((0, 0), (0, pc)))   # ⇒ no-op in every bin
    wyp = jnp.pad(wy, lead + ((0, 0), (0, pc)))
    t = (c + pc) // chunk_size
    if x.ndim == 3:
        b, n = w.shape[0], w.shape[1]
        xt = jnp.moveaxis(xp.reshape(b, t, chunk_size, F), 1, 0)
        wt = jnp.moveaxis(wp.reshape(b, n, t, chunk_size), 2, 0)
        wyt = jnp.moveaxis(wyp.reshape(b, n, t, chunk_size), 2, 0)
        shape = (b, n, F, bins)
    else:
        n = w.shape[0]
        xt = xp.reshape(t, chunk_size, F)
        wt = jnp.moveaxis(wp.reshape(n, t, chunk_size), 1, 0)
        wyt = jnp.moveaxis(wyp.reshape(n, t, chunk_size), 1, 0)
        shape = (n, F, bins)

    def fold(acc, tile):
        hw, hwy = node_histograms(*tile, bins, interpret=interpret)
        return (acc[0] + hw, acc[1] + hwy), None

    init = (jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32))
    (hw, hwy), _ = jax.lax.scan(fold, init, (xt, wt, wyt))
    return hw, hwy


def node_histograms(x, w, wy, bins: int, interpret: bool | None = None,
                    chunk_size: int | None = None):
    """(hist_w, hist_wy) [(B,) N, F, Q] — see ref.node_histograms_ref.

    ``interpret=None`` (default): Pallas on TPU, jnp ref elsewhere.
    ``interpret=True``: force the interpreted Pallas kernel (parity
    testing).  ``interpret=False``: force the compiled kernel.
    ``chunk_size``: accumulate over point tiles of that many examples
    (the streaming tier — caps the one-hot intermediate at one tile;
    bitwise-equal on the protocol's dyadic weights).  ``None`` is the
    monolithic path, unchanged.
    """
    if chunk_size is not None and chunk_size < x.shape[-2]:
        return _chunked_histograms(x, w, wy, bins, interpret, chunk_size)
    if interpret is None:
        if jax.default_backend() != "tpu":
            return node_histograms_ref(x, w, wy, bins)
        interpret = False
    return _pallas_histograms(x, w, wy, bins, interpret)


def best_node_splits(x, w, wy, bins: int, interpret: bool | None = None,
                     chunk_size: int | None = None):
    """Histogram + reduce: the best (feature, bin) split per node.

    Returns (feat, q, err) each [(B,) N] — the full split-finding step
    of one tree level in one call (kernel contraction + jnp reduction).
    ``chunk_size`` threads through to :func:`node_histograms`.
    """
    hw, hwy = node_histograms(x, w, wy, bins, interpret=interpret,
                              chunk_size=chunk_size)
    return best_splits_ref(hw, hwy)
