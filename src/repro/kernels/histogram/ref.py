"""Pure-jnp oracle for the weighted tree-histogram contraction.

Binning convention (shared with weak_tree — defined ONCE, here):
features live in [0, 1) and ``bin(x) = clip(floor(x·Q), 0, Q−1)`` with
``Q = bins``.  A split "x ≥ q/Q" is therefore exactly "bin(x) ≥ q",
which is how both the ERM routing and tree ``predict`` evaluate it —
so growing on histograms and predicting on raw features can never
disagree, even for x outside [0, 1) (the clip is part of the split).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bin_index(x, bins: int):
    """[.., F] f32 in [0,1) → int32 bin ids in [0, bins)."""
    b = jnp.floor(x * bins).astype(jnp.int32)
    return jnp.clip(b, 0, bins - 1)


def node_histograms_ref(x, w, wy, bins: int):
    """Per-node weighted feature histograms.

    x  [c, F]    f32 features (or [B, c, F] with a leading task axis);
    w  [N, c]    per-node routed weights (0 off-node; [B, N, c] batched);
    wy [N, c]    per-node routed signed weights w·y;
    →  (hist_w, hist_wy) [N, F, Q] f32 ([B, N, F, Q] batched):
       hist[n, f, q] = Σ_i w[n, i] · 1[bin(x[i, f]) == q].
    """
    b = bin_index(x, bins)
    onehot = (b[..., None]
              == jnp.arange(bins, dtype=jnp.int32)).astype(jnp.float32)
    if x.ndim == 3:
        return (jnp.einsum("bnc,bcfq->bnfq", w, onehot),
                jnp.einsum("bnc,bcfq->bnfq", wy, onehot))
    return (jnp.einsum("nc,cfq->nfq", w, onehot),
            jnp.einsum("nc,cfq->nfq", wy, onehot))


def node_histograms_chunked_ref(x, w, wy, bins: int, chunk_size: int):
    """:func:`node_histograms_ref` accumulated over point tiles.

    Same signature and result shapes, but the [c, F, Q] one-hot — the
    only O(c·F·Q) intermediate in the whole tree-growth path — never
    exceeds one ``chunk_size`` tile: points are zero-weight-padded to a
    tile multiple and a ``lax.scan`` folds per-tile histograms into the
    [N, F, Q] accumulator.  On dyadic-rational weights (the protocol's
    2^{−hits} MW weights) every partial sum is exact in f32, so the
    result is BITWISE equal to the monolithic einsum regardless of the
    changed reduction order — the contract tests/test_streaming.py pins.
    """
    c, F = x.shape[-2], x.shape[-1]
    if chunk_size >= c:
        return node_histograms_ref(x, w, wy, bins)
    pc = (-c) % chunk_size
    lead = ((0, 0),) if x.ndim == 3 else ()
    xp = jnp.pad(x, lead + ((0, pc), (0, 0)))   # pad rows: zero weight
    wp = jnp.pad(w, lead + ((0, 0), (0, pc)))   # ⇒ no-op in every bin
    wyp = jnp.pad(wy, lead + ((0, 0), (0, pc)))
    t = (c + pc) // chunk_size
    if x.ndim == 3:
        b, n = w.shape[0], w.shape[1]
        xt = jnp.moveaxis(xp.reshape(b, t, chunk_size, F), 1, 0)
        wt = jnp.moveaxis(wp.reshape(b, n, t, chunk_size), 2, 0)
        wyt = jnp.moveaxis(wyp.reshape(b, n, t, chunk_size), 2, 0)
        shape = (b, n, F, bins)
    else:
        n = w.shape[0]
        xt = xp.reshape(t, chunk_size, F)
        wt = jnp.moveaxis(wp.reshape(n, t, chunk_size), 1, 0)
        wyt = jnp.moveaxis(wyp.reshape(n, t, chunk_size), 1, 0)
        shape = (n, F, bins)

    def fold(acc, tile):
        hw, hwy = node_histograms_ref(*tile, bins)
        return (acc[0] + hw, acc[1] + hwy), None

    init = (jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32))
    (hw, hwy), _ = jax.lax.scan(fold, init, (xt, wt, wyt))
    return hw, hwy


def split_err_surface(hist_w, hist_wy):
    """Two-leaf weighted error of every (feature, bin) split candidate.

    hist_* [..., N, F, Q] → err [..., N, F, Q] f32,
        err(f, q) = ½(W_L − |WY_L|) + ½(W_R − |WY_R|),
    where L = bins < q, R = bins ≥ q.  q = 0 is the degenerate
    everything-right split (its error is the no-split optimum), kept as
    a candidate so an unsplittable node degrades deterministically.
    """
    cw = jnp.cumsum(hist_w, axis=-1)
    cwy = jnp.cumsum(hist_wy, axis=-1)
    left_w = cw - hist_w                    # exclusive prefix: bins < q
    left_wy = cwy - hist_wy
    tot_w = cw[..., -1:]
    tot_wy = cwy[..., -1:]
    return (0.5 * (left_w - jnp.abs(left_wy))
            + 0.5 * ((tot_w - left_w) - jnp.abs(tot_wy - left_wy)))


def _pinned_argmin(v, size: int):
    """Index of the minimum of v's last axis with ties pinned to the
    LOWEST index — explicitly, not via argmin's backend-dependent
    tie-breaking (XLA:CPU happens to take the first occurrence but TPU
    reductions make no such promise; voting-mode elections need the
    winner to be engine-independent, so the pin is spelled out)."""
    vmin = jnp.min(v, axis=-1, keepdims=True)
    idx = jnp.arange(size, dtype=jnp.int32)
    return jnp.min(jnp.where(v == vmin, idx, size), axis=-1)


def best_splits_ref(hist_w, hist_wy):
    """Reduce histograms to the best (feature, bin) split per node.

    hist_* [..., N, F, Q] → (feat [..., N] i32, q [..., N] i32,
    err [..., N] f32): the split minimising :func:`split_err_surface`.
    Ties break to the lowest flat (feature, bin) index — pinned
    explicitly, bit-stable on every backend.
    """
    Q = hist_w.shape[-1]
    F = hist_w.shape[-2]
    err = split_err_surface(hist_w, hist_wy)
    flat = err.reshape(err.shape[:-2] + (F * Q,))
    j = _pinned_argmin(flat, F * Q)
    errmin = jnp.take_along_axis(flat, j[..., None], axis=-1)[..., 0]
    return (j // Q).astype(jnp.int32), (j % Q).astype(jnp.int32), errmin


def best_splits_per_feature(hist_w, hist_wy):
    """Best bin of EVERY feature — the voting mode's local proposals.

    hist_* [..., N, F, Q] → (q [..., N, F] i32, err [..., N, F] f32):
    per feature, the bin minimising :func:`split_err_surface` (ties to
    the lowest bin, same explicit pin as :func:`best_splits_ref`, so a
    player proposes the identical candidate on every backend)."""
    Q = hist_w.shape[-1]
    err = split_err_surface(hist_w, hist_wy)
    q = _pinned_argmin(err, Q)
    errmin = jnp.min(err, axis=-1)
    return q.astype(jnp.int32), errmin
