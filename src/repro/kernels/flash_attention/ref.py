"""Pure-jnp oracle for flash attention (GQA + causal + window)."""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q [B,H,S,hd]; k,v [B,KV,T,hd] → [B,H,S,hd] (f32 math)."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgsh,bkth->bkgst", qg, kf) * hd ** -0.5
    qpos = jnp.arange(S, dtype=jnp.int32)[:, None]
    kpos = jnp.arange(T, dtype=jnp.int32)[None, :]
    live = jnp.ones((S, T), bool)
    if causal:
        live &= kpos <= qpos
    if window > 0:
        live &= kpos > (qpos - window)
    s = jnp.where(live, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bkth->bkgsh", w, vf)
    return o.reshape(B, H, S, hd).astype(q.dtype)
