"""Blockwise (flash) causal attention with GQA + sliding window — TPU.

Grid (B·H, nq, nk), nk innermost.  TPU executes the grid sequentially
per core, so the online-softmax running state (m, l, acc) lives in VMEM
scratch and is carried across the nk steps of one (bh, iq) pair;
the output block is written on the last nk step.

GQA is handled in the index_map: query head h reads KV head h // G.

BlockSpecs (v5e): q/o tiles (BQ, hd), k/v tiles (BK, hd) with BQ = BK =
128 ⇒ MXU-aligned (128×hd @ hd×128) matmuls; VMEM per step =
(2·BQ·hd + 2·BK·hd + BQ·BK)·4B ≈ 0.9 MiB at hd = 128.

Causality/window is applied per-element inside the tile; fully-masked
tiles are skipped with ``pl.when`` (no FLOPs, no HBM reads for the
acc update — the k/v tiles are still prefetched by the pipeline, which
is the cost model XLA's cost analysis sees).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, nk: int, causal: bool, window: int,
                  scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    live = jnp.ones((bq, bk), bool)
    if causal:
        live &= k_pos <= q_pos
    if window > 0:
        live &= k_pos > (q_pos - window)

    # block-level skip: any work in this tile?
    tile_live = True
    if causal:
        tile_live = (ik * bk) <= (iq * bq + bq - 1)
    # (window skip is data-independent too but keep it simple/correct)

    @pl.when(jnp.asarray(tile_live))
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(live, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int = 0, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK,
                           interpret: bool = False):
    """q [B, H, S, hd]; k, v [B, KV, T, hd] → o [B, H, S, hd].

    S % bq == T % bk == 0 (caller pads); H % KV == 0 (GQA).
    """
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    assert S % bq == 0 and T % bk == 0 and H % KV == 0
    G = H // KV
    nq, nk = S // bq, T // bk
    scale = hd ** -0.5
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
        window=window, scale=scale)
    qr = q.reshape(B * H, S, hd)
    kr = k.reshape(B * KV, T, hd)
    vr = v.reshape(B * KV, T, hd)

    def kv_index(bh, iq, ik):
        return (bh // G, ik, 0)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),      # acc
            pltpu.VMEM((bq,), jnp.float32),         # m (running max)
            pltpu.VMEM((bq,), jnp.float32),         # l (running denom)
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd)
