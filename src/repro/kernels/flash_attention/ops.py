"""Public wrapper used by models/attention.py.

Accepts the model layout q [B, S, H, hd], k/v [B, T, KV, hd]; pads S/T
to block multiples, transposes to the kernel layout, dispatches
(interpret=True off-TPU), and unpads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    bq: int | None = None, bk: int | None = None,
                    interpret: bool | None = None):
    """q [B,S,H,hd]; k,v [B,T,KV,hd] → [B,S,H,hd]."""
    if interpret is None:
        interpret = _interpret_default()
    B, S, H, hd = q.shape
    T = k.shape[1]
    bq = bq or min(K.DEFAULT_BQ, max(8, 1 << (S - 1).bit_length()))
    bk = bk or min(K.DEFAULT_BK, max(8, 1 << (T - 1).bit_length()))
    ps, pt = (-S) % bq, (-T) % bk
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    if ps:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, ps), (0, 0)))
    if pt:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pt), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pt), (0, 0)))
        # padded keys must never win the softmax: causal masking already
        # excludes them for causal=True; for non-causal, mask via window
        # semantics is not available — caller handles (we only use the
        # kernel on causal paths).
        assert causal, "flash wrapper only supports causal attention"
    out = K.flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window, bq=bq, bk=bk,
        interpret=interpret)
    out = out[:, :, :S]
    return jnp.transpose(out, (0, 2, 1, 3))
