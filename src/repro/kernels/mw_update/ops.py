"""Public wrapper: pad, dispatch kernel (interpret on CPU), unpad."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mw_update import kernel as K


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def mw_update(hits, correct, alive, block: int = K.BLOCK,
              interpret: bool | None = None):
    """Fused hits update + weight sum.  Returns (new_hits [m], wsum [])."""
    if interpret is None:
        interpret = _interpret_default()
    m = hits.shape[0]
    block = min(block, max(128, 1 << (m - 1).bit_length()))
    pad = (-m) % block
    if pad:
        hits = jnp.pad(hits, (0, pad))
        correct = jnp.pad(correct, (0, pad))
        alive = jnp.pad(alive, (0, pad))       # padded entries dead
    new_hits, partials = K.mw_update_pallas(
        hits, correct, alive, interpret=interpret, block=block)
    return new_hits[:m], jnp.sum(partials)
