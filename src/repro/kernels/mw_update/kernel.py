"""Fused multiplicative-weights update (the paper's step 2(f) + 2(b)).

One pass over the player's shard fuses:
  hits'  = hits + 1[h_t(x) = y] · alive          (the 2^{-1[·]} update)
  partial[b] = Σ_{i ∈ block b, alive} 2^{-hits'_i}   (weight-sum reduce)

This is the protocol's memory-bound hot loop (touching every example
every round); unfused XLA would issue 3 elementwise passes + a reduce.
Block size 8×128-aligned; per-step VMEM = 4 input/output blocks
(4·BLOCK·4B = 128 KiB at BLOCK=8192) — far under v5e's 16 MiB budget,
sized to keep the (single) vector core streaming from HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _mw_kernel(hits_ref, correct_ref, alive_ref, new_hits_ref, wsum_ref):
    hits = hits_ref[...]
    corr = correct_ref[...]
    alive = alive_ref[...]
    new_hits = hits + jnp.where(corr & alive, 1, 0).astype(jnp.int32)
    new_hits_ref[...] = new_hits
    w = jnp.where(alive, jnp.exp2(-new_hits.astype(jnp.float32)), 0.0)
    wsum_ref[0] = jnp.sum(w)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def mw_update_pallas(hits, correct, alive, *, interpret: bool = False,
                     block: int = BLOCK):
    """hits int32 [m]; correct, alive bool [m] (m % block == 0 after
    padding by the caller) → (new_hits [m], wsum_partials [m/block])."""
    m = hits.shape[0]
    assert m % block == 0, f"pad to a multiple of {block}"
    nb = m // block
    return pl.pallas_call(
        _mw_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(hits, correct, alive)
