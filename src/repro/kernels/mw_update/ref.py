"""Pure-jnp oracle for the fused MW update."""

import jax.numpy as jnp


def mw_update_ref(hits, correct, alive, block: int):
    new_hits = hits + jnp.where(correct & alive, 1, 0).astype(jnp.int32)
    w = jnp.where(alive, jnp.exp2(-new_hits.astype(jnp.float32)), 0.0)
    partials = w.reshape(-1, block).sum(axis=1)
    return new_hits, partials
