import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh).

This is the proof that the distribution config is coherent without real
hardware: jax.jit(step).lower(**ShapeDtypeStructs).compile() must
succeed on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh for
every assigned architecture × input shape, and the compiled artifact
yields the roofline terms (§Roofline in EXPERIMENTS.md):

    compute_s    = HLO_FLOPs / (chips × 197e12)
    memory_s     = HLO_bytes / (chips × 819e9)
    collective_s = Σ collective bytes (parsed from optimized HLO)
                   / (chips × 50e9)

Usage:
    python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (INPUT_SHAPES, ASSIGNED_ARCHS, MeshConfig,
                                ModelConfig, ShapeConfig, get_config)
from repro.data.pipeline import batch_specs
from repro.launch import mesh as mesh_lib, sharding
from repro.models import build
from repro.optim import adamw_init

# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Model-input ShapeDtypeStructs for a given input shape.

    VLM: seq_len positions = frontend patch positions + text tokens.
    audio (enc-dec): seq_len source frames + seq_len//4 target tokens.
    """
    B, S = shape.global_batch, shape.seq_len
    if cfg.encoder_layers:
        St = max(S // 4, 16)
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                           jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, St), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, St), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((B, St), jnp.float32),
            "weights": jax.ShapeDtypeStruct((B,), jnp.float32),
            "alive": jax.ShapeDtypeStruct((B,), jnp.float32),
        }
    specs = batch_specs(cfg, shape)
    if cfg.frontend == "vit_stub":
        P_ = min(cfg.frontend_tokens, S // 2)
        St = S - P_
        specs = dict(
            specs,
            tokens=jax.ShapeDtypeStruct((B, St), jnp.int32),
            labels=jax.ShapeDtypeStruct((B, St), jnp.int32),
            loss_mask=jax.ShapeDtypeStruct((B, St), jnp.float32),
            prefix_embeds=jax.ShapeDtypeStruct((B, P_, cfg.d_model),
                                               jnp.bfloat16),
        )
    return specs


# ---------------------------------------------------------------------------
# Collective-bytes parsing from optimized HLO
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\s(]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-category result-shape bytes of every collective op, plus an
    'effective wire bytes per chip' model:
      all-reduce       2× result (ring reduce-scatter + all-gather)
      all-gather       1× result
      reduce-scatter   1× operand ≈ result × shards (we charge result ×1
                       conservatively: per-chip egress ≈ result bytes)
      all-to-all       1× result
      collective-permute 1× result
    """
    per = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    count = {k: 0 for k in per}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        per[op] += _shape_bytes(dtype, dims)
        count[op] += 1
    wire = (2 * per["all-reduce"] + per["all-gather"]
            + per["reduce-scatter"] + per["all-to-all"]
            + per["collective-permute"])
    return {"bytes_by_op": per, "count_by_op": count,
            "wire_bytes": int(wire)}


# ---------------------------------------------------------------------------
# Lower + compile one (arch, shape, mesh)
# ---------------------------------------------------------------------------

def _step_and_args(cfg: ModelConfig, shape: ShapeConfig,
                   mesh_cfg: MeshConfig):
    """Returns (fn, arg_specs, in_shardings) for the shape's step kind."""
    model = build(cfg)
    pshape = jax.eval_shape(lambda k: model.init(k), jax.ShapeDtypeStruct(
        (2,), jnp.uint32))
    pspecs = sharding.param_specs(pshape, cfg, mesh_cfg)
    if shape.kind == "train":
        oshape = jax.eval_shape(adamw_init, pshape)
        ospecs = sharding.opt_specs(pspecs)
        bspecs_sd = input_specs(cfg, shape)
        bparts = sharding.batch_partition(cfg, shape, mesh_cfg)
        bparts = {k: bparts.get(k, jax.sharding.PartitionSpec())
                  for k in bspecs_sd}
        step = model.make_train_step()
        return (step, (pshape, oshape, bspecs_sd),
                (pspecs, ospecs, bparts), None)
    if shape.kind == "prefill":
        bspecs_sd = input_specs(cfg, shape)
        bspecs_sd = {k: v for k, v in bspecs_sd.items()
                     if k in ("tokens", "frames", "prefix_embeds")}
        bparts = sharding.batch_partition(cfg, shape, mesh_cfg)
        bparts = {k: bparts.get(k, jax.sharding.PartitionSpec())
                  for k in bspecs_sd}
        step = model.make_prefill_step(window=model.decode_window(shape))
        out_shardings = None
        if os.environ.get("REPRO_PREFILL_OUT_SHARD", "1") != "0":
            # Constrain the returned KV/state cache to the batch axis —
            # leaving it unconstrained lets GSPMD replicate the cache
            # (a giant all-gather; found via the §Perf roofline).
            out_shape = jax.eval_shape(step, pshape, bspecs_sd)
            cspec = sharding.cache_partition(out_shape[1], cfg, shape,
                                             mesh_cfg)
            out_shardings = (jax.sharding.PartitionSpec(), cspec)
        return step, (pshape, bspecs_sd), (pspecs, bparts), out_shardings
    # decode
    cache_shape = jax.eval_shape(
        lambda: model.init_serve_cache(shape, filled=True))
    cspecs = sharding.cache_partition(cache_shape, cfg, shape, mesh_cfg)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    dp = mesh_cfg.data * mesh_cfg.pod
    tparts = (jax.sharding.PartitionSpec(mesh_cfg.batch_axes, None)
              if shape.global_batch % dp == 0
              else jax.sharding.PartitionSpec(None, None))
    step = model.make_decode_step(window=model.decode_window(shape))
    return step, (pshape, cache_shape, tok), (pspecs, cspecs, tparts), None


def _apply_overrides(cfg: ModelConfig, overrides):
    """--set key=value config overrides for §Perf variants."""
    import dataclasses
    if not overrides:
        return cfg
    kw = {}
    for kv in overrides:
        k, v = kv.split("=", 1)
        field = {f.name: f for f in dataclasses.fields(cfg)}[k]
        typ = field.type if isinstance(field.type, type) else type(
            getattr(cfg, k))
        if typ is bool or isinstance(getattr(cfg, k), bool):
            kw[k] = v.lower() in ("1", "true", "yes")
        elif isinstance(getattr(cfg, k), int):
            kw[k] = int(v)
        elif isinstance(getattr(cfg, k), float):
            kw[k] = float(v)
        else:
            kw[k] = v
    return dataclasses.replace(cfg, **kw)


def dry_run_one(arch: str, shape_name: str, multi_pod: bool = False,
                donate: bool = True, overrides=None) -> dict:
    cfg = _apply_overrides(get_config(arch), overrides)
    shape = INPUT_SHAPES[shape_name]
    # tensor-parallel hint for the attention layout constraints
    # (see models/attention._tp_size; off with REPRO_TP_SIZE=0)
    os.environ.setdefault("REPRO_TP_SIZE", "16")
    mesh_cfg = MeshConfig(pod=2 if multi_pod else 1)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with jax.set_mesh(mesh):
        step, arg_shapes, in_shardings, out_shardings = _step_and_args(
            cfg, shape, mesh_cfg)
        as_named = lambda tree: jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s), tree,
            is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
        in_shardings = as_named(in_shardings)
        kw = {}
        if out_shardings is not None:
            kw["out_shardings"] = as_named(out_shardings)
        jitted = jax.jit(
            step, in_shardings=in_shardings,
            donate_argnums=(0, 1) if shape.kind == "train" else
            ((1,) if shape.kind == "decode" else ()), **kw)
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    chips = mesh_cfg.num_devices
    # cost_analysis runs on the SPMD-PARTITIONED module: flops/bytes and
    # the parsed collective shapes are already PER-DEVICE quantities, so
    # the roofline terms divide by per-chip peaks only.
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh_cfg.shape), "chips": chips,
        "kind": shape.kind,
        "unrolled": os.environ.get("REPRO_SCAN_UNROLL", "1"),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": bytes_accessed,
        "collectives": coll,
        "compute_s": flops / mesh_lib.PEAK_FLOPS_BF16,
        "memory_s": bytes_accessed / mesh_lib.HBM_BW,
        "collective_s": coll["wire_bytes"] / mesh_lib.ICI_BW,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    for attr in ("generated_code_size_in_bytes",
                 "argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes"):
        try:
            result[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    terms = {"compute": result["compute_s"], "memory": result["memory_s"],
             "collective": result["collective_s"]}
    result["dominant"] = max(terms, key=terms.get)
    # model FLOPs: 6·N_active·tokens (train), 2·N_active·tokens (fwd);
    # compared per-device against the compiled per-device FLOPs — the
    # ratio exposes remat recompute, attention quadratic terms and
    # dispatch overheads.
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    factor = 6 if shape.kind == "train" else 2
    result["model_flops_per_dev"] = (factor * cfg.active_param_count()
                                     * tokens / chips)
    result["useful_ratio"] = (result["model_flops_per_dev"] / flops
                              if flops else 0.0)
    return result


def protocol_dry_run(multi_pod: bool = False, m_total: int = 1 << 24,
                     coreset: int = 512,
                     hits_dtype=jnp.int32) -> dict:
    """Lower + compile the paper's own workload: one full BoostAttempt
    (T rounds of coreset-gather → center ERM → MW update) with the
    sample sharded over the mesh's data(×pod) axes — 16 (or 32)
    players, 2^24 examples.  This is the communication pattern of
    Figure 1 on the production mesh."""
    from repro.core import boost_attempt, weak
    from repro.core.types import BoostConfig
    mesh_cfg = MeshConfig(pod=2 if multi_pod else 1)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    k = mesh_cfg.data * mesh_cfg.pod
    cfg = BoostConfig(k=k, coreset_size=coreset, domain_size=1 << 20,
                      deterministic_coreset=True)
    cls = weak.Thresholds(n=1 << 20)
    T = cfg.num_rounds(m_total)
    axes = ("pod", "data") if multi_pod else ("data",)
    fn = boost_attempt.boost_attempt_sharded(mesh, cfg, cls, T,
                                             player_axes=axes)
    specs = (
        jax.ShapeDtypeStruct((m_total,), jnp.int32),   # x
        jax.ShapeDtypeStruct((m_total,), jnp.int8),    # y
        jax.ShapeDtypeStruct((m_total,), jnp.bool_),   # alive
        jax.ShapeDtypeStruct((m_total,), hits_dtype),  # hits
        jax.ShapeDtypeStruct((2,), jnp.uint32),        # key
    )
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn).lower(*specs)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    res = {
        "arch": "boosting-protocol", "shape": f"m{m_total}",
        "mesh": list(mesh_cfg.shape), "kind": "protocol",
        "rounds": T, "coreset": coreset, "players": k,
        "compile_s": round(time.time() - t0, 1),
        "hlo_flops_per_dev": float(cost.get("flops", 0.0)),
        "hlo_bytes_per_dev": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "compute_s": float(cost.get("flops", 0.0))
        / mesh_lib.PEAK_FLOPS_BF16,
        "memory_s": float(cost.get("bytes accessed", 0.0))
        / mesh_lib.HBM_BW,
        "collective_s": coll["wire_bytes"] / mesh_lib.ICI_BW,
    }
    terms = {"compute": res["compute_s"], "memory": res["memory_s"],
             "collective": res["collective_s"]}
    res["dominant"] = max(terms, key=terms.get)
    # NOTE: collectives/flops inside the while-loop body are counted
    # once by XLA; multiply by `rounds` for per-attempt totals.
    res["per_attempt_collective_s"] = res["collective_s"] * T
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--protocol", action="store_true")
    ap.add_argument("--set", dest="overrides", nargs="*", default=None,
                    help="config overrides, e.g. moe_dispatch=sort")
    ap.add_argument("--tag", default="",
                    help="suffix for the output JSON (variant name)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    if args.protocol:
        os.makedirs(args.out, exist_ok=True)
        res = protocol_dry_run(multi_pod=args.multi_pod)
        tag = ("boosting-protocol_"
               + ("2x16x16" if args.multi_pod else "16x16"))
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1)
        print(f"OK   {tag}: dominant={res['dominant']} "
              f"collective={res['collective_s']:.6f}s/round "
              f"(compile {res['compile_s']:.0f}s)")
        return
    os.makedirs(args.out, exist_ok=True)
    pairs = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in INPUT_SHAPES:
                pairs.append((arch, shape))
    else:
        pairs.append((args.arch, args.shape))
    failures = 0
    for arch, shape in pairs:
        tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
        if args.tag:
            tag += "_" + args.tag
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"SKIP {tag} (exists)")
            continue
        try:
            res = dry_run_one(arch, shape, multi_pod=args.multi_pod,
                              overrides=args.overrides)
            res["variant"] = args.tag or "baseline"
            res["overrides"] = args.overrides or []
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            print(f"OK   {tag}: dominant={res['dominant']} "
                  f"compute={res['compute_s']:.4f}s "
                  f"memory={res['memory_s']:.4f}s "
                  f"collective={res['collective_s']:.4f}s "
                  f"(compile {res['compile_s']:.0f}s)")
        except Exception as e:
            failures += 1
            print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:400]}")
            traceback.print_exc()
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
