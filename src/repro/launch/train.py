"""End-to-end training driver.

CPU-runnable at reduced scale (the quickstart/examples use it); the same
code path lowers to the production mesh when --mesh production is given
(requires real hardware or the dry-run device-count override).

Features: resilient-boosting data weighting + quarantine (the paper's
mechanism as a training flag), AdamW + warmup-cosine, checkpointing,
eval on a held-out clean split.

Usage (CPU):
    python -m repro.launch.train --arch deepseek-7b --smoke \
        --steps 200 --noise 0.1 --resilient
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import base
from repro.core import resilient
from repro.data import DataConfig, SyntheticCorpus
from repro.models import build
from repro.optim import adamw_init


def run(args) -> dict:
    cfg = base.get_config(args.arch)
    if args.smoke:
        cfg = base.reduced(cfg, d_model=args.d_model, vocab=args.vocab)
    model = build(cfg)
    dc = DataConfig(vocab_size=min(cfg.vocab_size, args.vocab),
                    seq_len=args.seq_len, num_examples=args.num_examples,
                    noise_frac=args.noise, seed=args.seed)
    corpus = SyntheticCorpus(dc)
    params = model.init(jax.random.key(args.seed))
    opt = adamw_init(params)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    train_step = jax.jit(model.make_train_step(
        lr=args.lr, warmup=max(args.steps // 10, 10),
        total_steps=args.steps))
    rc = resilient.ResilientConfig(
        num_examples=dc.num_examples, check_every=args.check_every,
        coreset_size=args.coreset, min_hits_gap=args.min_gap,
        mw_enabled=args.resilient, quarantine_enabled=args.resilient)
    state = resilient.init_state(rc)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    rng = np.random.default_rng(args.seed)
    history = []
    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = corpus.batch(rng, args.batch, alive=state.alive)
        w, alive = resilient.batch_weights(state, batch["ids"], rc)
        ids = batch.pop("ids")
        params, opt, met = train_step(
            params, opt, dict(batch, weights=w, alive=alive))
        state = resilient.update(state, ids, met["per_example_nll"],
                                 rc, step)
        if step % args.log_every == 0 or step == args.steps:
            stats = resilient.quarantine_stats(state, corpus.noisy_ids)
            rec = {"step": step, "loss": float(met["loss"]),
                   "grad_norm": float(met["grad_norm"]),
                   "elapsed_s": round(time.time() - t0, 1), **stats}
            history.append(rec)
            print(json.dumps(rec))
        if ckpt and step % args.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt})
    # clean-split eval: loss on non-noisy examples only
    clean = np.setdiff1d(np.arange(dc.num_examples), corpus.noisy_ids)
    eval_ids = clean[:min(256, clean.size)]
    eb = {
        "tokens": jnp.asarray(corpus.tokens[eval_ids]),
        "labels": jnp.asarray(corpus.labels[eval_ids]),
        "loss_mask": jnp.ones((eval_ids.size, dc.seq_len), jnp.float32),
        "weights": jnp.ones((eval_ids.size,)),
        "alive": jnp.ones((eval_ids.size,)),
    }
    _, em = jax.jit(model.loss_fn)(params, eb)
    result = {
        "arch": cfg.name, "params": int(n_params),
        "steps": args.steps, "resilient": bool(args.resilient),
        "noise": args.noise,
        "final_train_loss": float(met["loss"]),
        "clean_eval_loss": float(em["loss"]),
        **resilient.quarantine_stats(state, corpus.noisy_ids),
        "history": history,
    }
    print(json.dumps({k: v for k, v in result.items()
                      if k != "history"}))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--num-examples", type=int, default=2048)
    ap.add_argument("--noise", type=float, default=0.0)
    ap.add_argument("--resilient", action="store_true")
    ap.add_argument("--check-every", type=int, default=25)
    ap.add_argument("--coreset", type=int, default=48)
    ap.add_argument("--min-gap", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()
    run(args)


if __name__ == "__main__":
    main()
