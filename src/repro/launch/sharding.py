"""Sharding policy: parameter/batch/cache PartitionSpecs per (arch, shape).

Megatron-style tensor parallel on the ``model`` axis with safe fallback:
any dimension that does not divide the axis size is replicated (granite's
40 experts → per-expert hidden dim is sharded instead; kv-projections are
sharded on the flattened KV·hd dim, which divides 16 for every assigned
arch).  Batch is sharded over (pod, data); for the B=1 long-context
decode shape the KV cache is sharded over ``data`` along the *sequence*
axis instead (sequence parallelism over the cache — softmax reductions
cross the axis, which XLA decomposes into the max/sum all-reduce pair).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig

# param leaf names whose matmul OUTPUT dim is sharded (col-parallel)
_COL = {"wq", "wk", "wv", "wg", "wu", "up", "in_proj", "wx", "x_proj",
        "lm_head", "router", "wi", "wf", "dt_proj"}
# names whose INPUT dim is sharded (row-parallel: follows a col-parallel)
_ROW = {"wo", "wd", "down", "out_proj"}


def _path_names(path):
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def _n_stack_dims(names) -> int:
    """Leaves under blocks/encoder/decoder carry a leading stack axis."""
    return 1 if any(n in ("blocks", "encoder", "decoder") for n in names)\
        else 0


def param_spec_for(path, shape, cfg: ModelConfig, model_size: int):
    names = _path_names(path)
    stack = _n_stack_dims(names)
    body = len(shape) - stack
    lead = (None,) * stack

    def ok(dim_size):
        return dim_size % model_size == 0

    # --- embeddings -----------------------------------------------------
    if names[-1] == "emb":
        return P("model", None) if ok(shape[0]) else P(None, None)
    # find owning module name (parent of "w"/"b", or the leaf itself)
    owner = names[-2] if names[-1] in ("w", "b") else names[-1]
    # --- MoE expert tensors [E, D, F] / [E, F, D] ------------------------
    if owner in ("wg", "wu", "wd") and body == 3:
        E = shape[stack]
        if ok(E):
            return P(*lead, "model", None, None)       # expert parallel
        # tensor parallel inside experts: shard the per-expert hidden dim
        hid_axis = 2 if owner in ("wg", "wu") else 1
        if ok(shape[stack + hid_axis]):
            spec = [None, None, None]
            spec[hid_axis] = "model"
            return P(*lead, *spec)
        return P(*lead, None, None, None)
    # --- 2-D matmul weights ----------------------------------------------
    if names[-1] == "w" and body == 2:
        if owner in _COL and ok(shape[-1]):
            return P(*lead, None, "model")
        if owner in _ROW and ok(shape[-2]):
            return P(*lead, "model", None)
        return P(*lead, None, None)
    if names[-1] == "b" and body == 1:
        if owner in _COL and ok(shape[-1]):
            return P(*lead, "model")
        return P(*lead, None)
    # --- mamba/xlstm vectors over d_inner --------------------------------
    if names[-1] in ("A_log",) and body == 2:
        return P(*lead, "model", None) if ok(shape[stack]) \
            else P(*lead, None, None)
    if names[-1] in ("D", "dt_bias", "conv_b") and body == 1:
        return P(*lead, "model") if ok(shape[-1]) else P(*lead, None)
    if names[-1] == "conv_w" and body == 2:            # [cw, di]
        return P(*lead, None, "model") if ok(shape[-1]) \
            else P(*lead, None, None)
    # norms, scalars, recurrent R (heads rarely divide): replicate
    return P(*([None] * len(shape)))


def param_specs(params_shape, cfg: ModelConfig, mesh_cfg: MeshConfig):
    """Pytree of PartitionSpec matching an eval_shape'd param tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [param_spec_for(path, leaf.shape, cfg, mesh_cfg.model)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_partition(cfg: ModelConfig, shape: ShapeConfig,
                    mesh_cfg: MeshConfig):
    """PartitionSpecs for a training/prefill batch dict."""
    axes = mesh_cfg.batch_axes
    dp = mesh_cfg.data * mesh_cfg.pod
    baxes = axes if shape.global_batch % dp == 0 else ()
    b = baxes if baxes else None

    def spec2(extra=1):
        return P(b, *([None] * extra))

    specs = {
        "tokens": spec2(), "labels": spec2(), "loss_mask": spec2(),
        "weights": P(b), "alive": P(b),
    }
    if cfg.frontend == "vit_stub":
        specs["prefix_embeds"] = P(b, None, None)
    if cfg.encoder_layers:
        specs["frames"] = P(b, None, None)
    return specs


def cache_partition(cache_shape, cfg: ModelConfig, shape: ShapeConfig,
                    mesh_cfg: MeshConfig):
    """Specs for the serving cache pytree.

    Batch-shard when divisible; otherwise (long_500k, B=1) shard the
    attention cache over its sequence axis and recurrent states over
    their (model-sharded) feature axes — data-axis work is then the
    sequence-parallel softmax reduction.
    """
    dp = mesh_cfg.data * mesh_cfg.pod
    batch_ok = shape.global_batch % dp == 0
    baxes = mesh_cfg.batch_axes

    def leaf_spec(path, leaf):
        names = _path_names(path)
        nd = len(leaf.shape)
        name = names[-1]
        if batch_ok:
            # [nsb, B, ...]: shard dim 1
            if nd >= 2:
                return P(None, baxes, *([None] * (nd - 2)))
            return P(*([None] * nd))
        # B = 1 long-context: shard attn cache sequence (dim 2 of
        # [nsb, B, C, KV, hd]) over data; states over model where legal
        if name in ("k", "v") and nd == 5:
            C = leaf.shape[2]
            if C % mesh_cfg.data == 0:
                return P(None, None, "data", None, None)
            return P(None, None, None, None, None)
        if name == "h" and nd == 4:                    # mamba [nsb,B,di,ds]
            return P(None, None, "model", None) \
                if leaf.shape[2] % mesh_cfg.model == 0 else P(*[None] * 4)
        if name == "C" and nd == 5:                    # mlstm C
            return P(None, None, None, "model", None) \
                if leaf.shape[3] % mesh_cfg.model == 0 else P(*[None] * 5)
        if name in ("n",) and nd == 4:
            return P(None, None, None, "model") \
                if leaf.shape[3] % mesh_cfg.model == 0 else P(*[None] * 4)
        if name in ("h", "c", "n", "m") and nd == 3:   # slstm [nsb,B,D]
            return P(None, None, "model") \
                if leaf.shape[2] % mesh_cfg.model == 0 else P(*[None] * 3)
        if name == "conv" and nd == 4:                 # [nsb,B,cw-1,di]
            return P(None, None, None, "model") \
                if leaf.shape[3] % mesh_cfg.model == 0 else P(*[None] * 4)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(p, l) for p, l in flat])


def opt_specs(pspecs):
    """AdamW state: moments shard like params; step replicated."""
    return {"step": P(),
            "m": jax.tree.map(lambda s: s, pspecs),
            "v": jax.tree.map(lambda s: s, pspecs)}
