"""Batched serving drivers.

Two workloads share this entry point:

* ``--workload lm`` (default) — prefill a batch of prompts, decode N
  tokens.  CPU-runnable at reduced scale; the same prefill/decode steps
  are what the dry-run lowers at production shapes.
* ``--workload classify`` — serve B independent AccuratelyClassify
  boosting tasks as ONE device dispatch via the batched engine
  (core/batched.py), or, with ``--engine sharded``, over a real
  ``players`` device mesh (core/sharded_batched.py) where the per-round
  coreset/weight-sum exchange is an actual collective and the ledger is
  validated against the measured payloads.  ``--scenario`` picks the
  adversarial noise model (core/scenarios.py): uniform flips, targeted
  flips on the heaviest points, a byzantine player corrupting its whole
  shard, boundary-hugging noise, or drifting noise waves — or an
  *infrastructure* adversary (``dropout``/``flaky``/``rejoin``): a
  player-alive schedule silences ``--infra-player`` mid-protocol and
  the engines proceed with k′ < k players, reporting E_S(f) ≤ OPT over
  the surviving shards and the mask-aware communication ledger.
* ``--workload serve-stream`` — continuous batching: a stream of
  heterogeneous requests (mixed m, noise, scenario) replayed from a
  Poisson or bursty arrival trace through
  :mod:`repro.launch.scheduler`'s shape-bucketed compile cache.
  Reports tasks/sec, p50/p99 latency per bucket, and the cache
  hit/miss/compile counters (steady state after ``--warmup`` must show
  zero compiles).  ``--preempt D:R`` injects a preemption: dispatch D
  is cut off after R rounds, checkpointed to msgpack, requeued and
  resumed bit-identically.

Usage:
    python -m repro.launch.serve --arch qwen3-32b --smoke \
        --batch 4 --prompt-len 64 --gen 16
    python -m repro.launch.serve --workload classify \
        --batch 32 --m 512 --k 4 --noise 2
    python -m repro.launch.serve --workload classify --engine sharded \
        --scenario byzantine --batch 8 --m 512 --k 4
    python -m repro.launch.serve --workload serve-stream \
        --requests 64 --trace poisson --rate 100 --policy pack
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base
from repro.core.pinned import pinned_argmax
from repro.models import build, frontend
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def run(args) -> dict:
    cfg = base.get_config(args.arch)
    if args.smoke:
        cfg = base.reduced(cfg)
    model = build(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    B, P = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, P)), jnp.int32)}
    if cfg.frontend == "vit_stub":
        batch["prefix_embeds"] = frontend.synth_embeds(
            jax.random.key(1), cfg, B, cfg.frontend_tokens)
    if cfg.encoder_layers:
        batch["frames"] = frontend.synth_embeds(
            jax.random.key(1), cfg, B, P)
    prefill = jax.jit(model.make_prefill_step())
    decode = jax.jit(model.make_decode_step())
    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    tok = pinned_argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen):
        logits, caches = decode(params, caches, tok)
        tok = (pinned_argmax(logits, -1)[:, None]
               % cfg.vocab_size).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    result = {
        "arch": cfg.name, "batch": B, "prompt_len": P,
        "generated": args.gen,
        "prefill_s": round(t_prefill, 3),
        "decode_s_per_token": round(t_decode / max(args.gen, 1), 4),
        "tokens_finite": bool(jnp.all(gen >= 0)),
        "sample": np.asarray(gen[0])[:12].tolist(),
    }
    print(json.dumps(result))
    return result


def run_classify(args) -> dict:
    """Serve a batch of B boosting tasks in one jitted dispatch.

    ``--scenario dropout/flaky/rejoin`` picks an *infrastructure*
    adversary (core/scenarios.InfraSpec): the tasks carry the usual
    ``--noise`` uniform flips, and a player-alive schedule silences
    ``--infra-player`` per the adversary — the engines proceed with
    k′ < k players and the report pins E_S(f) ≤ OPT over the surviving
    shards plus the masked ledger (sharded engine validates it against
    the measured collective payloads).
    """
    from repro.core import batched, scenarios, sharded_batched, tasks, weak
    from repro.core.types import BoostConfig

    cls = weak.make_class(args.cls, n=args.domain,
                          num_features=args.features,
                          tree_depth=args.tree_depth,
                          tree_bins=args.tree_bins,
                          tree_comm_mode=args.comm_mode,
                          tree_vote_topk=args.vote_topk)
    cfg = BoostConfig(
        k=args.k, coreset_size=args.coreset, domain_size=args.domain,
        opt_budget=args.opt_budget,
        deterministic_coreset=not weak.needs_features(cls))
    B = args.batch
    infra = args.scenario if args.scenario in scenarios.INFRA else None
    noise_scenario = None if infra else args.scenario
    if noise_scenario in scenarios.FEATURE_SCENARIOS:
        _check_feature_scenario(noise_scenario, args)
    x, y, ts = tasks.make_batch(cls, B, args.m, args.k, args.noise,
                                seed0=args.seed,
                                scenario=noise_scenario)
    keys = jax.random.split(jax.random.key(args.seed), B)
    player_sched = None
    spec = None
    if infra:
        spec = scenarios.InfraSpec(
            name=infra, player=args.infra_player,
            drop_round=args.infra_round,
            rejoin_round=args.infra_round + args.infra_gap,
            miss_rate=args.infra_miss_rate)
        player_sched = spec.schedule(args.k, seed=args.seed)
    if args.engine == "sharded":
        run = functools.partial(
            sharded_batched.run_accurately_classify_sharded,
            mesh=sharded_batched.make_players_mesh(args.k))
    else:
        run = batched.run_accurately_classify_batched
    # compile once, then measure the steady-state dispatch
    run(x, y, keys, cfg, cls, player_sched=player_sched)
    t0 = time.time()
    res = run(x, y, keys, cfg, cls, player_sched=player_sched)
    wall = time.time() - t0
    result = {
        "workload": "classify", "engine": args.engine, "batch": B,
        "m": args.m, "k": args.k, "class": args.cls,
        "noise": args.noise, "scenario": args.scenario or "uniform",
        "ok": int(res.ok.sum()), "attempts_max": int(res.attempts.max()),
        "wall_s": round(wall, 4),
        "tasks_per_s": round(B / max(wall, 1e-9), 2),
    }
    if infra:
        reports = [scenarios.infra_report(ts[b], res, b, spec,
                                          seed=args.seed)
                   for b in range(B) if res.ok[b]]
        result["survivors"] = int(spec.survivors(
            args.k, seed=args.seed).sum())
        result["guarantee_ok_survivors"] = int(
            sum(r["guarantee_ok"] for r in reports))
        result["bits_max"] = max((r["bits"] for r in reports), default=0)
    elif args.scenario is not None:
        # the adversary decides how much it corrupts (byzantine flips a
        # whole shard regardless of --noise): report what was planted
        result["noise"] = max(int(t.noise_count) for t in ts)
        reports = [scenarios.scenario_report(ts[b], res, b)
                   for b in range(B) if res.ok[b]]
        result["guarantee_ok"] = int(sum(r["guarantee_ok"]
                                         for r in reports))
        result["recall_contradicted_min"] = round(
            min((r["recall_contradicted"] for r in reports),
                default=1.0), 3)
        result["bits_max"] = max((r["bits"] for r in reports), default=0)
    if args.engine == "sharded":
        validated = 0
        for b in range(B):
            if res.ok[b]:
                res.validate_ledger(b)
                validated += 1
        result["mesh_devices"] = int(res.mesh_devices)
        result["ledger_vs_payload"] = (f"validated_{validated}/{B}"
                                       if validated else "no_ok_lanes")
        result["collective_bytes_max"] = int(res.wire_bytes.max())
    print(json.dumps(result))
    return result


def _check_feature_scenario(name: str, args) -> None:
    """Up-front validation of a planted-concept scenario: needs the
    tree class at sufficient depth — fail at argument time, not deep
    inside task construction (or after a serve-stream cache warm)."""
    from repro.core import scenarios

    if args.cls != "tree":
        raise SystemExit(
            f"--scenario {name} plants a tree concept: run it "
            "with --cls tree (--tree-depth/--tree-bins)")
    need = scenarios.ScenarioSpec(name=name).min_tree_depth()
    if args.tree_depth < need:
        raise SystemExit(
            f"--scenario {name} needs --tree-depth ≥ {need} "
            f"(got {args.tree_depth})")
    if name in ("xor", "checkerboard") and args.features < 2:
        raise SystemExit(
            f"--scenario {name} crosses two features: needs "
            f"--features ≥ 2 (got {args.features})")


def _next_pow2(v: int) -> int:
    return 1 << max(v - 1, 1).bit_length()


def run_serve_stream(args) -> dict:
    """Replay a mixed-shape request stream through the scheduler.

    ``--preempt D:R`` (repeatable) injects an infrastructure failure:
    the D-th dispatch is cut off after R wire rounds, its engine state
    checkpointed to ``--ckpt-dir`` (msgpack), and the batch requeued —
    the resumed completions are still bit-identical to ``one_shot``.
    """
    from repro.core import scenarios
    from repro.launch import scheduler as S

    if args.m % (2 * args.k):
        raise SystemExit(
            f"--m {args.m} must be a multiple of 2*k={2 * args.k}: the "
            "serve-stream shape mix includes m/2, and every shape's k "
            "shards must be equal-sized")
    if args.scenario in scenarios.INFRA:
        raise SystemExit(
            f"--scenario {args.scenario} is an infrastructure adversary "
            "— use --workload classify for player schedules, or "
            "--preempt for serve-stream fault injection")
    n = args.requests
    shapes = [
        {"m": args.m // 2, "noise": 0},
        {"m": args.m, "noise": args.noise},
        {"m": args.m * 2, "noise": args.noise,
         "scenario": args.scenario},
    ]
    preempt = {}
    for spec in args.preempt or []:
        d, r = spec.split(":")
        preempt[int(d)] = int(r)
    if args.trace == "bursty":
        arrivals = S.bursty_trace(n, rate_per_s=args.rate,
                                  burst=args.burst, seed=args.seed)
    else:
        arrivals = S.poisson_trace(n, rate_per_s=args.rate,
                                   seed=args.seed)
    if args.scenario in scenarios.FEATURE_SCENARIOS:
        _check_feature_scenario(args.scenario, args)
    reqs = S.make_request_stream(
        n, arrivals, shapes, seed0=args.seed, k=args.k,
        clsname=args.cls, domain=args.domain,
        num_features=args.features,
        tree_depth=args.tree_depth, tree_bins=args.tree_bins,
        tree_comm_mode=args.comm_mode, tree_vote_topk=args.vote_topk,
        coreset_size=args.coreset, opt_budget=args.opt_budget,
        engine=args.engine)
    # one lattice point per distinct shape: the next power of two over
    # each shape's per-player mloc (deduped, so nearby shapes share)
    lattice = S.BucketLattice(
        b_sizes=(1, 4, 8),
        mloc_sizes=tuple(sorted({_next_pow2(s["m"] // args.k)
                                 for s in shapes})))
    sched = S.BoostScheduler(lattice=lattice, policy=args.policy,
                             fill_wait_s=args.fill_wait,
                             ckpt_dir=args.ckpt_dir if preempt else None,
                             preempt=preempt)
    if args.warmup:
        sched.warm(reqs)                # compile every reachable bucket
    warm = dataclasses.replace(sched.cache.stats)
    done = sched.run_stream(reqs)
    reg = obs_metrics.default_registry()
    obs_metrics.publish_cache_stats(sched.cache.stats, reg)
    obs_metrics.publish_scheduler_stats(sched.stats, reg)
    result = {
        "workload": "serve-stream", "engine": args.engine,
        "trace": args.trace, "policy": args.policy,
        "requests": n, "dispatches": sched.stats.dispatches,
        "padded_requests": sched.stats.padded_requests,
        "filler_lanes": sched.stats.filler_lanes,
        "preemptions": sched.stats.preemptions,
        "resumes": sched.stats.resumes,
        "cache_hits": sched.cache.stats.hits,
        "cache_compiles": sched.cache.stats.compiles,
        "steady_compiles": sched.cache.stats.compiles - warm.compiles,
        "ok": sum(c.ok for c in done),
        **S.latency_summary(done),
    }
    if args.engine == "sharded":
        result["ledger_validated"] = sum(
            bool(c.validate_ledger()) for c in done if c.ok)
    print(json.dumps(result))
    return result


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI surface — exposed so the examples smoke test can
    assert documented flags (e.g. ``--comm-mode``/``--vote-topk``)
    actually parse without running a workload."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm",
                    choices=["lm", "classify", "serve-stream"])
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    # classify workload
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--noise", type=int, default=2)
    ap.add_argument("--cls", default="thresholds",
                    choices=["singletons", "thresholds", "intervals",
                             "stumps", "tree"])
    ap.add_argument("--domain", type=int, default=1 << 12)
    ap.add_argument("--coreset", type=int, default=100)
    ap.add_argument("--features", type=int, default=8)
    ap.add_argument("--tree-depth", type=int, default=2,
                    help="--cls tree: tree depth D (2^D leaves)")
    ap.add_argument("--tree-bins", type=int, default=32,
                    help="--cls tree: histogram bins Q (power of two)")
    ap.add_argument("--comm-mode", default="coreset",
                    choices=["coreset", "histogram", "voting"],
                    help="--cls tree: how split finding crosses the "
                         "wire (coreset gather, histogram merge, or "
                         "LightGBM-style parallel voting)")
    ap.add_argument("--vote-topk", type=int, default=2,
                    help="--comm-mode voting: proposals per node per "
                         "player")
    ap.add_argument("--opt-budget", type=int, default=16)
    ap.add_argument("--engine", default="batched",
                    choices=["batched", "sharded"])
    ap.add_argument("--scenario", default=None,
                    choices=[None, "clean", "uniform", "targeted_heavy",
                             "byzantine", "boundary", "drift",
                             "xor", "checkerboard", "bands",
                             "dropout", "flaky", "rejoin"])
    # infrastructure adversaries (--scenario dropout/flaky/rejoin)
    ap.add_argument("--infra-player", type=int, default=1,
                    help="player the infra adversary silences")
    ap.add_argument("--infra-round", type=int, default=5,
                    help="wire round the player first goes absent")
    ap.add_argument("--infra-gap", type=int, default=8,
                    help="rejoin: rounds absent before returning")
    ap.add_argument("--infra-miss-rate", type=float, default=0.3,
                    help="flaky: per-round absence probability")
    # serve-stream workload
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--trace", default="poisson",
                    choices=["poisson", "bursty"])
    ap.add_argument("--rate", type=float, default=100.0)
    ap.add_argument("--burst", type=int, default=8)
    ap.add_argument("--policy", default="pack",
                    choices=["pack", "fill"])
    ap.add_argument("--fill-wait", type=float, default=0.05)
    ap.add_argument("--warmup", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--preempt", action="append", metavar="D:R",
                    help="preempt dispatch D after R wire rounds "
                         "(repeatable); state checkpoints to --ckpt-dir")
    ap.add_argument("--ckpt-dir", default="experiments/preempt_ckpt")
    # observability (repro/obs): host-span tracing + metrics snapshot
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record host protocol spans and write a "
                         "Chrome/Perfetto trace JSON here (load it at "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics registry (scheduler/cache "
                         "counters, ckpt timing histograms) as JSON")
    return ap


def main():
    args = build_parser().parse_args()
    rec = obs_trace.enable() if args.trace_out else None
    try:
        if args.workload == "serve-stream":
            run_serve_stream(args)
        elif args.workload == "classify":
            run_classify(args)
        else:
            run(args)
    finally:
        if rec is not None:
            obs_trace.disable()
            rec.save(args.trace_out)
        if args.metrics_out:
            obs_metrics.default_registry().save(args.metrics_out)


if __name__ == "__main__":
    main()
