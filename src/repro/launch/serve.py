"""Batched serving driver: prefill a batch of prompts, decode N tokens.

CPU-runnable at reduced scale; the same prefill/decode steps are what
the dry-run lowers at production shapes.

Usage:
    python -m repro.launch.serve --arch qwen3-32b --smoke \
        --batch 4 --prompt-len 64 --gen 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base
from repro.models import build, frontend


def run(args) -> dict:
    cfg = base.get_config(args.arch)
    if args.smoke:
        cfg = base.reduced(cfg)
    model = build(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    B, P = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, P)), jnp.int32)}
    if cfg.frontend == "vit_stub":
        batch["prefix_embeds"] = frontend.synth_embeds(
            jax.random.key(1), cfg, B, cfg.frontend_tokens)
    if cfg.encoder_layers:
        batch["frames"] = frontend.synth_embeds(
            jax.random.key(1), cfg, B, P)
    prefill = jax.jit(model.make_prefill_step())
    decode = jax.jit(model.make_decode_step())
    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen):
        logits, caches = decode(params, caches, tok)
        tok = (jnp.argmax(logits, -1)[:, None]
               % cfg.vocab_size).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    result = {
        "arch": cfg.name, "batch": B, "prompt_len": P,
        "generated": args.gen,
        "prefill_s": round(t_prefill, 3),
        "decode_s_per_token": round(t_decode / max(args.gen, 1), 4),
        "tokens_finite": bool(jnp.all(gen >= 0)),
        "sample": np.asarray(gen[0])[:12].tolist(),
    }
    print(json.dumps(result))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args)


if __name__ == "__main__":
    main()
