"""Production mesh definitions (TPU v5e).

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the ``pod``
axis carries the data-parallel gradient all-reduce across the inter-pod
links (DCN in real deployments; the dry-run proves the sharding is
coherent across the axis).

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first init — see dryrun.py, which
must set XLA_FLAGS before anything else).
"""

from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType`` itself) only exist on newer jax; the
    pinned 0.4.x toolchain takes the two-argument form."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host actually has — for smoke tests / CPU runs."""
    n = len(jax.devices())
    data = n // model
    return make_mesh_compat((data, model), ("data", "model"))


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (conservative single link)
VMEM_BYTES = 16 * 2 ** 20
