"""Continuous-batching task server for heterogeneous boosting requests.

The one-shot drivers (``launch/serve.py --workload classify``) run one
homogeneous batch per process: every request must share (m, k, coreset,
scenario, engine), and each new shape pays a fresh jit compile.  This
module serves a *stream* of mixed requests through the existing engines
with none of that:

* **Shape bucketing.**  Requests are padded up to a small lattice of
  canonical (B, mloc) buckets — per-player shards pad to the next
  lattice ``mloc`` with dead rows (``tasks.pad_shards``; bit-safe per
  tests/test_batched.py), short batches fill lanes by duplicating a
  live lane (``batched.stack_for_dispatch``).  Engine statics (k,
  BoostConfig, hypothesis class, engine kind) partition requests into
  *compat groups*; noise level and scenario are data, so one in-flight
  batch freely mixes adversaries.

* **Compile cache.**  Each bucket's program is AOT-compiled once
  (``batched.lower_classify`` / ``sharded_batched.lower_classify_sharded``)
  and held in an LRU cache keyed on (compat, B, mloc).  Steady-state
  traffic hits the cache — zero recompiles, counters exposed in
  ``SchedulerStats`` and asserted in tests/test_scheduler.py.  The
  cache owns its executables, so eviction past the capacity really
  frees the program and a re-admission really recompiles.

* **Continuous admission.**  A virtual clock replays an arrival trace
  (Poisson or bursty, ``poisson_trace``/``bursty_trace``); while a
  batch is in flight new arrivals queue up, and when the dispatch
  returns the freed slots are refilled from the queue — iteration-level
  batching at the dispatch granularity (a jitted while-loop program
  cannot be entered mid-flight, so the admission quantum is one
  dispatch).  Two policies: ``pack`` dispatches as soon as any request
  is queued (smallest bucket B that covers the queue), ``fill`` holds
  admission until a full max-B batch is ready or ``fill_wait_s`` has
  passed for the oldest request.

* **Preemption + checkpoint/resume.**  The engines execute round-
  granularly (``init_state / run_rounds / finalize``), so a dispatch
  can be cut off after N wire rounds, its whole protocol state
  serialized to a msgpack checkpoint (``ckpt/msgpack_ckpt``), and the
  batch **requeued**: the next scheduler step restores the state from
  the file and runs the remaining rounds.  The checkpoint path is
  built not to stall the dispatch loop: saves go through a single
  off-thread writer (the loop pays only the device→host copy, not
  packb+fsync+rename), a re-preempted batch re-checkpoints
  **incrementally** (only leaves whose content hash changed since the
  previous snapshot of that dispatch, chained to it), and the resume
  restores **template-free** from the checkpoint's own manifest — no
  engine init runs just to build a ``like=`` template.  A preempted-
  and-resumed request completes bit-identical to its uninterrupted
  ``one_shot`` run — the same parity bar PR 3 set for batching (the
  step body is one program; the state round-trips exactly).
  ``preempt={dispatch: rounds}`` injects failures into ``run_stream``
  deterministically (resumes consume dispatch seqs too, so an entry
  can hit one); ``stats.preemptions``/``stats.resumes`` count them.

Every completion is bit-identical to the one-shot engine run of the
same padded request (``BoostScheduler.one_shot`` is that baseline;
tests pin it per request, plus host-reference parity on a sample), and
sharded completions carry ``validate_ledger``-checkable wire counters.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import time
from typing import Callable

import jax
import numpy as np

from repro.ckpt import msgpack_ckpt
from repro.core import batched, scenarios, sharded_batched, tasks, weak
from repro.core.types import BoostConfig
from repro.obs import trace as obs_trace


# ---------------------------------------------------------------------------
# Requests and their generated payloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Request:
    """One boosting task as a serving request (hashable, self-seeded)."""

    rid: int
    m: int = 256                 # total sample size (k must divide it)
    k: int = 4
    noise: int = 0
    clsname: str = "thresholds"
    domain: int = 1 << 12
    num_features: int = 8
    tree_depth: int = 2          # clsname == "tree": depth / bin grid
    tree_bins: int = 32
    tree_comm_mode: str = "coreset"  # coreset | histogram | voting
    tree_vote_topk: int = 2
    coreset_size: int = 100
    opt_budget: int = 16
    scenario: str | None = None  # core/scenarios.py adversary, or uniform
    engine: str = "batched"      # "batched" | "sharded"
    seed: int = 0
    arrival_s: float = 0.0

    def make_cls(self):
        return weak.make_class(self.clsname, n=self.domain,
                               num_features=self.num_features,
                               tree_depth=self.tree_depth,
                               tree_bins=self.tree_bins,
                               tree_comm_mode=self.tree_comm_mode,
                               tree_vote_topk=self.tree_vote_topk)

    def make_cfg(self) -> BoostConfig:
        # feature-row classes (stumps, trees) use the randomized
        # coreset — a capability of the class, not a name special-case
        return BoostConfig(
            k=self.k, coreset_size=self.coreset_size,
            domain_size=self.domain, opt_budget=self.opt_budget,
            deterministic_coreset=not weak.needs_features(
                self.make_cls()))

    def make_task(self) -> tasks.Task:
        if self.scenario is not None:
            return scenarios.make_scenario_task(
                self.make_cls(), m=self.m, k=self.k,
                spec=scenarios.ScenarioSpec(name=self.scenario,
                                            noise=self.noise),
                seed=self.seed)
        return tasks.make_task(self.make_cls(), m=self.m, k=self.k,
                               noise=self.noise, seed=self.seed)

    def make_key(self):
        return jax.random.key(self.seed)


@dataclasses.dataclass(frozen=True)
class CompatKey:
    """Engine statics — requests in one dispatch must share these."""

    engine: str
    cfg: BoostConfig
    cls: object

    @classmethod
    def of(cls_, req: Request) -> "CompatKey":
        return cls_(engine=req.engine, cfg=req.make_cfg(),
                    cls=req.make_cls())


@dataclasses.dataclass(frozen=True)
class BucketKey:
    compat: CompatKey
    B: int
    mloc: int


# ---------------------------------------------------------------------------
# The bucket lattice
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketLattice:
    """Canonical (B, mloc) grid requests are padded up to.

    Small on purpose: each lattice point is one compiled program, and
    steady-state traffic should touch a handful.  ``mloc`` rounds up to
    the next lattice value (never down — padding is dead rows, not
    truncation); ``B`` is chosen per dispatch by the admission policy.
    """

    b_sizes: tuple = (1, 4, 8)
    mloc_sizes: tuple = (64, 128, 256)

    def bucket_mloc(self, mloc: int) -> int:
        for s in self.mloc_sizes:
            if mloc <= s:
                return s
        raise ValueError(
            f"mloc={mloc} exceeds lattice {self.mloc_sizes!r}")

    def bucket_b(self, queued: int) -> int:
        for s in self.b_sizes:
            if queued <= s:
                return s
        return self.b_sizes[-1]

    @property
    def max_b(self) -> int:
        return self.b_sizes[-1]


# ---------------------------------------------------------------------------
# The compile cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    compiles: int = 0            # == misses; kept separate so tests can
    compile_s: float = 0.0       # assert "recompiled exactly once"


class CompileCache:
    """LRU of AOT-compiled bucket programs.

    Keyed on :class:`BucketKey`; the values are ``jax.stages.Compiled``
    executables owned by this cache — unlike the implicit jit cache,
    evicting one really frees it and the next admission of that bucket
    really recompiles (tests assert exactly-once).  ``capacity=None``
    means unbounded (the lattice already bounds the population).
    """

    def __init__(self, capacity: int | None = None):
        self.capacity = capacity
        self.stats = CacheStats()
        self._entries: "collections.OrderedDict" = collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: BucketKey, build: Callable[[], object]):
        if key in self._entries:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return self._entries[key]
        t0 = time.perf_counter()
        with obs_trace.span("compile", "compile", scope="scheduler",
                            B=key.B, mloc=key.mloc,
                            engine=getattr(key.compat, "engine", str(key.compat))):
            compiled = build()
        self.stats.compile_s += time.perf_counter() - t0
        self.stats.misses += 1
        self.stats.compiles += 1
        self._entries[key] = compiled
        if self.capacity is not None and len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
        return compiled


# ---------------------------------------------------------------------------
# Completions + stats
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Completion:
    """One served request: its lane of a bucket dispatch."""

    request: Request
    task: tasks.Task
    result: batched.BatchedClassifyResult   # the whole dispatch
    lane: int
    bucket: BucketKey
    queue_wait_s: float          # arrival → dispatch start (virtual)
    service_s: float             # dispatch wall time (shared by lanes)
    latency_s: float             # arrival → completion (virtual)
    resumed: bool = False        # completed via checkpoint-resume

    @property
    def ok(self) -> bool:
        return bool(self.result.ok[self.lane])

    def per_task(self):
        return self.result.per_task(self.lane)

    def classifier(self):
        return self.result.classifier(self.lane)

    def validate_ledger(self) -> dict:
        """Theorem 4.1 accounting ≡ this completion's measured
        collective payloads (docs/ledger.md walks the checked fields);
        sharded dispatches only."""
        if not isinstance(self.result,
                          sharded_batched.ShardedClassifyResult):
            raise TypeError("wire validation needs the sharded engine")
        return self.result.validate_ledger(self.lane)


@dataclasses.dataclass
class SchedulerStats:
    dispatches: int = 0
    served: int = 0
    filler_lanes: int = 0
    padded_requests: int = 0
    preemptions: int = 0
    resumes: int = 0
    # (B, mloc, engine) -> (served real lanes, dispatched lane capacity)
    # — capacity accumulates B per dispatch, so served/capacity is the
    # bucket's lane occupancy (repro.obs.metrics.publish_scheduler_stats
    # exports all three as gauges)
    per_bucket: dict = dataclasses.field(default_factory=dict)

    def note(self, bucket: BucketKey, n_real: int, B: int):
        self.dispatches += 1
        self.served += n_real
        self.filler_lanes += B - n_real
        key = (bucket.B, bucket.mloc, bucket.compat.engine)
        served, capacity = self.per_bucket.get(key, (0, 0))
        self.per_bucket[key] = (served + n_real, capacity + B)


@dataclasses.dataclass
class _Suspended:
    """A preempted in-flight batch, requeued for resume.

    The protocol state lives in the msgpack checkpoint chain (the tip
    is ``ckpt_path``; ``paths`` holds every file of the chain for
    cleanup); the static inputs (the stacked sample arrays and keys —
    regenerable from the requests, kept here to avoid rebuilding) ride
    along."""

    bucket: BucketKey
    admitted: list               # the (req, task, data) tuples
    payload: tuple               # stacked (x, y, alive, keys)
    m_true: np.ndarray
    ckpt_path: str               # chain tip — what a resume restores
    rounds_done: int
    chain: str = ""              # writer chain id (incremental diffing)
    paths: tuple = ()            # every file of the chain, for cleanup


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def latency_summary(completions) -> dict:
    """tasks/sec + p50/p99 latency, overall and per bucket."""
    if not completions:
        return {"served": 0}
    lats = [c.latency_s for c in completions]
    span = max(c.latency_s + c.request.arrival_s for c in completions)
    out = {
        "served": len(completions),
        "tasks_per_s": round(len(completions) / max(span, 1e-9), 2),
        "p50_latency_s": round(_percentile(lats, 50), 4),
        "p99_latency_s": round(_percentile(lats, 99), 4),
        "buckets": {},
    }
    by_bucket = collections.defaultdict(list)
    for c in completions:
        by_bucket[(c.bucket.B, c.bucket.mloc,
                   c.bucket.compat.engine)].append(c.latency_s)
    for bk, ls in sorted(by_bucket.items()):
        out["buckets"][f"B{bk[0]}_mloc{bk[1]}_{bk[2]}"] = {
            "served": len(ls),
            "p50_latency_s": round(_percentile(ls, 50), 4),
            "p99_latency_s": round(_percentile(ls, 99), 4),
        }
    return out


# ---------------------------------------------------------------------------
# The scheduler
# ---------------------------------------------------------------------------

class BoostScheduler:
    """Continuous-batching server over the batched/sharded engines.

    ``run_stream`` replays an arrival-stamped request list against a
    virtual clock: compute time is measured wall time, arrival time is
    the trace's.  ``submit``/``step`` expose the same machinery for
    open-loop driving.
    """

    def __init__(self, lattice: BucketLattice | None = None,
                 policy: str = "pack", fill_wait_s: float = 0.05,
                 cache_capacity: int | None = None,
                 cache: CompileCache | None = None,
                 ckpt_dir: str | None = None,
                 preempt: dict | None = None):
        if policy not in ("pack", "fill"):
            raise ValueError(f"unknown policy {policy!r}")
        self.lattice = lattice or BucketLattice()
        self.policy = policy
        self.fill_wait_s = fill_wait_s
        # ``cache`` lets several schedulers (e.g. a policy comparison)
        # share one pool of compiled programs
        if cache is not None and cache_capacity is not None:
            raise ValueError(
                "pass either cache= (shared, already sized) or "
                "cache_capacity=, not both")
        self.cache = cache or CompileCache(capacity=cache_capacity)
        # fault injection: {dispatch_seq: wire_rounds} — the seq-th
        # engine dispatch is preempted after that many rounds, its
        # state checkpointed to ckpt_dir and the batch requeued.  A
        # RESUME consumes a dispatch seq too, so injecting on it
        # preempts the same batch again — the re-checkpoint is then an
        # incremental snapshot chained to the previous one (only leaves
        # whose content changed are serialized).
        self.preempt = dict(preempt or {})
        self.ckpt_dir = ckpt_dir
        if self.preempt and not self.ckpt_dir:
            raise ValueError("preempt= injection needs ckpt_dir= (the "
                             "msgpack state has to land somewhere)")
        self.stats = SchedulerStats()
        self._queues: dict = collections.defaultdict(collections.deque)
        self._suspended: collections.deque = collections.deque()
        self._dispatch_seq = 0
        self._meshes: dict = {}
        self._writer: msgpack_ckpt.AsyncCheckpointer | None = None

    # -- request intake ----------------------------------------------------

    def submit(self, req: Request):
        """Generate the request's task data, pad it to its bucket mloc
        and enqueue it.  Queues are per (compat, bucket-mloc): a padded
        request's PRNG stream depends on its padded shape (the
        randomized coreset draws per-row), so re-padding at admission
        would break bit-parity with the one-shot baseline — each
        request is padded exactly once, here."""
        if req.m % req.k:
            raise ValueError(f"k={req.k} must divide m={req.m}")
        task = req.make_task()
        mloc_b = self.lattice.bucket_mloc(req.m // req.k)
        x, y, alive = tasks.pad_shards(task.x, task.y, mloc_b)
        if alive.shape[1] != req.m // req.k:
            self.stats.padded_requests += 1
        self._queues[(CompatKey.of(req), mloc_b)].append(
            (req, task, (x, y, alive, req.make_key())))

    def queued(self) -> int:
        return (sum(len(q) for q in self._queues.values())
                + sum(len(s.admitted) for s in self._suspended))

    # -- one dispatch ------------------------------------------------------

    def _mesh(self, k: int):
        if k not in self._meshes:
            self._meshes[k] = sharded_batched.make_players_mesh(k)
        return self._meshes[k]

    def _compiled(self, bucket: BucketKey, x, y, alive, keys):
        compat = bucket.compat
        if compat.engine == "sharded":
            build = lambda: sharded_batched.lower_classify_sharded(  # noqa: E731
                x, y, alive, keys, compat.cfg, compat.cls,
                mesh=self._mesh(compat.cfg.k))
        else:
            build = lambda: batched.lower_classify(  # noqa: E731
                x, y, alive, keys, compat.cfg, compat.cls)
        return self.cache.get(bucket, build)

    def _dispatch(self, bucket: BucketKey, x, y, alive, keys, m_true):
        """Compile-cache lookup + engine run → (result, service_s).

        ``service_s`` excludes any cache-miss compile — ``run_stream``
        charges compile time separately from the cache's ``compile_s``
        counter.
        """
        compiled = self._compiled(bucket, x, y, alive, keys)
        compat = bucket.compat
        t0 = time.perf_counter()
        with obs_trace.span("dispatch", "scheduler",
                            engine=compat.engine, B=bucket.B,
                            mloc=bucket.mloc):
            if compat.engine == "sharded":
                res = sharded_batched.run_accurately_classify_sharded(
                    x, y, keys, compat.cfg, compat.cls,
                    mesh=self._mesh(compat.cfg.k), alive=alive,
                    compiled=compiled, m_true=m_true)
            else:
                res = batched.run_accurately_classify_batched(
                    x, y, keys, compat.cfg, compat.cls, alive=alive,
                    compiled=compiled, m_true=m_true)
        return res, time.perf_counter() - t0

    # -- round-granular engine access (preemption path) --------------------

    def _engine_init(self, bucket: BucketKey, x, y, alive, keys):
        compat = bucket.compat
        if compat.engine == "sharded":
            return sharded_batched.init_state_sharded(
                x, y, keys, compat.cfg, alive=alive, cls=compat.cls)
        return batched.init_state(x, y, keys, compat.cfg, alive=alive,
                                  cls=compat.cls)

    def _engine_run(self, bucket: BucketKey, state, x, y, n):
        compat = bucket.compat
        if compat.engine == "sharded":
            return sharded_batched.run_rounds_sharded(
                state, x, y, compat.cfg, compat.cls,
                mesh=self._mesh(compat.cfg.k), n=n)
        return batched.run_rounds(state, x, y, compat.cfg, compat.cls,
                                  n=n)

    def _engine_finalize(self, bucket: BucketKey, state, x, y, alive,
                         m_true):
        compat = bucket.compat
        if compat.engine == "sharded":
            return sharded_batched.finalize_sharded(
                state, x, y, alive, compat.cfg, compat.cls,
                m_true=m_true, mesh=self._mesh(compat.cfg.k))
        return batched.finalize(state, x, y, alive, compat.cfg,
                                compat.cls, m_true=m_true)

    def _ckpt_writer(self) -> msgpack_ckpt.AsyncCheckpointer:
        if self._writer is None:
            self._writer = msgpack_ckpt.AsyncCheckpointer()
        return self._writer

    def _state_treedef(self, bucket: BucketKey) -> str:
        return (sharded_batched.STATE_TREEDEF
                if bucket.compat.engine == "sharded"
                else batched.STATE_TREEDEF)

    def _checkpoint(self, seq: int, bucket: BucketKey, state, admitted,
                    rounds_done: int, chain: str) -> str:
        """Hand the state to the writer thread (caller pays only the
        device→host copy); first save of a chain is a full snapshot,
        later ones serialize only changed leaves."""
        os.makedirs(self.ckpt_dir, exist_ok=True)
        path = os.path.join(self.ckpt_dir, f"preempt_{seq:04d}.msgpack")
        # the span covers only what the loop pays (device→host copy +
        # enqueue); the writer thread's own packb+fsync time lands in
        # the ckpt.save_s metric histogram (ckpt/msgpack_ckpt.py)
        with obs_trace.span("ckpt_save", "checkpoint", path=path,
                            rounds_done=rounds_done, chain=chain):
            self._ckpt_writer().save(
                path, state,
                meta={"rounds_done": rounds_done,
                      "engine": bucket.compat.engine,
                      "rids": [a[0].rid for a in admitted]},
                treedef=self._state_treedef(bucket), chain=chain)
        return path

    def _preempt_dispatch(self, seq: int, bucket: BucketKey, admitted,
                          payload, m_true, n_rounds: int):
        """Run ``n_rounds`` wire rounds, checkpoint the protocol state
        to msgpack (async, off-thread), drop it, and requeue the batch
        for resume."""
        x, y, alive, keys = payload
        t0 = time.perf_counter()
        with obs_trace.span("preempt", "scheduler", seq=seq,
                            rounds=n_rounds,
                            engine=bucket.compat.engine):
            state = self._engine_init(bucket, x, y, alive, keys)
            state = self._engine_run(bucket, state, x, y, n=n_rounds)
            chain = f"d{seq:04d}"
            path = self._checkpoint(seq, bucket, state, admitted,
                                    n_rounds, chain)
            del state                          # the preemption: state dies
        self._suspended.append(_Suspended(
            bucket=bucket, admitted=admitted, payload=payload,
            m_true=m_true, ckpt_path=path, rounds_done=n_rounds,
            chain=chain, paths=(path,)))
        self.stats.preemptions += 1
        return [], time.perf_counter() - t0

    def _resume(self, sus: _Suspended, seq: int, now: float):
        """Restore a preempted batch from its checkpoint and continue.

        The restore is **template-free**: the checkpoint manifest
        carries the state's treedef name + per-leaf dtypes, so no
        engine init runs (the old path burned discarded device compute
        and a fresh PRNG stream just to build a ``like=`` template).
        A resume consumes a dispatch seq, so an injected ``preempt``
        entry for it cuts the SAME batch off again — the re-checkpoint
        chains incrementally to the previous snapshot.  The whole
        chain is deleted once the batch completes.
        """
        x, y, alive, keys = sus.payload
        t0 = time.perf_counter()
        # early returns inside the span still close it — a resume that
        # is itself preempted leaves no dangling event in the trace
        with obs_trace.span("resume", "scheduler", seq=seq,
                            rounds_done=sus.rounds_done,
                            engine=sus.bucket.compat.engine) as r_sp:
            self._ckpt_writer().wait()         # tip durable before read
            state, _meta = msgpack_ckpt.restore_pytree(sus.ckpt_path)
            self.stats.resumes += 1
            n_pre = self.preempt.get(seq)
            if n_pre is not None:              # preempted AGAIN mid-resume
                r_sp.update(repreempted=True, rounds=n_pre)
                state = self._engine_run(sus.bucket, state, x, y, n=n_pre)
                path = self._checkpoint(seq, sus.bucket, state,
                                        sus.admitted,
                                        sus.rounds_done + n_pre,
                                        sus.chain)
                del state
                self._suspended.append(dataclasses.replace(
                    sus, ckpt_path=path,
                    rounds_done=sus.rounds_done + n_pre,
                    paths=sus.paths + (path,)))
                self.stats.preemptions += 1
                return [], time.perf_counter() - t0
            state = self._engine_run(sus.bucket, state, x, y, n=None)
            res = self._engine_finalize(sus.bucket, state, x, y, alive,
                                        sus.m_true)
        service_s = time.perf_counter() - t0
        self._ckpt_writer().forget(sus.chain)
        for p in sus.paths:                    # consumed — don't litter
            try:
                os.remove(p)
            except OSError:
                pass
        self.stats.note(sus.bucket, len(sus.admitted), sus.bucket.B)
        completions = []
        for lane, (req, task, _data) in enumerate(sus.admitted):
            completions.append(Completion(
                request=req, task=task, result=res, lane=lane,
                bucket=sus.bucket,
                queue_wait_s=max(now - req.arrival_s, 0.0),
                service_s=service_s,
                latency_s=max(now - req.arrival_s, 0.0) + service_s,
                resumed=True))
        return completions, service_s

    def step(self, now: float = 0.0):
        """Admit one batch from the fullest-eligible queue and dispatch.

        Returns (completions, service_s) — empty if nothing is queued.
        Admission pops up to bucket-B requests per compat group; the
        rest stay queued for the next step (the "slots free up" cycle).
        Preempted (suspended) batches resume before fresh admissions;
        a resume is an engine dispatch and consumes a dispatch seq (so
        ``preempt`` injections can hit it too).
        """
        if self._suspended:
            seq = self._dispatch_seq
            self._dispatch_seq += 1
            return self._resume(self._suspended.popleft(), seq, now)
        qkey = self._pick_queue()
        if qkey is None:
            return [], 0.0
        compat, mloc_b = qkey
        q = self._queues[qkey]
        B = self.lattice.bucket_b(len(q))
        take = min(len(q), B)
        admitted = [q.popleft() for _ in range(take)]
        if not q:
            del self._queues[qkey]
        items = [a[2] for a in admitted]
        x, y, alive, keys, n_real = batched.stack_for_dispatch(items, B)
        bucket = BucketKey(compat=compat, B=B, mloc=mloc_b)
        m_true = np.array([a[0].m for a in admitted]
                          + [admitted[0][0].m] * (B - n_real))
        seq = self._dispatch_seq
        self._dispatch_seq += 1
        n_pre = self.preempt.get(seq)
        if n_pre is not None:
            return self._preempt_dispatch(
                seq, bucket, admitted, (x, y, alive, keys), m_true,
                n_pre)
        res, service_s = self._dispatch(bucket, x, y, alive, keys,
                                        m_true)
        self.stats.note(bucket, n_real, B)
        completions = []
        for lane, (req, task, _data) in enumerate(admitted):
            completions.append(Completion(
                request=req, task=task, result=res, lane=lane,
                bucket=bucket,
                queue_wait_s=max(now - req.arrival_s, 0.0),
                service_s=service_s,
                latency_s=max(now - req.arrival_s, 0.0) + service_s))
        return completions, service_s

    def _pick_queue(self):
        """Oldest head request wins — FIFO across bucket queues."""
        best, best_t = None, None
        for qkey, q in self._queues.items():
            t = q[0][0].arrival_s
            if best_t is None or t < best_t:
                best, best_t = qkey, t
        return best

    # -- closed-loop stream ------------------------------------------------

    def run_stream(self, requests) -> list:
        """Serve an arrival-stamped request stream to completion.

        Virtual clock: arrivals advance it when the server is idle,
        dispatches advance it by their measured wall time (compile time
        on a cache miss is charged to the dispatch that missed — warm
        the cache first to measure steady state).
        """
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        clock = 0.0
        i = 0
        completions = []
        while i < len(pending) or self.queued():
            # admit everything that has arrived by now
            while i < len(pending) and pending[i].arrival_s <= clock:
                self.submit(pending[i])
                i += 1
            if not self.queued():
                clock = max(clock, pending[i].arrival_s)
                continue
            if self.policy == "fill" and i < len(pending) \
                    and self._queues and not self._suspended:
                deadline = self._fill_deadline()
                if deadline is not None and clock < deadline:
                    # hold admission for a fuller batch, but never past
                    # the head request's deadline
                    clock = max(clock,
                                min(pending[i].arrival_s, deadline))
                    continue
            compile_s0 = self.cache.stats.compile_s
            done, service_s = self.step(now=clock)
            dcompile = self.cache.stats.compile_s - compile_s0
            clock += service_s + dcompile
            for c in done:
                c.latency_s += dcompile
                completions.append(c)
        return completions

    def _fill_deadline(self) -> float | None:
        """Virtual time at which SOME queue must dispatch even if not
        full; None when a queue is already full enough to go now.

        Dispatch order is "oldest head across bucket queues"
        (:meth:`_pick_queue`), so the deadline must consider every
        queue, not just one: a full max-B batch anywhere dispatches
        immediately (returning None) even when the globally oldest head
        sits in a sparser queue, and the hold never extends past the
        oldest pending head + ``fill_wait_s`` — previously this read a
        single queue and a two-bucket burst could hold a ready batch
        (or a stale head) for the whole fill window.
        """
        heads = []
        for q in self._queues.values():
            if len(q) >= self.lattice.max_b:
                return None
            heads.append(q[0][0].arrival_s)
        return min(heads) + self.fill_wait_s

    # -- warmup ------------------------------------------------------------

    def warm(self, requests, b_sizes: tuple | None = None,
             stepping: bool | None = None) -> int:
        """Compile every bucket a request set can reach.

        The admission policy picks the bucket B from the instantaneous
        queue depth, so replaying a trace once does NOT deterministically
        visit every bucket the next replay will.  This enumerates the
        reachable set — each distinct (compat, bucket-mloc) × each
        lattice B — and compiles the missing ones with representative
        payloads, so a warmed scheduler serves any arrival order of
        these requests with zero recompiles.  Returns the number of
        programs compiled.

        ``stepping`` additionally compiles the round-granular programs
        the preempt/resume path runs (``init_state``/``run_rounds``; the
        slice length ``n`` is a traced argument, so one program per
        bucket covers every slice size including run-to-completion).
        Defaults to on when the scheduler has a checkpoint dir — a
        preemption-injected stream then pays no stepping compile inside
        measured service time.
        """
        if stepping is None:
            stepping = self.ckpt_dir is not None
        groups = {}
        for req in requests:
            mloc_b = self.lattice.bucket_mloc(req.m // req.k)
            groups.setdefault((CompatKey.of(req), mloc_b), req)
        before = self.cache.stats.compiles
        for (compat, mloc_b), req in groups.items():
            task = req.make_task()
            x, y, alive = tasks.pad_shards(task.x, task.y, mloc_b)
            item = (x, y, alive, req.make_key())
            for B in (b_sizes or self.lattice.b_sizes):
                xb, yb, ab, keys, _ = batched.stack_for_dispatch(
                    [item], B)
                bucket = BucketKey(compat=compat, B=B, mloc=mloc_b)
                self._compiled(bucket, xb, yb, ab, keys)
                if stepping:
                    st = self._engine_init(bucket, xb, yb, ab, keys)
                    self._engine_run(bucket, st, xb, yb, n=0)
        return self.cache.stats.compiles - before

    # -- parity baseline ---------------------------------------------------

    def one_shot(self, req: Request):
        """The one-shot engine run the scheduler must reproduce bit for
        bit: B=1, the request's own bucket mloc, same key.  Uses the
        same compile cache (B=1 buckets), so repeated parity checks
        don't recompile."""
        task = req.make_task()
        mloc_b = self.lattice.bucket_mloc(req.m // req.k)
        x, y, alive = tasks.pad_shards(task.x, task.y, mloc_b)
        x, y, alive, keys, _ = batched.stack_for_dispatch(
            [(x, y, alive, req.make_key())], 1)
        bucket = BucketKey(compat=CompatKey.of(req), B=1, mloc=mloc_b)
        res, _ = self._dispatch(bucket, x, y, alive, keys,
                                np.array([req.m]))
        return res


# ---------------------------------------------------------------------------
# Arrival traces
# ---------------------------------------------------------------------------

def poisson_trace(n: int, rate_per_s: float, seed: int = 0):
    """n exponential inter-arrival gaps (a Poisson process), as stamps."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=n)
    return np.cumsum(gaps)


def bursty_trace(n: int, rate_per_s: float, burst: int = 8,
                 seed: int = 0):
    """Same mean rate, but arrivals land in bursts of ``burst`` at the
    burst's start — the worst case for a fill policy's head latency."""
    rng = np.random.default_rng(seed)
    n_bursts = int(np.ceil(n / burst))
    gaps = rng.exponential(burst / rate_per_s, size=n_bursts)
    starts = np.cumsum(gaps)
    return np.repeat(starts, burst)[:n]


def make_request_stream(n: int, arrivals, shapes, seed0: int = 0,
                        **common) -> list:
    """n requests cycling through ``shapes`` (dicts of Request field
    overrides), stamped with ``arrivals``."""
    reqs = []
    for i in range(n):
        fields = dict(shapes[i % len(shapes)])
        fields.update(common)
        reqs.append(Request(rid=i, seed=seed0 + i,
                            arrival_s=float(arrivals[i]), **fields))
    return reqs
