"""Synthetic LM corpus with stable example identity.

Every example has a persistent id so the resilient-boosting state
(multiplicative weights + quarantine) attaches to *examples*, exactly
like the paper attaches weights to sample elements.  A configurable
fraction of examples is "noisy": their target sequence is decoupled
from the input pattern, so no model in the family can fit them — the
neural analogue of the paper's contradicting examples, and the thing
the hard-core quarantine should isolate.

The generator is a small deterministic Markov chain over the vocab
(fixed per seed), which a transformer learns quickly — giving a clean
signal for the resilient-vs-vanilla benchmark.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 64
    num_examples: int = 4096
    noise_frac: float = 0.0        # fraction of unlearnable examples
    branching: int = 4             # Markov successors per token
    seed: int = 0


class SyntheticCorpus:
    """Materialized synthetic corpus (host memory, numpy)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, S, N = cfg.vocab_size, cfg.seq_len, cfg.num_examples
        # Markov successor table: token t -> branching successors
        self.successors = rng.integers(0, V, size=(V, cfg.branching))
        starts = rng.integers(0, V, size=N)
        choices = rng.integers(0, cfg.branching, size=(N, S))
        toks = np.empty((N, S + 1), np.int32)
        toks[:, 0] = starts
        for s in range(S):
            toks[:, s + 1] = self.successors[toks[:, s], choices[:, s]]
        self.tokens = toks[:, :-1]
        self.labels = toks[:, 1:].copy()
        # noisy examples: labels replaced by an independent random walk —
        # unlearnable given the inputs
        n_noise = int(cfg.noise_frac * N)
        self.noisy_ids = rng.choice(N, size=n_noise, replace=False)
        if n_noise:
            self.labels[self.noisy_ids] = rng.integers(
                0, V, size=(n_noise, S))
        self.ids = np.arange(N, dtype=np.int32)

    def batch(self, rng: np.random.Generator, batch_size: int,
              alive: np.ndarray | None = None):
        """Sample a batch of alive examples (uniform over alive)."""
        if alive is None:
            pool = self.ids
        else:
            pool = self.ids[alive]
        idx = rng.choice(pool, size=batch_size,
                         replace=batch_size > pool.size)
        return {
            "ids": jnp.asarray(idx),
            "tokens": jnp.asarray(self.tokens[idx]),
            "labels": jnp.asarray(self.labels[idx]),
            "loss_mask": jnp.ones((batch_size, self.cfg.seq_len),
                                  jnp.float32),
        }


def make_batch(key, cfg, batch: int, seq: int):
    """Random batch for shape/smoke tests (no corpus)."""
    toks = jax.random.randint(key, (batch, seq), 0,
                              min(cfg.vocab_size, 1 << 15), jnp.int32)
    return {
        "tokens": toks,
        "labels": jnp.roll(toks, -1, axis=1),
        "loss_mask": jnp.ones((batch, seq), jnp.float32),
        "weights": jnp.ones((batch,), jnp.float32),
        "alive": jnp.ones((batch,), jnp.float32),
    }


def batch_specs(cfg, shape, dtype_tokens=jnp.int32):
    """ShapeDtypeStructs of a training batch for .lower() dry-runs."""
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), dtype_tokens),
        "labels": jax.ShapeDtypeStruct((B, S), dtype_tokens),
        "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        "weights": jax.ShapeDtypeStruct((B,), jnp.float32),
        "alive": jax.ShapeDtypeStruct((B,), jnp.float32),
    }
    return specs
