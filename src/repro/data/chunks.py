"""Chunked host→device feed for million-point tasks (streaming tier).

A task with m ≥ 10^6 examples should never need a monolithic
host→device transfer followed by a monolithic consume: the streaming
consumers (``repro.core.streaming.build_sketch``, the chunked histogram
accumulators) fold fixed-size tiles, so the feed's job is to hand them
device-resident tiles while the PREVIOUS tile is still being consumed.

:func:`iter_chunks` is the plain tiler (host arrays in, host views
out); :func:`prefetch_to_device` wraps any chunk iterator with a
one-deep double buffer: it issues ``jax.device_put`` for chunk i+1
before yielding chunk i, so on asynchronous-dispatch backends the PCIe
copy of the next tile overlaps the accumulation of the current one.
Order and values are untouched — the streaming paths' bitwise-parity
contracts hold with or without prefetching (pinned in
tests/test_streaming.py).
"""

from __future__ import annotations

from typing import Iterable, Iterator, Sequence

import jax
import numpy as np


def iter_chunks(arrays: Sequence, chunk_size: int) -> Iterator[tuple]:
    """Tile equal-length host arrays: yields ``(*slices, start)`` per
    ``chunk_size`` tile, in index order (the last tile may be ragged).

    ``start`` (python int) is the tile's offset in the full sample —
    the global-index base :func:`repro.core.streaming.sketch_from_chunk`
    needs.
    """
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be ≥ 1, got {chunk_size}")
    m = len(arrays[0])
    for a in arrays[1:]:
        if len(a) != m:
            raise ValueError("chunked arrays must share their length "
                             f"({len(a)} != {m})")
    for s in range(0, m, chunk_size):
        yield tuple(a[s:min(s + chunk_size, m)] for a in arrays) + (s,)


def prefetch_to_device(chunks: Iterable[tuple], depth: int = 1,
                       device=None) -> Iterator[tuple]:
    """Double-buffered device feed over any chunk iterator.

    Keeps ``depth`` chunks (default 1 — classic double buffering) in
    flight: each chunk's array members are ``jax.device_put`` BEFORE
    the previous chunk is yielded, so the async transfer overlaps the
    consumer's accumulation work.  The trailing ``start`` offset (and
    any other non-array member) passes through untouched; yield order
    is exactly the input order.
    """
    if depth < 1:
        raise ValueError(f"depth must be ≥ 1, got {depth}")

    def put(chunk: tuple) -> tuple:
        return tuple(
            jax.device_put(a, device) if isinstance(a, (np.ndarray,
                                                        jax.Array))
            else a
            for a in chunk)

    buf: list[tuple] = []
    for chunk in chunks:
        buf.append(put(chunk))            # issue the copy immediately
        if len(buf) > depth:
            yield buf.pop(0)
    yield from buf


def iter_shard_chunks(x: np.ndarray, y: np.ndarray, w: np.ndarray,
                      chunk_size: int, depth: int = 1,
                      device=None) -> Iterator[tuple]:
    """The sketch builder's feed: ``(x, y, w, start)`` tiles of one
    player's shard, double-buffered onto the device — compose directly
    with ``streaming.build_sketch(iter_shard_chunks(...), cap)``."""
    return prefetch_to_device(iter_chunks((x, y, w), chunk_size),
                              depth=depth, device=device)
