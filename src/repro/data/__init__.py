"""Data pipeline: synthetic corpora with example identity, label noise,
per-example boosting weights and quarantine masks."""

from repro.data.chunks import (iter_chunks, iter_shard_chunks,
                               prefetch_to_device)
from repro.data.pipeline import (DataConfig, SyntheticCorpus, make_batch,
                                 batch_specs)

__all__ = ["DataConfig", "SyntheticCorpus", "make_batch", "batch_specs",
           "iter_chunks", "iter_shard_chunks", "prefetch_to_device"]
