"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.
[arXiv:2308.11596]

Backbone only: the mel-spectrogram + conv feature extractor is a STUB;
``input_specs`` provides precomputed frame embeddings at d_model.
Decode over a long source is O(L_enc) per token (cross-attention reads
the cached encoder output), i.e. sub-quadratic per decoded token.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    num_layers=12,                  # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio_stub",
    long_context_mode="cross",
    citation="arXiv:2308.11596",
))
