"""internlm2-20b [dense] — GQA (kv=8).  [arXiv:2403.17297]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internlm2-20b",
    arch_type="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    long_context_mode="swa",
    citation="arXiv:2403.17297",
))
