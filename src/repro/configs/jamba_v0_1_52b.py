"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE every
other layer, 16 experts top-2.  [arXiv:2403.19887]

Superblock of 8 layers: attention at position 4, Mamba elsewhere;
MoE FFN at odd positions, dense MLP at even ones (Jamba's 1:7 attn
ratio and every-other-layer MoE).
"""

from repro.configs.base import ModelConfig, register

_PATTERN = tuple(
    (("attn" if i == 4 else "mamba"), ("moe" if i % 2 == 1 else "mlp"))
    for i in range(8)
)

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=_PATTERN,
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=14336,
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
    long_context_mode="native",      # Mamba states + sparse attention layers
    citation="arXiv:2403.19887",
))
