"""pixtral-12b [vlm] — Pixtral-ViT frontend (STUB) + Mistral-Nemo-style
decoder backbone.  [hf:mistralai/Pixtral-12B-2409]

Backbone only per the assignment carve-out: the vision encoder +
projector are stubbed; ``input_specs`` provides precomputed patch
embeddings at d_model.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    frontend="vit_stub",
    frontend_tokens=1024,            # patch positions per example
    long_context_mode="swa",         # Mistral-style sliding window
    citation="hf:mistralai/Pixtral-12B-2409",
))
