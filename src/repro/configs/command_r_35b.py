"""command-r-35b [dense] — GQA (kv=8), no biases.
[hf:CohereForAI/c4ai-command-r-v01]
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-35b",
    arch_type="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    attn_bias=False,
    rope_theta=8_000_000.0,
    long_context_mode="swa",
    citation="hf:CohereForAI/c4ai-command-r-v01",
))
