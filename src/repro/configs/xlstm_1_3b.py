"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1 per superblock of 8,
matching the paper's sparse sLSTM placement).  [arXiv:2405.04517]

d_ff=0 per assignment: xLSTM blocks carry their own up/down projections
(mLSTM pre-up-projection ×2, sLSTM gated FFN), no separate MLP.
"""

from repro.configs.base import ModelConfig, register

_PATTERN = tuple(
    (("slstm" if i == 7 else "mlstm"), "none") for i in range(8)
)

CONFIG = register(ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    block_pattern=_PATTERN,
    long_context_mode="native",      # constant-size recurrent state
    citation="arXiv:2405.04517",
))
