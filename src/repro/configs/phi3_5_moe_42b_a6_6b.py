"""phi3.5-moe-42b-a6.6b [moe] — 16 experts, top-2 routing.
[hf:microsoft/Phi-3.5-MoE-instruct]
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    block_pattern=(("attn", "moe"),),
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=6400,
    long_context_mode="swa",
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
))
