"""qwen3-32b [dense] — qk-norm, GQA (kv=8).  [hf:Qwen/Qwen3-8B]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=80,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    long_context_mode="swa",
    citation="hf:Qwen/Qwen3-8B",
))
