"""Config system: model architectures, input shapes, parallelism.

Every assigned architecture is a ``ModelConfig`` built from the exact
dimensions in the assignment (source paper / model card cited in each
``configs/<arch>.py``).  Heterogeneous stacks (hybrid / xLSTM) are
expressed as a repeating ``block_pattern`` — the transformer assembly
scans over "superblocks" (one pattern repetition) so the lowered HLO
stays compact regardless of depth.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

# ---------------------------------------------------------------------------
# Block descriptors
# ---------------------------------------------------------------------------

# mixer ∈ {"attn", "mamba", "mlstm", "slstm"}; ffn ∈ {"mlp", "moe", "none"}
Block = tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense|moe|hybrid|ssm|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- block structure ---------------------------------------------------
    block_pattern: tuple = (("attn", "mlp"),)
    # --- attention ----------------------------------------------------------
    head_dim: int = 0               # 0 -> d_model // num_heads
    qk_norm: bool = False
    attn_bias: bool = False
    # serving-path q/k/v layout constraint (§Perf G-P3): replicate K/V on
    # the model axis when KV heads don't divide it.  Measured: −75 %
    # collective on granite prefill; REGRESSES phi3.5 — per-arch tunable.
    attn_layout_constraint: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 = full attention
    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim (d_ff if 0)
    capacity_factor: float = 1.25
    moe_dispatch: str = "einsum"    # "einsum" (GSPMD) | "sort" (MegaBlocks-ish)
    expert_pad_to: int = 0          # pad expert count (e.g. 40→48 so the
                                    # expert axis divides the model axis)
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # --- SSM (mamba) ----------------------------------------------------------
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # --- encoder-decoder ------------------------------------------------------
    encoder_layers: int = 0         # >0 => enc-dec; num_layers = decoder layers
    # --- modality frontend (STUB per assignment carve-out) --------------------
    frontend: str = "none"          # none|vit_stub|audio_stub
    frontend_tokens: int = 0        # patch/frame positions occupied per example
    # --- misc ------------------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # long-context decode strategy for the long_500k shape:
    #   "native"  — sub-quadratic by construction (ssm / hybrid states)
    #   "swa"     — sliding-window ring cache (Mistral-style)
    #   "cross"   — enc-dec: O(L_enc) cross-attention per decoded token
    long_context_mode: str = "swa"
    remat: bool = True              # activation checkpointing over superblocks
    citation: str = ""

    # ----- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    @property
    def num_superblocks(self) -> int:
        assert self.num_layers % self.pattern_len == 0, (
            f"{self.name}: {self.num_layers} layers not divisible by "
            f"pattern of length {self.pattern_len}")
        return self.num_layers // self.pattern_len

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over any
        reasonable model-parallel degree (e.g. granite's 49155 → 49408)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        D, hd = self.d_model, self.hd
        total = self.padded_vocab * D                      # embed
        if not self.tie_embeddings:
            total += self.padded_vocab * D                 # lm head
        def attn_params():
            return D * (self.num_heads * hd) + 2 * D * (self.num_kv_heads * hd) \
                + (self.num_heads * hd) * D + 2 * D  # q,k,v,o + norms
        def mlp_params(ff):
            return 3 * D * ff + D
        def moe_params():
            return (self.num_experts * 3 * D * self.expert_d_ff
                    + D * self.num_experts + D)
        def mamba_params():
            di = self.ssm_expand * D
            return (2 * D * di + di * self.ssm_conv_width
                    + di * (2 * self.ssm_state_dim + 2) + di * D + D)
        def xlstm_params(kind):
            di = 2 * D
            if kind == "mlstm":
                return 2 * D * di + 3 * di + di * D + 2 * D
            return 4 * D * D + 4 * D * D // self.num_heads + 2 * D * D + 2 * D
        per_pattern = 0
        for mixer, ffn in self.block_pattern:
            if mixer == "attn":
                per_pattern += attn_params()
            elif mixer == "mamba":
                per_pattern += mamba_params()
            elif mixer in ("mlstm", "slstm"):
                per_pattern += xlstm_params(mixer)
            if ffn == "mlp":
                per_pattern += mlp_params(self.d_ff)
            elif ffn == "moe":
                per_pattern += moe_params()
        total += per_pattern * self.num_superblocks
        if self.encoder_layers:
            # encoder: self-attn + mlp per layer; decoder cross-attn extra
            total += self.encoder_layers * (attn_params() + mlp_params(self.d_ff))
            total += self.num_layers * attn_params()       # cross-attn
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.num_experts:
            return self.param_count()
        dense = self.param_count() - (
            sum(1 for _, f in self.block_pattern if f == "moe")
            * self.num_superblocks * self.num_experts * 3
            * self.d_model * self.expert_d_ff)
        active = (sum(1 for _, f in self.block_pattern if f == "moe")
                  * self.num_superblocks * self.experts_per_token * 3
                  * self.d_model * self.expert_d_ff)
        return dense + active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Sliding window used when a full-attention arch runs long_500k in "swa"
# mode (Mistral-style ring cache).
DEFAULT_SWA_WINDOW = 4096


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pod: int = 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pod

    @property
    def axis_names(self):
        return (("pod", "data", "model") if self.pod > 1
                else ("data", "model"))

    @property
    def shape(self):
        return ((self.pod, self.data, self.model) if self.pod > 1
                else (self.data, self.model))

    @property
    def batch_axes(self):
        return (("pod", "data") if self.pod > 1 else ("data",))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


ASSIGNED_ARCHS = (
    "pixtral-12b", "jamba-v0.1-52b", "phi3.5-moe-42b-a6.6b",
    "internlm2-20b", "xlstm-1.3b", "granite-moe-3b-a800m", "qwen3-32b",
    "seamless-m4t-medium", "deepseek-7b", "command-r-35b",
)


def load_all() -> None:
    """Import every per-arch config module (they call ``register``)."""
    import importlib
    for arch in ASSIGNED_ARCHS:
        importlib.import_module(
            "repro.configs." + arch.replace("-", "_").replace(".", "_"))


def reduced(cfg: ModelConfig, *, layers: Optional[int] = None,
            d_model: int = 256, vocab: int = 512,
            experts: int = 4) -> ModelConfig:
    """Smoke-test variant of the same family: ≤2 superblocks, d_model ≤ 512,
    ≤ 4 experts (assignment requirement)."""
    pat = cfg.block_pattern
    n_layers = layers or max(len(pat), 2 if len(pat) == 1 else len(pat))
    if n_layers % len(pat) != 0:
        n_layers = len(pat)
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=max(64, d_model * 2),
        moe_d_ff=(min(cfg.expert_d_ff, d_model) if cfg.num_experts else 0),
        vocab_size=vocab,
        num_experts=min(cfg.num_experts, experts) if cfg.num_experts else 0,
        experts_per_token=(min(cfg.experts_per_token, 2)
                           if cfg.num_experts else 0),
        encoder_layers=min(cfg.encoder_layers, 2),
        frontend_tokens=min(cfg.frontend_tokens, 8),
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        remat=False,
    )
