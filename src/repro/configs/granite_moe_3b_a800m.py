"""granite-moe-3b-a800m [moe] — 40 experts, top-8 routing, per-expert
d_ff=512.  [hf:ibm-granite/granite-3.0-1b-a400m-base]

Note: 40 experts do not divide the 16-way model axis, so expert
parameters are sharded over the per-expert hidden dim instead
(tensor-parallel within experts) — see models/moe.py.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    block_pattern=(("attn", "moe"),),
    num_experts=40,
    experts_per_token=8,
    moe_d_ff=512,
    attn_layout_constraint=True,   # §Perf G-P3 (measured win)
    long_context_mode="swa",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
