"""Per-architecture configs (assigned pool) + the paper's own protocol config."""

from repro.configs.base import (ModelConfig, ShapeConfig, MeshConfig,
                                INPUT_SHAPES, ASSIGNED_ARCHS,
                                get_config, all_configs, load_all, reduced)

__all__ = ["ModelConfig", "ShapeConfig", "MeshConfig", "INPUT_SHAPES",
           "ASSIGNED_ARCHS", "get_config", "all_configs", "load_all",
           "reduced"]
