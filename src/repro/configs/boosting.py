"""The paper's own 'architecture': the resilient boosting protocol
itself, as a dry-runnable distributed program (k players = data axis).
"""

from repro.core.types import BoostConfig

PRODUCTION_BOOST = BoostConfig(
    k=16,                       # one player per data-axis group
    coreset_size=512,
    domain_size=1 << 20,
    opt_budget=256,
    deterministic_coreset=True,
)
