"""Resilient boosting as a first-class *neural training* feature.

The deep-learning transliteration of AccuratelyClassify (DESIGN.md §2):

* per-example **multiplicative weights** over the training corpus —
  an example's weight halves whenever the model handles it well
  (per-example NLL below the corpus median), exactly mirroring
  W·2^{-1[h(x)=y]};
* each data shard periodically contributes a tiny **coreset** of its
  currently-heaviest examples (the ε-approximation message — O(c·d)
  floats instead of raw data / gradients);
* the **hard-core check**: examples whose weight has saturated (the MW
  distribution concentrated on them) *and* whose NLL stays above a
  noise threshold after the model has had every opportunity are, by the
  Impagliazzo-style argument, unfit-table by the model family —
  they are **quarantined** (the dispute set D), i.e. removed from the
  loss like the paper removes the non-realizable S'.

This is a faithful port of the *mechanism* (MW + coreset messages +
hard-set removal).  The paper's E_S(f) ≤ OPT theorem applies to the VC
track (core/classify.py); here the claim is empirical noise-robustness,
measured by benchmarks/neural_resilient.py against vanilla training on
the same noisy corpus.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ResilientConfig:
    num_examples: int
    coreset_size: int = 64          # per check, per shard
    check_every: int = 50           # steps between hard-core checks
    nll_threshold: float = 0.0      # 0 ⇒ adaptive (median + 2·MAD)
    min_ratio: float = 1.75         # coreset must be ≥ ratio × corpus level
    min_hits_gap: int = 4           # weight ratio 2^gap ⇒ "concentrated"
    mw_loss_weighting: bool = False  # apply MW weights to the loss (the
                                    # bookkeeping for quarantine always
                                    # runs); OFF by default — measured:
                                    # even capped weighting costs clean
                                    # eval at small scale, quarantine
                                    # alone is the win
    mw_cap_bits: int = 3            # SmoothBoost-style cap: batch weight
                                    # ratio ≤ 2^cap (unbounded MW skew
                                    # measurably hurts clean-eval loss —
                                    # the same fix the paper's cited
                                    # Chen–Balcan–Chau baseline uses)
    mw_enabled: bool = True
    quarantine_enabled: bool = True


@dataclasses.dataclass
class ResilientState:
    hits: np.ndarray                # [N] int32 — −log2 of MW weight
    alive: np.ndarray               # [N] bool
    nll_ema: np.ndarray             # [N] float32 — per-example loss EMA
    seen: np.ndarray                # [N] int32
    quarantined_at: list


def init_state(cfg: ResilientConfig) -> ResilientState:
    N = cfg.num_examples
    return ResilientState(
        hits=np.zeros(N, np.int32),
        alive=np.ones(N, bool),
        nll_ema=np.zeros(N, np.float32),
        seen=np.zeros(N, np.int32),
        quarantined_at=[],
    )


def batch_weights(state: ResilientState, ids: np.ndarray,
                  cfg: ResilientConfig):
    """MW weights + alive mask for a batch.

    The weights are SmoothBoost-cap-clipped relative MW weights, NOT
    normalized: ``w = 2^{clip(h_min − h, −cfg.mw_cap_bits, 0)}``, so the
    batch's lightest-hit example gets weight exactly 1, every other
    weight lies in ``[2^{−cap}, 1]`` (the cap bounds the skew the MW
    distribution can impose on a step), and the sum is whatever it is —
    the training loss divides by the weight sum itself.  With MW
    weighting disabled, all-ones.  ``alive`` is the quarantine mask as
    float (0 = quarantined, excluded from the loss).
    """
    ids = np.asarray(ids)
    if not (cfg.mw_enabled and cfg.mw_loss_weighting):
        w = np.ones(ids.shape, np.float32)
    else:
        h = state.hits[ids].astype(np.float32)
        w = np.exp2(np.clip(h.min() - h, -float(cfg.mw_cap_bits), 0.0))
    alive = state.alive[ids].astype(np.float32)
    return jnp.asarray(w), jnp.asarray(alive)


def update(state: ResilientState, ids, per_example_nll,
           cfg: ResilientConfig, step: int) -> ResilientState:
    """Post-step MW update + (periodically) the hard-core quarantine.

    Duplicate-safe: when ``ids`` repeats an id (sampling with
    replacement), every occurrence counts — hits accumulate via
    ``np.add.at`` (fancy-index ``+=`` silently dropped all but one
    increment) and the loss EMA folds the occurrences sequentially in
    batch order (plain ``nll_ema[ids] =`` was last-write-wins).
    """
    ids = np.asarray(ids)
    nll = np.asarray(per_example_nll, np.float32)
    # EMA of the example's loss
    if np.unique(ids).size == ids.size:
        # no duplicates: the vectorized fold is exact
        seen = state.seen[ids]
        ema = state.nll_ema[ids]
        alpha = np.where(seen == 0, 1.0, 0.3).astype(np.float32)
        state.nll_ema[ids] = (1 - alpha) * ema + alpha * nll
        state.seen[ids] = seen + 1
    else:
        for j in range(ids.size):          # sequential, duplicate-aware
            i = ids[j]
            a = np.float32(1.0 if state.seen[i] == 0 else 0.3)
            state.nll_ema[i] = (1 - a) * state.nll_ema[i] + a * nll[j]
            state.seen[i] += 1
    if cfg.mw_enabled:
        # "correct" analog: the model fits this example better than the
        # batch median ⇒ halve its weight (hits += 1)
        med = np.median(nll)
        np.add.at(state.hits, ids, (nll <= med).astype(np.int32))
    if cfg.quarantine_enabled and step > 0 and step % cfg.check_every == 0:
        _hard_core_check(state, cfg, step)
    return state


def _hard_core_check(state: ResilientState, cfg: ResilientConfig,
                     step: int) -> None:
    """Quarantine the coreset if it is provably hard.

    The MW dynamics concentrate weight on examples the model keeps
    getting wrong.  The coreset = the ``coreset_size`` heaviest alive
    examples.  If, despite the boosting pressure, the model's loss EMA
    on them is far above the corpus level (median + 2·MAD by default),
    no member of the family fits them — quarantine (dispute set).
    """
    alive_idx = np.where(state.alive & (state.seen > 0))[0]
    if alive_idx.size < 4 * cfg.coreset_size:
        return
    hits = state.hits[alive_idx]
    order = np.argsort(hits, kind="stable")       # fewest hits = heaviest
    coreset = alive_idx[order[:cfg.coreset_size]]
    rest = alive_idx[order[cfg.coreset_size:]]
    gap = np.median(state.hits[rest]) - np.median(state.hits[coreset])
    if gap < cfg.min_hits_gap:
        return                                    # weight not concentrated
    if cfg.nll_threshold > 0:
        thr = cfg.nll_threshold
    else:
        # adaptive: clearly above the fit-table corpus level, BOTH in
        # spread (median + 2·MAD) and in ratio (≥ min_ratio×median) —
        # the ratio floor stops the check from eating hard-but-learnable
        # examples once all actual noise is gone.
        lvl = state.nll_ema[rest]
        med = np.median(lvl)
        mad = np.median(np.abs(lvl - med)) + 1e-6
        thr = max(med + 2.0 * mad, cfg.min_ratio * med)
    hard = coreset[state.nll_ema[coreset] > thr]
    if hard.size:
        state.alive[hard] = False
        state.hits[hard] = 0
        state.quarantined_at.append((step, hard.copy()))


def quarantine_stats(state: ResilientState, noisy_ids=None) -> dict:
    q = ~state.alive
    out = {"quarantined": int(q.sum()),
           "alive": int(state.alive.sum())}
    if noisy_ids is not None:
        noisy = np.zeros_like(q)
        noisy[np.asarray(noisy_ids)] = True
        tp = int((q & noisy).sum())
        out.update(
            noise_recall=tp / max(int(noisy.sum()), 1),
            noise_precision=tp / max(int(q.sum()), 1),
        )
    return out
