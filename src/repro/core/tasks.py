"""Synthetic learning tasks for the protocol track.

Generates samples labelled by a ground-truth hypothesis from the class,
optionally corrupted by adversarial label noise (exactly ``noise``
flipped examples ⇒ OPT ≤ noise, and = noise for the classes here when
flips hit distinct points), then adversarially partitioned among k
players (contiguous by sort order — the worst case for naive splitting,
e.g. each player sees a different region of the domain).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import weak


@dataclasses.dataclass
class Task:
    x: np.ndarray            # [k, m_loc] (int track) or [k, m_loc, F]
    y: np.ndarray            # [k, m_loc] int8 ±1
    target_params: np.ndarray
    noise_count: int         # number of flipped labels (OPT ≤ this)
    cls: object
    flipped: np.ndarray | None = None   # [k, m_loc] bool — planted noise
    scenario: str = "uniform"           # which adversary corrupted S

    @property
    def flat_x(self):
        return self.x.reshape((-1,) + self.x.shape[2:])

    @property
    def flat_y(self):
        return self.y.reshape(-1)


def _split(rng, x, y, k, adversarial=True):
    m = x.shape[0]
    assert m % k == 0, "sample size must divide k for array layout"
    if adversarial:
        order = np.argsort(x if x.ndim == 1 else x[:, 0], kind="stable")
    else:
        order = rng.permutation(m)
    x, y = x[order], y[order]
    return (x.reshape((k, m // k) + x.shape[1:]),
            y.reshape(k, m // k))


def make_task(cls, m: int, k: int, noise: int, seed: int = 0,
              adversarial_split: bool = True) -> Task:
    """Sample m points, label by a random target in ``cls``, flip
    ``noise`` distinct labels.

    Class-agnostic via the capability protocol (core/weak.py): every
    hypothesis class supplies ``sample_points(rng, m)`` and
    ``sample_target(rng, x)``, so new classes plug in without editing
    this module.  (The per-class bodies moved verbatim from the old
    ``isinstance`` chain here — same rng call order, same streams.)
    """
    rng = np.random.default_rng(seed)
    if not (hasattr(cls, "sample_points") and hasattr(cls, "sample_target")):
        raise ValueError(
            f"{type(cls).__name__} lacks the sample_points/sample_target "
            "task-generation capability (see core/weak.py)")
    x = np.asarray(cls.sample_points(rng, m))
    params = np.asarray(cls.sample_target(rng, x), np.float32)
    import jax.numpy as jnp
    y = np.asarray(cls.predict(jnp.asarray(params), jnp.asarray(x)))
    y = y.astype(np.int8)
    # adversarial label noise on distinct points
    if noise > 0:
        flip = rng.choice(m, size=noise, replace=False)
        y[flip] = -y[flip]
    xs, ys = _split(rng, x, y, k, adversarial_split)
    return Task(x=xs, y=ys, target_params=params, noise_count=noise,
                cls=cls)


def make_batch(cls, B: int, m: int, k: int, noise: int, seed0: int = 0,
               adversarial_split: bool = True, scenario: str | None = None):
    """B independent tasks stacked for the batched engine.

    Returns (x [B, k, m/k(, F)], y [B, k, m/k], tasks list) — the one
    batch constructor shared by serving, benchmarks, examples and
    tests, so per-task seeding/splitting can never drift between them.
    ``scenario`` routes corruption through core/scenarios.py instead of
    the default uniform flips (None keeps the historical RNG stream).
    """
    if scenario is not None:
        from repro.core import scenarios
        spec = scenarios.ScenarioSpec(name=scenario, noise=noise)
        return scenarios.make_scenario_batch(
            cls, B, m, k, spec, seed0=seed0,
            adversarial_split=adversarial_split)
    ts = [make_task(cls, m=m, k=k, noise=noise, seed=seed0 + b,
                    adversarial_split=adversarial_split)
          for b in range(B)]
    return (np.stack([t.x for t in ts]), np.stack([t.y for t in ts]),
            ts)


def pad_shards(x: np.ndarray, y: np.ndarray, mloc: int):
    """Pad per-player shards up to ``mloc`` rows for shape bucketing.

    x: [k, mloc0(, F)], y: [k, mloc0] — one task's shards.  Returns
    (x_pad, y_pad, alive) at [k, mloc(, F)] where the appended rows
    repeat each shard's last example and are dead in the alive mask, so
    the engines ignore them entirely (the masking is bit-safe:
    tests/test_batched.py::test_batched_ragged_padding).
    """
    k, mloc0 = y.shape
    if mloc < mloc0:
        raise ValueError(f"bucket mloc={mloc} < task mloc={mloc0}")
    alive = np.ones((k, mloc0), bool)
    pad = mloc - mloc0
    if pad == 0:
        return x, y, alive
    reps = [(0, 0)] * x.ndim
    reps[1] = (0, pad)
    x_pad = np.pad(x, reps, mode="edge")
    y_pad = np.pad(y, [(0, 0), (0, pad)], mode="edge")
    alive_pad = np.pad(alive, [(0, 0), (0, pad)],
                       constant_values=False)
    return x_pad, y_pad, alive_pad


def shard_chunk_feed(task: Task, player: int, chunk_size: int,
                     weights: np.ndarray | None = None, depth: int = 1,
                     device=None):
    """Streaming-tier feed of one player's shard (docs/streaming.md).

    Yields double-buffered device-resident ``(x, y, w, start)`` tiles of
    ``task.x[player]`` — exactly what
    ``repro.core.streaming.build_sketch`` consumes, with the transfer of
    tile i+1 overlapping the accumulation of tile i
    (``repro.data.chunks.prefetch_to_device``).  ``weights`` defaults to
    uniform; the int track feeds 1-D domain points, the feature track
    feeds the first column (the sort axis every engine uses).
    """
    from repro.data import chunks

    x = task.x[player]
    if x.ndim > 1:
        x = x[:, 0]
    y = task.y[player]
    w = (np.ones(y.shape, np.float32) if weights is None
         else np.asarray(weights, np.float32))
    return chunks.iter_shard_chunks(x, y, w, chunk_size, depth=depth,
                                    device=device)


def true_opt(task: Task, grid: int = 4096) -> int:
    """Brute-force OPT over a hypothesis grid (exact for small classes).

    For thresholds/intervals/singletons ERM over the *full sample* with
    uniform weights is exact OPT (the ERM routines enumerate all
    behaviours on the given points, which is all behaviours on S).
    """
    import jax.numpy as jnp
    x = jnp.asarray(task.flat_x)
    y = jnp.asarray(task.flat_y)
    m = y.shape[0]
    w = jnp.ones((m,), jnp.float32) / m
    _, loss = task.cls.erm(x, y, w)
    return int(round(float(loss) * m))
