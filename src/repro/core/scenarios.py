"""Adversarial noise scenarios beyond uniform label flips.

The resilience claims of the paper (Theorem 2.2 / 4.1) are adversarial:
E_S(f) ≤ OPT holds for *any* sample, however the noise is placed and
however the shards are partitioned.  Uniform random flips — the only
noise `tasks.make_task` plants — are the weakest adversary that bound
permits.  This module supplies the stronger ones, each targeting a
different part of the protocol:

``uniform``
    Baseline: ``noise`` flips at uniformly random distinct examples.
``targeted_heavy``
    Flips one copy of each of the ``noise`` *most duplicated* points.
    Every corrupted point becomes contradicting (both labels present in
    S), i.e. pure hard-core mass: no hypothesis can be consistent, MW
    drives the weight onto exactly these points, and the Impagliazzo-
    style quarantine must find them (tests pin recall ≥ 0.9).
``byzantine``
    One colluding player flips its *entire shard* — the adversarial-
    partition worst case (with the sort-order split that player owns a
    contiguous domain region).  OPT jumps to O(m/k) and the protocol
    must still terminate with E_S(f) ≤ OPT.
``boundary``
    All flips concentrated on the points nearest the target concept's
    decision boundary, where a hypothesis-class learner is most easily
    misled (label noise is indistinguishable from a shifted threshold
    until the weights sharpen).
``drift``
    The flip budget is spread across ``waves`` disjoint domain regions.
    Under the adversarial (sorted) split each region lives at a
    different player, so successive stuck→quarantine attempts chase a
    *moving* noise front instead of one hard core.

All corruptors are pure numpy on the already-split ``[k, mloc]``
arrays, deterministic in their rng, and return an explicit flip mask so
tests can compute recall/precision of the quarantine against the
planted ground truth.

Multi-feature concept families (:data:`FEATURE_SCENARIOS` — ``xor``,
``checkerboard``, ``bands``) are a different kind of adversary: they
pick the *concept*, not the noise.  The sample is grid-snapped uniform
over [0, 1)^F labelled by a planted histogram tree
(:func:`make_feature_task`) that single-feature classes provably
cannot fit — the workload class the tree weak learner
(``weak_tree/``) exists for — and any corruptor above composes on top
(``ScenarioSpec.noise_kind``).  Ground-truth helpers:
:func:`planted_errors` (an in-class OPT witness) and
:func:`class_floor` (best full-sample loss of ANY class on the task,
e.g. the pinned ≥ 0.25·m stump floor on planted XOR).

Infrastructure adversaries (:class:`InfraSpec`) attack the *protocol*
rather than the labels: they emit a per-round ``player_alive [R, k]``
schedule the fault-tolerant engines consume (``player_sched=``):

``dropout``
    Player j participates until wire round r, then vanishes forever —
    the Blum et al. communication-aware setting where a party's budget
    (or the party) runs out mid-protocol.
``flaky``
    Player j misses a Bernoulli(``miss_rate``) subset of rounds (a
    straggler that overruns the round deadline) but always returns.
``rejoin``
    Player j is absent for rounds [r, r′), then rejoins with its MW
    state frozen at departure — a preempted worker coming back.

Pinned guarantees (tests/test_fault_tolerance.py): the protocol still
terminates with E_S(f) ≤ OPT *over the surviving shards* (players alive
in the schedule's final row), and the communication ledger equals the
measured collective payloads **under the mask** — only bits alive
players actually sent are charged.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import tasks, weak

SCENARIOS = ("clean", "uniform", "targeted_heavy", "byzantine",
             "boundary", "drift")
INFRA = ("none", "dropout", "flaky", "rejoin")

# Multi-feature concept families (planted ground truth, not a
# corruptor): the sample is labelled by a tree-expressible concept that
# single-feature classes provably cannot fit — XOR of two off-centre
# half-planes, a cells×cells checkerboard, alternating axis-aligned
# bands.  Any noise adversary above composes on top (``noise_kind``).
FEATURE_SCENARIOS = ("xor", "checkerboard", "bands")


def _x1d(x: np.ndarray) -> np.ndarray:
    """The 1-D sort key of the domain points ([k·mloc] flat)."""
    flat = x.reshape((-1,) + x.shape[2:])
    return flat if flat.ndim == 1 else flat[:, 0]


def _corrupt_uniform(rng, x, y, noise, params, cls):
    m = y.size
    flip = np.zeros(m, bool)
    if noise > 0:
        flip[rng.choice(m, size=min(noise, m), replace=False)] = True
    return flip


def _corrupt_targeted_heavy(rng, x, y, noise, params, cls):
    """One flipped copy of each of the ``noise`` heaviest points.

    Heaviness is multiplicity of the FULL point (whole feature row on
    the feature track), because the adversary's power here is exactly
    the hard-core mass a flipped copy creates: a point with a single
    copy yields no contradiction.  A continuous sample has no
    duplicates, so this adversary cannot materialise there — refuse
    loudly instead of silently degrading to arbitrary flips.
    """
    flat = x.reshape((-1,) + x.shape[2:])
    if flat.ndim == 2:
        _, first_idx, counts = np.unique(flat, axis=0, return_index=True,
                                         return_counts=True)
        keys = np.arange(first_idx.size)
    else:
        keys, first_idx, counts = np.unique(flat, return_index=True,
                                            return_counts=True)
    if noise > 0 and counts.max(initial=0) < 2:
        raise ValueError(
            "targeted_heavy needs duplicated points to corrupt (its "
            "flips must contradict surviving copies); this sample has "
            "none — use a discrete domain or another scenario")
    # heaviest first; ties broken by value so the choice is deterministic
    order = np.lexsort((keys, -counts))
    flip = np.zeros(y.size, bool)
    flip[first_idx[order[:min(noise, first_idx.size)]]] = True
    return flip


def _corrupt_boundary(rng, x, y, noise, params, cls):
    """Flips at the ``noise`` points nearest the target's boundary."""
    xf = _x1d(x).astype(np.float64)
    t, a, b = float(params[0]), float(params[1]), float(params[2])
    if t == 3.0:                               # interval: both endpoints
        dist = np.minimum(np.abs(xf - a), np.abs(xf - b))
    elif t == 4.0:                             # stump: feature a, theta b
        feat = x.reshape((-1,) + x.shape[2:])[:, int(a)].astype(np.float64)
        dist = np.abs(feat - b)
    elif t == 5.0:                             # tree: nearest node cut
        flat = x.reshape((-1,) + x.shape[2:]).astype(np.float64)
        ni, Q = cls.nodes, cls.bins
        feats = params[1:1 + ni].astype(np.int64)
        qbins = params[1 + ni:1 + 2 * ni]
        dist = np.full(flat.shape[0], np.inf)
        for f, q in zip(feats, qbins):
            if q > 0:                          # skip degenerate splits
                dist = np.minimum(dist, np.abs(flat[:, f] - q / Q))
    else:                                      # threshold / singleton: a
        dist = np.abs(xf - a)
    flip = np.zeros(y.size, bool)
    flip[np.argsort(dist, kind="stable")[:min(noise, y.size)]] = True
    return flip


def _corrupt_drift(rng, x, y, noise, params, cls, waves: int = 4):
    """noise flips split across ``waves`` disjoint domain regions."""
    m = y.size
    order = np.argsort(_x1d(x), kind="stable")
    flip = np.zeros(m, bool)
    waves = max(min(waves, noise if noise else 1, m), 1)
    bounds = np.linspace(0, m, waves + 1).astype(np.int64)
    per = [noise // waves + (1 if g < noise % waves else 0)
           for g in range(waves)]
    for g in range(waves):
        seg = order[bounds[g]:bounds[g + 1]]
        take = min(per[g], seg.size)
        if take > 0:
            flip[rng.choice(seg, size=take, replace=False)] = True
    return flip


_CORRUPTORS = {
    "uniform": _corrupt_uniform,
    "targeted_heavy": _corrupt_targeted_heavy,
    "boundary": _corrupt_boundary,
    "drift": _corrupt_drift,
}


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """A named adversary with its knobs (hashable, so batch builders can
    key jit caches on it).

    ``name`` is either a noise adversary (:data:`SCENARIOS`) applied to
    a class-labelled task, or a planted multi-feature concept
    (:data:`FEATURE_SCENARIOS`); for the latter ``noise_kind`` picks
    which noise adversary corrupts the planted sample on top (the
    feature families and the corruptors compose, they don't compete).
    """

    name: str
    noise: int = 0
    byzantine_player: int = 0
    waves: int = 4
    # feature-family knobs
    noise_kind: str = "uniform"  # corruptor composed over a planted task
    cells: int = 4               # checkerboard strips per axis (2^j)
    n_bands: int = 4             # bands count (2^j)

    def __post_init__(self):
        if self.name not in SCENARIOS + FEATURE_SCENARIOS:
            raise ValueError(
                f"unknown scenario {self.name!r}; pick from "
                f"{SCENARIOS + FEATURE_SCENARIOS}")
        if self.name in FEATURE_SCENARIOS:
            if self.noise_kind not in _CORRUPTORS:
                raise ValueError(
                    f"noise_kind {self.noise_kind!r} must be one of "
                    f"{tuple(_CORRUPTORS)}")
            for v, what in ((self.cells, "cells"),
                            (self.n_bands, "n_bands")):
                if v < 2 or v & (v - 1):
                    raise ValueError(
                        f"{what} must be a power of two ≥ 2, got {v}")

    def min_tree_depth(self) -> int:
        """Tree depth this scenario is DESIGNED for (FEATURE_SCENARIOS
        only) — CLI entry points validate against it up front instead
        of failing (or silently plateauing) deep inside a run.  For
        ``bands`` this is the greedy peel-chain depth n_bands−1, not
        the balanced representability depth log2(n_bands): greedy
        grows the chain, and a shallower class predictably leaves an
        impure leaf (see the bands builder's comment)."""
        if self.name == "xor":
            return 2
        if self.name == "checkerboard":
            return 2 * (self.cells.bit_length() - 1)
        if self.name == "bands":
            return max(self.n_bands - 1, 1)
        raise ValueError(f"{self.name!r} plants no tree concept")


def corrupt_task(task: tasks.Task, spec: ScenarioSpec,
                 seed: int = 0) -> tasks.Task:
    """Apply a scenario to a CLEAN task; returns a new Task whose
    ``flipped`` mask marks exactly the corrupted examples."""
    y = np.array(task.y)
    k, mloc = y.shape
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x5CE7A]))
    if spec.name == "clean":
        flip = np.zeros(y.size, bool)
    elif spec.name == "byzantine":
        j = spec.byzantine_player % k
        flip = np.zeros((k, mloc), bool)
        flip[j] = True
        flip = flip.reshape(-1)
    else:
        flip = _CORRUPTORS[spec.name](
            rng, task.x, y.reshape(-1), spec.noise, task.target_params,
            task.cls, **({"waves": spec.waves} if spec.name == "drift"
                         else {}))
    yf = y.reshape(-1)
    yf[flip] = -yf[flip]
    return dataclasses.replace(
        task, y=yf.reshape(k, mloc).astype(np.int8),
        noise_count=int(flip.sum()), flipped=flip.reshape(k, mloc),
        scenario=spec.name)


# ---------------------------------------------------------------------------
# Multi-feature concept families (planted trees — the workloads stumps
# provably cannot fit).
# ---------------------------------------------------------------------------

def _bst_cut_levels(cuts) -> list:
    """Sorted interior cuts [2^j − 1] → per-level cut lists of the
    balanced BST over them (level i holds 2^i cuts).  A leaf's path
    bits, read as a binary number (right = 1), are its strip index —
    the in-order property the leaf labelling below relies on."""
    cuts = list(cuts)
    j = (len(cuts) + 1).bit_length() - 1
    assert (1 << j) == len(cuts) + 1, "cuts must number 2^j − 1"
    return [[cuts[(2 * t + 1) * (1 << (j - 1 - i)) - 1]
             for t in range(1 << i)] for i in range(j)]


def _require_distinct_cuts(cuts: np.ndarray, what: str,
                           Q: int) -> np.ndarray:
    """Planted cuts must be strictly increasing interior bins — a
    collision means a strip/band vanished and the concept is silently
    NOT what was requested.  Refuse loudly: the fix is more bins (or
    fewer cells/bands), not a degenerate plant."""
    if not (np.all(np.diff(cuts) > 0) and cuts[0] >= 1
            and cuts[-1] <= Q - 1):
        raise ValueError(
            f"{what}: cannot plant {len(cuts) + 1} distinct strips on "
            f"a {Q}-bin grid (cuts {cuts.tolist()} collide) — raise "
            "tree_bins or lower cells/n_bands")
    return cuts


def _uneven_cuts(rng, Q: int, parts: int) -> np.ndarray:
    """parts−1 interior cut bins, deliberately OFF the even grid.

    Greedy split finding needs gain at the true boundaries: a perfectly
    even partition makes interior cuts gain-free at the root (mass
    balances) and greedy degenerates.  Even spacing plus a nonzero
    jitter of ≤ ¼ strip keeps every strip alive while making each cut's
    two sides unbalanced.
    """
    step = Q // parts
    base = np.arange(1, parts) * step
    mag = max(step // 4, 1)
    jit = rng.integers(1, mag + 1, size=parts - 1) \
        * rng.choice([-1, 1], size=parts - 1)
    return _require_distinct_cuts(
        np.clip(base + jit, 1, Q - 1), f"checkerboard×{parts}", Q)


def _plant_tree(cls, levels: list, leaf_of_path) -> np.ndarray:
    """Encode a concept as params of ``cls`` (HistogramTrees).

    ``levels[i]`` is the list of (feature, qbin) of level i's 2^i
    nodes; depths below ``len(levels)`` pad with degenerate qbin = 0
    splits (everything routes right), and every leaf takes the value of
    its first len(levels) path bits — so the padded tree computes the
    same function at any ``cls.depth ≥ len(levels)``.
    """
    d0, D = len(levels), cls.depth
    if D < d0:
        raise ValueError(
            f"concept needs depth ≥ {d0}, class has {D}")
    feats = np.zeros(cls.nodes, np.int64)
    qbins = np.zeros(cls.nodes, np.int64)
    for lv in range(d0):
        for i, (f, q) in enumerate(levels[lv]):
            feats[(1 << lv) - 1 + i] = f
            qbins[(1 << lv) - 1 + i] = q
    signs = np.array([leaf_of_path(leaf >> (D - d0))
                      for leaf in range(cls.leaves)], np.float32)
    return cls.pack_params(feats, qbins, signs)


def _plant_feature_concept(cls, spec: ScenarioSpec, rng) -> np.ndarray:
    """The planted tree of a FEATURE_SCENARIOS member, over cls's grid."""
    Q, F = cls.bins, cls.num_features
    s0 = float(rng.choice([-1.0, 1.0]))
    if spec.name == "xor":
        # two half-plane cuts, off-centre on opposite sides by
        # [Q/8, 3Q/16]: greedy's root gain is proportional to the
        # offset (a centred XOR has a flat gain surface and greedy
        # degenerates), while the best-stump error is ≈ the smaller cut
        # mass — capping the offset at 3Q/16 keeps it ≥ 5/16 > 0.25,
        # the separation the trees-vs-stumps tests pin
        f1, f2 = rng.choice(F, size=2, replace=False)
        qa = int(rng.integers(5 * Q // 16, 3 * Q // 8 + 1))
        qb = int(rng.integers(5 * Q // 8, 11 * Q // 16 + 1))
        levels = [[(f1, qa)], [(f2, qb), (f2, qb)]]
        return _plant_tree(
            cls, levels,
            lambda p: s0 * (1.0 if (p >> 1) != (p & 1) else -1.0))
    if spec.name == "checkerboard":
        c = spec.cells
        j = c.bit_length() - 1
        f1, f2 = rng.choice(F, size=2, replace=False)
        lv1 = _bst_cut_levels(_uneven_cuts(rng, Q, c))
        lv2 = _bst_cut_levels(_uneven_cuts(rng, Q, c))
        levels = [[(f1, q) for q in lv1[i]] for i in range(j)]
        levels += [[(f2, lv2[i][idx % (1 << i)])
                    for idx in range(1 << (j + i))] for i in range(j)]
        return _plant_tree(
            cls, levels,
            lambda p: s0 * (1.0 if ((p >> j) + (p & ((1 << j) - 1)))
                            % 2 == 0 else -1.0))
    # bands: alternating-sign intervals of one feature, widths strictly
    # DECREASING.  Alternation defeats stumps (min-side error stays a
    # band mass) and, with equal widths, also defeats 1-step greedy
    # (every cut of a −+− region scores the middle band — a flat gain
    # surface).  Decreasing masses restore a strict greedy gradient:
    # peeling the widest end band wins at every level, so a depth ≥
    # n_bands−1 tree grows the exact peel chain (the planted tree
    # itself is the balanced depth-log2(n_bands) form).
    b = spec.n_bands
    j = b.bit_length() - 1
    f1 = int(rng.integers(F))
    widths = np.power(0.62, np.arange(b))
    cuts = np.round(np.cumsum(widths / widths.sum())[:-1] * Q)
    cuts = np.clip(cuts.astype(np.int64)
                   + rng.integers(-1, 2, size=b - 1), 1, Q - 1)
    cuts = _require_distinct_cuts(cuts, f"bands×{b}", Q)
    lv = _bst_cut_levels(cuts)
    levels = [[(f1, lv[i][idx % (1 << i)]) for idx in range(1 << i)]
              for i in range(j)]
    return _plant_tree(
        cls, levels, lambda p: s0 * (1.0 if p % 2 == 0 else -1.0))


def make_feature_task(cls, m: int, k: int, spec: ScenarioSpec,
                      seed: int = 0,
                      adversarial_split: bool = True) -> tasks.Task:
    """A planted multi-feature task: grid-snapped uniform points of
    [0, 1)^F labelled by the scenario's tree concept, adversarially
    split, then corrupted by ``spec.noise_kind`` (``spec.noise`` flips
    — the planted tree labels all of them wrong, so OPT ≤ noise with
    the concept itself as witness; see :func:`planted_errors`)."""
    if not hasattr(cls, "pack_params"):
        raise ValueError(
            f"{spec.name!r} plants a tree concept and needs a "
            f"HistogramTrees class, got {type(cls).__name__} (run other "
            "classes on these tasks via class_floor for comparison)")
    import jax.numpy as jnp
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xFEA7]))
    x = cls.sample_points(rng, m)
    params = _plant_feature_concept(cls, spec, rng)
    y = np.asarray(cls.predict(jnp.asarray(params),
                               jnp.asarray(x))).astype(np.int8)
    xs, ys = tasks._split(rng, x, y, k, adversarial_split)
    task = tasks.Task(x=xs, y=ys, target_params=params, noise_count=0,
                      cls=cls, flipped=np.zeros((k, m // k), bool),
                      scenario=spec.name)
    if spec.noise > 0:
        # every corruptor knob rides along (byzantine_player is inert
        # today — noise_kind can't name byzantine — but forgetting it
        # here would silently target player 0 if that ever changes)
        task = corrupt_task(
            task, ScenarioSpec(name=spec.noise_kind, noise=spec.noise,
                               waves=spec.waves,
                               byzantine_player=spec.byzantine_player),
            seed=seed)
        task = dataclasses.replace(
            task, target_params=params,
            scenario=f"{spec.name}+{spec.noise_kind}")
    return task


def planted_errors(task: tasks.Task) -> int:
    """Errors of the PLANTED concept on the (corrupted) sample — an
    in-class witness, so true OPT ≤ this (= noise_count when every flip
    lands on a distinct point).  The greedy tree ERM floor
    (:func:`class_floor`) can sit above true OPT; this cannot."""
    import jax.numpy as jnp
    pred = task.cls.predict(jnp.asarray(task.target_params),
                            jnp.asarray(task.flat_x))
    return int(weak.empirical_errors(pred, jnp.asarray(task.flat_y)))


def class_floor(task: tasks.Task, cls=None) -> int:
    """Best full-sample uniform-weight error count ``cls`` reaches on
    the task (default: the task's own class) — exact OPT for the
    closed-form 1-D classes and stumps, the greedy floor for trees.
    The trees-vs-stumps separation tests pin
    ``class_floor(xor_task, stumps) ≥ 0.25·m`` while the tree protocol
    reaches ≤ planted_errors + ε·m."""
    import jax.numpy as jnp
    cls = task.cls if cls is None else cls
    x = jnp.asarray(task.flat_x)
    y = jnp.asarray(task.flat_y)
    m = int(y.shape[0])
    w = jnp.ones((m,), jnp.float32) / m
    _, loss = cls.erm(x, y, w)
    return int(round(float(loss) * m))


def make_scenario_task(cls, m: int, k: int, spec: ScenarioSpec,
                       seed: int = 0,
                       adversarial_split: bool = True) -> tasks.Task:
    """Clean task from ``tasks.make_task`` (identical x/target streams),
    then scenario corruption on the split arrays; FEATURE_SCENARIOS
    route to :func:`make_feature_task` (planted concept + composed
    noise) instead."""
    if spec.name in FEATURE_SCENARIOS:
        return make_feature_task(cls, m=m, k=k, spec=spec, seed=seed,
                                 adversarial_split=adversarial_split)
    base = tasks.make_task(cls, m=m, k=k, noise=0, seed=seed,
                           adversarial_split=adversarial_split)
    return corrupt_task(base, spec, seed=seed)


def make_scenario_batch(cls, B: int, m: int, k: int, spec: ScenarioSpec,
                        seed0: int = 0, adversarial_split: bool = True):
    """B corrupted tasks stacked for the batched/sharded engines."""
    ts = [make_scenario_task(cls, m=m, k=k, spec=spec, seed=seed0 + b,
                             adversarial_split=adversarial_split)
          for b in range(B)]
    return (np.stack([t.x for t in ts]), np.stack([t.y for t in ts]),
            ts)


# ---------------------------------------------------------------------------
# Infrastructure adversaries: per-round player-alive schedules.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InfraSpec:
    """A named infrastructure adversary with its knobs (hashable).

    The schedule row at wire round ``min(step, R−1)`` is the round's
    player mask — the final row extends forever, so ``dropout`` ends on
    a dead row and ``flaky``/``rejoin`` end on a live one.
    """

    name: str = "none"
    player: int = 0              # the targeted player
    drop_round: int = 6          # dropout/rejoin: first absent round
    rejoin_round: int = 18       # rejoin: first round back
    miss_rate: float = 0.3       # flaky: per-round absence probability
    horizon: int = 64            # flaky: schedule rows drawn

    def __post_init__(self):
        if self.name not in INFRA:
            raise ValueError(
                f"unknown infra adversary {self.name!r}; pick from {INFRA}")
        if self.name == "rejoin" and self.rejoin_round <= self.drop_round:
            raise ValueError("rejoin_round must exceed drop_round")

    def schedule(self, k: int, seed: int = 0) -> np.ndarray:
        """The ``[R, k]`` bool player_alive schedule this adversary
        induces.  Every row keeps ≥ 1 player alive (k ≥ 2 required for
        any adversary that silences a player)."""
        if self.name == "none":
            return np.ones((1, k), bool)
        if k < 2:
            raise ValueError(f"{self.name} needs k ≥ 2 players")
        j = self.player % k
        if self.name == "dropout":
            sched = np.ones((self.drop_round + 1, k), bool)
            sched[self.drop_round:, j] = False
        elif self.name == "rejoin":
            sched = np.ones((self.rejoin_round + 1, k), bool)
            sched[self.drop_round:self.rejoin_round, j] = False
        else:                                           # flaky
            rng = np.random.default_rng(
                np.random.SeedSequence([seed, 0xF1A2]))
            sched = np.ones((self.horizon, k), bool)
            sched[:, j] = rng.random(self.horizon) >= self.miss_rate
            sched[-1, j] = True        # always returns eventually
        assert sched.any(axis=-1).all()
        return sched

    def survivors(self, k: int, seed: int = 0) -> np.ndarray:
        """[k] bool — players alive at the schedule's horizon (its
        final, forever-repeating row): the shard set the E_S(f) ≤ OPT
        guarantee is pinned over."""
        return self.schedule(k, seed=seed)[-1]


def infra_report(task: tasks.Task, result, b: int,
                 spec: InfraSpec, seed: int = 0) -> dict:
    """Guarantee stats of one fault-injected task, over the shards of
    surviving players only: E_S(f) vs OPT restricted to those shards,
    with the dispute vote counting surviving copies."""
    import jax.numpy as jnp

    from repro.core import classify as C

    k = task.y.shape[0]
    surv = spec.survivors(k, seed=seed)
    res = result.per_task(b, player_mask=surv)
    f = C.make_classifier(task.cls, res)
    xs = task.x[surv].reshape((-1,) + task.x.shape[2:])
    ys = task.y[surv].reshape(-1)
    errs = int(weak.empirical_errors(f(jnp.asarray(xs)),
                                     jnp.asarray(ys)))
    m_s = ys.shape[0]
    w = jnp.ones((m_s,), jnp.float32) / m_s
    _, opt_loss = task.cls.erm(jnp.asarray(xs), jnp.asarray(ys), w)
    opt = int(round(float(opt_loss) * m_s))
    return {
        "infra": spec.name,
        "survivors": int(surv.sum()),
        "errors": errs,
        "opt": opt,
        "guarantee_ok": errs <= opt,
        "attempts": res.attempts,
        "disputed": int(res.dispute_count),
        "bits": res.ledger.total_bits,
    }


# ---------------------------------------------------------------------------
# Ground-truth helpers for the guarantee tests / serving stats.
# ---------------------------------------------------------------------------

def planted_points(task: tasks.Task) -> np.ndarray:
    """Unique domain points whose labels the scenario corrupted."""
    if task.flipped is None or not task.flipped.any():
        return np.zeros((0,) + tuple(task.x.shape[2:]), task.x.dtype)
    flat = task.flat_x
    sel = task.flipped.reshape(-1)
    return (np.unique(flat[sel], axis=0) if flat.ndim == 2
            else np.unique(flat[sel]))


def contradicted_points(task: tasks.Task) -> np.ndarray:
    """Points carrying BOTH labels in S — the sub-multiset no classifier
    can be consistent with (each contributes ≥ min(n₊, n₋) to OPT)."""
    xf, yf = task.flat_x, task.flat_y
    if xf.ndim == 2:                     # feature rows: O(m²) but tiny m
        eq = (xf[:, None, :] == xf[None]).all(-1)
        both = ((eq & (yf[None] > 0)).any(1)
                & (eq & (yf[None] < 0)).any(1))
        pts = xf[both]
        return np.unique(pts, axis=0) if pts.size else pts
    vals = np.unique(xf)
    pos = np.isin(vals, xf[yf > 0])
    neg = np.isin(vals, xf[yf < 0])
    return vals[pos & neg]


def quarantine_recall(dispute_x: np.ndarray, target_pts: np.ndarray,
                      ) -> float:
    """Fraction of the target point set that ended up quarantined."""
    tgt = np.asarray(target_pts)
    if tgt.shape[0] == 0:
        return 1.0
    dis = np.asarray(dispute_x)
    if tgt.ndim == 2:
        hit = (dis[:, None, :] == tgt[None]).all(-1).any(0) \
            if dis.shape[0] else np.zeros(tgt.shape[0], bool)
    else:
        hit = np.isin(tgt, dis)
    return float(hit.mean())


def scenario_report(task: tasks.Task, result, b: int | None = None,
                    ) -> dict:
    """Guarantee stats of one solved task: E_S(f) vs OPT, quarantine
    recall on contradicted/planted points.  ``result`` is either a
    ClassifyResult or a Batched/ShardedClassifyResult with lane b."""
    import jax.numpy as jnp

    from repro.core import classify

    res = result.per_task(b) if b is not None else result
    f = classify.make_classifier(task.cls, res)
    errs = int(weak.empirical_errors(f(jnp.asarray(task.flat_x)),
                                     jnp.asarray(task.flat_y)))
    opt = tasks.true_opt(task)
    contr = contradicted_points(task)
    return {
        "scenario": task.scenario,
        "errors": errs,
        "opt": opt,
        "guarantee_ok": errs <= opt,
        "attempts": res.attempts,
        "disputed": int(res.dispute_count),
        "contradicted": int(contr.shape[0]),
        "recall_contradicted": quarantine_recall(
            np.asarray(res.dispute_x), contr),
        "recall_planted": quarantine_recall(
            np.asarray(res.dispute_x), planted_points(task)),
        "bits": res.ledger.total_bits,
    }
