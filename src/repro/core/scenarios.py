"""Adversarial noise scenarios beyond uniform label flips.

The resilience claims of the paper (Theorem 2.2 / 4.1) are adversarial:
E_S(f) ≤ OPT holds for *any* sample, however the noise is placed and
however the shards are partitioned.  Uniform random flips — the only
noise `tasks.make_task` plants — are the weakest adversary that bound
permits.  This module supplies the stronger ones, each targeting a
different part of the protocol:

``uniform``
    Baseline: ``noise`` flips at uniformly random distinct examples.
``targeted_heavy``
    Flips one copy of each of the ``noise`` *most duplicated* points.
    Every corrupted point becomes contradicting (both labels present in
    S), i.e. pure hard-core mass: no hypothesis can be consistent, MW
    drives the weight onto exactly these points, and the Impagliazzo-
    style quarantine must find them (tests pin recall ≥ 0.9).
``byzantine``
    One colluding player flips its *entire shard* — the adversarial-
    partition worst case (with the sort-order split that player owns a
    contiguous domain region).  OPT jumps to O(m/k) and the protocol
    must still terminate with E_S(f) ≤ OPT.
``boundary``
    All flips concentrated on the points nearest the target concept's
    decision boundary, where a hypothesis-class learner is most easily
    misled (label noise is indistinguishable from a shifted threshold
    until the weights sharpen).
``drift``
    The flip budget is spread across ``waves`` disjoint domain regions.
    Under the adversarial (sorted) split each region lives at a
    different player, so successive stuck→quarantine attempts chase a
    *moving* noise front instead of one hard core.

All corruptors are pure numpy on the already-split ``[k, mloc]``
arrays, deterministic in their rng, and return an explicit flip mask so
tests can compute recall/precision of the quarantine against the
planted ground truth.

Infrastructure adversaries (:class:`InfraSpec`) attack the *protocol*
rather than the labels: they emit a per-round ``player_alive [R, k]``
schedule the fault-tolerant engines consume (``player_sched=``):

``dropout``
    Player j participates until wire round r, then vanishes forever —
    the Blum et al. communication-aware setting where a party's budget
    (or the party) runs out mid-protocol.
``flaky``
    Player j misses a Bernoulli(``miss_rate``) subset of rounds (a
    straggler that overruns the round deadline) but always returns.
``rejoin``
    Player j is absent for rounds [r, r′), then rejoins with its MW
    state frozen at departure — a preempted worker coming back.

Pinned guarantees (tests/test_fault_tolerance.py): the protocol still
terminates with E_S(f) ≤ OPT *over the surviving shards* (players alive
in the schedule's final row), and the communication ledger equals the
measured collective payloads **under the mask** — only bits alive
players actually sent are charged.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import tasks, weak

SCENARIOS = ("clean", "uniform", "targeted_heavy", "byzantine",
             "boundary", "drift")
INFRA = ("none", "dropout", "flaky", "rejoin")


def _x1d(x: np.ndarray) -> np.ndarray:
    """The 1-D sort key of the domain points ([k·mloc] flat)."""
    flat = x.reshape((-1,) + x.shape[2:])
    return flat if flat.ndim == 1 else flat[:, 0]


def _corrupt_uniform(rng, x, y, noise, params, cls):
    m = y.size
    flip = np.zeros(m, bool)
    if noise > 0:
        flip[rng.choice(m, size=min(noise, m), replace=False)] = True
    return flip


def _corrupt_targeted_heavy(rng, x, y, noise, params, cls):
    """One flipped copy of each of the ``noise`` heaviest points.

    Heaviness is multiplicity of the FULL point (whole feature row on
    the feature track), because the adversary's power here is exactly
    the hard-core mass a flipped copy creates: a point with a single
    copy yields no contradiction.  A continuous sample has no
    duplicates, so this adversary cannot materialise there — refuse
    loudly instead of silently degrading to arbitrary flips.
    """
    flat = x.reshape((-1,) + x.shape[2:])
    if flat.ndim == 2:
        _, first_idx, counts = np.unique(flat, axis=0, return_index=True,
                                         return_counts=True)
        keys = np.arange(first_idx.size)
    else:
        keys, first_idx, counts = np.unique(flat, return_index=True,
                                            return_counts=True)
    if noise > 0 and counts.max(initial=0) < 2:
        raise ValueError(
            "targeted_heavy needs duplicated points to corrupt (its "
            "flips must contradict surviving copies); this sample has "
            "none — use a discrete domain or another scenario")
    # heaviest first; ties broken by value so the choice is deterministic
    order = np.lexsort((keys, -counts))
    flip = np.zeros(y.size, bool)
    flip[first_idx[order[:min(noise, first_idx.size)]]] = True
    return flip


def _corrupt_boundary(rng, x, y, noise, params, cls):
    """Flips at the ``noise`` points nearest the target's boundary."""
    xf = _x1d(x).astype(np.float64)
    t, a, b = float(params[0]), float(params[1]), float(params[2])
    if t == 3.0:                               # interval: both endpoints
        dist = np.minimum(np.abs(xf - a), np.abs(xf - b))
    elif t == 4.0:                             # stump: feature a, theta b
        feat = x.reshape((-1,) + x.shape[2:])[:, int(a)].astype(np.float64)
        dist = np.abs(feat - b)
    else:                                      # threshold / singleton: a
        dist = np.abs(xf - a)
    flip = np.zeros(y.size, bool)
    flip[np.argsort(dist, kind="stable")[:min(noise, y.size)]] = True
    return flip


def _corrupt_drift(rng, x, y, noise, params, cls, waves: int = 4):
    """noise flips split across ``waves`` disjoint domain regions."""
    m = y.size
    order = np.argsort(_x1d(x), kind="stable")
    flip = np.zeros(m, bool)
    waves = max(min(waves, noise if noise else 1, m), 1)
    bounds = np.linspace(0, m, waves + 1).astype(int)
    per = [noise // waves + (1 if g < noise % waves else 0)
           for g in range(waves)]
    for g in range(waves):
        seg = order[bounds[g]:bounds[g + 1]]
        take = min(per[g], seg.size)
        if take > 0:
            flip[rng.choice(seg, size=take, replace=False)] = True
    return flip


_CORRUPTORS = {
    "uniform": _corrupt_uniform,
    "targeted_heavy": _corrupt_targeted_heavy,
    "boundary": _corrupt_boundary,
    "drift": _corrupt_drift,
}


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """A named adversary with its knobs (hashable, so batch builders can
    key jit caches on it)."""

    name: str
    noise: int = 0
    byzantine_player: int = 0
    waves: int = 4

    def __post_init__(self):
        if self.name not in SCENARIOS:
            raise ValueError(
                f"unknown scenario {self.name!r}; pick from {SCENARIOS}")


def corrupt_task(task: tasks.Task, spec: ScenarioSpec,
                 seed: int = 0) -> tasks.Task:
    """Apply a scenario to a CLEAN task; returns a new Task whose
    ``flipped`` mask marks exactly the corrupted examples."""
    y = np.array(task.y)
    k, mloc = y.shape
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x5CE7A]))
    if spec.name == "clean":
        flip = np.zeros(y.size, bool)
    elif spec.name == "byzantine":
        j = spec.byzantine_player % k
        flip = np.zeros((k, mloc), bool)
        flip[j] = True
        flip = flip.reshape(-1)
    else:
        flip = _CORRUPTORS[spec.name](
            rng, task.x, y.reshape(-1), spec.noise, task.target_params,
            task.cls, **({"waves": spec.waves} if spec.name == "drift"
                         else {}))
    yf = y.reshape(-1)
    yf[flip] = -yf[flip]
    return dataclasses.replace(
        task, y=yf.reshape(k, mloc).astype(np.int8),
        noise_count=int(flip.sum()), flipped=flip.reshape(k, mloc),
        scenario=spec.name)


def make_scenario_task(cls, m: int, k: int, spec: ScenarioSpec,
                       seed: int = 0,
                       adversarial_split: bool = True) -> tasks.Task:
    """Clean task from ``tasks.make_task`` (identical x/target streams),
    then scenario corruption on the split arrays."""
    base = tasks.make_task(cls, m=m, k=k, noise=0, seed=seed,
                           adversarial_split=adversarial_split)
    return corrupt_task(base, spec, seed=seed)


def make_scenario_batch(cls, B: int, m: int, k: int, spec: ScenarioSpec,
                        seed0: int = 0, adversarial_split: bool = True):
    """B corrupted tasks stacked for the batched/sharded engines."""
    ts = [make_scenario_task(cls, m=m, k=k, spec=spec, seed=seed0 + b,
                             adversarial_split=adversarial_split)
          for b in range(B)]
    return (np.stack([t.x for t in ts]), np.stack([t.y for t in ts]),
            ts)


# ---------------------------------------------------------------------------
# Infrastructure adversaries: per-round player-alive schedules.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InfraSpec:
    """A named infrastructure adversary with its knobs (hashable).

    The schedule row at wire round ``min(step, R−1)`` is the round's
    player mask — the final row extends forever, so ``dropout`` ends on
    a dead row and ``flaky``/``rejoin`` end on a live one.
    """

    name: str = "none"
    player: int = 0              # the targeted player
    drop_round: int = 6          # dropout/rejoin: first absent round
    rejoin_round: int = 18       # rejoin: first round back
    miss_rate: float = 0.3       # flaky: per-round absence probability
    horizon: int = 64            # flaky: schedule rows drawn

    def __post_init__(self):
        if self.name not in INFRA:
            raise ValueError(
                f"unknown infra adversary {self.name!r}; pick from {INFRA}")
        if self.name == "rejoin" and self.rejoin_round <= self.drop_round:
            raise ValueError("rejoin_round must exceed drop_round")

    def schedule(self, k: int, seed: int = 0) -> np.ndarray:
        """The ``[R, k]`` bool player_alive schedule this adversary
        induces.  Every row keeps ≥ 1 player alive (k ≥ 2 required for
        any adversary that silences a player)."""
        if self.name == "none":
            return np.ones((1, k), bool)
        if k < 2:
            raise ValueError(f"{self.name} needs k ≥ 2 players")
        j = self.player % k
        if self.name == "dropout":
            sched = np.ones((self.drop_round + 1, k), bool)
            sched[self.drop_round:, j] = False
        elif self.name == "rejoin":
            sched = np.ones((self.rejoin_round + 1, k), bool)
            sched[self.drop_round:self.rejoin_round, j] = False
        else:                                           # flaky
            rng = np.random.default_rng(
                np.random.SeedSequence([seed, 0xF1A2]))
            sched = np.ones((self.horizon, k), bool)
            sched[:, j] = rng.random(self.horizon) >= self.miss_rate
            sched[-1, j] = True        # always returns eventually
        assert sched.any(axis=-1).all()
        return sched

    def survivors(self, k: int, seed: int = 0) -> np.ndarray:
        """[k] bool — players alive at the schedule's horizon (its
        final, forever-repeating row): the shard set the E_S(f) ≤ OPT
        guarantee is pinned over."""
        return self.schedule(k, seed=seed)[-1]


def infra_report(task: tasks.Task, result, b: int,
                 spec: InfraSpec, seed: int = 0) -> dict:
    """Guarantee stats of one fault-injected task, over the shards of
    surviving players only: E_S(f) vs OPT restricted to those shards,
    with the dispute vote counting surviving copies."""
    import jax.numpy as jnp

    from repro.core import classify as C

    k = task.y.shape[0]
    surv = spec.survivors(k, seed=seed)
    res = result.per_task(b, player_mask=surv)
    f = C.make_classifier(task.cls, res)
    xs = task.x[surv].reshape((-1,) + task.x.shape[2:])
    ys = task.y[surv].reshape(-1)
    errs = int(weak.empirical_errors(f(jnp.asarray(xs)),
                                     jnp.asarray(ys)))
    m_s = ys.shape[0]
    w = jnp.ones((m_s,), jnp.float32) / m_s
    _, opt_loss = task.cls.erm(jnp.asarray(xs), jnp.asarray(ys), w)
    opt = int(round(float(opt_loss) * m_s))
    return {
        "infra": spec.name,
        "survivors": int(surv.sum()),
        "errors": errs,
        "opt": opt,
        "guarantee_ok": errs <= opt,
        "attempts": res.attempts,
        "disputed": int(res.dispute_count),
        "bits": res.ledger.total_bits,
    }


# ---------------------------------------------------------------------------
# Ground-truth helpers for the guarantee tests / serving stats.
# ---------------------------------------------------------------------------

def planted_points(task: tasks.Task) -> np.ndarray:
    """Unique domain points whose labels the scenario corrupted."""
    if task.flipped is None or not task.flipped.any():
        return np.zeros((0,) + tuple(task.x.shape[2:]), task.x.dtype)
    flat = task.flat_x
    sel = task.flipped.reshape(-1)
    return (np.unique(flat[sel], axis=0) if flat.ndim == 2
            else np.unique(flat[sel]))


def contradicted_points(task: tasks.Task) -> np.ndarray:
    """Points carrying BOTH labels in S — the sub-multiset no classifier
    can be consistent with (each contributes ≥ min(n₊, n₋) to OPT)."""
    xf, yf = task.flat_x, task.flat_y
    if xf.ndim == 2:                     # feature rows: O(m²) but tiny m
        eq = (xf[:, None, :] == xf[None]).all(-1)
        both = ((eq & (yf[None] > 0)).any(1)
                & (eq & (yf[None] < 0)).any(1))
        pts = xf[both]
        return np.unique(pts, axis=0) if pts.size else pts
    vals = np.unique(xf)
    pos = np.isin(vals, xf[yf > 0])
    neg = np.isin(vals, xf[yf < 0])
    return vals[pos & neg]


def quarantine_recall(dispute_x: np.ndarray, target_pts: np.ndarray,
                      ) -> float:
    """Fraction of the target point set that ended up quarantined."""
    tgt = np.asarray(target_pts)
    if tgt.shape[0] == 0:
        return 1.0
    dis = np.asarray(dispute_x)
    if tgt.ndim == 2:
        hit = (dis[:, None, :] == tgt[None]).all(-1).any(0) \
            if dis.shape[0] else np.zeros(tgt.shape[0], bool)
    else:
        hit = np.isin(tgt, dis)
    return float(hit.mean())


def scenario_report(task: tasks.Task, result, b: int | None = None,
                    ) -> dict:
    """Guarantee stats of one solved task: E_S(f) vs OPT, quarantine
    recall on contradicted/planted points.  ``result`` is either a
    ClassifyResult or a Batched/ShardedClassifyResult with lane b."""
    import jax.numpy as jnp

    from repro.core import classify

    res = result.per_task(b) if b is not None else result
    f = classify.make_classifier(task.cls, res)
    errs = int(weak.empirical_errors(f(jnp.asarray(task.flat_x)),
                                     jnp.asarray(task.flat_y)))
    opt = tasks.true_opt(task)
    contr = contradicted_points(task)
    return {
        "scenario": task.scenario,
        "errors": errs,
        "opt": opt,
        "guarantee_ok": errs <= opt,
        "attempts": res.attempts,
        "disputed": int(res.dispute_count),
        "contradicted": int(contr.shape[0]),
        "recall_contradicted": quarantine_recall(
            np.asarray(res.dispute_x), contr),
        "recall_planted": quarantine_recall(
            np.asarray(res.dispute_x), planted_points(task)),
        "bits": res.ledger.total_bits,
    }
