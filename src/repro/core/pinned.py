"""Tie-pinned reductions — the repo-law replacements for bare argmin/argmax.

Backend tie-breaking of ``jnp.argmin``/``jnp.argmax``/``lax.top_k`` is
NOT a contract: XLA:CPU happens to return the first occurrence, but TPU
reduction layouts make no such promise, and the whole value proposition
of the engines (bit-identical host/batched/sharded outputs, engine-
independent ERM winners) collapses if a tie can resolve differently per
backend.  Every selection on a value surface that can tie — ERM
candidate errors, split gains, vote elections — must therefore go
through a helper that spells the tie-break out in portable ops.

These helpers pin ties to the LOWEST index along the reduced axis,
implemented with ``min``/``where``/``iota`` only (no argmin/argmax
primitive reaches the jaxpr — ``tools/repro_lint`` audits traced
engines for exactly that).  On XLA:CPU the result is bit-identical to
the bare op, so adopting them is invisible to the parity suites.

``kernels/histogram/ref._pinned_argmin`` is the same construction,
kept local so the kernel oracle stays dependency-free; this module is
the canonical import for everything outside the kernel triples.
"""

from __future__ import annotations

import jax.numpy as jnp


def _pin_lowest(match: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Lowest index along ``axis`` where ``match`` holds (int32)."""
    size = match.shape[axis]
    shape = [1] * match.ndim
    shape[axis] = size
    idx = jnp.arange(size, dtype=jnp.int32).reshape(shape)
    return jnp.min(jnp.where(match, idx, jnp.int32(size)), axis=axis)


def pinned_argmin(v: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Index of the minimum along ``axis``, ties pinned to the lowest
    index — explicitly, not via argmin's backend-dependent tie order."""
    v = jnp.asarray(v)
    axis = axis % v.ndim
    vmin = jnp.min(v, axis=axis, keepdims=True)
    return _pin_lowest(v == vmin, axis)


def pinned_argmax(v: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Index of the maximum along ``axis``, ties pinned to the lowest
    index (the mirror of :func:`pinned_argmin`)."""
    v = jnp.asarray(v)
    axis = axis % v.ndim
    vmax = jnp.max(v, axis=axis, keepdims=True)
    return _pin_lowest(v == vmax, axis)
