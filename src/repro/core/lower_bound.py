"""Theorem 2.3 — the complementing negative result.

The Kane–Livni–Moran–Yehudayoff mapping turns a set-disjointness
instance (x, y ∈ {0,1}^r) into a distributed sample for the singletons
class:

    F_a(x) = {(i, (−1)^{1−x_i}) : i ∈ [r]},
    F_b(y) = {(i, (−1)^{1−y_i}) : i ∈ [r]}.

Lemma 5.1: if DISJ(x,y)=1 (disjoint) every classifier errs ≥ w(x)+w(y)
times on S = ⟨F_a(x); F_b(y)⟩, while if DISJ(x,y)=0 the best singleton
errs exactly w(x)+w(y)−2.  Hence a learner achieving E_S(f) ≤ OPT under
the promise OPT ≤ T(n) decides disjointness, which costs Ω(r) bits
(Razborov 1990; Kalyanasundaram–Schnitger 1992) — so communication must
grow Ω(T(n)).

We implement the reduction end-to-end so benchmarks can (a) verify that
our protocol *solves* the hard instances and (b) measure that its
communication indeed grows linearly with OPT ≈ T(n) — the matching
upper bound the paper points out ("more general than stated").
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classify, weak
from repro.core.types import BoostConfig


def disj_to_sample(xbits: np.ndarray, ybits: np.ndarray, n: int):
    """Build the 2-player distributed sample ⟨F_a(x); F_b(y)⟩ over [n).

    Bits are first zero-extended from r to n conceptually; the examples
    only mention points [0, r) so we materialize those (the remaining
    points never appear in S and influence nothing).
    """
    r = xbits.shape[0]
    assert ybits.shape[0] == r and r <= n
    pts = np.arange(r, dtype=np.int32)
    sa = ((-1) ** (1 - xbits)).astype(np.int8)      # +1 iff x_i = 1
    sb = ((-1) ** (1 - ybits)).astype(np.int8)
    x = jnp.stack([jnp.asarray(pts), jnp.asarray(pts)])      # [2, r]
    y = jnp.stack([jnp.asarray(sa), jnp.asarray(sb)])        # [2, r]
    return x, y


@dataclasses.dataclass
class DisjOutcome:
    disjoint_decided: bool
    errors: int
    opt: int
    total_bits: int
    attempts: int


def solve_disjointness(xbits: np.ndarray, ybits: np.ndarray, n: int,
                       cfg: BoostConfig, seed: int = 0) -> DisjOutcome:
    """The protocol π' from the proof of Theorem 2.3."""
    r = int(xbits.shape[0])
    wx, wy = int(xbits.sum()), int(ybits.sum())       # published: 2·log r bits
    x, y = disj_to_sample(xbits, ybits, n)
    cls = weak.Singletons(n=n)
    f, res = classify.learn(x, y, jax.random.key(seed), cfg, cls)
    preds = f(x.reshape(-1))
    errors = int(weak.empirical_errors(preds, y.reshape(-1)))
    # true OPT of the constructed sample (Lemma 5.1): an intersection
    # point j gives h_j two correct +1 examples (err = w(x)+w(y)−2);
    # in the disjoint case every classifier errs ≥ w(x)+w(y).
    inter = int(np.sum((xbits == 1) & (ybits == 1)))
    opt = wx + wy - 2 if inter > 0 else wx + wy
    # decision rule of π': output "disjoint" iff E_S(f) ≥ w(x)+w(y)
    decided_disjoint = errors >= wx + wy
    bits = res.ledger.total_bits + 2 * max(1, int(np.ceil(np.log2(max(r, 2)))))
    return DisjOutcome(disjoint_decided=decided_disjoint, errors=errors,
                       opt=opt, total_bits=bits, attempts=res.attempts)


def random_disj_instance(rng: np.random.Generator, r: int, weight: int,
                         disjoint: bool):
    """Random DISJ instance with |x|=|y|=weight and the given answer."""
    xbits = np.zeros(r, np.int8)
    ybits = np.zeros(r, np.int8)
    xi = rng.choice(r, size=weight, replace=False)
    xbits[xi] = 1
    if disjoint:
        rest = np.setdiff1d(np.arange(r), xi)
        ybits[rng.choice(rest, size=min(weight, rest.size),
                         replace=False)] = 1
    else:
        # force exactly one intersection point
        ybits[rng.choice(xi, size=1)] = 1
        rest = np.setdiff1d(np.arange(r), np.where(xbits | ybits)[0])
        extra = min(weight - 1, rest.size)
        if extra > 0:
            ybits[rng.choice(rest, size=extra, replace=False)] = 1
    return xbits, ybits
