"""The reduction baseline the paper discusses (Section 1 / Section 2.2).

An anonymous ALT'22 reviewer pointed out Theorem 2.2 also follows from
*semi-agnostic* distributed learning (Balcan et al. 2012; Chen, Balcan,
Chau 2016): obtain f with E_S(f) ≤ c·OPT using poly-communication, then
have every player broadcast the examples f misclassifies (≤ c·OPT of
them, each d·log n bits) and patch f on those points.

We implement a faithful *lite* version of that route to compare against
the paper's direct protocol:

1. ``agnostic_boost`` — distributed boosting with the same coreset
   messages, but instead of getting stuck it always takes the ERM
   hypothesis (best-effort weak learner) and runs the full T rounds,
   with the SmoothBoost-style weight cap (weights are clipped at
   ``smooth_cap`` × uniform) that Chen–Balcan–Chau use to bound the
   damage noisy examples can do.  Its output g satisfies
   E_S(g) ≤ c·OPT empirically (c measured by the benchmark, the paper's
   cited bound is a constant ≥ 2).
2. ``patch`` — players broadcast all examples g misclassifies; the final
   classifier answers by a majority vote over the broadcast multiset
   and falls back to g elsewhere.

Communication = boosting rounds (same ledger entries as BoostAttempt)
+ the patch broadcast (|misclassified| · (⌈log2 n⌉+1) bits, counted
exactly).  The benchmark compares total bits and final error against
AccuratelyClassify on identical inputs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import approximation, ledger as L, weak, weights as W
from repro.core.types import BoostConfig, Ledger


class _Carry(NamedTuple):
    t: jax.Array
    hits: jax.Array
    key: jax.Array
    h_params: jax.Array


def _capped_probs(hits, alive, cap: float):
    """SmoothBoost-style clipped distribution: min(p, cap/m), renormalized."""
    p = W.probs(hits, alive)
    m_alive = jnp.maximum(jnp.sum(alive), 1)
    p = jnp.minimum(p, cap / m_alive)
    p = jnp.where(alive, p, 0.0)
    return p / jnp.maximum(jnp.sum(p), 1e-30)


@functools.partial(jax.jit, static_argnames=("cfg", "cls", "num_rounds",
                                             "smooth_cap"))
def _agnostic_boost_jit(x, y, alive, key, cfg: BoostConfig, cls,
                        num_rounds: int, smooth_cap: float):
    k, c = x.shape[0], cfg.coreset_size

    def body(carry: _Carry, _):
        key, kc = jax.random.split(carry.key)
        keys = jax.random.split(kc, k)

        def player_coreset(kk, xx, hh, aa):
            p = _capped_probs(hh, aa, smooth_cap)
            logits = jnp.log(jnp.maximum(p, 1e-30))
            return jax.random.categorical(kk, logits, shape=(c,))

        idx = jax.vmap(player_coreset)(keys, x, carry.hits, alive)
        take = functools.partial(jnp.take_along_axis, axis=1)
        cx = take(x, idx[..., None]) if x.ndim == 3 else take(x, idx)
        cy = take(y, idx)
        log_wsums = jax.vmap(W.log_weight_sum)(carry.hits, alive)
        mix = W.mixture_weights(log_wsums)
        w = jnp.broadcast_to(mix[:, None] / c, (k, c)).reshape(-1)
        h, loss = cls.erm(cx.reshape((k * c,) + cx.shape[2:]),
                          cy.reshape(-1), w)
        pred = cls.predict(h, x)
        hits = W.update_hits(carry.hits, pred == y, alive)
        h_params = carry.h_params.at[carry.t].set(h)
        return _Carry(carry.t + 1, hits, key, h_params), loss

    carry0 = _Carry(jnp.int32(0), W.init_hits(x.shape[:2]), key,
                    jnp.zeros((num_rounds, weak.param_dim(cls)),
                              jnp.float32))
    carry, losses = jax.lax.scan(body, carry0, None, length=num_rounds)
    return carry.h_params, losses


@dataclasses.dataclass
class SemiAgnosticResult:
    classifier: object
    boost_errors: int           # E_S(g) before patching
    final_errors: int           # E_S(f) after patching
    patched: int                # examples broadcast in the patch step
    ledger: Ledger


def run_semi_agnostic(x, y, key, cfg: BoostConfig, cls,
                      smooth_cap: float = 8.0) -> SemiAgnosticResult:
    k, mloc = x.shape[0], x.shape[1]
    m = k * mloc
    num_rounds = cfg.num_rounds(m)
    alive = jnp.ones((k, mloc), bool)
    h_params, _ = _agnostic_boost_jit(x, y, alive, key, cfg, cls,
                                      num_rounds, smooth_cap)
    g = functools.partial(weak.ensemble_predict, cls, h_params, num_rounds)
    gx = g(x)
    wrong = np.asarray(gx != y)
    # patch step: players broadcast every misclassified example; the
    # center patches f on those points by the full-count majority
    # (players also report counts of their correctly-classified copies
    # of the same points — same accounting as classify.py).
    xf = np.asarray(x).reshape((m,) + tuple(x.shape[2:]))
    yf = np.asarray(y).reshape(-1)
    wf = wrong.reshape(-1)
    if wf.any():
        bad = xf[wf]
        pts = np.unique(bad, axis=0) if bad.ndim == 2 else np.unique(bad)
        if pts.ndim == 2:
            eq = (xf[:, None, :] == pts[None]).all(-1)
        else:
            eq = xf[:, None] == pts[None]
        pos = (((yf > 0)[:, None]) & eq).sum(0)
        neg = (((yf < 0)[:, None]) & eq).sum(0)
    else:
        pts = np.zeros((0,) + tuple(xf.shape[1:]), xf.dtype)
        pos = neg = np.zeros((0,), np.int64)
    from repro.core.classify import ResilientClassifier
    f = ResilientClassifier(cls=cls, hypotheses=h_params,
                            rounds=num_rounds, dispute_x=jnp.asarray(pts),
                            dispute_pos=jnp.asarray(pos),
                            dispute_neg=jnp.asarray(neg))
    preds = f(jnp.asarray(xf))
    final_errors = int(weak.empirical_errors(preds, jnp.asarray(yf)))
    n = L.domain_size(cls)
    led = L.boost_attempt_ledger(cfg, cls, m, num_rounds, stuck=False)
    led.bits_dispute = int(wf.sum()) * L.example_bits(n) * cfg.k
    return SemiAgnosticResult(
        classifier=f, boost_errors=int(wrong.sum()),
        final_errors=final_errors, patched=int(wf.sum()), ledger=led)
