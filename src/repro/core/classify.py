"""AccuratelyClassify (Figure 2) — the resilient learning protocol.

Outer loop: run BoostAttempt; while it returns a non-realizable coreset
S', quarantine S' (dispute multiset D — Observation 4.4 guarantees every
hypothesis' error drops by ≥ 1, so at most OPT iterations) and retry.
When an attempt succeeds, the final classifier is the dispute majority
vote patched over the boosted ensemble g.

Full-point quarantine (documented deviation, see DESIGN.md §8).  The
paper removes exactly the sub-multiset S' and votes over D-counts only.
When an ε-approximation captures only *some* copies of a point x (or
copies at one player but not another), the D-vote can disagree with the
overall majority at x and f errs up to OPT + O(1) — we observed exactly
this off-by-one empirically.  We therefore quarantine **every copy of
every disputed point, across all players**:

* the center broadcasts the stuck coreset's point set
  (|S'|·⌈log2 n⌉ bits to each of k players — same order as the coreset
  transmission itself);
* each player deletes all local copies and reports per-point label
  counts (2·⌈log2 m⌉ bits per point), which the center accumulates into
  the dispute table n₊/n₋;
* f(x) votes with the **full** counts of x in S, so
  E_S(f) = Σ_{x∈D} min(n₊, n₋) ≤ min over ALL classifiers ≤ OPT,
  unconditionally — which is precisely the guarantee Theorem 4.1 states
  ("makes the least number of errors among all possible classifiers").

Guarantees: E_S(f) ≤ OPT always; E_S(f) = 0 when S has no contradicting
examples; communication O(OPT · k·log|S|·(d log n + log|S|)) — the two
new messages add O(OPT·k·(log n + log m)) per disputed point, absorbed
by the same bound.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boost_attempt, ledger as L, weak
from repro.core.types import BoostConfig, ClassifyResult, Ledger
from repro.obs import trace as obs_trace


# ---------------------------------------------------------------------------
# Array-form quarantine primitives (jit-safe; used by core/batched.py).
#
# The host loop below dedupes the stuck coreset with np.unique/np.isin;
# on device the same semantics are masked point-matching: an example
# dies iff its point equals ANY entry of the stuck coreset, and the
# dispute-table size P is the number of distinct coreset values.  Both
# are O(m·K) / O(K²) compares with K = k·coreset_size — small, fixed
# shapes, no data-dependent output size.
# ---------------------------------------------------------------------------

def match_points(x: jax.Array, pts: jax.Array) -> jax.Array:
    """alive-agnostic point match: out[...] = 1[x[...] ∈ set(pts)].

    x: [k, mloc] int points or [k, mloc, F] feature rows;
    pts: [P] or [P, F] (need not be deduplicated).
    """
    if x.ndim == 3:
        flat = x.reshape(-1, x.shape[-1])
        hit = jnp.any(jnp.all(flat[:, None, :] == pts[None], axis=-1),
                      axis=-1)
        return hit.reshape(x.shape[:2])
    # int track: O((m+P)·log P) via sorted membership, not O(m·P)
    ps = jnp.sort(pts)
    xf = x.reshape(-1)
    pos = jnp.clip(jnp.searchsorted(ps, xf), 0, pts.shape[0] - 1)
    return (ps[pos] == xf).reshape(x.shape[:2])


def distinct_count(pts: jax.Array) -> jax.Array:
    """|unique(pts)| as a traced int32 (first-occurrence counting) —
    the all-valid case of :func:`distinct_count_masked`, kept as one
    implementation so the two can never diverge."""
    return distinct_count_masked(pts, jnp.ones((pts.shape[0],), bool))


def _sentinel(dtype) -> jax.Array:
    """A value no real point can equal under sorting: +inf for floats,
    dtype max for ints (outside every [0, n) domain)."""
    return (jnp.asarray(jnp.inf, dtype)
            if jnp.issubdtype(dtype, jnp.floating)
            else jnp.asarray(jnp.iinfo(dtype).max, dtype))


def mask_invalid_points(pts: jax.Array, valid: jax.Array) -> jax.Array:
    """Replace entries where ``valid`` is False so they can never match
    a real point (``match_points``-safe sentinel: scalar points → the
    sorting sentinel; float rows → NaN, never ==)."""
    if pts.ndim == 2:
        if jnp.issubdtype(pts.dtype, jnp.floating):
            return jnp.where(valid[:, None], pts, jnp.nan)
        return jnp.where(valid[:, None], pts, _sentinel(pts.dtype))
    return jnp.where(valid, pts, _sentinel(pts.dtype))


def distinct_count_masked(pts: jax.Array, valid: jax.Array) -> jax.Array:
    """|unique(pts[valid])| as a traced int32.

    The all-valid case is bit-identical to :func:`distinct_count` — the
    fault-tolerant engines call this with the per-round player mask so a
    dropped player's (untransmitted) coreset rows never inflate the
    dispute-table size P.
    """
    if pts.ndim == 2:
        eq = jnp.all(pts[:, None, :] == pts[None], axis=-1)     # [P, P]
        eq = eq & valid[None, :] & valid[:, None]
        earlier = jnp.tril(eq, k=-1)
        first = valid & ~jnp.any(earlier, axis=-1)
        return jnp.sum(first.astype(jnp.int32))
    big = _sentinel(pts.dtype)
    ps = jnp.sort(jnp.where(valid, pts, big))
    bumps = jnp.concatenate(
        [jnp.ones((1,), bool), ps[1:] != ps[:-1]])
    return jnp.sum((bumps & (ps != big)).astype(jnp.int32))


def dispute_table(x: np.ndarray, y: np.ndarray, alive0: np.ndarray,
                  disputed: np.ndarray):
    """Host-side: (unique points, n₊, n₋) from a disputed-example mask.

    Because quarantine always removes *every* copy of a disputed point,
    the copies of a point alive at its quarantine time are exactly its
    initially-alive copies — so the D-table counts are reconstructible
    from the mask alone, independent of attempt order.  Points with zero
    alive copies under ``alive0`` (e.g. every copy lived at a player
    masked out of the table) carry no label evidence and are dropped —
    the ensemble decides there, matching the host loop's zero-support
    filter.
    """
    x, y = np.asarray(x), np.asarray(y)
    alive0, disputed = np.asarray(alive0), np.asarray(disputed)
    sel = disputed.reshape(-1)
    if x.ndim == 3:
        flat = x.reshape(-1, x.shape[-1])
        pts = np.unique(flat[sel], axis=0) if sel.any() else \
            np.zeros((0, x.shape[-1]), x.dtype)
    else:
        flat = x.reshape(-1)
        pts = np.unique(flat[sel])
    pos, neg = _point_counts(x, y, alive0, pts)
    keep = (pos + neg) > 0
    return pts[keep], pos[keep], neg[keep]


def _kill_points(x: np.ndarray, alive: np.ndarray, pts: np.ndarray):
    """Remove every copy of every disputed point, on every player."""
    if x.ndim == 3:                       # feature rows
        flat = x.reshape(-1, x.shape[-1])
        dead = (flat[:, None, :] == pts[None]).all(-1).any(-1)
        dead = dead.reshape(x.shape[:2])
    else:
        dead = np.isin(x, pts)
    return alive & ~dead


def _point_counts(x: np.ndarray, y: np.ndarray, alive: np.ndarray,
                  pts: np.ndarray):
    """Label counts of each disputed point over all (alive) copies in S."""
    if x.ndim == 3:
        flat = x.reshape(-1, x.shape[-1])
        eq = (flat[:, None, :] == pts[None]).all(-1)        # [m, P]
    else:
        eq = x.reshape(-1)[:, None] == pts[None]            # [m, P]
    yf = y.reshape(-1)
    af = alive.reshape(-1)
    pos = ((yf > 0) & af)[:, None] & eq
    neg = ((yf < 0) & af)[:, None] & eq
    return pos.sum(0).astype(np.int64), neg.sum(0).astype(np.int64)


def _emit_attempt(sp, att_led: Ledger, res, q_control: int,
                  q_dispute: int) -> None:
    """Annotate a host attempt span with its per-category wire bits —
    the attempt's Theorem 4.1 ledger delta plus the quarantine charges
    — in the ``task_bits`` format ``repro.obs.roundtrace``'s validator
    sums (the host engine is single-task: everything lands on task 0).
    """
    bits = obs_trace.ledger_bits(att_led)
    bits["control"] += q_control
    bits["quarantine"] += q_dispute
    sp.update(task_bits={"0": bits},
              task_rounds={"0": res.rounds + (1 if res.stuck else 0)},
              task_attempts={"0": 1},
              rounds=res.rounds, stuck=res.stuck)


def run_accurately_classify(x, y, key, cfg: BoostConfig, cls,
                            alive=None) -> ClassifyResult:
    """Host-driven outer loop (≤ opt_budget BoostAttempt calls).

    x, y: [k, m_loc] shards (int-domain track) or [k, m_loc, F] features.
    """
    x_np, y_np = np.asarray(x), np.asarray(y)
    k, mloc = x_np.shape[0], x_np.shape[1]
    if alive is None:
        alive_np = np.ones((k, mloc), bool)
    else:
        alive_np = np.asarray(alive)
    led = Ledger()
    dis_pts: list = []
    dis_pos: list = []
    dis_neg: list = []
    stuck_history = []
    result = None
    m_bits_m = max(int(np.ceil(np.log2(max(k * mloc, 2)))), 1)
    n = L.domain_size(cls)
    for _attempt in range(cfg.opt_budget + 1):
        with obs_trace.span("attempt", "protocol", engine="host",
                            attempt=_attempt) as att_sp:
            key, sub = jax.random.split(key)
            m_alive = int(alive_np.sum())
            res = boost_attempt.run_boost_attempt(
                jnp.asarray(x_np), jnp.asarray(y_np),
                jnp.asarray(alive_np), sub, cfg, cls)
            att_led = L.boost_attempt_ledger(cfg, cls, max(m_alive, 2),
                                             res.rounds, res.stuck)
            led = led + att_led
            stuck_history.append(res.stuck)
            if not res.stuck:
                result = res
                if obs_trace.enabled():
                    _emit_attempt(att_sp, att_led, res, 0, 0)
                break
            # ---- full-point quarantine of the non-realizable coreset
            with obs_trace.span("quarantine", "protocol",
                                attempt=_attempt):
                cx = np.asarray(res.coreset_x).reshape(
                    (-1,) + tuple(np.asarray(res.coreset_x).shape[2:]))
                pts = (np.unique(cx, axis=0) if cx.ndim == 2
                       else np.unique(cx))
                pos, neg = _point_counts(x_np, y_np, alive_np, pts)
                # A coreset from a fully-dead shard can name points
                # with zero alive copies (repeat-disputed or
                # initially-padded).  They carry no label evidence, so
                # they don't enter the D-table / classifier vote (the
                # ensemble decides there) — this keeps f identical to
                # the mask-based batched engine.  The broadcast still
                # happened, so the ledger below charges the full |pts|.
                keep = (pos + neg) > 0
                dis_pts.append(pts[keep])
                dis_pos.append(pos[keep])
                dis_neg.append(neg[keep])
                alive_np = _kill_points(x_np, alive_np, pts)
                # ledger: point-set broadcast + per-player count reports
                P = int(pts.shape[0])
                q_control = cfg.k * P * L.point_bits(n)       # broadcast
                q_dispute = cfg.k * P * 2 * m_bits_m          # counts up
                led.bits_control += q_control
                led.bits_dispute += q_dispute
            if obs_trace.enabled():
                _emit_attempt(att_sp, att_led, res, q_control, q_dispute)
    if result is None:
        raise RuntimeError(
            f"AccuratelyClassify exceeded opt_budget={cfg.opt_budget}; "
            "OPT is larger than the promise this run was configured for.")
    if dis_pts:
        dpts = np.concatenate(dis_pts)
        dpos = np.concatenate(dis_pos)
        dneg = np.concatenate(dis_neg)
    else:
        dpts = np.zeros((0,) + tuple(x_np.shape[2:]), x_np.dtype)
        dpos = np.zeros((0,), np.int64)
        dneg = np.zeros((0,), np.int64)
    return ClassifyResult(
        hypotheses=result.hypotheses, rounds=result.rounds,
        dispute_x=jnp.asarray(dpts),
        dispute_y=(jnp.asarray(dpos), jnp.asarray(dneg)),
        dispute_count=int(dpts.shape[0]),
        attempts=len(stuck_history), stuck_history=stuck_history,
        ledger=led)


@dataclasses.dataclass(frozen=True)
class ResilientClassifier:
    """The final classifier f — dispute-vote patched over the ensemble.

    ``dispute_pos/neg`` are full label counts of each disputed point in
    S, so the vote is the pointwise-optimal labelling.
    """

    cls: object
    hypotheses: jax.Array        # [T, 4]
    rounds: int
    dispute_x: jax.Array         # [P] or [P, F]
    dispute_pos: jax.Array       # [P]
    dispute_neg: jax.Array       # [P]

    def g(self, x: jax.Array) -> jax.Array:
        return weak.ensemble_predict(self.cls, self.hypotheses,
                                     self.rounds, x)

    def __call__(self, x: jax.Array) -> jax.Array:
        gx = self.g(x).astype(jnp.int32)
        if self.dispute_x.shape[0] == 0:
            return gx.astype(jnp.int8)
        if self.dispute_x.ndim == 2:                  # feature rows
            eq = jnp.all(x[..., None, :] == self.dispute_x, axis=-1)
        else:
            eq = (x[..., None] == self.dispute_x)     # [..., P]
        pos = jnp.sum(jnp.where(eq, self.dispute_pos, 0), axis=-1)
        neg = jnp.sum(jnp.where(eq, self.dispute_neg, 0), axis=-1)
        in_d = jnp.any(eq, axis=-1)
        vote = jnp.where(pos >= neg, 1, -1)
        out = jnp.where(in_d, vote, gx)
        return out.astype(jnp.int8)


def make_classifier(cls, result: ClassifyResult) -> ResilientClassifier:
    pos, neg = result.dispute_y
    return ResilientClassifier(
        cls=cls, hypotheses=result.hypotheses, rounds=result.rounds,
        dispute_x=result.dispute_x, dispute_pos=pos, dispute_neg=neg)


def learn(x, y, key, cfg: BoostConfig, cls):
    """One-call API: returns (classifier, ClassifyResult)."""
    result = run_accurately_classify(x, y, key, cfg, cls)
    return make_classifier(cls, result), result
