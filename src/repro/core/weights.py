"""Multiplicative-weights state in log2 space.

The paper's update is ``W_{t+1}(z) = W_t(z) · 2^{-1[h_t(x)=y]}`` with
``W_1 ≡ 1``.  After ``T = ⌈6·log2 m⌉`` rounds a weight can be as small as
``2^{-T}``; storing the *hit count* ``H_t(z) = -log2 W_t(z)`` as an int32
is exact, overflow-free, and makes the paper's claim that the weight sums
``W_t^{(i)}`` need only ``O(log |S|)`` bits literal.

Dead (quarantined) examples are handled with an ``alive`` mask: they
contribute 0 to every distribution and are never sampled.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LN2 = 0.6931471805599453


def init_hits(shape) -> jax.Array:
    """H_1 ≡ 0  ⇔  W_1 ≡ 1."""
    return jnp.zeros(shape, dtype=jnp.int32)


def update_hits(hits: jax.Array, correct: jax.Array,
                alive: jax.Array) -> jax.Array:
    """W·2^{-1[h(x)=y]}  ⇔  H += 1[h(x)=y]; only alive examples move.
    Preserves the hits dtype (int16 suffices for T ≤ 32767 rounds and
    halves the protocol's dominant HBM term — §Perf P2)."""
    return hits + (correct & alive).astype(hits.dtype)


def log_weight_sum(hits: jax.Array, alive: jax.Array,
                   axis=None) -> jax.Array:
    """log2 of  Σ_{alive} 2^{-hits}, computed stably.

    This is the per-player ``W_t^{(i)}`` of step 2(b), in log2 space.
    Dead entries contribute -inf.
    """
    logw = jnp.where(alive, -hits.astype(jnp.float32), -jnp.inf)
    # log2-sum-exp2, stable under a per-axis max shift.
    mx = jnp.max(logw, axis=axis, keepdims=True)
    mx_safe = jnp.where(jnp.isfinite(mx), mx, 0.0)
    s = jnp.sum(jnp.exp2(logw - mx_safe), axis=axis, keepdims=True)
    out = mx_safe + jnp.log2(jnp.maximum(s, 1e-30))
    out = jnp.where(jnp.isfinite(mx), out, -jnp.inf)
    if axis is not None:
        out = jnp.squeeze(out, axis=axis)
    else:
        out = jnp.reshape(out, ())
    return out


def normalized_log_probs(hits: jax.Array, alive: jax.Array,
                         axis: int = -1) -> jax.Array:
    """log2 p_t(z) = -hits - log2 W  (−inf on dead entries)."""
    logw = jnp.where(alive, -hits.astype(jnp.float32), -jnp.inf)
    return logw - jnp.expand_dims(
        log_weight_sum(hits, alive, axis=axis), axis)


def probs(hits: jax.Array, alive: jax.Array, axis: int = -1) -> jax.Array:
    """The paper's p_t distribution (probability per example)."""
    return jnp.exp2(normalized_log_probs(hits, alive, axis=axis))


def mixture_weights(log_wsums: jax.Array) -> jax.Array:
    """W_t^{(i)} / W_t  from per-player log2 sums (step 2(c)).

    Players whose entire shard is dead get weight 0.
    """
    finite = jnp.isfinite(log_wsums)
    shifted = jnp.where(finite, log_wsums, -jnp.inf)
    mx = jnp.max(shifted)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    w = jnp.exp2(shifted - mx)
    return w / jnp.maximum(jnp.sum(w), 1e-30)
