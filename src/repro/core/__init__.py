"""Core — the paper's contribution: resilient distributed boosting."""

from repro.core.types import (BoostConfig, BoostAttemptResult,
                              ClassifyResult, Ledger)
from repro.core.boost_attempt import run_boost_attempt, boost_attempt_sharded
from repro.core.classify import (learn, run_accurately_classify,
                                 make_classifier, ResilientClassifier)
from repro.core import weak, weights, approximation, ledger, tasks

__all__ = [
    "BoostConfig", "BoostAttemptResult", "ClassifyResult", "Ledger",
    "run_boost_attempt", "boost_attempt_sharded", "learn",
    "run_accurately_classify", "make_classifier", "ResilientClassifier",
    "weak", "weights", "approximation", "ledger", "tasks",
]
