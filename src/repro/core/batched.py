"""Device-resident batched AccuratelyClassify engine, round-steppable.

The host-driven loop in :mod:`repro.core.classify` dispatches one
BoostAttempt at a time and round-trips to numpy for every quarantine —
``O(B · attempts)`` dispatches for B independent tasks.  This module
runs B tasks in ONE jitted program, and (since the fault-tolerance PR)
exposes the protocol as a **round-granular stepping API**:

* :func:`init_state`   — build the full protocol state (a pytree of
  arrays, msgpack-serializable for checkpoint/resume);
* :func:`run_rounds`   — advance every unfinished task by up to ``n``
  wire rounds (one step = one BoostAttempt round; attempt transitions —
  stuck→quarantine→retry, success, budget exhaustion — happen *inside*
  the step body, so a task crosses attempt boundaries mid-slice);
* :func:`finalize`     — materialise a :class:`BatchedClassifyResult`.

``run_rounds(state, ..., n=∞)`` is the whole protocol; running it in
slices (a preemptible scheduler, a checkpoint every N rounds) produces
bit-identical output to the uninterrupted run — the step body is the
same program either way, and the state round-trips exactly
(tests/test_fault_tolerance.py pins both).

**Fault tolerance.**  Every round consults a dynamic ``player_alive
[k]`` mask (row ``min(step, R−1)`` of a ``[R, k]`` schedule): an absent
player sends no coreset and no weight sum (its mixture weight is 0 and
its coreset rows are excluded from quarantine matching), receives no
hypothesis (its MW state freezes), and the ledger charges only bits
alive players actually moved (`ledger.boost_attempt_ledger_masked`).
With the default all-alive schedule every value — floats included — is
bit-identical to the pre-fault-tolerance engine; the host-reference
parity suite (tests/test_batched.py) keeps that honest.

Semantics are the reference loop's, bit for bit:

* the per-attempt PRNG stream is the same ``key, sub = split(key)``
  sequence ``run_accurately_classify`` performs on the host (keys are
  carried as raw ``key_data`` words so the state is pure numerics);
* the round bound is the paper's dynamic T = ⌈6·log2 m_alive⌉ per task
  per attempt, with m_alive counting examples of players alive at the
  attempt's first round;
* quarantine is the array form of np.unique/np.isin — masked
  point-matching against the stuck coreset (classify.match_points),
  with the dispute-table size from classify.distinct_count_masked so
  the communication ledger charges the identical bit counts.

Tasks finish at different attempt counts; finished lanes freeze (the
standard vmap-of-while masking) while stragglers continue.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import msgpack_ckpt
from repro.core import boost_attempt, classify, ledger as L, streaming, weak
from repro.core import weights as W
from repro.core.types import BoostConfig, ClassifyResult, Ledger
from repro.obs import trace as obs_trace


class StepState(NamedTuple):
    """Whole-protocol state of B tasks, one wire round at a time.

    Every field carries a leading ``[B]`` task axis; PRNG keys are raw
    ``key_data`` words (uint32) so the tuple is a plain-array pytree —
    msgpack-serializable via ckpt/msgpack_ckpt with no special cases.
    """

    # -- protocol-level ---------------------------------------------------
    attempt: jax.Array        # int32 — attempts executed so far
    done: jax.Array           # bool  — some attempt succeeded
    alive: jax.Array          # [k, mloc] current alive-example mask
    disputed: jax.Array       # [k, mloc] quarantined-example mask
    key_data: jax.Array       # task key (raw words)
    h_params: jax.Array       # [t_buf, P] winning ensemble, P=param_dim(cls)
    rounds: jax.Array         # int32 rounds of the winning attempt
    min_loss: jax.Array       # last center ERM loss (diagnostic)
    hist_stuck: jax.Array     # [A] bool   per-attempt stuck flag
    hist_rounds: jax.Array    # [A] int32  per-attempt rounds
    hist_alive: jax.Array     # [A] int32  alive examples entering attempt
    hist_p: jax.Array         # [A] int32  distinct disputed points
    hist_players: jax.Array       # [A] Σ_wire-rounds alive players
    hist_players_h: jax.Array     # [A] same over successful rounds only
    hist_players_last: jax.Array  # [A] alive players at the last round
    # -- in-attempt -------------------------------------------------------
    in_attempt: jax.Array     # bool — an attempt is in flight
    akey_data: jax.Array      # current attempt's round key (raw words)
    t: jax.Array              # int32 hypotheses produced this attempt
    bound: jax.Array          # int32 this attempt's round bound
    hits: jax.Array           # [k, mloc] MW state
    cur_h: jax.Array          # [t_buf, P] growing ensemble
    core_x: jax.Array         # [k, c(, F)] last round's pooled coreset
    core_y: jax.Array         # [k, c]
    step: jax.Array           # int32 global wire-round counter


# -- checkpoint identity of the stepping state ------------------------------
# Leaf names in a checkpoint are the StepState field names (stable
# across releases — renames are format breaks); fixed dtypes are
# validated on template-free restore.  core_x/core_y follow the task
# data's dtype (int32 shards or float32 feature rows) and restore at
# whatever dtype they were saved with.

STATE_TREEDEF = "repro.core.batched.StepState"

STATE_DTYPES = {
    "attempt": "int32", "done": "bool", "alive": "bool",
    "disputed": "bool", "key_data": "uint32", "h_params": "float32",
    "rounds": "int32", "min_loss": "float32", "hist_stuck": "bool",
    "hist_rounds": "int32", "hist_alive": "int32", "hist_p": "int32",
    "hist_players": "int32", "hist_players_h": "int32",
    "hist_players_last": "int32", "in_attempt": "bool",
    "akey_data": "uint32", "t": "int32", "bound": "int32",
    "hits": "int32", "cur_h": "float32", "step": "int32",
}


def check_state_dtypes(leaves: dict, dtypes: dict, what: str) -> None:
    """Fail loudly when a restored leaf's dtype drifted from the
    engine's declared layout (shared by both engines' reconstructors)."""
    for name, want in dtypes.items():
        got = np.dtype(np.asarray(leaves[name]).dtype)
        if got != np.dtype(want):
            raise ValueError(
                f"checkpoint leaf {name!r} of {what} has dtype {got} "
                f"but the engine expects {want} — refusing a silent "
                f"cast (bit-parity would break invisibly)")


def _unflatten_state(leaves: dict) -> StepState:
    missing = set(StepState._fields) - set(leaves)
    if missing:
        raise KeyError(f"checkpoint missing StepState leaves: "
                       f"{sorted(missing)}")
    check_state_dtypes(leaves, STATE_DTYPES, "batched.StepState")
    return StepState(**{f: leaves[f] for f in StepState._fields})


msgpack_ckpt.register_treedef(STATE_TREEDEF, _unflatten_state)


def num_rounds_dynamic(cfg: BoostConfig, m_alive: jax.Array) -> jax.Array:
    """Traced twin of ``BoostConfig.num_rounds`` (same f32 ops ⇒ same
    integer for every m, so the batched loop bound matches the host's)."""
    m = jnp.maximum(m_alive, 2).astype(jnp.float32)
    return jnp.ceil(cfg.rounds_factor * jnp.log2(m)).astype(jnp.int32)


def canon_player_sched(player_sched, B: int, k: int) -> jax.Array:
    """Normalise a player schedule to ``[B, R, k]`` bool.

    Accepts None (all alive, R = 1), ``[R, k]`` (shared by every task)
    or ``[B, R, k]``.  Row ``min(step, R−1)`` is the round's mask, so
    the final row extends forever.  Every round must keep ≥ 1 player
    alive (the mixture is undefined over zero senders).
    """
    if player_sched is None:
        return jnp.ones((B, 1, k), bool)
    sched = jnp.asarray(player_sched, bool)
    if sched.ndim == 2:
        sched = jnp.broadcast_to(sched[None], (B,) + sched.shape)
    if sched.shape[0] != B or sched.shape[2] != k:
        raise ValueError(
            f"player_sched {sched.shape} incompatible with B={B}, k={k}")
    if not bool(jnp.all(jnp.any(sched, axis=-1))):
        raise ValueError("player_sched has a round with zero alive "
                         "players — the protocol cannot proceed")
    return sched


def init_state(x, y, keys, cfg: BoostConfig, alive=None,
               t_buf: int | None = None, cls=None) -> StepState:
    """Fresh protocol state for a [B, k, mloc(, F)] batch.

    Inputs: ``x`` [B, k, mloc] int32 domain points (integer track) or
    [B, k, mloc, F] float32 feature rows; ``y`` [B, k, mloc] int8 ±1
    labels; ``keys`` [B] PRNG keys (one per task); ``alive`` optional
    [B, k, mloc] bool (False = padding rows, masked out of every
    coreset, weight sum and ledger charge); ``t_buf`` ensemble-buffer
    rounds (defaults to ``cfg.num_rounds(k·mloc)``).  ``cls`` sizes
    the ensemble buffers (``weak.param_dim`` — classes with wider
    hypothesis vectors than the 4-wide default, e.g. the histogram
    trees, need it); None keeps the legacy 4-wide layout.

    Returns a ``StepState`` — a plain pytree of device arrays (int32
    counters, bool masks, float32 ensemble/coreset buffers, uint32
    PRNG key data; no Python objects), so it round-trips through
    ``ckpt.msgpack_ckpt`` template-free.  Contract: ``init_state`` →
    ``run_rounds``* → ``finalize`` in ANY slicing is bit-identical to
    the single-dispatch engine run, which is itself bit-identical to
    the host reference loop given the same keys (docs/architecture.md;
    pinned in tests/test_batched.py, tests/test_fault_tolerance.py).
    """
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    B, k, mloc = x.shape[0], x.shape[1], x.shape[2]
    p_dim = weak.param_dim(cls)
    if alive is None:
        alive = jnp.ones((B, k, mloc), bool)
    else:
        alive = jnp.asarray(alive)
    if t_buf is None:
        t_buf = cfg.num_rounds(k * mloc)
    a_max = cfg.opt_budget + 1
    c = cfg.coreset_size
    kd = jax.random.key_data(jnp.asarray(keys))
    i32 = functools.partial(jnp.zeros, dtype=jnp.int32)
    return StepState(
        attempt=i32((B,)), done=jnp.zeros((B,), bool),
        alive=alive, disputed=jnp.zeros_like(alive),
        key_data=kd,
        h_params=jnp.zeros((B, t_buf, p_dim), jnp.float32),
        rounds=i32((B,)), min_loss=jnp.zeros((B,), jnp.float32),
        hist_stuck=jnp.zeros((B, a_max), bool),
        hist_rounds=i32((B, a_max)), hist_alive=i32((B, a_max)),
        hist_p=i32((B, a_max)), hist_players=i32((B, a_max)),
        hist_players_h=i32((B, a_max)),
        hist_players_last=i32((B, a_max)),
        in_attempt=jnp.zeros((B,), bool),
        akey_data=jnp.zeros_like(kd),
        t=i32((B,)), bound=i32((B,)),
        hits=W.init_hits((B, k, mloc)),
        cur_h=jnp.zeros((B, t_buf, p_dim), jnp.float32),
        core_x=jnp.zeros((B, k, c) + x.shape[3:], x.dtype),
        core_y=jnp.zeros((B, k, c), y.dtype),
        step=i32((B,)))


def _one_step(cfg: BoostConfig, cls, x, y, x_orders, sched,
              s: StepState) -> StepState:
    """ONE wire round of ONE task (vmap-ed over the batch axis).

    LOCKSTEP: core/sharded_batched.py mirrors this body with
    device-shard state + collectives; keep them in sync — the exact
    parity tests (tests/test_sharded_batched.py) fail on divergence.
    """
    a_max = cfg.opt_budget + 1
    active = (~s.done) & (s.attempt < a_max)
    k = x.shape[0]
    pa = sched[jnp.minimum(s.step, sched.shape[0] - 1)]          # [k]
    # ---- attempt start (no-op when one is already in flight) ----------
    start = ~s.in_attempt
    tkey = jax.random.wrap_key_data(s.key_data)
    nk, sub = jax.random.split(tkey)
    key_data = jnp.where(start, jax.random.key_data(nk), s.key_data)
    akey_data = jnp.where(start, jax.random.key_data(sub), s.akey_data)
    m_alive = jnp.sum((s.alive & pa[:, None]).astype(jnp.int32))
    a = s.attempt
    bound = jnp.where(start, num_rounds_dynamic(cfg, m_alive), s.bound)
    hits = jnp.where(start, W.init_hits(x.shape[:2]), s.hits)
    cur_h = jnp.where(start, jnp.zeros_like(s.cur_h), s.cur_h)
    t = jnp.where(start, 0, s.t)
    hist_alive = jnp.where(start, s.hist_alive.at[a].set(m_alive),
                           s.hist_alive)
    # ---- one BoostAttempt round (the reference round body) ------------
    y_sorted = jnp.take_along_axis(y, x_orders, axis=1)
    alive_sorted = jnp.take_along_axis(s.alive, x_orders, axis=1)
    carry = boost_attempt._Carry(
        t=t, it=jnp.int32(0), stuck=jnp.asarray(False),
        hits=hits, key=jax.random.wrap_key_data(akey_data),
        h_params=cur_h,
        core_idx=jnp.zeros((k, cfg.coreset_size), jnp.int32),
        core_x=s.core_x, core_y=s.core_y, min_loss=s.min_loss)
    out = boost_attempt._round_body(
        cfg, cls, x, y, s.alive, x_orders, y_sorted, alive_sorted,
        carry, player_alive=pa)
    stuck = out.stuck
    success = (~stuck) & (out.t >= bound)
    ended = stuck | success
    k_alive = jnp.sum(pa.astype(jnp.int32))
    # ---- full-point quarantine, masked to the round's senders ---------
    core_flat = out.core_x.reshape((-1,) + out.core_x.shape[2:])
    valid_flat = jnp.repeat(pa, cfg.coreset_size)
    masked_flat = classify.mask_invalid_points(core_flat, valid_flat)
    dead_new = s.alive & classify.match_points(x, masked_flat) & stuck
    p_count = jnp.where(
        stuck, classify.distinct_count_masked(core_flat, valid_flat), 0)
    nxt = StepState(
        attempt=jnp.where(ended, a + 1, a),
        done=s.done | success,
        alive=s.alive & ~dead_new,
        disputed=s.disputed | dead_new,
        key_data=key_data,
        h_params=jnp.where(success, out.h_params, s.h_params),
        rounds=jnp.where(success, out.t, s.rounds),
        min_loss=out.min_loss,
        hist_stuck=jnp.where(ended, s.hist_stuck.at[a].set(stuck),
                             s.hist_stuck),
        hist_rounds=jnp.where(ended, s.hist_rounds.at[a].set(out.t),
                              s.hist_rounds),
        hist_alive=hist_alive,
        hist_p=jnp.where(ended, s.hist_p.at[a].set(p_count), s.hist_p),
        hist_players=s.hist_players.at[a].add(k_alive),
        hist_players_h=s.hist_players_h.at[a].add(
            jnp.where(stuck, 0, k_alive)),
        hist_players_last=s.hist_players_last.at[a].set(k_alive),
        in_attempt=~ended,
        akey_data=jax.random.key_data(out.key),
        t=out.t,
        bound=bound,
        hits=out.hits,
        cur_h=out.h_params,
        core_x=out.core_x, core_y=out.core_y,
        step=s.step + 1)
    # finished lanes freeze (vmap-of-while masking)
    return jax.tree_util.tree_map(
        lambda new, old: jnp.where(active, new, old), nxt, s)


def _run_steps(x, y, sched, state: StepState, n, cfg: BoostConfig,
               cls) -> StepState:
    """Advance every active task by up to ``n`` wire rounds (traced)."""
    a_max = cfg.opt_budget + 1
    x1d = x if x.ndim == 3 else x[..., 0]
    # hoisted per slice; chunk-local runs under cfg.chunk_size (bitwise
    # identical to the monolithic argsort — streaming tier)
    x_orders = jax.vmap(jax.vmap(lambda v: streaming.sort_order(
        v, cfg.chunk_size, cfg.domain_size)))(x1d)

    def active(s: StepState):
        return (~s.done) & (s.attempt < a_max)

    def cond(carry):
        s, i = carry
        return jnp.any(active(s)) & (i < n)

    def body(carry):
        s, i = carry
        s2 = jax.vmap(functools.partial(_one_step, cfg, cls))(
            x, y, x_orders, sched, s)
        return s2, i + 1

    out, _ = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    return out


_RUN_FOREVER = jnp.int32(2 ** 30)


@functools.partial(jax.jit, static_argnames=("cfg", "cls"))
def _run_rounds_jit(x, y, sched, state, n, cfg, cls):
    return _run_steps(x, y, sched, state, n, cfg, cls)


def run_rounds(state: StepState, x, y, cfg: BoostConfig, cls,
               n: int | None = None, player_sched=None) -> StepState:
    """Advance the protocol by up to ``n`` wire rounds (None = to
    completion).

    ``state``: a ``StepState`` from :func:`init_state` (or a restored
    checkpoint of one); ``x``/``y``: the SAME [B, k, mloc(, F)] /
    [B, k, mloc] arrays the state was initialised with (data stays
    outside the state so checkpoints hold O(state), not O(m));
    ``player_sched``: optional [R, k] or [B, R, k] bool per-wire-round
    player-alive schedule (see :func:`canon_player_sched`).  Returns
    the advanced ``StepState``; tasks already done pass through
    unchanged.

    ``n`` is traced — every slice size shares one compiled program per
    input signature, so preempting at an arbitrary round never
    recompiles.  Bitwise contract: any slicing (1/3/7/… rounds per
    call) produces the same final state, bit for bit, as one
    ``n=None`` call (tests/test_fault_tolerance.py); with
    ``cfg.chunk_size`` set, the chunked sort path is bitwise identical
    to the monolithic argsort, so slicing AND chunking are both
    invisible in every output (docs/streaming.md,
    tests/test_streaming.py)."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    B, k = x.shape[0], x.shape[1]
    sched = canon_player_sched(player_sched, B, k)
    n_arr = _RUN_FOREVER if n is None else jnp.int32(n)
    with obs_trace.span("run_rounds", "engine", engine="batched", B=B,
                        n=(-1 if n is None else int(n))), \
            obs_trace.annotate("run_rounds"):
        return _run_rounds_jit(x, y, sched, state, n_arr, cfg, cls)


@functools.partial(jax.jit, static_argnames=("cfg", "cls", "t_buf"))
def _classify_batched_jit(x, y, alive0, keys, sched, cfg, cls, t_buf):
    state = init_state(x, y, keys, cfg, alive=alive0, t_buf=t_buf,
                       cls=cls)
    return _run_steps(x, y, sched, state, _RUN_FOREVER, cfg, cls)


def stack_for_dispatch(items, B: int):
    """Stack admitted (x, y, alive, key) tuples into bucket arrays.

    ``items`` holds up to B tasks already padded to a common [k, mloc];
    short batches are filled by duplicating lane 0 (a live lane — dead
    filler would spin through the whole opt_budget and a batch is as
    slow as its slowest lane).  Returns (x, y, alive, keys, n_real);
    lanes ≥ n_real are filler and their results must be discarded.
    """
    n_real = len(items)
    if not 0 < n_real <= B:
        raise ValueError(f"need 1..{B} items, got {n_real}")
    items = list(items) + [items[0]] * (B - n_real)
    x = np.stack([it[0] for it in items])
    y = np.stack([it[1] for it in items])
    alive = np.stack([it[2] for it in items])
    key_data = np.stack([np.asarray(jax.random.key_data(it[3]))
                         for it in items])
    keys = jax.random.wrap_key_data(jnp.asarray(key_data))
    return x, y, alive, keys, n_real


def lower_classify(x, y, alive, keys, cfg: BoostConfig, cls,
                   player_sched=None):
    """AOT-compile the batched engine for one input signature.

    Returns a ``jax.stages.Compiled`` executable with the statics
    (cfg, cls, t_buf) baked in — call it as ``compiled(x, y, alive,
    keys, player_sched)`` on arrays of exactly this shape/dtype.
    Unlike the implicit jit cache, the caller owns the executable's
    lifetime: dropping it (e.g. a serving compile-cache eviction) really
    frees the program, and re-lowering really recompiles.  Output is
    bit-identical to the jit path (same trace, same compiler).
    """
    t_buf = cfg.num_rounds(x.shape[1] * x.shape[2])
    sched = canon_player_sched(player_sched, x.shape[0], x.shape[1])
    with obs_trace.span("compile", "compile", engine="batched",
                        B=int(x.shape[0]), mloc=int(x.shape[2])):
        return _classify_batched_jit.lower(
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(alive), keys,
            sched, cfg, cls, t_buf).compile()


@dataclasses.dataclass
class BatchedClassifyResult:
    """Host view of one batched dispatch (B tasks).

    ``ok[b]`` is False iff task b exhausted ``opt_budget`` attempts —
    the batched analogue of the reference loop's RuntimeError.  The
    dispute table of task b is reconstructible from ``disputed[b]``
    alone (full-point quarantine ⇒ counts are the initially-alive
    counts; see classify.dispute_table).
    """

    hypotheses: np.ndarray   # [B, T_buf, P], P = weak.param_dim(cls)
    rounds: np.ndarray       # [B]
    ok: np.ndarray           # [B] bool
    attempts: np.ndarray     # [B]
    alive: np.ndarray        # [B, k, mloc] final alive mask
    disputed: np.ndarray     # [B, k, mloc]
    min_loss: np.ndarray     # [B]
    hist_stuck: np.ndarray   # [B, A]
    hist_rounds: np.ndarray  # [B, A]
    hist_alive: np.ndarray   # [B, A]
    hist_p: np.ndarray       # [B, A]
    # inputs, kept for per-task reconstruction
    x: np.ndarray
    y: np.ndarray
    alive0: np.ndarray
    cfg: BoostConfig
    cls: object
    # optional [B] true sample sizes — when the serving layer pads a
    # request's shards up to a bucket mloc, the protocol's |S| is still
    # the request's own m, and the dispute-report bit width ⌈log2 m⌉
    # must charge that, not the padded capacity
    m_true: np.ndarray | None = None
    # per-attempt alive-player sums under the dropout mask ([B, A]); an
    # all-alive run carries wire_rounds·k / rounds·k / k and the ledger
    # reduces bit-for-bit to the unmasked accounting
    hist_players: np.ndarray | None = None
    hist_players_h: np.ndarray | None = None
    hist_players_last: np.ndarray | None = None

    @property
    def batch(self) -> int:
        return int(self.rounds.shape[0])

    def _attempt_players(self, b: int, a: int):
        """(player_rounds, player_h_rounds, players_last) of attempt a,
        falling back to the all-alive counts for legacy results."""
        if self.hist_players is None:
            wire = int(self.hist_rounds[b, a]) \
                + (1 if self.hist_stuck[b, a] else 0)
            return (wire * self.cfg.k,
                    int(self.hist_rounds[b, a]) * self.cfg.k, self.cfg.k)
        return (int(self.hist_players[b, a]),
                int(self.hist_players_h[b, a]),
                int(self.hist_players_last[b, a]))

    def ledger(self, b: int) -> Ledger:
        """Bit-identical to the Ledger the reference loop accumulates
        (all players alive); under a dropout mask, charges only bits
        alive players actually sent.  docs/ledger.md walks every
        charge; the sharded twin's ``validate_ledger`` cross-checks
        the same numbers against measured collective payloads."""
        cfg, cls = self.cfg, self.cls
        k, mloc = self.x.shape[1], self.x.shape[2]
        n = L.domain_size(cls)
        m_eff = (k * mloc if self.m_true is None
                 else int(self.m_true[b]))
        m_bits_m = max(int(np.ceil(np.log2(max(m_eff, 2)))), 1)
        led = Ledger()
        for a in range(int(self.attempts[b])):
            stuck = bool(self.hist_stuck[b, a])
            pl_rounds, pl_h, pl_last = self._attempt_players(b, a)
            led = led + L.boost_attempt_ledger_masked(
                cfg, cls, max(int(self.hist_alive[b, a]), 2),
                int(self.hist_rounds[b, a]), stuck,
                pl_rounds, pl_h, pl_last)
            if stuck:
                p = int(self.hist_p[b, a])
                led.bits_control += pl_last * p * L.point_bits(n)
                led.bits_dispute += pl_last * p * 2 * m_bits_m
        return led

    def per_task(self, b: int, player_mask=None) -> ClassifyResult:
        """Materialise task b as a reference-shaped ClassifyResult.

        ``player_mask`` ([k] bool) restricts the dispute-table label
        counts to the given players' copies — pass the surviving-player
        set of a fault scenario so the D-vote is pointwise-optimal over
        the shards that are still there.
        """
        if not self.ok[b]:
            raise RuntimeError(
                f"task {b} exceeded opt_budget={self.cfg.opt_budget}")
        alive0 = self.alive0[b]
        if player_mask is not None:
            alive0 = alive0 & np.asarray(player_mask, bool)[:, None]
        pts, pos, neg = classify.dispute_table(
            self.x[b], self.y[b], alive0, self.disputed[b])
        n_att = int(self.attempts[b])
        return ClassifyResult(
            hypotheses=jnp.asarray(self.hypotheses[b]),
            rounds=int(self.rounds[b]),
            dispute_x=jnp.asarray(pts),
            dispute_y=(jnp.asarray(pos), jnp.asarray(neg)),
            dispute_count=int(pts.shape[0]),
            attempts=n_att,
            stuck_history=[bool(s) for s in self.hist_stuck[b, :n_att]],
            ledger=self.ledger(b))

    def classifier(self, b: int,
                   player_mask=None) -> classify.ResilientClassifier:
        return classify.make_classifier(
            self.cls, self.per_task(b, player_mask=player_mask))


def finalize(state: StepState, x, y, alive0, cfg: BoostConfig, cls,
             m_true=None) -> BatchedClassifyResult:
    """Materialise a (host) result from stepped protocol state.

    ``state``: a completed (or mid-protocol) ``StepState``;
    ``x``/``y``/``alive0``: the dispatch inputs, kept on the result
    for per-task reconstruction (``per_task``/``classifier``);
    ``m_true``: optional [B] int true sample sizes — when the serving
    layer padded shards up to a bucket mloc, the ledger's dispute-bit
    width must charge the request's own ⌈log2 m⌉, not the padded
    capacity.  Returns a ``BatchedClassifyResult`` of host numpy
    arrays: ``hypotheses`` [B, t_buf, P] float32, ``rounds``/
    ``attempts`` [B] int32, ``ok`` [B] bool, ``alive``/``disputed``
    [B, k, mloc] bool, plus per-attempt histories [B, A].  Pure
    materialisation — no protocol math happens here, so finalizing a
    restored checkpoint equals finalizing the original state bit for
    bit (tests/test_preemption.py)."""
    with obs_trace.span("finalize", "engine", engine="batched"):
        out = jax.device_get(state)
    return BatchedClassifyResult(
        hypotheses=out.h_params, rounds=out.rounds,
        ok=np.asarray(out.done), attempts=out.attempt,
        alive=out.alive, disputed=out.disputed, min_loss=out.min_loss,
        hist_stuck=out.hist_stuck, hist_rounds=out.hist_rounds,
        hist_alive=out.hist_alive, hist_p=out.hist_p,
        x=np.asarray(x), y=np.asarray(y), alive0=np.asarray(alive0),
        cfg=cfg, cls=cls,
        m_true=None if m_true is None else np.asarray(m_true),
        hist_players=out.hist_players,
        hist_players_h=out.hist_players_h,
        hist_players_last=out.hist_players_last)


def run_accurately_classify_batched(x, y, keys, cfg: BoostConfig, cls,
                                    alive=None, compiled=None,
                                    m_true=None, player_sched=None,
                                    ) -> BatchedClassifyResult:
    """B-task AccuratelyClassify in one device dispatch.

    x, y: [B, k, mloc] int shards or [B, k, mloc, F] feature rows;
    keys: [B] PRNG keys (one per task — the same key given to the
    reference loop reproduces it exactly) or a single key to split.
    ``alive``: optional [B, k, mloc] initial mask (False = padding, so
    ragged batches are padded to a common mloc and masked out).
    ``compiled``: optional executable from :func:`lower_classify` for
    this signature — the serving layer's compile cache passes it so a
    dispatch can never trigger an implicit recompile.
    ``m_true``: optional [B] true per-task sample sizes (see
    ``BatchedClassifyResult.m_true``).
    ``player_sched``: optional [R, k] or [B, R, k] per-round
    player-alive schedule (see :func:`canon_player_sched`) — the
    infrastructure-adversary hook (dropout/flaky/rejoin).
    """
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    B, k, mloc = x.shape[0], x.shape[1], x.shape[2]
    keys = jnp.asarray(keys)
    if keys.ndim == 0:                       # one typed key → B streams
        keys = jax.random.split(keys, B)
    if keys.shape[0] != B:
        raise ValueError(f"need {B} task keys, got shape {keys.shape}")
    if alive is None:
        alive = jnp.ones((B, k, mloc), bool)
    else:
        alive = jnp.asarray(alive)
    sched = canon_player_sched(player_sched, B, k)
    if compiled is not None:
        out = compiled(x, y, alive, keys, sched)
    else:
        t_buf = cfg.num_rounds(k * mloc)
        out = _classify_batched_jit(x, y, alive, keys, sched, cfg, cls,
                                    t_buf)
    return finalize(out, x, y, alive, cfg, cls, m_true=m_true)
