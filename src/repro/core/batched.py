"""Device-resident batched AccuratelyClassify engine.

The host-driven loop in :mod:`repro.core.classify` dispatches one
BoostAttempt at a time and round-trips to numpy for every quarantine —
``O(B · attempts)`` dispatches for B independent tasks.  This module
runs B tasks in ONE jitted program: the outer attempt loop, the inner
BoostAttempt round loop, the stuck check, the full-point quarantine and
the dispute bookkeeping are all ``lax.while_loop`` bodies ``vmap``-ed
over a leading task axis, so the host sees exactly one dispatch per
batch.

Semantics are the reference loop's, bit for bit (tests/test_batched.py
asserts it):

* the per-attempt PRNG stream is the same ``key, sub = split(key)``
  sequence ``run_accurately_classify`` performs on the host;
* the round bound is the paper's dynamic T = ⌈6·log2 m_alive⌉ per task
  per attempt (a traced bound inside a fixed ⌈6·log2 m⌉-sized program);
* quarantine is the array form of np.unique/np.isin — masked
  point-matching against the stuck coreset (classify.match_points),
  with the dispute-table size from classify.distinct_count so the
  communication ledger charges the identical bit counts.

Tasks finish at different attempt counts; finished lanes freeze (the
standard vmap-of-while masking) while stragglers continue.  Dead lanes
cost only select ops, so a batch is as slow as its slowest task, not
the sum.

The per-task protocol state (hits, alive, dispute masks) is small and
uniform across tasks — the regime where distributed-boosting analyses
(Chen–Balcan–Chau; smooth-boosting weight caps, Blanc et al. 2024) put
the bottleneck on per-round work rather than communication — which is
exactly what this engine amortises across the batch.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import boost_attempt, classify, ledger as L, weak
from repro.core import weights as W
from repro.core.types import BoostConfig, ClassifyResult, Ledger


class _TaskCarry(NamedTuple):
    attempt: jax.Array       # int32 — attempts executed so far
    done: jax.Array          # bool  — some attempt succeeded
    alive: jax.Array         # [k, mloc] current alive mask
    disputed: jax.Array      # [k, mloc] quarantined-example mask
    key: jax.Array
    h_params: jax.Array      # [T_buf, 4] ensemble of the winning attempt
    rounds: jax.Array        # int32 rounds of the winning attempt
    min_loss: jax.Array      # last center ERM loss (diagnostic)
    hist_stuck: jax.Array    # [A] bool   per-attempt stuck flag
    hist_rounds: jax.Array   # [A] int32  per-attempt rounds
    hist_alive: jax.Array    # [A] int32  alive count entering the attempt
    hist_p: jax.Array        # [A] int32  distinct disputed points


def num_rounds_dynamic(cfg: BoostConfig, m_alive: jax.Array) -> jax.Array:
    """Traced twin of ``BoostConfig.num_rounds`` (same f32 ops ⇒ same
    integer for every m, so the batched loop bound matches the host's)."""
    m = jnp.maximum(m_alive, 2).astype(jnp.float32)
    return jnp.ceil(cfg.rounds_factor * jnp.log2(m)).astype(jnp.int32)


def _attempt_body(cfg: BoostConfig, cls, x, y, x_orders, t_buf: int,
                  c: _TaskCarry) -> _TaskCarry:
    # LOCKSTEP: core/sharded_batched.py mirrors this body (and the
    # boost_attempt round body) with device-shard state + collectives;
    # keep them in sync — tests/test_sharded_batched.py pins exact
    # parity and fails on any divergence.
    key, sub = jax.random.split(c.key)
    m_alive = jnp.sum(c.alive.astype(jnp.int32))
    bound = num_rounds_dynamic(cfg, m_alive)
    hits0 = W.init_hits(x.shape[:2])
    out = boost_attempt.boost_attempt_arrays(
        x, y, c.alive, hits0, sub, cfg, cls, t_buf,
        round_bound=bound, x_orders=x_orders)
    stuck = out.stuck
    # ---- full-point quarantine, array form (no-op unless stuck) --------
    core_flat = out.core_x.reshape((-1,) + out.core_x.shape[2:])
    dead_new = c.alive & classify.match_points(x, core_flat) & stuck
    p_count = jnp.where(stuck, classify.distinct_count(core_flat), 0)
    a = c.attempt
    return _TaskCarry(
        attempt=a + 1,
        done=~stuck,
        alive=c.alive & ~dead_new,
        disputed=c.disputed | dead_new,
        key=key,
        h_params=jnp.where(stuck, c.h_params, out.h_params),
        rounds=jnp.where(stuck, c.rounds, out.t),
        min_loss=out.min_loss,
        hist_stuck=c.hist_stuck.at[a].set(stuck),
        hist_rounds=c.hist_rounds.at[a].set(out.t),
        hist_alive=c.hist_alive.at[a].set(m_alive),
        hist_p=c.hist_p.at[a].set(p_count),
    )


def classify_one_arrays(x, y, alive0, key, cfg: BoostConfig, cls,
                        t_buf: int) -> _TaskCarry:
    """Whole-protocol AccuratelyClassify for ONE task, fully on device.

    ``t_buf`` is the static hypothesis-buffer size (≥ any dynamic round
    bound, i.e. cfg.num_rounds(total sample size)).  Designed to be
    ``vmap``-ed over a leading task axis — all shapes are fixed.
    """
    a_max = cfg.opt_budget + 1
    x1d = x if x.ndim == 2 else x[:, :, 0]
    x_orders = jax.vmap(jnp.argsort)(x1d)   # hoisted across ALL attempts
    carry = _TaskCarry(
        attempt=jnp.int32(0), done=jnp.asarray(False),
        alive=alive0, disputed=jnp.zeros_like(alive0),
        key=key,
        h_params=jnp.zeros((t_buf, weak.PARAM_DIM), jnp.float32),
        rounds=jnp.int32(0), min_loss=jnp.float32(0),
        hist_stuck=jnp.zeros((a_max,), bool),
        hist_rounds=jnp.zeros((a_max,), jnp.int32),
        hist_alive=jnp.zeros((a_max,), jnp.int32),
        hist_p=jnp.zeros((a_max,), jnp.int32),
    )

    def cond(cy: _TaskCarry):
        return (~cy.done) & (cy.attempt < a_max)

    return jax.lax.while_loop(
        cond,
        functools.partial(_attempt_body, cfg, cls, x, y, x_orders, t_buf),
        carry)


@functools.partial(jax.jit, static_argnames=("cfg", "cls", "t_buf"))
def _classify_batched_jit(x, y, alive0, keys, cfg, cls, t_buf):
    one = functools.partial(classify_one_arrays, cfg=cfg, cls=cls,
                            t_buf=t_buf)
    return jax.vmap(one)(x, y, alive0, keys)


def stack_for_dispatch(items, B: int):
    """Stack admitted (x, y, alive, key) tuples into bucket arrays.

    ``items`` holds up to B tasks already padded to a common [k, mloc];
    short batches are filled by duplicating lane 0 (a live lane — dead
    filler would spin through the whole opt_budget and a batch is as
    slow as its slowest lane).  Returns (x, y, alive, keys, n_real);
    lanes ≥ n_real are filler and their results must be discarded.
    """
    n_real = len(items)
    if not 0 < n_real <= B:
        raise ValueError(f"need 1..{B} items, got {n_real}")
    items = list(items) + [items[0]] * (B - n_real)
    x = np.stack([it[0] for it in items])
    y = np.stack([it[1] for it in items])
    alive = np.stack([it[2] for it in items])
    key_data = np.stack([np.asarray(jax.random.key_data(it[3]))
                         for it in items])
    keys = jax.random.wrap_key_data(jnp.asarray(key_data))
    return x, y, alive, keys, n_real


def lower_classify(x, y, alive, keys, cfg: BoostConfig, cls):
    """AOT-compile the batched engine for one input signature.

    Returns a ``jax.stages.Compiled`` executable with the statics
    (cfg, cls, t_buf) baked in — call it as ``compiled(x, y, alive,
    keys)`` on arrays of exactly this shape/dtype.  Unlike the implicit
    jit cache, the caller owns the executable's lifetime: dropping it
    (e.g. a serving compile-cache eviction) really frees the program,
    and re-lowering really recompiles.  Output is bit-identical to the
    jit path (same trace, same compiler).
    """
    t_buf = cfg.num_rounds(x.shape[1] * x.shape[2])
    return _classify_batched_jit.lower(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(alive), keys, cfg,
        cls, t_buf).compile()


@dataclasses.dataclass
class BatchedClassifyResult:
    """Host view of one batched dispatch (B tasks).

    ``ok[b]`` is False iff task b exhausted ``opt_budget`` attempts —
    the batched analogue of the reference loop's RuntimeError.  The
    dispute table of task b is reconstructible from ``disputed[b]``
    alone (full-point quarantine ⇒ counts are the initially-alive
    counts; see classify.dispute_table).
    """

    hypotheses: np.ndarray   # [B, T_buf, 4]
    rounds: np.ndarray       # [B]
    ok: np.ndarray           # [B] bool
    attempts: np.ndarray     # [B]
    alive: np.ndarray        # [B, k, mloc] final alive mask
    disputed: np.ndarray     # [B, k, mloc]
    min_loss: np.ndarray     # [B]
    hist_stuck: np.ndarray   # [B, A]
    hist_rounds: np.ndarray  # [B, A]
    hist_alive: np.ndarray   # [B, A]
    hist_p: np.ndarray       # [B, A]
    # inputs, kept for per-task reconstruction
    x: np.ndarray
    y: np.ndarray
    alive0: np.ndarray
    cfg: BoostConfig
    cls: object
    # optional [B] true sample sizes — when the serving layer pads a
    # request's shards up to a bucket mloc, the protocol's |S| is still
    # the request's own m, and the dispute-report bit width ⌈log2 m⌉
    # must charge that, not the padded capacity
    m_true: np.ndarray | None = None

    @property
    def batch(self) -> int:
        return int(self.rounds.shape[0])

    def ledger(self, b: int) -> Ledger:
        """Bit-identical to the Ledger the reference loop accumulates."""
        cfg, cls = self.cfg, self.cls
        k, mloc = self.x.shape[1], self.x.shape[2]
        n = L.domain_size(cls)
        m_eff = (k * mloc if self.m_true is None
                 else int(self.m_true[b]))
        m_bits_m = max(int(np.ceil(np.log2(max(m_eff, 2)))), 1)
        led = Ledger()
        for a in range(int(self.attempts[b])):
            stuck = bool(self.hist_stuck[b, a])
            led = led + L.boost_attempt_ledger(
                cfg, cls, max(int(self.hist_alive[b, a]), 2),
                int(self.hist_rounds[b, a]), stuck)
            if stuck:
                p = int(self.hist_p[b, a])
                led.bits_control += cfg.k * p * L.point_bits(n)
                led.bits_dispute += cfg.k * p * 2 * m_bits_m
        return led

    def per_task(self, b: int) -> ClassifyResult:
        """Materialise task b as a reference-shaped ClassifyResult."""
        if not self.ok[b]:
            raise RuntimeError(
                f"task {b} exceeded opt_budget={self.cfg.opt_budget}")
        pts, pos, neg = classify.dispute_table(
            self.x[b], self.y[b], self.alive0[b], self.disputed[b])
        n_att = int(self.attempts[b])
        return ClassifyResult(
            hypotheses=jnp.asarray(self.hypotheses[b]),
            rounds=int(self.rounds[b]),
            dispute_x=jnp.asarray(pts),
            dispute_y=(jnp.asarray(pos), jnp.asarray(neg)),
            dispute_count=int(pts.shape[0]),
            attempts=n_att,
            stuck_history=[bool(s) for s in self.hist_stuck[b, :n_att]],
            ledger=self.ledger(b))

    def classifier(self, b: int) -> classify.ResilientClassifier:
        return classify.make_classifier(self.cls, self.per_task(b))


def run_accurately_classify_batched(x, y, keys, cfg: BoostConfig, cls,
                                    alive=None, compiled=None,
                                    m_true=None) -> BatchedClassifyResult:
    """B-task AccuratelyClassify in one device dispatch.

    x, y: [B, k, mloc] int shards or [B, k, mloc, F] feature rows;
    keys: [B] PRNG keys (one per task — the same key given to the
    reference loop reproduces it exactly) or a single key to split.
    ``alive``: optional [B, k, mloc] initial mask (False = padding, so
    ragged batches are padded to a common mloc and masked out).
    ``compiled``: optional executable from :func:`lower_classify` for
    this signature — the serving layer's compile cache passes it so a
    dispatch can never trigger an implicit recompile.
    ``m_true``: optional [B] true per-task sample sizes (see
    ``BatchedClassifyResult.m_true``).
    """
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    B, k, mloc = x.shape[0], x.shape[1], x.shape[2]
    keys = jnp.asarray(keys)
    if keys.ndim == 0:                       # one typed key → B streams
        keys = jax.random.split(keys, B)
    if keys.shape[0] != B:
        raise ValueError(f"need {B} task keys, got shape {keys.shape}")
    if alive is None:
        alive = jnp.ones((B, k, mloc), bool)
    else:
        alive = jnp.asarray(alive)
    if compiled is not None:
        out = jax.device_get(compiled(x, y, alive, keys))
    else:
        t_buf = cfg.num_rounds(k * mloc)
        out = jax.device_get(_classify_batched_jit(
            x, y, alive, keys, cfg, cls, t_buf))
    return BatchedClassifyResult(
        hypotheses=out.h_params, rounds=out.rounds,
        ok=np.asarray(out.done), attempts=out.attempt,
        alive=out.alive, disputed=out.disputed, min_loss=out.min_loss,
        hist_stuck=out.hist_stuck, hist_rounds=out.hist_rounds,
        hist_alive=out.hist_alive, hist_p=out.hist_p,
        x=np.asarray(x), y=np.asarray(y), alive0=np.asarray(alive),
        cfg=cfg, cls=cls,
        m_true=None if m_true is None else np.asarray(m_true))
