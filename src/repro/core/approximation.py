"""ε-approximation construction (step 2(a) of BoostAttempt).

A subsample S'_i of player i's shard is an ε-approximation of the
multiplicative-weights distribution p_t^i if for every h in the class
``|L_{S'_i}(h) − L_{p_t^i}(h)| ≤ ε``  (ε = 1/100 in the paper).

Two constructions, both O(coreset_size) examples and fully jittable:

1. **Deterministic quantile coreset** (``deterministic_coreset=True``).
   Sort the shard by domain point, take the points at cumulative-weight
   levels (j+½)/c.  For 1-D range-induced classes (thresholds,
   intervals, singletons — everything we instantiate on the integer
   track) the discrepancy of this construction is ≤ 2/c per range
   endpoint, so c = 400 gives a true 1/100-approximation *without
   randomness*, matching the paper's deterministic protocol.

2. **Randomized VC sampling** (``deterministic_coreset=False``).
   c i.i.d. draws from p_t^i (Gumbel-max / categorical).  By
   Vapnik–Chervonenkis (1971), c = O((d + log 1/δ)/ε²) draws form an
   ε-approximation w.h.p. — the paper's "computationally efficient
   implementation" (Section 4).

Both return *local indices*, so the caller can gather (x, y) for
transmission and later quarantine exactly these examples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import weights as W
from repro.core.pinned import pinned_argmax


def quantile_coreset(x: jax.Array, y: jax.Array, hits: jax.Array,
                     alive: jax.Array, c: int,
                     order: jax.Array | None = None,
                     y_sorted: jax.Array | None = None,
                     alive_sorted: jax.Array | None = None) -> jax.Array:
    """Deterministic per-label weighted-quantile coreset ([c] indices).

    Loss queries ``1[h(x) ≠ y]`` are unions of range events on the two
    label subpopulations, so a valid deterministic ε-approximation must
    control the discrepancy of each subpopulation separately: a plain
    x-quantile coreset mixes labels inside a weight bucket and its loss
    error degrades to ~1/√c (we measured 0.023 at c=400 — above ε).
    Allocating c± ∝ W± slots and taking weighted quantiles *within each
    label class* gives error ≤ 2/c per class, ≤ 4/c total — a true
    1/100-approximation at c = 400, with no randomness.

    Heavy points are replicated in proportion to weight, so point-mass
    (singleton) queries are covered too.  Dead shards return index 0
    repeated — callers weight them out via the zero mixture weight.
    """
    m = x.shape[0]
    if order is None:
        order = jnp.argsort(x)                   # sort by domain point
    # §Perf P4 (batched engine): y[order] and alive[order] are
    # loop-invariant across rounds, so callers in the round loop hoist
    # them; per round only hits needs re-gathering into sorted space.
    ys = y[order] if y_sorted is None else y_sorted
    al = alive[order] if alive_sorted is None else alive_sorted
    hs = hits[order]
    # §Perf P3: quantile levels are scale-free, so the normalization
    # (log-sum-exp over the shard) is unnecessary — use raw 2^{-hits}.
    # Stable for hits ≤ 126 in f32 via a max-shift in integer space.
    # The clip keeps the dead-lane exp2 argument finite (an all-dead
    # shard has hmin = intmax), so no inf ever enters the cumsum even
    # on fully padded shards of a batched task.
    hmin = jnp.min(jnp.where(al, hs, jnp.iinfo(hs.dtype).max))
    shift = jnp.clip((hs - hmin).astype(jnp.float32), 0.0, 126.0)
    p = jnp.where(al, jnp.exp2(-shift), 0.0)
    # one stacked cumsum/searchsorted for the two label subpopulations
    p2 = jnp.stack([jnp.where(ys > 0, p, 0.0),
                    jnp.where(ys > 0, 0.0, p)])              # [2, m]
    cum = jnp.cumsum(p2, axis=-1)
    w_pos, w_neg = cum[0, -1], cum[1, -1]
    has_pos = w_pos > 1e-12
    has_neg = w_neg > 1e-12
    c_pos = jnp.round(c * w_pos
                      / jnp.maximum(w_pos + w_neg, 1e-30)).astype(jnp.int32)
    c_pos = jnp.clip(c_pos, jnp.where(has_pos, 1, 0),
                     c - jnp.where(has_neg, 1, 0))
    j = jnp.arange(c, dtype=jnp.float32)
    c_posf = jnp.maximum(c_pos.astype(jnp.float32), 1.0)
    c_negf = jnp.maximum((c - c_pos).astype(jnp.float32), 1.0)
    lvls = jnp.stack([(j + 0.5) * w_pos / c_posf,
                      (j - c_posf + 0.5) * w_neg / c_negf])  # [2, c]
    idx2 = jnp.clip(jax.vmap(jnp.searchsorted)(cum, lvls), 0, m - 1)
    pos_sel = jnp.arange(c, dtype=jnp.int32) < c_pos
    idx_sorted = jnp.where(pos_sel, idx2[0], idx2[1])
    return order[idx_sorted]


def sampled_coreset(key: jax.Array, hits: jax.Array, alive: jax.Array,
                    c: int) -> jax.Array:
    """Randomized coreset: c i.i.d. categorical draws from p_t^i.

    Gumbel-max spelled out (the exact construction
    ``jax.random.categorical`` uses) so the winning index comes from
    ``pinned_argmax``: same gumbel draws, same sums — bit-identical
    draws where categorical's bare argmax has a unique winner, lowest
    index where it would tie (tie order is backend-defined; RL001)."""
    logp = W.normalized_log_probs(hits, alive) * W.LN2  # natural-log logits
    g = jax.random.gumbel(key, (c,) + logp.shape, logp.dtype)
    return pinned_argmax(g + logp[None, :], axis=-1)


def select_coreset(key: jax.Array, x: jax.Array, y: jax.Array,
                   hits: jax.Array, alive: jax.Array, c: int,
                   deterministic: bool,
                   order: jax.Array | None = None,
                   y_sorted: jax.Array | None = None,
                   alive_sorted: jax.Array | None = None) -> jax.Array:
    if deterministic:
        # `order` hoists the loop-invariant argsort(x) out of the round
        # loop (§Perf iteration P1 — the domain points never change);
        # y_sorted/alive_sorted hoist the matching gathers (§Perf P4).
        return quantile_coreset(x, y, hits, alive, c, order=order,
                                y_sorted=y_sorted,
                                alive_sorted=alive_sorted)
    return sampled_coreset(key, hits, alive, c)


def approximation_error(coreset_idx: jax.Array, x: jax.Array, y: jax.Array,
                        hits: jax.Array, alive: jax.Array,
                        predict_fn, hyp_params: jax.Array) -> jax.Array:
    """sup_h |L_{S'}(h) − L_p(h)| over the given hypothesis grid.

    Test/diagnostic utility: verifies the ε-approximation property that
    Lemma 4.2 relies on.
    """
    p = W.probs(hits, alive)
    preds_full = predict_fn(hyp_params, x)              # [C, m] in {±1}
    err_full = jnp.sum((preds_full != y[None, :]) * p[None, :], axis=-1)
    cx, cy = x[coreset_idx], y[coreset_idx]
    preds_core = predict_fn(hyp_params, cx)             # [C, c]
    err_core = jnp.mean(preds_core != cy[None, :], axis=-1)
    return jnp.max(jnp.abs(err_full - err_core))
