"""Core dataclasses for the resilient distributed boosting protocol.

Terminology follows the paper (Filmus–Mehalel–Moran, ICML 2022):

* ``k``       — number of players; the sample is adversarially split
                into ``k`` shards.
* ``m``       — total sample size ``|S|``.
* ``n``       — domain size ``|U|`` (points are integers in ``[0, n)`` on
                the 1-D track, or rows of a feature matrix).
* ``OPT``     — errors of the best hypothesis in the class on ``S``.
* coreset     — the ε-approximation each player transmits
                (ε = 1/100 in the paper; size ``O(d/ε²)``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

# The paper's constants (Figure 1 / Theorem 3.1).
EPS_APPROX = 1.0 / 100.0      # ε of the per-player ε-approximation
WEAK_EDGE_THRESHOLD = 1.0 / 100.0  # center accepts ĥ with L_{D_t}(ĥ) ≤ 1/100
ADABOOST_ROUNDS_FACTOR = 6    # T = ceil(6 log2 |S|)


@dataclasses.dataclass(frozen=True)
class BoostConfig:
    """Static configuration of the protocol.

    ``coreset_size`` is the per-player ε-approximation size.  The paper
    uses a *minimal-size* deterministic 1/100-approximation of size
    O(d/ε²) = O(d·10⁴); in practice much smaller coresets already satisfy
    the approximation property for the small-VC classes we instantiate
    (d ≤ 2), and the randomized variant (Vapnik–Chervonenkis sampling)
    needs O((d + log 1/δ)/ε²).  The ledger always *charges* the paper's
    bit cost per transmitted example, so shrinking the coreset only makes
    the measured communication smaller, never cheats the accounting.
    """

    k: int                              # number of players
    coreset_size: int = 256             # examples per player per round
    domain_size: int = 1 << 16          # n = |U|
    rounds_factor: int = ADABOOST_ROUNDS_FACTOR
    weak_threshold: float = WEAK_EDGE_THRESHOLD
    opt_budget: int = 64                # max outer (quarantine) iterations
    deterministic_coreset: bool = True  # quantile coreset (1-D classes) vs
                                        # Gumbel/categorical sampling
    seed: int = 0
    # Streaming tier (docs/streaming.md): when set, every engine builds
    # its loop-invariant per-player sort order from chunk-local sorted
    # runs (repro.core.streaming.sort_order — bitwise identical to the
    # monolithic argsort) and tree ERMs accumulate histograms over
    # point tiles of this many examples.  None = monolithic, unchanged.
    chunk_size: int | None = None

    def num_rounds(self, m: int) -> int:
        """T = ceil(6 * log2 |S|) — Theorem 3.1 with the paper's constants."""
        m = max(int(m), 2)
        # m is always a host int; ensure_compile_time_eval keeps this
        # concrete (same f32 math, bit for bit) when a caller sits
        # inside a trace — e.g. the jaxpr audit tracing init_state
        with jax.ensure_compile_time_eval():
            return int(jnp.ceil(self.rounds_factor * jnp.log2(m)))


@dataclasses.dataclass
class BoostAttemptResult:
    """Output of one BoostAttempt execution (Figure 1).

    Exactly one of the two paper outcomes holds:

    * ``stuck == False`` — ``hypotheses[:rounds]`` define the boosted
      classifier ``f = sign(Σ_t h_t)`` with ``E_S(f) = 0`` on the alive
      sample (Lemma 4.2).
    * ``stuck == True``  — ``coreset_index`` (per player) points at a
      non-realizable subsample S' (Observation 4.3), to be quarantined.
    """

    stuck: bool
    rounds: int                  # rounds actually executed
    hypotheses: Any              # [T, P] stacked hypothesis params
    coreset_index: Any           # [k, c] local indices of the final coreset
    coreset_x: Any               # [k, c] domain points of the final coreset
    coreset_y: Any               # [k, c] labels of the final coreset
    min_mixture_loss: Any        # L_{D_t}(ĥ) at the last round (diagnostic)


@dataclasses.dataclass
class ClassifyResult:
    """Output of AccuratelyClassify (Figure 2)."""

    hypotheses: Any              # boosting ensemble from the final attempt
    rounds: int
    dispute_x: Any               # [cap] quarantined points (−1 padded)
    dispute_y: Any               # [cap] labels of quarantined points
    dispute_count: int           # number of valid dispute entries
    attempts: int                # BoostAttempt invocations (≤ OPT + 1)
    stuck_history: list          # per-attempt stuck flag
    ledger: "Ledger"


@dataclasses.dataclass
class Ledger:
    """Bit-exact communication accounting (see core/ledger.py)."""

    bits_coresets: int = 0       # step 2(a): k coresets per round
    bits_weight_sums: int = 0    # step 2(b): k weight sums per round
    bits_hypotheses: int = 0     # step 2(d): broadcast h_t
    bits_control: int = 0        # step 2(e): stuck indication, loop control
    bits_dispute: int = 0        # outer loop: center holds S' (already sent)
    rounds: int = 0
    attempts: int = 0
    # distributed tree growth (weak_tree comm_mode != "coreset"): the
    # per-round histogram merge / vote proposals that REPLACE step
    # 2(a)'s coreset payload (bits_coresets then charges only the stuck
    # round's example transfer, which quarantine still needs)
    bits_histograms: int = 0
    bits_votes: int = 0

    @property
    def total_bits(self) -> int:
        return (self.bits_coresets + self.bits_weight_sums
                + self.bits_hypotheses + self.bits_control
                + self.bits_dispute + self.bits_histograms
                + self.bits_votes)

    def __add__(self, other: "Ledger") -> "Ledger":
        return Ledger(
            bits_coresets=self.bits_coresets + other.bits_coresets,
            bits_weight_sums=self.bits_weight_sums + other.bits_weight_sums,
            bits_hypotheses=self.bits_hypotheses + other.bits_hypotheses,
            bits_control=self.bits_control + other.bits_control,
            bits_dispute=self.bits_dispute + other.bits_dispute,
            rounds=self.rounds + other.rounds,
            attempts=self.attempts + other.attempts,
            bits_histograms=self.bits_histograms + other.bits_histograms,
            bits_votes=self.bits_votes + other.bits_votes,
        )
