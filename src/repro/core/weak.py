"""Hypothesis classes and the center's weighted-ERM weak learner.

The center (step 2(d) of BoostAttempt) must find ``ĥ ∈ H`` with
``L_{D_t}(ĥ) ≤ 1/100`` over the pooled coreset, or certify that none
exists.  Because ``L_{D_t}`` only depends on hypothesis behaviour *on the
coreset points*, exact ERM over H reduces to ERM over the finitely many
behaviours induced by the coreset — each class below implements that
reduction in closed, jittable form (prefix sums / Kadane / segment sums),
so the certificate "no hypothesis is 1/100-good" is exact, which is what
Observation 4.3 (non-realizability of S') requires.

Hypothesis encoding — a flat float32 vector, ``(type, a, b, s)`` for
the 4-wide classes below (``cls.param_dim``, default :data:`PARAM_DIM`,
is the class's width — the engines size their ensemble buffers from it
via :func:`param_dim`):

=====  ==========================  =======================================
type   class                       prediction
=====  ==========================  =======================================
1      singleton over [n)          +1 iff x == a   (paper's Thm 2.3 class)
2      threshold over [n)          s if x ≥ a else −s  (a = n ⇒ constant −s)
3      interval over [n)           +1 iff a ≤ x ≤ b
4      axis-aligned stump          s if X[..., f=a] ≥ b else −s
5      histogram tree (weak_tree)  leaf sign after depth-d bin routing
=====  ==========================  =======================================

All ``predict`` methods broadcast ``params [..., P]`` against point
arrays and return int8 ±1.

Capability protocol (how core/tasks.py, launch/ and benchmarks/ stay
class-agnostic — new classes plug in without editing them):

* ``needs_features``  — True iff the class consumes feature rows
  ``[.., F]`` (⇒ randomized coreset; 1-D integer classes keep the
  deterministic quantile coreset);
* ``param_dim``       — hypothesis vector width (absent ⇒ PARAM_DIM);
* ``sample_points(rng, m)`` / ``sample_target(rng, x)`` — how
  ``tasks.make_task`` draws a sample and a ground-truth hypothesis.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pinned import pinned_argmax, pinned_argmin

PARAM_DIM = 4


def param_dim(cls) -> int:
    """Hypothesis-vector width of a class (PARAM_DIM when unstated) —
    what the engines size ensemble buffers with."""
    return PARAM_DIM if cls is None else getattr(cls, "param_dim",
                                                 PARAM_DIM)


def needs_features(cls) -> bool:
    """True iff the class consumes feature rows [.., F] (the capability
    that used to be an ``isinstance(cls, AxisStumps)`` special-case)."""
    return bool(getattr(cls, "needs_features", False))


def _pm(b: jax.Array) -> jax.Array:
    """bool -> ±1 (int8)."""
    return jnp.where(b, jnp.int8(1), jnp.int8(-1))


def _field(params: jax.Array, i: int, x_ndim: int) -> jax.Array:
    """Extract param field i and append x_ndim broadcast axes, so that
    predict(params [..., 4], x [pts...]) returns [*param_batch, *pts]."""
    f = params[..., i]
    return f.reshape(f.shape + (1,) * x_ndim)


def _sorted_prefix(xs, ys, w, n: int | None = None):
    """Common ERM preamble: sort by point, return per-index prefix sums.

    §Perf P4: XLA:CPU's variadic/comparator sort (what a stable argsort
    lowers to) is ~10× slower than its single-operand numeric sort and
    is row-serial, so it becomes the hot op of the whole protocol once
    the round loop is batched over tasks.  When the caller can certify
    an integer domain [0, n) with n·len(xs) < 2³¹ we pack (x, index)
    into ONE int32 key and take the fast path — the index low bits make
    the unpacked order bitwise-identical to the stable argsort.
    """
    k = xs.shape[0]
    if (n is not None and 0 < n * k < 2 ** 31
            and jnp.issubdtype(xs.dtype, jnp.integer)):
        keys = xs.astype(jnp.int32) * k + jnp.arange(k, dtype=jnp.int32)
        keys_s = jnp.sort(keys)
        order = keys_s % k
        xs_s = (keys_s // k).astype(xs.dtype)
    else:
        order = jnp.argsort(xs)
        xs_s = xs[order]
    wp = jnp.where(ys[order] > 0, w[order], 0.0)
    wn = jnp.where(ys[order] > 0, 0.0, w[order])
    return order, xs_s, jnp.cumsum(wp), jnp.cumsum(wn), jnp.sum(wp), jnp.sum(wn)


def _first_occurrence(xs_s: jax.Array) -> jax.Array:
    """Mask of positions that start a run of equal values."""
    return jnp.concatenate(
        [jnp.ones((1,), bool), xs_s[1:] != xs_s[:-1]])


@dataclasses.dataclass(frozen=True)
class Singletons:
    """H = {h_a : a ∈ [n)}, h_a(x) = +1 iff x == a — the paper's
    lower-bound class (Theorem 2.3).  VC dimension 1."""

    n: int

    vc_dim: int = 1
    needs_features = False

    def hypothesis_bits(self) -> int:
        return int(jnp.ceil(jnp.log2(self.n))) + 2  # point id + type/sign

    def sample_points(self, rng, m: int):
        return rng.integers(0, self.n, size=m).astype("int32")

    def sample_target(self, rng, x):
        a = int(x[rng.integers(x.shape[0])])
        return np.array([1.0, a, a, 1.0], np.float32)

    def predict(self, params: jax.Array, x: jax.Array) -> jax.Array:
        a = _field(params, 1, x.ndim)
        return _pm(x == a)

    def erm(self, xs: jax.Array, ys: jax.Array, w: jax.Array):
        """Exact ERM: candidates a ∈ coreset ∪ {one point off-coreset}."""
        order, xs_s, cwp, cwn, Wp, _ = _sorted_prefix(xs, ys, w, n=self.n)
        k = xs.shape[0]
        first = _first_occurrence(xs_s)
        # segment sums of (w·1[y=+1], w·1[y=−1]) per unique value run:
        # run containing position j spans [start(j), end(j)).
        idx = jnp.arange(k, dtype=jnp.int32)
        start = jnp.where(first, idx, 0)
        start = jax.lax.associative_scan(jnp.maximum, start)        # run start
        nxt_first = jnp.concatenate([first[1:], jnp.ones((1,), bool)])
        end = jnp.where(nxt_first, idx, k - 1)
        end = jax.lax.associative_scan(jnp.minimum, end, reverse=True)
        seg_wp = cwp[end] - jnp.where(start > 0, cwp[start - 1], 0.0)
        seg_wn = cwn[end] - jnp.where(start > 0, cwn[start - 1], 0.0)
        # err(h_a) = Wp_total − seg_wp(a) + seg_wn(a)  for a in coreset
        errs = Wp - seg_wp + seg_wn
        j = pinned_argmin(errs)
        best_in, err_in = xs_s[j].astype(jnp.float32), errs[j]
        # off-coreset candidate: first free point (behaviour = constant −1)
        cand = jnp.concatenate(
            [jnp.zeros((1,), xs_s.dtype), (xs_s + 1) % self.n])
        pos = jnp.searchsorted(xs_s, cand)
        present = (pos < k) & (xs_s[jnp.clip(pos, 0, k - 1)] == cand)
        free_a = cand[pinned_argmin(present)].astype(jnp.float32)  # first False
        take_free = (Wp < err_in) | jnp.all(present)
        a = jnp.where(take_free & ~jnp.all(present), free_a, best_in)
        loss = jnp.where(take_free & ~jnp.all(present), Wp, err_in)
        params = jnp.stack([jnp.float32(1), a, a, jnp.float32(1)])
        return params, loss


@dataclasses.dataclass(frozen=True)
class Thresholds:
    """H = {x ↦ s·sign(x − θ)} over [n).  VC dimension 1."""

    n: int

    vc_dim: int = 1
    needs_features = False

    def hypothesis_bits(self) -> int:
        return int(jnp.ceil(jnp.log2(self.n + 1))) + 3

    def sample_points(self, rng, m: int):
        return rng.integers(0, self.n, size=m).astype("int32")

    def sample_target(self, rng, x):
        a = float(np.quantile(x, rng.uniform(0.2, 0.8)))
        s = float(rng.choice([-1.0, 1.0]))
        return np.array([2.0, np.floor(a), np.floor(a), s], np.float32)

    def predict(self, params: jax.Array, x: jax.Array) -> jax.Array:
        a = _field(params, 1, x.ndim)
        s = _field(params, 3, x.ndim)
        return (jnp.where(x >= a, s, -s)).astype(jnp.int8)

    def erm(self, xs: jax.Array, ys: jax.Array, w: jax.Array):
        order, xs_s, cwp, cwn, Wp, Wn = _sorted_prefix(xs, ys, w,
                                                       n=self.n)
        k = xs.shape[0]
        first = _first_occurrence(xs_s)
        # θ at position j ⇒ pred −s for i<j, +s for i≥j (value-aligned
        # only at first occurrences; j = k is the constant −s hypothesis).
        prev_wp = jnp.concatenate(
            [jnp.zeros((1,), jnp.float32), cwp])            # Σ_{i<j} wp
        prev_wn = jnp.concatenate([jnp.zeros((1,), jnp.float32), cwn])
        err_plus = prev_wp + (Wn - prev_wn)                 # s = +1
        valid = jnp.concatenate([first, jnp.ones((1,), bool)])
        err_plus = jnp.where(valid, err_plus, jnp.inf)
        err_minus = jnp.where(valid, (Wp + Wn) - err_plus, jnp.inf)
        jp, jm = pinned_argmin(err_plus), pinned_argmin(err_minus)
        use_plus = err_plus[jp] <= err_minus[jm]
        j = jnp.where(use_plus, jp, jm)
        theta = jnp.where(j < k, xs_s[jnp.clip(j, 0, k - 1)].astype(jnp.float32),
                          jnp.float32(self.n))
        s = jnp.where(use_plus, 1.0, -1.0)
        loss = jnp.where(use_plus, err_plus[jp], err_minus[jm])
        params = jnp.stack([jnp.float32(2), theta, theta, s])
        return params, loss


@dataclasses.dataclass(frozen=True)
class Intervals:
    """H = {x ↦ +1 iff a ≤ x ≤ b} over [n).  VC dimension 2."""

    n: int

    vc_dim: int = 2
    needs_features = False

    def hypothesis_bits(self) -> int:
        return 2 * int(jnp.ceil(jnp.log2(self.n))) + 2

    def sample_points(self, rng, m: int):
        return rng.integers(0, self.n, size=m).astype("int32")

    def sample_target(self, rng, x):
        a, b = np.sort(rng.choice(x, size=2, replace=False))
        return np.array([3.0, a, b, 1.0], np.float32)

    def predict(self, params: jax.Array, x: jax.Array) -> jax.Array:
        a = _field(params, 1, x.ndim)
        b = _field(params, 2, x.ndim)
        return _pm((x >= a) & (x <= b))

    def erm(self, xs: jax.Array, ys: jax.Array, w: jax.Array):
        """Kadane over value-grouped gains: err(a,b) = Wp − Σ_[a,b](wp−wn)."""
        order, xs_s, cwp, cwn, Wp, _ = _sorted_prefix(xs, ys, w, n=self.n)
        k = xs.shape[0]
        nxt_first = jnp.concatenate(
            [xs_s[1:] != xs_s[:-1], jnp.ones((1,), bool)])
        # prefix of gain g = wp − wn at run *ends* (value boundaries)
        P = cwp - cwn
        P_end = jnp.where(nxt_first, P, -jnp.inf)          # usable right ends
        prevP = jnp.concatenate([jnp.zeros((1,), jnp.float32), P[:-1]])
        first = _first_occurrence(xs_s)
        prevP_start = jnp.where(first, prevP, jnp.inf)     # usable left starts
        cummin = jax.lax.associative_scan(jnp.minimum, prevP_start)
        gain = P_end - cummin                              # best Σ ending at j
        j = pinned_argmax(gain)
        best_gain = gain[j]
        # left index: argmin of prevP_start over [0, j]
        masked = jnp.where(jnp.arange(k, dtype=jnp.int32) <= j,
                           prevP_start, jnp.inf)
        i = pinned_argmin(masked)
        a = xs_s[i].astype(jnp.float32)
        b = xs_s[j].astype(jnp.float32)
        loss_in = Wp - best_gain
        # empty interval (constant −1): encode as a > b
        use_empty = Wp < loss_in
        a = jnp.where(use_empty, jnp.float32(1), a)
        b = jnp.where(use_empty, jnp.float32(0), b)
        loss = jnp.where(use_empty, Wp, loss_in)
        params = jnp.stack([jnp.float32(3), a, b, jnp.float32(1)])
        return params, loss


@dataclasses.dataclass(frozen=True)
class AxisStumps:
    """H = {X ↦ s·sign(X[f] − θ)} over feature rows.  VC dim O(log F)."""

    num_features: int
    value_bits: int = 32

    needs_features = True

    @property
    def feature_dim(self) -> int:
        return self.num_features

    @property
    def vc_dim(self) -> int:
        return max(1, int(jnp.ceil(jnp.log2(self.num_features))) + 1)

    def hypothesis_bits(self) -> int:
        return (int(jnp.ceil(jnp.log2(self.num_features)))
                + self.value_bits + 3)

    def sample_points(self, rng, m: int):
        return (rng.standard_normal((m, self.num_features))
                .astype(np.float32) * 100.0)

    def sample_target(self, rng, x):
        f = int(rng.integers(self.num_features))
        theta = float(np.quantile(x[:, f], rng.uniform(0.2, 0.8)))
        s = float(rng.choice([-1.0, 1.0]))
        return np.array([4.0, f, theta, s], np.float32)

    def predict(self, params: jax.Array, x: jax.Array) -> jax.Array:
        """params [..., 4], x [*pts, F] → [*param_batch, *pts]."""
        f = params[..., 1].astype(jnp.int32)
        xv = jnp.take(x, f, axis=-1)            # [*pts, *param_batch]
        pts_nd = x.ndim - 1
        perm = tuple(range(pts_nd, xv.ndim)) + tuple(range(pts_nd))
        xv = jnp.transpose(xv, perm)            # [*param_batch, *pts]
        theta = _field(params, 2, pts_nd)
        s = _field(params, 3, pts_nd)
        return (jnp.where(xv >= theta, s, -s)).astype(jnp.int8)

    def erm(self, xs: jax.Array, ys: jax.Array, w: jax.Array):
        """vmap the 1-D threshold ERM over features."""
        thr = Thresholds(n=1 << self.value_bits)

        def per_feature(col):
            return thr.erm(col, ys, w)

        params_f, losses = jax.vmap(per_feature, in_axes=1)(xs)
        f = pinned_argmin(losses)
        p = params_f[f]
        params = jnp.stack(
            [jnp.float32(4), f.astype(jnp.float32), p[1], p[3]])
        return params, losses[f]


def erm_batch(cls, xs: jax.Array, ys: jax.Array, w: jax.Array):
    """ERM over a leading batch (task) axis: xs [B, c(, F)], ys/w [B, c]
    → (params [B, 4], loss [B]).

    Pad-safe: a padded example carries w = 0 and contributes nothing to
    any candidate's error, and an all-zero-weight row (a fully padded
    task) degenerates to loss 0 with a deterministic first-candidate
    hypothesis — callers mask such rows out rather than special-case
    them.  Every ERM above is closed-form over sorts/prefix sums, so
    vmap adds a batch dim without changing per-row op order (this is
    what the batched engine's bitwise-parity test relies on).
    """
    return jax.vmap(cls.erm)(xs, ys, w)


def make_class(name: str, *, n: int = 0, num_features: int = 0,
               tree_depth: int = 2, tree_bins: int = 32,
               tree_comm_mode: str = "coreset", tree_vote_topk: int = 2):
    if name == "singletons":
        return Singletons(n=n)
    if name == "thresholds":
        return Thresholds(n=n)
    if name == "intervals":
        return Intervals(n=n)
    if name == "stumps":
        return AxisStumps(num_features=num_features)
    if name == "tree":
        from repro.weak_tree import HistogramTrees
        return HistogramTrees(num_features=num_features,
                              depth=tree_depth, bins=tree_bins,
                              comm_mode=tree_comm_mode,
                              vote_topk=tree_vote_topk)
    raise ValueError(f"unknown hypothesis class {name!r}")


def ensemble_predict(cls, hyp_params: jax.Array, rounds: jax.Array,
                     x: jax.Array) -> jax.Array:
    """g(x) = sign(Σ_{t<rounds} h_t(x));  sign(0) := +1 (deterministic)."""
    hyp_params = jnp.asarray(hyp_params)
    T = hyp_params.shape[0]

    def one(t):
        p = cls.predict(hyp_params[t], x).astype(jnp.int32)
        return jnp.where(t < rounds, p, 0)

    votes = jnp.sum(jax.vmap(one)(jnp.arange(T, dtype=jnp.int32)), axis=0)
    return jnp.where(votes >= 0, jnp.int8(1), jnp.int8(-1))


def empirical_errors(predict_pm: jax.Array, y: jax.Array,
                     alive=None) -> jax.Array:
    """E_S(f): number of misclassified (alive) examples."""
    wrong = (predict_pm != y)
    if alive is not None:
        wrong = wrong & alive
    return jnp.sum(wrong.astype(jnp.int32))
