"""BoostAttempt (Figure 1) — distributed boosting that may get "stuck".

Two executable forms of the same round body:

* :func:`run_boost_attempt` — single-process simulation.  The k players
  are the leading axis of the sample arrays; player-local steps are
  ``vmap``-ed over that axis and the "center" runs inline.  This is the
  reference used by tests/benchmarks and the communication-ledger
  validation (the ledger charges exactly what *would* cross the wire).

* :func:`boost_attempt_sharded` — ``shard_map`` over the mesh ``data``
  (× ``pod``) axis: each device group is one player; the coresets and
  the scalar weight sums are ``all_gather``-ed (the star topology's
  k → center messages), the center's weighted ERM runs replicated, and
  the multiplicative-weights update is purely local.  This is what the
  production launcher and the multi-pod dry-run lower.

The loop is a ``jax.lax.while_loop`` with the paper's termination:
either T = ⌈6·log2 m⌉ hypotheses were produced (boosting succeeded,
Lemma 4.2 ⇒ E_S(f) = 0 on the alive sample) or the center certifies
that no hypothesis has mixture loss ≤ 1/100 (stuck ⇒ the pooled coreset
is non-realizable, Observation 4.3).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax.shard_map (with check_vma) landed after 0.4.x; fall back to the
# experimental entry point (check_rep) so the sharded form runs on the
# pinned toolchain as well as newer jax.
if hasattr(jax, "shard_map"):
    def _shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, mesh, in_specs, out_specs):
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

from repro.core import approximation, weights as W
from repro.core import streaming, weak
from repro.core.types import BoostAttemptResult, BoostConfig
from repro.obs import trace as obs_trace


class _Carry(NamedTuple):
    t: jax.Array            # hypotheses produced so far
    it: jax.Array           # loop iterations (rounds attempted)
    stuck: jax.Array        # bool
    hits: jax.Array         # [k, mloc] int32
    key: jax.Array
    h_params: jax.Array     # [T, 4]
    core_idx: jax.Array     # [k, c] last-round coreset (local indices)
    core_x: jax.Array       # [k, c(, F)]
    core_y: jax.Array       # [k, c]
    min_loss: jax.Array     # last center ERM loss


def _gather_coreset(x, y, idx):
    take = functools.partial(jnp.take_along_axis, axis=1)
    if x.ndim == 3:  # feature track: [k, mloc, F]
        cx = take(x, idx[..., None])
    else:
        cx = take(x, idx)
    return cx, take(y, idx)


def _center_erm(cls, cx, cy, mix, c):
    """Pooled-coreset ERM under the mixture D_t (step 2(c)+(d)).

    Classes with a distributed ``comm_mode`` (weak_tree trees in
    histogram/voting mode) grow from per-player partials instead: here
    the caller already holds all k players' shards, so the per-player
    grower runs with an identity gather — the same float path the
    sharded engine's real collectives produce (bit-parity per mode).
    """
    k = cy.shape[0]
    # jax.named_scope is device-side metadata (it adds no ops and no
    # host work) — profiler traces group the ERM under this label; it
    # is NOT an obs emission, so RL006 permits it in traced code
    with jax.named_scope("center_erm"):
        if getattr(cls, "comm_mode", "coreset") != "coreset":
            return cls.erm_players(cx, cy, mix / c)
        w = jnp.broadcast_to(mix[:, None] / c, (k, c)).reshape(-1)
        cx_flat = cx.reshape((k * c,) + cx.shape[2:])
        cy_flat = cy.reshape(-1)
        return cls.erm(cx_flat, cy_flat, w)


def _round_body(cfg: BoostConfig, cls, x, y, alive, x_orders,
                y_sorted, alive_sorted, carry: _Carry, *,
                player_alive=None) -> _Carry:
    key, kc = jax.random.split(carry.key)
    keys = jax.random.split(kc, x.shape[0])
    # --- players: step 2(a) coreset + step 2(b) weight sums -------------
    idx = jax.vmap(
        lambda kk, xx, yy, hh, aa, oo, yso, aso:
        approximation.select_coreset(
            kk, xx if xx.ndim == 1 else xx[:, 0], yy, hh, aa,
            cfg.coreset_size, cfg.deterministic_coreset and x.ndim == 2,
            order=oo, y_sorted=yso, alive_sorted=aso)
    )(keys, x, y, carry.hits, alive, x_orders, y_sorted, alive_sorted)
    cx, cy = _gather_coreset(x, y, idx)
    log_wsums = jax.vmap(W.log_weight_sum)(carry.hits, alive)     # [k]
    if player_alive is not None:
        # a player absent this round sends nothing: its weight sum is
        # excluded from the mixture (−inf ⇒ mixture weight 0, so its
        # coreset entries carry zero weight in the center ERM — the
        # candidate behaviours they add are sound: zero-weight points
        # can only certify MORE hypotheses, never hide a good one)
        log_wsums = jnp.where(player_alive, log_wsums, -jnp.inf)
    mix = W.mixture_weights(log_wsums)
    # --- center: step 2(c)+(d) weighted ERM over the pooled coreset -----
    h, loss = _center_erm(cls, cx, cy, mix, cfg.coreset_size)
    stuck_now = loss > cfg.weak_threshold
    # --- players: step 2(f) multiplicative-weights update ---------------
    pred = cls.predict(h, x)
    correct = (pred == y)
    upd = W.update_hits(carry.hits, correct, alive)
    if player_alive is not None:
        # absent players never received h_t: their MW state freezes
        upd = jnp.where(player_alive[:, None], upd, carry.hits)
    new_hits = jnp.where(stuck_now, carry.hits, upd)
    h_params = carry.h_params.at[carry.t].set(
        jnp.where(stuck_now, carry.h_params[carry.t], h))
    return _Carry(
        t=jnp.where(stuck_now, carry.t, carry.t + 1),
        it=carry.it + 1,
        stuck=stuck_now,
        hits=new_hits,
        key=key,
        h_params=h_params,
        core_idx=idx, core_x=cx, core_y=cy,
        min_loss=loss,
    )


def boost_attempt_arrays(x, y, alive, hits0, key, cfg: BoostConfig, cls,
                         num_rounds: int, *, round_bound=None,
                         x_orders=None):
    """Jittable BoostAttempt core. Returns the final carry tuple.

    ``num_rounds`` is the *static* hypothesis-buffer size.  The loop
    itself stops at ``round_bound`` when given (a traced int32 ≤
    ``num_rounds``) — this is what lets the batched engine run the
    paper's T = ⌈6·log2 m_alive⌉ bound with a per-task, per-attempt
    alive count while keeping one fixed-shape program.  ``x_orders``
    optionally passes in the loop-invariant per-player argsort so an
    outer loop (AccuratelyClassify attempts) can hoist it.
    """
    k, c = x.shape[0], cfg.coreset_size
    carry = _Carry(
        t=jnp.int32(0), it=jnp.int32(0), stuck=jnp.asarray(False),
        hits=hits0, key=key,
        h_params=jnp.zeros((num_rounds, weak.param_dim(cls)),
                           jnp.float32),
        core_idx=jnp.zeros((k, c), jnp.int32),
        core_x=jnp.zeros((k, c) + x.shape[2:], x.dtype),
        core_y=jnp.zeros((k, c), y.dtype),
        min_loss=jnp.float32(0),
    )
    bound = num_rounds if round_bound is None else round_bound

    def cond(cy: _Carry):
        return (~cy.stuck) & (cy.t < bound)

    # §Perf P1: loop-invariant per-player argsort hoisted out of the
    # round loop; §Perf P4: so are the y/alive gathers into sorted space.
    # With cfg.chunk_size the order is built from chunk-local sorted
    # runs (streaming tier) — bitwise identical, never sorts > a chunk.
    if x_orders is None:
        x1d = x if x.ndim == 2 else x[:, :, 0]
        x_orders = jax.vmap(lambda v: streaming.sort_order(
            v, cfg.chunk_size, cfg.domain_size))(x1d)
    y_sorted = jnp.take_along_axis(y, x_orders, axis=1)
    alive_sorted = jnp.take_along_axis(alive, x_orders, axis=1)
    return jax.lax.while_loop(
        cond, functools.partial(_round_body, cfg, cls, x, y, alive,
                                x_orders, y_sorted, alive_sorted), carry)


@functools.partial(jax.jit, static_argnames=("cfg", "cls", "num_rounds"))
def _boost_attempt_jit(x, y, alive, hits0, key, cfg, cls, num_rounds):
    return boost_attempt_arrays(x, y, alive, hits0, key, cfg, cls,
                                num_rounds)


def run_boost_attempt(x, y, alive, key, cfg: BoostConfig,
                      cls) -> BoostAttemptResult:
    """Host-facing single-process BoostAttempt on [k, mloc] shards."""
    m = int(jnp.sum(alive)) if not isinstance(alive, bool) else x.size
    num_rounds = cfg.num_rounds(max(m, 2))
    hits0 = W.init_hits(x.shape[:2])
    with obs_trace.span("boost_attempt", "attempt", m_alive=m,
                        bound=num_rounds) as sp, \
            obs_trace.annotate("boost_attempt"):
        out = _boost_attempt_jit(x, y, alive, hits0, key, cfg, cls,
                                 num_rounds)
        out = jax.device_get(out)
        if obs_trace.enabled():
            sp.update(rounds=int(out.t), stuck=bool(out.stuck))
    return BoostAttemptResult(
        stuck=bool(out.stuck), rounds=int(out.t),
        hypotheses=out.h_params,
        coreset_index=out.core_idx, coreset_x=out.core_x,
        coreset_y=out.core_y, min_mixture_loss=float(out.min_loss))


# ---------------------------------------------------------------------------
# shard_map production form: one player per device group along `data` axis.
# ---------------------------------------------------------------------------

def boost_attempt_sharded(mesh, cfg: BoostConfig, cls, num_rounds: int,
                          player_axes=("data",), no_center: bool = False):
    """Build the sharded BoostAttempt step.

    Returns a function (x, y, alive, hits, key) -> final carry where
    x/y/alive/hits are sharded [m_total(, F)] along ``player_axes`` and
    every device holds the replicated protocol outputs.  The coreset
    all_gather is the only cross-player communication per round — this
    IS the paper's message pattern on the wire.

    ``no_center=True`` implements the paper's §2.2 no-center model:
    player 0 plays the center — the coresets converge to it with a
    masked gather (psum of one-hot-placed contributions ≡ k→1 messages
    on a star-less topology), it alone runs the weak-learner ERM, and
    the chosen hypothesis is broadcast back (psum from player 0).  The
    default (False) emulates the center by an all_gather + replicated
    ERM, which is bit-equivalent on the wire model (every player
    receives the same coresets the center would).
    """
    axes = player_axes

    def per_device(x, y, alive, hits, key):
        # local shard plays one player; reconstruct the [1, mloc] layout
        xl = x[None]
        yl, al, hl = y[None], alive[None], hits[None]
        # §Perf P1: the domain points are loop-invariant — sort once
        # outside the round loop instead of inside every coreset build
        # (chunk-local runs under cfg.chunk_size, bitwise identical).
        x1d = xl[0] if xl.ndim == 2 else xl[0, :, 0]
        x_order = (streaming.sort_order(x1d, cfg.chunk_size,
                                        cfg.domain_size)
                   if cfg.deterministic_coreset else None)
        y_sorted = yl[0][x_order] if x_order is not None else None
        alive_sorted = al[0][x_order] if x_order is not None else None

        def round_body(carry):
            t, it, stuck, hitsl, kkey, h_params, last_loss = carry
            kkey, kc = jax.random.split(kkey)
            # identical key on all players is fine: sampling uses the
            # per-player fold below.
            pid = jax.lax.axis_index(axes)
            kp = jax.random.fold_in(kc, pid)
            idx = approximation.select_coreset(
                kp, x1d, yl[0],
                hitsl[0], al[0], cfg.coreset_size,
                cfg.deterministic_coreset and xl.ndim == 2,
                order=x_order, y_sorted=y_sorted,
                alive_sorted=alive_sorted)
            cx, cy = _gather_coreset(xl, yl, idx[None])
            log_wsum = W.log_weight_sum(hitsl[0], al[0])
            # --- the wire: gather tiny coresets + one scalar per player --
            cx_all = jax.lax.all_gather(cx[0], axes, tiled=False)
            cy_all = jax.lax.all_gather(cy[0], axes, tiled=False)
            ws_all = jax.lax.all_gather(log_wsum, axes, tiled=False)
            if isinstance(axes, tuple) and len(axes) > 1:
                cx_all = cx_all.reshape((-1,) + cx_all.shape[2:])
                cy_all = cy_all.reshape((-1,) + cy_all.shape[2:])
                ws_all = ws_all.reshape(-1)
            mix = W.mixture_weights(ws_all)
            if no_center:
                # Only player 0 (the acting center) runs the ERM; the
                # result is then broadcast from it.  lax.cond keeps the
                # non-center players' lane idle (the compiler still
                # schedules SPMD-uniformly, but the broadcast makes the
                # center's answer authoritative bit-for-bit).
                h0, loss0 = jax.lax.cond(
                    pid == 0,
                    lambda: _center_erm(cls, cx_all, cy_all, mix,
                                        cfg.coreset_size),
                    lambda: (jnp.zeros((weak.param_dim(cls),),
                                       jnp.float32),
                             jnp.float32(0)))
                h = jax.lax.psum(jnp.where(pid == 0, h0, 0.0), axes)
                loss = jax.lax.psum(jnp.where(pid == 0, loss0, 0.0),
                                    axes)
            else:
                h, loss = _center_erm(cls, cx_all, cy_all, mix,
                                      cfg.coreset_size)
            stuck_now = loss > cfg.weak_threshold
            pred = cls.predict(h, xl)
            new_hits = jnp.where(
                stuck_now, hitsl,
                W.update_hits(hitsl, pred == yl, al))
            h_params = h_params.at[t].set(
                jnp.where(stuck_now, h_params[t], h))
            return (jnp.where(stuck_now, t, t + 1), it + 1, stuck_now,
                    new_hits, kkey, h_params, loss)

        def cond(carry):
            t, it, stuck = carry[0], carry[1], carry[2]
            return (~stuck) & (t < num_rounds)

        carry0 = (jnp.int32(0), jnp.int32(0), jnp.asarray(False), hl, key,
                  jnp.zeros((num_rounds, weak.param_dim(cls)),
                            jnp.float32),
                  jnp.float32(0))
        t, it, stuck, hitsl, _, h_params, loss = jax.lax.while_loop(
            cond, round_body, carry0)
        return t, stuck, hitsl[0], h_params, loss

    in_specs = (P(*axes), P(*axes), P(*axes), P(*axes), P())
    out_specs = (P(), P(), P(*axes), P(), P())
    return _shard_map(per_device, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs)
