"""Mergeable streaming summaries — the large-m execution tier.

Every engine used to assume a task's m points fit one device-resident
buffer AND one monolithic sort: the per-player ``argsort(x)`` the
deterministic coreset hoists (§Perf P1) is the protocol's only
m-superlinear op, and XLA:CPU's variadic comparator sort is the cliff
the roadmap notes (``weak._sorted_prefix`` packs (x, idx) into one
int32 key to dodge it, but the pack needs ``n·m < 2³¹`` — dead at
m = 10⁶ on the default 2¹⁶ domain).  This module scales the data axis
with two constructions, both built from the same primitive — a
**chunk-local sorted summary** ``(x sorted ascending, original index)``
merged associatively:

1. :func:`sort_order` — the EXACT path.  Sort each ``chunk_size`` tile
   (each tile small enough for the packed single-operand fast sort),
   then merge pairs with a searchsorted/scatter two-pointer merge (no
   comparator sort anywhere).  Ties resolve lower-index-first at every
   level, so the result is **bitwise identical to the stable
   ``jnp.argsort``** — downstream (quantile levels, cumsums, coreset
   indices, hypotheses, ledgers) cannot tell the paths apart.  This is
   what ``BoostConfig.chunk_size`` switches on inside all three
   engines; parity is pinned in tests/test_streaming.py.

2. :class:`QuantileSketch` — the BOUNDED-MEMORY path.  A capacity-``cap``
   summary whose entries each represent a *segment* of the weighted
   point sequence (per-label masses ``wp``/``wn`` plus one genuine
   representative point per label); chunks enter via
   :func:`sketch_from_chunk`, merge via :func:`merge_sketches` (a
   two-pointer interleave — each side pays the other's segment
   granularity in rank error), and :func:`compress_sketch` folds
   mass-balanced buckets together, setting the granularity the next
   merge will charge.  :func:`build_sketch` arranges the merges in a
   logarithmic level buffer so the accumulated error is
   O(log(m/chunk) · W/cap), not O(m/chunk · W/cap).  The bound is
   **self-accounted**: like the communication ledger, the structure
   carries the price of every approximation it made, and
   :func:`coreset_bound` turns it into a sup-loss ε the pinned test
   (and the streaming benchmark gate) checks against the measured
   ``approximation.approximation_error``.

The sketch replaces the full-sample sort with O(m/chunk) chunk sorts
plus O(cap) state — one pass, transfer overlappable with
``repro.data.chunks.prefetch_to_device``.  The exact path keeps O(m)
state (the order itself is O(m)) but never materialises a sort larger
than ``chunk_size`` and never hits the comparator-sort cliff.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

# A chunk this size always fits the packed int32 single-operand sort
# for domains up to n = 2^16 (n·chunk < 2^31) — the default tile.
DEFAULT_CHUNK = 1 << 14


# ---------------------------------------------------------------------------
# The primitive: merge two sorted runs without a comparator sort.
# ---------------------------------------------------------------------------

def merge_sorted(xa, ia, xb, ib):
    """Merge two sorted summaries; ties place a-entries first.

    xa/xb ascending (each with its payload ia/ib in the same order) →
    (x, i) of length |a|+|b|, ascending, equal-x runs ordered a before
    b (and within each input, in input order).  When every a-index is
    smaller than every b-index — adjacent chunks merged in chunk order,
    the only way the callers below build runs — the tie rule equals
    global lower-index-first, i.e. the STABLE sort order.

    Implementation is two searchsorted rank computations + scatters
    (the classic parallel two-pointer merge): a[j] lands at
    ``j + rank_left(b, a[j])``, b[j] at ``j + rank_right(a, b[j])`` —
    all positions distinct by construction, no sort involved.
    """
    na, nb = xa.shape[0], xb.shape[0]
    pa = jnp.arange(na, dtype=jnp.int32) \
        + jnp.searchsorted(xb, xa, side="left").astype(jnp.int32)
    pb = jnp.arange(nb, dtype=jnp.int32) \
        + jnp.searchsorted(xa, xb, side="right").astype(jnp.int32)
    x = jnp.zeros((na + nb,), xa.dtype).at[pa].set(xa).at[pb].set(xb)
    i = jnp.zeros((na + nb,), ia.dtype).at[pa].set(ia).at[pb].set(ib)
    return x, i


def _chunk_order(xc, n: int | None):
    """Stable sort order of one chunk — packed single-operand fast path
    when the caller certifies an integer domain [0, n) that fits
    (``weak._sorted_prefix``'s trick, per tile instead of per shard)."""
    t = xc.shape[0]
    if (n is not None and 0 < n * t < 2 ** 31
            and jnp.issubdtype(xc.dtype, jnp.integer)):
        keys = xc.astype(jnp.int32) * t + jnp.arange(t, dtype=jnp.int32)
        keys_s = jnp.sort(keys)
        return keys_s % t
    return jnp.argsort(xc)


def chunk_runs(x, chunk_size: int, n: int | None = None):
    """Chunk-local sorted summaries of a 1-D array, in chunk order:
    list of (values ascending, original indices), one per tile."""
    m = x.shape[0]
    runs = []
    for s in range(0, m, chunk_size):
        xc = jax.lax.slice_in_dim(x, s, min(s + chunk_size, m))
        o = _chunk_order(xc, n)
        runs.append((xc[o], (o + s).astype(jnp.int32)))
    return runs


def merge_runs(runs):
    """Associative pairwise reduction of adjacent sorted runs (adjacency
    keeps the lower-index-first tie rule global — see merge_sorted)."""
    while len(runs) > 1:
        runs = [merge_sorted(*runs[i], *runs[i + 1])
                if i + 1 < len(runs) else runs[i]
                for i in range(0, len(runs), 2)]
    return runs[0]


def sort_order(x, chunk_size: int | None = None, n: int | None = None):
    """Stable argsort of a 1-D array, chunked when asked.

    ``chunk_size=None`` (or ≥ m) IS ``jnp.argsort(x)`` — the exact op
    the engines always ran, so the default path cannot drift.  With a
    chunk size, the order is built from chunk-local sorts + merges and
    is bitwise identical to the monolithic argsort (stable tie-breaking
    included); no sort larger than ``chunk_size`` ever runs, and each
    tile takes the packed int32 fast path when ``n`` (the domain size)
    certifies ``n·chunk_size < 2³¹``.  vmap-safe: everything is
    searchsorted/gather/scatter with static shapes.
    """
    m = x.shape[0]
    if chunk_size is None or chunk_size >= m:
        return jnp.argsort(x)
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be ≥ 1, got {chunk_size}")
    return merge_runs(chunk_runs(x, chunk_size, n))[1]


# ---------------------------------------------------------------------------
# Bounded-memory quantile-coreset sketch.
# ---------------------------------------------------------------------------

class QuantileSketch(NamedTuple):
    """Capacity-bounded mergeable summary of a weighted labelled sample.

    Entry j represents a contiguous *segment* of the x-sorted sample:
    ``x[j]`` is the segment's last member (the merge ordering key),
    ``wp[j]``/``wn[j]`` its total positive/negative label mass, and
    ``ip[j]``/``i_n[j]`` the global indices of a genuinely-positive /
    genuinely-negative member whose per-label rank equals the segment
    end's cumulative label mass (−1 while the label hasn't appeared) —
    a folded segment mixes labels, so one representative per label is
    the only way a selection can promise the label it ships.

    The error state is the sketch's self-accounting (the ledger ethos:
    carry the exact price of every approximation made):

    * ``err_p``/``err_n`` — how far any entry's recorded cumulative
      label mass may sit from its true rank.  Zero for fresh chunks;
      **merging adds the partner's granularity** (a folded segment of
      one sketch is attributed wholesale at its key's position among
      the other's entries, misplacing at most one segment's mass —
      ``max(err_a + gran_b, err_b + gran_a)``), compression adds
      nothing (kept entries keep their cumulative masses).
    * ``gran_p``/``gran_n`` — the largest per-label segment mass: the
      gap between a quantile level and the first entry at-or-past it.
      Zero while segments are single points; set by compression.

    A selected representative's true label rank is within
    ``err + gran`` of its quantile level — :func:`coreset_bound` turns
    that into the sup-loss ε the pinned test checks.
    """

    x: jax.Array       # [cap] segment-end points, ascending (merge key)
    wp: jax.Array      # [cap] f32 segment mass with label +1
    wn: jax.Array      # [cap] f32 segment mass with label −1
    ip: jax.Array      # [cap] int32 positive representative (−1 = none)
    i_n: jax.Array     # [cap] int32 negative representative (−1 = none)
    err_p: jax.Array   # f32 — rank-error bound, positive mass
    err_n: jax.Array   # f32 — rank-error bound, negative mass
    gran_p: jax.Array  # f32 — max positive segment mass
    gran_n: jax.Array  # f32 — max negative segment mass


def sketch_weights(hits, alive):
    """The engines' unnormalised MW weights (quantile levels are
    scale-free): 2^{−(hits−min alive hits)}, 0 on dead rows — the same
    max-shifted form ``approximation.quantile_coreset`` uses."""
    hmin = jnp.min(jnp.where(alive, hits, jnp.iinfo(hits.dtype).max))
    shift = jnp.clip((hits - hmin).astype(jnp.float32), 0.0, 126.0)
    return jnp.where(alive, jnp.exp2(-shift), 0.0)


def _rep_floor(dtype):
    """Sentinel ordering key for an absent representative — below every
    real point so a forward-fill max never picks it."""
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.array(jnp.iinfo(dtype).min, dtype)
    return jnp.array(-jnp.inf, dtype)


def _ffill_max(xv, iv):
    """Running max-by-key forward fill: position e gets the (key,
    payload) pair with the largest key among entries ≤ e (ties → the
    later entry).  Turns per-entry label representatives into
    last-seen-label-point-so-far — the refresh every merge needs so a
    segment's representative never goes stale behind interleaved mass
    from the partner sketch."""
    def op(a, b):
        ax, ai = a
        bx, bi = b
        take_b = bx >= ax
        return (jnp.where(take_b, bx, ax), jnp.where(take_b, bi, ai))
    return jax.lax.associative_scan(op, (xv, iv))


def sketch_from_chunk(x, y, w, start,
                      n: int | None = None) -> QuantileSketch:
    """Exact single-point-segment sketch of one chunk.

    x [t] points, y [t] ±1 labels, w [t] ≥ 0 weights; ``start`` is the
    chunk's offset in the global sample (indices are global; pass it as
    an array so one compiled program serves every chunk).  The chunk is
    sorted locally (fast path under the same ``n`` certificate as
    :func:`sort_order`) — err and gran are zero: every segment is one
    point and every cumulative mass exact.
    """
    o = _chunk_order(x, n)
    xs = x[o]
    ws = w[o]
    pos = y[o] > 0
    gi = (o + jnp.asarray(start, jnp.int32)).astype(jnp.int32)
    floor = _rep_floor(xs.dtype)
    _, ip = _ffill_max(jnp.where(pos, xs, floor),
                       jnp.where(pos, gi, -1))
    _, i_n = _ffill_max(jnp.where(pos, floor, xs),
                        jnp.where(pos, -1, gi))
    zero = jnp.float32(0)
    return QuantileSketch(
        x=xs,
        wp=jnp.where(pos, ws, 0.0), wn=jnp.where(pos, 0.0, ws),
        ip=ip, i_n=i_n,
        err_p=zero, err_n=zero, gran_p=zero, gran_n=zero)


def merge_sketches(a: QuantileSketch, b: QuantileSketch) -> QuantileSketch:
    """Associative merge: interleave the segment lists by key (two-
    pointer merge, no sort) and refresh representatives.

    The price of merging FOLDED sketches: a segment is attributed
    wholesale at its key, but its members spread down to the previous
    key, so each side's cumulative masses pick up rank error bounded by
    the *other* side's segment granularity (at most one partner segment
    straddles any point):

        err_label := err_a + err_b + gran_a + gran_b

    Merging exact sketches (gran 0) is free — the textbook mergeable-
    summary law, priced per label from the actual operands.
    Representatives are re-forward-filled across the interleaved list
    so each entry points at the last known point of its label, which is
    what keeps the selection's shipped label honest."""
    na, nb = a.x.shape[0], b.x.shape[0]
    x, j = merge_sorted(a.x, jnp.arange(na, dtype=jnp.int32),
                        b.x, jnp.arange(nb, dtype=jnp.int32) + na)

    def pick(fa, fb):
        return jnp.concatenate([fa, fb])[j]

    wp, wn = pick(a.wp, b.wp), pick(a.wn, b.wn)
    floor = _rep_floor(x.dtype)
    # Representative keys: a rep is a real point ≤ its segment key, so
    # the segment key upper-bounds it; forward-filling with the key as
    # ordering proxy keeps "latest label point at-or-before here".
    _, ip = _ffill_max(jnp.where(pick(a.ip, b.ip) >= 0, x, floor),
                       pick(a.ip, b.ip))
    _, i_n = _ffill_max(jnp.where(pick(a.i_n, b.i_n) >= 0, x, floor),
                        pick(a.i_n, b.i_n))
    return QuantileSketch(
        x=x, wp=wp, wn=wn, ip=ip, i_n=i_n,
        err_p=a.err_p + b.err_p + a.gran_p + b.gran_p,
        err_n=a.err_n + b.err_n + a.gran_n + b.gran_n,
        gran_p=jnp.maximum(a.gran_p, b.gran_p),
        gran_n=jnp.maximum(a.gran_n, b.gran_n))


def compress_sketch(s: QuantileSketch, cap: int) -> QuantileSketch:
    """Fold a sketch down to ``cap`` segments, paying the exact price.

    Buckets are MASS-balanced, not index-balanced: bucket j ends at the
    first entry whose cumulative total mass reaches ``(j+1)·W/cap``, so
    a bucket's mass is ≤ W/cap + one entry's mass even under the
    protocol's exponentially skewed MW weights (index-uniform buckets
    degrade with skew — measured, not guessed).  Each bucket folds to
    one segment at its LAST entry, keeping that entry's cumulative
    masses (compression does NOT move err) and its forward-filled
    per-label representatives.  What it does move is GRANULARITY — the
    largest per-label segment mass, the gap a quantile query can land
    inside and the misattribution the next merge will charge:

        gran_label := max_j bucket_mass_label(j)

    — accumulated numerically from the masses actually folded, not a
    formula: the bound is exact for the compression that actually
    happened.  No-op when the sketch already fits."""
    m = s.x.shape[0]
    if m <= cap:
        return s
    cwp = jnp.cumsum(s.wp)
    cwn = jnp.cumsum(s.wn)
    cw = cwp + cwn
    levels = (jnp.arange(1, cap + 1, dtype=jnp.float32) / cap) * cw[-1]
    ends = jnp.clip(jnp.searchsorted(cw, levels, side="left"), 0, m - 1)
    ends = ends.at[-1].set(m - 1)          # total mass is always kept
    seg_wp = jnp.diff(cwp[ends], prepend=0.0)
    seg_wn = jnp.diff(cwn[ends], prepend=0.0)
    return QuantileSketch(
        x=s.x[ends], wp=seg_wp, wn=seg_wn,
        ip=s.ip[ends], i_n=s.i_n[ends],
        err_p=s.err_p, err_n=s.err_n,
        gran_p=jnp.maximum(s.gran_p, jnp.max(seg_wp)),
        gran_n=jnp.maximum(s.gran_n, jnp.max(seg_wn)))


@partial(jax.jit, static_argnames="cap")
def _merge_compress(a: QuantileSketch, b: QuantileSketch,
                    cap: int) -> QuantileSketch:
    return compress_sketch(merge_sketches(a, b), cap)


def build_sketch(chunks, cap: int, n: int | None = None) -> QuantileSketch:
    """One-pass bounded-memory sketch of a chunked stream.

    ``chunks`` yields (x [t], y [t], w [t], start) tuples in index
    order (see ``repro.data.chunks`` for the double-buffered device
    feed).  Merges are arranged in a LOGARITHMIC level buffer (the
    classic mergeable-summary schedule): level ℓ holds at most one
    sketch covering 2^ℓ chunks, and two same-level sketches merge and
    promote.  Each merge charges the operands' granularity, so error
    accumulates like the merge-tree DEPTH — O(log(m/chunk) · W/cap) —
    instead of once per chunk; state never exceeds
    O(cap · log(m/chunk)) entries.  Returns the compressed sketch —
    its err/gran fields price everything that happened.
    """
    levels: list[QuantileSketch | None] = []
    seen = False
    for x, y, w, start in chunks:
        seen = True
        s = sketch_from_chunk(jnp.asarray(x), jnp.asarray(y),
                              jnp.asarray(w), start, n)
        s = compress_sketch(s, cap)
        i = 0
        while i < len(levels) and levels[i] is not None:
            s = _merge_compress(levels[i], s, cap)   # older side first
            levels[i] = None
            i += 1
        if i == len(levels):
            levels.append(s)
        else:
            levels[i] = s
    if not seen:
        raise ValueError("empty chunk stream")
    acc = None
    for s in reversed(levels):                       # oldest level first
        if s is None:
            continue
        acc = s if acc is None else merge_sketches(acc, s)
    return compress_sketch(acc, cap)


def sketch_coreset(s: QuantileSketch, c: int) -> jax.Array:
    """[c] global indices — ``approximation.quantile_coreset``'s
    per-label weighted-quantile selection, run on sketch segments.

    Same construction, same float ops: allocate c± ∝ W± slots, take
    mass-quantile levels (j+½)/c± within each label, searchsorted into
    the per-label cumulative masses, ship the landing segment's
    representative OF THAT LABEL.  On an uncompressed sketch of the
    whole sample this selects exactly the monolithic coreset's indices
    (pinned in tests/test_streaming.py); on a compressed one each
    selected point's label rank is within the self-accounted
    ``err + gran`` of its level."""
    cum = jnp.cumsum(jnp.stack([s.wp, s.wn]), axis=-1)      # [2, cap]
    w_pos, w_neg = cum[0, -1], cum[1, -1]
    has_pos = w_pos > 1e-12
    has_neg = w_neg > 1e-12
    c_pos = jnp.round(c * w_pos
                      / jnp.maximum(w_pos + w_neg, 1e-30)).astype(jnp.int32)
    c_pos = jnp.clip(c_pos, jnp.where(has_pos, 1, 0),
                     c - jnp.where(has_neg, 1, 0))
    j = jnp.arange(c, dtype=jnp.float32)
    c_posf = jnp.maximum(c_pos.astype(jnp.float32), 1.0)
    c_negf = jnp.maximum((c - c_pos).astype(jnp.float32), 1.0)
    lvls = jnp.stack([(j + 0.5) * w_pos / c_posf,
                      (j - c_posf + 0.5) * w_neg / c_negf])  # [2, c]
    i2 = jnp.clip(jax.vmap(jnp.searchsorted)(cum, lvls), 0,
                  s.x.shape[0] - 1)
    pos_sel = jnp.arange(c, dtype=jnp.int32) < c_pos
    return jnp.where(pos_sel, s.ip[i2[0]], s.i_n[i2[1]])


def coreset_bound(s: QuantileSketch, c: int) -> jax.Array:
    """Sup-loss ε the sketch guarantees for a size-c coreset.

    The monolithic per-label quantile coreset has discrepancy ≤ 2/c per
    label class (≤ 4/c total, the ``approximation.quantile_coreset``
    analysis); on a sketch each selected point's label rank sits within
    ``err + gran`` of its quantile level, adding ≤ 2·(err+gran)/W per
    class.  The streaming benchmark and the pinned ε test check the
    MEASURED ``approximation.approximation_error`` against this."""
    w_pos = jnp.sum(s.wp)
    w_neg = jnp.sum(s.wn)
    rel = ((s.err_p + s.gran_p) / jnp.maximum(w_pos, 1e-30)
           + (s.err_n + s.gran_n) / jnp.maximum(w_neg, 1e-30))
    return 4.0 / c + 2.0 * rel
