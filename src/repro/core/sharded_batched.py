"""Mesh-sharded batched AccuratelyClassify — k players as device shards.

`core/batched.py` runs B tasks in one jitted program, but it still
*simulates* the k players inside a single device: the "coreset
transmission" of step 2(a) is a vmap lane, not a message.  This module
runs the identical protocol over a real device mesh with a ``players``
axis: each device holds only its players' shards of every task, the
per-round coreset and weight-sum exchange is an actual
``lax.all_gather`` (the star topology's k → center messages), the alive
count is a ``lax.psum``, and the §2.2 no-center variant broadcasts the
acting center's hypothesis back with a ``psum`` — so the bytes the
communication ledger charges correspond to payloads that really cross
device boundaries.

Two properties are load-bearing and tested (tests/test_sharded_batched):

* **Bit-identical parity.**  Given the same per-task keys, every output
  (hypotheses, quarantine masks, stuck/round/alive histories, ledger
  bit counts) equals `core/batched.py`'s exactly.  This holds by
  construction: the per-player steps (coreset selection, weight sums,
  MW updates) touch only local rows, the pooled arrays entering the
  center ERM are reassembled in player order by the all_gather, and
  integer/float op order is unchanged — a player living on another
  device computes the same row it computed as a vmap lane.

* **Ledger ≡ payload.**  The engine counts, *at the collective sites*,
  how many coreset examples and weight-sum scalars each attempt
  gathered (increments are taken from the gathered arrays' shapes, so
  the counter moves iff the collective executes, by its payload size).
  ``validate_ledger`` then checks the Theorem 4.1 accounting against
  those measured counts: ledger coreset bits = gathered examples ×
  ``example_bits(n)``, ledger weight-sum bits = per-attempt gathered
  scalars × ``weight_sum_bits(m_alive, T)``, quarantine messages =
  k·P per stuck attempt.  The accounting is validated by construction,
  not by trust.

The mesh's ``players`` axis size p must divide k; each device then
hosts kloc = k/p players (p = k is one player per device).  On a
single-device host the same program runs with p = 1 — the collectives
still execute (over an axis of size 1), so the wire accounting and the
program structure are identical, only the transport is trivial.  Use
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to simulate an
N-device CPU mesh (see TESTING.md).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import approximation, batched, classify, ledger as L, weak
from repro.core import weights as W
from repro.core.boost_attempt import _center_erm, _gather_coreset, _shard_map
from repro.core.types import BoostConfig

AXIS = "players"


def make_players_mesh(k: int, devices=None) -> Mesh:
    """A 1-axis ``players`` mesh of p devices, p = the largest divisor
    of k the host can supply (p = 1 degenerates to the local engine,
    p = k is one player per device)."""
    devices = list(jax.devices() if devices is None else devices)
    p = max(d for d in range(1, min(k, len(devices)) + 1) if k % d == 0)
    return Mesh(np.asarray(devices[:p]), (AXIS,))


class _RoundCarry(NamedTuple):
    t: jax.Array            # hypotheses produced so far
    it: jax.Array           # loop iterations (wire rounds)
    stuck: jax.Array
    hits: jax.Array         # [kloc, mloc] — local players only
    key: jax.Array
    h_params: jax.Array     # [t_buf, 4] replicated
    core_x: jax.Array       # [k, c(, F)] pooled coreset (all_gather output)
    core_y: jax.Array       # [k, c]
    min_loss: jax.Array
    wire_core: jax.Array    # int32 — coreset examples gathered this attempt
    wire_ws: jax.Array      # int32 — weight-sum scalars gathered this attempt
    wire_bytes: jax.Array   # int32 — machine bytes of those collectives


class _TaskCarry(NamedTuple):
    attempt: jax.Array
    done: jax.Array
    alive: jax.Array        # [kloc, mloc]
    disputed: jax.Array     # [kloc, mloc]
    key: jax.Array
    h_params: jax.Array
    rounds: jax.Array
    min_loss: jax.Array
    hist_stuck: jax.Array   # [A]
    hist_rounds: jax.Array  # [A]
    hist_alive: jax.Array   # [A]
    hist_p: jax.Array       # [A]
    hist_wire_core: jax.Array   # [A] per-attempt gathered coreset examples
    hist_wire_ws: jax.Array     # [A] per-attempt gathered weight-sum scalars
    wire_bytes: jax.Array       # total collective payload, machine bytes
    wire_q_points: jax.Array    # quarantine point-set messages (k·P total)
    wire_q_counts: jax.Array    # quarantine count reports (k·P total)


def _slice_player_keys(keys_all: jax.Array, kloc: int) -> jax.Array:
    """This device's kloc keys out of the k per-player keys — sliced on
    the raw key data because dynamic_slice on typed keys is flaky on the
    pinned 0.4.x toolchain."""
    pid = jax.lax.axis_index(AXIS)
    data = jax.random.key_data(keys_all)                  # [k, key_words]
    loc = jax.lax.dynamic_slice_in_dim(data, pid * kloc, kloc, axis=0)
    return jax.random.wrap_key_data(loc)


def _round_body(cfg: BoostConfig, cls, k: int, x, y, alive, x_orders,
                y_sorted, alive_sorted, no_center: bool,
                c: _RoundCarry) -> _RoundCarry:
    # LOCKSTEP: this is boost_attempt._round_body with the vmap-lane
    # pooling replaced by collectives (and _attempt_body below mirrors
    # batched._attempt_body the same way).  Any semantic change to the
    # round/attempt bodies there must land here too — the exact-parity
    # tests (tests/test_sharded_batched.py) fail on any divergence.
    kloc = x.shape[0]
    key, kc = jax.random.split(c.key)
    keys_all = jax.random.split(kc, k)    # the host loop's k-key stream
    keys = _slice_player_keys(keys_all, kloc)
    # --- players (local rows only): step 2(a) coreset + 2(b) sums ------
    idx = jax.vmap(
        lambda kk, xx, yy, hh, aa, oo, yso, aso:
        approximation.select_coreset(
            kk, xx if xx.ndim == 1 else xx[:, 0], yy, hh, aa,
            cfg.coreset_size, cfg.deterministic_coreset and x.ndim == 2,
            order=oo, y_sorted=yso, alive_sorted=aso)
    )(keys, x, y, c.hits, alive, x_orders, y_sorted, alive_sorted)
    cx, cy = _gather_coreset(x, y, idx)                   # [kloc, c(, F)]
    log_wsums = jax.vmap(W.log_weight_sum)(c.hits, alive)  # [kloc]
    # --- the wire: every player's coreset + one scalar to the center ---
    cx_all = jax.lax.all_gather(cx, AXIS)                 # [p, kloc, c(, F)]
    cy_all = jax.lax.all_gather(cy, AXIS)
    ws_all = jax.lax.all_gather(log_wsums, AXIS)          # [p, kloc]
    # payload counters, taken from the gathered arrays themselves so
    # they move iff the collective executed, by its actual size
    n_examples = int(np.prod(cy_all.shape))               # k · c, exactly
    n_scalars = int(np.prod(ws_all.shape))                # k
    n_bytes = (cx_all.size * cx_all.dtype.itemsize
               + cy_all.size * cy_all.dtype.itemsize
               + ws_all.size * ws_all.dtype.itemsize)
    cx_all = cx_all.reshape((k,) + cx_all.shape[2:])      # player order
    cy_all = cy_all.reshape((k,) + cy_all.shape[2:])
    ws_all = ws_all.reshape(-1)
    mix = W.mixture_weights(ws_all)
    # --- center: step 2(c)+(d) pooled weighted ERM ----------------------
    if no_center:
        # §2.2: the first device acts as center; only it runs the ERM and
        # the result is psum-broadcast back (exact: all other summands
        # are literal zeros).
        pid = jax.lax.axis_index(AXIS)
        h0, loss0 = jax.lax.cond(
            pid == 0,
            lambda: _center_erm(cls, cx_all, cy_all, mix, cfg.coreset_size),
            lambda: (jnp.zeros((weak.PARAM_DIM,), jnp.float32),
                     jnp.float32(0)))
        h = jax.lax.psum(jnp.where(pid == 0, h0, 0.0), AXIS)
        loss = jax.lax.psum(jnp.where(pid == 0, loss0, 0.0), AXIS)
    else:
        h, loss = _center_erm(cls, cx_all, cy_all, mix, cfg.coreset_size)
    stuck_now = loss > cfg.weak_threshold
    # --- players: step 2(f) multiplicative-weights update (local) ------
    pred = cls.predict(h, x)
    new_hits = jnp.where(stuck_now, c.hits,
                         W.update_hits(c.hits, pred == y, alive))
    h_params = c.h_params.at[c.t].set(
        jnp.where(stuck_now, c.h_params[c.t], h))
    return _RoundCarry(
        t=jnp.where(stuck_now, c.t, c.t + 1),
        it=c.it + 1,
        stuck=stuck_now,
        hits=new_hits,
        key=key,
        h_params=h_params,
        core_x=cx_all, core_y=cy_all,
        min_loss=loss,
        wire_core=c.wire_core + n_examples,
        wire_ws=c.wire_ws + n_scalars,
        wire_bytes=c.wire_bytes + n_bytes,
    )


def _attempt_body(cfg: BoostConfig, cls, k: int, x, y, x_orders,
                  t_buf: int, no_center: bool,
                  c: _TaskCarry) -> _TaskCarry:
    kloc, mloc = x.shape[0], x.shape[1]
    key, sub = jax.random.split(c.key)
    m_alive = jax.lax.psum(jnp.sum(c.alive.astype(jnp.int32)), AXIS)
    bound = batched.num_rounds_dynamic(cfg, m_alive)
    # per-attempt sorted gathers (alive changes between attempts)
    y_sorted = jnp.take_along_axis(y, x_orders, axis=1)
    alive_sorted = jnp.take_along_axis(c.alive, x_orders, axis=1)
    rc0 = _RoundCarry(
        t=jnp.int32(0), it=jnp.int32(0), stuck=jnp.asarray(False),
        hits=W.init_hits((kloc, mloc)), key=sub,
        h_params=jnp.zeros((t_buf, weak.PARAM_DIM), jnp.float32),
        core_x=jnp.zeros((k, cfg.coreset_size) + x.shape[2:], x.dtype),
        core_y=jnp.zeros((k, cfg.coreset_size), y.dtype),
        min_loss=jnp.float32(0),
        wire_core=jnp.int32(0), wire_ws=jnp.int32(0),
        wire_bytes=jnp.int32(0),
    )

    def cond(rc: _RoundCarry):
        return (~rc.stuck) & (rc.t < bound)

    out = jax.lax.while_loop(
        cond,
        functools.partial(_round_body, cfg, cls, k, x, y, c.alive,
                          x_orders, y_sorted, alive_sorted, no_center),
        rc0)
    stuck = out.stuck
    # ---- full-point quarantine: the pooled stuck coreset is replicated
    # (it is the all_gather output), each device kills its local copies.
    core_flat = out.core_x.reshape((-1,) + out.core_x.shape[2:])
    dead_new = c.alive & classify.match_points(x, core_flat) & stuck
    p_count = jnp.where(stuck, classify.distinct_count(core_flat), 0)
    a = c.attempt
    return _TaskCarry(
        attempt=a + 1,
        done=~stuck,
        alive=c.alive & ~dead_new,
        disputed=c.disputed | dead_new,
        key=key,
        h_params=jnp.where(stuck, c.h_params, out.h_params),
        rounds=jnp.where(stuck, c.rounds, out.t),
        min_loss=out.min_loss,
        hist_stuck=c.hist_stuck.at[a].set(stuck),
        hist_rounds=c.hist_rounds.at[a].set(out.t),
        hist_alive=c.hist_alive.at[a].set(m_alive),
        hist_p=c.hist_p.at[a].set(p_count),
        hist_wire_core=c.hist_wire_core.at[a].set(out.wire_core),
        hist_wire_ws=c.hist_wire_ws.at[a].set(out.wire_ws),
        wire_bytes=c.wire_bytes + out.wire_bytes,
        wire_q_points=c.wire_q_points + k * p_count,
        wire_q_counts=c.wire_q_counts + k * p_count,
    )


def _classify_one_sharded(x, y, alive0, key, cfg: BoostConfig, cls,
                          k: int, t_buf: int,
                          no_center: bool) -> _TaskCarry:
    """One task's whole protocol on this device's [kloc, mloc] shard.
    vmap-ed over the leading task axis inside shard_map."""
    a_max = cfg.opt_budget + 1
    x1d = x if x.ndim == 2 else x[:, :, 0]
    x_orders = jax.vmap(jnp.argsort)(x1d)
    carry = _TaskCarry(
        attempt=jnp.int32(0), done=jnp.asarray(False),
        alive=alive0, disputed=jnp.zeros_like(alive0),
        key=key,
        h_params=jnp.zeros((t_buf, weak.PARAM_DIM), jnp.float32),
        rounds=jnp.int32(0), min_loss=jnp.float32(0),
        hist_stuck=jnp.zeros((a_max,), bool),
        hist_rounds=jnp.zeros((a_max,), jnp.int32),
        hist_alive=jnp.zeros((a_max,), jnp.int32),
        hist_p=jnp.zeros((a_max,), jnp.int32),
        hist_wire_core=jnp.zeros((a_max,), jnp.int32),
        hist_wire_ws=jnp.zeros((a_max,), jnp.int32),
        wire_bytes=jnp.int32(0),
        wire_q_points=jnp.int32(0), wire_q_counts=jnp.int32(0),
    )

    def cond(cy: _TaskCarry):
        return (~cy.done) & (cy.attempt < a_max)

    return jax.lax.while_loop(
        cond,
        functools.partial(_attempt_body, cfg, cls, k, x, y, x_orders,
                          t_buf, no_center),
        carry)


@functools.lru_cache(maxsize=None)
def _build_sharded(mesh: Mesh, cfg: BoostConfig, cls, t_buf: int,
                   no_center: bool):
    k = cfg.k
    p = mesh.shape[AXIS]
    if k % p != 0:
        raise ValueError(f"players mesh size {p} must divide k={k}")

    def per_device(x, y, alive, keys):
        one = functools.partial(_classify_one_sharded, cfg=cfg, cls=cls,
                                k=k, t_buf=t_buf, no_center=no_center)
        out = jax.vmap(one)(x, y, alive, keys)
        return {
            "attempt": out.attempt, "done": out.done,
            "alive": out.alive, "disputed": out.disputed,
            "h_params": out.h_params, "rounds": out.rounds,
            "min_loss": out.min_loss,
            "hist_stuck": out.hist_stuck, "hist_rounds": out.hist_rounds,
            "hist_alive": out.hist_alive, "hist_p": out.hist_p,
            "hist_wire_core": out.hist_wire_core,
            "hist_wire_ws": out.hist_wire_ws,
            "wire_bytes": out.wire_bytes,
            "wire_q_points": out.wire_q_points,
            "wire_q_counts": out.wire_q_counts,
        }

    sharded = P(None, AXIS)
    in_specs = (sharded, sharded, sharded, P())
    out_specs = {
        "attempt": P(), "done": P(), "alive": sharded,
        "disputed": sharded, "h_params": P(), "rounds": P(),
        "min_loss": P(), "hist_stuck": P(), "hist_rounds": P(),
        "hist_alive": P(), "hist_p": P(), "hist_wire_core": P(),
        "hist_wire_ws": P(), "wire_bytes": P(), "wire_q_points": P(),
        "wire_q_counts": P(),
    }
    return jax.jit(_shard_map(per_device, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs))


def lower_classify_sharded(x, y, alive, keys, cfg: BoostConfig, cls,
                           mesh: Mesh, no_center: bool = False):
    """AOT-compile the sharded engine for one input signature (the
    mesh-collective twin of ``batched.lower_classify``).  The returned
    executable is owned by the caller — a serving compile cache reuses
    it across admissions and dropping it really frees the program."""
    t_buf = cfg.num_rounds(x.shape[1] * x.shape[2])
    fn = _build_sharded(mesh, cfg, cls, t_buf, no_center)
    return fn.lower(jnp.asarray(x), jnp.asarray(y), jnp.asarray(alive),
                    keys).compile()


@dataclasses.dataclass
class ShardedClassifyResult(batched.BatchedClassifyResult):
    """BatchedClassifyResult + the measured collective payloads.

    ``per_task``, ``classifier`` and ``ledger`` are inherited unchanged
    (the protocol state is bit-identical to the local batched engine);
    the wire_* fields record what the collectives actually moved.
    """

    hist_wire_core: np.ndarray = None   # [B, A] coreset examples gathered
    hist_wire_ws: np.ndarray = None     # [B, A] weight-sum scalars gathered
    wire_bytes: np.ndarray = None       # [B] machine bytes of collectives
    wire_q_points: np.ndarray = None    # [B] quarantine point messages
    wire_q_counts: np.ndarray = None    # [B] quarantine count reports
    mesh_devices: int = 1

    def wire_summary(self, b: int) -> dict:
        return {
            "coreset_examples": int(self.hist_wire_core[b].sum()),
            "weight_sum_scalars": int(self.hist_wire_ws[b].sum()),
            "collective_bytes": int(self.wire_bytes[b]),
            "quarantine_point_msgs": int(self.wire_q_points[b]),
            "quarantine_count_msgs": int(self.wire_q_counts[b]),
            "mesh_devices": int(self.mesh_devices),
        }

    def validate_ledger(self, b: int) -> dict:
        """Cross-check Theorem 4.1 accounting against measured payloads.

        Raises AssertionError on any mismatch; returns the comparison.
        Checks, per task:
        * ledger coreset bits == gathered examples × example_bits(n);
        * ledger weight-sum bits == Σ_attempts gathered scalars ×
          weight_sum_bits(m_alive, T) with per-attempt m_alive;
        * per attempt, gathered payload == wire_rounds · k · c examples
          and wire_rounds · k scalars (the protocol's message pattern);
        * quarantine messages == k · Σ P over stuck attempts.
        """
        cfg, cls = self.cfg, self.cls
        n = L.domain_size(cls)
        led = self.ledger(b)
        n_att = int(self.attempts[b])
        got_core = int(self.hist_wire_core[b, :n_att].sum())
        got_ws = int(self.hist_wire_ws[b, :n_att].sum())
        exp_ws_bits = 0
        for a in range(n_att):
            wire_rounds = int(self.hist_rounds[b, a]) \
                + (1 if self.hist_stuck[b, a] else 0)
            assert int(self.hist_wire_core[b, a]) == \
                wire_rounds * cfg.k * cfg.coreset_size, (b, a)
            assert int(self.hist_wire_ws[b, a]) == wire_rounds * cfg.k, \
                (b, a)
            m_a = max(int(self.hist_alive[b, a]), 2)
            exp_ws_bits += int(self.hist_wire_ws[b, a]) \
                * L.weight_sum_bits(m_a, cfg.num_rounds(m_a))
        assert led.bits_coresets == got_core * L.example_bits(n), (
            led.bits_coresets, got_core)
        assert led.bits_weight_sums == exp_ws_bits, (
            led.bits_weight_sums, exp_ws_bits)
        p_total = int(self.hist_p[b, :n_att][
            np.asarray(self.hist_stuck[b, :n_att], bool)].sum())
        assert int(self.wire_q_points[b]) == cfg.k * p_total
        assert int(self.wire_q_counts[b]) == cfg.k * p_total
        return {
            "bits_coresets": led.bits_coresets,
            "coreset_examples_gathered": got_core,
            "bits_weight_sums": led.bits_weight_sums,
            "weight_sum_scalars_gathered": got_ws,
            "quarantine_msgs": int(self.wire_q_points[b]),
            "collective_bytes": int(self.wire_bytes[b]),
        }


def run_accurately_classify_sharded(x, y, keys, cfg: BoostConfig, cls,
                                    mesh: Mesh | None = None, alive=None,
                                    no_center: bool = False,
                                    compiled=None, m_true=None,
                                    ) -> ShardedClassifyResult:
    """B-task AccuratelyClassify over a real ``players`` device mesh.

    Same contract as ``batched.run_accurately_classify_batched`` (and
    bit-identical outputs on identical inputs); ``mesh`` defaults to
    ``make_players_mesh(k)`` over the host's devices.
    """
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    B, k, mloc = x.shape[0], x.shape[1], x.shape[2]
    if k != cfg.k:
        raise ValueError(f"x has {k} players but cfg.k={cfg.k}")
    keys = jnp.asarray(keys)
    if keys.ndim == 0:
        keys = jax.random.split(keys, B)
    if keys.shape[0] != B:
        raise ValueError(f"need {B} task keys, got shape {keys.shape}")
    if alive is None:
        alive = jnp.ones((B, k, mloc), bool)
    else:
        alive = jnp.asarray(alive)
    if mesh is None:
        mesh = make_players_mesh(k)
    if compiled is not None:
        out = jax.device_get(compiled(x, y, alive, keys))
    else:
        t_buf = cfg.num_rounds(k * mloc)
        fn = _build_sharded(mesh, cfg, cls, t_buf, no_center)
        out = jax.device_get(fn(x, y, alive, keys))
    return ShardedClassifyResult(
        hypotheses=out["h_params"], rounds=out["rounds"],
        ok=np.asarray(out["done"]), attempts=out["attempt"],
        alive=out["alive"], disputed=out["disputed"],
        min_loss=out["min_loss"],
        hist_stuck=out["hist_stuck"], hist_rounds=out["hist_rounds"],
        hist_alive=out["hist_alive"], hist_p=out["hist_p"],
        x=np.asarray(x), y=np.asarray(y), alive0=np.asarray(alive),
        cfg=cfg, cls=cls,
        m_true=None if m_true is None else np.asarray(m_true),
        hist_wire_core=out["hist_wire_core"],
        hist_wire_ws=out["hist_wire_ws"],
        wire_bytes=out["wire_bytes"],
        wire_q_points=out["wire_q_points"],
        wire_q_counts=out["wire_q_counts"],
        mesh_devices=mesh.shape[AXIS])
