"""Mesh-sharded batched AccuratelyClassify — k players as device shards.

`core/batched.py` runs B tasks in one jitted program, but it still
*simulates* the k players inside a single device: the "coreset
transmission" of step 2(a) is a vmap lane, not a message.  This module
runs the identical protocol over a real device mesh with a ``players``
axis: each device holds only its players' shards of every task, the
per-round coreset and weight-sum exchange is an actual
``lax.all_gather`` (the star topology's k → center messages), the alive
count is a ``lax.psum``, and the §2.2 no-center variant broadcasts the
acting center's hypothesis back with a ``psum`` — so the bytes the
communication ledger charges correspond to payloads that really cross
device boundaries.

Like the local engine, execution is **round-granular**
(:func:`init_state_sharded` / :func:`run_rounds_sharded` /
:func:`finalize_sharded`): one step is one BoostAttempt wire round,
attempt transitions happen inside the step body, and the state is a
plain dict of arrays — host-gatherable and msgpack-serializable, so a
preempted run resumes bit-identically from a checkpoint.  A per-round
``player_alive [k]`` schedule drives the infrastructure adversaries
(dropout / flaky / rejoin): an absent player's weight sum leaves the
mixture, its MW state freezes, its coreset rows are excluded from
quarantine, and — because the wire counters below are masked at the
collective sites — the ledger charges only payloads alive players
actually sent.

Two properties are load-bearing and tested (tests/test_sharded_batched):

* **Bit-identical parity.**  Given the same per-task keys and schedule,
  every output (hypotheses, quarantine masks, stuck/round/alive
  histories, ledger bit counts) equals `core/batched.py`'s exactly.
  This holds by construction: the per-player steps (coreset selection,
  weight sums, MW updates) touch only local rows, the pooled arrays
  entering the center ERM are reassembled in player order by the
  all_gather, and integer/float op order is unchanged — a player living
  on another device computes the same row it computed as a vmap lane.

* **Ledger ≡ payload.**  The engine counts, *at the collective sites*,
  how many coreset examples and weight-sum scalars each attempt
  gathered from players alive that round.  ``validate_ledger`` then
  checks the Theorem 4.1 accounting against those measured counts:
  ledger coreset bits = gathered examples × ``example_bits(n)``, ledger
  weight-sum bits = per-attempt gathered scalars ×
  ``weight_sum_bits(m_alive, T)``, quarantine messages = k_alive·P per
  stuck attempt.  The accounting is validated by construction, not by
  trust — with or without a dropout mask.

The mesh's ``players`` axis size p must divide k; each device then
hosts kloc = k/p players (p = k is one player per device).  On a
single-device host the same program runs with p = 1 — the collectives
still execute (over an axis of size 1), so the wire accounting and the
program structure are identical, only the transport is trivial.  Use
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to simulate an
N-device CPU mesh (see TESTING.md).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.ckpt import msgpack_ckpt
from repro.core import approximation, batched, classify, ledger as L
from repro.core import streaming, weak
from repro.core import weights as W
from repro.core.pinned import pinned_argmax
from repro.core.boost_attempt import _center_erm, _gather_coreset, _shard_map
from repro.core.types import BoostConfig
from repro.obs import trace as obs_trace

AXIS = "players"


def make_players_mesh(k: int, devices=None) -> Mesh:
    """A 1-axis ``players`` mesh of p devices, p = the largest divisor
    of k the host can supply (p = 1 degenerates to the local engine,
    p = k is one player per device)."""
    devices = list(jax.devices() if devices is None else devices)
    p = max(d for d in range(1, min(k, len(devices)) + 1) if k % d == 0)
    return Mesh(np.asarray(devices[:p]), (AXIS,))


class _RoundCarry(NamedTuple):
    t: jax.Array            # hypotheses produced so far
    it: jax.Array           # loop iterations (wire rounds)
    stuck: jax.Array
    hits: jax.Array         # [kloc, mloc] — local players only
    key: jax.Array
    h_params: jax.Array     # [t_buf, 4] replicated
    core_x: jax.Array       # [k, c(, F)] pooled coreset (all_gather output)
    core_y: jax.Array       # [k, c]
    min_loss: jax.Array
    wire_core: jax.Array    # int32 — coreset examples gathered this attempt
    wire_ws: jax.Array      # int32 — weight-sum scalars gathered this attempt
    wire_bytes: jax.Array   # int32 — machine bytes of those collectives
    wire_hist: jax.Array    # int32 — histogram scalars merged (comm_mode)
    wire_votes: jax.Array   # int32 — vote proposals exchanged (voting)


def _slice_player_keys(keys_all: jax.Array, kloc: int) -> jax.Array:
    """This device's kloc keys out of the k per-player keys — sliced on
    the raw key data because dynamic_slice on typed keys is flaky on the
    pinned 0.4.x toolchain."""
    pid = jax.lax.axis_index(AXIS)
    data = jax.random.key_data(keys_all)                  # [k, key_words]
    loc = jax.lax.dynamic_slice_in_dim(data, pid * kloc, kloc, axis=0)
    return jax.random.wrap_key_data(loc)


def _local_player_mask(player_alive: jax.Array, kloc: int) -> jax.Array:
    """This device's kloc entries of the replicated [k] player mask."""
    pid = jax.lax.axis_index(AXIS)
    return jax.lax.dynamic_slice_in_dim(player_alive, pid * kloc, kloc)


def _round_body(cfg: BoostConfig, cls, k: int, x, y, alive, x_orders,
                y_sorted, alive_sorted, no_center: bool,
                c: _RoundCarry, *, player_alive=None) -> _RoundCarry:
    # LOCKSTEP: this is boost_attempt._round_body with the vmap-lane
    # pooling replaced by collectives (and _one_step_sharded below
    # mirrors batched._one_step the same way).  Any semantic change to
    # the round/step bodies there must land here too — the exact-parity
    # tests (tests/test_sharded_batched.py) fail on any divergence.
    kloc = x.shape[0]
    key, kc = jax.random.split(c.key)
    keys_all = jax.random.split(kc, k)    # the host loop's k-key stream
    keys = _slice_player_keys(keys_all, kloc)
    # --- players (local rows only): step 2(a) coreset + 2(b) sums ------
    idx = jax.vmap(
        lambda kk, xx, yy, hh, aa, oo, yso, aso:
        approximation.select_coreset(
            kk, xx if xx.ndim == 1 else xx[:, 0], yy, hh, aa,
            cfg.coreset_size, cfg.deterministic_coreset and x.ndim == 2,
            order=oo, y_sorted=yso, alive_sorted=aso)
    )(keys, x, y, c.hits, alive, x_orders, y_sorted, alive_sorted)
    cx, cy = _gather_coreset(x, y, idx)                   # [kloc, c(, F)]
    log_wsums = jax.vmap(W.log_weight_sum)(c.hits, alive)  # [kloc]
    if player_alive is not None:
        # an absent player sends nothing: its weight sum leaves the
        # mixture before the gather (−inf ⇒ mixture weight 0)
        log_wsums = jnp.where(_local_player_mask(player_alive, kloc),
                              log_wsums, -jnp.inf)
    # --- the wire: every alive player's coreset + one scalar each ------
    cx_all = jax.lax.all_gather(cx, AXIS)                 # [p, kloc, c(, F)]
    cy_all = jax.lax.all_gather(cy, AXIS)
    ws_all = jax.lax.all_gather(log_wsums, AXIS)          # [p, kloc]
    comm_mode = L.tree_comm_mode(cls)
    # payload counters: what alive players actually sent.  Unmasked,
    # they are taken from the gathered arrays themselves (move iff the
    # collective executed, by its actual size); masked, they charge the
    # per-player payload × the round's alive count.
    k_alive = (jnp.int32(k) if player_alive is None
               else jnp.sum(player_alive.astype(jnp.int32)))
    core_pp_bytes = ((cx_all.size // k) * cx_all.dtype.itemsize
                     + (cy_all.size // k) * cy_all.dtype.itemsize)
    if comm_mode == "coreset":
        if player_alive is None:
            n_examples = int(np.prod(cy_all.shape))       # k · c, exactly
            n_scalars = int(np.prod(ws_all.shape))        # k
            n_bytes = (cx_all.size * cx_all.dtype.itemsize
                       + cy_all.size * cy_all.dtype.itemsize
                       + ws_all.size * ws_all.dtype.itemsize)
        else:
            n_examples = k_alive * cfg.coreset_size
            n_scalars = k_alive
            n_bytes = k_alive * (core_pp_bytes + ws_all.dtype.itemsize)
    cx_all = cx_all.reshape((k,) + cx_all.shape[2:])      # player order
    cy_all = cy_all.reshape((k,) + cy_all.shape[2:])
    ws_all = ws_all.reshape(-1)
    mix = W.mixture_weights(ws_all)
    # --- center: step 2(c)+(d) pooled weighted ERM ----------------------
    if comm_mode != "coreset":
        # Distributed tree growth: split finding runs on per-player
        # histograms (and votes), merged by a REAL collective — the
        # every-round coreset gather above survives only as a carry-
        # shape/quarantine simulation artifact; protocol-wise examples
        # cross the wire solely on the stuck round, and the counters
        # below charge exactly that.  The merge is centerless by
        # construction (every device computes the identical merged
        # answer), so the §2.2 no_center flag is moot here.
        pid = jax.lax.axis_index(AXIS)
        mix_loc = jax.lax.dynamic_slice_in_dim(mix, pid * kloc, kloc, 0)

        def _ag(a):
            g = jax.lax.all_gather(a, AXIS)
            return g.reshape((k,) + g.shape[2:])

        h, loss = cls.erm_players(cx, cy, mix_loc / cfg.coreset_size,
                                  all_gather=_ag)
    elif no_center:
        # §2.2: the first ALIVE player acts as center; only its device
        # runs the ERM and the result is psum-broadcast back (exact:
        # all other summands are literal zeros).
        pid = jax.lax.axis_index(AXIS)
        center = (jnp.int32(0) if player_alive is None
                  else pinned_argmax(player_alive))
        cdev = center // kloc
        h0, loss0 = jax.lax.cond(
            pid == cdev,
            lambda: _center_erm(cls, cx_all, cy_all, mix, cfg.coreset_size),
            lambda: (jnp.zeros((weak.param_dim(cls),), jnp.float32),
                     jnp.float32(0)))
        h = jax.lax.psum(jnp.where(pid == cdev, h0, 0.0), AXIS)
        loss = jax.lax.psum(jnp.where(pid == cdev, loss0, 0.0), AXIS)
    else:
        h, loss = _center_erm(cls, cx_all, cy_all, mix, cfg.coreset_size)
    stuck_now = loss > cfg.weak_threshold
    if comm_mode != "coreset":
        # distributed-mode payloads: per-player scalar counts are
        # STATIC class properties (ledger.py charges the same formulas)
        # × the round's alive-player count; coreset examples move only
        # when this round sticks (quarantine ships the points then)
        hist_pp = L.hist_scalars_per_player(cls)
        vote_pp = L.vote_entries_per_player(cls)
        n_examples = jnp.where(stuck_now, k_alive * cfg.coreset_size, 0)
        n_scalars = k_alive
        n_hist = k_alive * hist_pp
        n_votes = k_alive * vote_pp
        n_bytes = (jnp.where(stuck_now, k_alive * core_pp_bytes, 0)
                   + k_alive * (ws_all.dtype.itemsize
                                + 4 * hist_pp      # f32 histogram cells
                                + 4 * vote_pp))    # i32 vote entries
    else:
        n_hist = jnp.int32(0)
        n_votes = jnp.int32(0)
    # --- players: step 2(f) multiplicative-weights update (local) ------
    pred = cls.predict(h, x)
    upd = W.update_hits(c.hits, pred == y, alive)
    if player_alive is not None:
        # absent players never received h_t: their MW state freezes
        upd = jnp.where(_local_player_mask(player_alive, kloc)[:, None],
                        upd, c.hits)
    new_hits = jnp.where(stuck_now, c.hits, upd)
    h_params = c.h_params.at[c.t].set(
        jnp.where(stuck_now, c.h_params[c.t], h))
    return _RoundCarry(
        t=jnp.where(stuck_now, c.t, c.t + 1),
        it=c.it + 1,
        stuck=stuck_now,
        hits=new_hits,
        key=key,
        h_params=h_params,
        core_x=cx_all, core_y=cy_all,
        min_loss=loss,
        wire_core=c.wire_core + n_examples,
        wire_ws=c.wire_ws + n_scalars,
        wire_bytes=c.wire_bytes + n_bytes,
        wire_hist=c.wire_hist + n_hist,
        wire_votes=c.wire_votes + n_votes,
    )


# ---------------------------------------------------------------------------
# Round-granular stepping over the mesh.  The per-task state is a plain
# dict of arrays: {alive, disputed, hits} are player-sharded, the rest
# replicated — host-gathered it checkpoints via ckpt/msgpack_ckpt.
# ---------------------------------------------------------------------------

_SHARDED_FIELDS = ("alive", "disputed", "hits")

# -- checkpoint identity ----------------------------------------------------
# The sharded state is the batched StepState's leaves (same names, same
# dtypes — built by batched.init_state) plus the wire-payload counters.

STATE_TREEDEF = "repro.core.sharded_batched.state"

STATE_DTYPES = dict(
    batched.STATE_DTYPES,
    awire_core="int32", awire_ws="int32", hist_wire_core="int32",
    hist_wire_ws="int32", wire_bytes="int32", wire_q_points="int32",
    wire_q_counts="int32", awire_hist="int32", awire_votes="int32",
    hist_wire_hist="int32", hist_wire_votes="int32")


def _unflatten_state(leaves: dict) -> dict:
    missing = set(STATE_DTYPES) - set(leaves)
    if missing:
        raise KeyError(f"checkpoint missing sharded-state leaves: "
                       f"{sorted(missing)}")
    batched.check_state_dtypes(leaves, STATE_DTYPES, "sharded state")
    return dict(leaves)


msgpack_ckpt.register_treedef(STATE_TREEDEF, _unflatten_state)


def init_state_sharded(x, y, keys, cfg: BoostConfig, alive=None,
                       t_buf: int | None = None, cls=None) -> dict:
    """Fresh sharded-engine state (global [B, …] arrays; the shard_map
    call partitions the player-sharded fields per its in_specs).

    Same input shapes/dtypes as ``batched.init_state``: ``x``
    [B, k, mloc] int32 or [B, k, mloc, F] float32, ``y`` [B, k, mloc]
    int8 ±1, ``keys`` [B] PRNG keys, ``alive`` optional [B, k, mloc]
    bool.  Returns a dict state: the protocol fields ARE
    ``batched.init_state``'s — built by it, so the two engines' state
    layouts (and checkpoint shape contracts) can never drift — plus
    int32 [B] / [B, A] wire-payload counters (gathered coreset
    examples, weight-sum scalars, histogram scalars, vote proposals,
    collective bytes) that only this engine maintains.  ``cls`` sizes
    the ensemble buffers, exactly as there.  Bitwise contract: the
    protocol fields evolve identically to the local batched engine's
    on any mesh shape (docs/architecture.md,
    tests/test_sharded_batched.py); the counters feed
    ``ShardedClassifyResult.validate_ledger`` (docs/ledger.md).
    """
    state = batched.init_state(jnp.asarray(x), jnp.asarray(y), keys,
                               cfg, alive=alive, t_buf=t_buf,
                               cls=cls)._asdict()
    B = state["attempt"].shape[0]
    a_max = cfg.opt_budget + 1
    i32 = functools.partial(jnp.zeros, dtype=jnp.int32)
    state.update(
        awire_core=i32((B,)), awire_ws=i32((B,)),
        hist_wire_core=i32((B, a_max)),
        hist_wire_ws=i32((B, a_max)),
        wire_bytes=i32((B,)),
        wire_q_points=i32((B,)), wire_q_counts=i32((B,)),
        awire_hist=i32((B,)), awire_votes=i32((B,)),
        hist_wire_hist=i32((B, a_max)),
        hist_wire_votes=i32((B, a_max)))
    return state


def _one_step_sharded(cfg: BoostConfig, cls, k: int, no_center: bool,
                      x, y, x_orders, sched, s: dict) -> dict:
    """ONE wire round of ONE task on this device's [kloc, mloc] shard.
    LOCKSTEP with batched._one_step (collectives replace lane pooling)."""
    a_max = cfg.opt_budget + 1
    kloc = x.shape[0]
    active = (~s["done"]) & (s["attempt"] < a_max)
    pa = sched[jnp.minimum(s["step"], sched.shape[0] - 1)]       # [k]
    pa_loc = _local_player_mask(pa, kloc)
    # ---- attempt start ------------------------------------------------
    start = ~s["in_attempt"]
    tkey = jax.random.wrap_key_data(s["key_data"])
    nk, sub = jax.random.split(tkey)
    key_data = jnp.where(start, jax.random.key_data(nk), s["key_data"])
    akey_data = jnp.where(start, jax.random.key_data(sub),
                          s["akey_data"])
    m_alive = jax.lax.psum(
        jnp.sum((s["alive"] & pa_loc[:, None]).astype(jnp.int32)), AXIS)
    a = s["attempt"]
    bound = jnp.where(start, batched.num_rounds_dynamic(cfg, m_alive),
                      s["bound"])
    hits = jnp.where(start, W.init_hits(x.shape[:2]), s["hits"])
    cur_h = jnp.where(start, jnp.zeros_like(s["cur_h"]), s["cur_h"])
    t = jnp.where(start, 0, s["t"])
    awire_core = jnp.where(start, 0, s["awire_core"])
    awire_ws = jnp.where(start, 0, s["awire_ws"])
    awire_hist = jnp.where(start, 0, s["awire_hist"])
    awire_votes = jnp.where(start, 0, s["awire_votes"])
    hist_alive = jnp.where(start, s["hist_alive"].at[a].set(m_alive),
                           s["hist_alive"])
    # ---- one BoostAttempt round over the wire -------------------------
    y_sorted = jnp.take_along_axis(y, x_orders, axis=1)
    alive_sorted = jnp.take_along_axis(s["alive"], x_orders, axis=1)
    rc = _RoundCarry(
        t=t, it=jnp.int32(0), stuck=jnp.asarray(False),
        hits=hits, key=jax.random.wrap_key_data(akey_data),
        h_params=cur_h, core_x=s["core_x"], core_y=s["core_y"],
        min_loss=s["min_loss"],
        wire_core=jnp.int32(0), wire_ws=jnp.int32(0),
        wire_bytes=jnp.int32(0), wire_hist=jnp.int32(0),
        wire_votes=jnp.int32(0))
    out = _round_body(cfg, cls, k, x, y, s["alive"], x_orders, y_sorted,
                      alive_sorted, no_center, rc, player_alive=pa)
    stuck = out.stuck
    success = (~stuck) & (out.t >= bound)
    ended = stuck | success
    k_alive = jnp.sum(pa.astype(jnp.int32))
    # ---- full-point quarantine: the pooled stuck coreset is replicated
    # (it is the all_gather output); dead players' rows are masked out
    # and each device kills its local copies.
    core_flat = out.core_x.reshape((-1,) + out.core_x.shape[2:])
    valid_flat = jnp.repeat(pa, cfg.coreset_size)
    masked_flat = classify.mask_invalid_points(core_flat, valid_flat)
    dead_new = s["alive"] & classify.match_points(x, masked_flat) & stuck
    p_count = jnp.where(
        stuck, classify.distinct_count_masked(core_flat, valid_flat), 0)
    awire_core = awire_core + out.wire_core
    awire_ws = awire_ws + out.wire_ws
    awire_hist = awire_hist + out.wire_hist
    awire_votes = awire_votes + out.wire_votes
    nxt = {
        "attempt": jnp.where(ended, a + 1, a),
        "done": s["done"] | success,
        "alive": s["alive"] & ~dead_new,
        "disputed": s["disputed"] | dead_new,
        "key_data": key_data,
        "h_params": jnp.where(success, out.h_params, s["h_params"]),
        "rounds": jnp.where(success, out.t, s["rounds"]),
        "min_loss": out.min_loss,
        "hist_stuck": jnp.where(ended, s["hist_stuck"].at[a].set(stuck),
                                s["hist_stuck"]),
        "hist_rounds": jnp.where(ended,
                                 s["hist_rounds"].at[a].set(out.t),
                                 s["hist_rounds"]),
        "hist_alive": hist_alive,
        "hist_p": jnp.where(ended, s["hist_p"].at[a].set(p_count),
                            s["hist_p"]),
        "hist_players": s["hist_players"].at[a].add(k_alive),
        "hist_players_h": s["hist_players_h"].at[a].add(
            jnp.where(stuck, 0, k_alive)),
        "hist_players_last": s["hist_players_last"].at[a].set(k_alive),
        "in_attempt": ~ended,
        "akey_data": jax.random.key_data(out.key),
        "t": out.t,
        "bound": bound,
        "hits": out.hits,
        "cur_h": out.h_params,
        "core_x": out.core_x, "core_y": out.core_y,
        "step": s["step"] + 1,
        "awire_core": awire_core, "awire_ws": awire_ws,
        "awire_hist": awire_hist, "awire_votes": awire_votes,
        "hist_wire_core": jnp.where(
            ended, s["hist_wire_core"].at[a].set(awire_core),
            s["hist_wire_core"]),
        "hist_wire_ws": jnp.where(
            ended, s["hist_wire_ws"].at[a].set(awire_ws),
            s["hist_wire_ws"]),
        "hist_wire_hist": jnp.where(
            ended, s["hist_wire_hist"].at[a].set(awire_hist),
            s["hist_wire_hist"]),
        "hist_wire_votes": jnp.where(
            ended, s["hist_wire_votes"].at[a].set(awire_votes),
            s["hist_wire_votes"]),
        "wire_bytes": s["wire_bytes"] + out.wire_bytes,
        "wire_q_points": s["wire_q_points"] + k_alive * p_count,
        "wire_q_counts": s["wire_q_counts"] + k_alive * p_count,
    }
    return jax.tree_util.tree_map(
        lambda new, old: jnp.where(active, new, old), nxt, s)


@functools.lru_cache(maxsize=None)
def _build_sharded_step(mesh: Mesh, cfg: BoostConfig, cls,
                        no_center: bool):
    """jitted shard_map program (x, y, sched, state, n) → state."""
    k = cfg.k
    p = mesh.shape[AXIS]
    if k % p != 0:
        raise ValueError(f"players mesh size {p} must divide k={k}")
    a_max = cfg.opt_budget + 1

    def per_device(x, y, sched, state, n):
        x1d = x if x.ndim == 3 else x[..., 0]
        # chunk-local runs under cfg.chunk_size, bitwise identical to
        # the monolithic argsort (streaming tier)
        x_orders = jax.vmap(jax.vmap(lambda v: streaming.sort_order(
            v, cfg.chunk_size, cfg.domain_size)))(x1d)

        def active(st):
            return (~st["done"]) & (st["attempt"] < a_max)

        def cond(carry):
            st, i = carry
            return jnp.any(active(st)) & (i < n)

        def body(carry):
            st, i = carry
            st2 = jax.vmap(functools.partial(
                _one_step_sharded, cfg, cls, k, no_center))(
                x, y, x_orders, sched, st)
            return st2, i + 1

        out, _ = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
        return out

    sharded = P(None, AXIS)
    state_specs = {f: (sharded if f in _SHARDED_FIELDS else P())
                   for f in init_state_sharded(
                       np.zeros((1, k, 2), np.int32),
                       np.zeros((1, k, 2), np.int8),
                       jax.random.split(jax.random.key(0), 1), cfg,
                       cls=cls)}
    in_specs = (sharded, sharded, P(), state_specs, P())
    return jax.jit(_shard_map(per_device, mesh=mesh, in_specs=in_specs,
                              out_specs=state_specs))


def run_rounds_sharded(state: dict, x, y, cfg: BoostConfig, cls,
                       mesh: Mesh | None = None, n: int | None = None,
                       player_sched=None, no_center: bool = False) -> dict:
    """Advance the sharded protocol by up to ``n`` wire rounds (None =
    to completion); the mesh-collective twin of ``batched.run_rounds``.

    ``state``: the dict from :func:`init_state_sharded` (or a restored
    checkpoint); ``x``/``y``: the same [B, k, mloc(, F)] / [B, k, mloc]
    dispatch arrays; ``mesh``: a ``players`` mesh whose axis size
    divides k (default ``make_players_mesh(k)``); ``player_sched``:
    [R, k] / [B, R, k] bool infrastructure-adversary schedule;
    ``no_center``: the §2.2 center-free model.  Returns the advanced
    dict.  ``n`` is traced (one compiled program per signature, any
    slice size).  Bitwise contract: identical slicing ⇒ protocol
    fields identical to ``batched.run_rounds`` on the same inputs —
    the collectives change WHERE bytes move, never a single output
    bit — and ``cfg.chunk_size`` is equally invisible here
    (docs/streaming.md, tests/test_streaming.py)."""
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    B, k = x.shape[0], x.shape[1]
    sched = batched.canon_player_sched(player_sched, B, k)
    if mesh is None:
        mesh = make_players_mesh(k)
    fn = _build_sharded_step(mesh, cfg, cls, no_center)
    n_arr = batched._RUN_FOREVER if n is None else jnp.int32(n)
    with obs_trace.span("run_rounds", "engine", engine="sharded", B=B,
                        n=(-1 if n is None else int(n)),
                        mesh_devices=int(mesh.shape[AXIS])), \
            obs_trace.annotate("run_rounds_sharded"):
        return fn(x, y, sched, state, n_arr)


@functools.lru_cache(maxsize=None)
def _build_sharded(mesh: Mesh, cfg: BoostConfig, cls, t_buf: int,
                   no_center: bool):
    """Full-run program (x, y, alive, keys, sched) → final state dict."""
    step = _build_sharded_step(mesh, cfg, cls, no_center)

    def full(x, y, alive, keys, sched):
        state = init_state_sharded(x, y, keys, cfg, alive=alive,
                                   t_buf=t_buf, cls=cls)
        return step(x, y, sched, state, batched._RUN_FOREVER)

    return jax.jit(full)


def lower_classify_sharded(x, y, alive, keys, cfg: BoostConfig, cls,
                           mesh: Mesh, no_center: bool = False,
                           player_sched=None):
    """AOT-compile the sharded engine for one input signature (the
    mesh-collective twin of ``batched.lower_classify``).  The returned
    executable is owned by the caller — a serving compile cache reuses
    it across admissions and dropping it really frees the program."""
    t_buf = cfg.num_rounds(x.shape[1] * x.shape[2])
    sched = batched.canon_player_sched(player_sched, x.shape[0],
                                       x.shape[1])
    fn = _build_sharded(mesh, cfg, cls, t_buf, no_center)
    with obs_trace.span("compile", "compile", engine="sharded",
                        B=int(x.shape[0]), mloc=int(x.shape[2])):
        return fn.lower(jnp.asarray(x), jnp.asarray(y),
                        jnp.asarray(alive), keys, sched).compile()


@dataclasses.dataclass
class ShardedClassifyResult(batched.BatchedClassifyResult):
    """BatchedClassifyResult + the measured collective payloads.

    ``per_task``, ``classifier`` and ``ledger`` are inherited unchanged
    (the protocol state is bit-identical to the local batched engine);
    the wire_* fields record what the collectives actually moved.
    """

    hist_wire_core: np.ndarray = None   # [B, A] coreset examples gathered
    hist_wire_ws: np.ndarray = None     # [B, A] weight-sum scalars gathered
    wire_bytes: np.ndarray = None       # [B] machine bytes of collectives
    wire_q_points: np.ndarray = None    # [B] quarantine point messages
    wire_q_counts: np.ndarray = None    # [B] quarantine count reports
    hist_wire_hist: np.ndarray = None   # [B, A] histogram scalars merged
    hist_wire_votes: np.ndarray = None  # [B, A] vote proposals exchanged
    mesh_devices: int = 1

    def wire_summary(self, b: int) -> dict:
        return {
            "coreset_examples": int(self.hist_wire_core[b].sum()),
            "weight_sum_scalars": int(self.hist_wire_ws[b].sum()),
            "histogram_scalars": int(self.hist_wire_hist[b].sum()),
            "vote_proposals": int(self.hist_wire_votes[b].sum()),
            "collective_bytes": int(self.wire_bytes[b]),
            "quarantine_point_msgs": int(self.wire_q_points[b]),
            "quarantine_count_msgs": int(self.wire_q_counts[b]),
            "mesh_devices": int(self.mesh_devices),
        }

    def validate_ledger(self, b: int) -> dict:
        """Cross-check Theorem 4.1 accounting against measured payloads.

        docs/ledger.md walks the accounting this validates, field by
        field, with a worked example and the masked variants.
        Raises AssertionError on any mismatch; returns the comparison.
        Checks, per task (all player-mask-aware — under a dropout
        schedule only alive players' payloads are charged):
        * ledger coreset bits == gathered examples × example_bits(n);
        * ledger weight-sum bits == Σ_attempts gathered scalars ×
          weight_sum_bits(m_alive, T) with per-attempt m_alive;
        * per attempt, gathered payload == Σ_rounds k_alive · c examples
          and Σ_rounds k_alive scalars (the protocol's message pattern);
          in a distributed comm_mode the per-round payload is instead
          Σ_rounds k_alive · hist_scalars (+ votes), with examples
          gathered only on the stuck round;
        * ledger histogram/vote bits == merged scalars / exchanged
          proposals × their per-attempt bit widths;
        * quarantine messages == Σ_stuck k_alive(stuck round) · P.
        """
        cfg, cls = self.cfg, self.cls
        n = L.domain_size(cls)
        mode = L.tree_comm_mode(cls)
        hist_pp = L.hist_scalars_per_player(cls)
        vote_pp = L.vote_entries_per_player(cls)
        led = self.ledger(b)
        n_att = int(self.attempts[b])
        got_core = int(self.hist_wire_core[b, :n_att].sum())
        got_ws = int(self.hist_wire_ws[b, :n_att].sum())
        exp_ws_bits = 0
        exp_hist_bits = 0
        exp_vote_bits = 0
        exp_q = 0
        for a in range(n_att):
            pl_rounds, _, pl_last = self._attempt_players(b, a)
            stuck = bool(self.hist_stuck[b, a])
            if mode == "coreset":
                assert int(self.hist_wire_core[b, a]) == \
                    pl_rounds * cfg.coreset_size, (b, a)
            else:
                # distributed modes gather examples only when stuck —
                # from the stuck round's alive players
                assert int(self.hist_wire_core[b, a]) == \
                    (pl_last * cfg.coreset_size if stuck else 0), (b, a)
            assert int(self.hist_wire_hist[b, a]) == \
                pl_rounds * hist_pp, (b, a)
            assert int(self.hist_wire_votes[b, a]) == \
                pl_rounds * vote_pp, (b, a)
            assert int(self.hist_wire_ws[b, a]) == pl_rounds, (b, a)
            m_a = max(int(self.hist_alive[b, a]), 2)
            T_a = cfg.num_rounds(m_a)
            exp_ws_bits += int(self.hist_wire_ws[b, a]) \
                * L.weight_sum_bits(m_a, T_a)
            exp_hist_bits += int(self.hist_wire_hist[b, a]) \
                * L.histogram_cell_bits(m_a, T_a)
            exp_vote_bits += int(self.hist_wire_votes[b, a]) \
                * L.vote_entry_bits(cls, m_a, T_a) if vote_pp else 0
            if stuck:
                exp_q += pl_last * int(self.hist_p[b, a])
        assert led.bits_coresets == got_core * L.example_bits(n), (
            led.bits_coresets, got_core)
        assert led.bits_weight_sums == exp_ws_bits, (
            led.bits_weight_sums, exp_ws_bits)
        assert led.bits_histograms == exp_hist_bits, (
            led.bits_histograms, exp_hist_bits)
        assert led.bits_votes == exp_vote_bits, (
            led.bits_votes, exp_vote_bits)
        assert int(self.wire_q_points[b]) == exp_q, (
            int(self.wire_q_points[b]), exp_q)
        assert int(self.wire_q_counts[b]) == exp_q
        return {
            "bits_coresets": led.bits_coresets,
            "coreset_examples_gathered": got_core,
            "bits_weight_sums": led.bits_weight_sums,
            "weight_sum_scalars_gathered": got_ws,
            "bits_histograms": led.bits_histograms,
            "histogram_scalars_merged": int(
                self.hist_wire_hist[b, :n_att].sum()),
            "bits_votes": led.bits_votes,
            "vote_proposals_exchanged": int(
                self.hist_wire_votes[b, :n_att].sum()),
            "quarantine_msgs": int(self.wire_q_points[b]),
            "collective_bytes": int(self.wire_bytes[b]),
        }


def finalize_sharded(state: dict, x, y, alive0, cfg: BoostConfig, cls,
                     m_true=None, mesh: Mesh | None = None,
                     ) -> ShardedClassifyResult:
    """Materialise a host result from stepped sharded state.

    Same inputs as ``batched.finalize`` plus the state dict's wire
    counters.  Returns a ``ShardedClassifyResult``: every
    ``BatchedClassifyResult`` field (same shapes/dtypes — hypotheses
    [B, t_buf, P] float32, [B] int32 counters, [B, k, mloc] bool
    masks) plus the measured collective payloads ([B, A] int32
    ``hist_wire_*``, [B] int32 ``wire_*``) that
    ``validate_ledger`` checks against the Theorem 4.1 accounting
    (docs/ledger.md).  Pure materialisation, no protocol math."""
    with obs_trace.span("finalize", "engine", engine="sharded"):
        out = jax.device_get(state)
    return ShardedClassifyResult(
        hypotheses=out["h_params"], rounds=out["rounds"],
        ok=np.asarray(out["done"]), attempts=out["attempt"],
        alive=out["alive"], disputed=out["disputed"],
        min_loss=out["min_loss"],
        hist_stuck=out["hist_stuck"], hist_rounds=out["hist_rounds"],
        hist_alive=out["hist_alive"], hist_p=out["hist_p"],
        x=np.asarray(x), y=np.asarray(y), alive0=np.asarray(alive0),
        cfg=cfg, cls=cls,
        m_true=None if m_true is None else np.asarray(m_true),
        hist_players=out["hist_players"],
        hist_players_h=out["hist_players_h"],
        hist_players_last=out["hist_players_last"],
        hist_wire_core=out["hist_wire_core"],
        hist_wire_ws=out["hist_wire_ws"],
        hist_wire_hist=out["hist_wire_hist"],
        hist_wire_votes=out["hist_wire_votes"],
        wire_bytes=out["wire_bytes"],
        wire_q_points=out["wire_q_points"],
        wire_q_counts=out["wire_q_counts"],
        mesh_devices=1 if mesh is None else mesh.shape[AXIS])


def run_accurately_classify_sharded(x, y, keys, cfg: BoostConfig, cls,
                                    mesh: Mesh | None = None, alive=None,
                                    no_center: bool = False,
                                    compiled=None, m_true=None,
                                    player_sched=None,
                                    ) -> ShardedClassifyResult:
    """B-task AccuratelyClassify over a real ``players`` device mesh.

    Same contract as ``batched.run_accurately_classify_batched`` (and
    bit-identical outputs on identical inputs and schedules); ``mesh``
    defaults to ``make_players_mesh(k)`` over the host's devices.
    """
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    B, k, mloc = x.shape[0], x.shape[1], x.shape[2]
    if k != cfg.k:
        raise ValueError(f"x has {k} players but cfg.k={cfg.k}")
    keys = jnp.asarray(keys)
    if keys.ndim == 0:
        keys = jax.random.split(keys, B)
    if keys.shape[0] != B:
        raise ValueError(f"need {B} task keys, got shape {keys.shape}")
    if alive is None:
        alive = jnp.ones((B, k, mloc), bool)
    else:
        alive = jnp.asarray(alive)
    sched = batched.canon_player_sched(player_sched, B, k)
    if mesh is None:
        mesh = make_players_mesh(k)
    if compiled is not None:
        out = compiled(x, y, alive, keys, sched)
    else:
        t_buf = cfg.num_rounds(k * mloc)
        fn = _build_sharded(mesh, cfg, cls, t_buf, no_center)
        out = fn(x, y, alive, keys, sched)
    return finalize_sharded(out, x, y, alive, cfg, cls, m_true=m_true,
                            mesh=mesh)
