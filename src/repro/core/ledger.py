"""Bit-exact communication accounting (the paper's resource model).

Theorem 4.1 charges, per BoostAttempt round:

* step 2(a): k coresets, each ``coreset_size`` examples, each example
  ``⌈log2 n⌉ + 1`` bits (point id + label) — the paper's ``O(d log n)``
  with the class-specific coreset size playing the O(d/ε²) role;
* step 2(b): k weight sums, ``O(log |S|)`` bits each — exact here because
  weights live in log2 space: a weight sum is described by its integer
  hit-count histogram bound, we charge ``⌈log2(T·m)⌉ + mantissa`` bits;
* step 2(d): one hypothesis broadcast to k players,
  ``k · hypothesis_bits`` bits;
* step 2(e): k control bits when the attempt gets stuck (at most once).

AccuratelyClassify adds nothing on top (the center already holds S'),
so total = Σ attempts.  The benchmarks validate this ledger against the
Theorem 4.1 bound  O(OPT · k·log|S|·(d·log n + log|S|)).
"""

from __future__ import annotations

import math

from repro.core.types import BoostConfig, Ledger


def domain_size(cls) -> int:
    """|U| of a weak class: explicit ``n`` (protocol classes) or the
    2^value_bits grid of the feature track — THE convention every bit
    charge derives from, defined once."""
    return getattr(cls, "n", 1 << getattr(cls, "value_bits", 16))


def point_bits(n: int) -> int:
    return max(1, math.ceil(math.log2(max(n, 2))))


def example_bits(n: int) -> int:
    return point_bits(n) + 1                       # + label


def weight_sum_bits(m: int, num_rounds: int) -> int:
    """log2 W^(i) is in [−T, log2 m]; we transmit it in fixed point with
    ⌈log2 m⌉ fractional bits (enough for exact mixture reconstruction up
    to 1/m precision — far below the 1/100 slack the analysis uses)."""
    return math.ceil(math.log2(max(num_rounds + math.log2(max(m, 2)), 2))) \
        + math.ceil(math.log2(max(m, 2)))


# ---------------------------------------------------------------------------
# Distributed tree growth (weak_tree comm_mode): what replaces the
# per-round coreset payload.  All three are STATIC per-player per-round
# counts derived from the class — the engines' wire counters and the
# ledger charge the same formulas, and validate_ledger cross-checks.
# ---------------------------------------------------------------------------

def tree_comm_mode(cls) -> str:
    """The class's split-finding exchange mode ("coreset" for every
    class without the capability — all the 1-D protocol classes)."""
    return getattr(cls, "comm_mode", "coreset")


def hist_scalars_per_player(cls) -> int:
    """Histogram scalars ONE player ships per round: (hist_w, hist_wy)
    pairs over every node of every level — 2·nodes·F·Q in histogram
    mode, 2·nodes·elected·Q in voting mode (merged columns only)."""
    mode = tree_comm_mode(cls)
    if mode == "histogram":
        return 2 * cls.nodes * cls.num_features * cls.bins
    if mode == "voting":
        return 2 * cls.nodes * cls.elected * cls.bins
    return 0


def vote_entries_per_player(cls) -> int:
    """Vote proposals ONE player ships per round: top-k per node."""
    if tree_comm_mode(cls) == "voting":
        return cls.nodes * cls.vote_topk
    return 0


def collective_sites_per_round(cls, *, no_center: bool = False) -> dict:
    """Mesh-collective call sites ONE wire round of the sharded engine
    executes — the static census ``tools/repro_lint`` verifies against
    the traced jaxpr, so a new collective cannot ship unaccounted.

    Every entry corresponds to a charged (or control) payload in this
    module's accounting:

    * ``all_gather`` — the step 2(a)/2(b) exchanges (coreset x, coreset
      y, weight sums: 3 sites, charged as ``bits_coresets`` /
      ``bits_weight_sums``); a distributed ``comm_mode`` adds its
      per-level merges (histogram: hw + hwy = 2·depth, charged as
      ``bits_histograms``; voting: proposals + alive mask + elected
      hw/hwy = 4·depth, charged as ``bits_votes`` +
      ``bits_histograms``).
    * ``psum`` — the alive-example count (control traffic, not a
      payload the ledger charges) plus, under the §2.2 no-center
      model, the hypothesis/loss broadcast pair (charged as
      ``bits_hypotheses``).
    """
    mode = tree_comm_mode(cls)
    all_gather = 3
    if mode == "histogram":
        all_gather += 2 * cls.depth
    elif mode == "voting":
        all_gather += 4 * cls.depth
    psum = 1
    if no_center and mode == "coreset":
        psum += 2
    return {"all_gather": all_gather, "psum": psum}


def histogram_cell_bits(m: int, num_rounds: int) -> int:
    """One histogram scalar on the wire — a weight-scale quantity, so
    the same fixed-point format as a weight sum."""
    return weight_sum_bits(m, num_rounds)


def vote_entry_bits(cls, m: int, num_rounds: int) -> int:
    """One vote proposal: (feature id, bin edge, gain) — feat_bits +
    bin_bits + a weight-fixed-point gain (the center can early-exit on
    the proposed gain, so it rides along as in LightGBM's voting)."""
    return cls.feat_bits + cls.bin_bits + weight_sum_bits(m, num_rounds)


def boost_attempt_ledger(cfg: BoostConfig, cls, m: int, rounds: int,
                         stuck: bool) -> Ledger:
    """Exact bits for one BoostAttempt run that produced ``rounds``
    hypotheses (and one extra stuck round if ``stuck``)."""
    n = domain_size(cls)
    T = cfg.num_rounds(m)
    wire_rounds = rounds + (1 if stuck else 0)     # stuck round still sent 2(a,b)
    led = Ledger(attempts=1, rounds=wire_rounds)
    if tree_comm_mode(cls) == "coreset":
        led.bits_coresets = (wire_rounds * cfg.k * cfg.coreset_size
                             * example_bits(n))
    else:
        # distributed growth: histograms/votes replace the per-round
        # coreset payload; examples cross the wire only when the
        # attempt sticks (quarantine needs the actual points)
        led.bits_coresets = (cfg.k * cfg.coreset_size * example_bits(n)
                             if stuck else 0)
        led.bits_histograms = (wire_rounds * cfg.k
                               * hist_scalars_per_player(cls)
                               * histogram_cell_bits(m, T))
        led.bits_votes = (wire_rounds * cfg.k
                          * vote_entries_per_player(cls)
                          * vote_entry_bits(cls, m, T))
    led.bits_weight_sums = wire_rounds * cfg.k * weight_sum_bits(m, T)
    led.bits_hypotheses = rounds * cfg.k * cls.hypothesis_bits()
    led.bits_control = cfg.k * (1 if stuck else 0) + cfg.k  # stuck flag + halt
    return led


def boost_attempt_ledger_masked(cfg: BoostConfig, cls, m: int, rounds: int,
                                stuck: bool, player_rounds: int,
                                player_h_rounds: int,
                                players_last: int) -> Ledger:
    """:func:`boost_attempt_ledger` under a per-round ``player_alive``
    mask — only bits that alive players actually sent are charged.

    ``player_rounds``   = Σ over wire rounds of the alive-player count
                          (== wire_rounds·k when nobody drops);
    ``player_h_rounds`` = the same sum over *successful* rounds only
                          (hypothesis broadcasts reach alive players);
    ``players_last``    = alive players at the attempt's final wire
                          round (stuck flag / halt control bits).

    With an all-alive mask every field reduces bit-for-bit to
    :func:`boost_attempt_ledger` — the parity suites pin this.
    """
    n = domain_size(cls)
    T = cfg.num_rounds(m)
    wire_rounds = rounds + (1 if stuck else 0)
    led = Ledger(attempts=1, rounds=wire_rounds)
    if tree_comm_mode(cls) == "coreset":
        led.bits_coresets = (player_rounds * cfg.coreset_size
                             * example_bits(n))
    else:
        # only the stuck round ships examples — from the players alive
        # AT that round (== players_last, the stuck round is the
        # attempt's final wire round)
        led.bits_coresets = (players_last * cfg.coreset_size
                             * example_bits(n) if stuck else 0)
        led.bits_histograms = (player_rounds * hist_scalars_per_player(cls)
                               * histogram_cell_bits(m, T))
        led.bits_votes = (player_rounds * vote_entries_per_player(cls)
                          * vote_entry_bits(cls, m, T))
    led.bits_weight_sums = player_rounds * weight_sum_bits(m, T)
    led.bits_hypotheses = player_h_rounds * cls.hypothesis_bits()
    led.bits_control = players_last * (1 if stuck else 0) + players_last
    return led


def theorem_41_bound(cfg: BoostConfig, cls, m: int, opt: int,
                     constant: float = 1.0) -> float:
    """O(OPT · k·log|S|·(d·log n + hyp + log|S|)) with an explicit
    constant and the coreset size standing in for O(d/ε²).

    The explicit ``hypothesis_bits`` term makes the bound scale with
    the hypothesis description length — for the small 1-D classes it is
    dominated by the coreset term (the asymptotic form hides it in
    d·log n), but tree classes broadcast O(2^depth·log(F·Q))-bit
    hypotheses per round and the accounting must grow with them, never
    with m.  Monotone in ``hypothesis_bits`` by construction (tested in
    tests/test_ledger.py); adding the term only loosens the ≤-bound
    checks the property suite pins.
    """
    n = domain_size(cls)
    logm = math.log2(max(m, 2))
    logn = math.log2(max(n, 2))
    d = cls.vc_dim
    T = cfg.num_rounds(m)
    # distributed tree growth swaps the per-round coreset payload for
    # histograms/votes; the bound keeps BOTH terms (monotone loosening
    # — coreset bits still cover the stuck round's example transfer)
    mode_payload = (hist_scalars_per_player(cls)
                    * histogram_cell_bits(m, T)
                    + vote_entries_per_player(cls)
                    * vote_entry_bits(cls, m, T)
                    if tree_comm_mode(cls) != "coreset" else 0)
    per_attempt = cfg.k * (6 * logm + 1) * (
        cfg.coreset_size * (logn + 1) / max(d, 1) * d
        + cls.hypothesis_bits() + logm + mode_payload)
    return constant * max(opt + 1, 1) * per_attempt


def naive_baseline_bits(m: int, n: int) -> int:
    """Send-all-data baseline: every example to the center."""
    return m * example_bits(n)
