"""Finite-class agnostic learning with NO promise on OPT.

Section 6 of the paper ("Characterizing agnostic learning") observes
that the linear-in-OPT communication of AccuratelyClassify is necessary
for some classes (Theorem 2.3) but avoidable for others — "for example
finite classes".  This module makes that observation executable, as a
baseline/extension the benchmarks can compare against:

For a finite class H = {h_1, …, h_H}: each player computes its local
error vector E_i(h) = #mistakes of h on S_i (zero communication), and
sends it to the center: ⌈log2 m⌉·|H| bits.  The center sums and returns
argmin — exactly OPT errors, **independent of OPT**, with communication
k·|H|·⌈log2 m⌉ + k·⌈log2 |H|⌉ bits.

This is proper (outputs h ∈ H) — no contradiction with the
Kane–Livni–Moran–Yehudayoff impossibility, which concerns classes whose
size is super-exponential in the relevant parameters; here the protocol
is only communication-efficient when |H| ∈ polylog, which singletons
over [n] (|H| = n) are NOT — hence Theorem 2.3 still bites for them and
the OPT-dependence of the boosting route remains necessary in general.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.pinned import pinned_argmin


@dataclasses.dataclass
class FiniteResult:
    best_params: jax.Array
    errors: int
    opt: int                      # == errors (exact ERM)
    total_bits: int


def learn_finite(x, y, hyp_params: jax.Array, cls) -> FiniteResult:
    """x, y: [k, m_loc] shards; hyp_params: [H, 4] the finite class."""
    k, mloc = x.shape[0], x.shape[1]
    m = k * mloc

    def player_errors(xi, yi):
        preds = cls.predict(hyp_params, xi)           # [H, m_loc]
        return jnp.sum((preds != yi[None]).astype(jnp.int32), axis=-1)

    per_player = jax.vmap(player_errors)(x, y)        # [k, H]
    totals = per_player.sum(0)                        # [H]
    j = int(pinned_argmin(totals))
    errors = int(totals[j])
    H = hyp_params.shape[0]
    bits = (k * H * max(1, math.ceil(math.log2(max(m, 2))))
            + k * max(1, math.ceil(math.log2(max(H, 2)))))
    return FiniteResult(best_params=hyp_params[j], errors=errors,
                        opt=errors, total_bits=bits)
