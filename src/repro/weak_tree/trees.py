"""Depth-d axis-aligned decision trees grown by weighted histograms.

The protocol is agnostic to the hypothesis class — players ship
coresets, the center ships back ANY weighted-ERM hypothesis and the
wire pays ``hypothesis_bits`` per round (Theorem 4.1's bits scale with
the hypothesis description length, never with m).  Every class the repo
had so far is single-feature, so each scenario was axis-separable; this
class opens the multi-feature regime (XOR / checkerboard / bands —
concepts stumps provably cannot fit) with the LightGBM-style fast path:
per-node weighted feature histograms (``kernels/histogram``) reduced to
best (feature, bin) splits, level by level.

**Fixed-shape, array-encoded.**  A depth-d tree is a complete binary
tree: ``nodes = 2^d − 1`` internal nodes in level order, ``leaves =
2^d``.  Hypothesis encoding — a flat float32 vector (rides the
``erm/erm_batch/ensemble_predict`` contract and the engines' ensemble
buffers unchanged, like the 4-wide classes):

    params = [type=5 | feat_0..feat_{NI−1} | qbin_0..qbin_{NI−1}
              | sign_0..sign_{NL−1}]           (param_dim = 1+2·NI+NL)

Node j at level l (0-indexed flat id ``2^l − 1 + i``) routes a point
right iff ``bin(x[feat_j]) ≥ qbin_j`` where ``bin`` is the fixed
[0, 1)-grid map of kernels/histogram/ref.py — predict evaluates the
SAME comparison the grower optimised, so they can never disagree.  A
``qbin = 0`` split is degenerate (everything right): how an
unsplittable node (empty, pure, or tie) pads out the fixed shape.

**Greedy, not exact.**  Unlike the closed-form 1-D classes, tree ERM is
greedy level-wise split finding — the standard histogram-boosting trade
(exact depth-d ERM is NP-hard).  The stuck certificate is therefore
approximate: a stuck round means GREEDY found no 1/100-good tree.
Quarantine soundness is unaffected (disputed points get the pointwise-
optimal majority vote regardless of why the attempt stuck); only the
communication bound inherits the greedy slack.  Scenario note: greedy
needs the planted boundaries OFF-centre (a perfectly symmetric XOR has
a zero-gain root and greedy degenerates) — core/scenarios.py plants
asymmetric cuts for exactly this reason.

ERM weights follow the repo contract: w ≥ 0 sums to ~1 (mixture/c), a
zero-weight row contributes to no histogram, and an all-zero-weight
call degenerates to loss 0 with the deterministic first-candidate tree.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.histogram import ops as H

TYPE_TREE = 5.0


@dataclasses.dataclass(frozen=True)
class HistogramTrees:
    """H = depth-``depth`` axis trees over [0,1)^F on a ``bins``-bin
    grid.  Hashable (a jit static / scheduler CompatKey component)."""

    num_features: int
    depth: int = 2
    bins: int = 32               # power of two: q/Q thresholds are exact

    # How split finding crosses the wire (core/boost_attempt._center_erm
    # dispatches on it; ledger.py charges it; scheduler.CompatKey hashes
    # it so mixed-mode traffic partitions into separate compile buckets):
    #   "coreset"   — players ship coresets, the center grows on pooled
    #                 examples (the paper's step 2(a) exchange);
    #   "histogram" — players ship per-node weighted histograms, the
    #                 merge is the sum — examples cross the wire only on
    #                 a stuck round (quarantine needs the points);
    #   "voting"    — LightGBM-style parallel voting: players ship top-k
    #                 per-node split proposals, a deterministic election
    #                 picks ≤ 2·topk candidate features, and one merged-
    #                 histogram round runs on the elected columns only.
    comm_mode: str = "coreset"
    vote_topk: int = 2           # proposals per node per player (voting)

    # Streaming tier (docs/streaming.md): when set, every histogram
    # build accumulates over point tiles of this many examples instead
    # of one monolithic [c, F, Q] one-hot — bitwise-equal on the
    # protocol's dyadic weights, hashable like every other field here.
    chunk_size: int | None = None

    # capability protocol (core/tasks.py, serve/scheduler): this class
    # consumes feature rows [.., F] and needs the randomized coreset
    needs_features: bool = dataclasses.field(default=True, init=False,
                                             repr=False)

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError(f"depth must be ≥ 1, got {self.depth}")
        if self.bins < 2 or self.bins & (self.bins - 1):
            raise ValueError(
                f"bins must be a power of two ≥ 2, got {self.bins}")
        if self.comm_mode not in ("coreset", "histogram", "voting"):
            raise ValueError(
                f"comm_mode must be coreset|histogram|voting, "
                f"got {self.comm_mode!r}")
        if self.vote_topk < 1:
            raise ValueError(f"vote_topk must be ≥ 1, got {self.vote_topk}")

    # -- shape/bit accounting ---------------------------------------------

    @property
    def feature_dim(self) -> int:
        return self.num_features

    @property
    def nodes(self) -> int:
        return (1 << self.depth) - 1

    @property
    def leaves(self) -> int:
        return 1 << self.depth

    @property
    def param_dim(self) -> int:
        return 1 + 2 * self.nodes + self.leaves

    @property
    def elected(self) -> int:
        """Candidate features the voting election keeps per node —
        LightGBM's 2·topk cap (every elected feature was in SOME
        player's top-k, so ≤ min(F, k·topk), and 2·topk suffices for
        the majority-vote guarantee)."""
        return min(self.num_features, 2 * self.vote_topk)

    @property
    def bin_bits(self) -> int:
        return int(math.log2(self.bins))

    @property
    def feat_bits(self) -> int:
        return max(1, math.ceil(math.log2(max(self.num_features, 2))))

    @property
    def value_bits(self) -> int:
        """A grid point is F bin ids — what a coreset example costs on
        the wire (ledger.domain_size reads this)."""
        return self.num_features * self.bin_bits

    @property
    def vc_dim(self) -> int:
        """log2|H| = hypothesis_bits bounds the VC dimension of this
        finite class (|H| ≤ (F·Q)^nodes · 2^leaves)."""
        return self.hypothesis_bits()

    def hypothesis_bits(self) -> int:
        """nodes·(⌈log2 F⌉ + bin_bits) + leaves — each internal node
        names a feature and a bin edge, each leaf a sign."""
        return (self.nodes * (self.feat_bits + self.bin_bits)
                + self.leaves)

    # -- prediction --------------------------------------------------------

    def _unpack(self, p: jax.Array):
        ni = self.nodes
        feat = p[1:1 + ni].astype(jnp.int32)
        qbin = p[1 + ni:1 + 2 * ni].astype(jnp.int32)
        sign = p[1 + 2 * ni:1 + 2 * ni + self.leaves]
        return feat, qbin, sign

    def _route(self, feat, qbin, b):
        """b [M, F] bin ids → leaf index [M] (level-order descent)."""
        node = jnp.zeros(b.shape[:-1], jnp.int32)
        for level in range(self.depth):
            flat = node + ((1 << level) - 1)
            f = feat[flat]
            q = qbin[flat]
            xv = jnp.take_along_axis(b, f[..., None], axis=-1)[..., 0]
            node = node * 2 + (xv >= q).astype(jnp.int32)
        return node

    def _predict_one(self, p: jax.Array, x: jax.Array) -> jax.Array:
        feat, qbin, sign = self._unpack(p)
        b = H.bin_index(x, self.bins)
        leaf = self._route(feat, qbin, b)
        return jnp.where(jnp.take(sign, leaf) > 0,
                         jnp.int8(1), jnp.int8(-1))

    def predict(self, params: jax.Array, x: jax.Array) -> jax.Array:
        """params [..., P], x [*pts, F] → int8 ±1 [*param_batch, *pts]."""
        params = jnp.asarray(params)
        if params.ndim == 1:
            return self._predict_one(params, x)
        flat = params.reshape((-1, params.shape[-1]))
        out = jax.vmap(lambda p: self._predict_one(p, x))(flat)
        return out.reshape(params.shape[:-1] + x.shape[:-1])

    # -- the weak learner --------------------------------------------------

    def erm(self, xs: jax.Array, ys: jax.Array, w: jax.Array):
        """Greedy level-wise histogram tree on (xs [c, F], ys, w).

        One ``node_histograms`` launch per level (2^l nodes fold into
        the kernel's node axis; under the engines' task-vmap the whole
        level of all B tasks is one batched contraction).  Returns
        (params [param_dim], loss) with loss = the returned tree's
        weighted error — closed-form from the leaf sums, same float
        values every engine computes (bitwise parity relies on it).
        """
        c = xs.shape[0]
        wy = w * ys.astype(w.dtype)
        b = H.bin_index(xs, self.bins)
        route = jnp.zeros((c,), jnp.int32)
        feats, qbins = [], []
        for level in range(self.depth):
            N = 1 << level
            onnode = (route[:, None]
                      == jnp.arange(N, dtype=jnp.int32)[None])    # [c, N]
            wn = jnp.where(onnode, w[:, None], 0.0).T             # [N, c]
            wyn = jnp.where(onnode, wy[:, None], 0.0).T
            f_n, q_n, _ = H.best_node_splits(xs, wn, wyn, self.bins,
                                             chunk_size=self.chunk_size)
            feats.append(f_n)
            qbins.append(q_n)
            f_pt = f_n[route]
            q_pt = q_n[route]
            xv = jnp.take_along_axis(b, f_pt[:, None], axis=1)[:, 0]
            route = route * 2 + (xv >= q_pt).astype(jnp.int32)
        NL = self.leaves
        onleaf = (route[:, None] == jnp.arange(NL, dtype=jnp.int32)[None])
        w_leaf = jnp.sum(jnp.where(onleaf, w[:, None], 0.0), axis=0)
        wy_leaf = jnp.sum(jnp.where(onleaf, wy[:, None], 0.0), axis=0)
        sign = jnp.where(wy_leaf >= 0, 1.0, -1.0)    # sign(0) := +1
        loss = jnp.sum(0.5 * (w_leaf - jnp.abs(wy_leaf)))
        params = jnp.concatenate(
            [jnp.array([TYPE_TREE], jnp.float32),
             jnp.concatenate(feats).astype(jnp.float32),
             jnp.concatenate(qbins).astype(jnp.float32),
             sign.astype(jnp.float32)])
        return params, loss

    def erm_players(self, cx: jax.Array, cy: jax.Array, pw: jax.Array,
                    *, all_gather=None, interpret=None):
        """Distributed greedy grower — the ``comm_mode`` collectives.

        cx [kp, c, F] float32 / cy [kp, c] int8 ±1: per-player coreset
        shards; pw [kp] float32: per-player per-example weight
        (mixture/c — a dead player carries pw = 0 and contributes zero
        to every histogram and no votes).  With ``chunk_size`` set,
        each player's local histograms accumulate over point tiles —
        bitwise-equal to the monolithic build on the protocol's dyadic
        weights, so the parity contract below is chunking-invariant
        (docs/streaming.md).
        ``all_gather`` pools a [kp, …] per-player array to [k, …] in
        player order (identity when the caller already holds all k
        players — the host and batched engines; the sharded engine
        passes a real ``lax.all_gather``+reshape).  Returns (params
        [param_dim], loss), same encoding as :meth:`erm`.

        Per level, each player builds its local per-node histograms with
        the kernels/histogram triple (kp is the kernel's native batch
        axis); then either

        * **histogram**: gather + sum over the player axis — the merged
          global histogram, reduced to best splits exactly as the
          pooled-coreset grower would (``jnp.sum`` over the gathered
          [k, …] array, NOT a ``psum``: reduction order must not depend
          on mesh topology or bit-parity across engines breaks);
        * **voting**: each player proposes its ``vote_topk`` best
          features per node (stable argsort of per-feature best errors
          ⇒ lowest feature wins local ties); the election counts votes
          of players with pw > 0 and ranks features by
          ``votes·F + (F−1−f)`` — all ranks distinct, so ``lax.top_k``
          is fully deterministic: most votes wins, lowest feature
          breaks vote ties.  One merged-histogram round then runs on
          the ``elected`` columns only.

        Leaves come from the LAST level's merged histograms (prefix
        sums at the chosen split), so no extra payload is needed.  Each
        mode's float path is engine-independent (the parity tests pin
        host ≡ batched ≡ sharded per mode) but the per-player-partial
        summation order differs from the pooled grower's, so modes may
        disagree with each other in the last float bit — by design.
        """
        kp, c = cx.shape[0], cx.shape[1]
        F = self.num_features
        ag = all_gather if all_gather is not None else (lambda a: a)
        w = jnp.broadcast_to(pw[:, None], (kp, c))            # [kp, c]
        wy = w * cy.astype(w.dtype)
        b = H.bin_index(cx, self.bins)                        # [kp, c, F]
        route = jnp.zeros((kp, c), jnp.int32)
        feats, qbins = [], []
        sel = q_n = hw_m = hwy_m = None
        for level in range(self.depth):
            N = 1 << level
            onnode = (route[..., None]
                      == jnp.arange(N, dtype=jnp.int32))      # [kp, c, N]
            wn = jnp.where(onnode, w[..., None], 0.0)
            wyn = jnp.where(onnode, wy[..., None], 0.0)
            hw, hwy = H.node_histograms(
                cx, wn.transpose(0, 2, 1), wyn.transpose(0, 2, 1),
                self.bins, interpret=interpret,
                chunk_size=self.chunk_size)                   # [kp,N,F,Q]
            if self.comm_mode == "voting":
                _, err_f = H.best_splits_per_feature(hw, hwy)  # [kp,N,F]
                prop = jnp.argsort(err_f, axis=-1,
                                   stable=True)[..., :self.vote_topk]
                votes_all = ag(prop)                          # [k,N,topk]
                alive_all = ag(pw > 0)                        # [k]
                onefeat = ((votes_all[..., None]
                            == jnp.arange(F, dtype=jnp.int32))
                           & alive_all[:, None, None, None])
                votes = jnp.sum(onefeat.astype(jnp.int32),
                                axis=(0, 2))                  # [N, F]
                rank = votes * F + jnp.arange(F - 1, -1, -1,
                                              dtype=jnp.int32)
                _, elect = jax.lax.top_k(rank, self.elected)  # [N, E]
                gidx = elect[None, :, :, None]
                hw_e = jnp.take_along_axis(hw, gidx, axis=2)
                hwy_e = jnp.take_along_axis(hwy, gidx, axis=2)
                hw_m = jnp.sum(ag(hw_e), axis=0)              # [N, E, Q]
                hwy_m = jnp.sum(ag(hwy_e), axis=0)
                sel, q_n, _ = H.best_splits_ref(hw_m, hwy_m)
                f_n = jnp.take_along_axis(elect, sel[:, None],
                                          axis=1)[:, 0]
            else:                                             # histogram
                hw_m = jnp.sum(ag(hw), axis=0)                # [N, F, Q]
                hwy_m = jnp.sum(ag(hwy), axis=0)
                f_n, q_n, _ = H.best_splits_ref(hw_m, hwy_m)
                sel = f_n
            feats.append(f_n)
            qbins.append(q_n)
            f_pt = f_n[route]
            q_pt = q_n[route]
            xv = jnp.take_along_axis(b, f_pt[..., None], axis=-1)[..., 0]
            route = route * 2 + (xv >= q_pt).astype(jnp.int32)
        # -- leaves from the last level's merged histograms: the chosen
        # column's prefix sums at q give each child's (w, wy) exactly —
        # children interleave as [left_0, right_0, left_1, …], matching
        # the route*2 + (bin ≥ q) descent above.
        hw_sel = jnp.take_along_axis(
            hw_m, sel[:, None, None], axis=1)[:, 0]           # [N, Q]
        hwy_sel = jnp.take_along_axis(hwy_m, sel[:, None, None],
                                      axis=1)[:, 0]
        cw = jnp.cumsum(hw_sel, axis=-1)
        cwy = jnp.cumsum(hwy_sel, axis=-1)
        left_w = jnp.take_along_axis(cw - hw_sel, q_n[:, None],
                                     axis=-1)[:, 0]
        left_wy = jnp.take_along_axis(cwy - hwy_sel, q_n[:, None],
                                      axis=-1)[:, 0]
        w_leaf = jnp.stack([left_w, cw[:, -1] - left_w],
                           axis=-1).reshape(-1)
        wy_leaf = jnp.stack([left_wy, cwy[:, -1] - left_wy],
                            axis=-1).reshape(-1)
        sign = jnp.where(wy_leaf >= 0, 1.0, -1.0)    # sign(0) := +1
        loss = jnp.sum(0.5 * (w_leaf - jnp.abs(wy_leaf)))
        params = jnp.concatenate(
            [jnp.array([TYPE_TREE], jnp.float32),
             jnp.concatenate(feats).astype(jnp.float32),
             jnp.concatenate(qbins).astype(jnp.float32),
             sign.astype(jnp.float32)])
        return params, loss

    # -- task-generation capability (core/tasks.py) ------------------------

    def sample_points(self, rng: np.random.Generator, m: int):
        """m grid-snapped uniform points of [0, 1)^F (bin centres, so
        every q/Q threshold separates them exactly)."""
        u = rng.random((m, self.num_features))
        return ((np.floor(u * self.bins) + 0.5)
                / self.bins).astype(np.float32)

    def sample_target(self, rng: np.random.Generator, x: np.ndarray):
        """A random tree of this class: uniform node features, interior
        bin cuts and leaf signs (both label classes forced non-empty
        when possible, so targets aren't trivially constant)."""
        feat = rng.integers(0, self.num_features, size=self.nodes)
        qbin = rng.integers(1, self.bins, size=self.nodes)
        sign = rng.choice([-1.0, 1.0], size=self.leaves)
        if np.all(sign == sign[0]):
            sign[rng.integers(self.leaves)] = -sign[0]
        return np.concatenate(
            [[TYPE_TREE], feat, qbin, sign]).astype(np.float32)

    def pack_params(self, feat, qbin, sign) -> np.ndarray:
        """Host-side encoder for planted trees (core/scenarios.py)."""
        feat = np.asarray(feat).reshape(self.nodes)
        qbin = np.asarray(qbin).reshape(self.nodes)
        sign = np.asarray(sign).reshape(self.leaves)
        return np.concatenate(
            [[TYPE_TREE], feat, qbin, sign]).astype(np.float32)
