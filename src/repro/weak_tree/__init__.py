"""Histogram-grown decision-tree weak learners (see trees.py)."""

from repro.weak_tree.trees import TYPE_TREE, HistogramTrees

__all__ = ["HistogramTrees", "TYPE_TREE"]
