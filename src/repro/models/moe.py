"""Mixture-of-Experts FFN: top-k router + two dispatch strategies.

* ``einsum`` (default, GSPMD-friendly): GShard/MaxText-style grouped
  one-hot dispatch.  Tokens are processed in groups of
  ``GROUP_SIZE`` so the dispatch einsum costs
  O(T · E·C_g · D) with C_g = ceil(group·K/E·cf) — linear in total
  tokens, quadratic only in the (fixed) group size.  With experts
  sharded over the ``model`` axis XLA lowers the dispatch to the
  canonical all-to-all pattern.

* ``sort`` (MegaBlocks-style): argsort tokens by expert, gather into
  per-expert capacity buffers, batched expert matmul, scatter-add back.
  No one-hot FLOPs — pure data movement — but the gathers partition
  poorly under GSPMD; used on single-device paths and measured against
  ``einsum`` in the §Perf hillclimb.

Expert weight sharding (see configs/granite): experts axis if
E % model_parallelism == 0 (expert parallel), otherwise the per-expert
hidden dim (tensor parallel inside each expert).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

GROUP_SIZE = 1024


def _num_experts(cfg) -> int:
    """Physical expert count (≥ logical; padded experts never win the
    router because their logits are masked to −inf)."""
    return max(cfg.expert_pad_to, cfg.num_experts)


def init(key, cfg):
    D, E, F = cfg.d_model, _num_experts(cfg), cfg.expert_d_ff
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": L.linear_init(kr, D, cfg.num_experts, scale=0.02),
        "wg": L._normal(kg, (E, D, F)),
        "wu": L._normal(ku, (E, D, F)),
        "wd": L._normal(kd, (E, F, D)),
    }


def _route(p, cfg, x2d):
    """Router logits/softmax in f32. x2d: [T, D] -> gates [T,K], idx [T,K],
    plus aux losses."""
    logits = (x2d.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    # top-k via stable argsort, not lax.top_k: equal-prob experts must
    # resolve to the lowest expert id on every backend (top_k tie order
    # is not a contract; see repro.core.pinned / RL001)
    order = jnp.argsort(-probs, axis=-1, stable=True)
    idx = order[..., :cfg.experts_per_token]                   # [T, K]
    gates = jnp.take_along_axis(probs, idx, axis=-1)
    gates = gates / jnp.maximum(
        jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # load-balance aux (Switch): E * Σ_e fraction_tokens(e)·mean_prob(e)
    E = cfg.num_experts
    onehot = jax.nn.one_hot(idx[:, 0], E)                      # top-1 counts
    load = onehot.mean(0)
    importance = probs.mean(0)
    aux = E * jnp.sum(load * importance)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, idx, (cfg.router_aux_weight * aux
                        + cfg.router_z_weight * zloss)


def _einsum_moe(p, cfg, xg, exact=False):
    """xg: [G, Tg, D] grouped tokens.  exact=True sizes capacity for the
    zero-drop worst case (serving: a decode step must be deterministic
    and lossless; Tg is tiny there so C = Tg·K is cheap)."""
    G, Tg, D = xg.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    Ep = _num_experts(cfg)                 # physical (maybe padded)
    if exact:
        C = Tg * K
    else:
        C = max(1, int(Tg * K / E * cfg.capacity_factor))
    gates, idx, aux = jax.vmap(
        lambda g: _route(p, cfg, g), in_axes=0)(xg)
    dispatch = jnp.zeros((G, Tg, Ep, C), jnp.bfloat16)
    combine = jnp.zeros((G, Tg, Ep, C), jnp.float32)
    offset = jnp.zeros((G, Ep), jnp.int32)
    for kk in range(K):
        oh = jax.nn.one_hot(idx[..., kk], Ep, dtype=jnp.int32)  # [G,Tg,Ep]
        pos = jnp.cumsum(oh, axis=1) - 1 + offset[:, None, :]
        offset = offset + oh.sum(axis=1)
        keep = (pos < C) & (oh > 0)
        sel = jax.nn.one_hot(jnp.clip(pos, 0, C - 1), C,
                             dtype=jnp.bfloat16)               # [G,Tg,E,C]
        sel = sel * keep[..., None].astype(jnp.bfloat16) \
            * oh[..., None].astype(jnp.bfloat16)
        dispatch = dispatch + sel
        combine = combine + sel.astype(jnp.float32) \
            * gates[..., kk][..., None, None]
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg.astype(jnp.bfloat16))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                               p["wg"].astype(jnp.bfloat16)))
    u = jnp.einsum("gecd,edf->gecf", xe, p["wu"].astype(jnp.bfloat16))
    ye = jnp.einsum("gecf,efd->gecd", h * u, p["wd"].astype(jnp.bfloat16))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(jnp.bfloat16), ye)
    return y, jnp.mean(aux)


def _sort_moe(p, cfg, x2d, exact=False):
    """x2d: [T, D] — gather/scatter dispatch, no one-hot FLOPs."""
    T, D = x2d.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    Ep = _num_experts(cfg)
    C = T * K if exact else max(1, int(T * K / E * cfg.capacity_factor))
    gates, idx, aux = _route(p, cfg, x2d)
    flat_e = idx.reshape(-1)                        # [T*K] expert ids
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)  # token per slot
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    start = jnp.searchsorted(e_sorted, jnp.arange(Ep, dtype=jnp.int32))
    pos = (jnp.arange(T * K, dtype=jnp.int32)
           - start[e_sorted])                       # rank within expert
    keep = pos < C
    slot = jnp.where(keep, e_sorted * C + pos, Ep * C)  # Ep*C = drop bin
    xe_flat = jnp.zeros((Ep * C + 1, D), jnp.bfloat16).at[slot].set(
        x2d[flat_t[order]].astype(jnp.bfloat16))
    xe = xe_flat[:Ep * C].reshape(Ep, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                               p["wg"].astype(jnp.bfloat16)))
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(jnp.bfloat16))
    ye = jnp.einsum("ecf,efd->ecd", h * u,
                    p["wd"].astype(jnp.bfloat16)).reshape(Ep * C, D)
    contrib = ye[jnp.where(keep, slot, 0)] \
        * (flat_g[order] * keep)[:, None].astype(jnp.bfloat16)
    y = jnp.zeros((T, D), jnp.float32).at[flat_t[order]].add(
        contrib.astype(jnp.float32))
    return y.astype(x2d.dtype), aux


def apply(p, cfg, x, exact=None):
    """x: [B, S, D] -> (y, aux_loss).

    exact defaults to True for single-token (decode) calls: serving must
    be drop-free; training uses the capacity factor.
    """
    B, S, D = x.shape
    T = B * S
    if exact is None:
        exact = S == 1
    if cfg.moe_dispatch == "sort":
        y, aux = _sort_moe(p, cfg, x.reshape(T, D), exact=exact)
        return y.reshape(B, S, D), aux
    g = max(1, T // GROUP_SIZE) if T >= GROUP_SIZE else 1
    while T % g:
        g -= 1
    xg = x.reshape(g, T // g, D)
    y, aux = _einsum_moe(p, cfg, xg, exact=exact)
    return y.reshape(B, S, D).astype(x.dtype), aux
