"""Primitive layers: linear, norms, RoPE, SwiGLU MLP, embeddings.

Functional style: every module is an ``init(key, ...) -> params`` plus a
pure ``apply(params, x, ...)``.  Params are stored float32; forward
computation runs in the config compute dtype (bf16 on TPU) with f32
accumulation where it matters (norms, softmax, logits).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Initializer = jax.nn.initializers.Initializer


def _normal(key, shape, scale=0.02):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def linear_init(key, in_dim: int, out_dim: int, bias: bool = False,
                scale: float = 0.02):
    p = {"w": _normal(key, (in_dim, out_dim), scale)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), jnp.float32)
    return p


def linear(p, x, dtype=jnp.bfloat16):
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rms_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def rms_norm_scaleless(x, eps: float = 1e-5):
    """Per-head qk-norm without learned scale (qwen3-style uses learned;
    we fold the learned scale in via rmsnorm params on head_dim)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def layernorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layer_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE.  x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) *
                    jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs    # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                          # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    if 2 * half != hd:                                        # odd head_dim
        out = jnp.concatenate([out, x[..., 2 * half:].astype(jnp.float32)],
                              axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": linear_init(kg, d_model, d_ff),
        "wu": linear_init(ku, d_model, d_ff),
        "wd": linear_init(kd, d_ff, d_model),
    }


def mlp(p, x, dtype=jnp.bfloat16):
    g = jax.nn.silu(linear(p["wg"], x, dtype))
    u = linear(p["wu"], x, dtype)
    return linear(p["wd"], g * u, dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int):
    return {"emb": _normal(key, (vocab, d_model), 0.02)}


def embed(p, tokens, dtype=jnp.bfloat16):
    return jnp.take(p["emb"], tokens, axis=0).astype(dtype)


def unembed(p, x, dtype=jnp.bfloat16):
    """Logits in f32 (loss stability)."""
    return (x.astype(dtype) @ p["emb"].T.astype(dtype)).astype(jnp.float32)
