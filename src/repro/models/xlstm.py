"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

TPU adaptation (DESIGN.md §2): the xLSTM paper's CUDA kernels fuse the
recurrence; here

* mLSTM training/prefill uses the *chunkwise-parallel* form — dense
  (stabilized) gate matrices within a chunk of 256 tokens (MXU
  matmuls), recurrent (C, n, m) state across chunks, so the workspace
  is O(B·H·L²) not O(B·H·S²); decode uses the O(1) recurrent update.
* sLSTM is inherently sequential (recurrent R matrices): training uses
  ``jax.lax.scan`` over time; decode is a single step.

Shapes: d_model D, H heads, hd = D/H.
mLSTM state: C [B,H,hd,hd], n [B,H,hd], m [B,H].
sLSTM state: h,c,n [B,D], m [B,D].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg):
    D = cfg.d_model
    di = 2 * D
    ks = jax.random.split(key, 8)
    return {
        "up": L.linear_init(ks[0], D, 2 * di),       # [x_m, z-gate]
        "wq": L.linear_init(ks[1], di, di),
        "wk": L.linear_init(ks[2], di, di),
        "wv": L.linear_init(ks[3], di, di),
        "wi": L.linear_init(ks[4], di, cfg.num_heads, bias=True),
        "wf": L.linear_init(ks[5], di, cfg.num_heads, bias=True),
        "norm": L.rmsnorm_init(di),
        "down": L.linear_init(ks[6], di, D),
    }


def _mlstm_qkv(p, cfg, xm):
    B, S, di = xm.shape
    H = cfg.num_heads
    hd = di // H
    q = L.linear(p["wq"], xm).reshape(B, S, H, hd)
    k = L.linear(p["wk"], xm).reshape(B, S, H, hd) / jnp.sqrt(float(hd))
    v = L.linear(p["wv"], xm).reshape(B, S, H, hd)
    logi = L.linear(p["wi"], xm).astype(jnp.float32)        # [B,S,H]
    logf = jax.nn.log_sigmoid(
        L.linear(p["wf"], xm).astype(jnp.float32))          # [B,S,H]
    return q, k, v, logi, logf


MLSTM_CHUNK = 256


def mlstm_forward(p, cfg, x):
    """Chunkwise-parallel form (linear-attention style).

    Within a chunk of L tokens the stabilized gate matrix
    E_ts = exp(a_s - M_t), with a_s = i_s - F_s and
    M_t = max(cummax(a)_t, m_prev), has entries <= 1 (overflow-free) and
    the local cumulative forget F_t cancels out of every term except the
    carried stabilizer m_new = F_L + M_L.  Across chunks the (C, n, m)
    state is carried recurrently, so the workspace is O(B*H*L^2) instead
    of O(B*H*S^2):

      num_t = sum_{s<=t} E_ts (q_t.k_s) v_s + exp(m_prev - M_t) q_t C_prev
      qn_t  = sum_{s<=t} E_ts (q_t.k_s)      + exp(m_prev - M_t) q_t.n_prev
      h_t   = num_t / max(|qn_t|, exp(-(F_t + M_t)))
      C_new = exp(m_prev - M_L) C_prev + sum_s exp(a_s - M_L) k_s v_s^T
    """
    B, S, D = x.shape
    xz = L.linear(p["up"], x)
    xm, z = jnp.split(xz, 2, axis=-1)
    q, k, v, logi, logf = _mlstm_qkv(p, cfg, xm)
    H, hd = q.shape[2], q.shape[3]
    Lc = min(MLSTM_CHUNK, S)
    pad = (-S) % Lc
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                       constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
    nch = (S + pad) // Lc

    def chunks(t):
        return t.reshape((B, nch, Lc) + t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = map(chunks, (q, k, v))               # [nch,B,L,H,hd]
    lic, lfc = map(chunks, (logi, logf))              # [nch,B,L,H]
    tril = jnp.tril(jnp.ones((Lc, Lc), bool))

    def chunk_step(state, inputs):
        C, n, m_prev = state                          # [B,H,hd,hd],[B,H,hd],[B,H]
        qq, kk, vv, li, lf = inputs
        F = jnp.cumsum(lf, axis=1)                    # [B,L,H]
        a = li - F
        M = jnp.maximum(jax.lax.associative_scan(jnp.maximum, a, axis=1),
                        m_prev[:, None])              # [B,L,H]
        E = jnp.exp(a[:, None] - M[:, :, None])       # [B,t,s,H]
        E = jnp.where(tril[None, :, :, None], E, 0.0)
        qk = jnp.einsum("bthd,bshd->btsh", qq, kk,
                        preferred_element_type=jnp.float32)
        intra = qk * E                                # [B,t,s,H]
        carry = jnp.exp(jnp.minimum(m_prev[:, None] - M, 0.0))  # [B,L,H]
        qf = qq.astype(jnp.float32)
        num = (jnp.einsum("btsh,bshd->bthd", intra, vv.astype(jnp.float32))
               + jnp.einsum("bthd,bhde->bthe", qf, C) * carry[..., None])
        qn = (jnp.einsum("btsh->bth", intra)
              + jnp.einsum("bthd,bhd->bth", qf, n) * carry)
        floor = jnp.exp(jnp.minimum(-(F + M), 30.0))
        h = num / jnp.maximum(jnp.abs(qn), floor)[..., None]
        # ---- state update to chunk end -------------------------------
        M_L, F_L = M[:, -1], F[:, -1]                 # [B,H]
        w = jnp.exp(a - M_L[:, None])                 # [B,L,H] (<= 1)
        kw = kk.astype(jnp.float32) * w[..., None]
        C_new = (C * jnp.exp(jnp.minimum(m_prev - M_L, 0.0))[..., None, None]
                 + jnp.einsum("bshd,bshe->bhde", kw, vv.astype(jnp.float32)))
        n_new = (n * jnp.exp(jnp.minimum(m_prev - M_L, 0.0))[..., None]
                 + jnp.sum(kw, axis=1))
        m_new = F_L + M_L
        return (C_new, n_new, m_new), h.astype(x.dtype)

    state0 = (jnp.zeros((B, H, hd, hd), jnp.float32),
              jnp.zeros((B, H, hd), jnp.float32),
              jnp.full((B, H), -1e30, jnp.float32))
    # padding is state-exact: padded logi = −1e30 (no input) and padded
    # logf = 0 = log 1 (no forgetting).
    (C, n, m), hs = jax.lax.scan(chunk_step, state0,
                                 (qc, kc, vc, lic, lfc))
    out = hs.swapaxes(0, 1).reshape(B, S + pad, H * hd)[:, :S]
    out = L.rms_norm(p["norm"], out, cfg.norm_eps)
    y = L.linear(p["down"], out * jax.nn.silu(z))
    return y, {"C": C, "n": n, "m": m}


def mlstm_init_state(cfg, batch: int):
    di = 2 * cfg.d_model
    H = cfg.num_heads
    hd = di // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(p, cfg, x, state):
    """x: [B, 1, D] -> (y, new_state) — O(1) per token."""
    xz = L.linear(p["up"], x)
    xm, z = jnp.split(xz, 2, axis=-1)
    q, k, v, logi, logf = _mlstm_qkv(p, cfg, xm)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                     # [B,H,hd]
    logi, logf = logi[:, 0], logf[:, 0]                     # [B,H]
    m_new = jnp.maximum(logf + state["m"], logi)
    fg = jnp.exp(logf + state["m"] - m_new)[..., None]
    ig = jnp.exp(logi - m_new)[..., None]
    C = state["C"] * fg[..., None] + ig[..., None] \
        * (k[..., :, None] * v[..., None, :]).astype(jnp.float32)
    n = state["n"] * fg + ig * k.astype(jnp.float32)
    num = jnp.einsum("bhkv,bhk->bhv", C, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n,
                                         q.astype(jnp.float32))),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(x.shape[0], 1, -1).astype(x.dtype)
    y = L.rms_norm(p["norm"], y, cfg.norm_eps)
    out = L.linear(p["down"], y * jax.nn.silu(z))
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg):
    D = cfg.d_model
    H = cfg.num_heads
    hd = D // H
    ks = jax.random.split(key, 7)
    return {
        "wx": L.linear_init(ks[0], D, 4 * D, bias=True),   # i,f,z,o from x
        "r": L._normal(ks[1], (4, H, hd, hd), 0.02),       # recurrent, blockdiag
        "norm": L.rmsnorm_init(D),
        "up": L.linear_init(ks[2], D, 2 * ((4 * D) // 3)),
        "down": L.linear_init(ks[3], (4 * D) // 3, D),
    }


def _slstm_step(p, cfg, xt, state):
    """xt: [B, 4D] pre-activations from x; state: (h,c,n,m) [B,D] each."""
    h, c, n, m = state
    B, D = h.shape
    H = cfg.num_heads
    hd = D // H
    hh = h.reshape(B, H, hd)
    rec = jnp.einsum("bhd,ghde->gbhe", hh.astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(4, B, D)
    pre = xt.astype(jnp.float32).reshape(B, 4, D).transpose(1, 0, 2) + rec
    li, lf, z, o = pre[0], pre[1], jnp.tanh(pre[2]), jax.nn.sigmoid(pre[3])
    lf = jax.nn.log_sigmoid(lf)
    m_new = jnp.maximum(lf + m, li)
    ig = jnp.exp(li - m_new)
    fg = jnp.exp(lf + m - m_new)
    c_new = fg * c + ig * z
    n_new = fg * n + ig
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    return (h_new, c_new, n_new, m_new)


def slstm_forward(p, cfg, x):
    """x: [B, S, D] — recurrent scan over time."""
    B, S, D = x.shape
    xg = L.linear(p["wx"], x)                               # [B,S,4D]
    state0 = tuple(jnp.zeros((B, D), jnp.float32) for _ in range(3)) \
        + (jnp.full((B, D), -1e30, jnp.float32),)
    state0 = (state0[0], state0[1], state0[2], state0[3])

    def step(st, xt):
        st = _slstm_step(p, cfg, xt, st)
        return st, st[0]

    state, hs = jax.lax.scan(step, state0, xg.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)               # [B,S,D]
    y = L.rms_norm(p["norm"], y, cfg.norm_eps)
    gu = L.linear(p["up"], y)
    g, u = jnp.split(gu, 2, axis=-1)
    out = L.linear(p["down"], jax.nn.gelu(g) * u)
    return out, dict(zip(("h", "c", "n", "m"), state))


def slstm_init_state(cfg, batch: int):
    D = cfg.d_model
    return (jnp.zeros((batch, D), jnp.float32),
            jnp.zeros((batch, D), jnp.float32),
            jnp.zeros((batch, D), jnp.float32),
            jnp.full((batch, D), -1e30, jnp.float32))


def slstm_decode(p, cfg, x, state):
    """x: [B, 1, D] -> (y, new_state)."""
    xg = L.linear(p["wx"], x)[:, 0]
    state = _slstm_step(p, cfg, xg, state)
    y = state[0][:, None].astype(x.dtype)
    y = L.rms_norm(p["norm"], y, cfg.norm_eps)
    gu = L.linear(p["up"], y)
    g, u = jnp.split(gu, 2, axis=-1)
    return L.linear(p["down"], jax.nn.gelu(g) * u), state
