"""Encoder–decoder stack (seamless-m4t): bidirectional encoder over stub
frame embeddings, causal decoder with cross-attention.

Serving: ``prefill`` encodes the (long) source once and precomputes the
cross-attention K/V; each decode step then costs O(L_enc · d) for the
cross-attention read plus O(decoded) self-attention — sub-quadratic per
token, which is why long_500k runs for this arch (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers as L
from repro.models.transformer import scan_unroll


def _enc_layer_init(key, cfg):
    ka, kf = jax.random.split(key)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model),
        "attn": attention.init(ka, cfg),
        "norm2": L.rmsnorm_init(cfg.d_model),
        "ffn": L.mlp_init(kf, cfg.d_model, cfg.d_ff),
    }


def _dec_layer_init(key, cfg):
    ka, kc, kf = jax.random.split(key, 3)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model),
        "self_attn": attention.init(ka, cfg),
        "norm_x": L.rmsnorm_init(cfg.d_model),
        "cross_attn": attention.init(kc, cfg, cross=True),
        "norm2": L.rmsnorm_init(cfg.d_model),
        "ffn": L.mlp_init(kf, cfg.d_model, cfg.d_ff),
    }


def init_params(key, cfg):
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(
        jax.random.split(ke, cfg.encoder_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(
        jax.random.split(kd, cfg.num_layers))
    return {
        "encoder": enc,
        "decoder": dec,
        "embed": L.embed_init(kt, cfg.padded_vocab, cfg.d_model),
        "enc_norm": L.rmsnorm_init(cfg.d_model),
        "final_norm": L.rmsnorm_init(cfg.d_model),
        "lm_head": L.linear_init(kh, cfg.d_model, cfg.padded_vocab),
    }


def encode(params, cfg, frames):
    """frames: [B, Se, D] stub frontend embeddings -> [B, Se, D]."""
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)[None, :]

    def body(h, lp):
        hn = L.rms_norm(lp["norm1"], h, cfg.norm_eps)
        out, _, _ = attention.full_attention(
            lp["attn"], cfg, hn, positions, causal=False)
        h = h + out
        h = h + L.mlp(lp["ffn"], L.rms_norm(lp["norm2"], h, cfg.norm_eps))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, frames.astype(jnp.bfloat16),
                        params["encoder"], unroll=scan_unroll())
    return L.rms_norm(params["enc_norm"], h, cfg.norm_eps)


def decode_train(params, cfg, enc_out, tokens):
    """Teacher-forced decoder.  tokens: [B, St] -> logits [B, St, Vp]."""
    h = L.embed(params["embed"], tokens)
    St = tokens.shape[1]
    positions = jnp.arange(St, dtype=jnp.int32)[None, :]
    enc_positions = jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None, :]

    def body(h, lp):
        hn = L.rms_norm(lp["norm1"], h, cfg.norm_eps)
        out, _, _ = attention.full_attention(
            lp["self_attn"], cfg, hn, positions, causal=True)
        h = h + out
        hn = L.rms_norm(lp["norm_x"], h, cfg.norm_eps)
        out, _, _ = attention.full_attention(
            lp["cross_attn"], cfg, hn, positions, causal=False,
            kv_x=enc_out, kv_positions=enc_positions, use_rope=False)
        h = h + out
        h = h + L.mlp(lp["ffn"], L.rms_norm(lp["norm2"], h, cfg.norm_eps))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["decoder"],
                        unroll=scan_unroll())
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    return L.linear(params["lm_head"], h).astype(jnp.float32), jnp.float32(0)


def forward(params, cfg, frames, tokens):
    enc_out = encode(params, cfg, frames)
    return decode_train(params, cfg, enc_out, tokens)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def build_cross_cache(params, cfg, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output."""

    def per_layer(lp):
        B, T = enc_out.shape[0], enc_out.shape[1]
        KV, hd = cfg.num_kv_heads, cfg.hd
        k = L.linear(lp["cross_attn"]["wk"], enc_out).reshape(B, T, KV, hd)
        v = L.linear(lp["cross_attn"]["wv"], enc_out).reshape(B, T, KV, hd)
        return {"k": k, "v": v}

    return jax.lax.map(per_layer, params["decoder"])


def init_self_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16,
                    filled: bool = False):
    c = jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (cfg.num_layers,) + leaf.shape),
        attention.init_cache(cfg, batch, capacity, dtype))
    c["len"] = jnp.full((cfg.num_layers, batch),
                        capacity if filled else 0, jnp.int32)
    return c


def decode_step(params, cfg, cross_cache, self_cache, tokens):
    """One decoder token against cached encoder K/V.

    tokens: [B, 1] -> (logits [B, Vp], new self_cache).
    """
    h = L.embed(params["embed"], tokens)

    def body(hh, xs):
        lp, cc, sc = xs
        hn = L.rms_norm(lp["norm1"], hh, cfg.norm_eps)
        out, new_sc = attention.decode_attention(
            lp["self_attn"], cfg, hn, sc)
        hh = hh + out
        hn = L.rms_norm(lp["norm_x"], hh, cfg.norm_eps)
        hh = hh + attention.cross_decode_attention(
            lp["cross_attn"], cfg, hn, cc)
        hh = hh + L.mlp(lp["ffn"],
                        L.rms_norm(lp["norm2"], hh, cfg.norm_eps))
        return hh, new_sc

    h, new_cache = jax.lax.scan(
        body, h, (params["decoder"], cross_cache, self_cache),
        unroll=scan_unroll())
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = L.linear(params["lm_head"], h).astype(jnp.float32)
    return logits[:, 0], new_cache
