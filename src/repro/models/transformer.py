"""Decoder-only stack assembly: scan over superblocks.

A *superblock* is one repetition of ``cfg.block_pattern`` (dense archs:
a single (attn, mlp) layer; jamba: 8 heterogeneous layers; xlstm: 8
m/sLSTM blocks).  Parameters of each pattern position are stacked with a
leading [num_superblocks] axis and the stack is driven by
``jax.lax.scan`` — the lowered HLO contains each distinct layer body
once, keeping 40-compile dry-runs tractable and matching how production
JAX LLMs (MaxText et al.) scan layers.

Modes:
* ``forward``       — training forward, logits over the full sequence.
* ``prefill``       — forward + returns the KV/state cache.
* ``decode_step``   — one token, O(1)/O(window)/O(L_enc) per step.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.models import attention, layers as L, moe, ssm, xlstm


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key, cfg, mixer: str, ffn: str):
    km, kf = jax.random.split(key)
    p = {"norm1": L.rmsnorm_init(cfg.d_model)}
    if mixer == "attn":
        p["mixer"] = attention.init(km, cfg)
    elif mixer == "mamba":
        p["mixer"] = ssm.init(km, cfg)
    elif mixer == "mlstm":
        p["mixer"] = xlstm.mlstm_init(km, cfg)
    elif mixer == "slstm":
        p["mixer"] = xlstm.slstm_init(km, cfg)
    else:
        raise ValueError(mixer)
    if ffn == "mlp":
        p["norm2"] = L.rmsnorm_init(cfg.d_model)
        p["ffn"] = L.mlp_init(kf, cfg.d_model, cfg.d_ff)
    elif ffn == "moe":
        p["norm2"] = L.rmsnorm_init(cfg.d_model)
        p["ffn"] = moe.init(kf, cfg)
    return p


def init_params(key, cfg):
    ks = jax.random.split(key, cfg.pattern_len + 3)
    blocks = []
    for i, (mixer, ffn) in enumerate(cfg.block_pattern):
        stacked = jax.vmap(
            lambda kk: _block_init(kk, cfg, mixer, ffn))(
            jax.random.split(ks[i], cfg.num_superblocks))
        blocks.append(stacked)
    params = {
        "embed": L.embed_init(ks[-3], cfg.padded_vocab, cfg.d_model),
        "blocks": tuple(blocks),
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.linear_init(ks[-2], cfg.d_model,
                                          cfg.padded_vocab)
    return params


# ---------------------------------------------------------------------------
# Forward / prefill
# ---------------------------------------------------------------------------

def _apply_block(p, cfg, mixer, ffn, h, positions, *, window, use_flash,
                 collect_cache):
    """One pattern position on the full sequence."""
    cache_out = None
    hn = L.rms_norm(p["norm1"], h, cfg.norm_eps)
    if mixer == "attn":
        out, k, v = attention.full_attention(
            p["mixer"], cfg, hn, positions, causal=True, window=window,
            use_flash=use_flash, constrain_layout=collect_cache)
        if collect_cache:
            cache_out = {"k": k, "v": v}
    elif mixer == "mamba":
        out, state = ssm.forward(p["mixer"], cfg, hn)
        if collect_cache:
            cache_out = state
    elif mixer == "mlstm":
        out, state = xlstm.mlstm_forward(p["mixer"], cfg, hn)
        if collect_cache:
            cache_out = state
    elif mixer == "slstm":
        out, state = xlstm.slstm_forward(p["mixer"], cfg, hn)
        if collect_cache:
            cache_out = state
    h = h + out
    aux = jnp.float32(0)
    if ffn == "mlp":
        h = h + L.mlp(p["ffn"], L.rms_norm(p["norm2"], h, cfg.norm_eps))
    elif ffn == "moe":
        y, aux = moe.apply(p["ffn"], cfg,
                           L.rms_norm(p["norm2"], h, cfg.norm_eps))
        h = h + y
    return h, aux, cache_out


def _stack_forward(params, cfg, h, positions, *, window=0, use_flash=False,
                   collect_cache=False):
    """Scan superblocks.  Returns (h, aux_sum, caches or None)."""

    def body(carry, xs):
        hh, aux = carry
        caches = []
        for i, (mixer, ffn) in enumerate(cfg.block_pattern):
            hh, a, c = _apply_block(
                xs[i], cfg, mixer, ffn, hh, positions,
                window=window, use_flash=use_flash,
                collect_cache=collect_cache)
            aux = aux + a
            caches.append(c)
        return (hh, aux), tuple(caches)

    if cfg.remat and not collect_cache:
        body = jax.checkpoint(body)
    (h, aux), caches = jax.lax.scan(
        body, (h, jnp.float32(0)), params["blocks"],
        unroll=scan_unroll())
    return h, aux, caches if collect_cache else None


def scan_unroll():
    """Dry-run hook: REPRO_SCAN_UNROLL=full unrolls layer scans so the
    compiled HLO's cost analysis counts every layer (XLA counts a while
    body once, which would hide ~all layer FLOPs from the roofline)."""
    v = os.environ.get("REPRO_SCAN_UNROLL", "1")
    if v == "full":
        return True
    return max(int(v), 1)


def forward(params, cfg, tokens, prefix_embeds=None, use_flash=False):
    """Training forward.  tokens: [B, St] -> logits [B, S, Vp], aux."""
    h = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    h, aux, _ = _stack_forward(params, cfg, h, positions,
                               window=cfg.sliding_window,
                               use_flash=use_flash)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], h)
    else:
        logits = L.linear(params["lm_head"], h).astype(jnp.float32)
    return logits, aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16,
               filled: bool = True):
    """Cache pytree matching the superblock structure.

    capacity: KV slots for attention layers (ring if sliding window).
    filled=True marks the cache as holding ``capacity`` live positions
    (the dry-run decode shapes: "one new token against a cache of S").
    """
    nsb = cfg.num_superblocks

    def stack(make):
        return jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf, (nsb,) + leaf.shape),
            make())

    caches = []
    ln = jnp.full((batch,), capacity if filled else 0, jnp.int32)
    for mixer, _ in cfg.block_pattern:
        if mixer == "attn":
            c = stack(lambda: attention.init_cache(cfg, batch, capacity,
                                                   dtype))
            c["len"] = jnp.broadcast_to(ln, (nsb, batch))
        elif mixer == "mamba":
            c = stack(lambda: ssm.init_state(cfg, batch, dtype))
        elif mixer == "mlstm":
            c = stack(lambda: xlstm.mlstm_init_state(cfg, batch))
        elif mixer == "slstm":
            c = stack(lambda: dict(zip(
                ("h", "c", "n", "m"), xlstm.slstm_init_state(cfg, batch))))
        caches.append(c)
    return tuple(caches)


def prefill(params, cfg, tokens, prefix_embeds=None, use_flash=False,
            window=0):
    """Full-sequence forward that also returns the serving cache."""
    h = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    h, aux, caches = _stack_forward(
        params, cfg, h, positions, window=window or cfg.sliding_window,
        use_flash=use_flash, collect_cache=True)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    last = h[:, -1:]
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], last)
    else:
        logits = L.linear(params["lm_head"], last).astype(jnp.float32)
    # normalize attn caches: add "len"
    out_caches = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        c = caches[i]
        if mixer == "attn":
            B = tokens.shape[0]
            c = {"k": c["k"], "v": c["v"],
                 "len": jnp.full((cfg.num_superblocks, B), S, jnp.int32)}
        out_caches.append(c)
    return logits[:, 0], aux, tuple(out_caches)


def decode_step(params, cfg, caches, tokens, *, window=0):
    """One-token decode.  tokens: [B, 1] -> (logits [B, Vp], new caches)."""
    h = L.embed(params["embed"], tokens)

    def body(carry, xs):
        hh = carry
        block_params, cache = xs
        new_caches = []
        for i, (mixer, ffn) in enumerate(cfg.block_pattern):
            p = block_params[i]
            hn = L.rms_norm(p["norm1"], hh, cfg.norm_eps)
            if mixer == "attn":
                out, nc = attention.decode_attention(
                    p["mixer"], cfg, hn, cache[i], window=window)
            elif mixer == "mamba":
                out, nc = ssm.decode_step(p["mixer"], cfg, hn, cache[i])
            elif mixer == "mlstm":
                out, nc = xlstm.mlstm_decode(p["mixer"], cfg, hn, cache[i])
            elif mixer == "slstm":
                st = (cache[i]["h"], cache[i]["c"], cache[i]["n"],
                      cache[i]["m"])
                out, st = xlstm.slstm_decode(p["mixer"], cfg, hn, st)
                nc = dict(zip(("h", "c", "n", "m"), st))
            hh = hh + out
            if ffn == "mlp":
                hh = hh + L.mlp(p["ffn"],
                                L.rms_norm(p["norm2"], hh, cfg.norm_eps))
            elif ffn == "moe":
                y, _ = moe.apply(p["ffn"], cfg,
                                 L.rms_norm(p["norm2"], hh, cfg.norm_eps))
                hh = hh + y
            new_caches.append(nc)
        return hh, tuple(new_caches)

    h, new_caches = jax.lax.scan(body, h, (params["blocks"], caches),
                                 unroll=scan_unroll())
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], h)
    else:
        logits = L.linear(params["lm_head"], h).astype(jnp.float32)
    return logits[:, 0], new_caches
