"""GQA attention with KV cache, sliding window, optional qk-norm,
cross-attention, and a pluggable flash kernel.

Layouts:
  q:      [B, S, H,  hd]
  k, v:   [B, T, KV, hd]
  cache:  {"k": [B, C, KV, hd], "v": [B, C, KV, hd], "len": int32[B]}
The decode step writes at position ``len % C`` (ring buffer — exact for
sliding-window attention; for full attention callers guarantee
len < C, which every serve shape in this repo satisfies by
construction).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L

NEG_INF = -1e30


def _tp_size() -> int:
    """Tensor-parallel degree hint (set by the launcher/dry-run).

    GSPMD left alone may split the head_dim contraction when
    KV·hd is sharded wider than the KV head count — which turns the
    attention softmax into S×S-sized cross-shard all-reduces (we
    measured 32 × 25.8 GB on granite prefill_32k, §Perf G-P3).  With the
    hint we constrain q/k/v layouts so heads shard only when they
    divide the axis, and K/V replicate otherwise (one small all-gather
    instead).
    """
    return int(os.environ.get("REPRO_TP_SIZE", "0"))


def _constrain_heads(t: jax.Array) -> jax.Array:
    """t: [B, S, H, hd] — shard H over 'model' iff divisible, else
    replicate on the model axis."""
    tp = _tp_size()
    if not tp:
        return t
    if t.shape[2] % tp == 0:
        return jax.lax.with_sharding_constraint(
            t, P(None, None, "model", None))
    return jax.lax.with_sharding_constraint(t, P(None, None, None, None))


def init(key, cfg, cross: bool = False):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": L.linear_init(ks[0], D, H * hd, bias=cfg.attn_bias),
        "wk": L.linear_init(ks[1], D, KV * hd, bias=cfg.attn_bias),
        "wv": L.linear_init(ks[2], D, KV * hd, bias=cfg.attn_bias),
        "wo": L.linear_init(ks[3], H * hd, D, bias=cfg.attn_bias),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = L.rmsnorm_init(hd)
        p["k_norm"] = L.rmsnorm_init(hd)
    return p


def _project_qkv(p, cfg, x, kv_x, positions, kv_positions, use_rope=True,
                 constrain_layout=False):
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = L.linear(p["wq"], x).reshape(B, -1, H, hd)
    k = L.linear(p["wk"], kv_x).reshape(B, -1, KV, hd)
    v = L.linear(p["wv"], kv_x).reshape(B, -1, KV, hd)
    if constrain_layout and getattr(cfg, "attn_layout_constraint", False):
        # Serving paths only, per-arch opt-in: in training the same
        # constraint regresses (backward + remat re-issue the gathers;
        # +13 s collective on granite train_4k), and even in serving it
        # is arch-dependent (−75 % collective on granite prefill,
        # REGRESSION on phi3.5 where GSPMD's own choice was better).
        q, k, v = map(_constrain_heads, (q, k, v))
    if "q_norm" in p:
        q = L.rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = L.rms_norm(p["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def gqa_scores_mask(q, k, v, mask):
    """Reference XLA attention (einsum path).  mask: [B, 1|G?, S, T] bool."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H * hd)


def causal_mask(S: int, T: int, offset: int = 0, window: int = 0):
    """[S, T] bool; query i attends key j iff j ≤ i+offset and, with a
    window, j > i+offset−window."""
    i = jnp.arange(S, dtype=jnp.int32)[:, None] + offset
    j = jnp.arange(T, dtype=jnp.int32)[None, :]
    m = j <= i
    if window > 0:
        m &= j > (i - window)
    return m


def full_attention(p, cfg, x, positions, *, causal=True, window=0,
                   kv_x=None, kv_positions=None, use_rope=True,
                   use_flash=False, constrain_layout=False):
    """Training / prefill / encoder attention over a full sequence.

    Returns (out [B,S,D], k, v) so prefill can write the cache.
    """
    kv_x = x if kv_x is None else kv_x
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(p, cfg, x, kv_x, positions, kv_positions,
                           use_rope, constrain_layout=constrain_layout)
    B, S = q.shape[0], q.shape[1]
    T = k.shape[1]
    if use_flash and causal and kv_x is x:
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(q, k, v, causal=True, window=window)
        out = out.reshape(B, S, -1)
    else:
        if causal:
            m = causal_mask(S, T, offset=T - S, window=window)[None]
        else:
            m = jnp.ones((1, S, T), bool)
        out = gqa_scores_mask(q, k, v, jnp.broadcast_to(m, (B, S, T)))
    return L.linear(p["wo"], out), k, v


def init_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16):
    KV, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, capacity, KV, hd), dtype),
        "v": jnp.zeros((batch, capacity, KV, hd), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def decode_attention(p, cfg, x, cache, *, window=0, use_rope=True):
    """One-token decode: attend to ring cache + self, write self's K/V.

    x: [B, 1, D].  Returns (out [B,1,D], new_cache).
    """
    B = x.shape[0]
    C = cache["k"].shape[1]
    pos = cache["len"][:, None]                           # [B,1] absolute pos
    q, k_new, v_new = _project_qkv(p, cfg, x, x, pos, pos, use_rope,
                                   constrain_layout=True)
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    G = H // KV
    k_all = cache["k"]
    v_all = cache["v"]
    # validity of cache slots: slot s holds absolute position
    #   p(s) = s + C*floor((len-1-s)/C ... ring arithmetic; with the
    # invariant "entries written in the last min(len, C) steps are live":
    slots = jnp.arange(C, dtype=jnp.int32)[None, :]       # [1, C]
    ln = cache["len"][:, None]
    live = slots < jnp.minimum(ln, C)
    if window > 0:
        # absolute position of slot s (ring): latest write wins
        abs_pos = jnp.where(slots < (ln % jnp.maximum(C, 1)),
                            ln - (ln % C) + slots,
                            ln - (ln % C) - C + slots)
        live &= abs_pos > (ln - window)   # query pos = ln; j > i − window
        live &= abs_pos >= 0
    qg = q.reshape(B, 1, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k_all,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(live[:, None, None, None, :], scores, NEG_INF)
    # self-attention to the new token's own K/V
    self_score = jnp.einsum("bskgh,bskh->bkgs", qg,
                            k_new.reshape(B, 1, KV, hd),
                            preferred_element_type=jnp.float32)
    self_score = self_score / jnp.sqrt(jnp.float32(hd))
    all_scores = jnp.concatenate(
        [scores, self_score[..., None]], axis=-1)         # [B,KV,G,1,C+1]
    w = jax.nn.softmax(all_scores, axis=-1).astype(v_all.dtype)
    out = (jnp.einsum("bkgst,btkh->bskgh", w[..., :C], v_all)
           + jnp.einsum("bkgs,bskh->bskgh", w[..., C],
                        v_new.reshape(B, 1, KV, hd)))
    out = out.reshape(B, 1, H * hd)
    # ring write
    widx = (cache["len"] % C)
    k_cache = jax.vmap(lambda c, kk, i: c.at[i].set(kk[0]))(
        cache["k"], k_new, widx)
    v_cache = jax.vmap(lambda c, vv, i: c.at[i].set(vv[0]))(
        cache["v"], v_new, widx)
    new_cache = {"k": k_cache, "v": v_cache, "len": cache["len"] + 1}
    return L.linear(p["wo"], out), new_cache


def cross_decode_attention(p, cfg, x, enc_kv):
    """Cross-attention for enc-dec decode: O(L_enc) per token.

    enc_kv: precomputed {"k","v"} over encoder output [B, T, KV, hd].
    """
    B = x.shape[0]
    q = L.linear(p["wq"], x).reshape(B, 1, cfg.num_heads, cfg.hd)
    out = gqa_scores_mask(q, enc_kv["k"], enc_kv["v"],
                          jnp.ones((B, 1, enc_kv["k"].shape[1]), bool))
    return L.linear(p["wo"], out)
