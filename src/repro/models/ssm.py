"""Mamba selective-state-space block (for jamba) — TPU-adapted.

The CUDA selective-scan kernel from the Mamba paper is a GPU-specific
fused recurrence; on TPU the idiomatic equivalent is a first-order
linear recurrence evaluated with ``jax.lax.associative_scan`` (log-depth,
maps onto the VPU) for training/prefill, and a constant-time state
update for decode.  See DESIGN.md §2 (hardware adaptation).

State per layer: h [B, d_inner, d_state];  conv ring [B, cw-1, d_inner].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def _dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    dt_rank = max(cfg.d_model // 16, 1)
    return di, cfg.ssm_state_dim, dt_rank, cfg.ssm_conv_width


def init(key, cfg):
    D = cfg.d_model
    di, ds, dtr, cw = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": L.linear_init(ks[0], D, 2 * di),
        "conv_w": L._normal(ks[1], (cw, di), 0.1),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": L.linear_init(ks[2], di, dtr + 2 * ds),
        "dt_proj": L.linear_init(ks[3], dtr, di, scale=dtr ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(
                ks[4], (di,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1))))),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.linear_init(ks[5], di, D),
    }


def _ssm_inputs(p, cfg, u):
    """u: [B, S', di] post-conv activations -> (dA, dBu, C)."""
    di, ds, dtr, _ = _dims(cfg)
    xdbc = L.linear(p["x_proj"], u).astype(jnp.float32)
    dt, Bc, Cc = jnp.split(xdbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(L.linear(p["dt_proj"], dt.astype(u.dtype)
                                  ).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                               # [di, ds]
    dA = jnp.exp(dt[..., None] * A)                        # [B,S,di,ds]
    dBu = (dt * u.astype(jnp.float32))[..., None] * Bc[..., None, :]
    return dA, dBu, Cc


def _conv(p, cfg, x, state=None):
    """Causal depthwise conv1d.  x: [B,S,di]; state: [B,cw-1,di] or None."""
    cw = cfg.ssm_conv_width
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # [B, S+cw-1, di]
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i].astype(x.dtype)
              for i in range(cw))
    out = out + p["conv_b"].astype(x.dtype)
    new_state = xp[:, -(cw - 1):] if cw > 1 else pad
    return out, new_state


CHUNK = 128


def forward(p, cfg, x):
    """Training / prefill form — chunkwise scan.

    The O(S·di·ds) scan elements are materialized one CHUNK at a time
    (log-depth associative scan within a chunk, sequential recurrence
    across chunks), bounding the transient workspace at
    B·CHUNK·di·ds·4 bytes instead of B·S·di·ds.
    x: [B, S, D] -> ([B, S, D], final_state) — the state comes for free
    from the chunk recurrence, so prefill needs no recompute.
    """
    B, S, D = x.shape
    xz = L.linear(p["in_proj"], x)
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_state = _conv(p, cfg, u)
    u = jax.nn.silu(u)
    pad = (-S) % CHUNK
    if pad:
        u_p = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    else:
        u_p = u
    nch = u_p.shape[1] // CHUNK
    uc = u_p.reshape(B, nch, CHUNK, -1).transpose(1, 0, 2, 3)
    valid = (jnp.arange(nch * CHUNK, dtype=jnp.int32)
             < S).reshape(nch, 1, CHUNK)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, xs):
        u_chunk, vmask = xs
        dA, dBu, Cc = _ssm_inputs(p, cfg, u_chunk)      # [B,L,di,ds]
        # padded positions are identity steps so the carried state is
        # exactly the state at position S
        dA = jnp.where(vmask[..., None, None], dA, 1.0)
        dBu = jnp.where(vmask[..., None, None], dBu, 0.0)
        cumA, hs_local = jax.lax.associative_scan(
            combine, (dA, dBu), axis=1)
        hs = hs_local + cumA * h[:, None]               # [B,L,di,ds]
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cc)
        y = y + u_chunk.astype(jnp.float32) * p["D"]
        return hs[:, -1], y.astype(x.dtype)

    h_last, ys = jax.lax.scan(chunk_step, init_state(cfg, B)["h"],
                              (uc, valid))
    y = ys.transpose(1, 0, 2, 3).reshape(B, -1, u.shape[-1])[:, :S]
    y = y * jax.nn.silu(z)
    state = {"h": h_last, "conv": conv_state.astype(jnp.bfloat16)}
    return L.linear(p["out_proj"], y), state


def init_state(cfg, batch: int, dtype=jnp.float32):
    di, ds, _, cw = _dims(cfg)
    return {
        "h": jnp.zeros((batch, di, ds), jnp.float32),
        "conv": jnp.zeros((batch, cw - 1, di), dtype),
    }


def decode_step(p, cfg, x, state):
    """x: [B, 1, D] -> (y [B,1,D], new_state).  O(1) per token."""
    xz = L.linear(p["in_proj"], x)
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_state = _conv(p, cfg, u, state["conv"])
    u = jax.nn.silu(u)
    dA, dBu, Cc = _ssm_inputs(p, cfg, u)                   # S = 1
    h = state["h"] * dA[:, 0] + dBu[:, 0]                  # [B, di, ds]
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None]
    y = y + u.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return L.linear(p["out_proj"], y), {"h": h, "conv": conv_state}
