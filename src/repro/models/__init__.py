"""Model substrate: layers, attention, MoE, SSM, xLSTM, assemblies."""

from repro.models.model import Model, build, cross_entropy

__all__ = ["Model", "build", "cross_entropy"]
