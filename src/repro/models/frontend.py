"""Modality frontends — STUBS per the assignment carve-out.

``[audio]`` / ``[vlm]`` entries specify the transformer backbone only;
the mel-spectrogram + conv feature extractor (audio) and the ViT/SigLIP
vision encoder + projector (VLM) are stubbed: ``input_specs`` provides
precomputed frame/patch embeddings of the right shape, and the runtime
smoke tests synthesize random embeddings with the same specs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embed_spec(cfg, batch: int, positions: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct for precomputed frontend embeddings at d_model."""
    return jax.ShapeDtypeStruct((batch, positions, cfg.d_model), dtype)


def synth_embeds(key, cfg, batch: int, positions: int,
                 dtype=jnp.bfloat16):
    """Random stand-in embeddings for runtime smoke tests."""
    return (jax.random.normal(key, (batch, positions, cfg.d_model))
            * 0.02).astype(dtype)
