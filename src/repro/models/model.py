"""Top-level model API: build (init, train_step, prefill, decode) per arch.

The resilient-boosting hook (DESIGN.md §2): ``train_step`` consumes a
per-example weight vector and an alive mask from the data pipeline —
the multiplicative-weights state maintained by ``core/resilient.py`` —
and uses them to modulate the per-example loss.  For vanilla training
the pipeline passes uniform weights / all-alive.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import DEFAULT_SWA_WINDOW, ModelConfig, ShapeConfig
from repro.models import encdec, frontend, layers as L, transformer
from repro.optim import adamw


def cross_entropy(logits, labels, mask):
    """Token CE with masking.  logits f32 [B,S,V]; labels [B,S]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll  # [B, S] per-token


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    use_flash: bool = False

    # ------------------------------------------------------------------ init
    def init(self, key):
        if self.cfg.encoder_layers:
            return encdec.init_params(key, self.cfg)
        return transformer.init_params(key, self.cfg)

    # -------------------------------------------------------------- forward
    def logits(self, params, batch):
        cfg = self.cfg
        if cfg.encoder_layers:
            return encdec.forward(params, cfg, batch["frames"],
                                  batch["tokens"])
        prefix = batch.get("prefix_embeds")
        return transformer.forward(params, cfg, batch["tokens"],
                                   prefix_embeds=prefix,
                                   use_flash=self.use_flash)

    def loss_fn(self, params, batch):
        """Weighted LM loss.  batch:
          tokens [B,St], labels [B,St], loss_mask [B,St],
          weights [B] (boosting MW weights), alive [B] (quarantine mask),
          optional prefix_embeds / frames.
        """
        cfg = self.cfg
        logits, aux = self.logits(params, batch)
        labels = batch["labels"]
        mask = batch["loss_mask"].astype(jnp.float32)
        if logits.shape[1] != labels.shape[1]:
            # multimodal prefix: loss only over the token tail
            logits = logits[:, logits.shape[1] - labels.shape[1]:]
        nll = cross_entropy(logits, labels, mask)            # [B, St]
        per_example = nll.sum(-1) / jnp.maximum(mask.sum(-1), 1.0)
        w = (batch["weights"] * batch["alive"]).astype(jnp.float32)
        w = w / jnp.maximum(w.sum(), 1e-9)
        loss = jnp.sum(per_example * w)
        metrics = {
            "loss": loss,
            "aux_loss": aux,
            "per_example_nll": per_example,
            "tokens": mask.sum(),
        }
        return loss + aux, metrics

    # ----------------------------------------------------------- train step
    def make_train_step(self, *, lr: float = 3e-4, warmup: int = 100,
                        total_steps: int = 10_000, clip: float = 1.0):
        cfg = self.cfg

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(params, batch)
            grads, gnorm = adamw.clip_by_global_norm(grads, clip)
            lr_t = adamw.linear_warmup_cosine(
                opt_state["step"] + 1, lr, warmup, total_steps)
            new_params, new_opt = adamw.adamw_update(
                params, grads, opt_state, lr=lr_t)
            metrics = dict(metrics, grad_norm=gnorm, lr=lr_t)
            return new_params, new_opt, metrics

        return train_step

    # -------------------------------------------------------------- serving
    def make_prefill_step(self, window: int = 0):
        cfg = self.cfg

        def prefill_step(params, batch):
            if cfg.encoder_layers:
                enc_out = encdec.encode(params, cfg, batch["frames"])
                cross = encdec.build_cross_cache(params, cfg, enc_out)
                self_cache = encdec.init_self_cache(
                    cfg, batch["tokens"].shape[0],
                    int(batch["tokens"].shape[1]) + 1)
                logits, _ = encdec.decode_train(params, cfg, enc_out,
                                                batch["tokens"])
                return logits[:, -1], (cross, self_cache)
            logits, _, caches = transformer.prefill(
                params, cfg, batch["tokens"],
                prefix_embeds=batch.get("prefix_embeds"),
                use_flash=self.use_flash, window=window)
            return logits, caches

        return prefill_step

    def make_decode_step(self, window: int = 0):
        cfg = self.cfg

        def decode_step(params, caches, tokens):
            if cfg.encoder_layers:
                cross, self_cache = caches
                logits, new_self = encdec.decode_step(
                    params, cfg, cross, self_cache, tokens)
                return logits, (cross, new_self)
            return transformer.decode_step(params, cfg, caches, tokens,
                                           window=window)

        return decode_step

    # --------------------------------------------------- serving cache spec
    def init_serve_cache(self, shape: ShapeConfig, filled: bool = True):
        """Cache for a decode shape; capacity honours the long-context
        mode (SWA archs keep only a ring of DEFAULT_SWA_WINDOW slots for
        the long_500k shape — that IS the sub-quadratic claim)."""
        cfg = self.cfg
        window = self.decode_window(shape)
        capacity = min(shape.seq_len, window) if window else shape.seq_len
        B = shape.global_batch
        if cfg.encoder_layers:
            cross = {
                "k": jnp.zeros((cfg.num_layers, B, shape.seq_len,
                                cfg.num_kv_heads, cfg.hd), jnp.bfloat16),
                "v": jnp.zeros((cfg.num_layers, B, shape.seq_len,
                                cfg.num_kv_heads, cfg.hd), jnp.bfloat16),
            }
            self_cache = encdec.init_self_cache(cfg, B, 1024,
                                                filled=False)
            return (cross, self_cache)
        return transformer.init_cache(cfg, B, capacity, filled=filled)

    def decode_window(self, shape: ShapeConfig) -> int:
        cfg = self.cfg
        if cfg.sliding_window:
            return cfg.sliding_window
        if (shape.name == "long_500k"
                and cfg.long_context_mode == "swa"):
            return DEFAULT_SWA_WINDOW
        return 0


def build(cfg: ModelConfig, use_flash: bool = False) -> Model:
    return Model(cfg=cfg, use_flash=use_flash)
