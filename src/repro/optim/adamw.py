"""AdamW and SGD over arbitrary pytrees, plus schedules and clipping.

State layout matches production frameworks: first/second moments in
f32 with the same sharding as the parameters (the dry-run memory
analysis accounts for them).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def cosine_schedule(step, base_lr: float, total_steps: int,
                    final_frac: float = 0.1):
    t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
    return base_lr * (final_frac + (1 - final_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * t)))


def linear_warmup_cosine(step, base_lr: float, warmup: int,
                         total_steps: int, final_frac: float = 0.1):
    warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
    cos = cosine_schedule(jnp.maximum(step - warmup, 0), base_lr,
                          max(total_steps - warmup, 1), final_frac)
    return jnp.where(step < warmup, warm, cos)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(params, grads, state, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state["step"] + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------

def sgd_init(params):
    return {
        "step": jnp.zeros((), jnp.int32),
        "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params),
    }


def sgd_update(params, grads, state, *, lr, momentum: float = 0.9,
               weight_decay: float = 0.0):
    step = state["step"] + 1

    def upd(p, g, m):
        g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m = momentum * m + g
        return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["mom"])
    out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    return (treedef.unflatten([o[0] for o in out]),
            {"step": step, "mom": treedef.unflatten([o[1] for o in out])})
