"""Optimizers (hand-built — optax is not available offline)."""

from repro.optim.adamw import (adamw_init, adamw_update, sgd_init,
                               sgd_update, clip_by_global_norm,
                               cosine_schedule, linear_warmup_cosine)

__all__ = ["adamw_init", "adamw_update", "sgd_init", "sgd_update",
           "clip_by_global_norm", "cosine_schedule",
           "linear_warmup_cosine"]
