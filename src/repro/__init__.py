"""repro — A Resilient Distributed Boosting Algorithm (Filmus, Mehalel, Moran; ICML 2022).

A production-grade JAX framework implementing the paper's communication-
efficient resilient boosting protocol (BoostAttempt / AccuratelyClassify),
plus a multi-architecture transformer substrate on which the protocol's
communication pattern (tiny weighted coresets instead of raw data) and
resilience mechanism (hard-core-set quarantine) are first-class
distributed-training features.
"""

__version__ = "1.0.0"
