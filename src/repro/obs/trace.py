"""Host-side span/event tracing in Chrome trace-event format.

A :class:`TraceRecorder` collects *complete* events (``ph: "X"`` —
named spans with microsecond ``ts``/``dur``) and *instant* events
(``ph: "i"``), the subset of the Chrome trace-event spec that Perfetto
and ``chrome://tracing`` render natively.  Load the JSON written by
:meth:`TraceRecorder.save` straight into https://ui.perfetto.dev.

Span taxonomy (docs/observability.md): ``attempt``, ``round``,
``run_rounds``, ``finalize``, ``quarantine``, ``compile``, ``dispatch``,
``preempt``, ``resume``, ``ckpt_save`` / ``ckpt_restore``.  Round and
attempt spans carry a ``task_bits`` args dict — per-task wire bits by
ledger category — which :func:`repro.obs.roundtrace.validate_trace`
proves bit-exact against the Theorem 4.1 ledger.

Tracing is **disabled by default**.  The module-level :func:`span` /
:func:`instant` helpers return a preallocated no-op when no recorder is
active, so the instrumented hot paths pay one ``is None`` test — the
benchmarks/observability.py overhead gate holds this under 2% on the
batched engine.

Device-side nesting: :func:`annotate` wraps
``jax.profiler.TraceAnnotation`` so, when a profiler trace is being
captured (:func:`device_trace`), device activity appears under the
host protocol spans.  Emission from *inside* jitted code is a lint
error (RL006) — a traced obs call would run once at trace time and
never again.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time

import jax


# Ledger-category ↔ Ledger-field mapping: the ``task_bits`` dicts that
# round/attempt spans carry are keyed by these categories, and
# repro.obs.roundtrace.validate_trace compares their sums field-by-field
# against the Theorem 4.1 Ledger (docs/observability.md has the table).
CATEGORY_FIELDS = {
    "coreset": "bits_coresets",
    "ws": "bits_weight_sums",
    "hypotheses": "bits_hypotheses",
    "control": "bits_control",
    "histograms": "bits_histograms",
    "votes": "bits_votes",
    "quarantine": "bits_dispute",
}


def ledger_bits(led) -> dict:
    """A ``repro.core.types.Ledger`` (or delta of one) as a per-category
    bits dict — the span ``task_bits`` payload format."""
    return {cat: int(getattr(led, field))
            for cat, field in CATEGORY_FIELDS.items()}


class _NullSpan:
    """Shared no-op span: the disabled-tracing fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def update(self, **args) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Span:
    """One complete event; a context manager timing its ``with`` body.

    ``update(**args)`` merges into the event's args — callable after
    the timed work so spans can carry results (round counts, wire
    bits) computed inside the region.
    """

    __slots__ = ("_rec", "name", "cat", "args", "_t0")

    def __init__(self, rec: "TraceRecorder", name: str, cat: str,
                 args: dict):
        self._rec = rec
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0

    def update(self, **args) -> None:
        self.args.update(args)

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self._rec._complete(self.name, self.cat, self._t0,
                            time.perf_counter(), self.args)
        return False


class TraceRecorder:
    """Append-only event sink (thread-safe: list.append is atomic).

    ``ts`` is microseconds since the recorder's construction — a fresh
    recorder after checkpoint/resume restarts the clock, which Perfetto
    renders fine and the ledger validator ignores (it sums ``args``
    payloads, never timestamps).
    """

    def __init__(self):
        self.events: list[dict] = []
        self._epoch = time.perf_counter()
        self._pid = os.getpid()

    # -- emission -----------------------------------------------------------

    def _us(self, t: float) -> float:
        return (t - self._epoch) * 1e6

    def _complete(self, name: str, cat: str, t0: float, t1: float,
                  args: dict) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": self._us(t0), "dur": max(self._us(t1) - self._us(t0), 0.0),
            "pid": self._pid, "tid": threading.get_ident(),
            "args": args})

    def span(self, name: str, cat: str = "protocol", **args) -> Span:
        return Span(self, name, cat, dict(args))

    def instant(self, name: str, cat: str = "protocol", **args) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self._us(time.perf_counter()),
            "pid": self._pid, "tid": threading.get_ident(),
            "args": dict(args)})

    # -- export -------------------------------------------------------------

    def extend(self, events) -> None:
        """Merge events from another recorder (e.g. the pre-preemption
        segment of a resumed run) — validation spans both segments."""
        self.events.extend(events)

    def chrome_trace(self) -> dict:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        """Write Perfetto-loadable JSON (atomic: tmp + rename)."""
        tmp = path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(self.chrome_trace(), f)
        os.replace(tmp, path)


# ---------------------------------------------------------------------------
# module-level switchboard: the instrumentation sites call these
# ---------------------------------------------------------------------------

_ACTIVE: TraceRecorder | None = None


def enable(recorder: TraceRecorder | None = None) -> TraceRecorder:
    """Install (and return) the active recorder; idempotent-friendly —
    pass an existing recorder to keep appending to it."""
    global _ACTIVE
    _ACTIVE = recorder if recorder is not None else TraceRecorder()
    return _ACTIVE


def disable() -> TraceRecorder | None:
    """Deactivate tracing; returns the recorder that was active."""
    global _ACTIVE
    rec, _ACTIVE = _ACTIVE, None
    return rec


def active() -> TraceRecorder | None:
    return _ACTIVE


def enabled() -> bool:
    return _ACTIVE is not None


@contextlib.contextmanager
def recording(recorder: TraceRecorder | None = None):
    """Scoped enable/disable; yields the recorder."""
    rec = enable(recorder)
    try:
        yield rec
    finally:
        if _ACTIVE is rec:
            disable()


def span(name: str, cat: str = "protocol", **args):
    """A timing span when tracing is on, the shared no-op when off."""
    rec = _ACTIVE
    if rec is None:
        return _NULL_SPAN
    return rec.span(name, cat, **args)


def instant(name: str, cat: str = "protocol", **args) -> None:
    rec = _ACTIVE
    if rec is not None:
        rec.instant(name, cat, **args)


def annotate(name: str):
    """``jax.profiler.TraceAnnotation`` under an active recorder —
    nests device activity (when a profiler trace is being captured)
    under the host protocol span of the same region; a no-op context
    otherwise."""
    if _ACTIVE is None:
        return _NULL_SPAN
    ann = getattr(jax.profiler, "TraceAnnotation", None)
    return ann(name) if ann is not None else _NULL_SPAN


@contextlib.contextmanager
def device_trace(log_dir: str):
    """Capture a ``jax.profiler`` device trace alongside host spans —
    open the resulting directory in TensorBoard/Perfetto and the
    :func:`annotate` regions frame the device activity."""
    jax.profiler.start_trace(log_dir)
    try:
        yield log_dir
    finally:
        jax.profiler.stop_trace()
