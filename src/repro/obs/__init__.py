"""Observability: host-side tracing + metrics (docs/observability.md).

Two pillars, both disabled by default and free when off:

* :mod:`repro.obs.trace`   — span/event recorder emitting Chrome-trace
  JSON (Perfetto-viewable), with ``jax.profiler`` hooks so device
  activity nests under protocol spans;
* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket latency
  histograms (p50/p99 derivable) that scheduler and checkpoint stats
  publish into.

:mod:`repro.obs.roundtrace` drives an engine one wire round at a time
and derives each round's per-category wire bits from state-counter
deltas — the trace↔ledger cross-validation that makes the trace a
second, independent witness of the Theorem 4.1 accounting.

Emission is HOST-SIDE ONLY: repro-lint rule RL006 rejects obs calls
reachable from traced (jitted) code, where they would silently become
trace-time constants.
"""

from repro.obs import trace, metrics, roundtrace  # noqa: F401  (order:
# trace/metrics are dependency-free; roundtrace pulls repro.core.ledger
# and must come last so a core → obs.trace import never cycles)

__all__ = ["metrics", "roundtrace", "trace"]
