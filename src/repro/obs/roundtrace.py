"""Round-granular trace driver + trace↔ledger cross-validation.

:func:`trace_rounds` advances a stepping engine ONE wire round at a
time (``run_rounds(..., n=1)`` under the hood) and emits a ``round``
span per step whose ``task_bits`` args carry, per task, the wire bits
that round moved — split by ledger category (``coreset`` / ``ws`` /
``hypotheses`` / ``control`` / ``histograms`` / ``votes`` /
``quarantine``).  The bits are **derived from state-counter deltas**:
the engines' per-attempt histories (``hist_players``,
``hist_players_h``, ``hist_alive``, ``hist_stuck``, ``hist_p``, …) are
monotone within an attempt and advance by exactly one round's worth
per step, so before/after differences identify what the round sent —
no instrumentation inside jitted code (that would violate RL006), and
because those counters round-trip exactly through
``ckpt/msgpack_ckpt`` checkpoints, a run preempted and resumed from a
checkpoint traces the same per-round bits with no double-count.

:func:`validate_trace` then proves the traced sums are **bit-exact**
equal to `repro.core.ledger.boost_attempt_ledger_masked` as summed by
``result.ledger(b)`` — per task, per category, including dropout
masks — making the trace a second, independent witness of the
Theorem 4.1 accounting (the sharded engine's ``validate_ledger`` is
the third: measured collective payloads).

Works on both stepping engines: the batched ``StepState`` NamedTuple
and the sharded dict state expose the same counter names
(``core/sharded_batched.py`` builds its state from
``batched.init_state``), so one accessor serves both.
"""

from __future__ import annotations

import math

import jax
import numpy as np

from repro.core import ledger as L
from repro.obs import trace as T
from repro.obs.trace import CATEGORY_FIELDS, ledger_bits  # noqa: F401

# the small per-task counters the driver snapshots each round — O(B·A)
# ints, never the O(B·k·mloc) protocol arrays
_COUNTER_FIELDS = ("attempt", "done", "step", "hist_stuck",
                   "hist_rounds", "hist_alive", "hist_p",
                   "hist_players", "hist_players_h", "hist_players_last")


def _field(state, name: str):
    """One accessor for both engines' states (NamedTuple vs dict)."""
    if isinstance(state, dict):
        return state[name]
    return getattr(state, name)


def snapshot_counters(state) -> dict:
    """Host copies of the per-task accounting counters."""
    return {f: np.asarray(jax.device_get(_field(state, f)))
            for f in _COUNTER_FIELDS}


def round_bits(cfg, cls, s0: dict, s1: dict, m_default: int,
               m_true=None) -> tuple[dict, dict, dict]:
    """Wire bits of ONE step, per task, from counter deltas.

    ``s0``/``s1``: :func:`snapshot_counters` before/after a single
    ``run_rounds(n=1)`` call.  Returns ``(task_bits, rounds, players)``
    — ``task_bits[b]`` a per-category dict, ``rounds[b]`` the wire
    rounds task b advanced (0 or 1; a task absent from the maps was
    frozen), ``players[b]`` the round's alive-player count.  The
    formulas are `repro.core.ledger.boost_attempt_ledger_masked`'s,
    re-expressed per round: summed over an attempt's rounds they
    reproduce every field exactly (docs/observability.md walks the
    algebra; benchmarks/observability.py gates the bit-exactness).
    """
    n = L.domain_size(cls)
    mode = L.tree_comm_mode(cls)
    c = cfg.coreset_size
    hyp_bits = cls.hypothesis_bits()
    task_bits: dict[int, dict] = {}
    rounds: dict[int, int] = {}
    players: dict[int, int] = {}
    for b in range(int(s0["attempt"].shape[0])):
        if int(s1["step"][b]) == int(s0["step"][b]):
            continue                       # frozen lane (done / budget)
        a0 = int(s0["attempt"][b])
        k_alive = int(s1["hist_players"][b, a0]
                      - s0["hist_players"][b, a0])
        dh = int(s1["hist_players_h"][b, a0]
                 - s0["hist_players_h"][b, a0])
        ended = int(s1["attempt"][b]) > a0
        stuck = bool(s1["hist_stuck"][b, a0]) if ended else False
        # the attempt's m_alive/T are fixed at its first round and
        # recorded in hist_alive before any round's charges
        m_a = max(int(s1["hist_alive"][b, a0]), 2)
        T_a = cfg.num_rounds(m_a)
        bits = dict.fromkeys(CATEGORY_FIELDS, 0)
        if mode == "coreset":
            bits["coreset"] = k_alive * c * L.example_bits(n)
        else:
            # distributed growth: histograms/votes every round;
            # examples cross the wire only on the stuck (final) round
            bits["histograms"] = (k_alive * L.hist_scalars_per_player(cls)
                                  * L.histogram_cell_bits(m_a, T_a))
            bits["votes"] = (k_alive * L.vote_entries_per_player(cls)
                             * L.vote_entry_bits(cls, m_a, T_a))
            if stuck:
                bits["coreset"] = k_alive * c * L.example_bits(n)
        bits["ws"] = k_alive * L.weight_sum_bits(m_a, T_a)
        bits["hypotheses"] = dh * hyp_bits
        if ended:
            # stuck flag (if any) + halt bit, to the final round's
            # alive players (== players_last by construction)
            bits["control"] = k_alive * (2 if stuck else 1)
            if stuck:
                p = int(s1["hist_p"][b, a0])
                m_eff = m_default if m_true is None else int(m_true[b])
                m_bits = max(int(math.ceil(math.log2(max(m_eff, 2)))), 1)
                bits["control"] += k_alive * p * L.point_bits(n)
                bits["quarantine"] = k_alive * p * 2 * m_bits
        task_bits[b] = bits
        rounds[b] = 1
        players[b] = k_alive
    return task_bits, rounds, players


def trace_rounds(step_fn, state, cfg, cls, *, m_true=None,
                 recorder: T.TraceRecorder | None = None,
                 max_rounds: int | None = None, engine: str = "batched"):
    """Drive ``step_fn`` one wire round at a time, emitting ``round``
    spans with per-task per-category wire bits until every task halts.

    ``step_fn(state) -> state`` must advance by at most ONE wire round
    (wrap ``run_rounds`` / ``run_rounds_sharded`` with ``n=1``);
    ``m_true``: optional [B] true sample sizes (the serving layer's
    padded-bucket case — dispute-report widths charge the request's own
    ⌈log2 m⌉).  Rounds where players are masked out emit a
    ``dead_players`` instant event per affected task with ``bits=0`` —
    absent players move nothing, and the trace says so explicitly.
    Returns the final state; validate with :func:`validate_trace`.
    Tracing only the small counter snapshots, the driver costs
    O(B·attempts) host ints per round — use it for traced runs; the
    disabled-tracing hot path stays one dispatch.
    """
    rec = recorder if recorder is not None else T.active()
    if rec is None:
        raise ValueError("trace_rounds needs a recorder: pass one or "
                         "enable tracing (repro.obs.trace.enable)")
    k = int(_field(state, "alive").shape[1])
    m_default = k * int(_field(state, "alive").shape[2])
    a_max = cfg.opt_budget + 1
    s0 = snapshot_counters(state)
    r = 0
    while bool(np.any(~s0["done"] & (s0["attempt"] < a_max))):
        if max_rounds is not None and r >= max_rounds:
            break
        with rec.span("round", "protocol", engine=engine) as sp:
            state = step_fn(state)
            s1 = snapshot_counters(state)
            task_bits, rounds, players = round_bits(
                cfg, cls, s0, s1, m_default, m_true=m_true)
            sp.update(
                task_bits={str(b): tb for b, tb in task_bits.items()},
                task_rounds={str(b): n for b, n in rounds.items()},
                task_attempts={str(b): 1 for b in rounds
                               if int(s1["attempt"][b])
                               > int(s0["attempt"][b])},
                players={str(b): p for b, p in players.items()})
        for b, alive_players in players.items():
            if alive_players < k:
                rec.instant("dead_players", "protocol", task=b,
                            players_dead=k - alive_players,
                            players_alive=alive_players, bits=0)
        if not rounds:
            break                          # no lane advanced: all halted
        s0 = s1
        r += 1
    return state


# ---------------------------------------------------------------------------
# validation: traced sums ≡ ledger, bit for bit
# ---------------------------------------------------------------------------

def _events(events_or_recorder) -> list:
    if isinstance(events_or_recorder, T.TraceRecorder):
        return events_or_recorder.events
    return list(events_or_recorder)


def traced_totals(events_or_recorder) -> dict:
    """Sum every span's ``task_bits`` / ``task_rounds`` /
    ``task_attempts`` payloads: task id → {category: bits, plus
    ``rounds`` and ``attempts`` counts}."""
    totals: dict[int, dict] = {}
    for ev in _events(events_or_recorder):
        args = ev.get("args") or {}
        for key, slot in (("task_bits", None), ("task_rounds", "rounds"),
                          ("task_attempts", "attempts")):
            for bs, val in (args.get(key) or {}).items():
                acc = totals.setdefault(
                    int(bs), dict.fromkeys(CATEGORY_FIELDS, 0)
                    | {"rounds": 0, "attempts": 0})
                if slot is None:
                    for cat, v in val.items():
                        acc[cat] += int(v)
                else:
                    acc[slot] += int(val)
    return totals


def validate_trace(events_or_recorder, ledgers: dict) -> dict:
    """Prove traced wire bits ≡ ledger, per task and per category.

    ``ledgers``: task id → ``repro.core.types.Ledger`` (e.g.
    ``{b: result.ledger(b) for b in range(result.batch)}``, or
    ``{0: classify_result.ledger}`` for the host engine).  Checks
    every category of :data:`repro.obs.trace.CATEGORY_FIELDS` plus the
    ``rounds``/``attempts`` counts for **bit-exact** equality; raises
    ``AssertionError`` naming every divergence, returns the per-task
    comparison when clean.  Merged event lists from a
    checkpoint/resume pair validate the same way — bits are counter
    deltas, so a resumed segment continues where the preempted one
    stopped with no overlap.
    """
    got = traced_totals(events_or_recorder)
    report: dict[int, dict] = {}
    errors: list[str] = []
    for b, led in ledgers.items():
        want = ledger_bits(led)
        want["rounds"] = int(led.rounds)
        want["attempts"] = int(led.attempts)
        have = got.get(int(b))
        if have is None:
            errors.append(f"task {b}: no traced bits at all")
            continue
        for key, w in want.items():
            if have.get(key, 0) != w:
                errors.append(
                    f"task {b} {key}: traced {have.get(key, 0)} != "
                    f"ledger {w}")
        report[int(b)] = {"traced": have, "ledger": want}
    extra = sorted(set(got) - {int(b) for b in ledgers})
    if extra:
        errors.append(f"traced bits for unknown tasks {extra}")
    if errors:
        raise AssertionError(
            "trace↔ledger mismatch:\n" + "\n".join(errors))
    return report
