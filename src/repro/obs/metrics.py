"""Counters, gauges and fixed-bucket latency histograms.

A :class:`MetricsRegistry` is a flat name → instrument map with
get-or-create accessors, so call sites never coordinate registration.
Histograms use FIXED bucket upper bounds (default: a log-spaced
seconds ladder), which makes them mergeable across processes and keeps
:meth:`Histogram.quantile` (p50/p99) a deterministic function of the
counts — no reservoir sampling, no data-dependent state.

Publishers bridge the existing stats objects into a registry:
:func:`publish_cache_stats` (`repro.launch.scheduler.CacheStats`),
:func:`publish_scheduler_stats` (`repro.launch.scheduler.SchedulerStats`
including per-bucket occupancy), and the checkpointer's save/restore
timings land in ``ckpt.save_s`` / ``ckpt.restore_s`` histograms of the
:func:`default_registry`.  ``serve.py --metrics-out`` snapshots the
registry to JSON.
"""

from __future__ import annotations

import bisect
import json
import os

# log-spaced seconds ladder: 100µs .. 100s — wide enough for both a
# cached-dispatch latency and a cold XLA compile
DEFAULT_BUCKETS_S = (
    1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0,
    30.0, 100.0)


class Counter:
    """Monotone accumulator."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def to_dict(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def to_dict(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram: ``counts[i]`` observations ≤
    ``buckets[i]``, plus one overflow cell; tracks count and sum so
    means and rates fall out."""

    __slots__ = ("name", "buckets", "counts", "count", "sum")

    def __init__(self, name: str, buckets=DEFAULT_BUCKETS_S):
        self.name = name
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(self.buckets):
            raise ValueError(f"histogram {name}: buckets must ascend")
        self.counts = [0] * (len(self.buckets) + 1)
        self.count = 0
        self.sum = 0.0

    def observe(self, v) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v

    def quantile(self, q: float) -> float:
        """Deterministic quantile estimate: walk the cumulative counts
        to the target rank, interpolate linearly inside the bucket.
        The overflow bucket clamps to its lower edge (the estimate is
        then a lower bound — fixed buckets cannot see past the ladder).
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= rank and c > 0:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = (self.buckets[i] if i < len(self.buckets)
                      else self.buckets[-1])
                frac = (rank - seen) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            seen += c
        return self.buckets[-1]

    def to_dict(self) -> dict:
        return {"type": "histogram", "buckets": list(self.buckets),
                "counts": list(self.counts), "count": self.count,
                "sum": self.sum, "p50": self.quantile(0.5),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Flat name → instrument map; accessors get-or-create, and a
    name can only ever hold one instrument kind."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, kind, *args):
        m = self._metrics.get(name)
        if m is None:
            m = kind(name, *args)
            self._metrics[name] = m
        elif not isinstance(m, kind):
            raise TypeError(
                f"metric {name!r} is {type(m).__name__}, "
                f"not {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets=DEFAULT_BUCKETS_S) -> Histogram:
        return self._get(name, Histogram, buckets)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def to_dict(self) -> dict:
        return {name: self._metrics[name].to_dict()
                for name in self.names()}

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
        os.replace(tmp, path)


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry ambient instrumentation (checkpoint
    timings) publishes into."""
    return _DEFAULT


def reset_default_registry() -> MetricsRegistry:
    """Fresh default registry (test isolation)."""
    global _DEFAULT
    _DEFAULT = MetricsRegistry()
    return _DEFAULT


# ---------------------------------------------------------------------------
# publishers: existing stats objects → registry
# ---------------------------------------------------------------------------

def publish_cache_stats(stats, reg: MetricsRegistry,
                        prefix: str = "scheduler.compile_cache") -> None:
    """`repro.launch.scheduler.CacheStats` → counters + compile-time
    histogram (one observation per recorded compile second — the stats
    object keeps only the total, so the histogram gets the mean; the
    per-compile distribution lives in `repro.obs.trace` compile spans).
    """
    reg.counter(f"{prefix}.hits").value = stats.hits
    reg.counter(f"{prefix}.misses").value = stats.misses
    reg.counter(f"{prefix}.evictions").value = stats.evictions
    reg.counter(f"{prefix}.compiles").value = stats.compiles
    reg.gauge(f"{prefix}.compile_s_total").set(stats.compile_s)
    if stats.compiles:
        reg.histogram(f"{prefix}.compile_s").observe(
            stats.compile_s / stats.compiles)


def publish_scheduler_stats(stats, reg: MetricsRegistry,
                            prefix: str = "scheduler") -> None:
    """`repro.launch.scheduler.SchedulerStats` → counters, plus one
    gauge pair per (B, mloc, engine) bucket for occupancy: served real
    lanes vs dispatched capacity."""
    for field in ("dispatches", "served", "filler_lanes",
                  "padded_requests", "preemptions", "resumes"):
        reg.counter(f"{prefix}.{field}").value = getattr(stats, field)
    for key, (served, capacity) in sorted(stats.per_bucket.items()):
        tag = f"B{key[0]}_mloc{key[1]}_{key[2]}"  # latency_summary's
        reg.gauge(f"{prefix}.bucket.{tag}.served").set(served)
        reg.gauge(f"{prefix}.bucket.{tag}.capacity").set(capacity)
        reg.gauge(f"{prefix}.bucket.{tag}.occupancy").set(
            served / capacity if capacity else 0.0)
