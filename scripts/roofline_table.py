"""Render the EXPERIMENTS.md §Roofline table from dry-run JSONs.

    PYTHONPATH=src python scripts/roofline_table.py [dir]
"""

import glob
import json
import os
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x):
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x:.1e}"
    return f"{x:.4f}"


def main(d="experiments/roofline_1pod"):
    rows = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    print("| arch | shape | compute_s | memory_s | collective_s |"
          " dominant | useful ratio | what moves the dominant term |")
    print("|---|---|---|---|---|---|---|---|")
    hints = {
        ("memory", "train"): "flash-attn fusion / bf16 master+collectives",
        ("memory", "prefill"): "flash attention (no S² scores in HBM)",
        ("memory", "decode"): "KV-cache quantization / GQA-packed loads",
        ("collective", "train"): "bf16 grads + reduce-scatter (ZeRO)",
        ("collective", "prefill"): "sequence-parallel norms, fewer TP hops",
        ("collective", "decode"): "replicate small tensors, skip TP gather",
        ("compute", "train"): "less remat recompute, MXU-aligned dims",
        ("compute", "prefill"): "skip masked tiles (causal block skip)",
        ("compute", "decode"): "batch growth amortizes weight reads",
    }
    for r in rows:
        kind = r.get("kind", "train")
        hint = hints.get((r["dominant"], kind), "-")
        print(f"| {r['arch']} | {r['shape']} | {fmt(r['compute_s'])} | "
              f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | "
              f"**{r['dominant']}** | {r.get('useful_ratio', 0):.3f} | "
              f"{hint} |")
    # summary
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"\ndominant-term distribution: {doms} over {len(rows)} pairs")


if __name__ == "__main__":
    main(*sys.argv[1:])
