"""Scheduler preemption: checkpoint → requeue → resume ≡ one_shot.

The ISSUE 4 acceptance bar: a request preempted mid-stream and resumed
from a msgpack checkpoint produces bit-identical output (hypotheses,
quarantine masks, ledger bits) to its uninterrupted ``one_shot`` run —
validated the same way PR 3 gates batching parity.
"""

import os

import numpy as np
import pytest

from repro.launch import scheduler as S

SHAPES = [
    {"m": 64, "k": 2, "noise": 0},
    {"m": 96, "k": 2, "noise": 1},
    {"m": 128, "k": 2, "noise": 2, "scenario": "drift"},
]
LATTICE = S.BucketLattice(b_sizes=(2, 4), mloc_sizes=(32, 48, 64))
COMMON = dict(coreset_size=48, opt_budget=6)


def _stream(n, engine="batched", seed=3):
    arrivals = S.poisson_trace(n, rate_per_s=500.0, seed=seed)
    return S.make_request_stream(n, arrivals, SHAPES, seed0=100,
                                 engine=engine, **COMMON)


def _assert_one_shot_parity(sched, c):
    one = sched.one_shot(c.request)
    assert bool(c.result.ok[c.lane]) == bool(one.ok[0])
    assert int(c.result.attempts[c.lane]) == int(one.attempts[0])
    np.testing.assert_array_equal(c.result.hypotheses[c.lane],
                                  one.hypotheses[0])
    np.testing.assert_array_equal(c.result.disputed[c.lane],
                                  one.disputed[0])
    if c.ok:
        ref, got = one.per_task(0), c.per_task()
        assert ref.stuck_history == got.stuck_history
        for f in ("bits_coresets", "bits_weight_sums",
                  "bits_hypotheses", "bits_control", "bits_dispute"):
            assert getattr(ref.ledger, f) == getattr(got.ledger, f), f


def test_preempted_stream_completes_bit_identical(tmp_path):
    """Two dispatches preempted mid-stream (after 3 and 5 wire rounds),
    states checkpointed, batches requeued and resumed — EVERY request
    still completes bit-identical to its one_shot baseline."""
    reqs = _stream(24)
    sched = S.BoostScheduler(lattice=LATTICE, ckpt_dir=str(tmp_path),
                             preempt={0: 3, 2: 5})
    done = sched.run_stream(reqs)
    assert len(done) == len(reqs)
    assert sched.stats.preemptions == 2
    assert sched.stats.resumes == 2
    resumed = [c for c in done if c.resumed]
    assert len(resumed) >= 2
    # each checkpoint hit disk (the resume read it back) and was
    # deleted once its batch completed — no stale state accumulates
    ckpts = [f for f in os.listdir(tmp_path) if f.endswith(".msgpack")]
    assert ckpts == []
    for c in done:
        _assert_one_shot_parity(sched, c)


def test_preempted_equals_unpreempted_stream(tmp_path):
    """The same stream with and without fault injection yields the same
    per-request protocol outputs — preemption only changes timing."""
    reqs = _stream(8, seed=5)
    cache = S.CompileCache()
    plain = S.BoostScheduler(lattice=LATTICE, cache=cache)
    done_plain = {c.request.rid: c for c in plain.run_stream(reqs)}
    pre = S.BoostScheduler(lattice=LATTICE, cache=cache,
                           ckpt_dir=str(tmp_path), preempt={0: 2})
    done_pre = {c.request.rid: c for c in pre.run_stream(reqs)}
    assert pre.stats.resumes == 1
    assert done_plain.keys() == done_pre.keys()
    for rid, cp in done_pre.items():
        c0 = done_plain[rid]
        np.testing.assert_array_equal(cp.result.hypotheses[cp.lane],
                                      c0.result.hypotheses[c0.lane])
        np.testing.assert_array_equal(cp.result.disputed[cp.lane],
                                      c0.result.disputed[c0.lane])
        if cp.ok:
            assert (cp.per_task().ledger.total_bits
                    == c0.per_task().ledger.total_bits)


def test_sharded_preemption_keeps_wire_ledger_valid(tmp_path):
    """A preempted sharded dispatch resumes with its collective payload
    counters intact: validate_ledger still passes on every ok lane."""
    reqs = _stream(6, engine="sharded", seed=7)
    sched = S.BoostScheduler(lattice=LATTICE, ckpt_dir=str(tmp_path),
                             preempt={0: 2})
    done = sched.run_stream(reqs)
    assert len(done) == 6
    assert sched.stats.resumes == 1
    validated = 0
    for c in done:
        if c.ok:
            c.validate_ledger()
            validated += 1
        _assert_one_shot_parity(sched, c)
    assert validated > 0


def test_chained_re_preemption_checkpoints_incrementally(tmp_path):
    """A resumed dispatch can itself be preempted again: the second
    checkpoint chains incrementally onto the first (only changed
    leaves on disk), and the chain restores to a bit-identical
    completion.  Resumes consume dispatch seqs, so preempt={0:…, 1:…}
    targets the batch's first dispatch AND its first resume."""
    from repro.ckpt import msgpack_ckpt
    reqs = S.make_request_stream(2, np.zeros(2), [SHAPES[0]], seed0=2,
                                 **COMMON)   # one shape ⇒ one bucket
    sched = S.BoostScheduler(lattice=LATTICE, ckpt_dir=str(tmp_path),
                             preempt={0: 2, 1: 2})
    for r in reqs:
        sched.submit(r)
    done, _ = sched.step()                   # dispatch 0: preempted
    assert done == [] and sched.stats.preemptions == 1
    done, _ = sched.step()                   # resume 1: re-preempted
    assert done == [] and sched.stats.preemptions == 2
    assert sched.stats.resumes == 1
    sched._ckpt_writer().wait()              # flush the async writer
    ckpts = sorted(f for f in os.listdir(tmp_path)
                   if f.endswith(".msgpack"))
    assert len(ckpts) == 2
    assert msgpack_ckpt.snapshot_base(
        os.path.join(tmp_path, ckpts[1])) == ckpts[0]
    done, _ = sched.step()                   # resume 2: completes
    assert len(done) == 2 and all(c.resumed for c in done)
    assert sched.stats.resumes == 2
    # the whole chain is deleted once the batch completes
    assert [f for f in os.listdir(tmp_path)
            if f.endswith(".msgpack")] == []
    for c in done:
        _assert_one_shot_parity(sched, c)


def test_preempt_requires_ckpt_dir():
    with pytest.raises(ValueError):
        S.BoostScheduler(lattice=LATTICE, preempt={0: 3})


def test_queued_counts_suspended_batches(tmp_path):
    """A preempted batch is requeued — visible in queued(), drained by
    the next step()."""
    reqs = S.make_request_stream(2, np.zeros(2), [SHAPES[0]], seed0=1,
                                 **COMMON)   # one shape ⇒ one bucket
    sched = S.BoostScheduler(lattice=LATTICE, ckpt_dir=str(tmp_path),
                             preempt={0: 2})
    for r in reqs:
        sched.submit(r)
    n0 = sched.queued()
    assert n0 == 2
    done, _ = sched.step()
    assert done == [] and sched.stats.preemptions == 1
    assert sched.queued() == 2            # requeued, not lost
    done, _ = sched.step()
    assert len(done) == 2 and all(c.resumed for c in done)
    assert sched.queued() == 0
