"""Streaming tier: chunked sorts, chunked histograms, bounded-memory
quantile sketch, and the `chunk_size` engine capability.

The tier's contract (docs/streaming.md) has two halves:

* **Bitwise** — everything on the protocol path is chunked by
  *identity-preserving* decomposition: `streaming.sort_order` equals
  the stable `jnp.argsort` exactly, chunked histogram accumulation
  equals the monolithic kernels exactly on dyadic weights, so
  `BoostConfig.chunk_size` is invisible to hypotheses, rounds,
  quarantine and ledger across all three engines.
* **Self-accounted** — the sketch path (`streaming.build_sketch`) is
  lossy but HONEST: `streaming.coreset_bound` must dominate the
  measured sup-loss approximation error, and in the bench regime land
  under the paper's ε = 1/100 (the pinned ε-approximation guarantee).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import approximation, batched, classify, sharded_batched
from repro.core import streaming, tasks, weak
from repro.core.types import EPS_APPROX, BoostConfig
from repro.data import chunks as data_chunks
from repro.kernels.histogram import ops as hist_ops


# ---------------------------------------------------------------------------
# sort_order ≡ stable argsort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,chunk", [
    (1024, 128),      # dividing
    (1000, 128),      # ragged last run
    (7, 3),           # tiny, odd run count
    (513, 512),       # one full + one singleton run
    (64, 64),         # single chunk (delegates)
    (64, 4096),       # chunk > m (delegates)
])
def test_sort_order_matches_argsort(m, chunk):
    rng = np.random.default_rng(m * 1000 + chunk)
    n = 1 << 12
    x_int = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    got = streaming.sort_order(x_int, chunk, n)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.argsort(x_int)))
    x_f = jnp.asarray(rng.normal(size=m), jnp.float32)
    got_f = streaming.sort_order(x_f, chunk)
    np.testing.assert_array_equal(np.asarray(got_f),
                                  np.asarray(jnp.argsort(x_f)))


def test_sort_order_stable_under_heavy_ties():
    # stability is THE property the engines' deterministic coresets
    # lean on: equal keys must keep index order, exactly as the
    # monolithic stable argsort does
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 4, 4096), jnp.int32)   # ~1k ties/key
    got = streaming.sort_order(x, 100, 4)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.argsort(x)))


def test_sort_order_none_is_monolithic():
    x = jnp.asarray([3, 1, 2], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(streaming.sort_order(x, None)),
        np.asarray(jnp.argsort(x)))


# ---------------------------------------------------------------------------
# chunked histograms ≡ monolithic, bitwise, on dyadic weights
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,tile,batched_form", [
    (257, 64, False), (130, 200, False), (512, 128, False),
    (257, 64, True), (1, 1, True),
])
def test_chunked_histograms_bitwise(c, tile, batched_form):
    rng = np.random.default_rng(c * 7 + tile)
    F, Q, NODES = 5, 16, 3
    x = jnp.asarray((rng.integers(0, Q, (c, F)) + 0.5) / Q, jnp.float32)
    w = jnp.asarray(rng.integers(0, 256, (NODES, c)) / 256.0, jnp.float32)
    wy = w * jnp.asarray(rng.choice([-1.0, 1.0], (NODES, c)), jnp.float32)
    if batched_form:
        x, w, wy = x[None], w[None], wy[None]
    ref = hist_ops.node_histograms_ref(x, w, wy, Q)
    chunked_ref = hist_ops.node_histograms_chunked_ref(x, w, wy, Q, tile)
    for a, b in zip(chunked_ref, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # dispatching entry (covers the Pallas-interpret routing on CPU)
    got = hist_ops.node_histograms(x, w, wy, Q, chunk_size=tile)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunked_best_splits_bitwise():
    rng = np.random.default_rng(0)
    c, F, Q, NODES = 321, 4, 8, 2
    x = jnp.asarray((rng.integers(0, Q, (c, F)) + 0.5) / Q, jnp.float32)
    w = jnp.asarray(rng.integers(0, 256, (NODES, c)) / 256.0, jnp.float32)
    wy = w * jnp.asarray(rng.choice([-1.0, 1.0], (NODES, c)), jnp.float32)
    ref = hist_ops.best_node_splits(x, w, wy, Q)
    got = hist_ops.best_node_splits(x, w, wy, Q, chunk_size=100)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# chunk feed (data/chunks.py)
# ---------------------------------------------------------------------------

def test_iter_chunks_tiles_and_offsets():
    x = np.arange(10)
    y = np.arange(10) * 2
    tiles = list(data_chunks.iter_chunks((x, y), 4))
    assert [t[-1] for t in tiles] == [0, 4, 8]
    np.testing.assert_array_equal(np.concatenate([t[0] for t in tiles]),
                                  x)
    np.testing.assert_array_equal(np.concatenate([t[1] for t in tiles]),
                                  y)
    assert len(tiles[-1][0]) == 2          # ragged tail preserved


def test_iter_chunks_validates():
    with pytest.raises(ValueError):
        list(data_chunks.iter_chunks((np.arange(3), np.arange(4)), 2))
    with pytest.raises(ValueError):
        list(data_chunks.iter_chunks((np.arange(3),), 0))


def test_prefetch_preserves_order_and_values():
    x = np.arange(100)
    tiles = list(data_chunks.prefetch_to_device(
        data_chunks.iter_chunks((x,), 7), depth=2))
    assert all(isinstance(t[0], jax.Array) for t in tiles)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(t[0]) for t in tiles]), x)
    assert [t[-1] for t in tiles] == list(range(0, 100, 7))


# ---------------------------------------------------------------------------
# quantile sketch: exactness, honesty, pinned ε
# ---------------------------------------------------------------------------

def _random_stream(m, seed, n=1 << 14, hmax=13, p_pos=0.5,
                   dead_frac=0.0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, n, m).astype(np.int32)
    y = np.where(rng.random(m) < p_pos, 1, -1).astype(np.int8)
    hits = rng.integers(0, hmax + 1, m).astype(np.int32)
    alive = rng.random(m) >= dead_frac
    w = np.asarray(streaming.sketch_weights(jnp.asarray(hits),
                                            jnp.asarray(alive)))
    return x, y, hits, alive, w


def _measured_error(idx, x, y, hits, alive, n=1 << 14):
    # sup over a dense threshold grid, both polarities — the class the
    # integer track boosts over
    theta = np.arange(0, n + 1, max(1, n // 256), dtype=np.int32)
    grid = jnp.asarray(np.stack(
        [np.concatenate([theta, theta]),
         np.concatenate([np.ones_like(theta), -np.ones_like(theta)])],
        axis=1))

    def predict(params, pts):
        return (jnp.where(pts[None, :] <= params[:, 0:1], 1, -1)
                * params[:, 1:2])

    return float(approximation.approximation_error(
        idx, jnp.asarray(x), jnp.asarray(y), jnp.asarray(hits),
        jnp.asarray(alive), predict, grid))


def test_sketch_uncompressed_matches_quantile_coreset():
    # cap ≥ m ⇒ no compression anywhere ⇒ the sketch coreset IS the
    # deterministic quantile coreset, index for index
    m, c = 999, 64
    x, y, hits, alive, w = _random_stream(m, seed=1)
    feed = data_chunks.iter_shard_chunks(x, y, w, 128)
    sk = streaming.build_sketch(feed, cap=1024)
    got = streaming.sketch_coreset(sk, c)
    ref = approximation.quantile_coreset(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(hits),
        jnp.asarray(alive), c)
    gx, gy = x[np.asarray(got)], y[np.asarray(got)]
    rx, ry = x[np.asarray(ref)], y[np.asarray(ref)]
    np.testing.assert_array_equal(gx, rx)
    np.testing.assert_array_equal(gy, ry)
    assert float(streaming.coreset_bound(sk, c)) <= 4 / c + 1e-6


@pytest.mark.parametrize("m,hmax,p_pos,dead", [
    (20_000, 13, 0.5, 0.0),
    (20_000, 13, 0.9, 0.1),
    (50_000, 40, 0.5, 0.0),     # extreme skew: 2^-40 weights
    (50_000, 0, 0.5, 0.3),
])
def test_sketch_bound_is_honest(m, hmax, p_pos, dead):
    # the sketch may be coarse, but it must never claim better than it
    # delivers: measured sup-loss error ≤ its self-accounted bound
    x, y, hits, alive, w = _random_stream(m, seed=m + hmax, hmax=hmax,
                                          p_pos=p_pos, dead_frac=dead)
    feed = data_chunks.iter_shard_chunks(x, y, w, 2048)
    sk = streaming.build_sketch(feed, cap=4096)
    c = 256
    idx = streaming.sketch_coreset(sk, c)
    bound = float(streaming.coreset_bound(sk, c))
    measured = _measured_error(idx, x, y, hits, alive)
    assert measured <= bound + 1e-6, (measured, bound)


def test_sketch_pinned_epsilon_guarantee():
    # the bench regime (cap=16384, c=1024): the self-accounted bound
    # must land under the paper's ε = 1/100, and the measured error
    # under the bound — the streaming tier's ε-approximation pin
    m = 100_000
    x, y, hits, alive, w = _random_stream(m, seed=5)
    feed = data_chunks.iter_shard_chunks(x, y, w, 16_384)
    sk = streaming.build_sketch(feed, cap=16_384)
    c = 1024
    idx = streaming.sketch_coreset(sk, c)
    bound = float(streaming.coreset_bound(sk, c))
    measured = _measured_error(idx, x, y, hits, alive)
    assert measured <= bound + 1e-6, (measured, bound)
    assert bound <= EPS_APPROX, bound


def test_build_sketch_empty_stream_raises():
    with pytest.raises(ValueError):
        streaming.build_sketch(iter(()), cap=64)


# ---------------------------------------------------------------------------
# chunk_size is bitwise invisible to the engines
# ---------------------------------------------------------------------------

def _engine_cfg(chunk, n, k=4):
    return BoostConfig(k=k, coreset_size=64, domain_size=n,
                       opt_budget=32, chunk_size=chunk)


def test_host_engine_chunk_parity():
    n = 1 << 12
    cls = weak.Thresholds(n=n)
    task = tasks.make_task(cls, m=1024, k=4, noise=3, seed=2)
    x, y = jnp.asarray(task.x), jnp.asarray(task.y)
    key = jax.random.key(0)
    ref = classify.run_accurately_classify(x, y, key,
                                           _engine_cfg(None, n), cls)
    got = classify.run_accurately_classify(x, y, key,
                                           _engine_cfg(100, n), cls)
    np.testing.assert_array_equal(np.asarray(ref.hypotheses),
                                  np.asarray(got.hypotheses))
    assert ref.rounds == got.rounds
    assert ref.attempts == got.attempts
    assert ref.ledger.total_bits == got.ledger.total_bits


def test_batched_engine_chunk_parity():
    n = 1 << 12
    cls = weak.Thresholds(n=n)
    B, k = 2, 4
    x, y, _ = tasks.make_batch(cls, B, 512, k, 3, seed0=11)
    keys = jax.random.split(jax.random.key(5), B)
    ref = batched.run_accurately_classify_batched(
        x, y, keys, _engine_cfg(None, n), cls)
    got = batched.run_accurately_classify_batched(
        x, y, keys, _engine_cfg(100, n), cls)
    for f in ("hypotheses", "rounds", "ok", "attempts", "disputed",
              "alive", "min_loss"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(got, f)), f)
    for b in range(B):
        assert ref.ledger(b).total_bits == got.ledger(b).total_bits


def test_sharded_engine_chunk_parity():
    n = 1 << 12
    cls = weak.Thresholds(n=n)
    B, k = 2, 4
    x, y, _ = tasks.make_batch(cls, B, 512, k, 3, seed0=11)
    keys = jax.random.split(jax.random.key(5), B)
    ref = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, _engine_cfg(None, n), cls)
    got = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, _engine_cfg(100, n), cls)
    for f in ("hypotheses", "rounds", "ok", "attempts", "disputed"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(got, f)), f)


def test_tree_class_chunk_parity():
    # feature-track: HistTrees with chunk_size must produce the same
    # splits (histograms are bitwise ⇒ argmin ties break identically)
    from repro.weak_tree import trees as T
    rng = np.random.default_rng(3)
    c, F = 300, 4
    cls = T.HistogramTrees(num_features=F, depth=2, bins=16)
    cls_chunked = T.HistogramTrees(num_features=F, depth=2, bins=16,
                                   chunk_size=128)
    x = jnp.asarray(rng.normal(size=(c, F)), jnp.float32)
    y = jnp.asarray(rng.choice([-1, 1], c), jnp.int8)
    # dyadic weights (the protocol's 2^-hits regime): partial f32 sums
    # are exact, so chunked accumulation is bitwise — the contract
    w = jnp.asarray(rng.integers(0, 256, c) / 256.0, jnp.float32)
    p_ref, l_ref = cls.erm(x, y, w)
    p_got, l_got = cls_chunked.erm(x, y, w)
    np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_got))
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_got))
