"""End-to-end behaviour tests: the full learning protocol and the full
neural training driver, exercised through the public APIs."""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classify, ledger, tasks, weak
from repro.core.types import BoostConfig


def test_end_to_end_protocol_beats_naive_communication():
    """The headline claim: polylog communication at polylog OPT, far
    below shipping the raw data, with E_S(f) ≤ OPT."""
    n = 1 << 16
    m = 1 << 14
    cls = weak.Thresholds(n=n)
    cfg = BoostConfig(k=8, coreset_size=400, domain_size=n,
                      opt_budget=24)
    task = tasks.make_task(cls, m=m, k=8, noise=6, seed=11)
    opt = tasks.true_opt(task)
    f, res = classify.learn(jnp.asarray(task.x), jnp.asarray(task.y),
                            jax.random.key(0), cfg, cls)
    errs = int(weak.empirical_errors(f(jnp.asarray(task.flat_x)),
                                     jnp.asarray(task.flat_y)))
    assert errs <= opt
    naive = ledger.naive_baseline_bits(m, n)
    # protocol total must not blow up as m grows (polylog vs linear):
    # at m = 16384 the naive baseline is already comparable, the point
    # is the SCALING — verified in benchmarks/comm_vs_m; here we assert
    # the protocol transmitted < coreset_rounds upper bound and is
    # within the Thm 4.1 envelope.
    bound = ledger.theorem_41_bound(cfg, cls, m, opt, constant=4.0)
    assert res.ledger.total_bits <= bound
    assert res.ledger.rounds <= (opt + 1) * (cfg.num_rounds(m) + 1)


def test_end_to_end_training_driver():
    """launch/train.py --resilient on a noisy corpus: loss decreases and
    planted noise is quarantined with high precision."""
    from repro.launch.train import run
    args = argparse.Namespace(
        arch="deepseek-7b", smoke=True, steps=300, batch=64,
        seq_len=32, d_model=128, vocab=128, num_examples=1024,
        noise=0.10, resilient=True, check_every=25, coreset=48,
        min_gap=3, lr=1e-3, seed=0, log_every=150, ckpt_dir=None,
        ckpt_every=999)
    out = run(args)
    assert out["final_train_loss"] < 4.0
    assert out["clean_eval_loss"] < 4.5
    assert out["noise_recall"] >= 0.6
    assert out["noise_precision"] >= 0.6


def test_end_to_end_serving_driver():
    from repro.launch.serve import run
    args = argparse.Namespace(arch="qwen3-32b", smoke=True, batch=2,
                              prompt_len=32, gen=8, seed=0)
    out = run(args)
    assert out["tokens_finite"]
    assert len(out["sample"]) > 0
