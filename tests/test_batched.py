"""Batched engine ≡ reference loop, bit for bit.

The device-resident engine (core/batched.py) must reproduce
``run_accurately_classify`` exactly when given the same per-task keys:
same attempt/stuck history, same quarantine sets, same ledger bits,
bitwise-identical hypotheses, and an identical final classifier.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched, classify, tasks, weak
from repro.core.types import BoostConfig

N = 1 << 12


def _batch_of_tasks(cls, B, m, k, noise, seed0):
    x, y, _ = tasks.make_batch(cls, B, m, k, noise, seed0=seed0)
    return x, y


def _assert_task_parity(ref, got):
    assert ref.attempts == got.attempts
    assert ref.rounds == got.rounds
    assert ref.stuck_history == got.stuck_history
    # hypotheses of the winning attempt: bitwise
    np.testing.assert_array_equal(
        np.asarray(ref.hypotheses)[:ref.rounds],
        np.asarray(got.hypotheses)[:got.rounds])
    # ledger: identical integer bit counts, field by field
    for f in ("bits_coresets", "bits_weight_sums", "bits_hypotheses",
              "bits_control", "bits_dispute", "rounds", "attempts"):
        assert getattr(ref.ledger, f) == getattr(got.ledger, f), f
    # quarantine set: same unique points, same D-table counts
    ref_pts = np.unique(np.asarray(ref.dispute_x))
    got_pts = np.unique(np.asarray(got.dispute_x))
    np.testing.assert_array_equal(ref_pts, got_pts)
    rp, rn = (np.asarray(a) for a in ref.dispute_y)
    gp, gn = (np.asarray(a) for a in got.dispute_y)
    # reference may carry duplicate entries (re-disputed dead points
    # count 0); aggregate per point before comparing
    def agg(pts, vals):
        out = {}
        for p, v in zip(pts.tolist(), vals.tolist()):
            out[p] = out.get(p, 0) + v
        return out
    assert agg(np.asarray(ref.dispute_x), rp) == \
        agg(np.asarray(got.dispute_x), gp)
    assert agg(np.asarray(ref.dispute_x), rn) == \
        agg(np.asarray(got.dispute_x), gn)


@pytest.mark.parametrize("clsname,noise", [
    ("thresholds", 0), ("thresholds", 3), ("intervals", 3),
    ("singletons", 2),
])
def test_batched_bitwise_parity(clsname, noise):
    cls = weak.make_class(clsname, n=N)
    cfg = BoostConfig(k=4, coreset_size=100, domain_size=N,
                      opt_budget=16)
    B, m = 4, 512
    x, y = _batch_of_tasks(cls, B, m, 4, noise, seed0=11)
    keys = jax.random.split(jax.random.key(5), B)
    res = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    assert bool(res.ok.all())
    for b in range(B):
        ref = classify.run_accurately_classify(
            jnp.asarray(x[b]), jnp.asarray(y[b]), keys[b], cfg, cls)
        got = res.per_task(b)
        _assert_task_parity(ref, got)
        # the final classifiers agree everywhere on S
        f_ref = classify.make_classifier(cls, ref)
        f_got = res.classifier(b)
        flat = x[b].reshape(-1)
        np.testing.assert_array_equal(
            np.asarray(f_ref(jnp.asarray(flat))),
            np.asarray(f_got(jnp.asarray(flat))))


def test_batched_parity_feature_track():
    """AxisStumps (randomized coreset, feature rows) parity."""
    cls = weak.AxisStumps(num_features=4)
    cfg = BoostConfig(k=2, coreset_size=64, domain_size=N, opt_budget=8,
                      deterministic_coreset=False)
    B, m = 2, 128
    x, y = _batch_of_tasks(cls, B, m, 2, 1, seed0=3)
    keys = jax.random.split(jax.random.key(9), B)
    res = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    assert bool(res.ok.all())
    for b in range(B):
        ref = classify.run_accurately_classify(
            jnp.asarray(x[b]), jnp.asarray(y[b]), keys[b], cfg, cls)
        got = res.per_task(b)
        assert ref.attempts == got.attempts
        assert ref.stuck_history == got.stuck_history
        np.testing.assert_array_equal(
            np.asarray(ref.hypotheses)[:ref.rounds],
            np.asarray(got.hypotheses)[:got.rounds])
        assert ref.ledger.total_bits == got.ledger.total_bits


def test_batched_ragged_padding():
    """A padded (alive=False) task matches the host loop on the same
    mask — ragged batches are just masks."""
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=4, coreset_size=100, domain_size=N,
                      opt_budget=16)
    B, m = 3, 512
    x, y = _batch_of_tasks(cls, B, m, 4, 2, seed0=23)
    alive0 = np.ones((B, 4, m // 4), bool)
    alive0[1, :, -40:] = False            # task 1 is padded to m
    keys = jax.random.split(jax.random.key(2), B)
    res = batched.run_accurately_classify_batched(
        x, y, keys, cfg, cls, alive=alive0)
    assert bool(res.ok.all())
    for b in range(B):
        ref = classify.run_accurately_classify(
            jnp.asarray(x[b]), jnp.asarray(y[b]), keys[b], cfg, cls,
            alive=jnp.asarray(alive0[b]))
        got = res.per_task(b)
        assert ref.attempts == got.attempts
        assert ref.stuck_history == got.stuck_history
        np.testing.assert_array_equal(
            np.asarray(ref.hypotheses)[:ref.rounds],
            np.asarray(got.hypotheses)[:got.rounds])
        assert ref.ledger.total_bits == got.ledger.total_bits


def test_batched_budget_exhaustion_flags_not_raises():
    """Host loop raises when OPT exceeds the budget; the batched engine
    must flag ok=False for that lane (and only that lane)."""
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=2, coreset_size=32, domain_size=N, opt_budget=0)
    rng = np.random.default_rng(0)
    m = 128
    x0 = rng.integers(0, N, m).astype(np.int32)
    y0 = np.where(x0 >= N // 2, 1, -1).astype(np.int8)
    # a contradicting pair makes the sample non-realizable ⇒ stuck
    x0[0], y0[0] = 7, 1
    x0[1], y0[1] = 7, -1
    x_bad = x0.reshape(2, -1)
    y_bad = y0.reshape(2, -1)
    t_ok = tasks.make_task(cls, m=m, k=2, noise=0, seed=1)
    x = np.stack([x_bad, t_ok.x])
    y = np.stack([y_bad, t_ok.y])
    keys = jax.random.split(jax.random.key(0), 2)
    res = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    assert not bool(res.ok[0]) and bool(res.ok[1])
    with pytest.raises(RuntimeError):
        res.per_task(0)
    with pytest.raises(RuntimeError):
        classify.run_accurately_classify(
            jnp.asarray(x[0]), jnp.asarray(y[0]), keys[0], cfg, cls)
    res.per_task(1)          # healthy lane still materialises
