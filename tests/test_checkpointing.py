"""Checkpoint durability + incremental/async/template-free semantics.

ISSUE 6 coverage: engine-state round-trips (both engines, thresholds +
histogram trees) through the template-free path, loud shape/dtype
mismatches instead of silent ``astype``, crash-mid-write atomicity
(fsync before publish), incremental chains restoring ≡ full snapshots,
manager retention with chain-ancestor protection, and the async
writer's barrier/error contract.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import msgpack_ckpt
from repro.core import batched, scenarios, sharded_batched, tasks, weak
from repro.core.types import BoostConfig
from repro.weak_tree import HistogramTrees

N = 1 << 10
CLS = weak.Thresholds(n=N)
CFG = BoostConfig(k=4, coreset_size=32, domain_size=N, opt_budget=4)


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(x, y)


@pytest.fixture(scope="module")
def batched_state():
    x, y, _ = tasks.make_batch(CLS, 2, 64, 4, 1, seed0=7)
    keys = jax.random.split(jax.random.key(2), 2)
    st = batched.init_state(x, y, keys, CFG)
    st = batched.run_rounds(st, x, y, CFG, CLS, n=3)
    return jax.block_until_ready(st), (x, y, CFG, CLS)


# ---------------------------------------------------------------------------
# Template-free round-trips (both engines, stumps + trees)
# ---------------------------------------------------------------------------

def test_roundtrip_batched_template_free(tmp_path, batched_state):
    """Restore rebuilds the exact StepState from the manifest alone —
    no template, no engine init — bit-identical, dtypes preserved."""
    state, _ = batched_state
    path = str(tmp_path / "s.msgpack")
    msgpack_ckpt.save_pytree(path, jax.device_get(state),
                             meta={"rounds_done": 3},
                             treedef=batched.STATE_TREEDEF)
    restored, meta = msgpack_ckpt.restore_pytree(path)
    assert isinstance(restored, batched.StepState)
    assert meta["rounds_done"] == 3
    _assert_trees_equal(state, restored)
    # ...and matches the legacy template path exactly
    via_like, _ = msgpack_ckpt.load_pytree(path, like=state)
    _assert_trees_equal(restored, via_like)


def test_roundtrip_batched_trees(tmp_path):
    """A histogram-tree engine state (feature inputs, wider h_params)
    round-trips template-free too — the manifest, not the hypothesis
    class, defines the layout."""
    cls = HistogramTrees(num_features=4, depth=2, bins=8)
    cfg = BoostConfig(k=4, coreset_size=32,
                      domain_size=1 << min(cls.value_bits, 30),
                      opt_budget=4, deterministic_coreset=False)
    spec = scenarios.ScenarioSpec(name="xor", noise=2)
    ts = [scenarios.make_feature_task(cls, m=64, k=4, spec=spec, seed=s)
          for s in range(2)]
    x = np.stack([t.x for t in ts])
    y = np.stack([t.y for t in ts])
    keys = jax.random.split(jax.random.key(3), 2)
    st = batched.init_state(x, y, keys, cfg, cls=cls)
    st = batched.run_rounds(st, x, y, cfg, cls, n=2)
    path = str(tmp_path / "t.msgpack")
    msgpack_ckpt.save_pytree(path, jax.device_get(st),
                             treedef=batched.STATE_TREEDEF)
    restored, _ = msgpack_ckpt.restore_pytree(path)
    assert isinstance(restored, batched.StepState)
    _assert_trees_equal(st, restored)


@pytest.mark.xdist_group("device_mesh_subprocess")
def test_roundtrip_sharded_template_free(tmp_path):
    x, y, _ = tasks.make_batch(CLS, 2, 64, 4, 1, seed0=9)
    keys = jax.random.split(jax.random.key(4), 2)
    st = sharded_batched.init_state_sharded(x, y, keys, CFG, cls=CLS)
    st = sharded_batched.run_rounds_sharded(st, x, y, CFG, CLS, n=2)
    path = str(tmp_path / "sh.msgpack")
    msgpack_ckpt.save_pytree(path, jax.device_get(st),
                             treedef=sharded_batched.STATE_TREEDEF)
    restored, _ = msgpack_ckpt.restore_pytree(path)
    assert isinstance(restored, dict)
    assert set(restored) == set(st)
    for k in st:
        np.testing.assert_array_equal(np.asarray(st[k]),
                                      np.asarray(restored[k]))


def test_template_free_rejects_dtype_drift(tmp_path, batched_state):
    """The engine reconstructor re-checks its declared dtype layout —
    a checkpoint whose leaves drifted is refused, not silently cast."""
    state, _ = batched_state
    bad = state._replace(hits=np.asarray(state.hits, np.int64))
    path = str(tmp_path / "bad.msgpack")
    msgpack_ckpt.save_pytree(path, jax.device_get(bad),
                             treedef=batched.STATE_TREEDEF)
    with pytest.raises(ValueError, match="dtype"):
        msgpack_ckpt.restore_pytree(path)


def test_unregistered_treedef_raises(tmp_path):
    path = str(tmp_path / "u.msgpack")
    msgpack_ckpt.save_pytree(path, {"a": np.zeros(2, np.int32)},
                             treedef="no.such.treedef")
    with pytest.raises(KeyError, match="not registered"):
        msgpack_ckpt.restore_pytree(path)


# ---------------------------------------------------------------------------
# Loud mismatches + owned arrays (satellite 1)
# ---------------------------------------------------------------------------

def test_load_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "c.msgpack")
    msgpack_ckpt.save_pytree(path, {"a": np.zeros(4, np.float32)})
    with pytest.raises(ValueError, match="shape"):
        msgpack_ckpt.load_pytree(path, like={"a": np.zeros(5,
                                                           np.float32)})


def test_load_dtype_mismatch_raises_not_casts(tmp_path):
    """The old path did ``astype`` here — resuming f32 state into an
    f64 template silently changed every subsequent weight update."""
    path = str(tmp_path / "c.msgpack")
    msgpack_ckpt.save_pytree(path, {"a": np.zeros(4, np.float32)})
    with pytest.raises(ValueError, match="dtype"):
        msgpack_ckpt.load_pytree(path, like={"a": np.zeros(4,
                                                           np.float64)})


def test_load_missing_key_raises(tmp_path):
    path = str(tmp_path / "c.msgpack")
    msgpack_ckpt.save_pytree(path, {"a": np.zeros(4, np.float32)})
    with pytest.raises(KeyError, match="missing"):
        msgpack_ckpt.load_pytree(path, like={"a": np.zeros(4, np.float32),
                                             "b": np.zeros(1, np.int32)})


def test_loaded_arrays_are_owned_and_writable(tmp_path):
    """np.frombuffer over the msgpack blob yields read-only views; the
    loader must hand back owned copies that survive in-place updates."""
    path = str(tmp_path / "c.msgpack")
    msgpack_ckpt.save_pytree(path, {"a": np.arange(6, dtype=np.int32)})
    arrays, _ = msgpack_ckpt.load_pytree(path)
    assert arrays["a"].flags.writeable
    arrays["a"] += 1          # would raise on a frombuffer view
    np.testing.assert_array_equal(arrays["a"], np.arange(1, 7))


# ---------------------------------------------------------------------------
# Durable atomic writes (satellite 2)
# ---------------------------------------------------------------------------

def test_fsync_before_publish_then_dir(tmp_path, monkeypatch):
    events = []
    real_fsync, real_replace = os.fsync, os.replace

    def spy_fsync(fd):
        events.append("fsync")
        return real_fsync(fd)

    def spy_replace(src, dst):
        events.append("replace")
        return real_replace(src, dst)

    monkeypatch.setattr(os, "fsync", spy_fsync)
    monkeypatch.setattr(os, "replace", spy_replace)
    msgpack_ckpt.save_pytree(str(tmp_path / "c.msgpack"),
                             {"a": np.ones(2, np.float32)})
    # data fsync'd BEFORE the atomic publish, directory entry after
    assert events == ["fsync", "replace", "fsync"]


@pytest.mark.parametrize("crash_at", ["fsync", "replace"])
def test_crash_mid_write_preserves_previous(tmp_path, monkeypatch,
                                            crash_at):
    """A crash between write and publish never corrupts the previous
    snapshot and never leaks the temp file."""
    path = str(tmp_path / "c.msgpack")
    first = {"a": np.arange(4, dtype=np.int32)}
    msgpack_ckpt.save_pytree(path, first)

    def boom(*a, **k):
        raise OSError("simulated crash")

    monkeypatch.setattr(os, crash_at, boom)
    with pytest.raises(OSError, match="simulated crash"):
        msgpack_ckpt.save_pytree(path, {"a": np.zeros(4, np.int32)})
    monkeypatch.undo()
    got, _ = msgpack_ckpt.load_pytree(path, like=first)
    np.testing.assert_array_equal(got["a"], first["a"])
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []


def test_corrupt_checkpoint_raises_clearly(tmp_path):
    path = tmp_path / "c.msgpack"
    path.write_bytes(b"\xde\xad\xbe\xef not msgpack")
    with pytest.raises(ValueError, match="corrupt checkpoint"):
        msgpack_ckpt.load_pytree(str(path))


# ---------------------------------------------------------------------------
# Incremental chains
# ---------------------------------------------------------------------------

def test_incremental_chain_restores_equal_to_full(tmp_path,
                                                  batched_state):
    state, (x, y, cfg, cls) = batched_state
    base_path = str(tmp_path / "c0.msgpack")
    hashes = msgpack_ckpt.save_pytree(base_path, jax.device_get(state),
                                      treedef=batched.STATE_TREEDEF)
    state2 = batched.run_rounds(state, x, y, cfg, cls, n=2)
    host2 = jax.device_get(state2)
    tip = str(tmp_path / "c1.msgpack")
    msgpack_ckpt.save_pytree(tip, host2, treedef=batched.STATE_TREEDEF,
                             base=base_path, base_hashes=hashes)
    full = str(tmp_path / "full.msgpack")
    msgpack_ckpt.save_pytree(full, host2,
                             treedef=batched.STATE_TREEDEF)
    assert msgpack_ckpt.snapshot_base(tip) == "c0.msgpack"
    assert msgpack_ckpt.snapshot_base(full) is None
    assert os.path.getsize(tip) < os.path.getsize(full)
    via_chain, _ = msgpack_ckpt.restore_pytree(tip)
    via_full, _ = msgpack_ckpt.restore_pytree(full)
    _assert_trees_equal(via_chain, via_full)
    _assert_trees_equal(via_chain, state2)


def test_incremental_unchanged_leaves_not_rewritten(tmp_path):
    t0 = {"big": np.zeros(1024, np.float32),
          "ctr": np.int32(0)}
    p0 = str(tmp_path / "a0.msgpack")
    h0 = msgpack_ckpt.save_pytree(p0, t0)
    t1 = dict(t0, ctr=np.int32(1))        # only the counter changed
    p1 = str(tmp_path / "a1.msgpack")
    msgpack_ckpt.save_pytree(p1, t1, base=p0, base_hashes=h0)
    payload = msgpack_ckpt._read_payload(p1)
    assert set(payload["arrays"]) == {"ctr"}
    got, _ = msgpack_ckpt.load_pytree(p1, like=t1)
    _assert_trees_equal(got, t1)


# ---------------------------------------------------------------------------
# Async writer (tentpole b)
# ---------------------------------------------------------------------------

def test_async_writer_wait_is_a_durability_barrier(tmp_path,
                                                   batched_state):
    state, _ = batched_state
    w = msgpack_ckpt.AsyncCheckpointer(max_pending=2)
    paths = [str(tmp_path / f"a{i}.msgpack") for i in range(3)]
    for p in paths:
        w.save(p, state, treedef=batched.STATE_TREEDEF)
    w.wait()
    for p in paths:
        restored, _ = msgpack_ckpt.restore_pytree(p)
        _assert_trees_equal(state, restored)
    w.close()


def test_async_writer_chains_incrementally(tmp_path):
    w = msgpack_ckpt.AsyncCheckpointer()
    t0 = {"big": np.zeros(512, np.float32), "ctr": np.int32(0)}
    p0, p1, p2 = (str(tmp_path / f"c{i}.msgpack") for i in range(3))
    w.save(p0, t0, chain="d0")
    w.save(p1, dict(t0, ctr=np.int32(1)), chain="d0")
    w.wait()
    assert msgpack_ckpt.snapshot_base(p0) is None
    assert msgpack_ckpt.snapshot_base(p1) == "c0.msgpack"
    assert set(msgpack_ckpt._read_payload(p1)["arrays"]) == {"ctr"}
    w.forget("d0")                       # chain consumed → next is full
    w.save(p2, dict(t0, ctr=np.int32(2)), chain="d0")
    w.wait()
    assert msgpack_ckpt.snapshot_base(p2) is None
    w.close()


def test_async_writer_error_surfaces_in_wait(tmp_path):
    w = msgpack_ckpt.AsyncCheckpointer()
    blocker = tmp_path / "sub"
    blocker.write_text("a file where the save needs a directory")
    w.save(str(blocker / "x.msgpack"), {"a": np.zeros(2, np.int32)})
    with pytest.raises(RuntimeError, match="async checkpoint save"):
        w.wait()
    # the error is consumed; the writer stays usable
    ok = str(tmp_path / "ok.msgpack")
    w.save(ok, {"a": np.ones(2, np.int32)})
    w.wait()
    assert os.path.exists(ok)
    w.close()


def test_save_pytree_async_module_level(tmp_path):
    path = str(tmp_path / "m.msgpack")
    w = msgpack_ckpt.save_pytree_async(path, {"a": np.arange(3)})
    w.wait()
    arrays, _ = msgpack_ckpt.load_pytree(path)
    np.testing.assert_array_equal(arrays["a"], np.arange(3))


# ---------------------------------------------------------------------------
# CheckpointManager (satellite 3 + retention)
# ---------------------------------------------------------------------------

def test_manager_keep_zero_raises(tmp_path):
    """keep=0 used to silently disable retention (``steps()[:-0]`` is
    the empty slice) — it must refuse loudly."""
    with pytest.raises(ValueError, match="keep=0"):
        msgpack_ckpt.CheckpointManager(str(tmp_path), keep=0)
    with pytest.raises(ValueError, match="full_every"):
        msgpack_ckpt.CheckpointManager(str(tmp_path), full_every=0)


def test_manager_steps_skips_stray_files(tmp_path):
    mgr = msgpack_ckpt.CheckpointManager(str(tmp_path), keep=3)
    mgr.save(10, {"a": np.zeros(2, np.int32)})
    (tmp_path / "ckpt_garbage.msgpack").write_bytes(b"junk")
    (tmp_path / "ckpt_00000020.msgpack.tmp").write_bytes(b"junk")
    with pytest.warns(UserWarning, match="unparsable"):
        steps = mgr.steps()
    assert steps == [10]
    # the stray file is still on disk, so restore_latest's internal
    # steps() scan warns again (warnings are errors under pytest.ini)
    with pytest.warns(UserWarning, match="unparsable"):
        got, meta = mgr.restore_latest()
    assert meta["step"] == 10
    np.testing.assert_array_equal(got["a"], np.zeros(2))


def test_manager_restore_latest_empty_dir(tmp_path):
    mgr = msgpack_ckpt.CheckpointManager(str(tmp_path))
    assert mgr.restore_latest() == (None, None)


def test_manager_retention_protects_chain_ancestors(tmp_path):
    """keep=1 with a live incremental chain must NOT delete the bases
    the kept tip restores through."""
    mgr = msgpack_ckpt.CheckpointManager(str(tmp_path), keep=1,
                                         incremental=True,
                                         full_every=10)
    tree = {"big": np.zeros(256, np.float32), "ctr": np.int32(0)}
    for step in range(4):
        mgr.save(step, dict(tree, ctr=np.int32(step)))
    assert mgr.steps() == [0, 1, 2, 3]   # chain keeps every ancestor
    got, meta = mgr.restore_latest()
    assert meta["step"] == 3
    assert int(got["ctr"]) == 3
    np.testing.assert_array_equal(got["big"], tree["big"])


def test_manager_full_every_bounds_chains(tmp_path):
    """full_every=2 rolls a fresh full snapshot, letting retention
    finally collect the old chain."""
    mgr = msgpack_ckpt.CheckpointManager(str(tmp_path), keep=1,
                                         incremental=True,
                                         full_every=2)
    tree = {"big": np.zeros(256, np.float32), "ctr": np.int32(0)}
    for step in range(7):
        mgr.save(step, dict(tree, ctr=np.int32(step)))
    kept = mgr.steps()
    assert kept[-1] == 6
    assert len(kept) <= 3                # tip + its short chain only
    got, _ = mgr.restore_latest()
    assert int(got["ctr"]) == 6


def test_manager_template_free_restore_roundtrip(tmp_path,
                                                 batched_state):
    state, _ = batched_state
    mgr = msgpack_ckpt.CheckpointManager(str(tmp_path), keep=2,
                                         incremental=True,
                                         treedef=batched.STATE_TREEDEF)
    mgr.save(1, jax.device_get(state))
    restored, meta = mgr.restore_latest()
    assert isinstance(restored, batched.StepState)
    assert meta["step"] == 1
    _assert_trees_equal(state, restored)
