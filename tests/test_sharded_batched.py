"""Mesh-sharded batched engine ≡ local batched engine, bit for bit —
and the communication ledger ≡ the collective payloads actually moved.

Two layers:

* In-process: a 1-device ``players`` mesh (the collectives execute over
  an axis of size 1, so the program structure and wire accounting are
  the real ones, only the transport is trivial).  Full-field parity
  against ``core/batched.py`` plus ``validate_ledger`` on every lane.
* Subprocess: a REAL 2-device CPU mesh
  (``XLA_FLAGS=--xla_force_host_platform_device_count=2`` must be set
  before jax initialises, hence the subprocess — same pattern as
  tests/test_sharded_parity.py).  Covers k=4 over p=2 (two players per
  device), the §2.2 no-center model, and the feature/sampled-coreset
  track (AxisStumps), asserting bitwise-equal hypotheses, masks,
  histories and per-field ledger bits, and the ledger-vs-payload
  identities.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import batched, scenarios, sharded_batched, tasks, weak
from repro.core.types import BoostConfig

N = 1 << 12


def _assert_engine_parity(ref, got, B):
    np.testing.assert_array_equal(ref.hypotheses, got.hypotheses)
    np.testing.assert_array_equal(ref.rounds, got.rounds)
    np.testing.assert_array_equal(ref.ok, got.ok)
    np.testing.assert_array_equal(ref.attempts, got.attempts)
    np.testing.assert_array_equal(ref.alive, got.alive)
    np.testing.assert_array_equal(ref.disputed, got.disputed)
    np.testing.assert_array_equal(ref.hist_stuck, got.hist_stuck)
    np.testing.assert_array_equal(ref.hist_rounds, got.hist_rounds)
    np.testing.assert_array_equal(ref.hist_alive, got.hist_alive)
    np.testing.assert_array_equal(ref.hist_p, got.hist_p)
    for b in range(B):
        for f in ("bits_coresets", "bits_weight_sums", "bits_hypotheses",
                  "bits_control", "bits_dispute", "rounds", "attempts"):
            assert getattr(ref.ledger(b), f) == getattr(got.ledger(b), f), f


def test_sharded_engine_parity_single_device_mesh():
    """players-mesh program ≡ batched engine on this host's devices."""
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=4, coreset_size=24, domain_size=N, opt_budget=32)
    B, m = 2, 512
    x, y, _ = tasks.make_batch(cls, B, m, 4, 3, seed0=11)
    keys = jax.random.split(jax.random.key(5), B)
    ref = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    got = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, cfg, cls)
    _assert_engine_parity(ref, got, B)
    # classifiers agree pointwise too
    for b in range(B):
        flat = jax.numpy.asarray(x[b].reshape(-1))
        np.testing.assert_array_equal(
            np.asarray(ref.classifier(b)(flat)),
            np.asarray(got.classifier(b)(flat)))


def test_sharded_wire_equals_ledger_single_device_mesh():
    """Theorem 4.1 accounting == payloads measured at the collectives."""
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=4, coreset_size=24, domain_size=N, opt_budget=32)
    B, m = 2, 512
    x, y, _ = tasks.make_batch(cls, B, m, 4, 3, seed0=11)
    keys = jax.random.split(jax.random.key(5), B)
    got = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, cfg, cls)
    for b in range(B):
        report = got.validate_ledger(b)       # raises on any mismatch
        assert report["coreset_examples_gathered"] > 0
        assert report["collective_bytes"] > 0
        summary = got.wire_summary(b)
        assert summary["mesh_devices"] >= 1
        # a stuck attempt happened (noise > 0) ⇒ quarantine messages flowed
        assert summary["quarantine_point_msgs"] > 0


def test_sharded_engine_scenario_parity():
    """Scenario-corrupted batches run identically on both engines (the
    adversary lives in the data, not the engine)."""
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=4, coreset_size=24, domain_size=N, opt_budget=32)
    spec = scenarios.ScenarioSpec(name="targeted_heavy", noise=8)
    x, y, ts = scenarios.make_scenario_batch(cls, 2, 512, 4, spec,
                                             seed0=7)
    keys = jax.random.split(jax.random.key(1), 2)
    ref = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    got = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, cfg, cls)
    _assert_engine_parity(ref, got, 2)
    for b in range(2):
        got.validate_ledger(b)
        rep = scenarios.scenario_report(ts[b], got, b)
        assert rep["guarantee_ok"], rep


def test_players_mesh_picks_a_divisor_of_k():
    """make_players_mesh never builds a mesh the engine would reject:
    its size always divides k, for any k and device count."""
    ndev = len(jax.devices())
    for k in (1, 2, 3, 4, 6, 16):
        mesh = sharded_batched.make_players_mesh(k)
        p = mesh.shape[sharded_batched.AXIS]
        assert k % p == 0 and 1 <= p <= ndev, (k, p)


_SCRIPT = r"""
import numpy as np
import jax
import jax.numpy as jnp

assert jax.device_count() == 2, jax.devices()

from repro.core import batched, sharded_batched, tasks, weak
from repro.core import ledger as L
from repro.core.types import BoostConfig

N = 1 << 12
cls = weak.Thresholds(n=N)
cfg = BoostConfig(k=4, coreset_size=100, domain_size=N, opt_budget=16)
B, m = 3, 256
x, y, _ = tasks.make_batch(cls, B, m, 4, 3, seed0=11)
keys = jax.random.split(jax.random.key(5), B)
ref = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)

mesh = sharded_batched.make_players_mesh(4)
assert mesh.shape["players"] == 2, mesh          # 2 players per device

for no_center in (False, True):
    got = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, cfg, cls, mesh=mesh, no_center=no_center)
    np.testing.assert_array_equal(ref.hypotheses, got.hypotheses)
    np.testing.assert_array_equal(ref.attempts, got.attempts)
    np.testing.assert_array_equal(ref.alive, got.alive)
    np.testing.assert_array_equal(ref.disputed, got.disputed)
    np.testing.assert_array_equal(ref.hist_stuck, got.hist_stuck)
    np.testing.assert_array_equal(ref.hist_rounds, got.hist_rounds)
    np.testing.assert_array_equal(ref.hist_alive, got.hist_alive)
    np.testing.assert_array_equal(ref.hist_p, got.hist_p)
    for b in range(B):
        for f in ("bits_coresets", "bits_weight_sums",
                  "bits_hypotheses", "bits_control", "bits_dispute",
                  "rounds", "attempts"):
            assert getattr(ref.ledger(b), f) == \
                getattr(got.ledger(b), f), (no_center, b, f)
        got.validate_ledger(b)
        # the ledger's per-round coreset/weight-sum bits equal the
        # payload the all_gather actually moved, restated explicitly:
        n_att = int(got.attempts[b])
        assert got.ledger(b).bits_coresets == \
            int(got.hist_wire_core[b, :n_att].sum()) * L.example_bits(N)

# feature track: randomized (PRNG) coresets over the real mesh
cls2 = weak.AxisStumps(num_features=4)
cfg2 = BoostConfig(k=4, coreset_size=64, domain_size=N, opt_budget=8,
                   deterministic_coreset=False)
x2, y2, _ = tasks.make_batch(cls2, 2, 128, 4, 1, seed0=3)
keys2 = jax.random.split(jax.random.key(9), 2)
ref2 = batched.run_accurately_classify_batched(x2, y2, keys2, cfg2, cls2)
got2 = sharded_batched.run_accurately_classify_sharded(
    x2, y2, keys2, cfg2, cls2, mesh=mesh)
np.testing.assert_array_equal(ref2.hypotheses, got2.hypotheses)
np.testing.assert_array_equal(ref2.attempts, got2.attempts)
np.testing.assert_array_equal(ref2.disputed, got2.disputed)
for b in range(2):
    got2.validate_ledger(b)
print("SHARDED_BATCHED_2DEV_OK")
"""


@pytest.mark.xdist_group(name="device_mesh_subprocess")
def test_sharded_batched_two_device_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARDED_BATCHED_2DEV_OK" in out.stdout
