"""Direct unit tests for core/semi_agnostic.py (the reduction baseline).

Previously only smoke-covered via test_substrate.py; these pin the two
contracts the baseline's analysis leans on:

* ``patch`` makes the final classifier EXACT on every broadcast point —
  f answers the full-count majority there, so no broadcast point can be
  classified worse than pointwise-optimally;
* the patch-broadcast ledger entry is exactly
  |misclassified| · (⌈log2 n⌉ + 1) bits per player-broadcast, i.e.
  ``patched · example_bits(n) · k`` in total — counted, not bounded.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ledger as L
from repro.core import semi_agnostic, tasks, weak
from repro.core.types import BoostConfig

N = 1 << 12
CLS = weak.Thresholds(n=N)
CFG = BoostConfig(k=4, coreset_size=400, domain_size=N)


def _run(noise, seed):
    task = tasks.make_task(CLS, m=1024, k=4, noise=noise, seed=seed)
    res = semi_agnostic.run_semi_agnostic(
        jnp.asarray(task.x), jnp.asarray(task.y), jax.random.key(0),
        CFG, CLS)
    return task, res


def test_patch_exact_on_every_broadcast_point():
    task, res = _run(noise=6, seed=2)
    f = res.classifier
    pts = np.asarray(f.dispute_x)
    assert pts.shape[0] > 0, "no point was broadcast — weak scenario"
    xf, yf = task.flat_x, task.flat_y
    for p in pts.tolist():
        copies = yf[xf == p]
        maj = 1 if (copies > 0).sum() >= (copies < 0).sum() else -1
        got = int(np.asarray(f(jnp.asarray([p], xf.dtype)))[0])
        assert got == maj, (p, got, maj)
    # exactness ⇒ errors at broadcast points are the pointwise minimum,
    # so patching can only help: E_S(f) ≤ E_S(g)
    assert res.final_errors <= res.boost_errors


def test_patch_bits_counted_exactly():
    task, res = _run(noise=6, seed=2)
    g = res.classifier.g                      # unpatched ensemble
    gx = np.asarray(g(jnp.asarray(task.x)))
    misclassified = int((gx != task.y).sum())
    assert res.patched == misclassified
    # |misclassified| · (⌈log2 n⌉+1) bits per player-broadcast
    per_example = L.example_bits(N)
    assert per_example == int(np.ceil(np.log2(N))) + 1
    assert res.ledger.bits_dispute == res.patched * per_example * CFG.k
    # and the boosting rounds are charged like any BoostAttempt
    assert res.ledger.bits_coresets == \
        CFG.num_rounds(1024) * CFG.k * CFG.coreset_size * per_example


def test_clean_sample_needs_no_patch():
    task, res = _run(noise=0, seed=5)
    assert res.boost_errors == 0
    assert res.patched == 0
    assert res.final_errors == 0
    assert res.ledger.bits_dispute == 0
    assert np.asarray(res.classifier.dispute_x).shape[0] == 0
