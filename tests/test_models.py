"""Model-internals correctness: chunkwise forms vs naive references,
MoE dispatch equivalence, SWA masking, attention cache ring."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import attention, layers as L, moe, ssm, xlstm


def _cfg(**kw):
    return dataclasses.replace(
        base.reduced(base.get_config("deepseek-7b")), **kw)


# ---------------------------------------------------------------------------
# Mamba: chunkwise scan == naive sequential recurrence
# ---------------------------------------------------------------------------

def test_mamba_chunkwise_matches_sequential():
    cfg = dataclasses.replace(
        base.reduced(base.get_config("jamba-v0.1-52b")), num_layers=8)
    p = ssm.init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, ssm.CHUNK + 37,
                                              cfg.d_model), jnp.float32)
    y_chunk, state = ssm.forward(p, cfg, x)
    # naive: token-by-token decode over the same inputs
    st = ssm.init_state(cfg, 2)
    ys = []
    for t in range(x.shape[1]):
        yt, st = ssm.decode_step(p, cfg, x[:, t:t + 1], st)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=2e-2, atol=2e-2)
    # carried state matches too
    np.testing.assert_allclose(np.asarray(state["h"]),
                               np.asarray(st["h"]), rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# mLSTM: chunkwise parallel == stepwise recurrent decode
# ---------------------------------------------------------------------------

def test_mlstm_chunkwise_matches_recurrent():
    cfg = base.reduced(base.get_config("xlstm-1.3b"))
    p = xlstm.mlstm_init(jax.random.key(0), cfg)
    S = xlstm.MLSTM_CHUNK // 2 + 13        # forces padding path too
    x = jax.random.normal(jax.random.key(1), (2, S, cfg.d_model),
                          jnp.float32) * 0.5
    y_par, state = xlstm.mlstm_forward(p, cfg, x)
    st = xlstm.mlstm_init_state(cfg, 2)
    ys = []
    for t in range(S):
        yt, st = xlstm.mlstm_decode(p, cfg, x[:, t:t + 1], st)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(state["m"]),
                               np.asarray(st["m"]), rtol=1e-3, atol=1e-3)


def test_mlstm_multichunk_state_carry():
    cfg = base.reduced(base.get_config("xlstm-1.3b"))
    p = xlstm.mlstm_init(jax.random.key(0), cfg)
    S = xlstm.MLSTM_CHUNK * 2 + 5          # 3 chunks
    x = jax.random.normal(jax.random.key(2), (1, S, cfg.d_model),
                          jnp.float32) * 0.5
    y_par, _ = xlstm.mlstm_forward(p, cfg, x)
    st = xlstm.mlstm_init_state(cfg, 1)
    ys = []
    for t in range(S):
        yt, st = xlstm.mlstm_decode(p, cfg, x[:, t:t + 1], st)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=4e-2, atol=4e-2)


# ---------------------------------------------------------------------------
# sLSTM scan == stepwise
# ---------------------------------------------------------------------------

def test_slstm_scan_matches_decode():
    cfg = base.reduced(base.get_config("xlstm-1.3b"))
    p = xlstm.slstm_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 19, cfg.d_model),
                          jnp.float32) * 0.5
    y_scan, state = xlstm.slstm_forward(p, cfg, x)
    st = xlstm.slstm_init_state(cfg, 2)
    ys = []
    for t in range(x.shape[1]):
        yt, st = xlstm.slstm_decode(p, cfg, x[:, t:t + 1], st)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# MoE: einsum dispatch == sort dispatch (generous capacity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["phi3.5-moe-42b-a6.6b",
                                  "granite-moe-3b-a800m"])
def test_moe_dispatch_equivalence(arch):
    cfg = dataclasses.replace(base.reduced(base.get_config(arch)),
                              capacity_factor=8.0)
    p = moe.init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    y_e, aux_e = moe.apply(p, cfg, x, exact=True)
    cfg_s = dataclasses.replace(cfg, moe_dispatch="sort")
    y_s, aux_s = moe.apply(p, cfg_s, cfg_s and x, exact=True)
    np.testing.assert_allclose(np.asarray(y_e, np.float32),
                               np.asarray(y_s, np.float32),
                               rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(float(aux_e), float(aux_s), rtol=1e-3)


def test_moe_aux_loss_decreases_with_balance():
    """Uniform router probs ⇒ aux ≈ weight·1 (+ z term); peaked ⇒ larger."""
    cfg = base.reduced(base.get_config("phi3.5-moe-42b-a6.6b"))
    E = cfg.num_experts
    x = jax.random.normal(jax.random.key(0), (64, cfg.d_model))
    p = moe.init(jax.random.key(1), cfg)
    # balanced: tiny router weights -> near-uniform
    p_bal = dict(p, router={"w": p["router"]["w"] * 0.0})
    _, _, aux_bal = moe._route(p_bal, cfg, x)
    p_peak = dict(p, router={"w": p["router"]["w"] * 0 +
                             jnp.eye(cfg.d_model, E) * 50})
    _, _, aux_peak = moe._route(p_peak, cfg, x)
    assert float(aux_peak) > float(aux_bal)


# ---------------------------------------------------------------------------
# Attention: sliding-window mask + ring cache decode
# ---------------------------------------------------------------------------

def test_swa_training_mask_matches_window_definition():
    S, W = 16, 5
    m = attention.causal_mask(S, S, window=W)
    for i in range(S):
        for j in range(S):
            expect = (j <= i) and (j > i - W)
            assert bool(m[i, j]) == expect


def test_ring_cache_decode_matches_full_swa():
    """Decode with a ring cache of size=window equals full-cache SWA."""
    cfg = _cfg(sliding_window=0)
    p = attention.init(jax.random.key(0), cfg)
    B, S, W = 1, 24, 8
    x = jax.random.normal(jax.random.key(1), (B, S + 1, cfg.d_model),
                          jnp.float32) * 0.3
    pos = jnp.arange(S + 1)[None]
    # reference: full-sequence SWA forward, last position output
    ref, _, _ = attention.full_attention(p, cfg, x, pos, causal=True,
                                         window=W)
    # decode path: feed x[:-1] into a ring cache of capacity W, then
    # decode position S
    cache = attention.init_cache(cfg, B, W, dtype=jnp.float32)
    for t in range(S):
        _, cache = attention.decode_attention(p, cfg, x[:, t:t + 1],
                                              cache, window=W)
    got, _ = attention.decode_attention(p, cfg, x[:, S:S + 1], cache,
                                        window=W)
    np.testing.assert_allclose(np.asarray(got[:, 0], np.float32),
                               np.asarray(ref[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_qk_norm_applied():
    cfg = dataclasses.replace(_cfg(), qk_norm=True)
    p = attention.init(jax.random.key(0), cfg)
    assert "q_norm" in p and "k_norm" in p
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    out, _, _ = attention.full_attention(p, cfg, x, jnp.arange(8)[None],
                                         causal=True)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_rope_rotation_property():
    """RoPE preserves norms and relative-position inner products."""
    x = jax.random.normal(jax.random.key(0), (1, 6, 2, 64), jnp.float32)
    pos = jnp.arange(6)[None]
    r = L.rope(x, pos, theta=10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(r), axis=-1), rtol=1e-4)
    # relative property: <R(p)q, R(p+d)k> independent of p
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 64))
    dots = []
    for p0 in (0, 3, 11):
        rq = L.rope(q, jnp.asarray([[p0]]), 1e4)
        rk = L.rope(k, jnp.asarray([[p0 + 4]]), 1e4)
        dots.append(float(jnp.sum(rq * rk)))
    np.testing.assert_allclose(dots[0], dots[1], rtol=1e-4)
    np.testing.assert_allclose(dots[0], dots[2], rtol=1e-4)


# ---------------------------------------------------------------------------
# Causality property: future tokens never affect past logits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["deepseek-7b", "jamba-v0.1-52b",
                                  "xlstm-1.3b", "phi3.5-moe-42b-a6.6b"])
def test_causality_property(arch):
    """logits[:, :t] are invariant to any change in tokens[:, t:] —
    holds for attention, Mamba, m/sLSTM and MoE mixers alike (MoE needs
    drop-free capacity, otherwise cross-token capacity contention leaks
    batch statistics, which is expected and documented)."""
    from repro.models import transformer
    cfg = dataclasses.replace(base.reduced(base.get_config(arch)),
                              capacity_factor=8.0)
    params = transformer.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 24), 0,
                              cfg.vocab_size)
    t = 11
    toks2 = toks.at[:, t:].set((toks[:, t:] + 7) % cfg.vocab_size)
    la, _ = transformer.forward(params, cfg, toks)
    lb, _ = transformer.forward(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(la[:, :t], np.float32),
                               np.asarray(lb[:, :t], np.float32),
                               rtol=3e-3, atol=3e-3)
