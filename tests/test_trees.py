"""Histogram-tree weak learner: ERM semantics, the XOR separation
acceptance bar, three-way engine bit-parity, ledger accounting, and
scheduler integration.

The acceptance criterion this file pins (ISSUE 5): on the planted XOR
scenario the depth-2 tree class reaches ``E_S(f) ≤ OPT + 0.05·m``
while AxisStumps is pinned ≥ 0.25·m error — both sides asserted.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched, classify, ledger, scenarios, sharded_batched, \
    tasks, weak
from repro.core.types import BoostConfig
from repro.weak_tree import HistogramTrees

F, BINS, M, K = 4, 32, 256, 4


def _tree(depth=2):
    return HistogramTrees(num_features=F, depth=depth, bins=BINS)


def _cfg(cls, opt_budget=16, coreset=64):
    return BoostConfig(k=K, coreset_size=coreset,
                       domain_size=1 << min(cls.value_bits, 30),
                       opt_budget=opt_budget,
                       deterministic_coreset=False)


def _xor_task(seed=0, noise=4, cls=None):
    cls = cls or _tree()
    spec = scenarios.ScenarioSpec(name="xor", noise=noise)
    return scenarios.make_feature_task(cls, m=M, k=K, spec=spec,
                                       seed=seed)


# ---------------------------------------------------------------------------
# ERM / predict semantics
# ---------------------------------------------------------------------------

def test_erm_loss_equals_predicted_error():
    """The returned loss IS the returned tree's weighted error (the
    stuck check depends on it) — exact with dyadic weights."""
    cls = _tree()
    rng = np.random.default_rng(3)
    m = 256
    xs = cls.sample_points(rng, m)
    tgt = cls.sample_target(rng, xs)
    ys = np.asarray(cls.predict(jnp.asarray(tgt),
                                jnp.asarray(xs))).astype(np.int8)
    flip = rng.choice(m, 6, replace=False)
    ys[flip] = -ys[flip]
    w = np.full(m, 1.0 / 256, np.float32)          # dyadic: sums exact
    p, loss = jax.jit(cls.erm)(jnp.asarray(xs), jnp.asarray(ys),
                               jnp.asarray(w))
    pred = cls.predict(p, jnp.asarray(xs))
    err = float(jnp.sum(jnp.where(pred != jnp.asarray(ys),
                                  jnp.asarray(w), 0.0)))
    assert float(loss) == err
    assert float(p[0]) == 5.0                      # type code
    assert p.shape == (cls.param_dim,)


def test_erm_recovers_planted_tree_and_batch_matches():
    cls = _tree()
    task = _xor_task(seed=1, noise=0)
    x = jnp.asarray(task.flat_x)
    y = jnp.asarray(task.flat_y)
    w = jnp.ones((M,), jnp.float32) / M
    p, loss = cls.erm(x, y, w)
    assert float(loss) == 0.0                      # exact XOR fit
    # erm_batch is vmap(erm): identical rows bit-for-bit
    pb, lb = weak.erm_batch(cls, jnp.stack([x, x]), jnp.stack([y, y]),
                            jnp.stack([w, w]))
    np.testing.assert_array_equal(np.asarray(pb[0]), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(pb[1]), np.asarray(p))


def test_predict_param_batch_and_ensemble():
    cls = _tree()
    rng = np.random.default_rng(0)
    xs = jnp.asarray(cls.sample_points(rng, 64))
    ps = jnp.stack([jnp.asarray(cls.sample_target(rng, np.asarray(xs)))
                    for _ in range(3)])
    out = cls.predict(ps, xs)                      # [3, 64]
    assert out.shape == (3, 64)
    for t in range(3):
        np.testing.assert_array_equal(np.asarray(out[t]),
                                      np.asarray(cls.predict(ps[t], xs)))
    ens = weak.ensemble_predict(cls, ps, jnp.int32(3), xs)
    votes = np.sum(np.asarray(out, np.int32), axis=0)
    np.testing.assert_array_equal(np.asarray(ens),
                                  np.where(votes >= 0, 1, -1))


def test_zero_weight_rows_are_inert():
    """Padding contract of erm_batch: w = 0 rows change nothing."""
    cls = _tree()
    rng = np.random.default_rng(7)
    xs = cls.sample_points(rng, 128)
    tgt = cls.sample_target(rng, xs)
    ys = np.asarray(cls.predict(jnp.asarray(tgt), jnp.asarray(xs)))
    w = rng.integers(1, 64, 128).astype(np.float32) / 64
    w2 = np.concatenate([w, np.zeros(32, np.float32)])
    xs2 = np.concatenate([xs, cls.sample_points(rng, 32)])
    ys2 = np.concatenate([ys, -np.ones(32, np.int8)])
    p1, l1 = cls.erm(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(w))
    p2, l2 = cls.erm(jnp.asarray(xs2), jnp.asarray(ys2),
                     jnp.asarray(w2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    assert float(l1) == float(l2)


def test_split_ties_break_to_lowest_feature_bin():
    """Tie-breaking is pinned, not backend luck: on a gain surface with
    EXACT ties (dyadic weights, duplicated feature columns — every
    partial sum exactly representable) the chosen split must be the
    lowest flat (feature, bin) index, identically on ref histograms and
    the interpret-mode Pallas kernel."""
    from repro.kernels.histogram import ops as H

    Q = 8
    rng = np.random.default_rng(2)
    c = 64
    col = ((rng.integers(0, Q, c) + 0.5) / Q).astype(np.float32)
    x = np.stack([col, col, rng.random(c).astype(np.float32)], axis=1)
    w = (rng.integers(1, 32, (1, c)) / 32.0).astype(np.float32)
    wy = w * rng.choice([-1.0, 1.0], (1, c)).astype(np.float32)
    hw_ref, hwy_ref = H.node_histograms_ref(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(wy), Q)
    hw_k, hwy_k = H.node_histograms(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(wy), Q,
        interpret=jax.default_backend() != "tpu")
    np.testing.assert_array_equal(np.asarray(hw_ref), np.asarray(hw_k))
    np.testing.assert_array_equal(np.asarray(hwy_ref),
                                  np.asarray(hwy_k))
    # columns 0 and 1 are identical ⇒ their err surfaces tie exactly;
    # the winner must be feature 0 on both histogram paths
    err = np.asarray(H.split_err_surface(hw_ref, hwy_ref))
    np.testing.assert_array_equal(err[0, 0], err[0, 1])
    for hw, hwy in ((hw_ref, hwy_ref), (hw_k, hwy_k)):
        f, q, _ = H.best_splits_ref(hw, hwy)
        assert int(f[0]) == 0
        # and within the feature, the lowest of the tied bins
        tied = np.flatnonzero(err[0, 0] == err[0, 0, int(q[0])])
        assert int(q[0]) == tied[0]
    # the fully-degenerate surface (wy ≡ 0: EVERY candidate ties) pins
    # the global minimum to (feature 0, bin 0)
    f0, q0, _ = H.best_splits_ref(hw_ref, jnp.zeros_like(hwy_ref))
    assert int(f0[0]) == 0 and int(q0[0]) == 0
    # per-feature proposals (voting mode) use the same pin
    qf, _ = H.best_splits_per_feature(hw_ref, jnp.zeros_like(hwy_ref))
    np.testing.assert_array_equal(np.asarray(qf)[0], 0)


# ---------------------------------------------------------------------------
# The acceptance bar: XOR separation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_xor_trees_reach_opt_stumps_pinned(seed):
    """Depth-2 trees: E_S(f) ≤ OPT + 0.05·m on planted XOR; AxisStumps
    ≥ 0.25·m on the same sample.  Both sides asserted."""
    cls = _tree()
    task = _xor_task(seed=seed, noise=4)
    # OPT ≤ planted (in-class witness misclassifies exactly the flips)
    planted = scenarios.planted_errors(task)
    assert planted <= 4
    f, res = classify.learn(jnp.asarray(task.x), jnp.asarray(task.y),
                            jax.random.key(seed), _cfg(cls), cls)
    errs = int(weak.empirical_errors(f(jnp.asarray(task.flat_x)),
                                     jnp.asarray(task.flat_y)))
    assert errs <= planted + 0.05 * M
    stump_floor = scenarios.class_floor(
        task, weak.AxisStumps(num_features=F))
    assert stump_floor >= 0.25 * M


def test_bands_trees_solve_where_stumps_plateau():
    cls = _tree(depth=3)
    spec = scenarios.ScenarioSpec(name="bands", noise=4, n_bands=4)
    task = scenarios.make_feature_task(cls, m=M, k=K, spec=spec, seed=2)
    planted = scenarios.planted_errors(task)
    f, res = classify.learn(jnp.asarray(task.x), jnp.asarray(task.y),
                            jax.random.key(2), _cfg(cls), cls)
    errs = int(weak.empirical_errors(f(jnp.asarray(task.flat_x)),
                                     jnp.asarray(task.flat_y)))
    assert errs <= planted + 0.05 * M
    # alternating bands: the best stump still eats a full band
    assert scenarios.class_floor(
        task, weak.AxisStumps(num_features=F)) >= 0.1 * M


def test_checkerboard_floor_separation():
    """4×4 checkerboard: even the greedy depth-4 floor beats the best
    stump decisively (the protocol-level run is exercised on xor/bands;
    checkerboard pins the representational gap)."""
    cls = _tree(depth=4)
    spec = scenarios.ScenarioSpec(name="checkerboard", noise=0, cells=4)
    task = scenarios.make_feature_task(cls, m=M, k=K, spec=spec, seed=0)
    tree_floor = scenarios.class_floor(task)
    stump_floor = scenarios.class_floor(
        task, weak.AxisStumps(num_features=F))
    assert stump_floor >= 0.25 * M
    assert tree_floor < stump_floor


def test_feature_scenario_noise_composition():
    """Noise adversaries compose over planted concepts: the flip mask
    is exact and planted_errors counts exactly the flips."""
    cls = _tree()
    for kind in ("uniform", "boundary", "drift"):
        spec = scenarios.ScenarioSpec(name="xor", noise=6,
                                      noise_kind=kind)
        task = scenarios.make_feature_task(cls, m=M, k=K, spec=spec,
                                           seed=3)
        assert task.flipped.sum() == 6
        assert task.scenario == f"xor+{kind}"
        assert scenarios.planted_errors(task) == 6


# ---------------------------------------------------------------------------
# Engine parity + ledger
# ---------------------------------------------------------------------------

def _parity_inputs(seed0=5, B=2, noise=3):
    cls = _tree()
    spec = scenarios.ScenarioSpec(name="xor", noise=noise)
    ts = [scenarios.make_feature_task(cls, m=M, k=K, spec=spec,
                                      seed=seed0 + b) for b in range(B)]
    x = np.stack([t.x for t in ts])
    y = np.stack([t.y for t in ts])
    keys = jax.random.split(jax.random.key(seed0), B)
    return cls, ts, x, y, keys


def test_tree_host_batched_sharded_bit_parity():
    """The tentpole parity bar: all three engines produce bit-identical
    protocol outputs for the tree class, and the sharded wire counters
    validate against the Theorem 4.1 ledger."""
    cls, ts, x, y, keys = _parity_inputs()
    cfg = _cfg(cls)
    bres = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    sres = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, cfg, cls)
    # batched ≡ sharded: every field, bit for bit
    np.testing.assert_array_equal(bres.hypotheses, sres.hypotheses)
    np.testing.assert_array_equal(bres.attempts, sres.attempts)
    np.testing.assert_array_equal(bres.disputed, sres.disputed)
    np.testing.assert_array_equal(bres.min_loss, sres.min_loss)
    for b in range(x.shape[0]):
        # host ≡ batched: winning ensemble prefix, disputes, ledger
        href = classify.run_accurately_classify(
            jnp.asarray(x[b]), jnp.asarray(y[b]), keys[b], cfg, cls)
        got = bres.per_task(b)
        assert href.attempts == got.attempts
        assert href.rounds == got.rounds
        np.testing.assert_array_equal(
            np.asarray(href.hypotheses)[:href.rounds],
            np.asarray(got.hypotheses)[:got.rounds])
        # dispute tables: same point set (host lists per-attempt groups,
        # the batched table is globally sorted) and same classifier
        def _rowsort(a):
            a = np.asarray(a)
            return a[np.lexsort(a.T[::-1])]
        np.testing.assert_array_equal(_rowsort(href.dispute_x),
                                      _rowsort(got.dispute_x))
        fh = classify.make_classifier(cls, href)
        fb = classify.make_classifier(cls, got)
        xs = jnp.asarray(ts[b].flat_x)
        np.testing.assert_array_equal(np.asarray(fh(xs)),
                                      np.asarray(fb(xs)))
        assert href.ledger == got.ledger
        sres.validate_ledger(b)                    # ledger ≡ payload


def test_tree_ledger_charges_tree_hypothesis_bits():
    """bits_hypotheses = Σ_attempts rounds·k·hypothesis_bits with the
    tree encoding nodes·(⌈log2 F⌉+bin_bits)+leaves."""
    cls, ts, x, y, keys = _parity_inputs(B=1)
    assert cls.hypothesis_bits() == 3 * (2 + 5) + 4   # d=2, F=4, Q=32
    cfg = _cfg(cls)
    res = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    led = res.ledger(0)
    expect = sum(int(res.hist_rounds[0, a]) * K * cls.hypothesis_bits()
                 for a in range(int(res.attempts[0])))
    assert led.bits_hypotheses == expect
    # and the Theorem 4.1 form covers the measured total
    bound = ledger.theorem_41_bound(cfg, cls, M, opt=4, constant=1.5)
    assert led.total_bits <= bound


def test_tree_round_granular_stepping_bit_identical():
    """run_rounds in 3-round slices == monolithic, for the wide-param
    tree state (checkpointable pytree contract)."""
    cls, ts, x, y, keys = _parity_inputs(B=1)
    cfg = _cfg(cls)
    mono = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    state = batched.init_state(x, y, keys, cfg, cls=cls)
    a_max = cfg.opt_budget + 1
    while bool(np.any(~np.asarray(state.done)
                      & (np.asarray(state.attempt) < a_max))):
        state = batched.run_rounds(state, x, y, cfg, cls, n=3)
    sliced = batched.finalize(state, x, y,
                              np.ones(x.shape[:3], bool), cfg, cls)
    np.testing.assert_array_equal(mono.hypotheses, sliced.hypotheses)
    np.testing.assert_array_equal(mono.disputed, sliced.disputed)
    np.testing.assert_array_equal(mono.attempts, sliced.attempts)


# ---------------------------------------------------------------------------
# Scheduler integration (CompatKey coverage for tree requests)
# ---------------------------------------------------------------------------

def test_scheduler_buckets_trees_alongside_stumps():
    from repro.launch import scheduler as S
    reqs = S.make_request_stream(
        8, np.linspace(0, 0.05, 8),
        shapes=[{"clsname": "tree", "scenario": "xor", "noise": 2,
                 "m": 128, "num_features": F, "tree_depth": 2,
                 "tree_bins": BINS, "coreset_size": 48},
                {"clsname": "stumps", "noise": 1, "m": 128,
                 "num_features": F, "coreset_size": 48}],
        k=K, opt_budget=16)
    sched = S.BoostScheduler(policy="pack")
    sched.warm(reqs)
    warm = sched.cache.stats.compiles
    done = sched.run_stream(reqs)
    assert len(done) == 8 and all(c.ok for c in done)
    # trees and stumps land in distinct compat groups (CompatKey
    # hashes the class), and steady state never recompiles
    assert sched.cache.stats.compiles == warm
    kinds = {type(c.bucket.compat.cls).__name__ for c in done}
    assert kinds == {"HistogramTrees", "AxisStumps"}
    # depth/bins are part of the key: a different tree shape is a
    # different bucket (fresh compile), same shape hits the cache
    r = done[0].request
    deeper = dataclasses.replace(r, rid=99, tree_depth=3)
    assert S.CompatKey.of(deeper) != S.CompatKey.of(r)
    assert S.CompatKey.of(dataclasses.replace(r, rid=98)) \
        == S.CompatKey.of(r)
    # tree completions reproduce their one-shot baseline bit for bit
    c = next(c for c in done if c.request.clsname == "tree")
    one = sched.one_shot(c.request)
    np.testing.assert_array_equal(c.result.hypotheses[c.lane],
                                  one.hypotheses[0])
    assert c.per_task().ledger.total_bits \
        == one.per_task(0).ledger.total_bits
