"""repro-lint: AST rules + jaxpr verification (ISSUE 9 tentpole).

Three layers of coverage:

* fixture pairs — every registered rule has a pass fixture (0 findings)
  and a fail fixture (≥1 finding of that rule, non-zero CLI exit);
* the real tree — ``src/`` lints clean with an EMPTY suppressions
  baseline, and the jaxpr audit passes on both engines in every mode;
* mutation tests — un-pinning the histogram kernel's ``_pinned_argmin``
  and deleting wire-counter accumulations in the sharded engine are
  demonstrated to FAIL the lint / audit (the invariants bite, they are
  not decorative).
"""

import os
import subprocess
import sys

import pytest

from tools.repro_lint import engine as E
from tools.repro_lint import rules as R

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tools", "repro_lint", "fixtures")
BASELINE = os.path.join(REPO, "tools", "repro_lint",
                        "baseline_suppressions.txt")


def _lint_file(path):
    with open(path, encoding="utf-8") as f:
        src = f.read()
    return E.lint_source(src, os.path.relpath(path, REPO), R.ALL_RULES)


def _lint_dir(path):
    kept, _ = E.lint_paths([path], R.ALL_RULES, repo_root=REPO)
    return kept


# ---------------------------------------------------------------------------
# the real tree
# ---------------------------------------------------------------------------

def test_src_tree_lints_clean():
    kept, suppressed = E.lint_paths(
        [os.path.join(REPO, "src")], R.ALL_RULES, repo_root=REPO,
        baseline=E.load_baseline(BASELINE))
    assert kept == [], "\n".join(str(v) for v in kept)
    assert suppressed == [], (
        "baseline_suppressions.txt must stay EMPTY (repo policy: fixes "
        "land with the rules); suppressed: "
        + "\n".join(str(v) for v in suppressed))


def test_baseline_suppressions_file_is_empty():
    assert E.load_baseline(BASELINE) == set()


# ---------------------------------------------------------------------------
# fixture pairs, one per rule
# ---------------------------------------------------------------------------

SOURCE_RULES = ("RL001", "RL002", "RL003", "RL005", "RL006")


@pytest.mark.parametrize("rid", SOURCE_RULES)
def test_pass_fixture_is_clean(rid):
    found = _lint_file(os.path.join(FIXTURES, f"{rid}_pass.py"))
    assert found == [], "\n".join(str(v) for v in found)


@pytest.mark.parametrize("rid", SOURCE_RULES)
def test_fail_fixture_fires_its_rule(rid):
    found = _lint_file(os.path.join(FIXTURES, f"{rid}_fail.py"))
    assert found, f"{rid}_fail.py produced no findings"
    assert {v.rule for v in found} == {rid}, (
        f"{rid}_fail.py must fail {rid} and only {rid}: "
        + "\n".join(str(v) for v in found))


def test_rl004_pass_fixture_is_clean():
    assert _lint_dir(os.path.join(FIXTURES, "RL004_pass")) == []


def test_rl004_fail_fixture_fires():
    found = _lint_dir(os.path.join(FIXTURES, "RL004_fail"))
    assert found and {v.rule for v in found} == {"RL004"}


def test_every_registered_rule_has_fixture_pair():
    for rid in R.RULE_IDS:
        has_files = all(
            os.path.exists(os.path.join(FIXTURES, f"{rid}_{kind}.py"))
            for kind in ("pass", "fail"))
        has_dirs = all(
            os.path.isdir(os.path.join(FIXTURES, f"{rid}_{kind}"))
            for kind in ("pass", "fail"))
        assert has_files or has_dirs, f"{rid} has no fixture pair"


def test_cli_exit_codes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    ok = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint",
         os.path.join(FIXTURES, "RL001_pass.py")],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint",
         os.path.join(FIXTURES, "RL001_fail.py")],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "RL001" in bad.stdout


def test_inline_pragma_suppresses_only_named_rule():
    src = ("# lint-fixture-path: src/repro/core/fixture_pragma.py\n"
           "import jax.numpy as jnp\n"
           "j = jnp.argmin(x)  # repro-lint: allow=RL001 tie-free by "
           "construction\n"
           "k = jnp.argmax(x)  # repro-lint: allow=RL003 wrong rule\n")
    found = E.lint_source(src, "virtual.py", R.ALL_RULES)
    assert [v.rule for v in found] == ["RL001"]
    assert found[0].line == 4


# ---------------------------------------------------------------------------
# jaxpr audit: both engines, every mode
# ---------------------------------------------------------------------------

def test_jaxpr_audit_clean_on_both_engines():
    from tools.repro_lint import jaxpr_audit as A
    failures = A.run_audit()
    assert failures == [], "\n".join(failures)


def test_jaxpr_finalize_smoke():
    from tools.repro_lint import jaxpr_audit as A
    A.finalize_smoke()


def test_collective_census_matches_ledger_declaration():
    """The per-mode expected counts come from ledger.py, not from the
    audit module — a drift in either direction is a failure."""
    from repro.core import ledger
    from tools.repro_lint import jaxpr_audit as A
    tree = A.HistogramTrees(num_features=3, depth=2, bins=8,
                            comm_mode="voting")
    rep = A.audit_case("tree-voting", tree, False, "sharded")
    assert rep.failures == [], "\n".join(rep.failures)
    assert rep.expected == ledger.collective_sites_per_round(tree)
    assert rep.collectives["all_gather"] == 3 + 4 * tree.depth


# ---------------------------------------------------------------------------
# mutation tests: the invariants bite
# ---------------------------------------------------------------------------

def _read(relpath):
    with open(os.path.join(REPO, relpath), encoding="utf-8") as f:
        return f.read()


def test_unpinning_histogram_ref_fails_rl001():
    rel = "src/repro/kernels/histogram/ref.py"
    src = _read(rel)
    assert E.lint_source(src, rel, R.ALL_RULES) == []
    mutated = src.replace("j = _pinned_argmin(flat, F * Q)",
                          "j = jnp.argmin(flat, axis=-1)")
    assert mutated != src, "mutation site moved — update this test"
    found = E.lint_source(mutated, rel, R.ALL_RULES)
    assert any(v.rule == "RL001" for v in found), (
        "reverting to bare jnp.argmin must fail RL001")


def test_monkeypatched_unpin_fails_jaxpr_audit(monkeypatch):
    """Even a runtime unpin (no source change) is caught: the traced
    tree engine then contains the denied `argmin` primitive."""
    import jax.numpy as jnp
    from repro.kernels.histogram import ref
    from tools.repro_lint import jaxpr_audit as A
    monkeypatch.setattr(
        ref, "_pinned_argmin",
        lambda v, size: jnp.argmin(v, axis=-1).astype(jnp.int32))
    # bins=16 (vs the canonical 8): cls is a jit static arg, so this
    # forces a FRESH trace — a config already traced unpatched would be
    # served from the jit cache and hide the mutation
    tree = A.HistogramTrees(num_features=3, depth=2, bins=16,
                            comm_mode="histogram")
    rep = A.audit_case("tree-histogram", tree, False, "sharded")
    assert any("argmin" in f for f in rep.failures), rep.failures


@pytest.mark.parametrize("deleted", [
    "    awire_core = awire_core + out.wire_core\n",
    "    awire_ws = awire_ws + out.wire_ws\n",
])
def test_deleting_wire_accumulation_fails_rl002(deleted):
    rel = "src/repro/core/sharded_batched.py"
    src = _read(rel)
    assert E.lint_source(src, rel, R.ALL_RULES) == []
    mutated = src.replace(deleted, "")
    assert mutated != src, (
        f"accumulation line {deleted!r} moved — update this test")
    found = E.lint_source(mutated, rel, R.ALL_RULES)
    name = deleted.strip().split(" ")[0]
    assert any(v.rule == "RL002" and name in v.message for v in found), (
        f"deleting {name} accumulation must fail RL002: "
        + "\n".join(str(v) for v in found))


def test_removing_collective_site_fails_census(monkeypatch):
    """Dropping a declared collective from the ledger census (the dual
    of adding an unaccounted one to the engine) fails the audit."""
    from repro.core import ledger
    from tools.repro_lint import jaxpr_audit as A
    real = ledger.collective_sites_per_round

    def short_census(cls, *, no_center=False):
        out = dict(real(cls, no_center=no_center))
        out["all_gather"] -= 1     # pretend one site is unaccounted
        return out

    monkeypatch.setattr(ledger, "collective_sites_per_round",
                        short_census)
    cls = A.AxisStumps(num_features=3)
    rep = A.audit_case("stumps", cls, False, "sharded")
    assert any("eqn count" in f for f in rep.failures), rep.failures


# ---------------------------------------------------------------------------
# benchmarks/run.py --list (satellite)
# ---------------------------------------------------------------------------

def test_bench_run_list_exits_zero():
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--list"],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    from benchmarks.run import EXPECTED_GATES, _suite
    lines = [ln for ln in out.stdout.splitlines() if ln.strip()]
    names = {ln.split(":")[0] for ln in lines}
    assert names == set(_suite())
    for suite, gates in EXPECTED_GATES.items():
        row = next(ln for ln in lines if ln.startswith(suite + ":"))
        for g in gates:
            assert g in row
