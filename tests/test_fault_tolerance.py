"""Fault-tolerant protocol execution (ISSUE 4 tentpole).

Three layers, each pinned bitwise:

* **Stepping API.**  ``init_state / run_rounds / finalize`` run in
  slices is bit-identical to the uninterrupted engine run (which is
  itself bit-identical to the host reference loop — tests/test_batched
  keeps that anchor).  A round slice crosses attempt boundaries.
* **Checkpoint/resume.**  The whole protocol state round-trips through
  a msgpack file (ckpt/msgpack_ckpt) mid-run and completes identically.
* **Infrastructure adversaries.**  dropout / flaky / rejoin player
  schedules: the protocol proceeds with k′ < k players, E_S(f) ≤ OPT
  holds over the surviving shards, the sharded engine stays bit-equal
  to the local one under the same schedule, and ``validate_ledger``
  passes with the mask applied — only alive players' payloads charged.
"""

import os

import jax
import numpy as np
import pytest

from repro.core import (batched, ledger, scenarios, sharded_batched,
                        tasks, weak)
from repro.ckpt import msgpack_ckpt
from repro.core.types import BoostConfig

N = 1 << 12
CFG = BoostConfig(k=4, coreset_size=100, domain_size=N, opt_budget=16)
CLS = weak.Thresholds(n=N)


def _batch(B=2, m=512, noise=3, seed0=11):
    x, y, ts = tasks.make_batch(CLS, B, m, 4, noise, seed0=seed0)
    keys = jax.random.split(jax.random.key(5), B)
    return x, y, keys, ts


def _assert_bitwise(ref, got):
    np.testing.assert_array_equal(ref.hypotheses, got.hypotheses)
    np.testing.assert_array_equal(ref.rounds, got.rounds)
    np.testing.assert_array_equal(ref.ok, got.ok)
    np.testing.assert_array_equal(ref.attempts, got.attempts)
    np.testing.assert_array_equal(ref.alive, got.alive)
    np.testing.assert_array_equal(ref.disputed, got.disputed)
    np.testing.assert_array_equal(ref.hist_stuck, got.hist_stuck)
    np.testing.assert_array_equal(ref.hist_rounds, got.hist_rounds)
    np.testing.assert_array_equal(ref.hist_alive, got.hist_alive)
    np.testing.assert_array_equal(ref.hist_p, got.hist_p)
    np.testing.assert_array_equal(ref.hist_players, got.hist_players)
    np.testing.assert_array_equal(ref.hist_players_h,
                                  got.hist_players_h)
    np.testing.assert_array_equal(ref.hist_players_last,
                                  got.hist_players_last)
    for b in range(ref.batch):
        for f in ("bits_coresets", "bits_weight_sums", "bits_hypotheses",
                  "bits_control", "bits_dispute", "bits_histograms",
                  "bits_votes", "rounds", "attempts"):
            assert getattr(ref.ledger(b), f) == getattr(got.ledger(b), f), f


# ---------------------------------------------------------------------------
# Round-granular stepping ≡ monolithic run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("slice_rounds", [1, 3, 7])
def test_sliced_run_rounds_bit_identical(slice_rounds):
    x, y, keys, _ = _batch()
    full = batched.run_accurately_classify_batched(x, y, keys, CFG, CLS)
    state = batched.init_state(x, y, keys, CFG)
    a_max = CFG.opt_budget + 1
    slices = 0
    while bool(np.any(~np.asarray(state.done)
                      & (np.asarray(state.attempt) < a_max))):
        state = batched.run_rounds(state, x, y, CFG, CLS,
                                   n=slice_rounds)
        slices += 1
        assert slices < 500, "stepper failed to terminate"
    got = batched.finalize(state, x, y, full.alive0, CFG, CLS)
    assert slices > 1            # the slicing actually sliced
    _assert_bitwise(full, got)


def test_stepper_feature_track_randomized_coreset():
    """Slicing must preserve the PRNG stream of the randomized-coreset
    (AxisStumps) track too — keys are state, not recomputed."""
    cls = weak.AxisStumps(num_features=4)
    cfg = BoostConfig(k=2, coreset_size=64, domain_size=N, opt_budget=8,
                      deterministic_coreset=False)
    x, y, _ = tasks.make_batch(cls, 2, 128, 2, 1, seed0=3)
    keys = jax.random.split(jax.random.key(9), 2)
    full = batched.run_accurately_classify_batched(x, y, keys, cfg, cls)
    state = batched.init_state(x, y, keys, cfg)
    for _ in range(200):
        state = batched.run_rounds(state, x, y, cfg, cls, n=2)
        if bool(np.all(np.asarray(state.done))):
            break
    got = batched.finalize(state, x, y, full.alive0, cfg, cls)
    _assert_bitwise(full, got)


def test_checkpoint_resume_bit_identical(tmp_path):
    """Protocol state → msgpack file → fresh process state → resume:
    the completed run equals the uninterrupted one, bit for bit."""
    x, y, keys, _ = _batch()
    full = batched.run_accurately_classify_batched(x, y, keys, CFG, CLS)
    state = batched.run_rounds(batched.init_state(x, y, keys, CFG),
                               x, y, CFG, CLS, n=4)
    path = os.path.join(tmp_path, "engine_state.msgpack")
    msgpack_ckpt.save_pytree(path, jax.device_get(state),
                             meta={"rounds_done": 4})
    del state                                   # the preemption
    template = batched.init_state(x, y, keys, CFG)
    restored, meta = msgpack_ckpt.load_pytree(path, like=template)
    assert meta["rounds_done"] == 4
    done = batched.run_rounds(restored, x, y, CFG, CLS)
    got = batched.finalize(done, x, y, full.alive0, CFG, CLS)
    _assert_bitwise(full, got)


def test_sharded_checkpoint_resume_bit_identical(tmp_path):
    x, y, keys, _ = _batch()
    full = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, CFG, CLS)
    state = sharded_batched.init_state_sharded(x, y, keys, CFG)
    state = sharded_batched.run_rounds_sharded(state, x, y, CFG, CLS,
                                               n=5)
    path = os.path.join(tmp_path, "sharded_state.msgpack")
    msgpack_ckpt.save_pytree(path, jax.device_get(state), meta={})
    del state
    template = sharded_batched.init_state_sharded(x, y, keys, CFG)
    restored, _ = msgpack_ckpt.load_pytree(path, like=template)
    done = sharded_batched.run_rounds_sharded(restored, x, y, CFG, CLS)
    got = sharded_batched.finalize_sharded(done, x, y, full.alive0,
                                           CFG, CLS)
    _assert_bitwise(full, got)
    for b in range(full.batch):
        got.validate_ledger(b)


# ---------------------------------------------------------------------------
# Infrastructure adversaries
# ---------------------------------------------------------------------------

SPECS = {
    "dropout": scenarios.InfraSpec(name="dropout", player=1,
                                   drop_round=5),
    "flaky": scenarios.InfraSpec(name="flaky", player=2, miss_rate=0.3,
                                 horizon=64),
    "rejoin": scenarios.InfraSpec(name="rejoin", player=0, drop_round=4,
                                  rejoin_round=12),
}


@pytest.mark.parametrize("name", sorted(SPECS))
def test_infra_adversary_guarantee_over_survivors(name):
    """The protocol proceeds with k′ < k players and E_S(f) ≤ OPT holds
    over the surviving shards (the pinned per-adversary guarantee)."""
    spec = SPECS[name]
    sched = spec.schedule(4, seed=0)
    assert not sched.all(), "adversary must actually silence someone"
    x, y, keys, ts = _batch(B=3)
    res = batched.run_accurately_classify_batched(
        x, y, keys, CFG, CLS, player_sched=sched)
    assert bool(res.ok.all())
    for b in range(3):
        rep = scenarios.infra_report(ts[b], res, b, spec)
        assert rep["guarantee_ok"], (name, b, rep)
    # determinism: same schedule, same bits
    res2 = batched.run_accurately_classify_batched(
        x, y, keys, CFG, CLS, player_sched=sched)
    _assert_bitwise(res, res2)


@pytest.mark.parametrize("name", sorted(SPECS))
def test_infra_ledger_equals_payload_under_mask(name):
    """Sharded engine under the same schedule: bit-equal to the local
    engine, and Theorem 4.1 accounting == measured collective payloads
    with only alive players' messages charged."""
    spec = SPECS[name]
    sched = spec.schedule(4, seed=0)
    x, y, keys, _ = _batch(B=2)
    ref = batched.run_accurately_classify_batched(
        x, y, keys, CFG, CLS, player_sched=sched)
    got = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, CFG, CLS, player_sched=sched)
    _assert_bitwise(ref, got)
    baseline = batched.run_accurately_classify_batched(x, y, keys, CFG,
                                                       CLS)
    for b in range(2):
        got.validate_ledger(b)
        # masked accounting is strictly cheaper than all-alive on the
        # rounds the silenced player missed
        assert got.ledger(b).total_bits < baseline.ledger(b).total_bits
        k_dead_rounds = int(np.sum(~sched.all(axis=-1)))
        assert k_dead_rounds > 0
        # the per-attempt alive-player sums never exceed k·wire_rounds
        n_att = int(got.attempts[b])
        for a in range(n_att):
            wire = int(got.hist_rounds[b, a]) + int(got.hist_stuck[b, a])
            assert int(got.hist_players[b, a]) <= wire * CFG.k


def test_dropout_quarantine_excludes_dead_players_coreset():
    """A stuck round after the dropout must quarantine only points the
    ALIVE players' coresets named — the dead player's rows are masked
    out of the match and the dispute-table size P."""
    spec = scenarios.InfraSpec(name="dropout", player=1, drop_round=0)
    sched = spec.schedule(4, seed=0)       # player 1 never participates
    x, y, keys, _ = _batch(B=2)
    res = batched.run_accurately_classify_batched(
        x, y, keys, CFG, CLS, player_sched=sched)
    assert bool(res.ok.all())
    for b in range(2):
        if not res.disputed[b].any():
            continue
        # every disputed point must occur in some surviving player's
        # shard (the dead player's shard alone can't name points)
        disputed_pts = np.unique(res.x[b][res.disputed[b]])
        surv_pts = np.unique(res.x[b][[0, 2, 3]])
        assert np.isin(disputed_pts, surv_pts).all()


def test_player_schedule_shapes_and_validation():
    spec = scenarios.InfraSpec(name="dropout", player=2, drop_round=3)
    sched = spec.schedule(4)
    assert sched.shape == (4, 4)
    np.testing.assert_array_equal(sched[:3, 2], True)
    assert not sched[3, 2]
    np.testing.assert_array_equal(spec.survivors(4),
                                  [True, True, False, True])
    rj = scenarios.InfraSpec(name="rejoin", player=0, drop_round=2,
                             rejoin_round=5)
    s = rj.schedule(3)
    np.testing.assert_array_equal(s[:, 0],
                                  [True, True, False, False, False, True])
    assert rj.survivors(3).all()
    fl = scenarios.InfraSpec(name="flaky", player=1, miss_rate=0.5,
                             horizon=32)
    s = fl.schedule(2, seed=3)
    assert s.shape == (32, 2) and s[:, 0].all() and s[-1, 1]
    assert not s[:, 1].all()               # it actually missed rounds
    assert fl.survivors(2, seed=3).all()
    with pytest.raises(ValueError):
        scenarios.InfraSpec(name="warp-core-breach")
    with pytest.raises(ValueError):
        scenarios.InfraSpec(name="rejoin", drop_round=5, rejoin_round=5)
    with pytest.raises(ValueError):
        scenarios.InfraSpec(name="dropout").schedule(1)   # k=1: nobody left
    assert scenarios.InfraSpec(name="none").schedule(1).shape == (1, 1)


def test_masked_point_helpers_int_and_float():
    """mask_invalid_points / distinct_count_masked work on every point
    dtype the tracks use — 1-D int, 1-D float, and float feature rows —
    and the all-valid case equals the unmasked count."""
    import jax.numpy as jnp

    from repro.core import classify

    pts_i = jnp.asarray([5, 5, 2, 9], jnp.int32)
    valid = jnp.asarray([True, True, False, True])
    assert int(classify.distinct_count_masked(pts_i, valid)) == 2
    assert int(classify.distinct_count(pts_i)) == 3
    masked = classify.mask_invalid_points(pts_i, valid)
    assert not bool(classify.match_points(
        jnp.asarray([[2]], jnp.int32), masked)[0, 0])
    pts_f = jnp.asarray([1.5, 2.5, 1.5], jnp.float32)
    assert int(classify.distinct_count(pts_f)) == 2
    assert int(classify.distinct_count_masked(
        pts_f, jnp.asarray([True, False, True]))) == 1
    rows = jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.float32)
    rv = jnp.asarray([True, False])
    assert int(classify.distinct_count_masked(rows, rv)) == 1
    mrows = classify.mask_invalid_points(rows, rv)
    assert not bool(classify.match_points(rows[None, 1:2], mrows)[0, 0])


def test_canon_player_sched_rejects_dead_rounds():
    with pytest.raises(ValueError):
        batched.canon_player_sched(np.zeros((2, 4), bool), B=1, k=4)
    with pytest.raises(ValueError):
        batched.canon_player_sched(np.ones((1, 3), bool), B=1, k=4)
    out = batched.canon_player_sched(np.ones((2, 4), bool), B=3, k=4)
    assert out.shape == (3, 2, 4)


def test_checkpoint_shape_mismatch_fails_loudly(tmp_path):
    """Restoring engine state against a template of different shapes
    (wrong batch / budget) must raise a clear error, not a reshape
    failure inside a jit trace."""
    x, y, keys, _ = _batch(B=2, m=256)
    state = batched.run_rounds(batched.init_state(x, y, keys, CFG),
                               x, y, CFG, CLS, n=2)
    path = os.path.join(tmp_path, "state.msgpack")
    msgpack_ckpt.save_pytree(path, jax.device_get(state), meta={})
    x3, y3, keys3, _ = _batch(B=3, m=256)
    wrong = batched.init_state(x3, y3, keys3, CFG)
    with pytest.raises(ValueError, match="shape"):
        msgpack_ckpt.load_pytree(path, like=wrong)


# ---------------------------------------------------------------------------
# Distributed tree-growth modes (histogram-merge / voting) under
# infrastructure adversaries — dead players must contribute neither
# histograms nor votes, and the masked ledger must still equal the
# measured collective payloads.
# ---------------------------------------------------------------------------

TREE_CFG = BoostConfig(k=4, coreset_size=64, domain_size=1 << 12,
                       opt_budget=16, deterministic_coreset=False)
TREE_SPECS = {
    "dropout": scenarios.InfraSpec(name="dropout", player=1,
                                   drop_round=0),
    "rejoin": scenarios.InfraSpec(name="rejoin", player=0, drop_round=2,
                                  rejoin_round=5),
}


def _tree_cls(mode):
    return weak.make_class("tree", num_features=4, tree_depth=2,
                           tree_bins=8, tree_comm_mode=mode,
                           tree_vote_topk=1)


def _tree_batch(cls, B=2, m=256, seed0=21):
    spec = scenarios.ScenarioSpec(name="xor", noise=2)
    x, y, ts = scenarios.make_scenario_batch(cls, B, m, 4, spec,
                                             seed0=seed0)
    keys = jax.random.split(jax.random.key(7), B)
    return x, y, keys, ts


@pytest.mark.parametrize("infra", sorted(TREE_SPECS))
@pytest.mark.parametrize("mode", ["histogram", "voting"])
def test_tree_comm_infra_parity_and_masked_ledger(mode, infra):
    """Batched ≡ sharded bitwise under dropout/rejoin for both
    distributed tree-growth modes, with validate_ledger proving the
    masked accounting equals the measured histogram/vote payloads."""
    cls = _tree_cls(mode)
    sched = TREE_SPECS[infra].schedule(4, seed=0)
    assert not sched.all()
    x, y, keys, _ = _tree_batch(cls)
    ref = batched.run_accurately_classify_batched(
        x, y, keys, TREE_CFG, cls, player_sched=sched)
    got = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, TREE_CFG, cls, player_sched=sched)
    assert bool(ref.ok.all())
    _assert_bitwise(ref, got)
    wire = np.asarray(got.hist_rounds) + np.asarray(got.hist_stuck)
    alive_rounds = np.asarray(got.hist_players)
    assert np.any(alive_rounds < 4 * wire)   # somebody actually missed
    for b in range(ref.batch):
        got.validate_ledger(b)               # masked ledger ≡ payload
        led = got.ledger(b)
        assert led.bits_histograms > 0       # both modes merge hists
        assert (led.bits_votes > 0) == (mode == "voting")


def test_tree_comm_dead_player_ships_no_payload():
    """With player 1 silenced for the whole run, the measured histogram
    and vote payload counters can only ever count 3 alive players per
    wire round — the dead player's messages are never charged."""
    cls = _tree_cls("voting")
    sched = TREE_SPECS["dropout"].schedule(4, seed=0)
    x, y, keys, _ = _tree_batch(cls)
    got = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, TREE_CFG, cls, player_sched=sched)
    assert bool(got.ok.all())
    wire = np.asarray(got.hist_rounds) + np.asarray(got.hist_stuck)
    assert np.all(np.asarray(got.hist_players) <= 3 * wire)
    hist_pp = ledger.hist_scalars_per_player(cls)
    vote_pp = ledger.vote_entries_per_player(cls)
    assert hist_pp > 0 and vote_pp > 0
    for b in range(got.batch):
        got.validate_ledger(b)
        # the measured counters are exactly (alive player-rounds) ×
        # (static per-player payload): 3/4 of the all-alive charge
        n_att = int(got.attempts[b])
        pr = int(np.sum(np.asarray(got.hist_players)[b, :n_att]))
        assert int(np.sum(got.hist_wire_hist[b, :n_att])) \
            == pr * hist_pp
        assert int(np.sum(got.hist_wire_votes[b, :n_att])) \
            == pr * vote_pp


@pytest.mark.parametrize("mode", ["histogram", "voting"])
def test_tree_comm_sharded_checkpoint_resume(mode, tmp_path):
    """Mid-run sharded state → msgpack (template-free restore) → resume:
    bit-identical to the uninterrupted run for both distributed modes —
    the new histogram/vote wire counters round-trip with the state."""
    cls = _tree_cls(mode)
    x, y, keys, _ = _tree_batch(cls)
    full = sharded_batched.run_accurately_classify_sharded(
        x, y, keys, TREE_CFG, cls)
    state = sharded_batched.init_state_sharded(x, y, keys, TREE_CFG,
                                               cls=cls)
    state = sharded_batched.run_rounds_sharded(state, x, y, TREE_CFG,
                                               cls, n=3)
    path = os.path.join(tmp_path, f"tree_{mode}.msgpack")
    msgpack_ckpt.save_pytree(path, jax.device_get(state),
                             treedef=sharded_batched.STATE_TREEDEF)
    del state                                    # the preemption
    restored, _ = msgpack_ckpt.restore_pytree(path)
    assert {"awire_hist", "awire_votes",
            "hist_wire_hist", "hist_wire_votes"} <= set(restored)
    done = sharded_batched.run_rounds_sharded(restored, x, y, TREE_CFG,
                                              cls)
    got = sharded_batched.finalize_sharded(done, x, y, full.alive0,
                                           TREE_CFG, cls)
    _assert_bitwise(full, got)
    np.testing.assert_array_equal(full.hist_wire_hist,
                                  got.hist_wire_hist)
    np.testing.assert_array_equal(full.hist_wire_votes,
                                  got.hist_wire_votes)
    for b in range(full.batch):
        got.validate_ledger(b)


def test_all_alive_schedule_is_a_bitwise_noop():
    """An explicit all-alive schedule must not perturb a single bit
    relative to the default path (masking reduces exactly)."""
    x, y, keys, _ = _batch(B=2)
    ref = batched.run_accurately_classify_batched(x, y, keys, CFG, CLS)
    got = batched.run_accurately_classify_batched(
        x, y, keys, CFG, CLS, player_sched=np.ones((7, 4), bool))
    _assert_bitwise(ref, got)
