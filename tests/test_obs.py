"""repro/obs: tracing + metrics units, and trace integrity under faults.

Three layers:

* instrument units — the no-op fast path when tracing is disabled, span
  args/update semantics, Chrome-trace export, fixed-bucket histogram
  quantiles, registry get-or-create discipline;
* fault integrity — a round span interrupted mid-protocol still closes
  (the event is recorded, the exception propagates), a checkpoint/resume
  pair merges into one ledger-exact trace with no double-counted bits,
  and dropout rounds record dead players as explicit zero-bit events;
* the validator bites — tampering with a traced event (dropping a round,
  zeroing a category) is an AssertionError, not a silent pass.

The full engine × comm-mode × mask validation matrix lives in
benchmarks/observability.py (gated); these tests keep the small fast
cases in tier-1.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import msgpack_ckpt
from repro.core import batched, tasks, weak
from repro.core.types import BoostConfig
from repro.obs import metrics as M
from repro.obs import roundtrace
from repro.obs import trace as T

B, K, MLOC = 2, 2, 64
N_DOMAIN = 1 << 10

# player 0 sits out wire round 1 (canon_player_sched extends the last row)
MASK_SCHED = np.ones((4, K), bool)
MASK_SCHED[1, 0] = False


def _problem(seed0=11):
    cls = weak.make_class("thresholds", n=N_DOMAIN)
    cfg = BoostConfig(k=K, coreset_size=32, domain_size=N_DOMAIN,
                      opt_budget=8)
    x, y, _ = tasks.make_batch(cls, B, MLOC, K, 3, seed0=seed0)
    keys = jax.random.split(jax.random.key(3), B)
    return cls, cfg, x, y, keys


def _step(x, y, cfg, cls, player_sched=None):
    return lambda s: batched.run_rounds(s, x, y, cfg, cls, n=1,
                                        player_sched=player_sched)


def _traced_to_completion(player_sched=None, seed0=11):
    cls, cfg, x, y, keys = _problem(seed0)
    rec = T.TraceRecorder()
    st = batched.init_state(x, y, keys, cfg, cls=cls)
    st = roundtrace.trace_rounds(_step(x, y, cfg, cls, player_sched),
                                 st, cfg, cls, recorder=rec)
    res = batched.finalize(st, x, y, np.ones(y.shape, bool), cfg, cls)
    return rec, res


# ---------------------------------------------------------------------------
# instrument units: trace
# ---------------------------------------------------------------------------

def test_disabled_tracing_is_shared_noop():
    assert not T.enabled()
    sp = T.span("anything", "protocol", x=1)
    assert sp is T.span("other")            # one preallocated null span
    with sp as s:
        s.update(ignored=True)              # no-op, no recorder touched
    T.instant("nothing")                    # no-op
    assert T.active() is None


def test_recording_scope_and_span_args(tmp_path):
    with T.recording() as rec:
        assert T.enabled() and T.active() is rec
        with T.span("work", "engine", engine="batched") as sp:
            sp.update(rounds=3)
        T.instant("mark", "engine", task=0)
    assert not T.enabled()                  # scope restored
    ev = {e["name"]: e for e in rec.events}
    assert ev["work"]["ph"] == "X"
    assert ev["work"]["cat"] == "engine"
    assert ev["work"]["dur"] >= 0.0
    assert ev["work"]["args"] == {"engine": "batched", "rounds": 3}
    assert ev["mark"]["ph"] == "i"
    out = os.path.join(tmp_path, "trace.json")
    rec.save(out)
    with open(out, encoding="utf-8") as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    assert doc["traceEvents"] == rec.events


def test_span_records_event_even_when_body_raises():
    rec = T.TraceRecorder()
    with pytest.raises(RuntimeError):
        with rec.span("interrupted", "protocol"):
            raise RuntimeError("preempted")
    assert [e["name"] for e in rec.events] == ["interrupted"]
    assert rec.events[0]["ph"] == "X"


def test_ledger_bits_covers_every_category():
    import types as pytypes
    led = pytypes.SimpleNamespace(
        **{field: i for i, field in
           enumerate(T.CATEGORY_FIELDS.values(), start=1)})
    bits = T.ledger_bits(led)
    assert set(bits) == set(T.CATEGORY_FIELDS)
    assert sorted(bits.values()) == list(
        range(1, len(T.CATEGORY_FIELDS) + 1))


# ---------------------------------------------------------------------------
# instrument units: metrics
# ---------------------------------------------------------------------------

def test_histogram_quantiles_are_deterministic():
    h = M.Histogram("t", buckets=(1.0, 2.0, 4.0))
    assert h.quantile(0.5) == 0.0           # empty
    for v in (0.5,) * 50 + (3.0,) * 50:
        h.observe(v)
    assert h.count == 100
    assert h.sum == pytest.approx(175.0)
    assert 0.0 < h.quantile(0.25) <= 1.0    # inside the first bucket
    assert 2.0 < h.quantile(0.99) <= 4.0    # inside the third
    assert h.quantile(0.25) <= h.quantile(0.5) <= h.quantile(0.99)
    with pytest.raises(ValueError):
        h.quantile(1.5)
    d = h.to_dict()
    assert d["type"] == "histogram" and "p50" in d and "p99" in d


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError):
        M.Histogram("bad", buckets=(2.0, 1.0))


def test_registry_get_or_create_and_kind_discipline(tmp_path):
    reg = M.MetricsRegistry()
    c = reg.counter("a.count")
    c.inc()
    assert reg.counter("a.count") is c      # get-or-create, not replace
    assert reg.counter("a.count").value == 1
    reg.gauge("a.gauge").set(2.5)
    reg.histogram("a.lat").observe(0.01)
    with pytest.raises(TypeError):
        reg.gauge("a.count")                # a name holds ONE kind
    assert reg.names() == ["a.count", "a.gauge", "a.lat"]
    out = os.path.join(tmp_path, "metrics.json")
    reg.save(out)
    with open(out, encoding="utf-8") as f:
        doc = json.load(f)
    assert doc["a.count"] == {"type": "counter", "value": 1}
    assert doc["a.gauge"]["value"] == 2.5


def test_default_registry_reset_isolation():
    reg = M.default_registry()
    assert M.default_registry() is reg
    fresh = M.reset_default_registry()
    assert fresh is not reg
    assert M.default_registry() is fresh


# ---------------------------------------------------------------------------
# fault integrity
# ---------------------------------------------------------------------------

def test_round_span_closes_when_step_preempted_mid_protocol():
    cls, cfg, x, y, keys = _problem()
    st = batched.init_state(x, y, keys, cfg, cls=cls)
    calls = {"n": 0}

    def step(s):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("dispatch preempted")
        return batched.run_rounds(s, x, y, cfg, cls, n=1)

    rec = T.TraceRecorder()
    with pytest.raises(RuntimeError, match="preempted"):
        roundtrace.trace_rounds(step, st, cfg, cls, recorder=rec)
    rounds = [e for e in rec.events if e["name"] == "round"]
    assert len(rounds) == 2                 # interrupted span still closed
    assert all(e["ph"] == "X" for e in rounds)
    assert "task_bits" in rounds[0]["args"]  # the completed round's bits


def test_resumed_run_does_not_double_count(tmp_path):
    cls, cfg, x, y, keys = _problem(seed0=21)
    step = _step(x, y, cfg, cls)
    path = os.path.join(tmp_path, "preempt.msgpack")

    rec_a = T.TraceRecorder()
    st = batched.init_state(x, y, keys, cfg, cls=cls)
    st = roundtrace.trace_rounds(step, st, cfg, cls, recorder=rec_a,
                                 max_rounds=2)
    msgpack_ckpt.save_pytree(path, jax.device_get(st),
                             treedef=batched.STATE_TREEDEF)
    del st                                   # the preemption: state dies

    restored, _meta = msgpack_ckpt.restore_pytree(path)
    rec_b = T.TraceRecorder()
    restored = roundtrace.trace_rounds(step, restored, cfg, cls,
                                       recorder=rec_b)
    res = batched.finalize(restored, x, y, np.ones(y.shape, bool), cfg,
                           cls)

    assert rec_a.events and rec_b.events
    merged = rec_a.events + rec_b.events
    ledgers = {b: res.ledger(b) for b in range(B)}
    rep = roundtrace.validate_trace(merged, ledgers)
    # the merged segments account for every round exactly once
    for b in range(B):
        assert rep[b]["traced"]["rounds"] == int(res.ledger(b).rounds)
    # either half alone under-counts (the other half moved bits too)
    with pytest.raises(AssertionError):
        roundtrace.validate_trace(rec_a.events, ledgers)
    with pytest.raises(AssertionError):
        roundtrace.validate_trace(rec_b.events, ledgers)


def test_dropout_rounds_emit_zero_bit_dead_player_events():
    rec, res = _traced_to_completion(player_sched=MASK_SCHED)
    roundtrace.validate_trace(rec, {b: res.ledger(b) for b in range(B)})
    dead = [e for e in rec.events if e["name"] == "dead_players"]
    assert dead, "masked round must record its dead players"
    for e in dead:
        assert e["ph"] == "i"
        assert e["args"]["bits"] == 0        # absent players move nothing
        assert e["args"]["players_dead"] >= 1
        assert (e["args"]["players_alive"]
                + e["args"]["players_dead"]) == K


# ---------------------------------------------------------------------------
# the validator bites
# ---------------------------------------------------------------------------

def test_validate_trace_detects_tampering():
    rec, res = _traced_to_completion()
    ledgers = {b: res.ledger(b) for b in range(B)}
    roundtrace.validate_trace(rec, ledgers)  # clean baseline

    events = json.loads(json.dumps(rec.events))  # deep copy
    victim = next(e for e in events
                  if (e.get("args") or {}).get("task_bits"))
    task, bits = next(iter(victim["args"]["task_bits"].items()))
    cat = next((c for c, v in bits.items() if v), "ws")
    bits[cat] += 1
    with pytest.raises(AssertionError, match=f"task {task} {cat}"):
        roundtrace.validate_trace(events, ledgers)

    idx = next(i for i, e in enumerate(rec.events)
               if (e.get("args") or {}).get("task_bits"))
    dropped = rec.events[:idx] + rec.events[idx + 1:]
    with pytest.raises(AssertionError):
        roundtrace.validate_trace(dropped, ledgers)


def test_validate_trace_rejects_unknown_tasks():
    rec, res = _traced_to_completion()
    rec.instant("bogus", task_bits={"99": {"ws": 1}})
    with pytest.raises(AssertionError, match="unknown tasks"):
        roundtrace.validate_trace(rec, {b: res.ledger(b)
                                        for b in range(B)})
