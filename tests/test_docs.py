"""Docs cannot rot: run the CI doc-consistency check as a tier-1 test.

`.github/scripts/check_docs.py` resolves every dotted
``repro.*``/``benchmarks.*`` backtick reference in docs/*.md +
README.md via import, and asserts TESTING.md quotes ROADMAP.md's
tier-1 command verbatim.  Running it here means doc drift fails the
same `pytest -x -q` gate as a broken test — not just the CI job.
"""

import importlib.util
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPT = os.path.join(REPO, ".github", "scripts", "check_docs.py")


def _load():
    spec = importlib.util.spec_from_file_location("check_docs", SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_exist():
    for page in ("architecture.md", "ledger.md", "streaming.md"):
        assert os.path.exists(os.path.join(REPO, "docs", page)), page


def test_doc_references_resolve():
    mod = _load()
    failures = mod.check_refs(REPO)
    assert not failures, "\n".join(failures)


def test_tier1_command_agrees():
    mod = _load()
    failures = mod.check_tier1_command(REPO)
    assert not failures, "\n".join(failures)


def test_checker_catches_a_bad_ref(tmp_path):
    # the check itself must not rot: a fabricated dangling reference
    # has to be reported
    mod = _load()
    docs = tmp_path / "docs"
    docs.mkdir()
    (docs / "bad.md").write_text(
        "see `repro.core.no_such_module.missing_symbol`")
    failures = mod.check_refs(str(tmp_path))
    assert any("no_such_module" in f for f in failures)
