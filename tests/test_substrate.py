"""Substrate tests: optimizer, checkpointing, data pipeline, sharding
specs, lower-bound reduction, semi-agnostic baseline, resilient state."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_pytree, save_pytree
from repro.configs import base
from repro.core import lower_bound, resilient, semi_agnostic, tasks, weak
from repro.core.types import BoostConfig
from repro.data import DataConfig, SyntheticCorpus
from repro.optim import adamw, adamw_init


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = adamw.adamw_update(params, g, state, lr=5e-2,
                                           weight_decay=0.0)
    assert float(loss(params)) < 1e-3
    assert int(state["step"]) == 300


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((2, 2), -10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2)
                         for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm),
                               float(jnp.sqrt(8 * 100.0)), rtol=1e-5)


def test_schedule_warmup_then_decay():
    lrs = [float(adamw.linear_warmup_cosine(s, 1.0, 10, 100))
           for s in range(100)]
    assert lrs[0] < lrs[5] < lrs[9]          # warms up
    assert lrs[15] > lrs[60] > lrs[95]       # decays
    assert abs(lrs[10] - 1.0) < 0.05


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32),
                       "c": (jnp.ones((2,)), jnp.zeros((1,)))}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.msgpack")
        save_pytree(path, tree, meta={"step": 7})
        restored, meta = load_pytree(path, like=tree)
        assert meta["step"] == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (10, 20, 30, 40):
            mgr.save(s, {"w": jnp.asarray([float(s)])})
        assert mgr.steps() == [30, 40]
        restored, meta = mgr.restore_latest(like={"w": jnp.zeros((1,))})
        assert float(restored["w"][0]) == 40.0


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_corpus_deterministic_and_noisy_split():
    dc = DataConfig(vocab_size=64, seq_len=16, num_examples=256,
                    noise_frac=0.25, seed=3)
    c1, c2 = SyntheticCorpus(dc), SyntheticCorpus(dc)
    np.testing.assert_array_equal(c1.tokens, c2.tokens)
    np.testing.assert_array_equal(c1.noisy_ids, c2.noisy_ids)
    assert len(c1.noisy_ids) == 64
    clean = np.setdiff1d(np.arange(256), c1.noisy_ids)
    # clean examples follow the Markov chain, noisy ones don't
    ok = c1.successors[c1.tokens[clean[0]]]          # [S, branching]
    assert all(c1.labels[clean[0]][s] in ok[s] for s in range(16))


def test_corpus_batch_respects_alive():
    dc = DataConfig(num_examples=128, seq_len=8, seed=0)
    c = SyntheticCorpus(dc)
    alive = np.zeros(128, bool)
    alive[:10] = True
    rng = np.random.default_rng(0)
    b = c.batch(rng, 32, alive=alive)
    assert np.asarray(b["ids"]).max() < 10


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", base.ASSIGNED_ARCHS)
def test_param_specs_divisibility(arch):
    """Every sharded dim divides the production model axis (16)."""
    from repro.launch import sharding
    from repro.models import build
    cfg = base.get_config(arch)
    mesh_cfg = base.MeshConfig()
    model = build(cfg)
    pshape = jax.eval_shape(model.init,
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = sharding.param_specs(pshape, cfg, mesh_cfg)
    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    flat_p = jax.tree.leaves(pshape)
    assert len(flat_s) == len(flat_p)
    n_sharded = 0
    for spec, leaf in zip(flat_s, flat_p):
        for dim, ax in enumerate(spec):
            if ax == "model":
                assert leaf.shape[dim] % mesh_cfg.model == 0, (
                    arch, spec, leaf.shape)
                n_sharded += 1
    assert n_sharded > 0                      # something actually shards


# ---------------------------------------------------------------------------
# Lower bound (Theorem 2.3) + semi-agnostic baseline
# ---------------------------------------------------------------------------

def test_disj_reduction_decides_correctly():
    n = 1 << 12
    cfg = BoostConfig(k=2, coreset_size=400, domain_size=n,
                      opt_budget=40)
    rng = np.random.default_rng(0)
    for disjoint in (True, False):
        x, y = lower_bound.random_disj_instance(rng, r=8, weight=3,
                                                disjoint=disjoint)
        out = lower_bound.solve_disjointness(x, y, n, cfg, seed=1)
        assert out.disjoint_decided == disjoint, (disjoint, out)
        assert out.total_bits > 0


def test_semi_agnostic_baseline_runs_and_patches():
    cls = weak.Thresholds(n=1 << 12)
    task = tasks.make_task(cls, m=2048, k=4, noise=6, seed=2)
    cfg = BoostConfig(k=4, coreset_size=400, domain_size=1 << 12)
    res = semi_agnostic.run_semi_agnostic(
        jnp.asarray(task.x), jnp.asarray(task.y), jax.random.key(0),
        cfg, cls)
    opt = tasks.true_opt(task)
    assert res.final_errors <= res.boost_errors
    assert res.final_errors <= max(3 * opt, opt + 2)
    assert res.ledger.total_bits > 0


# ---------------------------------------------------------------------------
# Resilient neural state
# ---------------------------------------------------------------------------

def test_resilient_mw_and_quarantine_mechanics():
    rc = resilient.ResilientConfig(num_examples=512, coreset_size=8,
                                   check_every=1, min_hits_gap=2)
    st = resilient.init_state(rc)
    ids = np.arange(64)
    # easy examples: low nll -> hits increase
    st = resilient.update(st, ids, np.full(64, 0.1), rc, step=0)
    assert st.hits[:64].sum() > 0
    w, alive = resilient.batch_weights(st, np.arange(8), rc)
    assert w.shape == (8,) and bool(jnp.all(alive == 1.0))
    # plant persistent hard examples and drive checks (mixed batches —
    # the "correct" analog is relative to the batch median, like the
    # real pipeline sees)
    hard_ids = np.arange(504, 512)
    for step in range(1, 40):
        ids = np.concatenate([np.arange(0, 480), hard_ids])
        nll = np.concatenate([np.full(480, 0.1, np.float32),
                              np.full(8, 9.0, np.float32)])
        st = resilient.update(st, ids, nll, rc, step)
    stats = resilient.quarantine_stats(st, hard_ids)
    assert stats["noise_recall"] == 1.0
    assert stats["quarantined"] <= rc.coreset_size * 2
