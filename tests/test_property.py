"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an *optional* test dependency (see TESTING.md): when
absent the module skips instead of killing collection for the whole
suite.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional test dep: pip install hypothesis (see TESTING.md)")
from hypothesis import given, settings, strategies as st

from repro.core import ledger, weak, weights
from repro.core.types import BoostConfig, Ledger

N = 1 << 10


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(8, 200),
       st.integers(0, 30))
def test_mw_normalization(seed, m, hmax):
    """p_t is a probability distribution supported on alive examples."""
    rng = np.random.default_rng(seed)
    hits = jnp.asarray(rng.integers(0, hmax + 1, m), jnp.int32)
    alive = jnp.asarray(rng.random(m) < 0.7)
    if not bool(jnp.any(alive)):
        return
    p = weights.probs(hits, alive)
    np.testing.assert_allclose(float(jnp.sum(p)), 1.0, rtol=1e-4)
    assert float(jnp.min(p)) >= 0.0
    assert float(jnp.max(jnp.where(alive, 0.0, p))) == 0.0


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(4, 128))
def test_mixture_weights_simplex(seed, k):
    rng = np.random.default_rng(seed)
    lw = jnp.asarray(rng.uniform(-60, 10, k), jnp.float32)
    dead = rng.random(k) < 0.2
    lw = jnp.where(jnp.asarray(dead), -jnp.inf, lw)
    if dead.all():
        return
    mix = weights.mixture_weights(lw)
    np.testing.assert_allclose(float(jnp.sum(mix)), 1.0, rtol=1e-5)
    assert float(jnp.max(jnp.where(jnp.asarray(dead), mix, 0.0))) == 0.0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(8, 96),
       st.sampled_from(["thresholds", "intervals", "singletons"]))
def test_erm_never_beaten_by_random_hypotheses(seed, m, clsname):
    """ERM loss ≤ loss of any sampled hypothesis (optimality property)."""
    cls = weak.make_class(clsname, n=N)
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.integers(0, N, m), jnp.int32)
    ys = jnp.asarray(rng.choice([-1, 1], m), jnp.int8)
    w = rng.random(m).astype(np.float32)
    w = jnp.asarray(w / w.sum())
    _, best = cls.erm(xs, ys, w)
    type_id = {"singletons": 1.0, "thresholds": 2.0, "intervals": 3.0}
    for _ in range(20):
        a, b = sorted(rng.integers(0, N, 2).tolist())
        s = float(rng.choice([-1.0, 1.0]))
        if clsname != "thresholds":
            s = 1.0
        params = jnp.asarray([type_id[clsname], a, b if clsname ==
                              "intervals" else a, s], jnp.float32)
        loss = float(jnp.sum((cls.predict(params, xs) != ys) * w))
        assert float(best) <= loss + 1e-5


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8),
       st.integers(16, 64))
def test_observation_4_4(seed, flips, m_half):
    """Removing a non-realizable subsample lowers EVERY hypothesis'
    error by ≥ 1 (thresholds over a line)."""
    cls = weak.Thresholds(n=N)
    rng = np.random.default_rng(seed)
    m = 2 * m_half
    x = rng.integers(0, N, m).astype(np.int32)
    y = np.where(x >= N // 2, 1, -1).astype(np.int8)
    # build a non-realizable subsample: a contradicting pair
    x[0], y[0] = 5, 1
    x[1], y[1] = 5, -1
    sub = np.zeros(m, bool)
    sub[:2] = True
    grid = jnp.asarray([[2.0, t, t, s] for t in range(0, N, 97)
                        for s in (1.0, -1.0)], jnp.float32)
    preds = cls.predict(grid, jnp.asarray(x))             # [C, m]
    errs_full = jnp.sum(preds != jnp.asarray(y)[None], axis=-1)
    errs_rest = jnp.sum(
        (preds != jnp.asarray(y)[None]) & ~jnp.asarray(sub)[None], axis=-1)
    assert bool(jnp.all(errs_full >= errs_rest + 1))


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.integers(2, 20), st.integers(100, 10 ** 7),
       st.integers(64, 2048))
def test_ledger_monotonicity(k, rounds, m, coreset):
    """More rounds / players / examples never decrease charged bits,
    and the Ledger add is consistent."""
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=k, coreset_size=coreset, domain_size=N)
    a = ledger.boost_attempt_ledger(cfg, cls, m, rounds, stuck=False)
    b = ledger.boost_attempt_ledger(cfg, cls, m, rounds + 1, stuck=False)
    assert b.total_bits >= a.total_bits
    s = a + b
    assert s.total_bits == a.total_bits + b.total_bits
    assert s.attempts == 2
    c2 = BoostConfig(k=k + 1, coreset_size=coreset, domain_size=N)
    assert ledger.boost_attempt_ledger(
        c2, cls, m, rounds, stuck=False).total_bits >= a.total_bits


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 64), st.integers(4, 10 ** 7),
       st.integers(16, 2048), st.integers(0, 1), st.data())
def test_ledger_within_theorem_41_bound(k, m, coreset, stuck, data):
    """One attempt's exact charged bits sit under the Theorem 4.1 form
    O(k·log|S|·(d·log n + log|S|)) with a small explicit constant (the
    1.5 slack absorbs the hypothesis-broadcast and weight-sum terms the
    asymptotic form hides — measured worst ratio ≈ 1.07)."""
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=k, coreset_size=coreset, domain_size=N)
    T = cfg.num_rounds(m)
    rounds = data.draw(st.integers(1, T), label="rounds")
    led = ledger.boost_attempt_ledger(cfg, cls, m, rounds, bool(stuck))
    bound = ledger.theorem_41_bound(cfg, cls, m, opt=0, constant=1.5)
    assert led.total_bits <= bound, (led.total_bits, bound)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 32), st.integers(1, 18), st.integers(100, 10 ** 6),
       st.integers(16, 1024), st.integers(0, 1))
def test_ledger_monotone_in_rounds_k_coreset(k, rounds, m, coreset,
                                             stuck):
    """boost_attempt_ledger totals are monotone in each resource knob:
    more rounds, more players, or bigger coresets never charge less."""
    cls = weak.Thresholds(n=N)
    stuck = bool(stuck)
    base = ledger.boost_attempt_ledger(
        BoostConfig(k=k, coreset_size=coreset, domain_size=N),
        cls, m, rounds, stuck)
    more_rounds = ledger.boost_attempt_ledger(
        BoostConfig(k=k, coreset_size=coreset, domain_size=N),
        cls, m, rounds + 1, stuck)
    more_players = ledger.boost_attempt_ledger(
        BoostConfig(k=k + 1, coreset_size=coreset, domain_size=N),
        cls, m, rounds, stuck)
    more_coreset = ledger.boost_attempt_ledger(
        BoostConfig(k=k, coreset_size=coreset + 1, domain_size=N),
        cls, m, rounds, stuck)
    assert more_rounds.total_bits >= base.total_bits
    assert more_players.total_bits >= base.total_bits
    assert more_coreset.total_bits >= base.total_bits
    # and the bound itself is monotone where the ledger is
    for opt in (0, 1, 5):
        assert ledger.theorem_41_bound(
            BoostConfig(k=k, coreset_size=coreset, domain_size=N),
            cls, m, opt + 1) >= ledger.theorem_41_bound(
            BoostConfig(k=k, coreset_size=coreset, domain_size=N),
            cls, m, opt)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(50, 400))
def test_quantile_coreset_range_property(seed, c):
    """Weighted quantile coreset approximates every threshold event
    within 2/c."""
    from repro.core import approximation
    rng = np.random.default_rng(seed)
    m = 512
    x = jnp.asarray(rng.integers(0, N, m), jnp.int32)
    y = jnp.asarray(rng.choice([-1, 1], m), jnp.int8)
    hits = jnp.asarray(rng.integers(0, 10, m), jnp.int32)
    alive = jnp.ones(m, bool)
    idx = approximation.quantile_coreset(x, y, hits, alive, c)
    p = weights.probs(hits, alive)
    for t in rng.integers(0, N, 10):
        for s in (1, -1):
            true_mass = float(jnp.sum(
                jnp.where((x >= t) & (y == s), p, 0.0)))
            core_mass = float(jnp.mean((x[idx] >= t) & (y[idx] == s)))
            assert abs(true_mass - core_mass) <= 4.0 / c + 1e-6
