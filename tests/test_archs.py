"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate a REDUCED
variant of the same family (≤2 superblocks, d_model ≤ 512, ≤4 experts),
run one forward/train step and one prefill+decode step on CPU, and
assert output shapes + finiteness (no NaNs).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import build, frontend
from repro.optim import adamw_init

B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "weights": jnp.ones((B,), jnp.float32),
        "alive": jnp.ones((B,), jnp.float32),
    }
    if cfg.frontend == "vit_stub":
        batch["prefix_embeds"] = frontend.synth_embeds(
            jax.random.key(1), cfg, B, cfg.frontend_tokens)
    if cfg.encoder_layers:
        batch["frames"] = frontend.synth_embeds(jax.random.key(1), cfg,
                                                B, S)
    return batch


@pytest.mark.parametrize("arch", base.ASSIGNED_ARCHS)
def test_smoke_reduced_config(arch):
    cfg = base.reduced(base.get_config(arch))
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.num_superblocks <= 2 or cfg.num_layers <= 8
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    # --- one train step ---------------------------------------------------
    step = jax.jit(model.make_train_step(total_steps=10))
    new_params, _, met = step(params, adamw_init(params), batch)
    loss = float(met["loss"])
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(float(met["grad_norm"]))
    # params changed and stayed finite
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params,
        new_params)
    assert max(jax.tree.leaves(diffs)) > 0
    assert all(bool(jnp.all(jnp.isfinite(p.astype(jnp.float32))))
               for p in jax.tree.leaves(new_params))


@pytest.mark.parametrize("arch", base.ASSIGNED_ARCHS)
def test_smoke_serve(arch):
    cfg = base.reduced(base.get_config(arch))
    model = build(cfg)
    params = model.init(jax.random.key(0))
    batch = {k: v for k, v in _batch(cfg).items()
             if k in ("tokens", "prefix_embeds", "frames")}
    logits, caches = jax.jit(model.make_prefill_step())(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    dec = jax.jit(model.make_decode_step())
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32) \
        % cfg.vocab_size
    logits2, caches = dec(params, caches, tok)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-32b",
                                  "xlstm-1.3b", "jamba-v0.1-52b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_decode_matches_forward(arch):
    """KV-cache/state decode reproduces the teacher-forced forward."""
    from repro.models import transformer
    cfg = dataclasses.replace(base.reduced(base.get_config(arch)),
                              capacity_factor=8.0)
    model = build(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(3), (B, S + 1), 0,
                              cfg.vocab_size)
    logits_full, _ = transformer.forward(params, cfg, toks)
    ref = logits_full[:, -1]
    _, caches = jax.jit(model.make_prefill_step())(
        params, {"tokens": toks[:, :S]})
    got, _ = jax.jit(model.make_decode_step())(params, caches,
                                               toks[:, S:S + 1])
    rel = float(jnp.max(jnp.abs(ref - got))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    has_moe = any(ffn == "moe" for _, ffn in cfg.block_pattern)
    if rel >= 0.05 and has_moe:
        # Top-k expert routing is discontinuous: a near-tie in router
        # scores can flip an expert under the decode path's equally
        # valid fp rounding, moving a few raw logits a lot while the
        # predictive distribution stays put (observed on jamba at this
        # exact token seed).  Accept iff the flip is distributionally
        # irrelevant: tiny KL and identical argmax.  Dense archs keep
        # the strict check — they have no discontinuity to excuse.
        lp_ref = jax.nn.log_softmax(ref, -1)
        lp_got = jax.nn.log_softmax(got, -1)
        kl = float(jnp.max(jnp.sum(
            jnp.exp(lp_ref) * (lp_ref - lp_got), -1)))
        argmax_same = bool(jnp.all(
            jnp.argmax(ref, -1) == jnp.argmax(got, -1)))
        assert kl < 5e-3 and argmax_same, (rel, kl, argmax_same)
    else:
        assert rel < 0.05, rel


def test_param_counts_match_assignment():
    """Analytic parameter counts sit near the assigned model sizes."""
    expect = {
        "pixtral-12b": (11e9, 14e9),
        "jamba-v0.1-52b": (48e9, 55e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
        "internlm2-20b": (18e9, 22e9),
        "xlstm-1.3b": (1.1e9, 1.7e9),
        "granite-moe-3b-a800m": (2.8e9, 3.9e9),
        "qwen3-32b": (28e9, 34e9),
        "seamless-m4t-medium": (0.8e9, 1.4e9),
        "deepseek-7b": (6.3e9, 7.5e9),
        "command-r-35b": (30e9, 37e9),
    }
    for arch, (lo, hi) in expect.items():
        n = base.get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    phi = base.get_config("phi3.5-moe-42b-a6.6b")
    assert 5.5e9 <= phi.active_param_count() <= 7.5e9   # "a6.6b"
    gr = base.get_config("granite-moe-3b-a800m")
    assert 0.7e9 <= gr.active_param_count() <= 1.2e9    # "a800m"


def test_encdec_decode_matches_teacher_forcing():
    """seamless: cached cross-attention decode == teacher-forced logits."""
    from repro.models import encdec, frontend
    cfg = base.reduced(base.get_config("seamless-m4t-medium"))
    model = build(cfg)
    params = model.init(jax.random.key(0))
    frames = frontend.synth_embeds(jax.random.key(1), cfg, B, S)
    toks = jax.random.randint(jax.random.key(2), (B, S // 2 + 1), 0,
                              cfg.vocab_size)
    enc_out = encdec.encode(params, cfg, frames)
    logits_tf, _ = encdec.decode_train(params, cfg, enc_out,
                                       toks)
    ref = logits_tf[:, -1]
    cross = encdec.build_cross_cache(params, cfg, enc_out)
    self_cache = encdec.init_self_cache(cfg, B, toks.shape[1] + 4)
    got = None
    for t in range(toks.shape[1]):
        got, self_cache = encdec.decode_step(
            params, cfg, cross, self_cache, toks[:, t:t + 1])
    rel = float(jnp.max(jnp.abs(ref - got))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.05, rel


def test_vlm_prefix_decode():
    """pixtral: multimodal prefix (stub patches) + decode consistency."""
    from repro.models import frontend, transformer
    cfg = base.reduced(base.get_config("pixtral-12b"))
    model = build(cfg)
    params = model.init(jax.random.key(0))
    prefix = frontend.synth_embeds(jax.random.key(1), cfg, B,
                                   cfg.frontend_tokens)
    toks = jax.random.randint(jax.random.key(2), (B, 17), 0,
                              cfg.vocab_size)
    logits_full, _ = transformer.forward(params, cfg, toks,
                                         prefix_embeds=prefix)
    ref = logits_full[:, -1]
    _, caches = jax.jit(model.make_prefill_step())(
        params, {"tokens": toks[:, :-1], "prefix_embeds": prefix})
    got, _ = jax.jit(model.make_decode_step())(params, caches,
                                               toks[:, -1:])
    rel = float(jnp.max(jnp.abs(ref - got))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.05, rel
