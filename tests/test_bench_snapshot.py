"""BENCH_<n>.json trajectory snapshots must never clobber each other
(ISSUE 7 satellite): the index is claimed with O_CREAT|O_EXCL and a
collision retries on the next index instead of truncating an existing
snapshot."""

import json
import os

from benchmarks import run as bench_run


def test_back_to_back_snapshots_both_survive(tmp_path):
    root = str(tmp_path)
    p1 = bench_run.write_trajectory_snapshot(
        {"suite": [{"bench": "a"}]}, 0, None, root=root)
    p2 = bench_run.write_trajectory_snapshot(
        {"suite": [{"bench": "b"}]}, 1, "suite", root=root)
    assert p1 != p2
    assert os.path.basename(p1) == "BENCH_1.json"
    assert os.path.basename(p2) == "BENCH_2.json"
    with open(p1) as f:
        s1 = json.load(f)
    with open(p2) as f:
        s2 = json.load(f)
    assert s1["n"] == 1 and s1["results"]["suite"][0]["bench"] == "a"
    assert s2["n"] == 2 and s2["failures"] == 1 and s2["only"] == "suite"


def test_snapshot_collision_retries_not_truncates(tmp_path, monkeypatch):
    """Even when the glob-derived index is stale (another process wrote
    BENCH_1 after our scan), the O_EXCL claim must skip ahead rather
    than overwrite."""
    root = str(tmp_path)
    stale = os.path.join(root, "BENCH_1.json")
    with open(stale, "w") as f:
        json.dump({"precious": True}, f)
    # a glob that never sees the existing file → the naive index is 1
    monkeypatch.setattr(bench_run.glob, "glob", lambda pat: [])
    p = bench_run.write_trajectory_snapshot({}, 0, None, root=root)
    assert os.path.basename(p) == "BENCH_2.json"
    with open(stale) as f:
        assert json.load(f) == {"precious": True}   # untouched


def test_snapshot_ignores_non_index_files(tmp_path):
    root = str(tmp_path)
    for name in ("BENCH_xyz.json", "BENCH_.json", "notBENCH_3.json"):
        with open(os.path.join(root, name), "w") as f:
            f.write("{}")
    p = bench_run.write_trajectory_snapshot({}, 0, None, root=root)
    assert os.path.basename(p) == "BENCH_1.json"
