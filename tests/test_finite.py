"""Finite-class extension (paper §6): exact agnostic ERM, no OPT promise."""

import jax.numpy as jnp
import numpy as np

from repro.core import finite, tasks, weak


def test_finite_class_exact_and_opt_free():
    n = 256
    cls = weak.Thresholds(n=n)
    # the finite class: thresholds on a coarse grid, both signs
    grid = jnp.asarray([[2.0, t, t, s] for t in range(0, n, 8)
                        for s in (1.0, -1.0)], jnp.float32)
    rng = np.random.default_rng(0)
    for noise in (0, 50, 400):           # NO promise: huge OPT is fine
        x = rng.integers(0, n, 2048).astype(np.int32)
        y = np.where(x >= 96, 1, -1).astype(np.int8)
        flip = rng.choice(2048, size=noise, replace=False)
        y[flip] = -y[flip]
        xk = jnp.asarray(x.reshape(4, -1))
        yk = jnp.asarray(y.reshape(4, -1))
        res = finite.learn_finite(xk, yk, grid, cls)
        # exact ERM over the finite class
        preds = cls.predict(grid, jnp.asarray(x))
        brute = int(jnp.min(jnp.sum(preds != jnp.asarray(y)[None], -1)))
        assert res.errors == brute
        # communication independent of OPT
        assert res.total_bits == finite.learn_finite(
            xk, yk, grid, cls).total_bits


def test_finite_bits_scale_with_class_not_opt():
    n = 256
    cls = weak.Thresholds(n=n)
    rng = np.random.default_rng(1)
    x = rng.integers(0, n, 1024).astype(np.int32)
    y = np.where(x >= 100, 1, -1).astype(np.int8)
    xk, yk = jnp.asarray(x.reshape(4, -1)), jnp.asarray(y.reshape(4, -1))
    small = jnp.asarray([[2.0, t, t, 1.0] for t in range(0, n, 32)],
                        jnp.float32)
    big = jnp.asarray([[2.0, t, t, 1.0] for t in range(0, n, 2)],
                      jnp.float32)
    bs = finite.learn_finite(xk, yk, small, cls).total_bits
    bb = finite.learn_finite(xk, yk, big, cls).total_bits
    assert bb > bs
    assert bb / bs <= (big.shape[0] / small.shape[0]) + 1
