"""Per-kernel shape/dtype sweeps, interpret=True, against ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.histogram import ops as hist_ops
from repro.kernels.histogram.ref import best_splits_ref, node_histograms_ref
from repro.kernels.mw_update import ops as mw_ops
from repro.kernels.mw_update.ref import mw_update_ref
from repro.kernels.stump import ops as stump_ops
from repro.kernels.stump.ref import stump_errors_ref


@pytest.mark.parametrize("m", [64, 1000, 8192, 16384])
@pytest.mark.parametrize("seed", [0, 1])
def test_mw_update_sweep(m, seed):
    rng = np.random.default_rng(seed)
    hits = jnp.asarray(rng.integers(0, 60, m), jnp.int32)
    correct = jnp.asarray(rng.random(m) < 0.5)
    alive = jnp.asarray(rng.random(m) < 0.85)
    new_hits, wsum = mw_ops.mw_update(hits, correct, alive)
    ref_hits = hits + jnp.where(correct & alive, 1, 0)
    ref_w = jnp.sum(jnp.where(alive,
                              jnp.exp2(-ref_hits.astype(jnp.float32)), 0.0))
    np.testing.assert_array_equal(np.asarray(new_hits),
                                  np.asarray(ref_hits))
    np.testing.assert_allclose(float(wsum), float(ref_w), rtol=1e-5)


def test_mw_update_block_partials():
    m, block = 512, 128
    rng = np.random.default_rng(2)
    hits = jnp.asarray(rng.integers(0, 20, m), jnp.int32)
    correct = jnp.asarray(rng.random(m) < 0.5)
    alive = jnp.ones(m, bool)
    from repro.kernels.mw_update import kernel as K
    nh, parts = K.mw_update_pallas(hits, correct, alive,
                                   interpret=True, block=block)
    rh, rp = mw_update_ref(hits, correct, alive, block)
    np.testing.assert_array_equal(np.asarray(nh), np.asarray(rh))
    np.testing.assert_allclose(np.asarray(parts), np.asarray(rp),
                               rtol=1e-6)


@pytest.mark.parametrize("c,F,Q", [(32, 1, 8), (128, 8, 128),
                                   (257, 9, 130), (512, 16, 256)])
def test_stump_sweep(c, F, Q):
    rng = np.random.default_rng(c + F + Q)
    x = jnp.asarray(rng.standard_normal((c, F)) * 10, jnp.float32)
    w = rng.random(c).astype(np.float32)
    w = jnp.asarray(w / w.sum())
    y = jnp.asarray(rng.choice([-1.0, 1.0], c), jnp.float32)
    th = jnp.asarray(np.sort(rng.standard_normal((F, Q)) * 10, axis=1),
                     jnp.float32)
    got = stump_ops.stump_errors(x, w, y, th)
    ref = stump_errors_ref(x, w, y, th)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-6)
    assert got.shape == (F, Q, 2)


# Edge cases for the stump contraction: shapes straddling the block
# boundaries (±1 around BC/BF/BQ after caller padding), all-negative
# weights, duplicate thresholds — for both the 2-D and the batched
# (leading task axis) grids.
@pytest.mark.parametrize("c,F,Q", [(127, 7, 127), (129, 9, 129),
                                   (128, 8, 128), (1, 1, 1),
                                   (255, 17, 257)])
def test_stump_block_boundaries(c, F, Q):
    rng = np.random.default_rng(c * 31 + F * 7 + Q)
    x = jnp.asarray(rng.standard_normal((c, F)) * 5, jnp.float32)
    w = jnp.asarray(rng.random(c), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], c), jnp.float32)
    th = jnp.asarray(rng.standard_normal((F, Q)) * 5, jnp.float32)
    got = stump_ops.stump_errors(x, w, y, th, interpret=True)
    ref = stump_errors_ref(x, w, y, th)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-6)


def test_stump_all_negative_weights():
    """wy < 0 everywhere (every example labelled −1): the accumulated
    scores are all-negative, errors must still match the oracle."""
    rng = np.random.default_rng(0)
    c, F, Q = 130, 9, 127
    x = jnp.asarray(rng.standard_normal((c, F)), jnp.float32)
    w = jnp.asarray(rng.random(c) + 0.1, jnp.float32)
    y = -jnp.ones((c,), jnp.float32)
    th = jnp.asarray(rng.standard_normal((F, Q)), jnp.float32)
    got = stump_ops.stump_errors(x, w, y, th, interpret=True)
    ref = stump_errors_ref(x, w, y, th)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-6)
    assert float(jnp.min(got)) >= -3e-5   # errors are non-negative


def test_stump_duplicate_thresholds():
    """Repeated θ values (ties with x values included) must produce
    identical columns — the ≥ comparison is exact, no fuzz."""
    rng = np.random.default_rng(1)
    c, F = 64, 4
    x = jnp.asarray(rng.integers(0, 8, (c, F)), jnp.float32)
    w = jnp.asarray(rng.random(c), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], c), jnp.float32)
    base = jnp.asarray(rng.integers(0, 8, (F, 1)), jnp.float32)
    th = jnp.tile(base, (1, 6))                    # 6 identical columns
    got = stump_ops.stump_errors(x, w, y, th, interpret=True)
    ref = stump_errors_ref(x, w, y, th)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-6)
    for q in range(1, 6):
        np.testing.assert_array_equal(np.asarray(got[:, q]),
                                      np.asarray(got[:, 0]))


@pytest.mark.parametrize("B,c,F,Q", [(1, 127, 7, 129), (3, 129, 9, 127),
                                     (2, 128, 8, 128), (4, 33, 3, 17)])
def test_stump_batched_sweep(B, c, F, Q):
    """The batched grid (leading task axis, per-task thresholds AND
    weights) against the batched oracle, at boundary shapes."""
    rng = np.random.default_rng(B * 97 + c + F + Q)
    x = jnp.asarray(rng.standard_normal((B, c, F)) * 5, jnp.float32)
    w = jnp.asarray(rng.random((B, c)), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], (B, c)), jnp.float32)
    th = jnp.asarray(rng.standard_normal((B, F, Q)) * 5, jnp.float32)
    got = stump_ops.stump_errors(x, w, y, th, interpret=True)
    ref = stump_errors_ref(x, w, y, th)
    assert got.shape == (B, F, Q, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-6)
    # each batch lane must equal its own unbatched launch
    for b in range(B):
        one = stump_ops.stump_errors(x[b], w[b], y[b], th[b],
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(one),
                                   rtol=3e-5, atol=3e-6)


def test_stump_batched_all_negative_and_duplicates():
    rng = np.random.default_rng(4)
    B, c, F, Q = 2, 129, 9, 130
    x = jnp.asarray(rng.integers(0, 6, (B, c, F)), jnp.float32)
    w = jnp.asarray(rng.random((B, c)) + 0.05, jnp.float32)
    y = -jnp.ones((B, c), jnp.float32)
    th = jnp.repeat(jnp.asarray(rng.integers(0, 6, (B, F, 1)),
                                jnp.float32), Q, axis=2)
    got = stump_ops.stump_errors(x, w, y, th, interpret=True)
    ref = stump_errors_ref(x, w, y, th)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-6)


# Histogram (tree split-finding) kernel: the parity bar is BITWISE.
# Inputs use dyadic-rational weights (multiples of 1/256), whose
# partial sums are all exactly representable in f32, so the sum is
# independent of accumulation order and kernel-vs-ref equality is
# assertable bit for bit — including on padded/ragged shapes where the
# kernel's block partition differs most from the ref einsum.
def _dyadic_hist_inputs(rng, c, F, N, bins):
    x = ((rng.integers(0, bins, (c, F)) + 0.5) / bins).astype(np.float32)
    w = (rng.integers(0, 256, (N, c)) / 256.0).astype(np.float32)
    wy = w * rng.choice([-1.0, 1.0], (N, c)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(wy)


@pytest.mark.parametrize("c,F,N,bins", [
    (128, 8, 1, 64), (130, 9, 3, 32), (1, 1, 1, 4),
    (257, 5, 4, 32), (127, 7, 2, 128),
])
def test_histogram_kernel_bitwise_parity(c, F, N, bins):
    rng = np.random.default_rng(c * 13 + F + N + bins)
    x, w, wy = _dyadic_hist_inputs(rng, c, F, N, bins)
    ref = node_histograms_ref(x, w, wy, bins)
    got = hist_ops.node_histograms(x, w, wy, bins, interpret=True)
    for g, r in zip(got, ref):
        assert g.shape == (N, F, bins)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


@pytest.mark.parametrize("B,c,F,N,bins", [
    (1, 127, 7, 2, 32), (3, 129, 5, 4, 32), (2, 128, 8, 1, 64),
    (4, 33, 3, 2, 16),
])
def test_histogram_kernel_batched_bitwise_parity(B, c, F, N, bins):
    """The task-batched grid (outermost axis folds task × node) against
    the batched oracle AND each lane's own unbatched launch."""
    rng = np.random.default_rng(B * 97 + c + F + N)
    xs, ws, wys = zip(*[_dyadic_hist_inputs(rng, c, F, N, bins)
                        for _ in range(B)])
    x, w, wy = jnp.stack(xs), jnp.stack(ws), jnp.stack(wys)
    ref = node_histograms_ref(x, w, wy, bins)
    got = hist_ops.node_histograms(x, w, wy, bins, interpret=True)
    for g, r in zip(got, ref):
        assert g.shape == (B, N, F, bins)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
    for b in range(B):
        one = hist_ops.node_histograms(x[b], w[b], wy[b], bins,
                                       interpret=True)
        for g, o in zip(got, one):
            np.testing.assert_array_equal(np.asarray(g[b]),
                                          np.asarray(o))


def test_histogram_zero_weight_rows_and_out_of_range():
    """Zero-weight rows land nowhere; x outside [0, 1) clips to the
    edge bins (the same clip predict applies, so grower and predictor
    agree even on out-of-range points)."""
    bins = 16
    x = jnp.asarray([[-0.5], [0.0], [0.999], [1.5]], jnp.float32)
    w = jnp.asarray([[1.0, 0.0, 0.5, 0.25]], jnp.float32)
    wy = w
    hw, _ = hist_ops.node_histograms(x, w, wy, bins, interpret=True)
    assert float(hw[0, 0, 0]) == 1.0               # clipped low + w=0 row
    assert float(hw[0, 0, bins - 1]) == 0.75       # 0.999 and clipped 1.5


def test_best_splits_reduction():
    """best_splits_ref finds the provably optimal (feature, bin) on a
    hand-built histogram, ties to the first flat index."""
    hw = jnp.zeros((1, 2, 4), jnp.float32)
    hwy = jnp.zeros((1, 2, 4), jnp.float32)
    # feature 1: bins [+2, +2, -3, -3] → split at q=2 is perfect
    hw = hw.at[0, 1].set(jnp.asarray([2.0, 2.0, 3.0, 3.0]))
    hwy = hwy.at[0, 1].set(jnp.asarray([2.0, 2.0, -3.0, -3.0]))
    # feature 0: all weight in one bin, pure → any split scores 0 err
    hw = hw.at[0, 0, 1].set(10.0)
    hwy = hwy.at[0, 0, 1].set(10.0)
    f, q, err = best_splits_ref(hw, hwy)
    assert float(err[0]) == 0.0
    assert int(f[0]) == 0 and int(q[0]) == 0       # first flat tie


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 4, 2, 32), (2, 128, 8, 8, 64), (1, 200, 4, 1, 16),
    (1, 256, 2, 2, 128),
])
@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, hd, window, dtype):
    rng = np.random.default_rng(S + H + window)
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), dtype)
    got = flash_ops.flash_attention(q, k, v, causal=True, window=window)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True,
        window=window).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_attention_path():
    """models/attention full_attention(use_flash=True) == einsum path."""
    from repro.configs import base
    from repro.models import attention
    cfg = base.reduced(base.get_config("deepseek-7b"))
    key = jax.random.key(0)
    p = attention.init(key, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model),
                          jnp.float32)
    pos = jnp.arange(64)[None]
    out_ein, _, _ = attention.full_attention(p, cfg, x, pos, causal=True)
    out_fl, _, _ = attention.full_attention(p, cfg, x, pos, causal=True,
                                            use_flash=True)
    np.testing.assert_allclose(np.asarray(out_ein, np.float32),
                               np.asarray(out_fl, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_vmem_budget_static():
    """BlockSpec working sets fit v5e VMEM (static check)."""
    from repro.kernels.flash_attention import kernel as FK
    from repro.kernels.histogram import kernel as HK
    from repro.kernels.mw_update import kernel as MK
    from repro.kernels.stump import kernel as SK
    vmem = 16 * 2 ** 20
    bq, bk, hd = FK.DEFAULT_BQ, FK.DEFAULT_BK, 256
    flash = (bq * hd + 2 * bk * hd + bq * bk + bq * hd + 2 * bq) * 4
    assert flash < vmem // 4
    assert MK.BLOCK * 4 * 4 < vmem // 4
    bc, bf, bqq = SK.BC, SK.BF, SK.BQ
    assert (bc * bf + bf * bqq + bc * bf * bqq) * 4 < vmem // 4
    hc, hf, hq = HK.BC, HK.BF, HK.BQ
    # x tile + 2 weight chunks + compare tile + 2 accumulated outputs
    assert (hc * hf + 2 * hc + hc * hf * hq + 2 * hf * hq) * 4 \
        < vmem // 4
