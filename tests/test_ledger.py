"""Direct unit tests for core/ledger.py bit-accounting primitives.

The property suite (test_property.py) covers these only through
generated protocol runs — and only when ``hypothesis`` is installed.
These pin the edge cases (n = 1, m = 1, T = 0) and the explicit
``hypothesis_bits`` scaling of the Theorem 4.1 bound directly.
"""

import dataclasses

import pytest

from repro.core import ledger, weak
from repro.core.types import BoostConfig
from repro.weak_tree import HistogramTrees

N = 1 << 10


def _cfg(k=4, coreset=64):
    return BoostConfig(k=k, coreset_size=coreset, domain_size=N)


# ---------------------------------------------------------------------------
# example_bits / weight_sum_bits edge cases
# ---------------------------------------------------------------------------

def test_point_bits_degenerate_domain():
    """n = 1 (and even n = 0): a point id still costs ≥ 1 bit — the
    message must exist on the wire."""
    assert ledger.point_bits(1) == 1
    assert ledger.point_bits(0) == 1
    assert ledger.point_bits(2) == 1
    assert ledger.point_bits(3) == 2
    assert ledger.example_bits(1) == 2             # id + label


def test_point_bits_powers_of_two_exact():
    for b in (1, 2, 8, 16, 31):
        assert ledger.point_bits(1 << b) == b
        assert ledger.point_bits((1 << b) + 1) == b + 1


def test_weight_sum_bits_edge_cases():
    """m = 1 and T = 0: the fixed-point encoding never degenerates to
    zero bits, and both arguments are monotone knobs."""
    assert ledger.weight_sum_bits(1, 0) == 2       # clamps m→2, T→log2 2
    assert ledger.weight_sum_bits(2, 0) == 2
    for m, T in ((1, 0), (1, 5), (256, 0), (256, 48), (1 << 20, 120)):
        assert ledger.weight_sum_bits(m, T) >= 2
        assert ledger.weight_sum_bits(m * 2, T) \
            >= ledger.weight_sum_bits(m, T)
        assert ledger.weight_sum_bits(m, T + 64) \
            >= ledger.weight_sum_bits(m, T)


def test_boost_attempt_ledger_zero_rounds():
    """rounds = 0, not stuck: no wire rounds, no hypotheses — only the
    halt control bits; stuck still charges the extra 2(a,b) round."""
    cfg = _cfg()
    cls = weak.Thresholds(n=N)
    led = ledger.boost_attempt_ledger(cfg, cls, m=256, rounds=0,
                                      stuck=False)
    assert led.bits_coresets == 0
    assert led.bits_weight_sums == 0
    assert led.bits_hypotheses == 0
    assert led.bits_control == cfg.k
    stuck = ledger.boost_attempt_ledger(cfg, cls, m=256, rounds=0,
                                        stuck=True)
    assert stuck.bits_coresets \
        == cfg.k * cfg.coreset_size * ledger.example_bits(N)
    assert stuck.bits_hypotheses == 0
    assert stuck.bits_control == 2 * cfg.k


def test_masked_ledger_all_alive_reduces_to_unmasked():
    cfg = _cfg()
    cls = weak.Thresholds(n=N)
    for rounds, stuck in ((0, False), (3, False), (3, True)):
        wire = rounds + (1 if stuck else 0)
        a = ledger.boost_attempt_ledger(cfg, cls, 256, rounds, stuck)
        b = ledger.boost_attempt_ledger_masked(
            cfg, cls, 256, rounds, stuck,
            player_rounds=wire * cfg.k,
            player_h_rounds=rounds * cfg.k, players_last=cfg.k)
        assert a == b


# ---------------------------------------------------------------------------
# theorem_41_bound: explicit hypothesis_bits scaling
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _StubClass:
    """A class whose ONLY varying knob is hypothesis_bits — isolates
    the bound's monotonicity in the hypothesis encoding."""

    n: int = N
    vc_dim: int = 1
    hyp_bits: int = 8

    def hypothesis_bits(self) -> int:
        return self.hyp_bits


def test_theorem_41_bound_monotone_in_hypothesis_bits():
    cfg = _cfg()
    prev = 0.0
    for hb in (1, 8, 64, 512, 4096):
        cur = ledger.theorem_41_bound(cfg, _StubClass(hyp_bits=hb),
                                      m=4096, opt=3)
        assert cur > prev
        prev = cur
    # strictly increasing at fixed everything-else, and linear-ish in
    # the added term: doubling hyp_bits can at most double the bound
    lo = ledger.theorem_41_bound(cfg, _StubClass(hyp_bits=64), 4096, 3)
    hi = ledger.theorem_41_bound(cfg, _StubClass(hyp_bits=128), 4096, 3)
    assert lo < hi <= 2 * lo


def test_theorem_41_bound_covers_tree_hypotheses():
    """The bound grows with the tree encoding: a depth-3 class bounds
    strictly above depth-2 at equal (m, opt), both above thresholds."""
    cfg = _cfg()
    thr = weak.Thresholds(n=N)
    t2 = HistogramTrees(num_features=8, depth=2, bins=32)
    t3 = HistogramTrees(num_features=8, depth=3, bins=32)
    assert t3.hypothesis_bits() > t2.hypothesis_bits()
    b2 = ledger.theorem_41_bound(cfg, t2, 4096, 3)
    b3 = ledger.theorem_41_bound(cfg, t3, 4096, 3)
    assert b2 < b3
    # the attempt ledger itself charges the per-class hypothesis bits
    led2 = ledger.boost_attempt_ledger(cfg, t2, 4096, 5, stuck=False)
    led3 = ledger.boost_attempt_ledger(cfg, t3, 4096, 5, stuck=False)
    assert led3.bits_hypotheses - led2.bits_hypotheses \
        == 5 * cfg.k * (t3.hypothesis_bits() - t2.hypothesis_bits())
    assert led2.total_bits <= ledger.theorem_41_bound(
        cfg, t2, 4096, 0, constant=1.5)


def test_tree_hypothesis_bits_formula():
    """nodes·(⌈log2 F⌉ + bin_bits) + leaves, across shapes."""
    for (f, d, q), want in (
            ((4, 2, 32), 3 * (2 + 5) + 4),
            ((8, 3, 64), 7 * (3 + 6) + 8),
            ((2, 1, 16), 1 * (1 + 4) + 2),
    ):
        cls = HistogramTrees(num_features=f, depth=d, bins=q)
        assert cls.hypothesis_bits() == want
        assert cls.param_dim == 1 + 2 * cls.nodes + cls.leaves
    with pytest.raises(ValueError):
        HistogramTrees(num_features=4, depth=2, bins=33)
    with pytest.raises(ValueError):
        HistogramTrees(num_features=4, depth=0)
