"""Deterministic hard-core quarantine behaviour of core/resilient.py.

Simulates the training loop's per-example NLL stream with planted label
noise at known ids: noisy examples keep a high loss EMA and never earn
MW hits, so the hard-core check must quarantine them (high noise
recall) — and must quarantine NOTHING on a clean corpus, where the
adaptive threshold (median + 2·MAD, ratio floor) sits above every
example's EMA no matter how the MW weights drift.
"""

import numpy as np

from repro.core import resilient


def _run_stream(cfg, noisy_ids, steps, seed, clean_nll=0.5,
                noisy_nll=3.0, jitter=0.05):
    rng = np.random.default_rng(seed)
    state = resilient.init_state(cfg)
    N = cfg.num_examples
    noisy = np.zeros(N, bool)
    if noisy_ids.size:
        noisy[noisy_ids] = True
    batch = 128
    for step in range(1, steps + 1):
        ids = rng.choice(N, size=batch, replace=False)
        nll = np.where(noisy[ids], noisy_nll, clean_nll)
        nll = nll + rng.normal(0.0, jitter, size=batch)
        state = resilient.update(state, ids, nll.astype(np.float32),
                                 cfg, step)
    return state


def test_planted_noise_is_quarantined():
    cfg = resilient.ResilientConfig(num_examples=1024, coreset_size=64,
                                    check_every=50)
    noisy_ids = np.arange(0, 1024, 25)            # 41 planted noisy ids
    state = _run_stream(cfg, noisy_ids, steps=600, seed=0)
    stats = resilient.quarantine_stats(state, noisy_ids=noisy_ids)
    assert stats["quarantined"] > 0
    assert stats["noise_recall"] >= 0.9, stats
    assert stats["noise_precision"] >= 0.9, stats


def test_clean_corpus_zero_quarantine():
    cfg = resilient.ResilientConfig(num_examples=1024, coreset_size=64,
                                    check_every=50)
    state = _run_stream(cfg, np.array([], int), steps=600, seed=1)
    stats = resilient.quarantine_stats(state)
    assert stats["quarantined"] == 0, stats
    assert stats["alive"] == 1024


def test_quarantine_is_deterministic():
    """Same stream seed ⇒ identical quarantine sets (no hidden state)."""
    cfg = resilient.ResilientConfig(num_examples=512, coreset_size=32,
                                    check_every=50)
    noisy_ids = np.arange(0, 512, 20)
    s1 = _run_stream(cfg, noisy_ids, steps=400, seed=3)
    s2 = _run_stream(cfg, noisy_ids, steps=400, seed=3)
    np.testing.assert_array_equal(s1.alive, s2.alive)
    assert len(s1.quarantined_at) == len(s2.quarantined_at)
    for (t1, q1), (t2, q2) in zip(s1.quarantined_at, s2.quarantined_at):
        assert t1 == t2
        np.testing.assert_array_equal(q1, q2)
