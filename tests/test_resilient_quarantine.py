"""Deterministic hard-core quarantine behaviour of core/resilient.py.

Simulates the training loop's per-example NLL stream with planted label
noise at known ids: noisy examples keep a high loss EMA and never earn
MW hits, so the hard-core check must quarantine them (high noise
recall) — and must quarantine NOTHING on a clean corpus, where the
adaptive threshold (median + 2·MAD, ratio floor) sits above every
example's EMA no matter how the MW weights drift.
"""

import numpy as np

from repro.core import resilient


def _run_stream(cfg, noisy_ids, steps, seed, clean_nll=0.5,
                noisy_nll=3.0, jitter=0.05):
    rng = np.random.default_rng(seed)
    state = resilient.init_state(cfg)
    N = cfg.num_examples
    noisy = np.zeros(N, bool)
    if noisy_ids.size:
        noisy[noisy_ids] = True
    batch = 128
    for step in range(1, steps + 1):
        ids = rng.choice(N, size=batch, replace=False)
        nll = np.where(noisy[ids], noisy_nll, clean_nll)
        nll = nll + rng.normal(0.0, jitter, size=batch)
        state = resilient.update(state, ids, nll.astype(np.float32),
                                 cfg, step)
    return state


def test_planted_noise_is_quarantined():
    cfg = resilient.ResilientConfig(num_examples=1024, coreset_size=64,
                                    check_every=50)
    noisy_ids = np.arange(0, 1024, 25)            # 41 planted noisy ids
    state = _run_stream(cfg, noisy_ids, steps=600, seed=0)
    stats = resilient.quarantine_stats(state, noisy_ids=noisy_ids)
    assert stats["quarantined"] > 0
    assert stats["noise_recall"] >= 0.9, stats
    assert stats["noise_precision"] >= 0.9, stats


def test_clean_corpus_zero_quarantine():
    cfg = resilient.ResilientConfig(num_examples=1024, coreset_size=64,
                                    check_every=50)
    state = _run_stream(cfg, np.array([], int), steps=600, seed=1)
    stats = resilient.quarantine_stats(state)
    assert stats["quarantined"] == 0, stats
    assert stats["alive"] == 1024


def test_duplicate_ids_accumulate_every_increment():
    """Regression (ISSUE 4): sampling WITH replacement repeats ids in a
    batch.  ``hits[ids] += …`` dropped all but one increment per
    duplicated id and ``nll_ema[ids] = …`` was last-write-wins; the fix
    (np.add.at + sequential EMA fold) must count every occurrence."""
    cfg = resilient.ResilientConfig(num_examples=8, coreset_size=2,
                                    check_every=1000)
    state = resilient.init_state(cfg)
    ids = np.array([3, 3, 3, 5])
    nll = np.array([0.1, 0.2, 0.3, 9.0], np.float32)
    state = resilient.update(state, ids, nll, cfg, step=1)
    # batch median is 0.25: occurrences 0.1 and 0.2 of id 3 are hits
    assert int(state.hits[3]) == 2, state.hits[3]
    assert int(state.hits[5]) == 0
    assert int(state.seen[3]) == 3 and int(state.seen[5]) == 1
    # EMA folds the three id-3 observations sequentially:
    # 0.1 → 0.7·0.1+0.3·0.2 = 0.13 → 0.7·0.13+0.3·0.3 = 0.181
    np.testing.assert_allclose(state.nll_ema[3], 0.181, rtol=1e-5)
    np.testing.assert_allclose(state.nll_ema[5], 9.0, rtol=1e-6)
    # a duplicate-free batch still takes the vectorized path, bitwise
    # equal to the sequential fold
    s1 = resilient.init_state(cfg)
    s2 = resilient.init_state(cfg)
    ids_u = np.array([0, 1, 2])
    nll_u = np.array([0.5, 1.5, 2.5], np.float32)
    resilient.update(s1, ids_u, nll_u, cfg, step=1)
    for j in range(3):
        resilient.update(s2, ids_u[j:j + 1], nll_u[j:j + 1], cfg, step=1)
    np.testing.assert_array_equal(s1.nll_ema, s2.nll_ema)
    np.testing.assert_array_equal(s1.seen, s2.seen)


def test_batch_weights_smoothboost_cap_semantics():
    """batch_weights returns cap-clipped relative weights — max exactly
    1 at the lightest-hit example, min ≥ 2^−cap, NOT normalized (the
    docstring satellite of ISSUE 4 pins the actual semantics)."""
    cfg = resilient.ResilientConfig(num_examples=16, mw_enabled=True,
                                    mw_loss_weighting=True, mw_cap_bits=3)
    state = resilient.init_state(cfg)
    state.hits[:] = np.arange(16)
    state.alive[10] = False
    ids = np.array([0, 1, 2, 3, 9, 10, 15])
    w, alive = (np.asarray(a) for a in
                resilient.batch_weights(state, ids, cfg))
    assert w.max() == 1.0                      # lightest-hit example
    assert w.min() >= 2.0 ** -cfg.mw_cap_bits  # SmoothBoost cap
    np.testing.assert_allclose(w[:4], [1.0, 0.5, 0.25, 0.125])
    assert not np.isclose(w.sum(), 1.0)        # NOT normalized
    np.testing.assert_array_equal(alive, [1, 1, 1, 1, 1, 0, 1])
    # weighting off ⇒ all-ones
    cfg_off = resilient.ResilientConfig(num_examples=16)
    w0, _ = resilient.batch_weights(state, ids, cfg_off)
    np.testing.assert_array_equal(np.asarray(w0), np.ones(7))


def test_quarantine_is_deterministic():
    """Same stream seed ⇒ identical quarantine sets (no hidden state)."""
    cfg = resilient.ResilientConfig(num_examples=512, coreset_size=32,
                                    check_every=50)
    noisy_ids = np.arange(0, 512, 20)
    s1 = _run_stream(cfg, noisy_ids, steps=400, seed=3)
    s2 = _run_stream(cfg, noisy_ids, steps=400, seed=3)
    np.testing.assert_array_equal(s1.alive, s2.alive)
    assert len(s1.quarantined_at) == len(s2.quarantined_at)
    for (t1, q1), (t2, q2) in zip(s1.quarantined_at, s2.quarantined_at):
        assert t1 == t2
        np.testing.assert_array_equal(q1, q2)
