"""Exactness of the center's weighted ERM for every hypothesis class.

The stuck/not-stuck certificate (Observation 4.3) requires the ERM to be
EXACT over the class restricted to the coreset — we verify against brute
force over all behaviours.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import weak

N = 1 << 10


def brute_force_best(cls, xs, ys, w):
    """Exhaustive ERM over a dense hypothesis grid."""
    if isinstance(cls, weak.Singletons):
        cands = [np.array([1.0, a, a, 1.0], np.float32) for a in range(N)]
    elif isinstance(cls, weak.Thresholds):
        cands = [np.array([2.0, t, t, s], np.float32)
                 for t in range(N + 1) for s in (1.0, -1.0)]
    elif isinstance(cls, weak.Intervals):
        pts = sorted(set(np.asarray(xs).tolist()))
        cands = [np.array([3.0, a, b, 1.0], np.float32)
                 for a in pts for b in pts if a <= b]
        cands.append(np.array([3.0, 1.0, 0.0, 1.0], np.float32))
    params = jnp.asarray(np.stack(cands))
    preds = cls.predict(params, jnp.asarray(xs))           # [C, m]
    errs = jnp.sum((preds != jnp.asarray(ys)[None]) * jnp.asarray(w)[None],
                   axis=-1)
    return float(jnp.min(errs))


@pytest.mark.parametrize("clsname", ["singletons", "thresholds",
                                     "intervals"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_erm_exact(clsname, seed):
    cls = weak.make_class(clsname, n=N)
    rng = np.random.default_rng(seed)
    m = 64
    xs = rng.integers(0, N, m).astype(np.int32)
    ys = rng.choice([-1, 1], m).astype(np.int8)
    w = rng.random(m).astype(np.float32)
    w /= w.sum()
    params, loss = cls.erm(jnp.asarray(xs), jnp.asarray(ys),
                           jnp.asarray(w))
    best = brute_force_best(cls, xs, ys, w)
    assert float(loss) <= best + 1e-5, (clsname, float(loss), best)
    # reported loss must equal the actual loss of the returned hypothesis
    pred = cls.predict(params, jnp.asarray(xs))
    actual = float(jnp.sum((pred != jnp.asarray(ys)) * jnp.asarray(w)))
    np.testing.assert_allclose(actual, float(loss), atol=1e-5)


@pytest.mark.parametrize("seed", [0, 1])
def test_stump_erm_exact(seed):
    cls = weak.AxisStumps(num_features=5)
    rng = np.random.default_rng(seed)
    m = 48
    xs = rng.standard_normal((m, 5)).astype(np.float32)
    ys = rng.choice([-1, 1], m).astype(np.int8)
    w = rng.random(m).astype(np.float32)
    w /= w.sum()
    params, loss = cls.erm(jnp.asarray(xs), jnp.asarray(ys),
                           jnp.asarray(w))
    # brute force: thresholds at data values per feature, both signs
    best = np.inf
    for f in range(5):
        for t in list(xs[:, f]) + [xs[:, f].max() + 1]:
            for s in (1, -1):
                pred = np.where(xs[:, f] >= t, s, -s)
                best = min(best, float(np.sum((pred != ys) * w)))
    assert float(loss) <= best + 1e-5
    pred = cls.predict(params, jnp.asarray(xs))
    np.testing.assert_allclose(
        float(jnp.sum((pred != jnp.asarray(ys)) * jnp.asarray(w))),
        float(loss), atol=1e-5)


def test_predict_broadcasting():
    cls = weak.Thresholds(n=N)
    params = jnp.asarray(np.array(
        [[2.0, 5, 5, 1.0], [2.0, 9, 9, -1.0]], np.float32))
    x = jnp.arange(12, dtype=jnp.int32)
    out = cls.predict(params, x)
    assert out.shape == (2, 12)
    assert out.dtype == jnp.int8
    single = cls.predict(params[0], x)
    assert single.shape == (12,)
    np.testing.assert_array_equal(np.asarray(single), np.asarray(out[0]))


def test_ensemble_majority():
    cls = weak.Thresholds(n=N)
    hs = jnp.asarray(np.array(
        [[2.0, 4, 4, 1.0]] * 2 + [[2.0, 8, 8, -1.0]], np.float32))
    x = jnp.asarray([2, 6, 10], jnp.int32)
    out = weak.ensemble_predict(cls, hs, 3, x)
    # votes: x=2: (-1,-1,+1) -> -1 ; x=6: (+1,+1,+1)... wait h3 at 6: 6<8 -> +1
    np.testing.assert_array_equal(np.asarray(out), [-1, 1, 1])


def test_ensemble_predict_tie_break_and_rounds_masking():
    """Direct unit coverage of weak.ensemble_predict (previously only
    exercised through engine parity): sign(0) := +1 deterministically,
    and hypotheses at t ≥ rounds never vote."""
    cls = weak.Thresholds(n=N)
    up = np.array([2.0, 4, 4, 1.0], np.float32)     # +1 for x ≥ 4
    dn = np.array([2.0, 4, 4, -1.0], np.float32)    # −1 for x ≥ 4
    x = jnp.asarray([0, 4, 9], jnp.int32)
    # two exactly opposed hypotheses ⇒ vote sum 0 everywhere ⇒ +1
    hs = jnp.asarray(np.stack([up, dn]))
    np.testing.assert_array_equal(
        np.asarray(weak.ensemble_predict(cls, hs, 2, x)), [1, 1, 1])
    # rounds masking: garbage rows beyond `rounds` must not vote —
    # with rounds=1 only `up` speaks, whatever lives at t ≥ 1
    garbage = np.full((3, 4), 7.0, np.float32)
    hs_pad = jnp.asarray(np.concatenate([up[None], garbage]))
    out1 = weak.ensemble_predict(cls, hs_pad, 1, x)
    np.testing.assert_array_equal(np.asarray(out1), [-1, 1, 1])
    # rounds=0: empty ensemble votes 0 ⇒ the +1 tie-break everywhere
    np.testing.assert_array_equal(
        np.asarray(weak.ensemble_predict(cls, hs_pad, 0, x)), [1, 1, 1])
    # a traced rounds value behaves identically (the engines pass one)
    np.testing.assert_array_equal(
        np.asarray(weak.ensemble_predict(cls, hs_pad, jnp.int32(1), x)),
        np.asarray(out1))


def test_singletons_erm_full_domain_coverage_fallback():
    """Singletons.erm's off-coreset candidate (constant −1 via a free
    point) must NOT be taken when the coreset covers ALL of [0, n) —
    there is no free point to name, even if the constant would win."""
    cls = weak.Singletons(n=3)
    # every point carries more − than + weight ⇒ every singleton is
    # worse than constant −1 (err_in = Wp + 1/9 > Wp) — yet all 3
    # domain points are present, so the fallback is unavailable
    xs = jnp.asarray([0, 0, 1, 1, 2, 2], jnp.int32)
    ys = jnp.asarray([1, -1, 1, -1, 1, -1], jnp.int8)
    w = jnp.asarray([1, 2, 1, 2, 1, 2], jnp.float32) / 9.0
    params, loss = cls.erm(xs, ys, w)
    a = float(params[1])
    assert a in (0.0, 1.0, 2.0), a          # an in-coreset candidate
    # reported loss equals the actual loss of the returned hypothesis
    pred = cls.predict(params, xs)
    actual = float(jnp.sum((pred != ys) * w))
    np.testing.assert_allclose(actual, float(loss), atol=1e-6)
    np.testing.assert_allclose(float(loss), 3 / 9 + 1 / 9, atol=1e-6)
    # same weights on a larger domain: the free point IS available and
    # the constant −1 (loss Wp) wins
    cls10 = weak.Singletons(n=10)
    params2, loss2 = cls10.erm(xs, ys, w)
    np.testing.assert_allclose(float(loss2), 3 / 9, atol=1e-6)
    assert float(params2[1]) not in (0.0, 1.0, 2.0)
    pred2 = cls10.predict(params2, xs)
    np.testing.assert_allclose(
        float(jnp.sum((pred2 != ys) * w)), float(loss2), atol=1e-6)


def test_erm_batch_matches_per_row_and_is_pad_safe():
    """erm_batch == row-by-row erm, and zero-weight (padded) examples
    leave every candidate's error untouched."""
    rng = np.random.default_rng(7)
    B, c = 5, 64
    for cls in (weak.Thresholds(n=N), weak.Intervals(n=N),
                weak.Singletons(n=N)):
        xs = jnp.asarray(rng.integers(0, N, (B, c)), jnp.int32)
        ys = jnp.asarray(rng.choice([-1, 1], (B, c)), jnp.int8)
        w = jnp.asarray(rng.random((B, c)), jnp.float32)
        pb, lb = weak.erm_batch(cls, xs, ys, w)
        for b in range(B):
            p1, l1 = cls.erm(xs[b], ys[b], w[b])
            np.testing.assert_array_equal(np.asarray(pb[b]),
                                          np.asarray(p1))
            np.testing.assert_array_equal(np.asarray(lb[b]),
                                          np.asarray(l1))
        # padding the row with w=0 examples must not change the loss
        pad_x = jnp.concatenate([xs, jnp.zeros((B, 16), jnp.int32)], -1)
        pad_y = jnp.concatenate([ys, jnp.ones((B, 16), jnp.int8)], -1)
        pad_w = jnp.concatenate([w, jnp.zeros((B, 16), jnp.float32)], -1)
        _, lp = weak.erm_batch(cls, pad_x, pad_y, pad_w)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lb),
                                   rtol=1e-6, atol=1e-7)
    # a fully padded row (all-zero weights) degenerates without NaN
    thr = weak.Thresholds(n=N)
    xs0 = jnp.zeros((2, c), jnp.int32)
    ys0 = jnp.ones((2, c), jnp.int8)
    w0 = jnp.zeros((2, c), jnp.float32)
    p0, l0 = weak.erm_batch(thr, xs0, ys0, w0)
    assert bool(jnp.all(jnp.isfinite(p0))) and bool(
        jnp.all(l0 == 0.0))
