"""Shared fixtures.  NOTE: no XLA_FLAGS device-count override here —
smoke tests and benches must see the host's real (1) device; only
launch/dryrun.py sets the 512-device override, in its own process.
"""

import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
