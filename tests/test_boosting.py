"""System behaviour of the paper's protocol (Theorems 2.2 / 4.1).

* realizable samples: BoostAttempt never gets stuck and outputs a
  consistent classifier (Lemma 4.2);
* noisy samples: AccuratelyClassify achieves E_S(f) ≤ OPT within
  ≤ OPT + 1 attempts (Observation 4.4);
* no contradicting examples ⇒ E_S(f) = 0 (Theorem 4.1);
* measured communication respects the Theorem 4.1 bound shape;
* the deterministic quantile coreset is a true 1/100-approximation;
* the shard_map production form computes the same protocol.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (approximation, boost_attempt, classify, ledger,
                        tasks, weak, weights)
from repro.core.types import BoostConfig

N = 1 << 12


def _learn(cls, task, cfg, seed=0):
    f, res = classify.learn(jnp.asarray(task.x), jnp.asarray(task.y),
                            jax.random.key(seed), cfg, cls)
    preds = f(jnp.asarray(task.flat_x))
    errs = int(weak.empirical_errors(preds, jnp.asarray(task.flat_y)))
    return f, res, errs


@pytest.mark.parametrize("clsname", ["thresholds", "intervals",
                                     "singletons"])
def test_realizable_consistent(clsname):
    cls = weak.make_class(clsname, n=N)
    cfg = BoostConfig(k=4, coreset_size=400, domain_size=N, opt_budget=4)
    task = tasks.make_task(cls, m=2048, k=4, noise=0, seed=7)
    f, res, errs = _learn(cls, task, cfg)
    assert res.attempts == 1 and not res.stuck_history[0]
    assert errs == 0


@pytest.mark.parametrize("clsname,noise,seed", [
    ("thresholds", 4, 0), ("thresholds", 8, 1), ("intervals", 4, 2),
    ("intervals", 8, 3), ("singletons", 4, 4), ("singletons", 8, 5),
])
def test_noisy_at_most_opt(clsname, noise, seed):
    cls = weak.make_class(clsname, n=N)
    cfg = BoostConfig(k=4, coreset_size=400, domain_size=N,
                      opt_budget=32)
    task = tasks.make_task(cls, m=2048, k=4, noise=noise, seed=seed)
    opt = tasks.true_opt(task)
    f, res, errs = _learn(cls, task, cfg, seed)
    assert errs <= opt, (errs, opt)
    assert res.attempts <= opt + 1           # Observation 4.4


def test_no_contradictions_zero_error():
    """noise flips distinct points; as long as the flipped point has a
    single occurrence there are no contradicting examples at the same
    point with both labels UNLESS duplicates — construct explicitly."""
    cls = weak.Thresholds(n=N)
    rng = np.random.default_rng(0)
    x = rng.choice(N, size=1024, replace=False).astype(np.int32)  # unique
    y = np.where(x >= 2000, 1, -1).astype(np.int8)
    y[:5] = -y[:5]                            # noise, but no contradictions
    cfg = BoostConfig(k=4, coreset_size=400, domain_size=N, opt_budget=32)
    xk = jnp.asarray(x.reshape(4, -1))
    yk = jnp.asarray(y.reshape(4, -1))
    f, res = classify.learn(xk, yk, jax.random.key(0), cfg, cls)
    errs = int(weak.empirical_errors(f(jnp.asarray(x)), jnp.asarray(y)))
    assert errs == 0                          # Theorem 4.1, furthermore-part


def test_communication_bound_shape():
    """Measured bits ≤ constant × OPT·k·log|S|·(coreset·log n + log|S|)."""
    cls = weak.Thresholds(n=N)
    cfg = BoostConfig(k=4, coreset_size=400, domain_size=N, opt_budget=64)
    for noise, seed in ((0, 0), (5, 1), (10, 2)):
        task = tasks.make_task(cls, m=4096, k=4, noise=noise, seed=seed)
        opt = tasks.true_opt(task)
        _, res, errs = _learn(cls, task, cfg, seed)
        bound = ledger.theorem_41_bound(cfg, cls, 4096, opt, constant=4.0)
        assert res.ledger.total_bits <= bound, (noise, res.ledger.total_bits,
                                                bound)
        # protocol must beat sending the raw data once OPT is small
        naive = ledger.naive_baseline_bits(4096, N)
        assert res.ledger.total_bits < 60 * naive  # sanity ceiling


def test_quantile_coreset_is_approximation():
    """|L_{S'}(h) − L_p(h)| ≤ 1/100 for all thresholds (c = 400)."""
    rng = np.random.default_rng(3)
    m = 2048
    x = jnp.asarray(rng.integers(0, N, m), jnp.int32)
    y = jnp.asarray(rng.choice([-1, 1], m), jnp.int8)
    hits = jnp.asarray(rng.integers(0, 12, m), jnp.int32)
    alive = jnp.asarray(rng.random(m) < 0.9)
    idx = approximation.quantile_coreset(x, y, hits, alive, c=400)
    cls = weak.Thresholds(n=N)
    grid = jnp.asarray(
        [[2.0, t, t, s] for t in range(0, N, 7) for s in (1.0, -1.0)],
        jnp.float32)
    err = approximation.approximation_error(
        idx, x, y, hits, alive, cls.predict, grid)
    assert float(err) <= 1.0 / 100.0 + 1e-6, float(err)


def test_sharded_equals_reference():
    """shard_map form on a 1-device mesh reproduces the k=1 reference."""
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    cls = weak.Thresholds(n=N)
    task = tasks.make_task(cls, m=1024, k=1, noise=0, seed=5)
    cfg = BoostConfig(k=1, coreset_size=400, domain_size=N)
    T = cfg.num_rounds(1024)
    fn = boost_attempt.boost_attempt_sharded(mesh, cfg, cls, num_rounds=T)
    x = jnp.asarray(task.x.reshape(-1))
    y = jnp.asarray(task.y.reshape(-1))
    t, stuck, hits, h_params, loss = fn(
        x, y, jnp.ones_like(x, bool), jnp.zeros_like(x), jax.random.key(0))
    assert not bool(stuck)
    g = weak.ensemble_predict(cls, h_params, int(t), x)
    assert int(weak.empirical_errors(g, y)) == 0
    # reference single-process run also consistent
    res = boost_attempt.run_boost_attempt(
        jnp.asarray(task.x), jnp.asarray(task.y),
        jnp.ones_like(jnp.asarray(task.x), bool), jax.random.key(0),
        cfg, cls)
    assert not res.stuck


def test_log_weight_math():
    rng = np.random.default_rng(1)
    hits = jnp.asarray(rng.integers(0, 40, 256), jnp.int32)
    alive = jnp.asarray(rng.random(256) < 0.8)
    # float64 oracle on host numpy: jnp.float64 would silently truncate
    # to f32 with x64 off (and now warns-as-errors under pytest.ini)
    direct = float(np.sum(np.where(np.asarray(alive),
                                   2.0 ** (-np.asarray(hits, np.float64)),
                                   0.0)))
    lw = float(weights.log_weight_sum(hits, alive))
    np.testing.assert_allclose(2.0 ** lw, direct, rtol=1e-5)
    p = weights.probs(hits, alive)
    np.testing.assert_allclose(float(jnp.sum(p)), 1.0, rtol=1e-5)
    assert float(jnp.max(jnp.where(alive, 0.0, p))) == 0.0


def test_no_center_model_equivalent():
    """§2.2: the no-center protocol (player 0 acts as center) produces
    a consistent classifier identical in outcome to the center model."""
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    cls = weak.Thresholds(n=N)
    task = tasks.make_task(cls, m=1024, k=1, noise=0, seed=9)
    cfg = BoostConfig(k=1, coreset_size=400, domain_size=N)
    T = cfg.num_rounds(1024)
    x = jnp.asarray(task.x.reshape(-1))
    y = jnp.asarray(task.y.reshape(-1))
    args = (x, y, jnp.ones_like(x, bool), jnp.zeros_like(x),
            jax.random.key(0))
    fn_c = boost_attempt.boost_attempt_sharded(mesh, cfg, cls, T)
    fn_n = boost_attempt.boost_attempt_sharded(mesh, cfg, cls, T,
                                               no_center=True)
    tc, sc, _, hc, _ = fn_c(*args)
    tn, sn, _, hn, _ = fn_n(*args)
    assert int(tc) == int(tn) and bool(sc) == bool(sn)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hn),
                               rtol=1e-6)
    g = weak.ensemble_predict(cls, hn, int(tn), x)
    assert int(weak.empirical_errors(g, y)) == 0
