"""Every noise scenario pins its guarantee (ISSUE 2 acceptance).

All protocol runs share ONE BoostConfig / class / batch shape so the
batched engine compiles exactly once for the whole module; the
adversaries differ only in the data they plant.

Pinned guarantees:

* ``clean``          — zero quarantine, one attempt, E_S(f) = 0;
* ``targeted_heavy`` — quarantine recall ≥ 0.9 on the planted points
  (observed 1.0: every corrupted point is contradicting, and a winning
  attempt has E = 0 on the alive sample, so contradicted points cannot
  survive), and E_S(f) = OPT = noise exactly;
* ``byzantine``      — protocol terminates within budget and
  E_S(f) ≤ OPT (the VC-track Theorem 4.1 bound) even when a player's
  whole shard lies;
* ``boundary``       — E_S(f) ≤ OPT with noise hugging the decision
  threshold;
* ``drift``          — multiple quarantine waves (attempts ≥ 2) and
  full recall on contradicted points as the noise front moves.
"""

import jax
import numpy as np
import pytest

from repro.core import batched, scenarios, tasks, weak
from repro.core.types import BoostConfig

N = 1 << 12
K, M, B = 4, 512, 2
CFG = BoostConfig(k=K, coreset_size=24, domain_size=N, opt_budget=32)
CLS = weak.Thresholds(n=N)


def _solve(spec, seed0=7):
    x, y, ts = scenarios.make_scenario_batch(CLS, B, M, K, spec,
                                             seed0=seed0)
    keys = jax.random.split(jax.random.key(1), B)
    res = batched.run_accurately_classify_batched(x, y, keys, CFG, CLS)
    assert bool(res.ok.all())
    return [scenarios.scenario_report(ts[b], res, b) for b in range(B)], ts


def test_clean_corpus_zero_quarantine():
    reports, _ = _solve(scenarios.ScenarioSpec(name="clean"))
    for rep in reports:
        assert rep["disputed"] == 0, rep
        assert rep["attempts"] == 1, rep
        assert rep["errors"] == 0, rep


def test_targeted_heavy_recall_and_exact_opt():
    spec = scenarios.ScenarioSpec(name="targeted_heavy", noise=8)
    reports, ts = _solve(spec)
    for rep, t in zip(reports, ts):
        # every flip hit a distinct multi-copy point ⇒ all contradicted
        assert rep["contradicted"] == spec.noise, rep
        assert rep["recall_planted"] >= 0.9, rep
        assert rep["recall_contradicted"] >= 0.9, rep
        # min(n₊,n₋) = 1 per corrupted point ⇒ E_S(f) = OPT = noise
        assert rep["opt"] == spec.noise, rep
        assert rep["errors"] <= rep["opt"], rep


def test_byzantine_player_guarantee():
    """A colluding player flips its whole shard; Theorem 4.1's
    E_S(f) ≤ OPT must survive, whichever player colludes."""
    for player in range(K):
        spec = scenarios.ScenarioSpec(name="byzantine",
                                      byzantine_player=player)
        reports, ts = _solve(spec, seed0=8)
        for rep, t in zip(reports, ts):
            assert int(t.flipped.sum()) == M // K    # the whole shard
            assert rep["guarantee_ok"], (player, rep)
    # at least one colluder position must actually hurt (OPT > 0) —
    # otherwise the scenario is vacuous for this target/seed
    spec = scenarios.ScenarioSpec(name="byzantine", byzantine_player=1)
    reports, _ = _solve(spec, seed0=8)
    assert any(rep["opt"] > 0 for rep in reports), reports


def test_boundary_noise_guarantee():
    spec = scenarios.ScenarioSpec(name="boundary", noise=8)
    reports, ts = _solve(spec)
    for rep, t in zip(reports, ts):
        assert int(t.flipped.sum()) == spec.noise
        assert rep["guarantee_ok"], rep
        assert rep["recall_contradicted"] >= 0.9, rep
        # the flips really hug the boundary: every corrupted point is
        # closer to θ than the median clean point
        theta = float(t.target_params[1])
        d = np.abs(t.flat_x.astype(np.int64) - theta)
        sel = t.flipped.reshape(-1)
        assert d[sel].max() <= np.median(d[~sel]), spec


def test_drift_waves_quarantined_across_attempts():
    spec = scenarios.ScenarioSpec(name="drift", noise=8, waves=4)
    reports, ts = _solve(spec)
    for rep, t in zip(reports, ts):
        assert int(t.flipped.sum()) == spec.noise
        assert rep["guarantee_ok"], rep
        assert rep["attempts"] >= 2, rep          # quarantine waves
        assert rep["recall_contradicted"] >= 0.9, rep
        # the planted flips span several players' regions (the front
        # actually drifts across the adversarial split)
        assert int((t.flipped.sum(axis=1) > 0).sum()) >= 2, t.flipped


def test_scenarios_deterministic_and_distinct():
    spec = scenarios.ScenarioSpec(name="drift", noise=8)
    t1 = scenarios.make_scenario_task(CLS, M, K, spec, seed=3)
    t2 = scenarios.make_scenario_task(CLS, M, K, spec, seed=3)
    np.testing.assert_array_equal(t1.y, t2.y)
    np.testing.assert_array_equal(t1.flipped, t2.flipped)
    # different adversaries corrupt different examples on the same base
    masks = {}
    for name in ("uniform", "targeted_heavy", "boundary", "drift"):
        t = scenarios.make_scenario_task(
            CLS, M, K, scenarios.ScenarioSpec(name=name, noise=8), seed=3)
        assert int(t.flipped.sum()) == 8, name
        masks[name] = t.flipped.reshape(-1)
    assert not np.array_equal(masks["uniform"], masks["targeted_heavy"])
    assert not np.array_equal(masks["boundary"], masks["drift"])


def test_unknown_scenario_rejected():
    with pytest.raises(ValueError):
        scenarios.ScenarioSpec(name="gaussian")


def test_make_batch_scenario_passthrough():
    """tasks.make_batch(scenario=...) is the same corruption stream as
    calling scenarios directly — serving and tests can't drift."""
    xa, ya, ta = tasks.make_batch(CLS, 2, M, K, 8, seed0=5,
                                  scenario="targeted_heavy")
    spec = scenarios.ScenarioSpec(name="targeted_heavy", noise=8)
    xb, yb, tb = scenarios.make_scenario_batch(CLS, 2, M, K, spec,
                                               seed0=5)
    np.testing.assert_array_equal(xa, xb)
    np.testing.assert_array_equal(ya, yb)
    for a, b in zip(ta, tb):
        np.testing.assert_array_equal(a.flipped, b.flipped)
