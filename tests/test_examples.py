"""Smoke-run every ``examples/*.py`` in-process at tiny scale.

The seed-era examples (quickstart, distributed_boosting,
resilient_training, serve_batch) were never executed by CI and could
rot silently; this runs each one through ``runpy`` with shrunken
arguments (or env knobs, for the arg-less quickstart) so an API drift
in any example fails tier-1.  Every example must also appear in
``CASES`` — adding an example without a smoke entry fails the
completeness check.
"""

import os
import runpy
import sys

import pytest

EXAMPLES = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples")

# name → (argv, env overrides)
CASES = {
    "quickstart": ([], {"QUICKSTART_M": "512", "QUICKSTART_NOISE": "3"}),
    "distributed_boosting": (["--smoke"], {}),
    "resilient_training": (["--smoke"], {}),
    "serve_batch": (["--archs", "qwen3-32b", "--batch", "1",
                     "--gen", "2"], {}),
    "batched_classify": (["--batch", "2", "--m", "64", "--k", "2",
                          "--noise", "1"], {}),
    "sharded_scenarios": (["--batch", "1", "--m", "64", "--k", "2",
                           "--noise", "1", "--coreset", "16"], {}),
    "serving": (["--requests", "6", "--rate", "500"], {}),
    "fault_tolerance": (["--batch", "1", "--m", "128", "--k", "4",
                         "--noise", "1"], {}),
    "tree_boosting": (["--batch", "1", "--m", "128", "--noise", "2"],
                      {}),
}


def _example_names():
    return sorted(
        f[:-3] for f in os.listdir(EXAMPLES)
        if f.endswith(".py") and not f.startswith("_"))


def test_every_example_has_a_smoke_case():
    assert set(_example_names()) == set(CASES), (
        "examples/ and CASES drifted — give every example a tiny-scale "
        "smoke entry")


@pytest.mark.parametrize("name", sorted(CASES))
def test_example_runs(name, monkeypatch, capsys):
    argv, env = CASES[name]
    path = os.path.join(EXAMPLES, f"{name}.py")
    monkeypatch.setattr(sys, "argv", [path] + argv)
    for k, v in env.items():
        monkeypatch.setenv(k, v)
    runpy.run_path(path, run_name="__main__")
    # every example narrates what it did; silence means it didn't run
    assert capsys.readouterr().out.strip()


def test_documented_serve_flags_parse():
    """The flags the quickstart/TESTING.md point at must parse.

    quickstart.py and TESTING.md tell users to reach for
    ``--comm-mode``/``--vote-topk`` (PR 7's distributed tree growth);
    a CLI rename would orphan that advice silently — the parser is the
    contract, so parse the documented invocations against it.
    """
    from repro.launch.serve import build_parser
    ap = build_parser()
    args = ap.parse_args(
        ["--workload", "classify", "--cls", "tree",
         "--comm-mode", "voting", "--vote-topk", "1"])
    assert args.comm_mode == "voting" and args.vote_topk == 1
    args = ap.parse_args(["--comm-mode", "histogram"])
    assert args.comm_mode == "histogram"
    with pytest.raises(SystemExit):       # invalid mode must be refused
        ap.parse_args(["--comm-mode", "telepathy"])
