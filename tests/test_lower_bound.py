"""Direct unit tests for core/lower_bound.py (Theorem 2.3 reduction).

Previously only smoke-covered via test_substrate.py; these pin the
actual content of the Kane–Livni–Moran–Yehudayoff mapping:

* the constructed sample realises Lemma 5.1 exactly (label layout,
  contradiction structure, OPT values);
* the protocol π' decides DISJ correctly on both answers;
* E_S(f) equals OPT on intersecting instances and is ≥ w(x)+w(y) on
  disjoint ones (the decision margin);
* measured communication grows with OPT ≈ r — the Ω(T(n)) direction.
"""

import numpy as np
import pytest

from repro.core import lower_bound
from repro.core.types import BoostConfig

N = 1 << 12
CFG = BoostConfig(k=2, coreset_size=400, domain_size=N, opt_budget=24)


def test_disj_sample_construction_matches_lemma_5_1():
    xbits = np.array([1, 0, 1, 0, 0], np.int8)
    ybits = np.array([0, 0, 1, 1, 0], np.int8)
    x, y = lower_bound.disj_to_sample(xbits, ybits, N)
    assert x.shape == (2, 5) and y.shape == (2, 5)
    # both players hold all points [0, r); labels are (−1)^{1−bit}
    np.testing.assert_array_equal(np.asarray(x[0]), np.arange(5))
    np.testing.assert_array_equal(np.asarray(x[1]), np.arange(5))
    np.testing.assert_array_equal(np.asarray(y[0]),
                                  np.where(xbits == 1, 1, -1))
    np.testing.assert_array_equal(np.asarray(y[1]),
                                  np.where(ybits == 1, 1, -1))
    # contradiction structure: point i is contradicting iff x_i ≠ y_i
    contradicted = np.asarray(y[0]) != np.asarray(y[1])
    np.testing.assert_array_equal(contradicted, xbits != ybits)


@pytest.mark.parametrize("r,weight,seed", [(8, 3, 0), (16, 5, 1),
                                           (32, 12, 2)])
def test_disj_decided_correctly_both_answers(r, weight, seed):
    rng = np.random.default_rng(seed)
    for disjoint in (True, False):
        xbits, ybits = lower_bound.random_disj_instance(
            rng, r=r, weight=weight, disjoint=disjoint)
        out = lower_bound.solve_disjointness(xbits, ybits, N, CFG,
                                             seed=seed)
        assert out.disjoint_decided == disjoint, (r, weight, disjoint)
        wx, wy = int(xbits.sum()), int(ybits.sum())
        if disjoint:
            # Lemma 5.1: every classifier errs ≥ w(x)+w(y); the protocol
            # meets that with equality (it is pointwise optimal)
            assert out.errors >= wx + wy, out
            assert out.opt == wx + wy
        else:
            # best singleton errs exactly w(x)+w(y)−2, and E_S(f) ≤ OPT
            # forces equality
            assert out.opt == wx + wy - 2
            assert out.errors == out.opt, out
        assert out.attempts <= CFG.opt_budget


def test_measured_bits_grow_with_opt():
    """The Ω(T(n)) direction: communication on the hard instances must
    grow with r ≈ OPT (Theorem 2.3's matching upper bound)."""
    rng = np.random.default_rng(0)
    bits = []
    for r in (8, 32, 96):
        per_answer = []
        for disjoint in (True, False):
            xbits, ybits = lower_bound.random_disj_instance(
                rng, r=r, weight=r // 2, disjoint=disjoint)
            out = lower_bound.solve_disjointness(xbits, ybits, N, CFG,
                                                 seed=r)
            assert out.disjoint_decided == disjoint
            per_answer.append(out.total_bits)
        bits.append(int(np.mean(per_answer)))
    assert bits[0] < bits[1] < bits[2], bits
