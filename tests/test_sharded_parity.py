"""boost_attempt_sharded ≡ run_boost_attempt on a real 2-device mesh.

The device count must be fixed before jax initialises, so the actual
comparison runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (the same
pattern launch/dryrun.py uses).  Asserts identical hypotheses and
stuck verdicts for both the center and the §2.2 no-center model.
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import boost_attempt, tasks, weak
from repro.core.types import BoostConfig
from repro.launch.mesh import make_mesh_compat

assert jax.device_count() == 2, jax.devices()

N = 1 << 12
cls = weak.Thresholds(n=N)
k, m = 2, 1024
cfg = BoostConfig(k=k, coreset_size=200, domain_size=N)
T = cfg.num_rounds(m)

for noise, seed in ((0, 5), (3, 8)):
    task = tasks.make_task(cls, m=m, k=k, noise=noise, seed=seed)
    xk = jnp.asarray(task.x)          # [2, m/2] — one shard per device
    yk = jnp.asarray(task.y)
    ref = boost_attempt.run_boost_attempt(
        xk, yk, jnp.ones_like(xk, bool), jax.random.key(0), cfg, cls)

    mesh = make_mesh_compat((2,), ("data",))
    x = xk.reshape(-1)
    y = yk.reshape(-1)
    args = (x, y, jnp.ones_like(x, bool), jnp.zeros_like(x),
            jax.random.key(0))
    for no_center in (False, True):
        fn = boost_attempt.boost_attempt_sharded(
            mesh, cfg, cls, num_rounds=T, no_center=no_center)
        t, stuck, hits, h_params, loss = fn(*args)
        assert bool(stuck) == ref.stuck, (no_center, noise)
        assert int(t) == ref.rounds, (no_center, noise, int(t), ref.rounds)
        np.testing.assert_array_equal(
            np.asarray(h_params)[:int(t)],
            np.asarray(ref.hypotheses)[:ref.rounds],
            err_msg=f"no_center={no_center} noise={noise}")
        if not ref.stuck:
            g = weak.ensemble_predict(cls, h_params, int(t), x)
            assert int(weak.empirical_errors(g, y)) == 0
print("SHARDED_PARITY_OK")
"""


@pytest.mark.xdist_group(name="device_mesh_subprocess")
def test_sharded_parity_two_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARDED_PARITY_OK" in out.stdout
