"""Continuous-batching scheduler: zero steady-state recompiles, bitwise
parity with the one-shot engines, and a compile cache whose eviction is
real.

The acceptance bar (ISSUE 3): a steady-state stream of ≥ 200
mixed-shape requests across ≥ 3 buckets completes with 0 recompiles
after warmup, with every request's output bit-identical to the
corresponding one-shot engine run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched, classify, tasks, weak
from repro.launch import scheduler as S

SHAPES = [
    {"m": 64, "k": 2, "noise": 0},
    {"m": 96, "k": 2, "noise": 1},
    {"m": 128, "k": 2, "noise": 2, "scenario": "drift"},
]
# three mloc lattice points ⇒ the 200-request stream hits ≥ 3 distinct
# buckets no matter how the queue depths fall
LATTICE = S.BucketLattice(b_sizes=(2, 4), mloc_sizes=(32, 48, 64))
COMMON = dict(coreset_size=48, opt_budget=6)


def _stream(n, engine="batched", rate=500.0, seed=3):
    arrivals = S.poisson_trace(n, rate_per_s=rate, seed=seed)
    return S.make_request_stream(n, arrivals, SHAPES, seed0=100,
                                 engine=engine, **COMMON)


def _assert_one_shot_parity(sched, c):
    """Completion lane ≡ the one-shot engine run of the same request."""
    one = sched.one_shot(c.request)
    assert bool(c.result.ok[c.lane]) == bool(one.ok[0])
    assert int(c.result.attempts[c.lane]) == int(one.attempts[0])
    assert int(c.result.rounds[c.lane]) == int(one.rounds[0])
    np.testing.assert_array_equal(c.result.hypotheses[c.lane],
                                  one.hypotheses[0])
    np.testing.assert_array_equal(c.result.disputed[c.lane],
                                  one.disputed[0])
    if c.ok:
        ref, got = one.per_task(0), c.per_task()
        assert ref.stuck_history == got.stuck_history
        for f in ("bits_coresets", "bits_weight_sums",
                  "bits_hypotheses", "bits_control", "bits_dispute"):
            assert getattr(ref.ledger, f) == getattr(got.ledger, f), f


def test_stream_200_requests_zero_recompiles_bitwise_parity():
    reqs = _stream(200)
    sched = S.BoostScheduler(lattice=LATTICE, policy="pack")
    sched.warm(reqs, b_sizes=LATTICE.b_sizes + (1,))  # +B=1: one_shot
    warm_compiles = sched.cache.stats.compiles
    assert warm_compiles > 0
    jit_cache0 = batched._classify_batched_jit._cache_size()

    done = sched.run_stream(reqs)

    # every request served, ≥ 3 distinct buckets actually hit
    assert len(done) == len(reqs)
    buckets = {(c.bucket.B, c.bucket.mloc) for c in done}
    assert len(buckets) >= 3, buckets
    # ZERO recompiles in steady state — by the scheduler's own compile
    # counter AND by the engine's jit cache (the AOT path must never
    # fall back to implicit jit compilation)
    assert sched.cache.stats.compiles == warm_compiles
    assert sched.cache.stats.misses == warm_compiles
    assert sched.cache.stats.hits >= sched.stats.dispatches
    assert batched._classify_batched_jit._cache_size() == jit_cache0

    # bitwise parity with the one-shot engine for EVERY request (cache
    # stays warm: one_shot shares the B=1 buckets, so 200 checks are
    # 200 cache hits)
    for c in done:
        _assert_one_shot_parity(sched, c)
    assert sched.cache.stats.compiles == warm_compiles


def test_scheduler_matches_host_reference():
    """A served lane reproduces the host loop on the same padded mask —
    the scheduler inherits the engines' reference-parity, padding and
    lane stacking included."""
    arrivals = np.zeros(8)
    shapes = [{"m": 64, "k": 2, "noise": 1},      # exact fit: mloc 32
              {"m": 80, "k": 2, "noise": 1}]     # padded: mloc 40 → 48
    reqs = S.make_request_stream(8, arrivals, shapes, seed0=40,
                                 **COMMON)
    sched = S.BoostScheduler(lattice=LATTICE, policy="fill",
                             fill_wait_s=10.0)
    sched.warm(reqs)
    done = sched.run_stream(reqs)
    assert len(done) == 8
    picks = {}
    for c in done:
        picks.setdefault(c.request.m, c)
    for m in (64, 80):
        c = picks[m]
        req = c.request
        task = c.task
        mloc_b = LATTICE.bucket_mloc(req.m // req.k)
        x, y, alive = tasks.pad_shards(task.x, task.y, mloc_b)
        ref = classify.run_accurately_classify(
            jnp.asarray(x), jnp.asarray(y), req.make_key(),
            req.make_cfg(), req.make_cls(), alive=jnp.asarray(alive))
        got = c.per_task()
        assert ref.attempts == got.attempts
        assert ref.stuck_history == got.stuck_history
        np.testing.assert_array_equal(
            np.asarray(ref.hypotheses)[:ref.rounds],
            np.asarray(got.hypotheses)[:got.rounds])
        np.testing.assert_array_equal(
            np.unique(np.asarray(ref.dispute_x)),
            np.unique(np.asarray(got.dispute_x)))
        if req.m == 64:       # exact fit ⇒ identical bit accounting too
            assert ref.ledger.total_bits == got.ledger.total_bits


def test_second_admission_same_bucket_zero_compiles():
    """The compile-cache satellite: a second admission in the same
    bucket performs zero recompiles (scheduler counter + jit cache)."""
    reqs = _stream(4, rate=1e-3, seed=1)   # slow trace ⇒ one per dispatch
    same = [S.Request(rid=r.rid, m=64, k=2, noise=0, seed=r.seed,
                      arrival_s=r.arrival_s, **COMMON)
            for r in reqs]
    sched = S.BoostScheduler(lattice=LATTICE)
    for r in same[:2]:
        sched.submit(r)
    sched.step()
    first = sched.cache.stats.compiles
    assert first == 1
    jit_cache0 = batched._classify_batched_jit._cache_size()
    for r in same[2:]:
        sched.submit(r)
    done, _ = sched.step()
    assert done and sched.cache.stats.compiles == first
    assert sched.cache.stats.hits == 1
    assert batched._classify_batched_jit._cache_size() == jit_cache0


def test_cache_eviction_recompiles_exactly_once_unit():
    """LRU semantics with counting builders (no engines)."""
    cache = S.CompileCache(capacity=1)
    built = []

    def builder(tag):
        def build():
            built.append(tag)
            return tag
        return build

    a = S.BucketKey(compat="A", B=1, mloc=32)
    b = S.BucketKey(compat="B", B=1, mloc=32)
    assert cache.get(a, builder("a")) == "a"
    assert cache.get(b, builder("b")) == "b"      # evicts a
    assert cache.stats.evictions == 1
    assert cache.get(a, builder("a")) == "a"      # rebuilt exactly once
    assert built == ["a", "b", "a"]
    assert cache.get(a, builder("a")) == "a"      # now a hit
    assert built == ["a", "b", "a"]
    assert cache.stats == S.CacheStats(
        hits=1, misses=3, evictions=2, compiles=3,
        compile_s=cache.stats.compile_s)


def test_cache_eviction_really_recompiles_engine_programs():
    """Past the cap the executable is freed: re-admitting the evicted
    bucket lowers+compiles again (exactly once), and the recompiled
    program returns bit-identical results."""
    lattice = S.BucketLattice(b_sizes=(1,), mloc_sizes=(32, 64))
    sched = S.BoostScheduler(lattice=lattice, cache_capacity=1)
    req_a = S.Request(rid=0, m=64, k=2, noise=1, seed=5, **COMMON)
    req_b = S.Request(rid=1, m=128, k=2, noise=1, seed=6, **COMMON)

    sched.submit(req_a)
    out1, _ = sched.step()
    assert sched.cache.stats.compiles == 1
    sched.submit(req_b)                    # different bucket: evicts A
    sched.step()
    assert sched.cache.stats.compiles == 2
    assert sched.cache.stats.evictions == 1
    sched.submit(req_a)                    # recompiles A exactly once
    out2, _ = sched.step()
    assert sched.cache.stats.compiles == 3
    sched.submit(req_a)                    # same bucket again: a hit
    out3, _ = sched.step()
    assert sched.cache.stats.compiles == 3
    assert sched.cache.stats.hits == 1
    for o in (out2, out3):                 # recompile changed no bits
        np.testing.assert_array_equal(o[0].result.hypotheses[0],
                                      out1[0].result.hypotheses[0])


def test_sharded_stream_parity_and_wire_ledger():
    """Sharded completions validate Theorem 4.1 accounting against the
    measured collective payloads, and match the one-shot sharded run."""
    reqs = _stream(12, engine="sharded", seed=7)
    sched = S.BoostScheduler(lattice=LATTICE)
    sched.warm(reqs, b_sizes=LATTICE.b_sizes + (1,))
    warm_compiles = sched.cache.stats.compiles
    done = sched.run_stream(reqs)
    assert len(done) == 12
    assert sched.cache.stats.compiles == warm_compiles
    validated = 0
    for c in done:
        if c.ok:
            report = c.validate_ledger()
            assert report["bits_coresets"] > 0
            validated += 1
    assert validated > 0
    for c in done[::4]:
        _assert_one_shot_parity(sched, c)


def test_bucket_lattice_rounding():
    lat = S.BucketLattice(b_sizes=(2, 4), mloc_sizes=(32, 64))
    assert lat.bucket_mloc(9) == 32
    assert lat.bucket_mloc(32) == 32
    assert lat.bucket_mloc(33) == 64
    with pytest.raises(ValueError):
        lat.bucket_mloc(65)
    with pytest.raises(ValueError):       # not IndexError
        S.BucketLattice(mloc_sizes=()).bucket_mloc(4)
    assert lat.bucket_b(1) == 2
    assert lat.bucket_b(3) == 4
    assert lat.bucket_b(99) == 4
    assert lat.max_b == 4


def test_pad_shards_masks_dead_rows():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 100, (2, 5)).astype(np.int32)
    y = rng.choice([-1, 1], (2, 5)).astype(np.int8)
    xp, yp, alive = tasks.pad_shards(x, y, 8)
    assert xp.shape == (2, 8) and alive.shape == (2, 8)
    np.testing.assert_array_equal(xp[:, :5], x)
    np.testing.assert_array_equal(xp[:, 5:], np.repeat(x[:, -1:], 3, 1))
    assert alive[:, :5].all() and not alive[:, 5:].any()
    xs, ys, al = tasks.pad_shards(x, y, 5)     # exact fit: no copy
    assert xs is x and ys is y and al.all()
    with pytest.raises(ValueError):
        tasks.pad_shards(x, y, 4)
    # feature track pads rows
    xf = rng.standard_normal((2, 5, 3)).astype(np.float32)
    xfp, _, _ = tasks.pad_shards(xf, y, 8)
    assert xfp.shape == (2, 8, 3)
    np.testing.assert_array_equal(xfp[:, 5:], np.repeat(xf[:, -1:], 3, 1))


def test_stack_for_dispatch_fills_with_live_lane():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 100, (2, 4)).astype(np.int32)
    y = rng.choice([-1, 1], (2, 4)).astype(np.int8)
    alive = np.ones((2, 4), bool)
    k0, k1 = jax.random.split(jax.random.key(0))
    xb, yb, ab, keys, n_real = batched.stack_for_dispatch(
        [(x, y, alive, k0), (x + 1, y, alive, k1)], 4)
    assert n_real == 2 and xb.shape == (4, 2, 4)
    np.testing.assert_array_equal(xb[2], xb[0])     # filler = lane 0
    np.testing.assert_array_equal(xb[3], xb[0])
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(keys[2])),
        np.asarray(jax.random.key_data(k0)))
    with pytest.raises(ValueError):
        batched.stack_for_dispatch([], 4)
    with pytest.raises(ValueError):
        batched.stack_for_dispatch([(x, y, alive, k0)] * 5, 4)


def test_arrival_traces():
    arr = S.poisson_trace(50, rate_per_s=100.0, seed=2)
    assert arr.shape == (50,) and np.all(np.diff(arr) >= 0)
    assert 0.1 < arr[-1] < 5.0           # ~0.5 s expected span
    burst = S.bursty_trace(50, rate_per_s=100.0, burst=8, seed=2)
    assert burst.shape == (50,) and np.all(np.diff(burst) >= 0)
    # arrivals land in bursts: at most ceil(50/8) distinct stamps
    assert len(np.unique(burst)) <= 7
    # same mean rate ballpark
    assert 0.1 < burst[-1] < 5.0


def test_fill_policy_two_bucket_burst_dispatches_full_batch():
    """Regression (ISSUE 7 satellite): the fill hold must scan EVERY
    bucket queue.  With an older lone head in bucket A and a full max-B
    burst in bucket B, the old single-queue ``_fill_deadline`` held the
    ready batch for the whole fill window; the fix dispatches it
    immediately, so the burst's head latency stays far under
    fill_wait_s."""
    fill_wait = 30.0
    reqs = [S.Request(rid=0, m=64, k=2, noise=0, seed=1,
                      arrival_s=0.0, **COMMON)]          # bucket mloc 32
    reqs += [S.Request(rid=1 + i, m=96, k=2, noise=0, seed=2 + i,
                       arrival_s=1e-3, **COMMON)          # bucket mloc 48
             for i in range(LATTICE.max_b)]              # a FULL batch
    # a straggler far out keeps the fill hold live while the burst waits
    reqs.append(S.Request(rid=9, m=64, k=2, noise=0, seed=9,
                          arrival_s=3 * fill_wait, **COMMON))
    sched = S.BoostScheduler(lattice=LATTICE, policy="fill",
                             fill_wait_s=fill_wait)
    sched.warm(reqs)
    done = sched.run_stream(reqs)
    assert len(done) == len(reqs)
    burst = [c for c in done if c.request.m == 96]
    assert len(burst) == LATTICE.max_b
    # one full-B dispatch, not max_b trickles
    assert {c.bucket.B for c in burst} == {LATTICE.max_b}
    assert len({id(c.result) for c in burst}) == 1
    # head latency: admitted as soon as the server is free — far under
    # the fill window the old code charged (the only wait is at most
    # one warm dispatch of the lone bucket-A head in front of it)
    assert max(c.queue_wait_s for c in burst) < fill_wait / 2, \
        [c.queue_wait_s for c in burst]


def test_padded_requests_counter_counts_only_padded_shapes():
    """m=64,k=2 fits mloc 32 exactly; m=80,k=2 pads 40 → 48."""
    sched = S.BoostScheduler(lattice=LATTICE)
    sched.submit(S.Request(rid=0, m=64, k=2, **COMMON))
    assert sched.stats.padded_requests == 0
    sched.submit(S.Request(rid=1, m=80, k=2, **COMMON))
    assert sched.stats.padded_requests == 1
    sched.submit(S.Request(rid=2, m=80, k=2, seed=1, **COMMON))
    assert sched.stats.padded_requests == 2


def test_stats_note_accumulates_per_bucket_occupancy():
    """note() tracks (served, capacity) per bucket so occupancy is
    derivable without re-walking completions."""
    stats = S.SchedulerStats()
    compat = S.CompatKey(engine="batched", cfg=None, cls=None)
    b4 = S.BucketKey(compat=compat, B=4, mloc=32)
    b2 = S.BucketKey(compat=compat, B=2, mloc=64)
    stats.note(b4, 3, 4)
    stats.note(b4, 4, 4)
    stats.note(b2, 1, 2)
    assert stats.dispatches == 3
    assert stats.served == 8
    assert stats.filler_lanes == 2
    assert stats.per_bucket[(4, 32, "batched")] == (7, 8)
    assert stats.per_bucket[(2, 64, "batched")] == (1, 2)


def test_preempt_resume_counters_and_metrics_export(tmp_path):
    """stats.preemptions/resumes count injected faults, and the whole
    stats surface exports through the metrics registry (satellite of
    the observability tentpole)."""
    from repro.obs import metrics as M

    reqs = _stream(4, rate=1e-3, seed=9)
    sched = S.BoostScheduler(lattice=LATTICE, ckpt_dir=str(tmp_path),
                             preempt={0: 1, 1: 1})
    done = sched.run_stream(reqs)
    assert len(done) == 4
    # seq 0 preempted; seq 1 is its resume, preempted AGAIN; seq 2
    # completes the batch
    assert sched.stats.preemptions == 2
    assert sched.stats.resumes == 2

    reg = M.MetricsRegistry()
    M.publish_scheduler_stats(sched.stats, reg)
    M.publish_cache_stats(sched.cache.stats, reg)
    out = reg.to_dict()
    assert out["scheduler.preemptions"]["value"] == 2
    assert out["scheduler.resumes"]["value"] == 2
    assert (out["scheduler.padded_requests"]["value"]
            == sched.stats.padded_requests)
    assert (out["scheduler.dispatches"]["value"]
            == sched.stats.dispatches)
    assert (out["scheduler.compile_cache.compiles"]["value"]
            == sched.cache.stats.compiles)
    # one occupancy gauge per bucket, equal to served/capacity
    for key, (served, cap) in sched.stats.per_bucket.items():
        tag = f"B{key[0]}_mloc{key[1]}_{key[2]}"
        assert out[f"scheduler.bucket.{tag}.served"]["value"] == served
        assert out[f"scheduler.bucket.{tag}.capacity"]["value"] == cap
        assert (out[f"scheduler.bucket.{tag}.occupancy"]["value"]
                == served / cap)


def test_fill_policy_batches_fuller_than_pack():
    """Under a trickle of arrivals, fill holds for full batches while
    pack dispatches eagerly — fewer, fuller dispatches."""
    n = 8
    arrivals = np.arange(n) * 1e-4
    reqs = S.make_request_stream(n, arrivals,
                                 [{"m": 64, "k": 2, "noise": 0}],
                                 seed0=0, **COMMON)
    cache = S.CompileCache()
    fill = S.BoostScheduler(lattice=LATTICE, policy="fill",
                            fill_wait_s=10.0, cache=cache)
    fill.warm(reqs)
    done_fill = fill.run_stream(reqs)
    assert len(done_fill) == n
    assert fill.stats.dispatches == n // LATTICE.max_b
    assert fill.stats.filler_lanes == 0
    pack = S.BoostScheduler(lattice=LATTICE, policy="pack",
                            cache=cache)
    done_pack = pack.run_stream(reqs)
    assert len(done_pack) == n
    assert pack.stats.dispatches >= fill.stats.dispatches
